"""Pallas kernel: fused momentum-SGD update.

One VMEM pass computes ``m' = beta*m + g`` and ``x' = x - lr*m'`` instead of
three elementwise kernels (the fusion CUDA training stacks get from fused
optimizers). lr/beta enter as a tiny ``[2]`` f32 operand replicated to every
block, so the same compiled artifact serves any schedule — the Rust
coordinator owns the learning-rate policy.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 16384


def _fused_sgd_kernel(h_ref, x_ref, g_ref, m_ref, xo_ref, mo_ref):
    lr = h_ref[0]
    beta = h_ref[1]
    m_new = beta * m_ref[...] + g_ref[...]
    xo_ref[...] = x_ref[...] - lr * m_new
    mo_ref[...] = m_new


@functools.partial(jax.jit, static_argnames=("block",))
def fused_sgd(x, grad, momentum, lr_beta, *, block=DEFAULT_BLOCK):
    """Fused momentum update.

    Args:
      x, grad, momentum: ``[d]`` tensors of the same dtype.
      lr_beta: ``[2]`` f32 tensor ``[lr, beta]``.
      block: flat tile size.

    Returns:
      ``(x_new, m_new)``.
    """
    d = x.shape[0]
    assert grad.shape == (d,) and momentum.shape == (d,)
    assert lr_beta.shape == (2,)
    grid = (pl.cdiv(d, block),)
    return pl.pallas_call(
        _fused_sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), x.dtype),
            jax.ShapeDtypeStruct((d,), x.dtype),
        ],
        interpret=True,
    )(lr_beta.astype(jnp.float32), x, grad, momentum)
