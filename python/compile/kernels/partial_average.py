"""Pallas kernel: partial-averaging combine (paper eq. (5)).

The compute hot-spot of `neighbor_allreduce`: combine the local tensor with
``k`` received neighbor tensors under scalar weights,

    out = w[0] * x + sum_j w[j+1] * neighbors[j].

TPU adaptation (DESIGN.md §Hardware-Adaptation): on GPU this is a strided
elementwise kernel over a ``[k+1, d]`` buffer; here the flat ``d`` axis is
tiled into VPU-aligned ``(8, 128)``-multiples via ``BlockSpec`` and the
small neighbor axis stays resident in VMEM for each block, so every block
makes one HBM->VMEM pass per operand. With k <= 8 and the default block of
16384 f32 the VMEM working set is ~590 KB — comfortably double-bufferable
inside the ~16 MB VMEM budget.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls; numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flat block size: 128 lanes x 8 sublanes x 16 = one comfortable VMEM tile.
DEFAULT_BLOCK = 16384


def _combine_kernel(w_ref, x_ref, nb_ref, o_ref):
    """One block: o = w[0]*x + sum_k w[k+1]*nb[k] (f32 accumulation)."""
    w = w_ref[...]
    acc = w[0] * x_ref[...].astype(jnp.float32)
    k = nb_ref.shape[0]
    for j in range(k):  # k is static (trace-time) — unrolled over VMEM rows
        acc += w[j + 1] * nb_ref[j, :].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def partial_average(x, neighbors, weights, *, block=DEFAULT_BLOCK):
    """Pallas partial-averaging combine.

    Args:
      x: ``[d]`` local tensor (f32 or bf16).
      neighbors: ``[k, d]`` stacked neighbor tensors (same dtype).
      weights: ``[k+1]`` f32 combine weights, self first.
      block: flat tile size (multiple of 128).

    Returns:
      ``[d]`` combined tensor.
    """
    d = x.shape[0]
    k = neighbors.shape[0]
    assert neighbors.shape == (k, d), (neighbors.shape, (k, d))
    assert weights.shape == (k + 1,), weights.shape
    if k == 0:
        # Degenerate combine (isolated node): pure self-scaling; a
        # zero-height block has no interpreter representation.
        return (weights[0].astype(jnp.float32) * x).astype(x.dtype)
    grid = (pl.cdiv(d, block),)
    return pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k + 1,), lambda i: (0,)),        # weights: replicated
            pl.BlockSpec((block,), lambda i: (i,)),        # x: one tile
            pl.BlockSpec((k, block), lambda i: (0, i)),    # neighbors: k rows of the tile
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(weights.astype(jnp.float32), x, neighbors)


def vmem_bytes(k, block=DEFAULT_BLOCK, dtype_bytes=4):
    """Estimated VMEM working set per grid step (for DESIGN.md §Perf)."""
    return (k + 2) * block * dtype_bytes + (k + 1) * 4
