"""Pallas kernel: MXU-tiled matmul.

The transformer MLP's GEMM, tiled for the TPU systolic array: 128x128
output tiles with a k-loop grid axis accumulating in the output block
(f32). This is the TPU re-think of the paper's cuBLAS/tensor-core GEMMs
(DESIGN.md §Hardware-Adaptation): ``BlockSpec`` expresses the HBM<->VMEM
schedule that CUDA expresses with threadblocks + shared memory.

Working set per grid step at 128^3: 3 tiles x 64 KB = 192 KB of VMEM,
leaving room for double buffering; the MXU sees one 128x128x128 multiply
per step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128
TILE_N = 128
TILE_K = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "tile_k"))
def matmul(a, b, *, tile_m=TILE_M, tile_n=TILE_N, tile_k=TILE_K):
    """Tiled ``a @ b`` with f32 accumulation.

    Args:
      a: ``[m, k]``; b: ``[k, n]`` (f32 or bf16).

    Returns:
      ``[m, n]`` in ``a``'s dtype.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    # Zero-pad to tile multiples: interpret-mode pallas pads out-of-bounds
    # *loads* with NaN (to catch padding bugs), which would poison the k-axis
    # accumulation. Padding with explicit zeros keeps edge tiles exact.
    mp, kp, np_ = (
        pl.cdiv(m, tile_m) * tile_m,
        pl.cdiv(k, tile_k) * tile_k,
        pl.cdiv(n, tile_n) * tile_n,
    )
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    grid = (mp // tile_m, np_ // tile_n, kp // tile_k)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_k, tile_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(a, b)
    return out[:m, :n]


@jax.custom_vjp
def matmul_diff(a, b):
    """Differentiable wrapper: the interpret-mode kernel's grid accumulation
    (`program_id` inside the block) has no JVP rule, so backward re-uses the
    kernel itself: dA = dY @ B^T, dB = A^T @ dY."""
    return matmul(a, b)


def _matmul_fwd(a, b):
    return matmul(a, b), (a, b)


def _matmul_bwd(res, dy):
    a, b = res
    da = matmul(dy, b.T)
    db = matmul(a.T, dy)
    return da, db


matmul_diff.defvjp(_matmul_fwd, _matmul_bwd)


def mxu_utilization_estimate(m, k, n, tile=128):
    """Fraction of MXU issue slots doing useful work (edge-tile padding
    accounted). Used for the DESIGN.md §Perf roofline estimate."""
    import math

    tiles = math.ceil(m / tile) * math.ceil(n / tile) * math.ceil(k / tile)
    useful = m * k * n
    issued = tiles * tile**3
    return useful / issued
