"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a reference implementation here; pytest
checks `assert_allclose(kernel(...), ref(...))` across shapes and dtypes
(hypothesis sweeps). The Rust integration tests check the same numerics a
third time through the AOT artifacts.
"""

import jax.numpy as jnp


def partial_average_ref(x, neighbors, weights):
    """Weighted combine: ``w[0] * x + sum_k w[k+1] * neighbors[k]``.

    Args:
      x: ``[d]`` local tensor.
      neighbors: ``[k, d]`` stacked neighbor tensors.
      weights: ``[k+1]`` combine weights, self weight first.

    Returns:
      ``[d]`` combined tensor (paper eq. (5)).
    """
    x = jnp.asarray(x)
    neighbors = jnp.asarray(neighbors)
    weights = jnp.asarray(weights)
    acc = weights[0] * x
    if neighbors.shape[0]:
        acc = acc + jnp.tensordot(weights[1:], neighbors, axes=1)
    return acc.astype(x.dtype)


def fused_sgd_ref(x, grad, momentum, lr, beta):
    """Fused momentum-SGD update.

    ``m' = beta * m + g``; ``x' = x - lr * m'``.

    Returns ``(x', m')``.
    """
    x = jnp.asarray(x)
    m_new = beta * jnp.asarray(momentum) + jnp.asarray(grad)
    x_new = x - lr * m_new
    return x_new.astype(x.dtype), m_new.astype(x.dtype)


def matmul_ref(a, b):
    """Plain matmul with f32 accumulation."""
    return jnp.matmul(
        jnp.asarray(a), jnp.asarray(b), preferred_element_type=jnp.float32
    ).astype(jnp.asarray(a).dtype)
