"""L2: the training computation — a decoder-only transformer LM in JAX.

The forward/backward step is the per-node compute of the paper's DNN
experiments (the ImageNet/BERT workloads, substituted per DESIGN.md with a
config-scalable char-level LM). The MLP blocks route their GEMMs through
the L1 Pallas matmul kernel when ``use_pallas=True``, so the kernel lowers
into the same HLO artifact the Rust runtime executes.

Parameters travel as a *list* of named arrays (stable positional order) so
the Rust coordinator can marshal flat f32 buffers against the manifest —
see ``aot.py`` and ``rust/src/runtime/manifest.rs``.

Presets here must stay in sync with ``rust/src/config.rs::PRESETS``.
"""

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul_diff as pallas_matmul


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq: int
    batch: int

    @property
    def d_ff(self):
        return 4 * self.d_model

    @property
    def head_dim(self):
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


PRESETS = {
    "nano": ModelConfig("nano", vocab=96, d_model=32, n_layers=1, n_heads=2, seq=32, batch=4),
    "tiny": ModelConfig("tiny", vocab=96, d_model=64, n_layers=2, n_heads=2, seq=64, batch=8),
    "small": ModelConfig("small", vocab=96, d_model=128, n_layers=4, n_heads=4, seq=128, batch=8),
}


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the manifest contract with Rust."""
    d, ff, v, t = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq
    specs = [("p.embed", (v, d)), ("p.pos", (t, d))]
    for i in range(cfg.n_layers):
        p = f"p.l{i}."
        specs += [
            (p + "ln1_s", (d,)),
            (p + "ln1_b", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ln2_s", (d,)),
            (p + "ln2_b", (d,)),
            (p + "w1", (d, ff)),
            (p + "b1", (ff,)),
            (p + "w2", (ff, d)),
            (p + "b2", (d,)),
        ]
    specs += [("p.lnf_s", (d,)), ("p.lnf_b", (d,)), ("p.head", (d, v))]
    return specs


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jnp.ndarray]:
    """Scaled-normal init, deterministic in `seed`."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_s",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b", "b1", "b2")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = (1.0 / fan_in) ** 0.5
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _mm(a2d, w, use_pallas):
    """[N, d_in] @ [d_in, d_out] through the Pallas kernel when requested."""
    if use_pallas:
        return pallas_matmul(a2d, w)
    return jnp.matmul(a2d, w, preferred_element_type=jnp.float32)


def forward(params: List[jnp.ndarray], tokens, cfg: ModelConfig, use_pallas=False):
    """Logits ``[B, T, V]`` for int32 tokens ``[B, T]``."""
    specs = param_specs(cfg)
    p = {name: arr for (name, _), arr in zip(specs, params)}
    b, t = tokens.shape
    d, h = cfg.d_model, cfg.n_heads
    hd = cfg.head_dim

    x = p["p.embed"][tokens] + p["p.pos"][None, :t, :]
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    for i in range(cfg.n_layers):
        pre = f"p.l{i}."
        # Attention block.
        xn = _layer_norm(x, p[pre + "ln1_s"], p[pre + "ln1_b"])
        flat = xn.reshape(b * t, d)
        q = _mm(flat, p[pre + "wq"], use_pallas).reshape(b, t, h, hd)
        k = _mm(flat, p[pre + "wk"], use_pallas).reshape(b, t, h, hd)
        v = _mm(flat, p[pre + "wv"], use_pallas).reshape(b, t, h, hd)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd**0.5)
        att = jnp.where(mask[None, None, :, :], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b * t, d)
        x = x + _mm(ctx, p[pre + "wo"], use_pallas).reshape(b, t, d)
        # MLP block (the GEMM hot-spot — Pallas kernel target).
        xn = _layer_norm(x, p[pre + "ln2_s"], p[pre + "ln2_b"])
        flat = xn.reshape(b * t, d)
        hdn = jax.nn.gelu(_mm(flat, p[pre + "w1"], use_pallas) + p[pre + "b1"])
        x = x + (_mm(hdn, p[pre + "w2"], use_pallas) + p[pre + "b2"]).reshape(b, t, d)

    x = _layer_norm(x, p["p.lnf_s"], p["p.lnf_b"])
    return _mm(x.reshape(b * t, d), p["p.head"], use_pallas).reshape(b, t, cfg.vocab)


def loss_fn(params, tokens, targets, cfg: ModelConfig, use_pallas=False):
    """Mean next-token cross-entropy."""
    logits = forward(params, tokens, cfg, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_step_fn(cfg: ModelConfig, use_pallas=False):
    """Returns f(params..., tokens, targets) -> (loss, *grads) for AOT."""

    def step(*args):
        n_params = len(param_specs(cfg))
        params = list(args[:n_params])
        tokens, targets = args[n_params], args[n_params + 1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(ps, tokens, targets, cfg, use_pallas)
        )(params)
        return (loss, *grads)

    return step


def eval_loss_fn(cfg: ModelConfig, use_pallas=False):
    """Returns f(params..., tokens, targets) -> (loss, accuracy) for AOT."""

    def evaluate(*args):
        n_params = len(param_specs(cfg))
        params = list(args[:n_params])
        tokens, targets = args[n_params], args[n_params + 1]
        logits = forward(params, tokens, cfg, use_pallas)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        acc = jnp.mean((jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))
        return (jnp.mean(nll), acc)

    return evaluate


def linreg_grad_fn():
    """Decentralized linear regression (paper eq. (15)/(16)):
    f(A, x, b) -> (grad, loss) with grad = A^T (A x - b) / m."""

    def grad(a_mat, x, b_vec):
        r = a_mat @ x - b_vec
        m = a_mat.shape[0]
        return (a_mat.T @ r / m, 0.5 * jnp.mean(r * r))

    return grad


@functools.lru_cache(maxsize=None)
def jitted_loss(cfg: ModelConfig, use_pallas=False):
    return jax.jit(lambda params, tok, tgt: loss_fn(params, tok, tgt, cfg, use_pallas))
