"""L2 model checks: shapes, gradient sanity, learnability, pallas parity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32)
    targets = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq)), jnp.int32)
    return tokens, targets


def test_param_specs_count_matches_init():
    for name, cfg in model.PRESETS.items():
        params = model.init_params(cfg)
        specs = model.param_specs(cfg)
        assert len(params) == len(specs), name
        for p, (_, shape) in zip(params, specs):
            assert p.shape == shape


def test_param_count_matches_rust_formula():
    # Mirrors rust/src/config.rs::ModelPreset::param_count.
    cfg = model.PRESETS["tiny"]
    d, ff = cfg.d_model, 4 * cfg.d_model
    per_layer = 2 * d + 4 * d * d + 2 * d + d * ff + ff + ff * d + d
    expected = cfg.vocab * d + cfg.seq * d + cfg.n_layers * per_layer + 2 * d + d * cfg.vocab
    assert model.param_count(cfg) == expected


def test_forward_shapes_and_loss_near_uniform_at_init():
    cfg = model.PRESETS["nano"]
    params = model.init_params(cfg)
    tokens, targets = batch(cfg)
    logits = model.forward(params, tokens, cfg)
    assert logits.shape == (cfg.batch, cfg.seq, cfg.vocab)
    loss = model.loss_fn(params, tokens, targets, cfg)
    # Roughly log(V) at random init.
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


def test_causality():
    """Changing future tokens must not change past logits."""
    cfg = model.PRESETS["nano"]
    params = model.init_params(cfg)
    tokens, _ = batch(cfg)
    logits_a = model.forward(params, tokens, cfg)
    tokens_b = tokens.at[:, -1].set((tokens[:, -1] + 1) % cfg.vocab)
    logits_b = model.forward(params, tokens_b, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_a[:, :-1]), np.asarray(logits_b[:, :-1]), rtol=1e-5, atol=1e-5
    )


def test_train_step_outputs_loss_and_grads():
    cfg = model.PRESETS["nano"]
    params = model.init_params(cfg)
    tokens, targets = batch(cfg)
    step = model.train_step_fn(cfg)
    outs = step(*params, tokens, targets)
    assert len(outs) == 1 + len(params)
    assert np.isfinite(float(outs[0]))
    for g, p in zip(outs[1:], params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()


def test_sgd_reduces_loss():
    cfg = model.PRESETS["nano"]
    params = model.init_params(cfg)
    tokens, targets = batch(cfg)
    step = jax.jit(model.train_step_fn(cfg))
    first = None
    for _ in range(30):
        outs = step(*params, tokens, targets)
        loss, grads = float(outs[0]), outs[1:]
        if first is None:
            first = loss
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    assert loss < first - 0.5, f"loss did not decrease: {first} -> {loss}"


def test_pallas_forward_matches_jnp():
    cfg = model.PRESETS["nano"]
    params = model.init_params(cfg)
    tokens, targets = batch(cfg)
    loss_jnp = float(model.loss_fn(params, tokens, targets, cfg, use_pallas=False))
    loss_pallas = float(model.loss_fn(params, tokens, targets, cfg, use_pallas=True))
    assert abs(loss_jnp - loss_pallas) < 1e-3, (loss_jnp, loss_pallas)


def test_pallas_grads_match_jnp():
    cfg = model.PRESETS["nano"]
    params = model.init_params(cfg)
    tokens, targets = batch(cfg)
    g_jnp = jax.grad(lambda ps: model.loss_fn(ps, tokens, targets, cfg, False))(params)
    g_pal = jax.grad(lambda ps: model.loss_fn(ps, tokens, targets, cfg, True))(params)
    for a, b in zip(g_jnp, g_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-3)


def test_eval_fn_accuracy_bounds():
    cfg = model.PRESETS["nano"]
    params = model.init_params(cfg)
    tokens, targets = batch(cfg)
    loss, acc = model.eval_loss_fn(cfg)(*params, tokens, targets)
    assert 0.0 <= float(acc) <= 1.0
    assert np.isfinite(float(loss))


def test_linreg_grad_matches_closed_form():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    x = jnp.asarray(rng.standard_normal(8), jnp.float32)
    b = jnp.asarray(rng.standard_normal(32), jnp.float32)
    grad, loss = model.linreg_grad_fn()(a, x, b)
    want = np.asarray(a).T @ (np.asarray(a) @ np.asarray(x) - np.asarray(b)) / 32
    np.testing.assert_allclose(np.asarray(grad), want, rtol=1e-5, atol=1e-5)
    assert float(loss) >= 0.0
