"""AOT pipeline checks: artifacts exist, manifests are consistent, HLO text
has the expected entry computation."""

import os

import pytest

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, ".stamp")),
    reason="artifacts not built (run `make artifacts`)",
)


def parse_manifest(path):
    inputs, outputs, meta = [], [], {}
    with open(path) as f:
        for line in f:
            fields = line.split()
            if not fields or fields[0].startswith("#"):
                continue
            if fields[0] == "meta":
                meta[fields[1]] = " ".join(fields[2:])
            elif fields[0] == "input":
                inputs.append(tuple(fields[1:]))
            elif fields[0] == "output":
                outputs.append(tuple(fields[1:]))
    return inputs, outputs, meta


def artifacts():
    return sorted(f[: -len(".hlo.txt")] for f in os.listdir(ART) if f.endswith(".hlo.txt"))


def test_expected_artifacts_present():
    names = artifacts()
    assert "train_step_nano" in names
    assert "train_step_tiny" in names
    assert "train_step_nano_pallas" in names
    assert "linreg_grad" in names
    assert any(n.startswith("combine_k") for n in names)
    assert any(n.startswith("fused_sgd_") for n in names)
    assert any(n.startswith("matmul_") for n in names)


def test_every_artifact_has_manifest():
    for name in artifacts():
        man = os.path.join(ART, f"{name}.manifest")
        assert os.path.exists(man), f"missing manifest for {name}"
        inputs, outputs, _ = parse_manifest(man)
        assert inputs and outputs, name


def test_hlo_text_parses_structurally():
    for name in artifacts():
        with open(os.path.join(ART, f"{name}.hlo.txt")) as f:
            text = f.read()
        assert "HloModule" in text, name
        assert "ENTRY" in text, name


def test_train_step_manifest_matches_model():
    from compile import model

    cfg = model.PRESETS["nano"]
    inputs, outputs, meta = parse_manifest(os.path.join(ART, "train_step_nano.manifest"))
    specs = model.param_specs(cfg)
    # params..., tokens, targets
    assert len(inputs) == len(specs) + 2
    for (mname, mdtype, mdims), (sname, sshape) in zip(inputs, specs):
        assert mname == sname
        assert mdtype == "f32"
        want = "-" if not sshape else "x".join(str(d) for d in sshape)
        assert mdims == want, (mname, mdims, want)
    assert inputs[-2][0] == "tokens" and inputs[-2][1] == "i32"
    # loss + one grad per param
    assert len(outputs) == 1 + len(specs)
    assert outputs[0][0] == "loss"
    assert int(meta["param_count"]) == model.param_count(cfg)


def test_hlo_entry_parameter_count_matches_manifest():
    import re

    for name in ["train_step_nano", "combine_k2_d16384", "linreg_grad"]:
        inputs, _, _ = parse_manifest(os.path.join(ART, f"{name}.manifest"))
        with open(os.path.join(ART, f"{name}.hlo.txt")) as f:
            text = f.read()
        entry = text[text.index("ENTRY") :]
        # ENTRY is the last computation in the dump; count its parameter
        # instructions.
        n_params = len(re.findall(r"parameter\(\d+\)", entry))
        assert n_params == len(inputs), (name, n_params, len(inputs))
