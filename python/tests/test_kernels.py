"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes, per the testing contract: the kernel
path must be bit-compatible (up to accumulation tolerance) with ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_sgd import fused_sgd
from compile.kernels.matmul import matmul, matmul_diff, mxu_utilization_estimate
from compile.kernels.partial_average import partial_average, vmem_bytes

DTYPES = [jnp.float32, jnp.bfloat16]


def rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=700),
    k=st.integers(min_value=0, max_value=6),
    dtype_i=st.integers(min_value=0, max_value=1),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_partial_average_matches_ref(d, k, dtype_i, seed):
    dtype = DTYPES[dtype_i]
    rng = np.random.default_rng(seed)
    x = rand(rng, (d,), dtype)
    nb = rand(rng, (k, d), dtype)
    w = jnp.asarray(rng.dirichlet(np.ones(k + 1)), jnp.float32)
    got = partial_average(x, nb, w, block=128)
    want = ref.partial_average_ref(x, nb, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=900),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fused_sgd_matches_ref(d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (d,), jnp.float32)
    g = rand(rng, (d,), jnp.float32)
    m = rand(rng, (d,), jnp.float32)
    lr, beta = float(rng.uniform(1e-4, 1.0)), float(rng.uniform(0.0, 0.999))
    xo, mo = fused_sgd(x, g, m, jnp.array([lr, beta], jnp.float32), block=256)
    rx, rm = ref.fused_sgd_ref(x, g, m, lr, beta)
    np.testing.assert_allclose(np.asarray(xo), np.asarray(rx), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(mo), np.asarray(rm), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=200),
    k=st.integers(min_value=1, max_value=200),
    n=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rand(rng, (m, k), jnp.float32)
    b = rand(rng, (k, n), jnp.float32)
    got = matmul(a, b, tile_m=64, tile_n=64, tile_k=64)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_matmul_bf16_vs_f32_reference():
    rng = np.random.default_rng(0)
    a = rand(rng, (256, 128), jnp.bfloat16)
    b = rand(rng, (128, 256), jnp.bfloat16)
    got = matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )


def test_matmul_diff_gradients_match_jnp():
    rng = np.random.default_rng(1)
    a = rand(rng, (64, 32), jnp.float32)
    b = rand(rng, (32, 48), jnp.float32)

    def f_pallas(a, b):
        return jnp.sum(matmul_diff(a, b) ** 2)

    def f_ref(a, b):
        return jnp.sum(jnp.matmul(a, b) ** 2)

    ga_p, gb_p = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_p), np.asarray(ga_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb_p), np.asarray(gb_r), rtol=1e-4, atol=1e-4)


def test_partial_average_degenerate_no_neighbors():
    x = jnp.arange(130, dtype=jnp.float32)
    nb = jnp.zeros((0, 130), jnp.float32)
    w = jnp.array([1.0], jnp.float32)
    out = partial_average(x, nb, w, block=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))


def test_partial_average_doubly_stochastic_preserves_mean():
    # The combine with convex weights keeps values in the convex hull.
    rng = np.random.default_rng(2)
    x = rand(rng, (512,), jnp.float32)
    nb = rand(rng, (3, 512), jnp.float32)
    w = jnp.array([0.25, 0.25, 0.25, 0.25], jnp.float32)
    out = np.asarray(partial_average(x, nb, w))
    stacked = np.concatenate([np.asarray(x)[None], np.asarray(nb)], axis=0)
    assert (out <= stacked.max(axis=0) + 1e-5).all()
    assert (out >= stacked.min(axis=0) - 1e-5).all()


def test_vmem_estimate_within_budget():
    # k=8 neighbors at the default block: comfortably under 16 MB VMEM.
    assert vmem_bytes(8) < 16 * 2**20 / 8


def test_mxu_utilization_estimate():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert 0.4 < mxu_utilization_estimate(100, 128, 128) < 1.0


@pytest.mark.parametrize("k", [1, 2, 4])
def test_partial_average_weights_linear(k):
    # Linearity: combine(x, nb, 2w) == 2 * combine(x, nb, w).
    rng = np.random.default_rng(3)
    x = rand(rng, (256,), jnp.float32)
    nb = rand(rng, (k, 256), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 1, k + 1), jnp.float32)
    one = np.asarray(partial_average(x, nb, w))
    two = np.asarray(partial_average(x, nb, 2.0 * w))
    np.testing.assert_allclose(two, 2.0 * one, rtol=1e-5, atol=1e-5)
