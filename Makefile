# Convenience targets. Tier-1 verify is `make verify`.

.PHONY: verify build test examples benches bench-hotpath artifacts clean

verify: build test

build:
	cargo build --release

test:
	cargo test -q

examples:
	cargo build --release --examples

benches:
	cargo build --benches

# A/B the naive vs pooled/blocked communication hot path and write
# BENCH_hotpath.json (ms/op, effective GB/s, pool hit rate). Set
# HOTPATH_SMOKE=1 for a seconds-long CI-sized run.
bench-hotpath:
	cargo run --release --example perf_probe

# Lower the L2/L1 JAX/Pallas computations to HLO-text artifacts consumed by
# the Rust PJRT runtime (needs the Python toolchain; artifacts land in
# ./artifacts with a .stamp sentinel the tests/benches key off).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

clean:
	cargo clean
	rm -rf artifacts
