# Convenience targets. Tier-1 verify is `make verify`.

.PHONY: verify build test examples benches bench-hotpath bench-compress bench-algos bench-async bench-scale bench-chaos bench-wallclock artifacts clean

verify: build test

build:
	cargo build --release

test:
	cargo test -q

examples:
	cargo build --release --examples

benches:
	cargo build --benches

# A/B the naive vs pooled/blocked communication hot path plus the
# single-rank kernel section (seed scalar vs SIMD vs SIMD + intra-rank
# worker pool) and write BENCH_hotpath.json (ms/op, effective GB/s, pool
# hit rate, kernel GB/s, cpu model/features). Set HOTPATH_SMOKE=1 for a
# seconds-long CI-sized run; HOTPATH_THREADS sizes the worker pool
# (default: available cores capped at 4), e.g.
# `make bench-hotpath HOTPATH_THREADS=2`.
bench-hotpath:
	HOTPATH_THREADS=$(HOTPATH_THREADS) cargo run --release --example perf_probe

# Compare dense vs compressed neighbor averaging (topk/randk/q8/lowrank with
# error feedback) on the linear-regression workload and write
# BENCH_compress.json (bytes on wire, ms/iter, end-loss delta). Set
# COMPRESS_SMOKE=1 for a CI-sized run.
bench-compress:
	cargo run --release --example compress_probe

# Exercise the composable algorithm pipeline (schedule x weighting x
# compression) on the linear-regression workload and write BENCH_algos.json:
# DIGEST-style LocalUpdateSgd(H=8) bytes-to-target-loss vs dense D-SGD
# (>=8x alone, >=20x with TopK stacked), DecentralizedADMM convergence on a
# ring, and AL-DSGD dynamic weighting vs static MH rows on consensus spread
# under a 4x straggler with non-IID shards. Set ALGOS_SMOKE=1 for a
# CI-sized run.
bench-algos:
	cargo run --release --example algos_probe

# Sync DSGD vs async push-sum SGD (one-sided windows, causal drains) under
# uniform compute and under a 4x single-rank straggler; writes
# BENCH_async.json (virtual time to target loss, final-loss delta, max
# staleness) and gates the >=1.5x straggler speedup. Set ASYNC_SMOKE=1 for
# a CI-sized run.
bench-async:
	cargo run --release --example async_probe

# Event-loop scale sweep: neighbor-allreduce consensus at 64 / 1k / 10k
# ranks on exponential-2 under ExecMode::EventLoop; writes BENCH_scale.json
# (spectral gap, per-iteration contraction, peak RSS per rank, virtual and
# wall time) and gates contraction <= 1 - 0.1*gap plus the 64 KiB/rank
# memory bound. Set SCALE_SMOKE=1 to drop the 10k row for CI.
bench-scale:
	cargo run --release --example scale_probe

# Fault-injection sweep: consensus + sync DSGD on ring(8)+MH under a rank
# crash at T/2, 5% packet drop, and a 10% partition window, on both exec
# backends; writes BENCH_chaos.json and gates survivor contraction, <=10%
# final-loss degradation, and cross-backend fault-free agreement. Set
# CHAOS_SMOKE=1 for a CI-sized run.
bench-chaos:
	cargo run --release --example chaos_probe

# Real wall-clock milliseconds for consensus + DSGD over both transport
# backends: in-process SimBackends vs 4 real OS processes on loopback TCP
# (the probe re-executes itself per rank, DESIGN.md §Transport backends);
# writes BENCH_wallclock.json (mean/p95/ci90 ms/iter per backend, virtual
# time alongside) and gates sim/tcp parity <= 1e-6, identical payload byte
# counters, and the killed-worker -> peer_down path. Set WALLCLOCK_SMOKE=1
# for a CI-sized run.
bench-wallclock:
	cargo run --release --example wallclock_probe

# Sweep every BENCH_*.json the probes have produced into ./artifacts — a
# glob, so new probes are picked up without editing this target — then
# lower the L2/L1 JAX/Pallas computations to HLO-text artifacts consumed by
# the Rust PJRT runtime (needs the Python toolchain; lands a .stamp
# sentinel the tests/benches key off). The sweep runs first so bench JSON
# is still collected on machines without Python/JAX.
artifacts:
	@mkdir -p artifacts
	@for f in BENCH_*.json; do [ -e "$$f" ] && cp -f "$$f" artifacts/ || true; done
	cd python && python -m compile.aot --out-dir ../artifacts

clean:
	cargo clean
	rm -rf artifacts
