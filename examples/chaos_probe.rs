// Chaos probe: fault injection + failure-resilient decentralized training
// (ISSUE 7). Two workloads on the ring(8) + Metropolis-Hastings topology —
// pure consensus (repeated neighbor averaging) and synchronous DSGD on the
// decentralized linear-regression problem — each run fault-free first to
// calibrate the total virtual time T, then re-run under three seeded fault
// scenarios on BOTH exec backends:
//
//   * crash      — rank 3 dies at T/2. Survivors convert the hang into
//                  `CommError::PeerDown` within the receive deadline, evict
//                  the corpse, and re-derive a Metropolis-Hastings row over
//                  the survivor graph (self-healing topology).
//   * drop       — every link loses 5% of first-attempt packets; bounded
//                  retransmission with exponential backoff recovers them as
//                  (virtually) delayed deliveries.
//   * partition  — the 1-2 ring edge is cut for the middle 10% of the run;
//                  retries ride past the heal instant, and receives that
//                  expire meanwhile fold the missing weight back onto the
//                  receiver (mass-conserving degraded rounds).
//
// Gates (per scenario, per exec mode):
//   * consensus: the survivor spread still contracts to <= 0.5x its
//     initial value (numerically validated margin: orders of magnitude);
//   * DSGD: the global loss at the survivor-averaged iterate degrades
//     <= 10% vs the fault-free run;
//   * the fault machinery demonstrably fired (crashed rank stopped early /
//     retransmissions observed), and nothing hung — the probe completing
//     at all is the no-infinite-hang gate;
//   * fault-free baselines agree across Threads and EventLoop.
//
// Run: `make bench-chaos` (or `cargo run --release --example chaos_probe`).
// Env: CHAOS_SMOKE=1 shrinks the problem for CI; BENCH_CHAOS_OUT overrides
// the output path.

use bluefog::launcher::{run_spmd, ExecMode, SpmdConfig};
use bluefog::optim::{CommSpec, DecentralizedOptimizer, Dgd, StepOrder};
use bluefog::rng::Rng;
use bluefog::simnet::faults::FaultPlan;
use bluefog::topology::{builders, WeightMatrix};

const N: usize = 8;
const CRASH_RANK: usize = 3;
const PART_A: usize = 1;
const PART_B: usize = 2;
/// Receive deadline budget (virtual seconds): several round times, so
/// in-flight retries beat it and only genuine failures expire.
const DEADLINE: f64 = 2e-3;
/// Retransmission backoff base: attempt k fires at +base*(2^k - 1), so
/// four retries probe ~2.25 ms past the original send.
const BACKOFF: f64 = 0.15e-3;
/// Timeouts only *suspect* a peer; with the crash oracle driving real
/// evictions, keep the miss threshold far above any transient burst so a
/// partition never permanently shrinks the graph.
const MISS_THRESHOLD: u32 = 64;
/// Per-round compute charge (consensus) keeps vtime advancing uniformly.
const ROUND_COMPUTE: f64 = 200e-6;
/// Per-iteration compute charge (DSGD).
const STEP_COMPUTE: f64 = 1e-3;

#[derive(Clone, Copy)]
struct Problem {
    d: usize,      // features (DSGD) / vector length (consensus)
    rows: usize,   // rows per node
    iters: usize,  // DSGD iterations
    rounds: usize, // consensus rounds
    gamma: f32,    // DSGD step size
}

fn ring_cfg(mode: ExecMode, plan: FaultPlan) -> SpmdConfig {
    let graph = builders::ring(N);
    let weights = WeightMatrix::metropolis_hastings(&graph);
    SpmdConfig::new(N)
        .with_topo_check(false)
        .with_exec(mode)
        .with_topology(graph, weights)
        .with_faults(plan)
}

/// Deterministic per-rank consensus start vector (main rebuilds the same
/// vectors to measure the initial survivor spread).
fn consensus_x0(rank: usize, d: usize) -> Vec<f32> {
    Rng::new(0xC0A5_EED0 + rank as u64).normal_vec(d)
}

/// Per-node data `A_i [rows, d]`, `b_i [rows]`; `b = A x* + 0.1 noise`.
fn make_data(rank: usize, p: &Problem) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0xCAA5 + rank as u64);
    let mut x_star_rng = Rng::new(0x57A8);
    let x_star: Vec<f32> = x_star_rng.normal_vec(p.d);
    let a: Vec<f32> = rng.normal_vec(p.rows * p.d);
    let mut b = vec![0.0f32; p.rows];
    for r in 0..p.rows {
        let mut dot = 0.0f32;
        for (ac, xc) in a[r * p.d..(r + 1) * p.d].iter().zip(&x_star) {
            dot += ac * xc;
        }
        b[r] = dot + 0.1 * rng.normal() as f32;
    }
    (a, b)
}

fn global_data(p: &Problem) -> Vec<(Vec<f32>, Vec<f32>)> {
    (0..N).map(|r| make_data(r, p)).collect()
}

/// Global loss `(1/2 N rows) Σ_i ||A_i x − b_i||²` — the FIXED objective
/// (all 8 nodes' data), so fault-free and faulty runs are compared on the
/// same yardstick even when a rank died mid-training.
fn global_loss(data: &[(Vec<f32>, Vec<f32>)], p: &Problem, x: &[f32]) -> f64 {
    let mut sum = 0.0f64;
    for (a, b) in data {
        for r in 0..p.rows {
            let mut dot = 0.0f32;
            for (ac, xc) in a[r * p.d..(r + 1) * p.d].iter().zip(x) {
                dot += ac * xc;
            }
            sum += ((dot - b[r]) as f64).powi(2);
        }
    }
    sum / (2.0 * (N * p.rows) as f64)
}

/// Full-batch local gradient `A^T (A x − b) / rows` into `grad`.
fn local_grad(a: &[f32], b: &[f32], x: &[f32], p: &Problem, grad: &mut [f32]) {
    let d = p.d;
    for g in grad.iter_mut() {
        *g = 0.0;
    }
    for (r, br) in b.iter().enumerate() {
        let row = &a[r * d..(r + 1) * d];
        let mut dot = 0.0f32;
        for (ac, xc) in row.iter().zip(x) {
            dot += ac * xc;
        }
        let scale = (dot - br) / p.rows as f32;
        for (g, ac) in grad.iter_mut().zip(row) {
            *g += scale * ac;
        }
    }
}

/// Repeated neighbor averaging; a rank whose crash vtime passes unwinds
/// with its partial iterate instead of erroring the whole run.
fn run_consensus(
    mode: ExecMode,
    p: &Problem,
    plan: FaultPlan,
) -> anyhow::Result<Vec<(Vec<f32>, f64)>> {
    let prob = *p;
    run_spmd(ring_cfg(mode, plan), move |ctx| {
        let mut x = consensus_x0(ctx.rank(), prob.d);
        for _ in 0..prob.rounds {
            if ctx.crashed_now() {
                break;
            }
            ctx.simulate_compute(ROUND_COMPUTE);
            match ctx.neighbor_allreduce(&x) {
                Ok(y) => x = y,
                Err(e) => {
                    if ctx.crashed_now() {
                        break; // own crash surfaced mid-round
                    }
                    return Err(e);
                }
            }
        }
        Ok((x, ctx.vtime()))
    })
}

/// Synchronous DSGD (ATC, static topology) with the same crash unwind.
fn run_dsgd(mode: ExecMode, p: &Problem, plan: FaultPlan) -> anyhow::Result<Vec<(Vec<f32>, f64)>> {
    let prob = *p;
    run_spmd(ring_cfg(mode, plan), move |ctx| {
        let p = prob;
        let (a, b) = make_data(ctx.rank(), &p);
        let mut x = vec![0.0f32; p.d];
        let mut grad = vec![0.0f32; p.d];
        let mut opt = Dgd::new(p.gamma, StepOrder::Atc, CommSpec::Static);
        for _ in 0..p.iters {
            if ctx.crashed_now() {
                break;
            }
            ctx.simulate_compute(STEP_COMPUTE);
            local_grad(&a, &b, &x, &p, &mut grad);
            if let Err(e) = opt.step(ctx, &mut x, &grad) {
                if ctx.crashed_now() {
                    break;
                }
                return Err(e);
            }
        }
        Ok((x, ctx.vtime()))
    })
}

/// Max per-coordinate spread (max - min) across the given ranks.
fn spread(xs: &[(Vec<f32>, f64)], ranks: &[usize]) -> f64 {
    let d = xs[0].0.len();
    let mut worst = 0.0f64;
    for c in 0..d {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &r in ranks {
            let v = xs[r].0[c] as f64;
            lo = lo.min(v);
            hi = hi.max(v);
        }
        worst = worst.max(hi - lo);
    }
    worst
}

/// Spread of the deterministic consensus start vectors over `ranks`.
fn initial_spread(d: usize, ranks: &[usize]) -> f64 {
    let xs: Vec<(Vec<f32>, f64)> = (0..N).map(|r| (consensus_x0(r, d), 0.0)).collect();
    spread(&xs, ranks)
}

/// Coordinate-wise mean of the iterates held by `ranks`.
fn mean_iterate(xs: &[(Vec<f32>, f64)], ranks: &[usize]) -> Vec<f32> {
    let d = xs[0].0.len();
    let mut m = vec![0.0f32; d];
    for &r in ranks {
        for (mc, xc) in m.iter_mut().zip(&xs[r].0) {
            *mc += xc;
        }
    }
    let inv = 1.0 / ranks.len() as f32;
    for mc in m.iter_mut() {
        *mc *= inv;
    }
    m
}

fn mode_name(mode: ExecMode) -> &'static str {
    match mode {
        ExecMode::Threads => "threads",
        ExecMode::EventLoop => "event_loop",
    }
}

/// One scenario's measured outcome (both workloads).
struct ScenarioOutcome {
    name: &'static str,
    spread_ratio: f64,
    loss_ratio: f64,
    stats: (u64, u64, u64, u64, u64),
}

/// Fault-free calibration of one exec mode.
struct Baseline {
    t_cons: f64,
    t_dsgd: f64,
    spread_ff: f64,
    loss_ff: f64,
}

fn run_mode(mode: ExecMode, p: &Problem) -> anyhow::Result<(Baseline, Vec<ScenarioOutcome>)> {
    let all: Vec<usize> = (0..N).collect();
    let survivors: Vec<usize> = (0..N).filter(|&r| r != CRASH_RANK).collect();
    let data = global_data(p);
    let m = mode_name(mode);

    // ---- fault-free calibration runs ----------------------------------
    let cons0 = run_consensus(mode, p, FaultPlan::none())?;
    let t_cons = cons0.iter().map(|r| r.1).fold(0.0f64, f64::max);
    let spread0 = initial_spread(p.d, &all);
    let spread_ff = spread(&cons0, &all);
    let dsgd0 = run_dsgd(mode, p, FaultPlan::none())?;
    let t_dsgd = dsgd0.iter().map(|r| r.1).fold(0.0f64, f64::max);
    let loss_ff = global_loss(&data, p, &mean_iterate(&dsgd0, &all));
    println!(
        "  {m:>10} | baseline : T_cons {:.4}s T_dsgd {:.4}s | spread {spread0:.4} -> {spread_ff:.3e} | loss {loss_ff:.6}",
        t_cons, t_dsgd
    );
    anyhow::ensure!(spread0 > 0.0, "degenerate consensus start (zero spread)");

    // ---- fault scenarios: plans are functions of the calibrated T -----
    let scenarios: Vec<(&'static str, FaultPlan, FaultPlan)> = vec![
        (
            "crash",
            FaultPlan::seeded(0xC4A5, DEADLINE)
                .with_crash(CRASH_RANK, 0.5 * t_cons)
                .with_miss_threshold(MISS_THRESHOLD),
            FaultPlan::seeded(0xC4A5, DEADLINE)
                .with_crash(CRASH_RANK, 0.5 * t_dsgd)
                .with_miss_threshold(MISS_THRESHOLD),
        ),
        (
            "drop",
            FaultPlan::seeded(0xD201, DEADLINE)
                .with_drop(0.05, 3, BACKOFF)
                .with_miss_threshold(MISS_THRESHOLD),
            FaultPlan::seeded(0xD201, DEADLINE)
                .with_drop(0.05, 3, BACKOFF)
                .with_miss_threshold(MISS_THRESHOLD),
        ),
        (
            "partition",
            FaultPlan::seeded(0xBA22, DEADLINE)
                .with_drop(0.0, 4, BACKOFF)
                .with_partition(vec![PART_A], vec![PART_B], 0.45 * t_cons, 0.55 * t_cons)
                .with_miss_threshold(MISS_THRESHOLD),
            FaultPlan::seeded(0xBA22, DEADLINE)
                .with_drop(0.0, 4, BACKOFF)
                .with_partition(vec![PART_A], vec![PART_B], 0.45 * t_dsgd, 0.55 * t_dsgd)
                .with_miss_threshold(MISS_THRESHOLD),
        ),
    ];

    let mut outcomes = Vec::new();
    for (name, cons_plan, dsgd_plan) in scenarios {
        let live: &[usize] = if name == "crash" { &survivors } else { &all };
        let cons_stats = cons_plan.stats.clone();
        let cons = run_consensus(mode, p, cons_plan)?;
        let spread_f = spread(&cons, live);
        let spread_ratio = spread_f / initial_spread(p.d, live);

        let dsgd_stats = dsgd_plan.stats.clone();
        let dsgd = run_dsgd(mode, p, dsgd_plan)?;
        let loss_f = global_loss(&data, p, &mean_iterate(&dsgd, live));
        let loss_ratio = loss_f / loss_ff;

        let (c_lost, c_retried, ..) = cons_stats.snapshot();
        let (d_lost, d_retried, d_delayed, d_dup, d_crashed) = dsgd_stats.snapshot();
        println!(
            "  {m:>10} | {name:<9}: spread ratio {spread_ratio:.3e} | loss ratio {loss_ratio:.4} | \
             dsgd faults lost {d_lost} retried {d_retried} delayed {d_delayed} dup {d_dup} \
             crashed-sends {d_crashed}"
        );

        // -- gates -----------------------------------------------------
        anyhow::ensure!(
            spread_ratio <= 0.5,
            "{m}/{name}: survivor consensus failed to contract (spread ratio {spread_ratio:.3})"
        );
        anyhow::ensure!(
            loss_ratio <= 1.10,
            "{m}/{name}: DSGD final loss degraded {:.1}% vs fault-free (gate: 10%)",
            100.0 * (loss_ratio - 1.0)
        );
        match name {
            "crash" => {
                let crashed_end = dsgd[CRASH_RANK].1;
                anyhow::ensure!(
                    crashed_end < 0.8 * t_dsgd,
                    "{m}/crash: rank {CRASH_RANK} ran to vtime {crashed_end:.4}s — the crash \
                     schedule never fired (T = {t_dsgd:.4}s)"
                );
            }
            _ => {
                anyhow::ensure!(
                    c_retried + c_lost + d_retried + d_lost > 0,
                    "{m}/{name}: fault plan was active but no packet was ever dropped or retried"
                );
            }
        }
        outcomes.push(ScenarioOutcome {
            name,
            spread_ratio,
            loss_ratio,
            stats: dsgd_stats.snapshot(),
        });
    }
    Ok((Baseline { t_cons, t_dsgd, spread_ff, loss_ff }, outcomes))
}

fn scenario_json(s: &ScenarioOutcome) -> String {
    let (lost, retried, delayed, duplicated, crashed_sends) = s.stats;
    format!(
        concat!(
            "    \"{}\": {{\"spread_ratio\": {:.6e}, \"loss_ratio\": {:.6}, ",
            "\"lost\": {}, \"retried\": {}, \"delayed\": {}, \"duplicated\": {}, ",
            "\"crashed_sends\": {}}}"
        ),
        s.name, s.spread_ratio, s.loss_ratio, lost, retried, delayed, duplicated, crashed_sends
    )
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("CHAOS_SMOKE").is_ok();
    let p = if smoke {
        Problem { d: 16, rows: 32, iters: 48, rounds: 24, gamma: 0.25 }
    } else {
        Problem { d: 24, rows: 48, iters: 80, rounds: 40, gamma: 0.25 }
    };
    println!(
        "chaos probe: {N} nodes (ring + Metropolis-Hastings), d={} rows/node={} \
         iters={} rounds={} | crash@T/2 rank {CRASH_RANK}, 5% drop, 10% partition {PART_A}-{PART_B}",
        p.d, p.rows, p.iters, p.rounds
    );

    let (base_t, out_t) = run_mode(ExecMode::Threads, &p)?;
    let (base_e, out_e) = run_mode(ExecMode::EventLoop, &p)?;

    // Fault-free runs must agree across backends (the parity suite pins
    // this bitwise; the probe re-checks the derived metrics).
    let loss_gap = (base_t.loss_ff - base_e.loss_ff).abs();
    anyhow::ensure!(
        loss_gap <= 1e-9 * base_t.loss_ff.max(1e-30),
        "fault-free DSGD loss diverged across exec modes: threads {:.9e} vs event loop {:.9e}",
        base_t.loss_ff,
        base_e.loss_ff
    );
    let spread_gap = (base_t.spread_ff - base_e.spread_ff).abs();
    anyhow::ensure!(
        spread_gap <= 1e-9 * base_t.spread_ff.max(1e-30),
        "fault-free consensus spread diverged across exec modes: {:.9e} vs {:.9e}",
        base_t.spread_ff,
        base_e.spread_ff
    );

    let json = format!(
        concat!(
            "{{\n  \"bench\": \"chaos\",\n  \"nodes\": {},\n  \"d\": {},\n",
            "  \"rows_per_node\": {},\n  \"dsgd_iters\": {},\n  \"consensus_rounds\": {},\n",
            "  \"smoke\": {},\n  \"deadline_s\": {},\n  \"crash_rank\": {},\n",
            "  \"threads\": {{\n",
            "    \"t_cons_s\": {:.6}, \"t_dsgd_s\": {:.6}, ",
            "\"spread_ff\": {:.6e}, \"loss_ff\": {:.8},\n",
            "{},\n{},\n{}\n  }},\n",
            "  \"event_loop\": {{\n",
            "    \"t_cons_s\": {:.6}, \"t_dsgd_s\": {:.6}, ",
            "\"spread_ff\": {:.6e}, \"loss_ff\": {:.8},\n",
            "{},\n{},\n{}\n  }}\n}}\n"
        ),
        N,
        p.d,
        p.rows,
        p.iters,
        p.rounds,
        smoke,
        DEADLINE,
        CRASH_RANK,
        base_t.t_cons,
        base_t.t_dsgd,
        base_t.spread_ff,
        base_t.loss_ff,
        scenario_json(&out_t[0]),
        scenario_json(&out_t[1]),
        scenario_json(&out_t[2]),
        base_e.t_cons,
        base_e.t_dsgd,
        base_e.spread_ff,
        base_e.loss_ff,
        scenario_json(&out_e[0]),
        scenario_json(&out_e[1]),
        scenario_json(&out_e[2]),
    );
    let out_path = std::env::var("BENCH_CHAOS_OUT").unwrap_or_else(|_| "BENCH_chaos.json".into());
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    println!("chaos_probe OK");
    Ok(())
}
