//! Quickstart: the BlueFog-rs API in one file.
//!
//! 1. Launch 8 SPMD nodes over the static exponential-2 graph;
//! 2. run average consensus with `neighbor_allreduce` (paper eq. (5));
//! 3. run a few steps of decentralized gradient descent on a toy quadratic;
//! 4. overlap communication and computation with the non-blocking API
//!    (paper Listing 5).
//!
//! Run: `cargo run --release --example quickstart`

use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{CommSpec, DecentralizedOptimizer, Dgd, StepOrder};
use bluefog::tensor::axpy;

fn main() -> anyhow::Result<()> {
    let nodes = 8;

    // --- 1+2: average consensus -------------------------------------------
    // Every node starts from its rank; partial averaging over the sparse
    // exponential graph drives all nodes to the global mean without any
    // central server.
    let results = run_spmd(SpmdConfig::new(nodes), |ctx| {
        let mut x = vec![ctx.rank() as f32];
        for _ in 0..60 {
            x = ctx.neighbor_allreduce(&x)?; // <- one line of partial averaging
        }
        Ok(x[0])
    })?;
    let mean = (nodes - 1) as f32 / 2.0;
    println!("consensus: targets {mean}, got {results:?}");
    assert!(results.iter().all(|&v| (v - mean).abs() < 1e-3));

    // --- 3: decentralized gradient descent ---------------------------------
    // Minimize f(x) = mean_i 0.5 (x - c_i)^2 where node i only knows c_i.
    // The unique minimizer is mean(c_i); DGD finds it via local gradients +
    // partial averaging (paper Listing 1).
    let results = run_spmd(SpmdConfig::new(nodes), move |ctx| {
        let c = ctx.rank() as f32; // node-local data
        let mut x = vec![0.0f32];
        let mut opt = Dgd::new(0.05, StepOrder::Atc, CommSpec::Static);
        for _ in 0..400 {
            let grad = vec![x[0] - c];
            opt.step(ctx, &mut x, &grad)?;
        }
        Ok(x[0])
    })?;
    println!("DGD:       targets {mean}, got {results:?}");
    assert!(results.iter().all(|&v| (v - mean).abs() < 0.15)); // DGD keeps an O(gamma) bias

    // --- 4: non-blocking overlap -------------------------------------------
    // Start the partial averaging, compute the gradient while the tensors
    // move, then wait (Listing 5: handle = neighbor_allreduce_nonblocking;
    // grad = ComputeGradient(x); x = bf.wait(handle) - lr*grad).
    let results = run_spmd(SpmdConfig::new(nodes), move |ctx| {
        let c = ctx.rank() as f32;
        let mut x = vec![0.0f32];
        for _ in 0..400 {
            let handle = ctx.neighbor_allreduce_nonblocking(&x, None)?;
            let grad = vec![x[0] - c]; // overlapped with communication
            x = handle.wait(ctx)?;
            axpy(-0.05, &grad, &mut x);
        }
        Ok(x[0])
    })?;
    println!("AWC (nb):  targets {mean}, got {results:?}");
    // AWC's bias constant is larger than ATC's (a known trade-off for the
    // extra overlap; see paper §V-C).
    assert!(results.iter().all(|&v| (v - mean).abs() < 0.4));

    println!("quickstart OK");
    Ok(())
}
