// Async training probe: lights up the asynchronous decentralized regime
// end-to-end (paper §IV-C) and quantifies when it beats the synchronous
// one. Two compute profiles on the decentralized linear-regression
// workload:
//
//   * uniform   — every rank at nominal speed (sanity: async must match
//                 sync's final loss, since there is nothing to hide);
//   * straggler — one rank 4x slower. Synchronous DSGD is paced by the
//                 straggler (its lateness propagates through every
//                 neighbor exchange); asynchronous push-sum SGD lets the
//                 fast ranks keep stepping, draining the straggler's mass
//                 whenever it (virtually) arrives.
//
// Measured in *virtual time* on the simulated network/compute model:
// time until every rank's de-biased iterate reaches the target loss. The
// sync loop runs a fixed iteration count (collectives must stay matched
// across ranks); the async loop runs on a virtual-time budget so all
// ranks leave the regime near the same virtual instant — with a fixed
// per-rank step count the fast ranks would finish early and the straggler
// would split its push-sum mass into windows nobody drains until its
// weight underflows. Emits machine-readable `BENCH_async.json` and
// enforces the PR's acceptance gates:
//
//   * under the 4x straggler, async reaches the target in <= 1/1.5 of the
//     sync virtual time (speedup >= 1.5x; numerically validated margin
//     ~2.2-2.8x), and
//   * with no straggler, the async and sync final losses agree within 5%
//     (validated margin ~0.4%).
//
// Run: `make bench-async` (or `cargo run --release --example
// async_probe`). Env: ASYNC_SMOKE=1 shrinks the problem for CI;
// BENCH_ASYNC_OUT overrides the output path.

use bluefog::collective::{AllreduceAlgo, ReduceOp};
use bluefog::launcher::{run_spmd, AsyncSpec, SpmdConfig};
use bluefog::optim::{
    AsyncDecentralizedOptimizer, AsyncPushSumSgd, CommSpec, DecentralizedOptimizer, Dgd, StepOrder,
};
use bluefog::rng::Rng;
use bluefog::simnet::hetero::ComputeHeterogeneity;

const N: usize = 8; // nodes (expo2 topology, the launcher default)

#[derive(Clone, Copy)]
struct Problem {
    d: usize,         // features
    rows: usize,      // rows per node
    sync_iters: usize, // fixed sync iteration count (collectives stay matched)
    t_end: f64,       // async virtual-time budget, seconds
    gamma: f32,       // step size
    base_step: f64,   // nominal per-step compute seconds (virtual)
}

/// Per-node data `A_i [rows, d]`, `b_i [rows]`; `b = A x* + 0.1 noise`.
/// The mild noise keeps local optima close (small DGD bias floor) while
/// bounding the global optimum's loss away from zero.
fn make_data(rank: usize, p: &Problem) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0xa51c + rank as u64);
    let mut x_star_rng = Rng::new(0x57a8);
    let x_star: Vec<f32> = x_star_rng.normal_vec(p.d);
    let a: Vec<f32> = rng.normal_vec(p.rows * p.d);
    let mut b = vec![0.0f32; p.rows];
    for r in 0..p.rows {
        let mut dot = 0.0f32;
        for (ac, xc) in a[r * p.d..(r + 1) * p.d].iter().zip(&x_star) {
            dot += ac * xc;
        }
        b[r] = dot + 0.1 * rng.normal() as f32;
    }
    (a, b)
}

/// All nodes' datasets — deterministic, so every rank (and main) can
/// rebuild the *global* objective locally and evaluate any iterate on it.
fn global_data(p: &Problem) -> Vec<(Vec<f32>, Vec<f32>)> {
    (0..N).map(|r| make_data(r, p)).collect()
}

/// Global loss `(1/2 N rows) Σ_i ||A_i x − b_i||²` of an iterate.
fn global_loss(data: &[(Vec<f32>, Vec<f32>)], p: &Problem, x: &[f32]) -> f64 {
    let mut sum = 0.0f64;
    for (a, b) in data {
        for r in 0..p.rows {
            let mut dot = 0.0f32;
            for (ac, xc) in a[r * p.d..(r + 1) * p.d].iter().zip(x) {
                dot += ac * xc;
            }
            sum += ((dot - b[r]) as f64).powi(2);
        }
    }
    sum / (2.0 * (N * p.rows) as f64)
}

/// The global least-squares solution via the normal equations (Gaussian
/// elimination with partial pivoting) — anchors the target loss.
fn exact_solution(data: &[(Vec<f32>, Vec<f32>)], p: &Problem) -> Vec<f32> {
    let d = p.d;
    let mut aug = vec![0.0f64; d * (d + 1)];
    for (a, b) in data {
        for r in 0..p.rows {
            let row = &a[r * d..(r + 1) * d];
            for i in 0..d {
                let ari = row[i] as f64;
                aug[i * (d + 1) + d] += ari * b[r] as f64;
                for j in 0..d {
                    aug[i * (d + 1) + j] += ari * row[j] as f64;
                }
            }
        }
    }
    for col in 0..d {
        let piv = (col..d)
            .max_by(|&x, &y| {
                aug[x * (d + 1) + col].abs().partial_cmp(&aug[y * (d + 1) + col].abs()).unwrap()
            })
            .unwrap();
        if piv != col {
            for j in 0..=d {
                aug.swap(col * (d + 1) + j, piv * (d + 1) + j);
            }
        }
        let pv = aug[col * (d + 1) + col];
        for row in 0..d {
            if row != col {
                let f = aug[row * (d + 1) + col] / pv;
                for j in col..=d {
                    aug[row * (d + 1) + j] -= f * aug[col * (d + 1) + j];
                }
            }
        }
    }
    (0..d).map(|i| (aug[i * (d + 1) + d] / aug[i * (d + 1) + i]) as f32).collect()
}

/// Full-batch local gradient `A^T (A x − b) / rows` into `grad`.
fn local_grad(a: &[f32], b: &[f32], x: &[f32], p: &Problem, grad: &mut [f32]) {
    let d = p.d;
    for g in grad.iter_mut() {
        *g = 0.0;
    }
    for (r, br) in b.iter().enumerate() {
        let row = &a[r * d..(r + 1) * d];
        let mut dot = 0.0f32;
        for (ac, xc) in row.iter().zip(x) {
            dot += ac * xc;
        }
        let scale = (dot - br) / p.rows as f32;
        for (g, ac) in grad.iter_mut().zip(row) {
            *g += scale * ac;
        }
    }
}

struct Outcome {
    /// Virtual time at which *every* rank's iterate first reached target.
    ttt: f64,
    /// Global loss at the rank-averaged final iterate.
    final_loss: f64,
    /// Largest window staleness any rank observed (async only).
    max_staleness: f64,
}

fn collect_outcome(
    results: Vec<(Option<f64>, f64, f64)>,
    label: &str,
) -> anyhow::Result<(f64, f64)> {
    let mut ttt = 0.0f64;
    for (rank, &(hit, end_vtime, _)) in results.iter().enumerate() {
        let t = hit.ok_or_else(|| {
            anyhow::anyhow!(
                "{label}: rank {rank} never reached the target loss within its budget \
                 (ran to vtime {end_vtime:.3}s)"
            )
        })?;
        ttt = ttt.max(t);
    }
    Ok((ttt, results[0].2))
}

/// Synchronous DSGD (ATC over the static expo2 topology) under the given
/// compute profile. Compute is charged per step through the heterogeneity
/// model; the neighbor allreduce itself propagates straggler lateness.
fn run_sync(p: &Problem, hetero: ComputeHeterogeneity, target: f64) -> anyhow::Result<Outcome> {
    let prob = *p;
    let cfg = SpmdConfig::new(N).with_topo_check(false).with_async(AsyncSpec::new(hetero));
    let results = run_spmd(cfg, move |ctx| {
        let p = prob;
        let data = global_data(&p);
        let (a, b) = data[ctx.rank()].clone();
        let mut x = vec![0.0f32; p.d];
        let mut grad = vec![0.0f32; p.d];
        let mut opt = Dgd::new(p.gamma, StepOrder::Atc, CommSpec::Static);
        let mut hit: Option<f64> = None;
        for _ in 0..p.sync_iters {
            ctx.simulate_compute_hetero(p.base_step);
            local_grad(&a, &b, &x, &p, &mut grad);
            opt.step(ctx, &mut x, &grad)?;
            if hit.is_none() && global_loss(&data, &p, &x) <= target {
                hit = Some(ctx.vtime());
            }
        }
        let end_vtime = ctx.vtime();
        let x_bar = ctx.allreduce(&x, ReduceOp::Average, AllreduceAlgo::Ring)?;
        Ok((hit, end_vtime, global_loss(&data, &p, &x_bar)))
    })?;
    let (ttt, final_loss) = collect_outcome(results, "sync")?;
    Ok(Outcome { ttt, final_loss, max_staleness: 0.0 })
}

/// Asynchronous push-sum SGD under the given compute profile: one-sided
/// window ops, causal drains in receive-then-adapt order, no barriers; the
/// bounded-staleness horizon stands in for real wall time and the loop
/// runs on a virtual-time budget so all ranks leave the regime together.
fn run_async(p: &Problem, hetero: ComputeHeterogeneity, target: f64) -> anyhow::Result<Outcome> {
    let prob = *p;
    let horizon = 4.0 * p.base_step * hetero.max_factor();
    let spec = AsyncSpec::new(hetero).with_horizon(horizon);
    let cfg = SpmdConfig::new(N).with_topo_check(false).with_async(spec);
    let results = run_spmd(cfg, move |ctx| {
        let p = prob;
        let data = global_data(&p);
        let (a, b) = data[ctx.rank()].clone();
        let mut x = vec![0.0f32; p.d];
        let mut grad = vec![0.0f32; p.d];
        let mut opt = AsyncPushSumSgd::new(p.gamma, "async_probe");
        let mut hit: Option<f64> = None;
        let mut max_staleness = 0.0f64;
        // Safety cap well above t_end / base_step so a runaway loop ends.
        let step_cap = (4.0 * p.t_end / p.base_step) as usize;
        for _ in 0..step_cap {
            if ctx.vtime() >= p.t_end {
                break;
            }
            ctx.async_throttle();
            ctx.simulate_compute_hetero(p.base_step);
            // Receive-then-adapt: fold in arrived mass, then evaluate the
            // gradient on the refreshed iterate.
            opt.refresh(ctx, &mut x)?;
            local_grad(&a, &b, &x, &p, &mut grad);
            opt.step(ctx, &mut x, &grad)?;
            max_staleness = max_staleness.max(opt.staleness());
            if hit.is_none() && global_loss(&data, &p, &x) <= target {
                hit = Some(ctx.vtime());
            }
        }
        let end_vtime = ctx.vtime();
        opt.finalize(ctx, &mut x)?;
        let x_bar = ctx.allreduce(&x, ReduceOp::Average, AllreduceAlgo::Ring)?;
        Ok((hit, end_vtime, global_loss(&data, &p, &x_bar), max_staleness))
    })?;
    let max_staleness = results.iter().map(|r| r.3).fold(0.0f64, f64::max);
    let flat: Vec<(Option<f64>, f64, f64)> =
        results.into_iter().map(|(h, v, l, _)| (h, v, l)).collect();
    let (ttt, final_loss) = collect_outcome(flat, "async")?;
    Ok(Outcome { ttt, final_loss, max_staleness })
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("ASYNC_SMOKE").is_ok();
    let p = if smoke {
        Problem { d: 32, rows: 48, sync_iters: 90, t_end: 0.35, gamma: 0.25, base_step: 1e-3 }
    } else {
        Problem { d: 64, rows: 96, sync_iters: 110, t_end: 0.45, gamma: 0.2, base_step: 1e-3 }
    };
    let data = global_data(&p);
    let x_opt = exact_solution(&data, &p);
    let opt_loss = global_loss(&data, &p, &x_opt);
    let target = 2.0 * opt_loss;
    println!(
        "async probe: {N} nodes (expo2), linear regression d={} rows/node={} \
         | optimal loss {opt_loss:.6}, target {target:.6}",
        p.d, p.rows
    );

    // ---- profile 1: uniform compute (no straggler) ------------------------
    let uniform = ComputeHeterogeneity::uniform(N).with_jitter(0.05);
    let sync_u = run_sync(&p, uniform.clone(), target)?;
    let async_u = run_async(&p, uniform, target)?;
    let loss_delta_rel = (async_u.final_loss - sync_u.final_loss).abs() / sync_u.final_loss;
    println!(
        "  uniform  | sync  DSGD    : ttt {:>8.4}s | final loss {:.6}",
        sync_u.ttt, sync_u.final_loss
    );
    println!(
        "  uniform  | async push-sum: ttt {:>8.4}s | final loss {:.6} (delta {:+.2}%) | \
         max staleness {:.2} ms",
        async_u.ttt,
        async_u.final_loss,
        100.0 * (async_u.final_loss - sync_u.final_loss) / sync_u.final_loss,
        1e3 * async_u.max_staleness
    );

    // ---- profile 2: one 4x straggler --------------------------------------
    let strag = ComputeHeterogeneity::straggler(N, 0, 4.0).with_jitter(0.05);
    let sync_s = run_sync(&p, strag.clone(), target)?;
    let async_s = run_async(&p, strag, target)?;
    let speedup = sync_s.ttt / async_s.ttt;
    println!(
        "  straggler| sync  DSGD    : ttt {:>8.4}s | final loss {:.6}",
        sync_s.ttt, sync_s.final_loss
    );
    println!(
        "  straggler| async push-sum: ttt {:>8.4}s | final loss {:.6} | speedup {speedup:.2}x | \
         max staleness {:.2} ms",
        async_s.ttt,
        async_s.final_loss,
        1e3 * async_s.max_staleness
    );

    // ---- acceptance gates (ISSUE 5) ---------------------------------------
    anyhow::ensure!(
        speedup >= 1.5,
        "async push-sum speedup {speedup:.2}x under the 4x straggler is below the 1.5x gate \
         (sync {:.4}s vs async {:.4}s to target)",
        sync_s.ttt,
        async_s.ttt
    );
    anyhow::ensure!(
        loss_delta_rel <= 0.05,
        "async final loss {:.6} drifted {:.2}% from sync {:.6} with no straggler (gate: 5%)",
        async_u.final_loss,
        100.0 * loss_delta_rel,
        sync_u.final_loss
    );

    let json = format!(
        concat!(
            "{{\n  \"bench\": \"async\",\n  \"nodes\": {},\n  \"d\": {},\n",
            "  \"rows_per_node\": {},\n  \"sync_iters\": {},\n  \"t_end_s\": {},\n",
            "  \"gamma\": {},\n  \"base_step_s\": {},\n  \"smoke\": {},\n",
            "  \"optimal_loss\": {:.8},\n  \"target_loss\": {:.8},\n",
            "  \"uniform\": {{\n",
            "    \"sync\":  {{\"ttt_s\": {:.6}, \"final_loss\": {:.8}}},\n",
            "    \"async\": {{\"ttt_s\": {:.6}, \"final_loss\": {:.8}, ",
            "\"max_staleness_s\": {:.6}}},\n",
            "    \"final_loss_delta_rel\": {:.6}\n  }},\n",
            "  \"straggler_4x\": {{\n",
            "    \"sync\":  {{\"ttt_s\": {:.6}, \"final_loss\": {:.8}}},\n",
            "    \"async\": {{\"ttt_s\": {:.6}, \"final_loss\": {:.8}, ",
            "\"max_staleness_s\": {:.6}}},\n",
            "    \"speedup\": {:.4}\n  }}\n}}\n"
        ),
        N,
        p.d,
        p.rows,
        p.sync_iters,
        p.t_end,
        p.gamma,
        p.base_step,
        smoke,
        opt_loss,
        target,
        sync_u.ttt,
        sync_u.final_loss,
        async_u.ttt,
        async_u.final_loss,
        async_u.max_staleness,
        loss_delta_rel,
        sync_s.ttt,
        sync_s.final_loss,
        async_s.ttt,
        async_s.final_loss,
        async_s.max_staleness,
        speedup
    );
    let out_path = std::env::var("BENCH_ASYNC_OUT").unwrap_or_else(|_| "BENCH_async.json".into());
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    println!("async_probe OK");
    Ok(())
}
