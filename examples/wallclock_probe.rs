// Wall-clock probe (ISSUE 8): real milliseconds next to virtual-time
// numbers, measured over the two transport backends:
//
//   * sim — the portable workloads on in-process `SimBackend`s (one OS
//     thread per rank, no virtual network charging), plus a `run_spmd`
//     reference run that reports the virtual-time cost model's seconds
//     for the same DSGD shape;
//   * tcp — the same workloads as REAL OS processes over loopback TCP
//     (`run_tcp_job` re-executes this binary, one process per rank).
//
// Methodology (EXPERIMENTS.md §E16): the first WARMUP iterations of every
// run are discarded (socket buffers, allocator pools and branch caches
// warm up), stats are computed over the trimmed per-iteration wall times
// with `metrics::Stats` (mean/p95/ci90), and loopback numbers are a LOWER
// bound on real-network cost — no NIC, no switch, kernel memcpy only.
//
// Gates:
//   * sim/tcp parity: per-workload max |x_sim - x_tcp| <= 1e-6 and
//     bit-identical payload byte counters on every rank;
//   * failure path: a worker killed mid-run (abandoned sockets, no
//     Goodbye) surfaces as `peer_down` on every survivor and the whole
//     job still completes — the probe finishing is the no-hang gate;
//   * the JSON artifact (`BENCH_wallclock.json`) always carries real
//     milliseconds for both backends.
//
// Run: `make bench-wallclock` (or `cargo run --release --example
// wallclock_probe`). Env: WALLCLOCK_SMOKE=1 shrinks sizes for CI;
// BENCH_WALLCLOCK_OUT overrides the output path.

use bluefog::config::{PortableWorkload, TcpJobSpec};
use bluefog::launcher::{maybe_run_tcp_worker, run_spmd, run_tcp_job, worker_exit, SpmdConfig};
use bluefog::metrics::{cpu_features, cpu_model, Stats};
use bluefog::optim::{CommSpec, DecentralizedOptimizer, Dgd, StepOrder};
use bluefog::topology::builders;
use bluefog::transport::portable::{local_grad, regression_data, run_sim_fleet, RunOutput, RunSpec};

const NODES: usize = 4;
const TOPOLOGY: &str = "ring";
/// Discarded leading iterations (§E16 warmup).
const WARMUP: usize = 3;

struct Shape {
    iters: usize,
    dim: usize,
    rows: usize,
    gamma: f32,
}

fn shape(smoke: bool) -> Shape {
    if smoke {
        Shape { iters: 16, dim: 256, rows: 16, gamma: 0.05 }
    } else {
        Shape { iters: 48, dim: 4096, rows: 32, gamma: 0.05 }
    }
}

fn job(workload: PortableWorkload, s: &Shape) -> TcpJobSpec {
    TcpJobSpec {
        workload,
        nodes: NODES,
        iters: s.iters,
        dim: s.dim,
        rows: s.rows,
        gamma: s.gamma,
        topology: TOPOLOGY.into(),
        deadline_secs: 30.0,
        kill: None,
    }
}

/// Stats over the warmup-trimmed per-iteration wall milliseconds.
fn trimmed_stats(iter_ms: &[f64]) -> Stats {
    let trimmed = &iter_ms[WARMUP.min(iter_ms.len() - 1)..];
    Stats::from(trimmed)
}

/// Mean per-iteration milliseconds across all ranks (untrimmed; the
/// caller applies the §E16 warmup trim via [`trimmed_stats`]).
fn fleet_iter_ms(outs: &[RunOutput]) -> Vec<f64> {
    let iters = outs[0].iter_ms.len();
    (0..iters)
        .map(|i| outs.iter().map(|o| o.iter_ms[i]).sum::<f64>() / outs.len() as f64)
        .collect()
}

/// Virtual seconds the simulator's cost model charges for the same DSGD
/// shape (ring + Metropolis-Hastings, ATC order) — the number printed
/// next to the real milliseconds.
fn sim_vtime_dsgd(s: &Shape) -> anyhow::Result<f64> {
    let (graph, weights) = builders::by_name(TOPOLOGY, NODES)?;
    let cfg = SpmdConfig::new(NODES).with_topology(graph, weights).with_topo_check(false);
    let iters = s.iters;
    let dim = s.dim;
    let rows = s.rows;
    let gamma = s.gamma;
    let results = run_spmd(cfg, move |ctx| {
        let (a, b) = regression_data(ctx.rank(), dim, rows);
        let mut x = vec![0.0f32; dim];
        let mut grad = vec![0.0f32; dim];
        let mut opt = Dgd::new(gamma, StepOrder::Atc, CommSpec::Static);
        for _ in 0..iters {
            local_grad(&a, &b, &x, &mut grad);
            opt.step(ctx, &mut x, &grad)?;
        }
        Ok(ctx.vtime())
    })?;
    Ok(results.into_iter().fold(0.0f64, f64::max))
}

struct BackendRow {
    ms: Stats,
    bytes: Vec<u64>,
    x: Vec<Vec<f32>>,
}

/// One workload measured over both backends + the parity verdict.
struct WorkloadResult {
    name: &'static str,
    sim: BackendRow,
    tcp: BackendRow,
    max_delta: f64,
}

fn run_workload_rows(workload: PortableWorkload, s: &Shape) -> anyhow::Result<WorkloadResult> {
    let spec = job(workload, s);
    let run = RunSpec::from_job(&spec);

    let sim_outs: Vec<RunOutput> = run_sim_fleet(NODES, workload, &run)
        .into_iter()
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("sim fleet failed: {e}"))?;
    let sim = BackendRow {
        ms: trimmed_stats(&fleet_iter_ms(&sim_outs)),
        bytes: sim_outs.iter().map(|o| o.bytes_sent).collect(),
        x: sim_outs.into_iter().map(|o| o.x).collect(),
    };

    let report = run_tcp_job(&spec)?;
    let tcp_outs = report.outputs()?;
    let tcp = BackendRow {
        ms: trimmed_stats(&fleet_iter_ms(&tcp_outs)),
        bytes: tcp_outs.iter().map(|o| o.bytes_sent).collect(),
        x: tcp_outs.into_iter().map(|o| o.x).collect(),
    };

    let mut max_delta = 0.0f64;
    for (xs, xt) in sim.x.iter().zip(&tcp.x) {
        for (a, b) in xs.iter().zip(xt) {
            max_delta = max_delta.max((*a as f64 - *b as f64).abs());
        }
    }
    anyhow::ensure!(
        max_delta <= 1e-6,
        "{}: sim/tcp parameters diverged by {max_delta:.3e} (gate 1e-6)",
        workload.as_str()
    );
    anyhow::ensure!(
        sim.bytes == tcp.bytes,
        "{}: payload byte counters differ: sim {:?} vs tcp {:?}",
        workload.as_str(),
        sim.bytes,
        tcp.bytes
    );
    println!(
        "  {:<9} | sim {:8.4} ms/iter (p95 {:8.4}) | tcp {:8.4} ms/iter (p95 {:8.4}) | \
         max |delta| {max_delta:.2e} | bytes/rank {}",
        workload.as_str(),
        sim.ms.mean,
        sim.ms.p95,
        tcp.ms.mean,
        tcp.ms.p95,
        sim.bytes[0]
    );
    Ok(WorkloadResult { name: workload.as_str(), sim, tcp, max_delta })
}

/// Failure path: kill rank 2 before iteration 3 (sockets abandoned, no
/// Goodbye — a `kill -9` model). Every survivor must observe the typed
/// `peer_down` error; nothing may hang.
fn run_kill_gate(s: &Shape) -> anyhow::Result<()> {
    let mut spec = job(PortableWorkload::Consensus, s);
    spec.iters = 16.min(s.iters);
    spec.dim = 64.min(s.dim);
    spec.deadline_secs = 20.0;
    spec.kill = Some((2, 3));
    let report = run_tcp_job(&spec)?;
    let victim = &report.ranks[2];
    anyhow::ensure!(
        victim.exit_code == Some(worker_exit::KILLED),
        "victim exit code {:?}, expected {}",
        victim.exit_code,
        worker_exit::KILLED
    );
    for r in report.ranks.iter().filter(|r| r.rank != 2) {
        let err = r.error.as_ref();
        anyhow::ensure!(
            err.map(|e| e.kind == "peer_down").unwrap_or(false),
            "rank {} did not observe peer_down (got {:?}, exit code {:?})",
            r.rank,
            r.error,
            r.exit_code
        );
        anyhow::ensure!(
            r.exit_code == Some(worker_exit::COMM),
            "rank {} exit code {:?}, expected {}",
            r.rank,
            r.exit_code,
            worker_exit::COMM
        );
    }
    println!("  kill gate | rank 2 killed at iter 3 -> 3 survivors saw peer_down, no hang");
    Ok(())
}

fn stats_json(s: &Stats) -> String {
    format!(
        "{{\"mean_ms\": {:.6}, \"p95_ms\": {:.6}, \"ci90_ms\": {:.6}, \"n\": {}}}",
        s.mean, s.p95, s.ci90, s.n
    )
}

fn workload_json(w: &WorkloadResult) -> String {
    format!(
        concat!(
            "    \"{}\": {{\n",
            "      \"sim_wall\": {},\n",
            "      \"tcp_wall\": {},\n",
            "      \"max_delta\": {:.3e},\n",
            "      \"payload_bytes_per_rank\": {}\n",
            "    }}"
        ),
        w.name,
        stats_json(&w.sim.ms),
        stats_json(&w.tcp.ms),
        w.max_delta,
        w.sim.bytes[0]
    )
}

fn main() -> anyhow::Result<()> {
    // Worker mode first: `run_tcp_job` re-executes THIS binary as the
    // per-rank worker processes.
    maybe_run_tcp_worker();

    let smoke = std::env::var("WALLCLOCK_SMOKE").is_ok();
    let s = shape(smoke);
    println!(
        "wallclock probe: {NODES} procs ({TOPOLOGY}) dim={} iters={} warmup={WARMUP} smoke={smoke}",
        s.dim, s.iters
    );

    let vtime = sim_vtime_dsgd(&s)?;
    println!("  virtual   | cost-model DSGD time {vtime:.6} s (ring, ATC)");

    let consensus = run_workload_rows(PortableWorkload::Consensus, &s)?;
    let dsgd = run_workload_rows(PortableWorkload::Dsgd, &s)?;
    run_kill_gate(&s)?;

    let features = cpu_features().iter().map(|f| format!("\"{f}\"")).collect::<Vec<_>>().join(", ");
    let json = format!(
        concat!(
            "{{\n  \"bench\": \"wallclock\",\n  \"nodes\": {},\n  \"topology\": \"{}\",\n",
            "  \"dim\": {},\n  \"iters\": {},\n  \"warmup\": {},\n  \"smoke\": {},\n",
            "  \"cpu_model\": \"{}\",\n  \"cpu_features\": [{}],\n",
            "  \"loopback_lower_bound\": true,\n",
            "  \"sim_vtime_dsgd_s\": {:.6},\n",
            "  \"workloads\": {{\n{},\n{}\n  }}\n}}\n"
        ),
        NODES,
        TOPOLOGY,
        s.dim,
        s.iters,
        WARMUP,
        smoke,
        cpu_model().replace('"', "'"),
        features,
        vtime,
        workload_json(&consensus),
        workload_json(&dsgd),
    );
    let out_path =
        std::env::var("BENCH_WALLCLOCK_OUT").unwrap_or_else(|_| "BENCH_wallclock.json".into());
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    println!("wallclock_probe OK");
    Ok(())
}
