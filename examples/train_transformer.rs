//! End-to-end driver: decentralized transformer-LM training (paper §VII-B).
//!
//! Exercises the full three-layer stack on a real small workload:
//!
//! - **L2/L1**: the `tiny` transformer (≈0.4 M params) AOT-compiled by
//!   `python/compile/aot.py` from JAX (+ Pallas kernels in the `_pallas`
//!   variant);
//! - **runtime**: HLO-text artifacts loaded and executed via PJRT from Rust;
//! - **L3**: 8 simulated nodes training with decentralized momentum SGD
//!   (ATC order) over the exponential-2 topology with periodic global
//!   averaging (paper Listing 4), heterogeneous data shards, virtual-clock
//!   network accounting (2 machines x 4 ranks, NVLink + 25 Gbps tiers).
//!
//! Compares against the Horovod-style baseline (ring allreduce every step)
//! and reports losses, simulated wall-clock, and held-out accuracy.
//! Results are recorded in EXPERIMENTS.md §E10.
//!
//! Run: `make artifacts && cargo run --release --example train_transformer`
//! (use `--steps N` to override the default 300).

use bluefog::cli::Args;
use bluefog::config::{AlgoConfig, ModelPreset};
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{make_optimizer_cfg, CommSpec};
use bluefog::runtime::DeviceService;
use bluefog::simnet::NetworkModel;
use bluefog::topology::builders;
use bluefog::topology::dynamic::OnePeerExpo;
use bluefog::training::{eval_node, train_node, TrainRun};

const NODES: usize = 8;
const RANKS_PER_MACHINE: usize = 4;

struct Outcome {
    label: String,
    final_loss: f32,
    eval_loss: f32,
    eval_acc: f32,
    vtime: f64,
    wall: f64,
    logs: Vec<(usize, f32, f64)>,
}

fn run_one(
    label: &str,
    algo: &'static str,
    lr: f32,
    dynamic: bool,
    global_period: usize,
    steps: usize,
    device: &DeviceService,
) -> anyhow::Result<Outcome> {
    let preset = ModelPreset::by_name("tiny").unwrap();
    let (graph, weights) = builders::by_name("expo2", NODES)?;
    let cfg = SpmdConfig::new(NODES)
        .with_net(NetworkModel::aws_p3(RANKS_PER_MACHINE))
        .with_topology(graph, weights)
        .with_device(device.handle());
    let run = TrainRun::new(preset, steps);
    // One registry config covers the whole sweep — global averaging
    // included (paper Listing 4 is `global_period` in the schedule layer).
    let acfg = AlgoConfig {
        algo: algo.to_string(),
        gamma: lr,
        beta: 0.9,
        global_period,
        ..AlgoConfig::default()
    };
    let t0 = std::time::Instant::now();
    let results = run_spmd(cfg, move |ctx| {
        // The paper's throughput runs use the *dynamic* exponential-2
        // topology: one peer per iteration, so each step moves M bytes
        // instead of ring-allreduce's 2M (paper Fig. 12, [33]).
        let comm = if dynamic {
            CommSpec::Dynamic(std::sync::Arc::new(OnePeerExpo::new(ctx.size())))
        } else {
            CommSpec::Static
        };
        let mut opt = make_optimizer_cfg(&acfg, comm)?;
        let (logs, params) = train_node(ctx, &run, &mut opt)?;
        let (eval_loss, eval_acc) = eval_node(ctx, &run, &params, 4)?;
        Ok((logs, eval_loss, eval_acc, ctx.vtime()))
    })?;
    let wall = t0.elapsed().as_secs_f64();
    let (logs, eval_loss, eval_acc, vtime) = &results[0];
    Ok(Outcome {
        label: label.to_string(),
        final_loss: logs.last().map(|l| l.loss).unwrap_or(f32::NAN),
        eval_loss: *eval_loss,
        eval_acc: *eval_acc,
        vtime: *vtime,
        wall,
        logs: logs.iter().map(|l| (l.step, l.loss, l.vtime)).collect(),
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let steps = args.usize_or("steps", 300)?;
    anyhow::ensure!(
        std::path::Path::new("artifacts/train_step_tiny.hlo.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let device = DeviceService::new();
    println!(
        "# E2E: tiny transformer ({} params), {NODES} nodes ({RANKS_PER_MACHINE}/machine), {steps} steps",
        ModelPreset::by_name("tiny").unwrap().param_count()
    );

    let outcomes = vec![
        run_one("Horovod-style (ring allreduce)", "psgd", 0.08, false, 0, steps, &device)?,
        run_one("BlueFog ATC (dynamic expo2)", "atc", 0.5, true, 0, steps, &device)?,
        run_one("BlueFog DmSGD + global/20 (Listing 4)", "dmsgd-vanilla", 0.08, true, 20, steps, &device)?,
    ];

    println!("\n# loss curves (step, loss, simulated-time-s) from rank 0:");
    for o in &outcomes {
        println!("== {}", o.label);
        for (s, l, v) in o.logs.iter().step_by(3) {
            println!("  {s:5} {l:8.4} {v:10.4}");
        }
    }

    println!("\n# {:42} {:>10} {:>10} {:>8} {:>12} {:>9}", "algorithm", "final", "eval", "acc", "sim-time", "speedup");
    let base_vtime = outcomes[0].vtime;
    for o in &outcomes {
        println!(
            "  {:42} {:10.4} {:10.4} {:7.1}% {:11.4}s {:8.2}x",
            o.label,
            o.final_loss,
            o.eval_loss,
            o.eval_acc * 100.0,
            o.vtime,
            base_vtime / o.vtime
        );
    }
    println!("# (wall-clock on this container: {:?} s/run)", outcomes.iter().map(|o| o.wall.round()).collect::<Vec<_>>());

    // Validation: training must actually learn (well below uniform log 96 ≈
    // 4.56), and decentralized runs must be no slower than the ring
    // baseline in simulated time.
    for o in &outcomes {
        assert!(
            o.final_loss < 3.0,
            "{} did not learn: final loss {}",
            o.label,
            o.final_loss
        );
        assert!(o.eval_acc > 0.15, "{} eval accuracy too low", o.label);
    }
    let atc = &outcomes[1];
    assert!(
        atc.vtime <= base_vtime * 1.05,
        "decentralized ATC should not be slower than ring allreduce (got {} vs {})",
        atc.vtime,
        base_vtime
    );
    println!("train_transformer OK");
    Ok(())
}
