//! Decentralized linear regression (paper §IV-A, Appendices A & B).
//!
//! All nodes collaborate to solve
//! `x* = argmin (1/2n) sum_i ||A_i x - b_i||^2` where `A_i, b_i` are local.
//! Reproduces paper Listings 1, 6 and 7:
//!
//! - DGD over the static exponential graph (biased at fixed step size);
//! - Exact-Diffusion over the static ring (bias-corrected);
//! - Gradient-Tracking over the static ring (exact convergence);
//! - push-sum Gradient-Tracking over the *time-varying one-peer* grid.
//!
//! The per-node gradient `A^T (A x - b) / m` is computed by the AOT
//! `linreg_grad` artifact through the PJRT runtime — the same three-layer
//! path as DNN training (falls back to native Rust if artifacts are absent).
//!
//! Run: `cargo run --release --example linear_regression`

use std::sync::Arc;

use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{
    CommSpec, DecentralizedOptimizer, Dgd, ExactDiffusion, GradientTracking,
    PushSumGradientTracking, StepOrder,
};
use bluefog::rng::Rng;
use bluefog::runtime::{DeviceService, InputBuf};
use bluefog::tensor::norm2;
use bluefog::topology::dynamic::OnePeerFromGraph;
use bluefog::topology::{builders, WeightMatrix};

const N: usize = 8; // nodes
const M: usize = 64; // rows per node (matches the linreg_grad artifact)
const D: usize = 16; // features

/// Per-node data: A_i [M, D], b_i [M]; b = A x_star + noise.
fn make_data(rank: usize, x_star: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0x11ea + rank as u64);
    let a: Vec<f32> = rng.normal_vec(M * D);
    let mut b = vec![0.0f32; M];
    for r in 0..M {
        let mut dot = 0.0;
        for c in 0..D {
            dot += a[r * D + c] * x_star[c];
        }
        b[r] = dot + 1.0 * rng.normal() as f32; // strong per-node noise -> heterogeneous local optima
    }
    (a, b)
}

/// The global least-squares solution via the normal equations (reference).
fn exact_solution(datasets: &[(Vec<f32>, Vec<f32>)]) -> Vec<f32> {
    // Solve (sum A_i^T A_i) x = sum A_i^T b_i with Gaussian elimination.
    let mut ata = vec![0.0f64; D * D];
    let mut atb = vec![0.0f64; D];
    for (a, b) in datasets {
        for r in 0..M {
            for i in 0..D {
                let ari = a[r * D + i] as f64;
                atb[i] += ari * b[r] as f64;
                for j in 0..D {
                    ata[i * D + j] += ari * a[r * D + j] as f64;
                }
            }
        }
    }
    // Gaussian elimination with partial pivoting.
    let mut aug = vec![0.0f64; D * (D + 1)];
    for i in 0..D {
        for j in 0..D {
            aug[i * (D + 1) + j] = ata[i * D + j];
        }
        aug[i * (D + 1) + D] = atb[i];
    }
    for col in 0..D {
        let piv = (col..D)
            .max_by(|&a, &b| {
                aug[a * (D + 1) + col].abs().partial_cmp(&aug[b * (D + 1) + col].abs()).unwrap()
            })
            .unwrap();
        if piv != col {
            for j in 0..=D {
                aug.swap(col * (D + 1) + j, piv * (D + 1) + j);
            }
        }
        let p = aug[col * (D + 1) + col];
        for row in 0..D {
            if row != col {
                let f = aug[row * (D + 1) + col] / p;
                for j in col..=D {
                    aug[row * (D + 1) + j] -= f * aug[col * (D + 1) + j];
                }
            }
        }
    }
    (0..D).map(|i| (aug[i * (D + 1) + D] / aug[i * (D + 1) + i]) as f32).collect()
}

fn run_algorithm(
    label: &str,
    topo_name: &str,
    device: Option<bluefog::runtime::DeviceHandle>,
    make_opt: impl Fn(usize) -> Box<dyn DecentralizedOptimizer> + Send + Sync + 'static,
    iters: usize,
    x_opt: Vec<f32>,
) -> anyhow::Result<f64> {
    let (graph, weights) = builders::by_name(topo_name, N)?;
    let mut cfg = SpmdConfig::new(N).with_topology(graph, weights);
    if let Some(d) = device {
        cfg = cfg.with_device(d);
    }
    let x_opt_arc = Arc::new(x_opt);
    let x_opt2 = x_opt_arc.clone();
    let results = run_spmd(cfg, move |ctx| {
        let mut x_star_rng = Rng::new(0x57a2);
        let x_star: Vec<f32> = x_star_rng.normal_vec(D);
        let (a, b) = make_data(ctx.rank(), &x_star);
        let mut x = vec![0.0f32; D];
        let mut opt = make_opt(ctx.size());
        let use_artifact = ctx.device.is_some();
        if use_artifact {
            ctx.device.as_ref().unwrap().load("linreg_grad", "artifacts/linreg_grad.hlo.txt")?;
        }
        for _ in 0..iters {
            let grad: Vec<f32> = if use_artifact {
                // Three-layer path: gradient via the AOT artifact.
                let outs = ctx.device.as_ref().unwrap().execute(
                    "linreg_grad",
                    vec![
                        InputBuf::F32(a.clone(), vec![M, D]),
                        InputBuf::F32(x.clone(), vec![D]),
                        InputBuf::F32(b.clone(), vec![M]),
                    ],
                )?;
                outs[0].clone()
            } else {
                // Native fallback: A^T (A x - b) / M.
                let mut r = vec![0.0f32; M];
                for row in 0..M {
                    let mut dot = 0.0;
                    for c in 0..D {
                        dot += a[row * D + c] * x[c];
                    }
                    r[row] = dot - b[row];
                }
                let mut g = vec![0.0f32; D];
                for row in 0..M {
                    for c in 0..D {
                        g[c] += a[row * D + c] * r[row] / M as f32;
                    }
                }
                g
            };
            opt.step(ctx, &mut x, &grad)?;
        }
        // Error of the rank-local iterate vs the global solution.
        let err: f64 = x
            .iter()
            .zip(x_opt2.iter())
            .map(|(xi, oi)| (*xi as f64 - *oi as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        Ok(err)
    })?;
    let worst = results.iter().cloned().fold(0.0f64, f64::max);
    println!("{label:45} worst-node ||x - x*|| = {worst:.3e}");
    Ok(worst)
}

fn main() -> anyhow::Result<()> {
    // Build the shared ground truth once (same seeds as inside the nodes).
    let mut x_star_rng = Rng::new(0x57a2);
    let x_star: Vec<f32> = x_star_rng.normal_vec(D);
    let datasets: Vec<_> = (0..N).map(|r| make_data(r, &x_star)).collect();
    let x_opt = exact_solution(&datasets);
    println!("global least-squares solution ||x*|| = {:.4}", norm2(&x_opt));

    let have_artifacts = std::path::Path::new("artifacts/linreg_grad.hlo.txt").exists();
    let device = if have_artifacts {
        println!("(gradients through the AOT linreg_grad artifact)");
        Some(DeviceService::new())
    } else {
        println!("(artifacts not built; native gradient fallback)");
        None
    };
    let handle = device.as_ref().map(|d| d.handle());

    // Listing 1: DGD over the static exponential graph. Biased at fixed
    // step size — expect a visible error floor.
    let e_dgd = run_algorithm(
        "DGD (expo2, Listing 1)",
        "expo2",
        handle.clone(),
        |_| Box::new(Dgd::new(0.05, StepOrder::Atc, CommSpec::Static)),
        600,
        x_opt.clone(),
    )?;

    // Listing 6: Exact-Diffusion over the static ring.
    let e_ed = run_algorithm(
        "Exact-Diffusion (ring, Listing 6)",
        "ring",
        handle.clone(),
        |_| Box::new(ExactDiffusion::new(0.05, CommSpec::Static)),
        600,
        x_opt.clone(),
    )?;

    // Gradient tracking over the static ring.
    let e_gt = run_algorithm(
        "Gradient-Tracking (ring)",
        "ring",
        handle.clone(),
        |_| Box::new(GradientTracking::new(0.05, CommSpec::Static)),
        600,
        x_opt.clone(),
    )?;

    // Listing 7: push-sum GT over the one-peer time-varying grid.
    let e_ps = run_algorithm(
        "Push-sum GT (one-peer grid, Listing 7)",
        "mesh",
        handle.clone(),
        |n| {
            let base = builders::mesh_grid_2d(n);
            Box::new(PushSumGradientTracking::new(
                0.05,
                Arc::new(OnePeerFromGraph::new(&base)),
            ))
        },
        600,
        x_opt.clone(),
    )?;

    // Exactness ordering: bias-corrected methods beat DGD.
    assert!(e_ed < e_dgd, "Exact-Diffusion should beat DGD's bias floor");
    assert!(e_gt < e_dgd, "Gradient-Tracking should beat DGD's bias floor");
    assert!(e_ed < 5e-3 && e_gt < 5e-3, "corrected methods should reach the solution");
    assert!(e_ps < 0.2, "push-sum GT should approach the solution over dynamic topology");

    // Weight-matrix sanity: the chosen matrices have the claimed structure.
    let w_ring = WeightMatrix::metropolis_hastings(&builders::ring(N));
    assert!(w_ring.is_doubly_stochastic(1e-9));
    println!("linear_regression OK");
    Ok(())
}
