// Scale probe: drives the event-loop execution backend (ISSUE 6) through
// neighbor-allreduce consensus sweeps at 64 / 1024 / 10000 ranks on the
// static exponential-2 topology and emits machine-readable
// `BENCH_scale.json`. Thread-per-rank simulation tops out around a few
// hundred ranks (8 MiB stacks, OS scheduler thrash); the event-driven
// core parks every rank on a virtual-time priority queue, so the sweep is
// bounded by per-rank *state*, not per-rank *threads at full tilt*.
//
// Per row the probe records and enforces:
//
//   * consensus contraction: the per-iteration decay rate of the RMS
//     consensus error must beat `1 - 0.1 * spectral_gap` (theory says the
//     rate is ~`1 - gap` for the doubly-stochastic expo-2 averaging
//     matrix, so the gate has a wide margin while still scaling with the
//     gap — "error shrinks with the spectral gap");
//   * bounded memory: peak-RSS growth divided by rank count stays under
//     64 KiB/rank for the 1k+ rows (the 64-rank row is dominated by
//     fixed process overhead and is reported but not gated).
//
// Run: `make bench-scale` (or `cargo run --release --example
// scale_probe`). Env: SCALE_SMOKE=1 drops the 10k row for CI;
// BENCH_SCALE_OUT overrides the output path.

use bluefog::launcher::{run_spmd, ExecMode, SpmdConfig};
use bluefog::rng::Rng;
use bluefog::topology::{builders, SparseViews};

const D: usize = 16; // elements averaged per rank
const ITERS: usize = 10; // neighbor-allreduce rounds per row

/// Deterministic per-rank start vector; `main` regenerates the same
/// vectors to compute the initial consensus error without shipping them
/// back through the launcher.
fn start_vector(rank: usize) -> Vec<f32> {
    Rng::new(0x5ca1e ^ rank as u64).normal_vec(D)
}

/// RMS consensus error: `sqrt(mean_{i,j} (x_i[j] - mean_i x_i[j])^2)`,
/// accumulated in f64.
fn consensus_error(xs: &[Vec<f32>]) -> f64 {
    let n = xs.len();
    let d = xs[0].len();
    let mut mean = vec![0.0f64; d];
    for x in xs {
        for (m, v) in mean.iter_mut().zip(x) {
            *m += f64::from(*v);
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut acc = 0.0f64;
    for x in xs {
        for (m, v) in mean.iter().zip(x) {
            let dvt = f64::from(*v) - m;
            acc += dvt * dvt;
        }
    }
    (acc / (n * d) as f64).sqrt()
}

/// Peak resident set size in bytes (`VmHWM` from /proc/self/status).
/// Peak, not current: node threads join before the row ends, so current
/// RSS would credit freed stacks back and under-report.
fn peak_rss_bytes() -> u64 {
    let status = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

struct Row {
    ranks: usize,
    spectral_gap: f64,
    err0: f64,
    err_final: f64,
    contraction: f64,
    rss_per_rank_bytes: u64,
    vtime_final: f64,
    wall_s: f64,
}

fn sweep(n: usize) -> anyhow::Result<Row> {
    let graph = builders::exponential_two(n);
    let gap = SparseViews::uniform_pull(&graph).spectral_gap();

    let rss_before = peak_rss_bytes();
    let wall0 = std::time::Instant::now();

    let mut cfg = SpmdConfig::new(n)
        .with_exec(ExecMode::EventLoop)
        .with_sparse_topology(graph)
        .with_topo_check(false)
        .with_stack_size(256 << 10);
    // Blocking-only workload: skip the per-rank comm engines entirely.
    cfg.comm_threads = false;

    let results = run_spmd(cfg, move |ctx| {
        let mut x = start_vector(ctx.rank());
        for _ in 0..ITERS {
            x = ctx.neighbor_allreduce(&x)?;
        }
        Ok((x, ctx.vtime()))
    })?;

    let wall_s = wall0.elapsed().as_secs_f64();
    let rss_delta = peak_rss_bytes().saturating_sub(rss_before);

    let starts: Vec<Vec<f32>> = (0..n).map(start_vector).collect();
    let finals: Vec<Vec<f32>> = results.iter().map(|(x, _)| x.clone()).collect();
    let vtime_final = results.iter().map(|&(_, v)| v).fold(0.0f64, f64::max);

    let err0 = consensus_error(&starts);
    let err_final = consensus_error(&finals);
    let contraction = (err_final / err0).powf(1.0 / ITERS as f64);

    Ok(Row {
        ranks: n,
        spectral_gap: gap,
        err0,
        err_final,
        contraction,
        rss_per_rank_bytes: rss_delta / n as u64,
        vtime_final,
        wall_s,
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("SCALE_SMOKE").is_ok();
    let sizes: &[usize] = if smoke { &[64, 1024] } else { &[64, 1024, 10000] };

    let mut rows = Vec::new();
    for &n in sizes {
        let row = sweep(n)?;
        println!(
            "ranks={:>6}  gap={:.4}  err {:.4e} -> {:.4e}  contraction/iter={:.4}  \
             rss/rank={} B  vtime={:.4}s  wall={:.2}s",
            row.ranks,
            row.spectral_gap,
            row.err0,
            row.err_final,
            row.contraction,
            row.rss_per_rank_bytes,
            row.vtime_final,
            row.wall_s
        );
        rows.push(row);
    }

    // ---- acceptance gates (ISSUE 6) ---------------------------------------
    for row in &rows {
        anyhow::ensure!(
            row.err_final < row.err0,
            "consensus error grew at {} ranks: {:.4e} -> {:.4e}",
            row.ranks,
            row.err0,
            row.err_final
        );
        let gate = 1.0 - 0.1 * row.spectral_gap;
        anyhow::ensure!(
            row.contraction <= gate,
            "contraction {:.4} at {} ranks misses the spectral-gap gate {:.4} (gap {:.4})",
            row.contraction,
            row.ranks,
            gate,
            row.spectral_gap
        );
        if row.ranks >= 1024 {
            anyhow::ensure!(
                row.rss_per_rank_bytes <= 64 * 1024,
                "per-rank memory {} B at {} ranks exceeds the 64 KiB bound",
                row.rss_per_rank_bytes,
                row.ranks
            );
        }
    }

    let mut row_json = String::new();
    for (i, row) in rows.iter().enumerate() {
        row_json.push_str(&format!(
            concat!(
                "    {{\"ranks\": {}, \"spectral_gap\": {:.6}, \"err0\": {:.8e}, ",
                "\"err_final\": {:.8e}, \"contraction_per_iter\": {:.6}, ",
                "\"rss_per_rank_bytes\": {}, \"vtime_final_s\": {:.6}, \"wall_s\": {:.4}}}{}\n"
            ),
            row.ranks,
            row.spectral_gap,
            row.err0,
            row.err_final,
            row.contraction,
            row.rss_per_rank_bytes,
            row.vtime_final,
            row.wall_s,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    let json = format!(
        concat!(
            "{{\n  \"bench\": \"scale\",\n  \"exec\": \"event_loop\",\n",
            "  \"topology\": \"exponential_two\",\n  \"d\": {},\n  \"iters\": {},\n",
            "  \"smoke\": {},\n  \"rows\": [\n{}  ]\n}}\n"
        ),
        D, ITERS, smoke, row_json
    );
    let out_path = std::env::var("BENCH_SCALE_OUT").unwrap_or_else(|_| "BENCH_scale.json".into());
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    println!("scale_probe OK");
    Ok(())
}
