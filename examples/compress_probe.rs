// Compression probe: runs the decentralized linear-regression workload
// (paper §IV-A) under each compression scheme and compares it against the
// dense baseline on three axes — bytes on the wire (measured at the
// transport, not estimated), wall-clock ms per iteration, and end loss.
// Emits machine-readable `BENCH_compress.json` and enforces the PR's
// acceptance gates:
//
//   * TopK(k = d/16) puts >= 4x fewer bytes on the wire than dense, and
//   * its end loss lands within 5% of the dense baseline.
//
// Run: `make bench-compress` (or `cargo run --release --example
// compress_probe`). Env: COMPRESS_SMOKE=1 shrinks the problem for CI;
// BENCH_COMPRESS_OUT overrides the output path.
use std::time::Instant;

use bluefog::collective::{AllreduceAlgo, ReduceOp};
use bluefog::compress::CompressionSpec;
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{CommSpec, DecentralizedOptimizer, Dgd, StepOrder};
use bluefog::rng::Rng;

const N: usize = 8; // nodes

struct Problem {
    d: usize,     // features
    rows: usize,  // rows per node
    iters: usize,
    gamma: f32,
}

/// Per-node data A_i [rows, d], b_i [rows]; b = A x* + noise. The noise
/// keeps the global optimum's loss bounded away from zero so relative
/// end-loss comparisons are well-conditioned.
fn make_data(rank: usize, p: &Problem) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0xc0fe + rank as u64);
    let mut x_star_rng = Rng::new(0x57a7);
    let x_star: Vec<f32> = x_star_rng.normal_vec(p.d);
    let a: Vec<f32> = rng.normal_vec(p.rows * p.d);
    let mut b = vec![0.0f32; p.rows];
    for r in 0..p.rows {
        let mut dot = 0.0f32;
        for (ac, xc) in a[r * p.d..(r + 1) * p.d].iter().zip(&x_star) {
            dot += ac * xc;
        }
        b[r] = dot + rng.normal() as f32;
    }
    (a, b)
}

struct RunResult {
    label: String,
    ms_per_iter: f64,
    wire_bytes: u64,
    end_loss: f64,
}

/// One full training run under `spec`; returns the measured wire bytes of
/// the training loop only (warm-up and the final loss allreduce excluded)
/// and the global loss at the averaged iterate.
fn run_spec(p: &Problem, spec: CompressionSpec, label: String) -> anyhow::Result<RunResult> {
    let iters = p.iters;
    let (d, rows, gamma) = (p.d, p.rows, p.gamma);
    let results = run_spmd(
        SpmdConfig::new(N).with_topo_check(false).with_compression(spec),
        move |ctx| {
            let pr = Problem { d, rows, iters, gamma };
            let (a, b) = make_data(ctx.rank(), &pr);
            let mut x = vec![0.0f32; d];
            let mut opt = Dgd::new(gamma, StepOrder::Atc, CommSpec::Static);
            let mut grad = vec![0.0f32; d];
            let mut resid = vec![0.0f32; rows];
            // Align ranks, then count only the training loop's traffic.
            ctx.barrier()?;
            ctx.reset_bytes_sent();
            let t0 = Instant::now();
            for _ in 0..iters {
                // grad = A^T (A x - b) / rows
                for (r, res) in resid.iter_mut().enumerate() {
                    let mut dot = 0.0f32;
                    for (ac, xc) in a[r * d..(r + 1) * d].iter().zip(&x) {
                        dot += ac * xc;
                    }
                    *res = dot - b[r];
                }
                for g in grad.iter_mut() {
                    *g = 0.0;
                }
                for (r, res) in resid.iter().enumerate() {
                    let scale = res / rows as f32;
                    for (g, ac) in grad.iter_mut().zip(&a[r * d..(r + 1) * d]) {
                        *g += scale * ac;
                    }
                }
                opt.step(ctx, &mut x, &grad)?;
            }
            let dt = t0.elapsed().as_secs_f64();
            let bytes = ctx.bytes_sent();
            // End loss at the network-average iterate: (1/2nR) sum ||A x - b||^2,
            // via an (uncompressed, uncounted) global average of x and of the
            // per-node partial losses.
            let x_bar = ctx.allreduce(&x, ReduceOp::Average, AllreduceAlgo::Ring)?;
            let mut local = 0.0f64;
            for r in 0..rows {
                let mut dot = 0.0f32;
                for (ac, xc) in a[r * d..(r + 1) * d].iter().zip(&x_bar) {
                    dot += ac * xc;
                }
                local += ((dot - b[r]) as f64).powi(2);
            }
            local /= 2.0 * rows as f64;
            let loss = ctx.allreduce(&[local as f32], ReduceOp::Average, AllreduceAlgo::Ring)?;
            Ok((dt, bytes, loss[0] as f64))
        },
    )?;
    let dt = results.iter().map(|(t, _, _)| *t).fold(0.0f64, f64::max);
    let wire_bytes: u64 = results.iter().map(|(_, by, _)| *by).sum();
    let end_loss = results[0].2;
    Ok(RunResult {
        label,
        ms_per_iter: dt * 1e3 / p.iters as f64,
        wire_bytes,
        end_loss,
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("COMPRESS_SMOKE").is_ok();
    // rows = d/2 per node keeps the aggregate problem 4x overdetermined
    // (strongly convex, lambda_min ~ 0.25), so 600 iterations at gamma
    // well inside the local stability bound land both the dense and the
    // compressed runs on their common noise floor — the regime where the
    // 5% end-loss gate is meaningful rather than a race.
    let p = if smoke {
        Problem { d: 256, rows: 128, iters: 300, gamma: 0.08 }
    } else {
        Problem { d: 1024, rows: 512, iters: 600, gamma: 0.08 }
    };
    let k16 = p.d / 16;
    println!(
        "compress probe: {N} nodes (expo2), linear regression d={} rows/node={} iters={}",
        p.d, p.rows, p.iters
    );

    let dense = run_spec(&p, CompressionSpec::none(), "dense".into())?;
    println!(
        "  {:>16}: {:>7.3} ms/iter | {:>12} B on wire | end loss {:.6}",
        dense.label, dense.ms_per_iter, dense.wire_bytes, dense.end_loss
    );

    let specs = vec![
        CompressionSpec::top_k(k16),
        CompressionSpec::random_k(p.d / 8),
        CompressionSpec::quantize_u8(256),
        CompressionSpec::low_rank(2),
        CompressionSpec::top_k(k16).without_error_feedback(),
    ];
    let mut cases = Vec::new();
    for spec in specs {
        let r = run_spec(&p, spec, spec.label())?;
        let reduction = dense.wire_bytes as f64 / r.wire_bytes as f64;
        let loss_delta_rel = (r.end_loss - dense.end_loss).abs() / dense.end_loss;
        println!(
            "  {:>16}: {:>7.3} ms/iter | {:>12} B on wire ({reduction:>5.2}x less) | \
             end loss {:.6} (delta {:+.2}%)",
            r.label,
            r.ms_per_iter,
            r.wire_bytes,
            r.end_loss,
            100.0 * (r.end_loss - dense.end_loss) / dense.end_loss
        );
        cases.push((r, reduction, loss_delta_rel));
    }

    // Acceptance gates (ISSUE 3): TopK(k = d/16) with EF is case 0.
    let (topk, topk_reduction, topk_delta) = {
        let (r, red, delta) = &cases[0];
        (r, *red, *delta)
    };
    anyhow::ensure!(
        topk_reduction >= 4.0,
        "TopK(k=d/16) wire reduction {topk_reduction:.2}x below the 4x gate \
         ({} vs dense {} bytes)",
        topk.wire_bytes,
        dense.wire_bytes
    );
    anyhow::ensure!(
        topk_delta <= 0.05,
        "TopK(k=d/16) end loss {:.6} drifted {:.2}% from dense {:.6} (gate: 5%)",
        topk.end_loss,
        100.0 * topk_delta,
        dense.end_loss
    );

    let case_json: Vec<String> = cases
        .iter()
        .map(|(r, reduction, delta)| {
            format!(
                concat!(
                    "    {{\"label\": \"{}\", \"ms_per_iter\": {:.6}, \"wire_bytes\": {}, ",
                    "\"wire_reduction\": {:.4}, \"end_loss\": {:.8}, ",
                    "\"loss_delta_rel\": {:.6}}}"
                ),
                r.label, r.ms_per_iter, r.wire_bytes, reduction, r.end_loss, delta
            )
        })
        .collect();
    let json = format!(
        concat!(
            "{{\n  \"bench\": \"compress\",\n  \"nodes\": {},\n  \"d\": {},\n",
            "  \"rows_per_node\": {},\n  \"iters\": {},\n  \"gamma\": {},\n",
            "  \"smoke\": {},\n",
            "  \"baseline\": {{\"label\": \"dense\", \"ms_per_iter\": {:.6}, ",
            "\"wire_bytes\": {}, \"end_loss\": {:.8}}},\n",
            "  \"cases\": [\n{}\n  ]\n}}\n"
        ),
        N,
        p.d,
        p.rows,
        p.iters,
        p.gamma,
        smoke,
        dense.ms_per_iter,
        dense.wire_bytes,
        dense.end_loss,
        case_json.join(",\n")
    );
    let out_path =
        std::env::var("BENCH_COMPRESS_OUT").unwrap_or_else(|_| "BENCH_compress.json".into());
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    Ok(())
}
