// Algorithm-pipeline probe: exercises the composable schedule x weighting
// x compression surface (DESIGN.md §Algorithms) on the decentralized
// linear-regression workload and enforces the PR's acceptance gates,
// emitting machine-readable `BENCH_algos.json`:
//
//   A. **DIGEST local updates** (bytes-to-target-loss, EXPERIMENTS.md E17):
//      LocalUpdateSgd(H=8) must land within the shared loss target
//      (1.25x the dense D-SGD end loss) with >= 8x fewer wire bytes, and
//      >= 20x with TopK(k=d/16) compression stacked on top.
//   B. **DecentralizedADMM** (linearized prox) must converge on the same
//      workload over a ring: end loss <= 1.10x the dense D-SGD baseline.
//   C. **AL-DSGD dynamic weighting** must beat static MH rows on consensus
//      spread under a 4x straggler with non-IID shards: spread ratio
//      <= 0.95.
//
// Run: `make bench-algos` (or `cargo run --release --example algos_probe`).
// Env: ALGOS_SMOKE=1 shrinks the problems for CI; BENCH_ALGOS_OUT
// overrides the output path.
use bluefog::collective::{AllreduceAlgo, ReduceOp};
use bluefog::compress::CompressionSpec;
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{
    AlDsgdSpec, CommSpec, DecentralizedAdmm, DecentralizedOptimizer, Dgd, LocalUpdateSgd,
    NeighborWeighting, ProxKind, StepOrder,
};
use bluefog::rng::Rng;
use bluefog::topology::builders;

const N: usize = 8; // nodes
const H: usize = 8; // local steps per gossip round

#[derive(Clone, Copy)]
struct Problem {
    d: usize,    // features
    rows: usize, // rows per node
    iters: usize,
    gamma: f32,
}

/// Per-node IID regression data (same generator as `compress_probe`):
/// A_i [rows, d] standard normal, b = A x* + noise, shared x* (seed
/// 0x57a7) so the aggregate problem is strongly convex with a noise floor
/// bounded away from zero.
fn make_iid_data(rank: usize, d: usize, rows: usize) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0xc0fe + rank as u64);
    let x_star: Vec<f32> = Rng::new(0x57a7).normal_vec(d);
    let a: Vec<f32> = rng.normal_vec(rows * d);
    let mut b = vec![0.0f32; rows];
    for r in 0..rows {
        let mut dot = 0.0f32;
        for (ac, xc) in a[r * d..(r + 1) * d].iter().zip(&x_star) {
            dot += ac * xc;
        }
        b[r] = dot + rng.normal() as f32;
    }
    (a, b)
}

/// grad <- A^T (A x - b) / rows, reusing the caller's buffers.
fn regression_grad(a: &[f32], b: &[f32], x: &[f32], grad: &mut [f32], resid: &mut [f32]) {
    let d = x.len();
    let rows = b.len();
    for (r, res) in resid.iter_mut().enumerate() {
        let mut dot = 0.0f32;
        for (ac, xc) in a[r * d..(r + 1) * d].iter().zip(x) {
            dot += ac * xc;
        }
        *res = dot - b[r];
    }
    for g in grad.iter_mut() {
        *g = 0.0;
    }
    for (r, res) in resid.iter().enumerate() {
        let scale = res / rows as f32;
        for (g, ac) in grad.iter_mut().zip(&a[r * d..(r + 1) * d]) {
            *g += scale * ac;
        }
    }
}

/// Local contribution to the global loss at `x`: ||A x - b||^2 / (2 rows).
fn regression_loss(a: &[f32], b: &[f32], x: &[f32]) -> f64 {
    let d = x.len();
    let rows = b.len();
    let mut local = 0.0f64;
    for r in 0..rows {
        let mut dot = 0.0f32;
        for (ac, xc) in a[r * d..(r + 1) * d].iter().zip(x) {
            dot += ac * xc;
        }
        local += ((dot - b[r]) as f64).powi(2);
    }
    local / (2.0 * rows as f64)
}

struct GossipRun {
    label: String,
    wire_bytes: u64,
    comm_rounds: usize,
    end_loss: f64,
}

/// One training run of `iters` steps on the IID workload under `spec`;
/// `ring` selects the ring topology (Gate B) instead of the default
/// exponential-2 graph. Bytes count the training loop only; the end loss
/// is evaluated at the (uncounted) network-average iterate.
fn run_gossip(
    label: &str,
    p: &Problem,
    spec: CompressionSpec,
    ring: bool,
    make_opt: fn(f32) -> Box<dyn DecentralizedOptimizer>,
) -> anyhow::Result<GossipRun> {
    let Problem { d, rows, iters, gamma } = *p;
    let mut cfg = SpmdConfig::new(N).with_topo_check(false).with_compression(spec);
    if ring {
        let (graph, weights) = builders::by_name("ring", N)?;
        cfg = cfg.with_topology(graph, weights);
    }
    let results = run_spmd(cfg, move |ctx| {
        let (a, b) = make_iid_data(ctx.rank(), d, rows);
        let mut x = vec![0.0f32; d];
        let mut grad = vec![0.0f32; d];
        let mut resid = vec![0.0f32; rows];
        let mut opt = make_opt(gamma);
        ctx.barrier()?;
        ctx.reset_bytes_sent();
        for _ in 0..iters {
            regression_grad(&a, &b, &x, &mut grad, &mut resid);
            opt.step(ctx, &mut x, &grad)?;
        }
        let bytes = ctx.bytes_sent();
        let rounds = opt.comm_rounds();
        let x_bar = ctx.allreduce(&x, ReduceOp::Average, AllreduceAlgo::Ring)?;
        let local = regression_loss(&a, &b, &x_bar) as f32;
        let loss = ctx.allreduce(&[local], ReduceOp::Average, AllreduceAlgo::Ring)?;
        Ok((bytes, rounds, loss[0] as f64))
    })?;
    Ok(GossipRun {
        label: label.to_string(),
        wire_bytes: results.iter().map(|(by, _, _)| *by).sum(),
        comm_rounds: results[0].1,
        end_loss: results[0].2,
    })
}

/// Gate C data: per-rank regression around a *shifted* optimum
/// x*_i = x* + 0.5 delta_i (seed 0xbead + rank) — non-IID shards — plus a
/// shared noiseless validation set (seed 0x7a11) every node can score
/// itself on. Returns (A, b, A_val, b_val).
fn make_noniid_data(rank: usize, d: usize, rows: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let x_star: Vec<f32> = Rng::new(0x57a7).normal_vec(d);
    let mut rng = Rng::new(0xbead + rank as u64);
    let delta: Vec<f32> = rng.normal_vec(d);
    let shifted: Vec<f32> = x_star.iter().zip(&delta).map(|(xs, dl)| xs + 0.5 * dl).collect();
    let a: Vec<f32> = rng.normal_vec(rows * d);
    let mut b = vec![0.0f32; rows];
    for r in 0..rows {
        let mut dot = 0.0f32;
        for (ac, xc) in a[r * d..(r + 1) * d].iter().zip(&shifted) {
            dot += ac * xc;
        }
        b[r] = dot + 0.5 * rng.normal() as f32;
    }
    let mut vrng = Rng::new(0x7a11);
    let av: Vec<f32> = vrng.normal_vec(rows * d);
    let mut bv = vec![0.0f32; rows];
    for r in 0..rows {
        let mut dot = 0.0f32;
        for (ac, xc) in av[r * d..(r + 1) * d].iter().zip(&x_star) {
            dot += ac * xc;
        }
        bv[r] = dot;
    }
    (a, b, av, bv)
}

/// Gate C leg: LocalUpdateSgd(H) on the ring with non-IID shards and a 4x
/// straggler (rank 0 takes a local step only every 4th iteration — the
/// fixed-cadence image of `ComputeHeterogeneity::straggler(N, 0, 4.0)`).
/// Returns the consensus spread: the mean over the last 4 gossip rounds
/// of the max-node deviation ||x_i - x_bar||.
fn run_spread(p: &Problem, weighting: NeighborWeighting) -> anyhow::Result<f64> {
    let Problem { d, rows, iters, gamma } = *p;
    let (graph, weights) = builders::by_name("ring", N)?;
    let cfg = SpmdConfig::new(N).with_topology(graph, weights);
    let results = run_spmd(cfg, move |ctx| {
        let (a, b, av, bv) = make_noniid_data(ctx.rank(), d, rows);
        let mut x = vec![0.0f32; d];
        let mut grad = vec![0.0f32; d];
        let mut resid = vec![0.0f32; rows];
        let mut opt =
            LocalUpdateSgd::new(gamma, H, CommSpec::Static).with_weighting(weighting.clone());
        let mut spreads = Vec::new();
        for t in 0..iters {
            regression_grad(&a, &b, &x, &mut grad, &mut resid);
            // The AL-DSGD deviation signal: loss on the *shared* validation
            // set, so reports are comparable across non-IID shards.
            opt.observe_loss(regression_loss(&av, &bv, &x) as f32);
            let active = ctx.rank() != 0 || t % 4 == 0;
            opt.step_with_activity(ctx, &mut x, &grad, active)?;
            if (t + 1) % H == 0 {
                // Measurement-only collectives (not part of the algorithm).
                let x_bar = ctx.allreduce(&x, ReduceOp::Average, AllreduceAlgo::Ring)?;
                let dev = x
                    .iter()
                    .zip(&x_bar)
                    .map(|(xi, xb)| ((xi - xb) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                let mut report = vec![0.0f32; ctx.size()];
                report[ctx.rank()] = dev as f32;
                let all = ctx.allreduce(&report, ReduceOp::Sum, AllreduceAlgo::Ring)?;
                spreads.push(all.iter().fold(0.0f32, |m, &v| m.max(v)) as f64);
            }
        }
        let tail = &spreads[spreads.len().saturating_sub(4)..];
        Ok(tail.iter().sum::<f64>() / tail.len() as f64)
    })?;
    Ok(results[0])
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("ALGOS_SMOKE").is_ok();
    // Gate A/B problem: rows = d/2 per node keeps the aggregate system 4x
    // overdetermined (strongly convex); 600 iterations at gamma = 0.08
    // land dense D-SGD on its noise floor, so the 1.25x shared target is
    // a convergence bar, not a race.
    let (d, rows, iters) = if smoke { (128, 64, 300) } else { (256, 128, 600) };
    let p = Problem { d, rows, iters, gamma: 0.08 };
    // Gate C problem is smaller: the spread metric needs many gossip
    // rounds, not a tight loss floor.
    let cp = if smoke {
        Problem { d: 32, rows: 16, iters: 200, gamma: 0.08 }
    } else {
        Problem { d: 64, rows: 32, iters: 400, gamma: 0.08 }
    };
    println!("algos probe: {N} nodes, linear regression d={d} rows/node={rows} iters={iters}");

    // ---- Gate A: DIGEST local updates, bytes to the shared loss target --
    let dense = run_gossip("dense dsgd", &p, CompressionSpec::none(), false, |g| {
        Box::new(Dgd::new(g, StepOrder::Atc, CommSpec::Static))
    })?;
    let local = run_gossip("local-sgd H=8", &p, CompressionSpec::none(), false, |g| {
        Box::new(LocalUpdateSgd::new(g, H, CommSpec::Static))
    })?;
    // Stacked leg: TopK(k=d/16) under a damped gossip (gamma_g = 0.4
    // stabilizes the sparsified combine) needs ~1.5x the iterations to
    // reach the same target — still >= 20x fewer bytes end to end.
    let stacked = run_gossip(
        "local-sgd H=8 + topk(d/16)",
        &Problem { iters: iters * 3 / 2, ..p },
        CompressionSpec::top_k(d / 16).with_gossip_gamma(0.4),
        false,
        |g| Box::new(LocalUpdateSgd::new(g, H, CommSpec::Static)),
    )?;
    let target = 1.25 * dense.end_loss;
    for r in [&dense, &local, &stacked] {
        println!(
            "  {:>28}: {:>12} B on wire | {:>5} rounds | end loss {:.6}",
            r.label, r.wire_bytes, r.comm_rounds, r.end_loss
        );
    }
    let ratio_local = dense.wire_bytes as f64 / local.wire_bytes as f64;
    let ratio_stacked = dense.wire_bytes as f64 / stacked.wire_bytes as f64;
    anyhow::ensure!(
        local.end_loss <= target,
        "LocalUpdateSgd(H={H}) end loss {:.6} missed the shared target {target:.6}",
        local.end_loss
    );
    anyhow::ensure!(
        ratio_local >= 7.9,
        "LocalUpdateSgd(H={H}) byte reduction {ratio_local:.2}x below the 8x gate"
    );
    anyhow::ensure!(
        stacked.end_loss <= target,
        "stacked TopK end loss {:.6} missed the shared target {target:.6}",
        stacked.end_loss
    );
    anyhow::ensure!(
        ratio_stacked >= 20.0,
        "stacked TopK byte reduction {ratio_stacked:.2}x below the 20x gate"
    );
    println!("  gate A OK: {ratio_local:.1}x / {ratio_stacked:.1}x fewer bytes to target");

    // ---- Gate B: DecentralizedADMM converges on the ring ---------------
    let admm = run_gossip("admm (linearized)", &p, CompressionSpec::none(), true, |_| {
        Box::new(DecentralizedAdmm::new(8.0, ProxKind::Linearized { eta: 0.08 }))
    })?;
    println!(
        "  {:>28}: {:>12} B on wire | {:>5} rounds | end loss {:.6}",
        admm.label, admm.wire_bytes, admm.comm_rounds, admm.end_loss
    );
    let admm_rel = admm.end_loss / dense.end_loss;
    anyhow::ensure!(
        admm_rel <= 1.10,
        "DecentralizedAdmm end loss {:.6} is {admm_rel:.3}x dense (gate: 1.10x)",
        admm.end_loss
    );
    println!("  gate B OK: ADMM at {admm_rel:.3}x the dense end loss");

    // ---- Gate C: AL-DSGD weighting vs static MH rows on spread ---------
    let spread_static = run_spread(&cp, NeighborWeighting::Static)?;
    let spread_al = run_spread(&cp, NeighborWeighting::AlDsgd(AlDsgdSpec::default()))?;
    let spread_ratio = spread_al / spread_static;
    println!(
        "  spread under 4x straggler + non-IID: static {spread_static:.5}, \
         al-dsgd {spread_al:.5} ({spread_ratio:.3}x)"
    );
    anyhow::ensure!(
        spread_ratio <= 0.95,
        "AL-DSGD spread ratio {spread_ratio:.3} above the 0.95 gate"
    );
    println!("  gate C OK: AL-DSGD cut the consensus spread to {spread_ratio:.3}x");

    let json = format!(
        concat!(
            "{{\n  \"bench\": \"algos\",\n  \"nodes\": {},\n  \"d\": {},\n",
            "  \"rows_per_node\": {},\n  \"iters\": {},\n  \"gamma\": {},\n",
            "  \"smoke\": {},\n  \"local_steps\": {},\n",
            "  \"dense\": {{\"wire_bytes\": {}, \"comm_rounds\": {}, \"end_loss\": {:.8}}},\n",
            "  \"local\": {{\"wire_bytes\": {}, \"comm_rounds\": {}, \"end_loss\": {:.8}, ",
            "\"byte_reduction\": {:.4}}},\n",
            "  \"stacked_topk\": {{\"wire_bytes\": {}, \"comm_rounds\": {}, ",
            "\"end_loss\": {:.8}, \"byte_reduction\": {:.4}}},\n",
            "  \"admm\": {{\"wire_bytes\": {}, \"comm_rounds\": {}, \"end_loss\": {:.8}, ",
            "\"rel_to_dense\": {:.4}}},\n",
            "  \"al_dsgd\": {{\"spread_static\": {:.8}, \"spread_al\": {:.8}, ",
            "\"spread_ratio\": {:.4}}}\n}}\n"
        ),
        N,
        d,
        rows,
        iters,
        p.gamma,
        smoke,
        H,
        dense.wire_bytes,
        dense.comm_rounds,
        dense.end_loss,
        local.wire_bytes,
        local.comm_rounds,
        local.end_loss,
        ratio_local,
        stacked.wire_bytes,
        stacked.comm_rounds,
        stacked.end_loss,
        ratio_stacked,
        admm.wire_bytes,
        admm.comm_rounds,
        admm.end_loss,
        admm_rel,
        spread_static,
        spread_al,
        spread_ratio
    );
    let out_path = std::env::var("BENCH_ALGOS_OUT").unwrap_or_else(|_| "BENCH_algos.json".into());
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    Ok(())
}
