// Comparative hot-path probe: the naive allocating path (fresh `Vec` per
// payload/combine, k-pass kernels) vs the pooled/blocked path (rank-local
// buffer pool + single-pass blocked combine) on identical
// `neighbor_allreduce` workloads over a fully-connected graph (every rank
// fans out to n-1 neighbors). Emits machine-readable `BENCH_hotpath.json`
// with ms/op, effective GB/s and the pool hit rate after warm-up.
//
// Run: `make bench-hotpath` (or `cargo run --release --example perf_probe`).
// Env: HOTPATH_SMOKE=1 shrinks sizes/reps for CI; BENCH_HOTPATH_OUT
// overrides the output path.
use std::time::Instant;

use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::pool::{HotPath, PoolStats};
use bluefog::topology::builders;
use bluefog::topology::WeightMatrix;

struct ModeRun {
    ms_per_op: f64,
    gbps: f64,
    hits: u64,
    misses: u64,
}

impl ModeRun {
    /// Aggregate hit rate, using the library's own definition.
    fn hit_rate(&self) -> f64 {
        PoolStats { hits: self.hits, misses: self.misses, ..Default::default() }.hit_rate()
    }
}

fn run_mode(
    nodes: usize,
    numel: usize,
    reps: usize,
    warmup: usize,
    hot: HotPath,
) -> anyhow::Result<ModeRun> {
    let graph = builders::fully_connected(nodes);
    let weights = WeightMatrix::uniform_pull(&graph);
    let neighbors = nodes - 1;
    let results = run_spmd(
        SpmdConfig::new(nodes)
            .with_topology(graph, weights)
            .with_topo_check(false)
            .with_hot_path(hot),
        move |ctx| {
            let data = vec![1.0f32; numel];
            for _ in 0..warmup {
                let out = ctx.neighbor_allreduce(&data)?;
                ctx.recycle(out);
            }
            // Count only steady-state pool behavior, aligned across ranks.
            ctx.pool().reset_stats();
            ctx.barrier()?;
            let t0 = Instant::now();
            for _ in 0..reps {
                let out = ctx.neighbor_allreduce(&data)?;
                std::hint::black_box(&out);
                ctx.recycle(out);
            }
            let dt = t0.elapsed().as_secs_f64();
            let st = ctx.pool().stats();
            Ok((dt, st.hits, st.misses))
        },
    )?;
    let dt = results.iter().map(|(d, _, _)| *d).fold(0.0f64, f64::max);
    let hits: u64 = results.iter().map(|(_, h, _)| *h).sum();
    let misses: u64 = results.iter().map(|(_, _, m)| *m).sum();
    // Logical traffic: every rank receives `neighbors` tensors per op.
    let bytes = (reps * nodes * neighbors * numel * 4) as f64;
    Ok(ModeRun { ms_per_op: dt * 1e3 / reps as f64, gbps: bytes / dt / 1e9, hits, misses })
}

/// Best wall-clock of `trials` runs (thread-scheduling noise guard).
fn best_of(
    trials: usize,
    mut f: impl FnMut() -> anyhow::Result<ModeRun>,
) -> anyhow::Result<ModeRun> {
    let mut best: Option<ModeRun> = None;
    for _ in 0..trials {
        let r = f()?;
        best = Some(match best {
            Some(b) if b.ms_per_op <= r.ms_per_op => b,
            _ => r,
        });
    }
    Ok(best.expect("at least one trial"))
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("HOTPATH_SMOKE").is_ok();
    // 9 fully-connected nodes = the 8-neighbor fan-out case; smoke mode
    // keeps the same 9-node shape (so the fan-out/reclaim arity matches the
    // documented workload) but tiny tensors and few reps, finishing in
    // seconds on CI.
    let (nodes, warmup, cases): (usize, usize, Vec<(usize, usize)>) = if smoke {
        (9, 2, vec![(1 << 10, 5), (1 << 12, 5)])
    } else {
        (9, 4, vec![(1 << 12, 60), (1 << 16, 40), (1 << 20, 20)])
    };
    println!(
        "hot-path probe: {nodes} nodes fully connected ({} neighbors each), naive vs pooled",
        nodes - 1
    );
    let trials = if smoke { 1 } else { 2 };
    let mut entries = Vec::new();
    for &(numel, reps) in &cases {
        let naive = best_of(trials, || run_mode(nodes, numel, reps, warmup, HotPath::Naive))?;
        let pooled = best_of(trials, || run_mode(nodes, numel, reps, warmup, HotPath::Pooled))?;
        // The hit rate is deterministic (unlike wall time), so regressions
        // fail the probe — and the CI smoke step — loudly.
        anyhow::ensure!(
            pooled.hit_rate() > 0.9,
            "pool hit rate {:.1}% <= 90% after warm-up ({} hits / {} misses, numel {numel})",
            pooled.hit_rate() * 100.0,
            pooled.hits,
            pooled.misses
        );
        let speedup = naive.ms_per_op / pooled.ms_per_op;
        println!(
            "  {:>8} B/tensor x{reps}: naive {:>8.3} ms/op ({:>6.2} GB/s) | pooled {:>8.3} ms/op \
             ({:>6.2} GB/s) | speedup {:.2}x | pool hit rate {:.1}%",
            numel * 4,
            naive.ms_per_op,
            naive.gbps,
            pooled.ms_per_op,
            pooled.gbps,
            speedup,
            pooled.hit_rate() * 100.0
        );
        entries.push(format!(
            concat!(
                "    {{\"numel\": {}, \"bytes\": {}, \"reps\": {}, ",
                "\"naive\": {{\"ms_per_op\": {:.6}, \"gbps\": {:.4}}}, ",
                "\"pooled\": {{\"ms_per_op\": {:.6}, \"gbps\": {:.4}, ",
                "\"pool_hits\": {}, \"pool_misses\": {}, \"pool_hit_rate\": {:.4}}}, ",
                "\"speedup\": {:.4}}}"
            ),
            numel,
            numel * 4,
            reps,
            naive.ms_per_op,
            naive.gbps,
            pooled.ms_per_op,
            pooled.gbps,
            pooled.hits,
            pooled.misses,
            pooled.hit_rate(),
            speedup
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"nodes\": {nodes},\n  \"neighbors\": {},\n  \
         \"smoke\": {smoke},\n  \"cases\": [\n{}\n  ]\n}}\n",
        nodes - 1,
        entries.join(",\n")
    );
    let out_path =
        std::env::var("BENCH_HOTPATH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    Ok(())
}
