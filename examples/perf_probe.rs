// Comparative hot-path probe: the naive allocating path (fresh `Vec` per
// payload/combine, k-pass kernels) vs the pooled/blocked path (rank-local
// buffer pool + single-pass blocked combine) on identical
// `neighbor_allreduce` workloads over a fully-connected graph (every rank
// fans out to n-1 neighbors). Emits machine-readable `BENCH_hotpath.json`
// with ms/op, effective GB/s and the pool hit rate after warm-up.
//
// A second section A/Bs the combine kernels themselves on one rank:
// the frozen seed k-pass scalar kernel vs the blocked SIMD kernel at 1
// thread vs the same kernel sharded over the intra-rank worker pool.
// With >= 2 worker threads the probe *gates* on the SIMD+threads kernel
// reaching 2x the scalar GB/s.
//
// Run: `make bench-hotpath` (or `cargo run --release --example perf_probe`).
// Env: HOTPATH_SMOKE=1 shrinks sizes/reps for CI; HOTPATH_THREADS sizes the
// intra-rank worker pool (default: available cores capped at 4);
// BENCH_HOTPATH_OUT overrides the output path.
use std::time::Instant;

use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::metrics::{cpu_features, cpu_model};
use bluefog::parallel::WorkerPool;
use bluefog::pool::{HotPath, PoolStats};
use bluefog::tensor::{scalar, weighted_combine_blocked_into_par};
use bluefog::topology::builders;
use bluefog::topology::WeightMatrix;

struct ModeRun {
    ms_per_op: f64,
    gbps: f64,
    hits: u64,
    misses: u64,
}

impl ModeRun {
    /// Aggregate hit rate, using the library's own definition.
    fn hit_rate(&self) -> f64 {
        PoolStats { hits: self.hits, misses: self.misses, ..Default::default() }.hit_rate()
    }
}

fn run_mode(
    nodes: usize,
    numel: usize,
    reps: usize,
    warmup: usize,
    hot: HotPath,
    intra_threads: usize,
) -> anyhow::Result<ModeRun> {
    let graph = builders::fully_connected(nodes);
    let weights = WeightMatrix::uniform_pull(&graph);
    let neighbors = nodes - 1;
    let results = run_spmd(
        SpmdConfig::new(nodes)
            .with_topology(graph, weights)
            .with_topo_check(false)
            .with_hot_path(hot)
            .with_intra_threads(intra_threads),
        move |ctx| {
            let data = vec![1.0f32; numel];
            for _ in 0..warmup {
                let out = ctx.neighbor_allreduce(&data)?;
                ctx.recycle(out);
            }
            // Count only steady-state pool behavior, aligned across ranks.
            ctx.pool().reset_stats();
            ctx.barrier()?;
            let t0 = Instant::now();
            for _ in 0..reps {
                let out = ctx.neighbor_allreduce(&data)?;
                std::hint::black_box(&out);
                ctx.recycle(out);
            }
            let dt = t0.elapsed().as_secs_f64();
            let st = ctx.pool().stats();
            Ok((dt, st.hits, st.misses))
        },
    )?;
    let dt = results.iter().map(|(d, _, _)| *d).fold(0.0f64, f64::max);
    let hits: u64 = results.iter().map(|(_, h, _)| *h).sum();
    let misses: u64 = results.iter().map(|(_, _, m)| *m).sum();
    // Logical traffic: every rank receives `neighbors` tensors per op.
    let bytes = (reps * nodes * neighbors * numel * 4) as f64;
    Ok(ModeRun { ms_per_op: dt * 1e3 / reps as f64, gbps: bytes / dt / 1e9, hits, misses })
}

/// Best wall-clock of `trials` runs (thread-scheduling noise guard).
fn best_of(
    trials: usize,
    mut f: impl FnMut() -> anyhow::Result<ModeRun>,
) -> anyhow::Result<ModeRun> {
    let mut best: Option<ModeRun> = None;
    for _ in 0..trials {
        let r = f()?;
        best = Some(match best {
            Some(b) if b.ms_per_op <= r.ms_per_op => b,
            _ => r,
        });
    }
    Ok(best.expect("at least one trial"))
}

/// Intra-rank worker count: `HOTPATH_THREADS` env, else available cores
/// capped at 4 (the combine shards saturate memory bandwidth quickly).
fn resolve_threads() -> usize {
    match std::env::var("HOTPATH_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4),
    }
}

/// Best total wall time of `trials` timed loops of `reps` calls each,
/// after one discarded warmup call.
fn time_best(trials: usize, reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..trials {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

struct KernelRun {
    numel: usize,
    parts: usize,
    reps: usize,
    scalar_gbps: f64,
    simd_gbps: f64,
    simd_mt_gbps: f64,
}

/// Single-rank combine-kernel A/B: the frozen seed k-pass kernel
/// ([`scalar::weighted_combine`]) vs the blocked SIMD kernel serial vs
/// sharded over `threads` workers. All three compute the same
/// `w0*base + sum(w_i * p_i)`; GB/s uses the logical traffic of one
/// combine (read `parts + 1` buffers, write one output).
fn bench_kernels(numel: usize, parts: usize, reps: usize, threads: usize) -> KernelRun {
    let base = vec![1.0f32; numel];
    let peers: Vec<Vec<f32>> = (0..parts)
        .map(|i| (0..numel).map(|j| ((i * 31 + j) % 17) as f32 * 0.125 - 1.0).collect())
        .collect();
    let views: Vec<&[f32]> = peers.iter().map(|p| p.as_slice()).collect();
    let w = 1.0 / (parts + 1) as f32;
    let ws = vec![w; parts];
    let bytes = ((parts + 2) * numel * 4 * reps) as f64;
    let trials = 3;

    let mut all_views = vec![base.as_slice()];
    all_views.extend(views.iter().copied());
    let mut all_ws = vec![w];
    all_ws.extend(ws.iter().copied());
    let t_scalar = time_best(trials, reps, || {
        std::hint::black_box(scalar::weighted_combine(&all_views, &all_ws));
    });

    let mut acc = vec![0.0f32; numel];
    let t_simd = time_best(trials, reps, || {
        acc.copy_from_slice(&base);
        weighted_combine_blocked_into_par(WorkerPool::serial(), &mut acc, w, &views, &ws);
        std::hint::black_box(&acc);
    });

    let pool = WorkerPool::new(threads);
    let t_mt = time_best(trials, reps, || {
        acc.copy_from_slice(&base);
        weighted_combine_blocked_into_par(&pool, &mut acc, w, &views, &ws);
        std::hint::black_box(&acc);
    });

    KernelRun {
        numel,
        parts,
        reps,
        scalar_gbps: bytes / t_scalar / 1e9,
        simd_gbps: bytes / t_simd / 1e9,
        simd_mt_gbps: bytes / t_mt / 1e9,
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("HOTPATH_SMOKE").is_ok();
    // 9 fully-connected nodes = the 8-neighbor fan-out case; smoke mode
    // keeps the same 9-node shape (so the fan-out/reclaim arity matches the
    // documented workload) but tiny tensors and few reps, finishing in
    // seconds on CI.
    let (nodes, warmup, cases): (usize, usize, Vec<(usize, usize)>) = if smoke {
        (9, 2, vec![(1 << 10, 5), (1 << 12, 5)])
    } else {
        (9, 4, vec![(1 << 12, 60), (1 << 16, 40), (1 << 20, 20)])
    };
    let threads = resolve_threads();
    println!(
        "hot-path probe: {nodes} nodes fully connected ({} neighbors each), naive vs pooled, \
         {threads} intra-rank thread(s)",
        nodes - 1
    );

    let (knumel, kreps) = if smoke { (1 << 18, 8) } else { (1 << 21, 12) };
    let k = bench_kernels(knumel, 8, kreps, threads);
    println!(
        "  kernel A/B ({} KiB, {} parts): scalar {:>6.2} GB/s | SIMD x1 {:>6.2} GB/s | \
         SIMD x{threads} {:>6.2} GB/s",
        knumel * 4 / 1024,
        k.parts,
        k.scalar_gbps,
        k.simd_gbps,
        k.simd_mt_gbps
    );
    // The single-rank throughput gate (satisfiable only with real
    // parallelism; a 1-thread run still reports the numbers).
    if threads >= 2 {
        anyhow::ensure!(
            k.simd_mt_gbps >= 2.0 * k.scalar_gbps,
            "kernel gate: SIMD x{threads} {:.2} GB/s < 2x scalar {:.2} GB/s",
            k.simd_mt_gbps,
            k.scalar_gbps
        );
    }

    let trials = if smoke { 1 } else { 2 };
    let mut entries = Vec::new();
    for &(numel, reps) in &cases {
        let naive =
            best_of(trials, || run_mode(nodes, numel, reps, warmup, HotPath::Naive, threads))?;
        let pooled =
            best_of(trials, || run_mode(nodes, numel, reps, warmup, HotPath::Pooled, threads))?;
        // The hit rate is deterministic (unlike wall time), so regressions
        // fail the probe — and the CI smoke step — loudly.
        anyhow::ensure!(
            pooled.hit_rate() > 0.9,
            "pool hit rate {:.1}% <= 90% after warm-up ({} hits / {} misses, numel {numel})",
            pooled.hit_rate() * 100.0,
            pooled.hits,
            pooled.misses
        );
        let speedup = naive.ms_per_op / pooled.ms_per_op;
        println!(
            "  {:>8} B/tensor x{reps}: naive {:>8.3} ms/op ({:>6.2} GB/s) | pooled {:>8.3} ms/op \
             ({:>6.2} GB/s) | speedup {:.2}x | pool hit rate {:.1}%",
            numel * 4,
            naive.ms_per_op,
            naive.gbps,
            pooled.ms_per_op,
            pooled.gbps,
            speedup,
            pooled.hit_rate() * 100.0
        );
        entries.push(format!(
            concat!(
                "    {{\"numel\": {}, \"bytes\": {}, \"reps\": {}, ",
                "\"naive\": {{\"ms_per_op\": {:.6}, \"gbps\": {:.4}}}, ",
                "\"pooled\": {{\"ms_per_op\": {:.6}, \"gbps\": {:.4}, ",
                "\"pool_hits\": {}, \"pool_misses\": {}, \"pool_hit_rate\": {:.4}}}, ",
                "\"speedup\": {:.4}}}"
            ),
            numel,
            numel * 4,
            reps,
            naive.ms_per_op,
            naive.gbps,
            pooled.ms_per_op,
            pooled.gbps,
            pooled.hits,
            pooled.misses,
            pooled.hit_rate(),
            speedup
        ));
    }
    let features = cpu_features().iter().map(|f| format!("\"{f}\"")).collect::<Vec<_>>().join(", ");
    let json = format!(
        concat!(
            "{{\n  \"bench\": \"hotpath\",\n  \"nodes\": {nodes},\n  \"neighbors\": {},\n",
            "  \"smoke\": {smoke},\n",
            "  \"cpu_model\": \"{}\",\n  \"cpu_features\": [{}],\n",
            "  \"intra_threads\": {threads},\n",
            "  \"kernel\": {{\"numel\": {}, \"parts\": {}, \"reps\": {}, ",
            "\"scalar_gbps\": {:.4}, \"simd_gbps\": {:.4}, \"simd_mt_gbps\": {:.4}}},\n",
            "  \"cases\": [\n{}\n  ]\n}}\n"
        ),
        nodes - 1,
        cpu_model().replace('"', "'"),
        features,
        k.numel,
        k.parts,
        k.reps,
        k.scalar_gbps,
        k.simd_gbps,
        k.simd_mt_gbps,
        entries.join(",\n"),
        nodes = nodes,
        smoke = smoke,
        threads = threads
    );
    let out_path =
        std::env::var("BENCH_HOTPATH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    std::fs::write(&out_path, json)?;
    println!("wrote {out_path}");
    Ok(())
}
