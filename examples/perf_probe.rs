// L3 hot-path probe: wall time of large neighbor_allreduce + training step marshalling.
use bluefog::launcher::{run_spmd, SpmdConfig};
fn main() -> anyhow::Result<()> {
    let n = 8;
    let numel = 1 << 20; // 4 MB
    let reps = 30;
    let t0 = std::time::Instant::now();
    run_spmd(SpmdConfig::new(n).with_topo_check(false), move |ctx| {
        let data = vec![1.0f32; numel];
        for _ in 0..reps {
            let out = ctx.neighbor_allreduce(&data)?;
            std::hint::black_box(&out);
        }
        Ok(())
    })?;
    let dt = t0.elapsed().as_secs_f64();
    println!("neighbor_allreduce 4MB x{reps} x{n} nodes: total {:.3}s, {:.2} ms/op/node, {:.2} GB/s effective", dt, dt*1e3/reps as f64, (reps*n*3*numel*4) as f64/dt/1e9);
    Ok(())
}
