//! Asynchronous push-sum average consensus via window operations
//! (paper §IV-C, Listing 3).
//!
//! Every node owns `x_i^(0)`; the goal is for all nodes to learn the global
//! average **without any synchronization between neighbors**. Naive
//! asynchronous gossip is biased; push-sum fixes it by propagating an extra
//! scalar weight `p` alongside `x` (the extended vector `x_ext = [x; p]`)
//! with *column-stochastic* (mass-conserving) weights: each node's
//! `x/p` converges to the unbiased average.
//!
//! The asynchronous primitives are exactly the paper's:
//! `win_create` → loop { `win_accumulate` (with mutex),
//! `win_update_then_collect` } → `barrier` → `win_free`.
//! Nodes deliberately run different speeds (per-rank extra work) to
//! exercise asynchrony.
//!
//! Run: `cargo run --release --example async_push_sum`

use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::topology::{builders, WeightMatrix};

const N: usize = 8;
const D: usize = 4; // payload dimension
const ITERS: usize = 150;

fn main() -> anyhow::Result<()> {
    let g = builders::exponential_two(N);
    let w = WeightMatrix::uniform_pull(&g);
    let cfg = SpmdConfig::new(N).with_topology(g, w);

    let results = run_spmd(cfg, |ctx| {
        let rank = ctx.rank();
        // Node-local initial vector: deterministic, distinct per rank.
        let x0: Vec<f32> = (0..D).map(|j| (rank * D + j) as f32).collect();

        // x_ext = [x; p] with the push-sum weight p initialized to 1
        // (Listing 3, lines 1-3).
        let mut x_ext: Vec<f32> = x0.clone();
        x_ext.push(1.0);
        ctx.win_create("x_ext", &x_ext, /*zero_init=*/ true)?;

        // Push-style weights: split mass evenly over out-neighbors + self
        // (Listing 3, lines 6-8). Column-stochastic by construction.
        let out = ctx.out_neighbor_ranks();
        let self_weight = 1.0 / (out.len() + 1) as f64;
        let dst_weights: Vec<(usize, f64)> =
            out.iter().map(|&r| (r, self_weight)).collect();

        for i in 0..ITERS {
            // Simulated speed heterogeneity: per-rank pacing, so windows
            // fill asynchronously but with bounded delay
            // (100 us + rank-dependent jitter). Without it, the 1-core
            // scheduler can run one node's whole loop before its peers get
            // CPU time — the unbounded-delay regime where push-sum's weight
            // decays to floating-point zero before any mass arrives. Real
            // clusters (and BlueFog's MPI windows) have rough fairness.
            std::thread::sleep(std::time::Duration::from_micros(100 + 37 * rank as u64 % 100));
            // Push a share of (x, p) into every out-neighbor's window
            // buffer; the mutex prevents read/write races (require_mutex).
            ctx.win_accumulate("x_ext", &mut x_ext, self_weight, &dst_weights)?;
            // Drain whatever neighbors have pushed so far; reset the
            // buffers so mass is counted exactly once.
            ctx.win_update_then_collect("x_ext", &mut x_ext)?;
            // Invariant check (any time, any node): p stays positive.
            anyhow::ensure!(
                x_ext[D] > 0.0,
                "push-sum weight collapsed at iter {i} (unbounded asynchrony)"
            );
        }

        // Different processes may end at different times (Listing 3 line 16).
        ctx.barrier()?;
        ctx.win_update_then_collect("x_ext", &mut x_ext)?;
        ctx.win_free("x_ext")?;

        // Unbiased estimate: y = x / p (eq. (21)).
        let p = x_ext[D];
        let y: Vec<f32> = x_ext[..D].iter().map(|v| v / p).collect();
        Ok((y, p))
    })?;

    // True average of the initial vectors.
    let want: Vec<f32> = (0..D)
        .map(|j| (0..N).map(|r| (r * D + j) as f32).sum::<f32>() / N as f32)
        .collect();
    println!("true average: {want:?}");
    let mut worst = 0.0f64;
    for (rank, (y, p)) in results.iter().enumerate() {
        let err: f64 = y
            .iter()
            .zip(&want)
            .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        println!("rank {rank}: y = {y:?} (p = {p:.3}), error {err:.2e}");
        worst = worst.max(err);
    }
    // Mass conservation across the network: sum of p must remain N.
    let p_total: f64 = results.iter().map(|(_, p)| *p as f64).sum();
    println!("sum of push-sum weights: {p_total:.6} (expected {N})");
    assert!((p_total - N as f64).abs() < 1e-3, "push-sum mass leaked");
    assert!(worst < 1e-3, "asynchronous push-sum did not reach consensus: {worst}");
    println!("async_push_sum OK");
    Ok(())
}
