//! Mobile adaptive network: the fish-school simulation (paper §IV-B,
//! Figs. 5–6).
//!
//! Each fish is one SPMD node. Neighborhoods are *spatial* — fish within a
//! distance threshold — so the topology changes every iteration as the
//! school moves. Each fish holds a noisy local measurement of the distance
//! and azimuth to a predator, and the school estimates the predator's
//! position `w*` by decentralized SGD over the time-varying
//! Metropolis–Hastings topology (paper Listing 2), then **disperses** from
//! it, and — once a second predator appears stationary — **encircles** it.
//!
//! Position exchange uses `neighbor_allgather`; the estimate uses
//! dynamic `neighbor_allreduce` with per-iteration `src/dst` weights.
//!
//! Run: `cargo run --release --example fish_school`

use bluefog::collective::neighbor::NeighborWeights;
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::tensor::axpy;
use bluefog::topology::{builders, WeightMatrix};

const N_FISH: usize = 16;
const THRESHOLD: f64 = 3.0; // neighborhood radius
const GAMMA: f32 = 0.25; // estimation step size

/// Metropolis–Hastings weights from the current spatial neighborhoods
/// (paper: "src_weights is updated at each iteration through the neighbor
/// location collections function and Metropolis-Hastings Rule").
fn mh_weights(my_rank: usize, neighbors: &[usize], degrees: &[usize]) -> (f64, Vec<(usize, f64)>) {
    let my_deg = degrees[my_rank];
    let mut src = Vec::with_capacity(neighbors.len());
    let mut total = 0.0;
    for &j in neighbors {
        let w = 1.0 / (1 + my_deg.max(degrees[j])) as f64;
        src.push((j, w));
        total += w;
    }
    (1.0 - total, src)
}

/// All pairwise spatial neighborhoods from gathered positions.
fn neighborhoods(positions: &[(f64, f64)]) -> (Vec<Vec<usize>>, Vec<usize>) {
    let n = positions.len();
    let mut nbrs = vec![vec![]; n];
    for i in 0..n {
        for j in 0..n {
            if i != j {
                let dx = positions[i].0 - positions[j].0;
                let dy = positions[i].1 - positions[j].1;
                if (dx * dx + dy * dy).sqrt() <= THRESHOLD {
                    nbrs[i].push(j);
                }
            }
        }
    }
    let degrees = nbrs.iter().map(|v| v.len()).collect();
    (nbrs, degrees)
}

fn main() -> anyhow::Result<()> {
    // Fully-connected graph as the *window* for allgather of positions (the
    // spatial neighborhood is applied on top of the gathered locations).
    let g = builders::fully_connected(N_FISH);
    let w = WeightMatrix::metropolis_hastings(&g);
    let cfg = SpmdConfig::new(N_FISH).with_topology(g, w);

    let results = run_spmd(cfg, |ctx| {
        let rank = ctx.rank();
        let n = ctx.size();
        // Initial school: a tight cluster around the origin.
        let mut pos = (
            (rank % 4) as f64 * 1.2 - 1.8 + 0.1 * ctx.rng.normal(),
            (rank / 4) as f64 * 1.2 - 1.8 + 0.1 * ctx.rng.normal(),
        );
        let predator = (6.0f64, 5.0f64);
        let mut w_est = vec![0.0f32; 2]; // local estimate of predator position
        let mut spread_log = vec![];
        let mut err_log = vec![];

        for iter in 0..120 {
            // 1. Collect all fish locations (system-level neighbor_allgather
            //    over the fully-connected window).
            let mine = vec![pos.0 as f32, pos.1 as f32];
            let gathered = ctx.neighbor_allgather(&mine)?;
            let mut positions = vec![(0.0f64, 0.0f64); n];
            positions[rank] = pos;
            for (src, p) in &gathered {
                positions[*src] = (p[0] as f64, p[1] as f64);
            }

            // 2. Dynamic spatial topology + Metropolis-Hastings weights.
            let (nbrs, degrees) = neighborhoods(&positions);
            let (self_w, src_w) = mh_weights(rank, &nbrs[rank], &degrees);

            // 3. Noisy local observation: distance + direction to predator.
            let dx = predator.0 - pos.0;
            let dy = predator.1 - pos.1;
            let dist = (dx * dx + dy * dy).sqrt();
            let theta = dy.atan2(dx) + 0.05 * ctx.rng.normal();
            let d_obs = dist + 0.1 * ctx.rng.normal();
            let u = [theta.cos() as f32, theta.sin() as f32];

            // 4. D-SGD step on f_i(w) = 0.5 (d - u^T (w - x_i))^2.
            let proj = u[0] * (w_est[0] - pos.0 as f32) + u[1] * (w_est[1] - pos.1 as f32);
            let resid = proj - d_obs as f32;
            let grad = [resid * u[0], resid * u[1]];
            axpy(-GAMMA, &grad, &mut w_est);

            // 5. Partial averaging over the *time-varying* topology
            //    (pull-style dynamic neighbor_allreduce, paper Listing 2).
            let weights = NeighborWeights::push_pull(
                self_w,
                src_w.clone(),
                src_w.iter().map(|&(r, _)| (r, 1.0)).collect(),
            );
            w_est = ctx.neighbor_allreduce_dynamic(&w_est, &weights)?;

            // 6. Behavior: disperse for the first 60 iters, then encircle.
            let to_pred = (w_est[0] as f64 - pos.0, w_est[1] as f64 - pos.1);
            let dist_est = (to_pred.0 * to_pred.0 + to_pred.1 * to_pred.1).sqrt().max(1e-6);
            if iter < 60 {
                // escape: move away from the estimated predator position.
                pos.0 -= 0.08 * to_pred.0 / dist_est;
                pos.1 -= 0.08 * to_pred.1 / dist_est;
            } else {
                // encircle: approach a ring of radius 2 around the estimate.
                let target_r = 2.0;
                let radial = dist_est - target_r;
                pos.0 += 0.10 * radial * to_pred.0 / dist_est;
                pos.1 += 0.10 * radial * to_pred.1 / dist_est;
                // tangential motion to spread around the ring
                pos.0 += 0.05 * (-to_pred.1 / dist_est);
                pos.1 += 0.05 * (to_pred.0 / dist_est);
            }

            // Logs: school spread and estimation error.
            if iter % 20 == 19 {
                let cx: f64 = positions.iter().map(|p| p.0).sum::<f64>() / n as f64;
                let cy: f64 = positions.iter().map(|p| p.1).sum::<f64>() / n as f64;
                let spread = positions
                    .iter()
                    .map(|p| ((p.0 - cx).powi(2) + (p.1 - cy).powi(2)).sqrt())
                    .sum::<f64>()
                    / n as f64;
                let err = ((w_est[0] as f64 - predator.0).powi(2)
                    + (w_est[1] as f64 - predator.1).powi(2))
                .sqrt();
                spread_log.push(spread);
                err_log.push(err);
            }
        }
        // Final ring radius around the true predator.
        let r_final = ((pos.0 - predator.0).powi(2) + (pos.1 - predator.1).powi(2)).sqrt();
        Ok((spread_log, err_log, r_final))
    })?;

    let (spread, err, _) = &results[0];
    println!("# iter-window  school-spread  predator-estimate-error (rank 0)");
    for (i, (s, e)) in spread.iter().zip(err).enumerate() {
        println!("{:>4}..{:<4}   {s:10.3}     {e:10.3}", i * 20, i * 20 + 19);
    }
    let radii: Vec<f64> = results.iter().map(|(_, _, r)| *r).collect();
    let mean_r: f64 = radii.iter().sum::<f64>() / radii.len() as f64;
    let spread_r: f64 =
        radii.iter().map(|r| (r - mean_r).abs()).fold(0.0, f64::max);
    println!("final encircle radius: mean {mean_r:.2} (target 2.0), max dev {spread_r:.2}");

    // The estimate must converge despite the dynamic topology (Fig. 5/6).
    assert!(err.last().unwrap() < &0.5, "predator estimate did not converge: {err:?}");
    // Disperse phase (windows 0..2 cover iters 0..59) must grow the spread.
    assert!(spread[2] > spread[0], "school did not disperse: {spread:?}");
    // Encircle phase must put every fish near the radius-2 ring.
    assert!((mean_r - 2.0).abs() < 0.7, "school did not encircle (mean radius {mean_r})");
    println!("fish_school OK");
    Ok(())
}
