//! Minimal, pure-std reimplementation of the subset of the `anyhow` API this
//! workspace uses. The real crate is unavailable offline, so this shim
//! provides the same surface with identical call-site syntax:
//!
//! - [`Error`] / [`Result`] — a string-message error with a context chain;
//! - [`anyhow!`] — build an [`Error`] from a format string (or any
//!   `Display` value);
//! - [`bail!`] — early-return `Err(anyhow!(...))`;
//! - [`ensure!`] — `bail!` unless a condition holds;
//! - `Error::context` — wrap an error with an outer message (shown by the
//!   `{:#}` alternate formatting as `outer: inner: ...`);
//! - a blanket `From<E: std::error::Error>` so `?` converts std errors.
//!
//! Like the real `anyhow::Error`, [`Error`] deliberately does **not**
//! implement `std::error::Error` — that is what makes the blanket `From`
//! impl coherent.

use std::fmt;

/// `Result` specialized to [`Error`], the usual `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-message error with an optional chain of context messages.
///
/// `messages[0]` is the innermost (root) message; later entries are context
/// layers added by [`Error::context`], outermost last.
pub struct Error {
    messages: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { messages: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    ///
    /// `{}` shows only the outermost message; `{:#}` shows the whole chain
    /// as `outer: ...: root` (matching anyhow's alternate formatting).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.messages.push(context.to_string());
        self
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        &self.messages[0]
    }

    /// Iterate the chain outermost-first (like `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.messages.iter().rev().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first.
            let mut first = true;
            for m in self.messages.iter().rev() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            // `{}`: outermost message only.
            write!(f, "{}", self.messages.last().expect("error has a message"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: outermost message, then the cause chain.
        write!(f, "{}", self.messages.last().expect("error has a message"))?;
        if self.messages.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in self.messages.iter().rev().skip(1) {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

// The blanket conversion that makes `?` work on std errors inside functions
// returning `anyhow::Result`. Coherent only because `Error` itself does not
// implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve the source chain as context layers (innermost first).
        let mut messages = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            messages.insert(0, s.to_string());
            src = s.source();
        }
        Error { messages }
    }
}

/// Build an [`Error`] from a format string (inline captures supported) or a
/// single displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return `Err(anyhow!(...))` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn display_shows_outermost_only() {
        let e = Error::msg("root").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
    }

    #[test]
    fn macros_roundtrip() {
        let x = 3;
        let e = anyhow!("value {x} bad");
        assert_eq!(format!("{e}"), "value 3 bad");
        let e = anyhow!("value {} bad", 4);
        assert_eq!(format!("{e}"), "value 4 bad");
        assert!(fails(false).is_err());
        assert_eq!(fails(true).unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert!(parse("nope").is_err());
        assert_eq!(parse("12").unwrap(), 12);
    }

    #[test]
    fn debug_shows_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["top", "mid", "root"]);
        assert_eq!(e.root_cause(), "root");
    }
}
