//! Offline stub of the `xla` (xla-rs) PJRT client surface used by
//! `bluefog::runtime`.
//!
//! The real crate links `xla_extension` (a native XLA build) and cannot be
//! fetched or compiled in this offline container. This stub keeps the
//! runtime module compiling with the exact same call-site API; at runtime
//! [`PjRtClient::cpu`] reports that the backend is unavailable, which the
//! bluefog device service handles gracefully (every load/execute request is
//! answered with an error instead of a panic, and the runtime integration
//! tests skip when AOT artifacts have not been built).
//!
//! Swapping the real backend in is a one-line change in
//! `rust/Cargo.toml` — nothing in `bluefog::runtime` needs to change.

use std::fmt;

/// Error type mirroring `xla::Error`; converts into `anyhow::Error` via the
/// std-error blanket impl.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>() -> Result<T> {
    Err(XlaError(
        "XLA/PJRT backend unavailable: bluefog was built against the offline xla stub \
         (vendor/xla); install xla_extension and point Cargo at the real xla crate to \
         execute AOT artifacts"
            .to_string(),
    ))
}

/// Element types crossing the literal boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[allow(missing_docs)]
pub enum PrimitiveType {
    F32,
    F64,
    S32,
    S64,
}

/// Native Rust scalar types a [`Literal`] can be built from.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side tensor literal (stub: carries no data).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    /// Rank-0 literal from a scalar.
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    /// Convert to another element type.
    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        unavailable()
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// A computation ready for compilation (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer returned by an execution (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU PJRT client. Always fails in the stub; the caller's
    /// device-service thread degrades to answering every request with this
    /// error.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable_not_panics() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("unavailable"));
    }
}
