//! Table I — communication cost of the four averaging primitives.
//!
//! Prints (a) the paper's closed-form costs on the paper's network
//! parameters, and (b) the *simulated* virtual-clock cost measured by
//! actually running each collective over the in-process transport, to
//! validate that the simulator reproduces the analytic structure.
//!
//! Paper rows: Parameter Server `nM/B + nL`; Ring-Allreduce `2M/B + 2nL`;
//! BytePS `M/B + nL`; BlueFog partial averaging `M/B + L`.
//!
//! Run: `cargo bench --bench table1_comm_cost`

use bluefog::collective::neighbor::NeighborWeights;
use bluefog::collective::{AllreduceAlgo, ReduceOp};
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::simnet::{analytic, NetworkModel};
use bluefog::topology::dynamic::{DynamicTopology, OnePeerExpo};

/// Measure the worst-rank virtual time of one collective on a flat network.
fn simulate(algo: &str, n: usize, numel: usize, net: NetworkModel) -> f64 {
    let algo = algo.to_string();
    let cfg = SpmdConfig::new(n).with_net(net).with_topo_check(false);
    let results = run_spmd(cfg, move |ctx| {
        let data = vec![1.0f32; numel];
        let v0 = ctx.vtime();
        match algo.as_str() {
            "ps" => {
                ctx.allreduce(&data, ReduceOp::Average, AllreduceAlgo::ParameterServer)?;
            }
            "ring" => {
                ctx.allreduce(&data, ReduceOp::Average, AllreduceAlgo::Ring)?;
            }
            "byteps" => {
                ctx.allreduce(&data, ReduceOp::Average, AllreduceAlgo::BytePs)?;
            }
            "neighbor" => {
                let topo = OnePeerExpo::new(ctx.size());
                let view = topo.view(0, ctx.rank());
                let w = NeighborWeights::from_view(&view);
                ctx.neighbor_allreduce_dynamic(&data, &w)?;
            }
            _ => unreachable!(),
        }
        Ok(ctx.vtime() - v0)
    })
    .expect("simulation failed");
    results.iter().cloned().fold(0.0, f64::max)
}

fn main() {
    // The paper's Table I regime: 25 Gbps NIC, 50 us latency.
    let b = 25e9 / 8.0;
    let l = 50e-6;
    let m = 100e6; // 100 MB gradient (ResNet-50-scale message)

    println!("## Table I — analytic communication cost (M = 100 MB, B = 25 Gbps, L = 50 us)");
    println!(
        "{:<26} {:>11} {:>11} {:>11} {:>11}   cost model",
        "primitive", "n=4", "n=16", "n=64", "n=128"
    );
    let rows: Vec<(&str, Box<dyn Fn(usize) -> f64>, &str)> = vec![
        ("Parameter Server", Box::new(move |n| analytic::parameter_server(n, m, b, l)), "nM/B + nL"),
        ("Ring-Allreduce", Box::new(move |n| analytic::ring_allreduce(n, m, b, l)), "2M/B + 2nL"),
        ("BytePS", Box::new(move |n| analytic::byteps(n, m, b, l)), "M/B + nL"),
        ("BlueFog partial avg", Box::new(move |_| analytic::partial_averaging(1, m, b, l)), "M/B + L"),
    ];
    for (name, f, model) in &rows {
        print!("{name:<26}");
        for n in [4usize, 16, 64, 128] {
            print!(" {:>9.1}ms", f(n) * 1e3);
        }
        println!("   {model}");
    }

    // Structural checks that mirror the paper's ordering claims.
    for n in [16usize, 64, 128] {
        assert!(analytic::parameter_server(n, m, b, l) > analytic::ring_allreduce(n, m, b, l));
        assert!(analytic::ring_allreduce(n, m, b, l) > analytic::byteps(n, m, b, l));
        assert!(analytic::byteps(n, m, b, l) > analytic::partial_averaging(1, m, b, l));
    }

    // Simulated validation at transportable sizes (the simulator moves the
    // real bytes in process, so use 1 MB messages and n <= 16).
    let numel = 262_144; // 1 MB of f32
    let m_sim = numel as f64 * 4.0;
    println!();
    println!("## simulated virtual-clock cost (M = 1 MB; in-process transport)");
    println!(
        "{:<12} {:>5} {:>13} {:>13} {:>8}",
        "primitive", "n", "simulated", "analytic", "ratio"
    );
    let cases: Vec<(&str, Box<dyn Fn(usize) -> f64>)> = vec![
        ("ps", Box::new(move |n| analytic::parameter_server(n, m_sim, b, l))),
        ("ring", Box::new(move |n| analytic::ring_allreduce(n, m_sim, b, l))),
        ("byteps", Box::new(move |n| analytic::byteps(n, m_sim, b, l))),
        ("neighbor", Box::new(move |_| analytic::partial_averaging(1, m_sim, b, l))),
    ];
    for (algo, f) in &cases {
        for n in [4usize, 8, 16] {
            let sim = simulate(algo, n, numel, NetworkModel::flat(b, l));
            let ana = f(n);
            println!(
                "{:<12} {:>5} {:>11.3}ms {:>11.3}ms {:>8.2}",
                algo,
                n,
                sim * 1e3,
                ana * 1e3,
                sim / ana
            );
            // The simulator must reproduce the analytic structure within a
            // factor ~2 (it adds port contention the closed form ignores).
            assert!(
                sim / ana < 2.5 && sim / ana > 0.4,
                "{algo} n={n}: simulated {sim} vs analytic {ana}"
            );
        }
    }
    println!("\ntable1_comm_cost OK");
}
