//! Table II — fixed-epoch training time, validation accuracy and speedup:
//! Horovod vs BlueFog(H-ATC / ATC / H-AWC / AWC).
//!
//! Two panels:
//! 1. **Paper-scale panel** (schedule model): 90 epochs of ResNet-50 on
//!    ImageNet (1.28 M images) at 8x8 GPUs — the exact Table II setting —
//!    timed with the deterministic step scheduler.
//! 2. **Executed panel**: real training of the `tiny` transformer for a
//!    fixed step budget on 8 simulated nodes, reporting simulated time,
//!    validation accuracy and speedup (shape check: speedups in the
//!    paper's 1.26x–1.43x band, accuracy within ~2 points of the
//!    baseline).
//!
//! Run: `cargo bench --bench table2_train_time`

use std::sync::Arc;

use bluefog::config::{AlgoConfig, ModelPreset, WorkloadModel};
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{make_optimizer_cfg, CommSpec};
use bluefog::runtime::DeviceService;
use bluefog::simnet::schedule::{step_time, CommScheme, TriggerStyle};
use bluefog::simnet::NetworkModel;
use bluefog::topology::builders;
use bluefog::topology::dynamic::OnePeerExpo;
use bluefog::training::{eval_node, train_node, TrainRun};

// Same calibration as fig12_throughput (DESIGN.md): effective V100 fp32
// throughput for ResNet-50 (~360 img/s) and ~40% TCP goodput on 25 Gbps.
const RESNET_FLOPS: f64 = 4.1e12;

fn testbed() -> NetworkModel {
    let mut net = NetworkModel::aws_p3(8);
    net.inter_bw *= 0.4;
    net
}

fn paper_scale_panel() {
    println!("## Table II (paper scale, schedule model): ResNet-50, 90 epochs, 64 GPUs");
    let w = WorkloadModel::resnet50();
    let net = testbed();
    let n = 64;
    let steps_per_epoch = 1_281_167.0 / (n as f64 * w.batch as f64);
    let total_steps = 90.0 * steps_per_epoch;
    let rows: [(&str, CommScheme, TriggerStyle); 5] = [
        ("Horovod", CommScheme::RingAllreduce, TriggerStyle::Atc),
        ("BlueFog(H-ATC)", CommScheme::HierarchicalOnePeer, TriggerStyle::Atc),
        ("BlueFog(ATC)", CommScheme::NeighborOnePeer, TriggerStyle::Atc),
        ("BlueFog(H-AWC)", CommScheme::HierarchicalOnePeer, TriggerStyle::Awc),
        ("BlueFog(AWC)", CommScheme::NeighborOnePeer, TriggerStyle::Awc),
    ];
    let mut base = 0.0;
    println!("{:<18} {:>12} {:>10}   (paper: 14648s / 1.30x / 1.40x / 1.26x / 1.43x)", "algorithm", "time", "speedup");
    for (i, (name, scheme, trigger)) in rows.iter().enumerate() {
        let (t_step, _) = step_time(&w, n, &net, *scheme, *trigger, RESNET_FLOPS, 1.0);
        let total = t_step * total_steps;
        if i == 0 {
            base = total;
        }
        println!("{:<18} {:>10.0}s {:>9.2}x", name, total, base / total);
        if i > 0 {
            let s = base / total;
            assert!(
                (1.1..1.9).contains(&s),
                "{name}: speedup {s} outside the paper's band"
            );
        }
    }
    println!();
}

fn executed_panel() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/train_step_tiny.hlo.txt").exists() {
        println!("## executed panel SKIPPED (run `make artifacts` first)");
        return Ok(());
    }
    const NODES: usize = 8;
    const STEPS: usize = 150;
    println!("## Table II (executed): tiny transformer, {STEPS} steps, {NODES} nodes (4/machine)");
    let device = DeviceService::new();
    let rows: [(&str, bool, &str); 5] = [
        ("Horovod", false, "atc"), // order unused; the registry builds psgd
        ("BlueFog(H-ATC)", true, "atc"),
        ("BlueFog(ATC)", false, "atc"),
        ("BlueFog(H-AWC)", true, "awc"),
        ("BlueFog(AWC)", false, "awc"),
    ];
    let mut base_time = 0.0;
    let mut base_acc = 0.0;
    println!("{:<18} {:>12} {:>12} {:>10}", "algorithm", "sim time", "val acc", "speedup");
    for (i, (name, hierarchical, order)) in rows.iter().enumerate() {
        let preset = ModelPreset::by_name("tiny").unwrap();
        let (graph, weights) = builders::by_name("expo2", NODES)?;
        let cfg = SpmdConfig::new(NODES)
            .with_net(NetworkModel::aws_p3(4))
            .with_topology(graph, weights)
            .with_device(device.handle());
        let run = TrainRun::new(preset, STEPS);
        let is_baseline = i == 0;
        let hier = *hierarchical;
        // Both rows go through the registry: the baseline is `psgd`, the
        // decentralized rows are vanilla DmSGD with the ATC/AWC order flag.
        let acfg = AlgoConfig {
            algo: if is_baseline { "psgd" } else { "dmsgd-vanilla" }.to_string(),
            gamma: 0.08,
            beta: 0.9,
            order: order.to_string(),
            ..AlgoConfig::default()
        };
        let results = run_spmd(cfg, move |ctx| {
            let comm = if hier {
                CommSpec::Hierarchical
            } else {
                CommSpec::Dynamic(Arc::new(OnePeerExpo::new(ctx.size())))
            };
            let mut opt = make_optimizer_cfg(&acfg, comm)?;
            let (_, params) = train_node(ctx, &run, &mut opt)?;
            let (_, acc) = eval_node(ctx, &run, &params, 3)?;
            Ok((acc, ctx.vtime()))
        })?;
        let (acc, vtime) = results[0];
        if i == 0 {
            base_time = vtime;
            base_acc = acc;
        }
        println!(
            "{:<18} {:>11.4}s {:>11.1}% {:>9.2}x",
            name,
            vtime,
            acc * 100.0,
            base_time / vtime
        );
        if i > 0 {
            // Hierarchical variants pay their always-on inter-machine leg
            // at this small 2-machine scale and land near parity; flat
            // variants must show a clear speedup (see fig13_curves).
            let min_speedup = if name.contains("H-") { 0.90 } else { 1.05 };
            assert!(
                base_time / vtime > min_speedup,
                "{name}: expected speedup over the ring baseline, got {}",
                base_time / vtime
            );
            assert!(
                acc > base_acc - 0.06,
                "{name}: accuracy dropped too far ({acc} vs {base_acc})"
            );
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    paper_scale_panel();
    executed_panel()?;
    println!("\ntable2_train_time OK");
    Ok(())
}
