//! Fig. 13 — training loss & accuracy vs wall-clock time, and validation
//! accuracy vs epochs, for Horovod vs BlueFog(ATC/AWC/H-ATC/H-AWC).
//!
//! Real training of the `tiny` transformer LM on 8 simulated nodes
//! (substituting ImageNet/ResNet-50 per DESIGN.md); the time axis is the
//! virtual clock of the two-tier p3-like network, so curve *ordering*
//! mirrors the paper: decentralized variants reach the same loss in less
//! simulated time with similar final accuracy.
//!
//! Run: `make artifacts && cargo bench --bench fig13_curves`
//! (skips gracefully when artifacts are missing).

use std::sync::Arc;

use bluefog::config::{AlgoConfig, ModelPreset};
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{make_optimizer_cfg, CommSpec, DecentralizedOptimizer};
use bluefog::runtime::DeviceService;
use bluefog::simnet::NetworkModel;
use bluefog::topology::builders;
use bluefog::topology::dynamic::OnePeerExpo;
use bluefog::training::{eval_node, TrainRun};

const NODES: usize = 8;
const STEPS: usize = 120;
const EVAL_EVERY: usize = 40; // one "epoch" for the accuracy-vs-epoch panel

struct Curve {
    label: &'static str,
    points: Vec<(usize, f32, f64)>, // step, loss, vtime
    epochs: Vec<(usize, f32)>,      // epoch, val accuracy
    total_vtime: f64,
}

/// Build the curve's optimizer through the name->algorithm registry:
/// `Horovod` is the ring baseline, the rest are vanilla DmSGD with the
/// ATC/AWC order flag over a dynamic (flat) or hierarchical topology.
fn make_opt(label: &str, n: usize) -> anyhow::Result<Box<dyn DecentralizedOptimizer>> {
    let (algo, order) = match label {
        "Horovod" => ("psgd", "atc"),
        "ATC" | "H-ATC" => ("dmsgd-vanilla", "atc"),
        "AWC" | "H-AWC" => ("dmsgd-vanilla", "awc"),
        other => anyhow::bail!("unknown curve label '{other}'"),
    };
    let comm = if label.starts_with("H-") {
        CommSpec::Hierarchical
    } else {
        CommSpec::Dynamic(Arc::new(OnePeerExpo::new(n)))
    };
    let acfg = AlgoConfig {
        algo: algo.to_string(),
        gamma: 0.08,
        beta: 0.9,
        order: order.to_string(),
        ..AlgoConfig::default()
    };
    make_optimizer_cfg(&acfg, comm)
}

fn run_curve(label: &'static str, device: &DeviceService) -> anyhow::Result<Curve> {
    let preset = ModelPreset::by_name("tiny").unwrap();
    let (graph, weights) = builders::by_name("expo2", NODES)?;
    let cfg = SpmdConfig::new(NODES)
        .with_net(NetworkModel::aws_p3(4))
        .with_topology(graph, weights)
        .with_device(device.handle());
    let mut run = TrainRun::new(preset, EVAL_EVERY);
    run.log_every = 10;
    let results = run_spmd(cfg, move |ctx| {
        let mut opt = make_opt(label, ctx.size())?;
        // Train in epoch chunks so we can eval between them. Parameters
        // persist because train_node inits deterministically; instead we
        // run one long session by chaining: train EVAL_EVERY steps, eval,
        // repeat — carrying params forward manually.
        let mut all_logs = vec![];
        let mut epochs = vec![];
        let mut carried: Option<Vec<f32>> = None;
        for epoch in 0..(STEPS / EVAL_EVERY) {
            let mut r = run.clone();
            r.log_every = 10;
            // Continue from carried params by re-seeding init: train_node
            // always inits fresh, so we instead call the lower-level pieces.
            let (logs, params) = bluefog::training::driver::train_node_resumable(
                ctx,
                &r,
                opt.as_mut(),
                carried.take(),
                epoch * EVAL_EVERY,
            )?;
            let (_, acc) = eval_node(ctx, &r, &params, 2)?;
            epochs.push((epoch + 1, acc));
            all_logs.extend(logs);
            carried = Some(params);
        }
        Ok((all_logs, epochs, ctx.vtime()))
    })?;
    let (logs, epochs, vtime) = &results[0];
    Ok(Curve {
        label,
        points: logs.iter().map(|l| (l.step, l.loss, l.vtime)).collect(),
        epochs: epochs.clone(),
        total_vtime: *vtime,
    })
}

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/train_step_tiny.hlo.txt").exists() {
        println!("fig13_curves SKIPPED (run `make artifacts` first)");
        return Ok(());
    }
    let device = DeviceService::new();
    let labels: [&'static str; 5] = ["Horovod", "ATC", "AWC", "H-ATC", "H-AWC"];
    let mut curves = vec![];
    for l in labels {
        curves.push(run_curve(l, &device)?);
    }

    println!("## Fig. 13 (left/middle): loss vs simulated wall-clock");
    println!("{:<8} {:>6} {:>9} {:>12}", "algo", "step", "loss", "vtime(s)");
    for c in &curves {
        for (s, l, v) in &c.points {
            println!("{:<8} {:>6} {:>9.4} {:>12.5}", c.label, s, l, v);
        }
    }
    println!("\n## Fig. 13 (right): validation accuracy vs epochs");
    print!("{:<8}", "epoch");
    for c in &curves {
        print!(" {:>9}", c.label);
    }
    println!();
    for e in 0..(STEPS / EVAL_EVERY) {
        print!("{:<8}", e + 1);
        for c in &curves {
            print!(" {:>8.1}%", c.epochs[e].1 * 100.0);
        }
        println!();
    }

    println!("\n## total simulated time and speedup vs Horovod");
    let base = curves[0].total_vtime;
    for c in &curves {
        println!("  {:<8} {:>10.4}s {:>6.2}x", c.label, c.total_vtime, base / c.total_vtime);
    }

    // Shape checks: similar convergence, faster wall-clock (paper: "gains
    // 1.3x-1.43x speed-up with similar convergence").
    let hvd_acc = curves[0].epochs.last().unwrap().1;
    for c in &curves[1..] {
        let acc = c.epochs.last().unwrap().1;
        assert!(
            acc > hvd_acc - 0.06,
            "{}: accuracy degraded too much ({acc} vs {hvd_acc})",
            c.label
        );
        // Flat variants must win outright; hierarchical at this small
        // 2-machine scale pays its always-on inter-machine leg and lands
        // near parity (the paper's Table II also ranks H-ATC/H-AWC below
        // flat ATC/AWC: 1.26-1.30x vs 1.40-1.43x).
        let slack = if c.label.starts_with("H-") { 1.10 } else { 1.00 };
        assert!(
            c.total_vtime < base * slack,
            "{}: not competitive with Horovod ({} vs {base})",
            c.label,
            c.total_vtime
        );
    }
    println!("\nfig13_curves OK");
    Ok(())
}
