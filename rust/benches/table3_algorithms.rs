//! Table III — validation accuracy and wall-clock time across models x
//! algorithms x static/dynamic exponential topology.
//!
//! Paper: {ResNet-50, MobileNet-v2, EfficientNet} x {parallel SGD, vanilla
//! DmSGD, DmSGD, QG-DmSGD} x {static, dynamic} on ImageNet (8x8 GPUs).
//! Substitution (DESIGN.md): two transformer-LM presets (`nano`, `tiny`)
//! trained for a fixed step budget on 8 simulated nodes; same algorithm
//! grid, accuracy from a held-out split, time from the virtual clock.
//!
//! Shape targets: all decentralized variants within ~2 accuracy points of
//! parallel SGD; dynamic topology reduces time vs static "without any
//! noticeable performance degrade".
//!
//! Run: `make artifacts && cargo bench --bench table3_algorithms`

use std::sync::Arc;

use bluefog::config::{AlgoConfig, ModelPreset};
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{make_optimizer_cfg, CommSpec};
use bluefog::runtime::DeviceService;
use bluefog::simnet::NetworkModel;
use bluefog::topology::builders;
use bluefog::topology::dynamic::OnePeerExpo;
use bluefog::training::{eval_node, train_node, TrainRun};

const NODES: usize = 8;

fn run_cell(
    device: &DeviceService,
    preset_name: &'static str,
    algo: &'static str,
    dynamic: bool,
    steps: usize,
) -> anyhow::Result<(f32, f64)> {
    let preset = ModelPreset::by_name(preset_name).unwrap();
    let (graph, weights) = builders::by_name("expo2", NODES)?;
    let cfg = SpmdConfig::new(NODES)
        .with_net(NetworkModel::aws_p3(4))
        .with_topology(graph, weights)
        .with_device(device.handle());
    let run = TrainRun::new(preset, steps);
    // The whole grid goes through the name->algorithm registry — the bench
    // exercises exactly the surface `bfrun --algo` exposes.
    let acfg = AlgoConfig {
        algo: algo.to_string(),
        gamma: 0.08,
        beta: 0.9,
        ..AlgoConfig::default()
    };
    let results = run_spmd(cfg, move |ctx| {
        let comm = if dynamic {
            CommSpec::Dynamic(Arc::new(OnePeerExpo::new(ctx.size())))
        } else {
            CommSpec::Static
        };
        let mut opt = make_optimizer_cfg(&acfg, comm)?;
        let (_, params) = train_node(ctx, &run, &mut opt)?;
        let (_, acc) = eval_node(ctx, &run, &params, 3)?;
        Ok((acc, ctx.vtime()))
    })?;
    Ok(results[0])
}

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/train_step_tiny.hlo.txt").exists() {
        println!("table3_algorithms SKIPPED (run `make artifacts` first)");
        return Ok(());
    }
    let device = DeviceService::new();
    let models: [(&'static str, usize); 2] = [("nano", 150), ("tiny", 120)];
    let algos: [&'static str; 4] = ["psgd", "dmsgd-vanilla", "dmsgd", "qg-dmsgd"];

    println!("## Table III — top-1 val accuracy (and simulated time in ms) on 8 nodes");
    println!(
        "{:<16} {:>24} {:>24}",
        "", "STATIC acc (time)", "DYNAMIC acc (time)"
    );
    for (model, steps) in models {
        println!("# model = {model} ({steps} steps)");
        let mut psgd_acc = 0.0f32;
        for algo in algos {
            let (acc_s, t_s) = run_cell(&device, model, algo, false, steps)?;
            let (acc_d, t_d) = if algo == "psgd" {
                (f32::NAN, f64::NAN) // the paper leaves PSGD's dynamic cell empty
            } else {
                run_cell(&device, model, algo, true, steps)?
            };
            if algo == "psgd" {
                psgd_acc = acc_s;
                println!(
                    "{:<16} {:>15.1}% ({:>5.1}ms) {:>24}",
                    algo,
                    acc_s * 100.0,
                    t_s * 1e3,
                    "-"
                );
            } else {
                println!(
                    "{:<16} {:>15.1}% ({:>5.1}ms) {:>15.1}% ({:>5.1}ms)",
                    algo,
                    acc_s * 100.0,
                    t_s * 1e3,
                    acc_d * 100.0,
                    t_d * 1e3
                );
                // Accuracy parity with parallel SGD (paper: all within ~1 pt;
                // we allow 5 pts at this small scale/noise).
                assert!(
                    acc_s > psgd_acc - 0.05 && acc_d > psgd_acc - 0.05,
                    "{model}/{algo}: accuracy fell off psgd ({acc_s}/{acc_d} vs {psgd_acc})"
                );
                // Dynamic must be cheaper in time without accuracy loss
                // (the paper's main point for dynamic topologies).
                assert!(
                    t_d < t_s,
                    "{model}/{algo}: dynamic should cut communication time ({t_d} vs {t_s})"
                );
                assert!(
                    acc_d > acc_s - 0.05,
                    "{model}/{algo}: dynamic degraded accuracy ({acc_d} vs {acc_s})"
                );
            }
        }
    }
    println!("\ntable3_algorithms OK");
    Ok(())
}
