//! Fig. 11 — execution time of allreduce vs neighbor-allreduce vs dynamic
//! neighbor-allreduce as the number of nodes grows, on the CPU testbed
//! (1 MB messages, m4.4xlarge-like flat network) and the GPU testbed
//! (10 MB messages, p3.16xlarge-like two-tier network, 8 ranks/machine).
//!
//! As in the paper: static neighbor allreduce runs on the **ring** topology
//! and the dynamic variant on the **inner-outer exponential-2** graph, so
//! the per-iteration transfer volume matches. 10 repetitions; mean and 90%
//! confidence interval of the virtual-clock time (the wall-clock of the
//! in-process copy loop is also reported for reference).
//!
//! Run: `cargo bench --bench fig11_micro`

use bluefog::collective::neighbor::NeighborWeights;
use bluefog::collective::{AllreduceAlgo, ReduceOp};
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::metrics::Stats;
use bluefog::simnet::NetworkModel;
use bluefog::topology::dynamic::{DynamicTopology, InnerOuterExpo};
use bluefog::topology::{builders, WeightMatrix};

const REPS: usize = 10;

/// Returns per-rep (virtual seconds, wall seconds) for the chosen method.
fn measure(method: &str, n: usize, numel: usize, net: NetworkModel) -> Vec<(f64, f64)> {
    let method = method.to_string();
    let group = net.ranks_per_machine.max(1);
    let mut cfg = SpmdConfig::new(n).with_net(net).with_topo_check(false);
    if method == "neighbor" {
        let g = builders::ring(n);
        let w = WeightMatrix::metropolis_hastings(&g);
        cfg = cfg.with_topology(g, w);
    }
    let per_rank = run_spmd(cfg, move |ctx| {
        let data = vec![1.0f32; numel];
        let mut out = Vec::with_capacity(REPS);
        // one warmup + REPS measured; barrier between reps so per-rank
        // clock drift does not pipeline into the next measurement
        for rep in 0..=REPS {
            ctx.barrier()?;
            let v0 = ctx.vtime();
            let t0 = std::time::Instant::now();
            match method.as_str() {
                "allreduce" => {
                    ctx.allreduce(&data, ReduceOp::Average, AllreduceAlgo::Ring)?;
                }
                "neighbor" => {
                    ctx.neighbor_allreduce(&data)?;
                }
                "dynamic" => {
                    let topo = InnerOuterExpo::new(ctx.size(), group.min(ctx.size()));
                    let view = topo.view(rep, ctx.rank());
                    let w = NeighborWeights::from_view(&view);
                    ctx.neighbor_allreduce_dynamic(&data, &w)?;
                }
                _ => unreachable!(),
            }
            if rep > 0 {
                out.push((ctx.vtime() - v0, t0.elapsed().as_secs_f64()));
            }
        }
        Ok(out)
    })
    .expect("run failed");
    // Worst rank per rep (the collective finishes when the slowest does).
    (0..REPS)
        .map(|r| {
            let v = per_rank.iter().map(|reps| reps[r].0).fold(0.0, f64::max);
            let w = per_rank.iter().map(|reps| reps[r].1).fold(0.0, f64::max);
            (v, w)
        })
        .collect()
}

fn run_tier(label: &str, numel: usize, sizes: &[usize], net_for: impl Fn(usize) -> NetworkModel) {
    println!("## {label} ({} MB messages, {REPS} reps, mean ± 90% CI of virtual time)", numel * 4 / (1 << 20));
    println!(
        "{:<10} {:>22} {:>22} {:>22}",
        "n", "allreduce", "neighbor (ring)", "dyn neighbor (i/o-exp2)"
    );
    let mut last: Option<(f64, f64, f64)> = None;
    for &n in sizes {
        let mut row = vec![];
        for method in ["allreduce", "neighbor", "dynamic"] {
            let samples = measure(method, n, numel, net_for(n));
            let v: Vec<f64> = samples.iter().map(|s| s.0).collect();
            let s = Stats::from(&v);
            row.push((s.mean, s.ci90));
        }
        println!(
            "{:<10} {:>13.3}±{:.3}ms {:>13.3}±{:.3}ms {:>13.3}±{:.3}ms",
            n,
            row[0].0 * 1e3,
            row[0].1 * 1e3,
            row[1].0 * 1e3,
            row[1].1 * 1e3,
            row[2].0 * 1e3,
            row[2].1 * 1e3,
        );
        last = Some((row[0].0, row[1].0, row[2].0));
    }
    // Paper's findings at the largest size: neighbor methods are faster
    // than allreduce (allreduce pays O(n) latency rounds; partial averaging
    // pays O(1)), and scale flatter.
    let (ar, nb, dyn_nb) = last.unwrap();
    assert!(
        nb < ar * 1.02,
        "{label}: static neighbor ({nb}) should beat allreduce ({ar}) at the largest n"
    );
    assert!(dyn_nb < ar, "{label}: dynamic neighbor ({dyn_nb}) should beat allreduce ({ar})");
    println!();
}

fn main() {
    // CPU tier: 1 MB messages, flat 10 Gbps network (m4.4xlarge-like).
    run_tier("CPU (m4.4xlarge-like)", 262_144, &[2, 4, 8, 16, 32, 64], |_n| NetworkModel::aws_m4());
    // GPU tier: 10 MB messages, two-tier NVLink + 25 Gbps (p3.16xlarge).
    run_tier("GPU (p3.16xlarge-like)", 2_621_440, &[2, 4, 8, 16, 32, 64], |_n| {
        NetworkModel::aws_p3(8)
    });

    // The paper's "significant drop from 8 to 16 GPUs": crossing the
    // machine boundary must visibly increase the per-op time.
    let near = measure("allreduce", 8, 2_621_440, NetworkModel::aws_p3(8));
    let far = measure("allreduce", 16, 2_621_440, NetworkModel::aws_p3(8));
    let t8: f64 = near.iter().map(|s| s.0).sum::<f64>() / near.len() as f64;
    let t16: f64 = far.iter().map(|s| s.0).sum::<f64>() / far.len() as f64;
    println!("machine-boundary effect (allreduce, 10 MB): 8 GPUs {:.3}ms -> 16 GPUs {:.3}ms", t8 * 1e3, t16 * 1e3);
    assert!(t16 > 3.0 * t8, "crossing machines must dominate: {t8} -> {t16}");
    println!("\nfig11_micro OK");
}
