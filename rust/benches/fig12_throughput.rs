//! Fig. 12 — training throughput of Horovod (ring allreduce) vs BlueFog's
//! ATC / AWC / H-ATC / H-AWC over the dynamic exponential-2 topology, on
//! ResNet-50, VGG-16 and BERT-large, from 4 to 128 GPUs.
//!
//! Uses the deterministic step-schedule model
//! ([`bluefog::simnet::schedule`]): layer-wise gradient buckets, per-style
//! communication triggers (Fig. 8), Table I per-bucket costs, two-tier
//! p3.16xlarge network (8 GPUs/machine, NVLink intra, 25 Gbps inter, no
//! RDMA). See DESIGN.md for why the schedule model substitutes for the
//! physical cluster. Shape targets from the paper: BlueFog ≥ Horovod
//! everywhere, 1.2–1.8x at 128 GPUs, ResNet-50 ≈ 95% scaling efficiency vs
//! 50–60% for VGG/BERT, and a sharp efficiency drop from 8 to 16 GPUs.
//!
//! Run: `cargo bench --bench fig12_throughput`

use bluefog::config::WorkloadModel;
use bluefog::simnet::schedule::{throughput, CommScheme, TriggerStyle};
use bluefog::simnet::NetworkModel;

/// Calibration (DESIGN.md): per-workload *effective* device FLOPs chosen so
/// single-GPU step times match published V100 fp32 throughput
/// (ResNet-50 ~360 img/s, VGG-16 ~110 img/s, BERT-large ~9.4 samples/s),
/// and TCP goodput at ~40% of the 25 Gbps line rate (no RDMA, paper §VII).
fn effective_flops(name: &str) -> f64 {
    match name {
        "ResNet-50" => 4.1e12,
        "VGG-16" => 5.1e12,
        "BERT-large" => 10.0e12,
        _ => 5e12,
    }
}

fn testbed() -> NetworkModel {
    let mut net = NetworkModel::aws_p3(8);
    net.inter_bw *= 0.4;
    net
}

fn main() {
    let sizes = [4usize, 8, 16, 32, 64, 128];
    let algos: [(&str, CommScheme, TriggerStyle); 5] = [
        ("Horovod", CommScheme::RingAllreduce, TriggerStyle::Atc),
        ("ATC", CommScheme::NeighborOnePeer, TriggerStyle::Atc),
        ("AWC", CommScheme::NeighborOnePeer, TriggerStyle::Awc),
        ("H-ATC", CommScheme::HierarchicalOnePeer, TriggerStyle::Atc),
        ("H-AWC", CommScheme::HierarchicalOnePeer, TriggerStyle::Awc),
    ];

    for w in WorkloadModel::all() {
        let net = testbed();
        let dev = effective_flops(w.name);
        println!(
            "## {} ({} M params, batch {}/GPU) — throughput (samples/s)",
            w.name,
            w.params / 1_000_000,
            w.batch
        );
        print!("{:<10}", "n");
        for (name, _, _) in &algos {
            print!(" {name:>12}");
        }
        println!(" {:>10} {:>10}", "best/hvd", "hvd eff");
        let mut speedup_at_128 = 0.0;
        for &n in &sizes {
            print!("{n:<10}");
            let mut hvd = 0.0;
            let mut best = 0.0f64;
            for (i, (_, scheme, trigger)) in algos.iter().enumerate() {
                // The paper reuses the flat result for hierarchical at <= 8
                // GPUs (single machine).
                let scheme = if n <= 8 && *scheme == CommScheme::HierarchicalOnePeer {
                    CommScheme::NeighborOnePeer
                } else {
                    *scheme
                };
                let t = throughput(&w, n, &net, scheme, *trigger, dev, 1.0);
                if i == 0 {
                    hvd = t;
                }
                best = best.max(t);
                print!(" {t:>12.0}");
            }
            let t1 = w.batch as f64 / w.step_compute_time(dev, 1.0);
            let hvd_eff = hvd / (n as f64 * t1);
            println!(" {:>9.2}x {:>9.1}%", best / hvd, hvd_eff * 100.0);
            if n == 128 {
                speedup_at_128 = best / hvd;
            }

            // Shape assertion: every BlueFog variant at least matches
            // Horovod (the paper: "it is always faster than allreduce").
            for (name, scheme, trigger) in &algos[1..] {
                let scheme = if n <= 8 && *scheme == CommScheme::HierarchicalOnePeer {
                    CommScheme::NeighborOnePeer
                } else {
                    *scheme
                };
                let t = throughput(&w, n, &net, scheme, *trigger, dev, 1.0);
                assert!(
                    t >= hvd * 0.999,
                    "{}: {name} ({t}) slower than Horovod ({hvd}) at n={n}",
                    w.name
                );
            }
        }
        // Paper headline: 1.2x–1.8x at 128 GPUs. Our analytic ring cannot
        // benefit from production NCCL's multi-channel tricks, so the most
        // communication-bound models land slightly above the paper's 1.8
        // (see EXPERIMENTS.md §E3); we accept up to 2.5x.
        assert!(
            (1.1..2.5).contains(&speedup_at_128),
            "{}: speedup at 128 GPUs out of band: {speedup_at_128}",
            w.name
        );
        println!();
    }

    // Scaling-efficiency summary (the paper's 95% vs 50-60% observation).
    println!("## scaling efficiency of the best BlueFog variant at 128 GPUs");
    for w in WorkloadModel::all() {
        let net = testbed();
        let dev = effective_flops(w.name);
        let t1 = w.batch as f64 / w.step_compute_time(dev, 1.0);
        let best = algos[1..]
            .iter()
            .map(|(_, s, tr)| throughput(&w, 128, &net, *s, *tr, dev, 1.0))
            .fold(0.0f64, f64::max);
        let eff = best / (128.0 * t1);
        println!("  {:<12} {:>5.1}%", w.name, eff * 100.0);
    }
    println!("\nfig12_throughput OK");
}
