//! Ablation A3 — negotiation-service topology check overhead (paper §VI-C).
//!
//! The paper claims the check "only adds a small overhead compared to the
//! actual communication since it is just a scalar", and notes users can
//! turn it off. We measure dynamic `neighbor_allreduce` with the check on
//! and off across message sizes: the *absolute* overhead should stay
//! roughly constant (a scalar round) while the *relative* overhead shrinks
//! as the tensor grows.
//!
//! Run: `cargo bench --bench ablation_topocheck`

use bluefog::collective::neighbor::NeighborWeights;
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::simnet::NetworkModel;
use bluefog::topology::dynamic::{DynamicTopology, OnePeerExpo};

const ITERS: usize = 20;

fn measure(numel: usize, check: bool) -> (f64, f64) {
    let cfg = SpmdConfig::new(8)
        .with_net(NetworkModel::flat(25e9 / 8.0, 50e-6))
        .with_topo_check(check);
    let per_rank = run_spmd(cfg, move |ctx| {
        let data = vec![1.0f32; numel];
        let topo = OnePeerExpo::new(ctx.size());
        let t0 = std::time::Instant::now();
        let mut vtotal = 0.0;
        for i in 0..ITERS {
            ctx.barrier()?; // keep rank clocks aligned between iterations
            let v0 = ctx.vtime();
            let view = topo.view(i, ctx.rank());
            let w = NeighborWeights::from_view(&view);
            ctx.neighbor_allreduce_dynamic(&data, &w)?;
            vtotal += ctx.vtime() - v0;
        }
        Ok((vtotal / ITERS as f64, t0.elapsed().as_secs_f64() / ITERS as f64))
    })
    .expect("run failed");
    let v = per_rank.iter().map(|r| r.0).fold(0.0, f64::max);
    let w = per_rank.iter().map(|r| r.1).fold(0.0, f64::max);
    (v, w)
}

fn main() {
    println!("## topology-check ablation: dynamic neighbor_allreduce, 8 nodes, {ITERS} iters");
    println!(
        "{:<12} {:>14} {:>14} {:>12} {:>10}",
        "size", "check ON", "check OFF", "overhead", "relative"
    );
    let mut overheads = vec![];
    for numel in [1024usize, 16_384, 262_144, 1_048_576] {
        let (on, _) = measure(numel, true);
        let (off, _) = measure(numel, false);
        let overhead = on - off;
        println!(
            "{:>8} KB {:>11.3} ms {:>11.3} ms {:>9.3} ms {:>9.1}%",
            numel * 4 / 1024,
            on * 1e3,
            off * 1e3,
            overhead * 1e3,
            overhead / off * 100.0
        );
        overheads.push((numel, off, overhead));
    }
    // The scalar negotiation round costs ~2 link latencies regardless of
    // tensor size; at the largest size it must be a small fraction.
    let (_, off_large, ovh_large) = overheads[overheads.len() - 1];
    assert!(
        ovh_large / off_large < 0.25,
        "check overhead should be small vs large-tensor comm: {ovh_large} vs {off_large}"
    );
    // Absolute overhead should not grow with the tensor (it's a scalar).
    let ovh_small = overheads[0].2;
    assert!(
        ovh_large < ovh_small * 4.0 + 2e-4,
        "overhead should not scale with tensor size: {ovh_small} -> {ovh_large}"
    );
    println!("\nablation_topocheck OK");
}
