//! Ablation A2 — communication/computation overlap (paper §V-A/§V-C,
//! Fig. 8).
//!
//! The paper's toy three-layer network, reproduced on the virtual clock:
//! per-layer backward compute produces one gradient bucket each; we compare
//!
//! - **sequential**: blocking neighbor_allreduce after the full backward
//!   (no overlap);
//! - **ATC overlap**: each layer's communication is issued non-blocking as
//!   soon as its gradient is ready (the backward hook of Fig. 8);
//! - **AWC overlap**: all communication is issued at step start
//!   (communicates last iteration's parameters — the forward hook).
//!
//! Expected ordering: AWC <= ATC < sequential, with the gap equal to the
//! hidden communication time.
//!
//! Run: `cargo bench --bench ablation_overlap`

use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::simnet::NetworkModel;

const LAYERS: usize = 3;
const NUMEL: usize = 262_144; // 1 MB per layer bucket
const LAYER_COMPUTE: f64 = 1.0e-3; // 1 ms of backward compute per layer

fn measure(style: &'static str) -> f64 {
    let cfg = SpmdConfig::new(8)
        .with_net(NetworkModel::flat(25e9 / 8.0, 50e-6))
        .with_topo_check(false)
        .with_fusion_threshold(0); // isolate overlap from fusion
    let per_rank = run_spmd(cfg, move |ctx| {
        let data = vec![1.0f32; NUMEL];
        let v0 = ctx.vtime();
        match style {
            "sequential" => {
                // Full backward, then communicate layer by layer (blocking).
                for _ in 0..LAYERS {
                    ctx.simulate_compute(LAYER_COMPUTE);
                }
                for _ in 0..LAYERS {
                    ctx.neighbor_allreduce(&data)?;
                }
            }
            "atc" => {
                // Backward hook: issue each bucket as soon as computed.
                let mut handles = vec![];
                for _ in 0..LAYERS {
                    ctx.simulate_compute(LAYER_COMPUTE);
                    handles.push(ctx.neighbor_allreduce_nonblocking(&data, None)?);
                }
                for h in handles {
                    h.wait(ctx)?;
                }
            }
            "awc" => {
                // Forward hook: issue everything at step start.
                let mut handles = vec![];
                for _ in 0..LAYERS {
                    handles.push(ctx.neighbor_allreduce_nonblocking(&data, None)?);
                }
                for _ in 0..LAYERS {
                    ctx.simulate_compute(LAYER_COMPUTE);
                }
                for h in handles {
                    h.wait(ctx)?;
                }
            }
            _ => unreachable!(),
        }
        Ok(ctx.vtime() - v0)
    })
    .expect("run failed");
    per_rank.iter().cloned().fold(0.0, f64::max)
}

fn main() {
    println!(
        "## overlap ablation: {LAYERS}-layer toy net (Fig. 8), 1 MB/layer, {} ms compute/layer",
        LAYER_COMPUTE * 1e3
    );
    println!("{:<14} {:>14}", "style", "step time");
    let seq = measure("sequential");
    let atc = measure("atc");
    let awc = measure("awc");
    for (name, t) in [("sequential", seq), ("ATC overlap", atc), ("AWC overlap", awc)] {
        println!("{name:<14} {:>11.3} ms", t * 1e3);
    }
    println!(
        "\nhidden communication: ATC {:.3} ms, AWC {:.3} ms (of {:.3} ms total comm)",
        (seq - atc) * 1e3,
        (seq - awc) * 1e3,
        (seq - LAYERS as f64 * LAYER_COMPUTE) * 1e3
    );
    assert!(atc < seq, "ATC must hide some communication: {atc} vs {seq}");
    assert!(awc <= atc + 1e-9, "AWC must hide at least as much as ATC: {awc} vs {atc}");
    // The deeper the network, the more ATC hides (paper: "the deeper the
    // neural network is, the larger portion the communication in ATC-style
    // algorithm may overlap").
    println!("\nablation_overlap OK");
}
