//! Ablation A1 — tensor fusion (paper §VI-C).
//!
//! 64 small-tensor `neighbor_allreduce` requests (the shape of a DNN's
//! per-layer gradients) issued non-blocking, with the communication
//! thread's fusion threshold swept from 0 (fusion off) to 16 MB. Fusion
//! batches the latency term: with threshold T, ~ceil(total/T) messages pay
//! latency instead of 64.
//!
//! Also sweeps message size to show the paper's observation that
//! *neighbor* communication prefers a smaller fusion buffer than
//! ring-allreduce (its latency term is O(1), not O(n), so over-fusing only
//! adds copy/wait time).
//!
//! Run: `cargo bench --bench ablation_fusion`

use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::simnet::NetworkModel;

const TENSORS: usize = 64;
const NUMEL: usize = 4096; // 16 KB per tensor (latency/overhead-bound)

/// Virtual + wall time for one bucketed exchange round under a threshold.
fn measure(threshold: usize) -> (f64, f64) {
    let cfg = SpmdConfig::new(8)
        .with_net(NetworkModel::flat(25e9 / 8.0, 50e-6).with_overhead(20e-6))
        .with_fusion_threshold(threshold)
        .with_topo_check(false);
    let per_rank = run_spmd(cfg, |ctx| {
        let data = vec![1.0f32; NUMEL];
        let v0 = ctx.vtime();
        let t0 = std::time::Instant::now();
        // Issue all bucket requests back-to-back (layer-wise gradients),
        // then wait for all — exactly how the optimizer wrapper drains a
        // backward pass.
        let mut handles = Vec::with_capacity(TENSORS);
        for _ in 0..TENSORS {
            handles.push(ctx.neighbor_allreduce_nonblocking(&data, None)?);
        }
        for h in handles {
            let out = h.wait(ctx)?;
            anyhow::ensure!(out.len() == NUMEL, "bad result size");
        }
        Ok((ctx.vtime() - v0, t0.elapsed().as_secs_f64()))
    })
    .expect("run failed");
    let v = per_rank.iter().map(|r| r.0).fold(0.0, f64::max);
    let w = per_rank.iter().map(|r| r.1).fold(0.0, f64::max);
    (v, w)
}

fn main() {
    println!(
        "## fusion ablation: {TENSORS} x {} KB neighbor_allreduce, 8 nodes, 25 Gbps / 50 us lat / 20 us per-msg overhead",
        NUMEL * 4 / 1024
    );
    println!("{:<18} {:>14} {:>14}", "threshold", "virtual time", "wall time");
    let mut results = vec![];
    for threshold in [0usize, 256 << 10, 2 << 20, 16 << 20] {
        let (v, w) = measure(threshold);
        let label = if threshold == 0 {
            "off".to_string()
        } else {
            format!("{} KB", threshold >> 10)
        };
        println!("{label:<18} {:>11.3} ms {:>11.3} ms", v * 1e3, w * 1e3);
        results.push((threshold, v));
    }
    // Fusion-on must beat fusion-off on the latency-bound workload.
    let off = results[0].1;
    let on = results.iter().skip(1).map(|r| r.1).fold(f64::INFINITY, f64::min);
    println!("\nbest fused vs unfused: {:.2}x", off / on);
    assert!(
        on < off * 0.6,
        "fusion should cut the latency-bound time substantially: off={off} on={on}"
    );
    println!("\nablation_fusion OK");
}
