//! Ablation A4 — hierarchical vs flat neighbor allreduce (paper §V-B,
//! Fig. 7/10).
//!
//! Measures the executed virtual time of `neighbor_allreduce` (flat, over
//! the machine-blind exponential graph) vs `hierarchical_neighbor_allreduce`
//! (intra-machine ring + machine-level exchange + broadcast) as the number
//! of machines grows with 4 ranks each. The hierarchical variant pays fast
//! NVLink prices for most of its steps, so it wins once several machines
//! are involved and inter-machine bandwidth dominates.
//!
//! Run: `cargo bench --bench ablation_hierarchical`

use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::simnet::NetworkModel;
use bluefog::topology::{builders, WeightMatrix};

const RANKS_PER_MACHINE: usize = 4;
const NUMEL: usize = 262_144; // 1 MB

fn measure(machines: usize, hierarchical: bool) -> f64 {
    let n = machines * RANKS_PER_MACHINE;
    let g = builders::exponential_two(n);
    let w = WeightMatrix::uniform_pull(&g);
    let cfg = SpmdConfig::new(n)
        .with_net(NetworkModel::aws_p3(RANKS_PER_MACHINE))
        .with_topology(g, w)
        .with_topo_check(false);
    let per_rank = run_spmd(cfg, move |ctx| {
        let data = vec![1.0f32; NUMEL];
        let mut vtotal = 0.0;
        for _ in 0..5 {
            ctx.barrier()?; // align clocks between reps
            let v0 = ctx.vtime();
            if hierarchical {
                ctx.hierarchical_neighbor_allreduce(&data)?;
            } else {
                ctx.neighbor_allreduce(&data)?;
            }
            vtotal += ctx.vtime() - v0;
        }
        Ok(vtotal / 5.0)
    })
    .expect("run failed");
    per_rank.iter().cloned().fold(0.0, f64::max)
}

fn main() {
    println!(
        "## hierarchical ablation: 1 MB, {RANKS_PER_MACHINE} ranks/machine (NVLink intra, 25 Gbps inter)"
    );
    println!("{:<10} {:>6} {:>14} {:>14} {:>8}", "machines", "n", "flat", "hierarchical", "ratio");
    let mut multi_machine_win = false;
    for machines in [1usize, 2, 4, 8] {
        let flat = measure(machines, false);
        let hier = measure(machines, true);
        println!(
            "{:<10} {:>6} {:>11.3} ms {:>11.3} ms {:>8.2}",
            machines,
            machines * RANKS_PER_MACHINE,
            flat * 1e3,
            hier * 1e3,
            flat / hier
        );
        if machines >= 4 && hier < flat {
            multi_machine_win = true;
        }
    }
    assert!(
        multi_machine_win,
        "hierarchical must beat flat once several machines are involved"
    );
    println!("\nablation_hierarchical OK");
}
