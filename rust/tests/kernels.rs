//! ISSUE 9 property tests: the SIMD kernels are *bitwise* identical to the
//! frozen seed scalar kernels (same per-element accumulation order — the
//! vectorization runs across outputs), and the intra-rank worker pool
//! produces identical bytes for every thread count (shard boundaries are
//! pure functions of the length, never of the pool size).

use bluefog::compress::{CompressionSpec, CompressionState};
use bluefog::parallel::WorkerPool;
use bluefog::tensor::{self, scalar, COMBINE_BLOCK, PAR_MIN_ELEMS};

/// Boundary lengths around the lane width (8) and the combine block size.
const LENS: [usize; 8] = [0, 1, 7, 8, 9, COMBINE_BLOCK - 1, COMBINE_BLOCK, COMBINE_BLOCK + 1];

/// Deterministic non-NaN test data (LCG; never produces a negative zero,
/// so f32 min/max lane folds stay order-independent).
fn gen(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    (0..n)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 40) as i64 - (1 << 23)) as f32 / (1 << 20) as f32
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn simd_axpy_bitwise_matches_scalar() {
    for (i, &n) in LENS.iter().enumerate() {
        let x = gen(n, 11 + i as u64);
        let mut y_simd = gen(n, 97 + i as u64);
        let mut y_ref = y_simd.clone();
        tensor::axpy(0.73, &x, &mut y_simd);
        scalar::axpy(0.73, &x, &mut y_ref);
        assert_eq!(bits(&y_simd), bits(&y_ref), "axpy diverged at n={n}");
    }
}

#[test]
fn simd_scale_bitwise_matches_scalar() {
    for (i, &n) in LENS.iter().enumerate() {
        let mut x_simd = gen(n, 23 + i as u64);
        let mut x_ref = x_simd.clone();
        tensor::scale(-1.375, &mut x_simd);
        scalar::scale(-1.375, &mut x_ref);
        assert_eq!(bits(&x_simd), bits(&x_ref), "scale diverged at n={n}");
    }
}

#[test]
fn simd_blocked_combine_bitwise_matches_scalar() {
    for (i, &n) in LENS.iter().enumerate() {
        let parts: Vec<Vec<f32>> = (0..3).map(|p| gen(n, 1000 * p + i as u64)).collect();
        let views: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
        let ws = [0.25f32, 0.125, 0.5];
        let mut acc_simd = gen(n, 7 + i as u64);
        let mut acc_ref = acc_simd.clone();
        tensor::weighted_combine_blocked_into(&mut acc_simd, 0.125, &views, &ws);
        scalar::weighted_combine_blocked_into(&mut acc_ref, 0.125, &views, &ws);
        assert_eq!(bits(&acc_simd), bits(&acc_ref), "blocked combine diverged at n={n}");
    }
}

#[test]
fn simd_blocked_combine_handles_zero_parts() {
    let mut acc_simd = gen(COMBINE_BLOCK + 1, 3);
    let mut acc_ref = acc_simd.clone();
    tensor::weighted_combine_blocked_into(&mut acc_simd, 0.75, &[], &[]);
    scalar::weighted_combine_blocked_into(&mut acc_ref, 0.75, &[], &[]);
    assert_eq!(bits(&acc_simd), bits(&acc_ref));
}

#[test]
fn parallel_combine_identical_bytes_for_any_thread_count() {
    // Above PAR_MIN_ELEMS so the pool actually shards; +13 for a ragged
    // tail that does not fall on a block boundary.
    let n = PAR_MIN_ELEMS + 13;
    let parts: Vec<Vec<f32>> = (0..4).map(|p| gen(n, 40 + p)).collect();
    let views: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
    let ws = [0.25f32, 0.125, 0.0625, 0.25];
    let base = gen(n, 5);
    let mut reference = base.clone();
    tensor::weighted_combine_blocked_into(&mut reference, 0.3125, &views, &ws);
    for threads in [1usize, 2, 4] {
        let pool = WorkerPool::new(threads);
        let mut acc = base.clone();
        tensor::weighted_combine_blocked_into_par(&pool, &mut acc, 0.3125, &views, &ws);
        assert_eq!(bits(&acc), bits(&reference), "combine diverged at {threads} threads");
    }
}

#[test]
fn codec_encodes_identical_bytes_for_any_thread_count() {
    let d = PAR_MIN_ELEMS + 13;
    let rounds = 3;
    let specs = [
        CompressionSpec::top_k(257),
        CompressionSpec::random_k(129),
        CompressionSpec::quantize_u8(64),
        CompressionSpec::low_rank(2),
    ];
    for spec in specs {
        // Reference: serial encode of `rounds` error-feedback steps.
        let mut reference: Vec<Vec<u32>> = Vec::new();
        let mut st = CompressionState::new(spec, 42);
        for r in 0..rounds {
            let data = gen(d, 300 + r);
            let mut wire = Vec::new();
            st.encode(9, &data, &mut wire);
            reference.push(bits(&wire));
        }
        for threads in [2usize, 4] {
            let mut st = CompressionState::new(spec, 42).with_par(WorkerPool::new(threads));
            for (r, want) in reference.iter().enumerate() {
                let data = gen(d, 300 + r as u64);
                let mut wire = Vec::new();
                st.encode(9, &data, &mut wire);
                assert_eq!(
                    &bits(&wire),
                    want,
                    "{} diverged at {threads} threads, round {r}",
                    spec.label()
                );
            }
        }
    }
}
