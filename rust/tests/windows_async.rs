//! Integration tests: asynchronous window operations (paper §III-C) and
//! the asynchronous optimizers/regime built on them (§IV-C).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use bluefog::collective::{AllreduceAlgo, ReduceOp};
use bluefog::launcher::{run_spmd, AsyncSpec, SpmdConfig};
use bluefog::optim::{AsyncDecentralizedOptimizer, AsyncGossipSgd, AsyncPushSumSgd};
use bluefog::simnet::hetero::ComputeHeterogeneity;
use bluefog::simnet::NetworkModel;
use bluefog::topology::{builders, WeightMatrix};

fn ring_cfg(n: usize) -> SpmdConfig {
    let g = builders::ring(n);
    let w = WeightMatrix::metropolis_hastings(&g);
    SpmdConfig::new(n).with_topology(g, w)
}

#[test]
fn win_put_then_update_averages() {
    let n = 4;
    let results = run_spmd(ring_cfg(n), |ctx| {
        let x = vec![ctx.rank() as f32; 2];
        ctx.win_create("w", &x, false)?;
        // Everyone puts its raw tensor to its out-neighbors.
        ctx.win_put("w", &x, &[])?;
        ctx.barrier()?;
        // Uniform average over self + 2 ring in-neighbors.
        let third = 1.0 / 3.0;
        let srcs: Vec<(usize, f64)> =
            ctx.in_neighbor_ranks().into_iter().map(|r| (r, third)).collect();
        let out = ctx.win_update("w", &x, third, &srcs)?;
        ctx.barrier()?;
        ctx.win_free("w")?;
        Ok(out[0])
    })
    .unwrap();
    for (rank, got) in results.iter().enumerate() {
        let prev = (rank + n - 1) % n;
        let next = (rank + 1) % n;
        let want = (rank + prev + next) as f32 / 3.0;
        assert!((got - want).abs() < 1e-6, "rank {rank}: {got} != {want}");
    }
}

#[test]
fn win_get_pulls_registered_values() {
    let n = 4;
    let results = run_spmd(ring_cfg(n), |ctx| {
        let x = vec![(ctx.rank() * 100) as f32];
        ctx.win_create("g", &x, true)?;
        // Register our value via win_update (no sources yet).
        ctx.win_update("g", &x, 1.0, &[])?;
        ctx.barrier()?;
        // Pull each in-neighbor's registered tensor, then average.
        let srcs: Vec<(usize, f64)> =
            ctx.in_neighbor_ranks().into_iter().map(|r| (r, 1.0)).collect();
        ctx.win_get("g", &srcs)?;
        // Barrier before the averaging win_update: it re-registers the
        // *averaged* value as the local tensor, which a late win_get on
        // another rank would otherwise observe.
        ctx.barrier()?;
        let third = 1.0 / 3.0;
        let srcs_avg: Vec<(usize, f64)> = srcs.iter().map(|&(r, _)| (r, third)).collect();
        let out = ctx.win_update("g", &x, third, &srcs_avg)?;
        ctx.barrier()?;
        ctx.win_free("g")?;
        Ok(out[0])
    })
    .unwrap();
    for (rank, got) in results.iter().enumerate() {
        let prev = (rank + n - 1) % n;
        let next = (rank + 1) % n;
        let want = ((rank + prev + next) * 100) as f32 / 3.0;
        assert!((got - want).abs() < 1e-4, "rank {rank}: {got} != {want}");
    }
}

#[test]
fn win_accumulate_conserves_mass() {
    let n = 6;
    let results = run_spmd(ring_cfg(n), |ctx| {
        let mut x = vec![1.0f32];
        ctx.win_create("m", &x, true)?;
        let out = ctx.out_neighbor_ranks();
        let share = 1.0 / (out.len() + 1) as f64;
        let dsts: Vec<(usize, f64)> = out.iter().map(|&r| (r, share)).collect();
        for _ in 0..25 {
            ctx.win_accumulate("m", &mut x, share, &dsts)?;
            ctx.win_update_then_collect("m", &mut x)?;
        }
        ctx.barrier()?;
        ctx.win_update_then_collect("m", &mut x)?;
        ctx.win_free("m")?;
        Ok(x[0] as f64)
    })
    .unwrap();
    let total: f64 = results.iter().sum();
    assert!((total - n as f64).abs() < 1e-4, "mass leaked: {total} != {n}");
}

#[test]
fn win_create_rejects_duplicates_and_free_unknown() {
    let results = run_spmd(ring_cfg(2), |ctx| {
        ctx.win_create("dup", &[1.0], false)?;
        let dup_err = ctx.win_create("dup", &[1.0], false).is_err();
        // Size mismatch caught:
        let size_err = ctx.win_update("dup", &[1.0, 2.0], 1.0, &[]).is_err();
        let missing_err = ctx.win_free("nope").is_err();
        ctx.barrier()?;
        ctx.win_free("dup")?;
        Ok((dup_err, size_err, missing_err))
    })
    .unwrap();
    for (dup, size, missing) in results {
        assert!(dup && size && missing);
    }
}

#[test]
fn win_put_to_non_neighbor_is_rejected() {
    // Window topology is fixed at creation: pushing to a rank that is not
    // an in-neighbor under the window's topology must error.
    let n = 4;
    let results = run_spmd(ring_cfg(n), |ctx| {
        let x = vec![0.0f32];
        ctx.win_create("t", &x, true)?;
        // Rank 0's non-neighbor on a 4-ring is rank 2.
        let res = if ctx.rank() == 0 {
            ctx.win_put("t", &x, &[(2, 1.0)]).is_err()
        } else {
            true
        };
        ctx.barrier()?;
        ctx.win_free("t")?;
        Ok(res)
    })
    .unwrap();
    assert!(results.iter().all(|&r| r));
}

// ---------------------------------------------------------------------------
// Regression tests for the three window-op mass/liveness bugs (ISSUE 5).
// ---------------------------------------------------------------------------

/// Bug 1: `win_accumulate` with an empty `dst_weights` used to send to
/// nobody while still scaling the caller's tensor — silent mass loss. It
/// must default to the out-neighbors with weight 1, like `win_put`.
#[test]
fn win_accumulate_empty_dsts_defaults_to_out_neighbors() {
    let n = 4;
    let results = run_spmd(ring_cfg(n), |ctx| {
        let mut x = vec![1.0f32; 2];
        ctx.win_create("defdst", &x, true)?;
        ctx.barrier()?;
        ctx.win_accumulate("defdst", &mut x, 0.5, &[])?;
        ctx.barrier()?;
        let pending = ctx.win_pending("defdst")?;
        ctx.barrier()?;
        ctx.win_free("defdst")?;
        Ok((x, pending))
    })
    .unwrap();
    for (rank, (x, pending)) in results.iter().enumerate() {
        // Caller's tensor scaled by self_weight as before...
        assert_eq!(x[..], [0.5f32; 2], "rank {rank}: self scaling changed");
        // ...but the mass now actually went somewhere: both ring
        // in-neighbors pushed 1.0 * [1, 1] into our slots.
        assert_eq!(pending[..], [2.0f32; 2], "rank {rank}: default dsts did not receive");
    }
}

/// Bug 2: a rank whose `win_create` fails locally (duplicate name) used to
/// return before the barrier, deadlocking every peer. The barrier must be
/// reached on the error path too, and the error still propagate.
#[test]
fn win_create_error_reaches_barrier_and_propagates() {
    let results = run_spmd(ring_cfg(3), |ctx| {
        ctx.win_create("dupwin", &[1.0], false)?;
        // Rank 0 re-creates the same window (local error); its peers create
        // a fresh one. Every rank calls win_create exactly twice, so the
        // barriers pair up — before the fix this test hung forever.
        let dup_err = if ctx.rank() == 0 {
            ctx.win_create("dupwin", &[1.0], false).is_err()
        } else {
            ctx.win_create("other", &[1.0], false)?;
            true
        };
        ctx.barrier()?;
        ctx.win_free("dupwin")?;
        if ctx.rank() != 0 {
            ctx.win_free("other")?;
        }
        Ok(dup_err)
    })
    .unwrap();
    assert!(results.iter().all(|&e| e), "duplicate create must error after the barrier");
}

/// Bug 3: `win_update` used to silently skip a listed source with no slot,
/// biasing the weighted average low. It must error like `win_put`/`win_get`.
#[test]
fn win_update_missing_source_errors() {
    let n = 4;
    let results = run_spmd(ring_cfg(n), |ctx| {
        let x = vec![1.0f32];
        ctx.win_create("missrc", &x, true)?;
        // On a 4-ring, rank+2 is never an in-neighbor.
        let stranger = (ctx.rank() + 2) % 4;
        let err = ctx.win_update("missrc", &x, 0.5, &[(stranger, 0.5)]).is_err();
        ctx.barrier()?;
        ctx.win_free("missrc")?;
        Ok(err)
    })
    .unwrap();
    assert!(results.iter().all(|&e| e), "missing-slot source must be an error, not a skip");
}

/// Property: `Σ_i (x_i + pending_i)` is invariant under arbitrary seeded
/// interleavings of column-stochastic `win_accumulate` and both drain
/// flavors — the push-sum requirement the three bugfixes protect.
#[test]
fn window_mass_conservation_property() {
    let n = 6;
    let d = 3;
    let rounds = 12;
    let results = run_spmd(ring_cfg(n), move |ctx| {
        let mut x = vec![(ctx.rank() + 1) as f32; d];
        ctx.win_create("mass", &x, true)?;
        ctx.barrier()?;
        let mut worst = 0.0f64;
        for _ in 0..rounds {
            // Random column-stochastic split over a random out-subset.
            let chosen: Vec<usize> =
                ctx.out_neighbor_ranks().into_iter().filter(|_| ctx.rng.chance(0.7)).collect();
            if !chosen.is_empty() {
                let share = 1.0 / (chosen.len() + 1) as f64;
                let dsts: Vec<(usize, f64)> = chosen.iter().map(|&r| (r, share)).collect();
                ctx.win_accumulate("mass", &mut x, share, &dsts)?;
            }
            // Random drain flavor (or none at all this round).
            if ctx.rng.chance(0.5) {
                ctx.win_update_then_collect("mass", &mut x)?;
            } else if ctx.rng.chance(0.5) {
                ctx.win_update_then_collect_causal("mass", &mut x)?;
            }
            ctx.barrier()?;
            let pending = ctx.win_pending("mass")?;
            let held: Vec<f32> = x.iter().zip(&pending).map(|(a, b)| a + b).collect();
            let total = ctx.allreduce(&held, ReduceOp::Sum, AllreduceAlgo::Ring)?;
            let want = (n * (n + 1) / 2) as f64; // Σ (rank+1)
            for t in &total {
                worst = worst.max((*t as f64 - want).abs());
            }
        }
        ctx.win_update_then_collect("mass", &mut x)?;
        ctx.barrier()?;
        ctx.win_free("mass")?;
        Ok(worst)
    })
    .unwrap();
    let worst = results.iter().cloned().fold(0.0f64, f64::max);
    assert!(worst < 2e-3, "window mass leaked: worst per-element drift {worst}");
}

/// The causal drain leaves writes whose virtual arrival is in the future
/// pending (and does not drag the receiver's clock forward); once the
/// receiver's own clock passes the arrival, the mass is collected.
#[test]
fn causal_drain_defers_future_writes() {
    let flag = Arc::new(AtomicUsize::new(0));
    let g = builders::ring(3);
    let w = WeightMatrix::metropolis_hastings(&g);
    let cfg = SpmdConfig::new(3)
        .with_topology(g, w)
        .with_net(NetworkModel::flat(1e9, 0.0));
    let results = run_spmd(cfg, move |ctx| {
        let mut x = vec![(ctx.rank() + 1) as f32; 2];
        ctx.win_create("causal", &x, true)?;
        let mut ok = true;
        match ctx.rank() {
            1 => {
                // Write from 5 virtual seconds in the receiver's future.
                ctx.simulate_compute(5.0);
                ctx.win_accumulate("causal", &mut x, 0.5, &[(0, 0.5)])?;
                flag.store(1, Ordering::Release);
            }
            0 => {
                while flag.load(Ordering::Acquire) == 0 {
                    std::thread::yield_now();
                }
                let pending = ctx.win_pending("causal")?;
                ok &= pending == [1.0f32; 2]; // 0.5 * [2, 2]
                let deferred = ctx.win_update_then_collect_causal("causal", &mut x)?;
                ok &= deferred == 1;
                ok &= x == [1.0f32; 2]; // untouched: the write hasn't "arrived"
                ok &= ctx.vtime() < 5.0; // clock not dragged to the future
                // Causal win_update: rank 1's in-flight weight falls back
                // on the local tensor; rank 2's (drained, zero) slot is
                // included. 0.75 * [1,1] + 0.25 * [0,0] = [0.75, 0.75].
                let avg = ctx.win_update_causal("causal", &x, 0.5, &[(1, 0.25), (2, 0.25)])?;
                ok &= avg == [0.75f32; 2];
                ok &= ctx.vtime() < 5.0; // still not dragged
                ctx.simulate_compute(10.0);
                let deferred = ctx.win_update_then_collect_causal("causal", &mut x)?;
                ok &= deferred == 0;
                ok &= x == [2.0f32; 2]; // collected after arrival
                // Drained slots shed their arrival stamps: nothing pending
                // means nothing stale.
                ok &= ctx.win_staleness("causal")? == 0.0;
            }
            _ => {}
        }
        ctx.barrier()?;
        ctx.win_free("causal")?;
        Ok(ok)
    })
    .unwrap();
    assert!(results.iter().all(|&ok| ok));
}

// ---------------------------------------------------------------------------
// Asynchronous optimizers end-to-end (window → optimizer → regime).
// ---------------------------------------------------------------------------

/// Async push-sum SGD with zero gradients is asynchronous average
/// consensus: mass conservation + the push-sum correction must take every
/// rank to the initial mean despite a 4x straggler and causal drains. The
/// loop runs on a virtual-time budget (not a step count) so all ranks
/// leave the regime near the same virtual instant — with a fixed step
/// count the fast ranks finish early and the straggler splits its mass
/// into windows nobody drains until its push-sum weight underflows.
#[test]
fn async_push_sum_sgd_consensus_with_straggler() {
    let n = 6;
    let d = 3;
    let base = 1e-3;
    let t_end = 0.15; // fast ranks ~150 steps, straggler ~38
    let hetero = ComputeHeterogeneity::straggler(n, 0, 4.0).with_jitter(0.1);
    let cfg = SpmdConfig::new(n)
        .with_topo_check(false)
        .with_async(AsyncSpec::new(hetero).with_horizon(16.0 * base));
    let results = run_spmd(cfg, move |ctx| {
        let mut x = vec![ctx.rank() as f32; d];
        let zeros = vec![0.0f32; d];
        let mut opt = AsyncPushSumSgd::new(0.0, "cons");
        for _ in 0..10_000 {
            if ctx.vtime() >= t_end {
                break;
            }
            ctx.async_throttle();
            ctx.simulate_compute_hetero(base);
            opt.refresh(ctx, &mut x)?;
            opt.step(ctx, &mut x, &zeros)?;
        }
        opt.finalize(ctx, &mut x)?;
        Ok((x, opt.push_weight()))
    })
    .unwrap();
    let mean = (0..6).sum::<usize>() as f32 / 6.0;
    // Mass conservation across the network: Σ v_i = n exactly (up to fp).
    let v_total: f64 = results.iter().map(|(_, v)| *v as f64).sum();
    assert!((v_total - 6.0).abs() < 1e-3, "push-sum weight mass leaked: {v_total}");
    for (rank, (x, _)) in results.iter().enumerate() {
        for v in x {
            assert!(
                (v - mean).abs() < 5e-3,
                "rank {rank} did not reach consensus: {v} vs {mean}"
            );
        }
    }
}

/// AD-PSGD-style gossip: every combine is convex, so iterates stay in the
/// initial convex hull and the spread contracts despite stale slots.
#[test]
fn async_gossip_sgd_contracts_into_hull() {
    let n = 6;
    let steps = 200;
    let base = 1e-3;
    let g = builders::ring(n);
    let w = WeightMatrix::metropolis_hastings(&g);
    let cfg = SpmdConfig::new(n)
        .with_topology(g, w)
        .with_topo_check(false)
        .with_async(AsyncSpec::new(ComputeHeterogeneity::uniform(n).with_jitter(0.2))
            .with_horizon(8.0 * base));
    let results = run_spmd(cfg, move |ctx| {
        let mut x = vec![ctx.rank() as f32; 2];
        let zeros = vec![0.0f32; 2];
        let mut opt = AsyncGossipSgd::new(0.0, "gossip");
        for _ in 0..steps {
            ctx.async_throttle();
            ctx.simulate_compute_hetero(base);
            opt.refresh(ctx, &mut x)?;
            opt.step(ctx, &mut x, &zeros)?;
        }
        opt.finalize(ctx, &mut x)?;
        Ok(x)
    })
    .unwrap();
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for x in &results {
        for &v in x {
            assert!((-1e-4f32..=5.0001f32).contains(&v), "left the convex hull: {v}");
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    assert!(hi - lo < 1.0, "gossip failed to contract: spread {} (initial 5)", hi - lo);
}

#[test]
fn window_vtime_advances_on_update() {
    let results = run_spmd(ring_cfg(3), |ctx| {
        let mut x = vec![1.0f32; 1024];
        ctx.win_create("vt", &x, true)?;
        let share = 1.0 / 3.0;
        let dsts: Vec<(usize, f64)> =
            ctx.out_neighbor_ranks().into_iter().map(|r| (r, share)).collect();
        ctx.win_accumulate("vt", &mut x, share, &dsts)?;
        ctx.barrier()?;
        let before = ctx.vtime();
        ctx.win_update_then_collect("vt", &mut x)?;
        let after = ctx.vtime();
        ctx.barrier()?;
        ctx.win_free("vt")?;
        Ok(after >= before)
    })
    .unwrap();
    assert!(results.iter().all(|&ok| ok));
}
