//! Integration tests: asynchronous window operations (paper §III-C).

use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::topology::{builders, WeightMatrix};

fn ring_cfg(n: usize) -> SpmdConfig {
    let g = builders::ring(n);
    let w = WeightMatrix::metropolis_hastings(&g);
    SpmdConfig::new(n).with_topology(g, w)
}

#[test]
fn win_put_then_update_averages() {
    let n = 4;
    let results = run_spmd(ring_cfg(n), |ctx| {
        let x = vec![ctx.rank() as f32; 2];
        ctx.win_create("w", &x, false)?;
        // Everyone puts its raw tensor to its out-neighbors.
        ctx.win_put("w", &x, &[])?;
        ctx.barrier()?;
        // Uniform average over self + 2 ring in-neighbors.
        let third = 1.0 / 3.0;
        let srcs: Vec<(usize, f64)> =
            ctx.in_neighbor_ranks().into_iter().map(|r| (r, third)).collect();
        let out = ctx.win_update("w", &x, third, &srcs)?;
        ctx.barrier()?;
        ctx.win_free("w")?;
        Ok(out[0])
    })
    .unwrap();
    for (rank, got) in results.iter().enumerate() {
        let prev = (rank + n - 1) % n;
        let next = (rank + 1) % n;
        let want = (rank + prev + next) as f32 / 3.0;
        assert!((got - want).abs() < 1e-6, "rank {rank}: {got} != {want}");
    }
}

#[test]
fn win_get_pulls_registered_values() {
    let n = 4;
    let results = run_spmd(ring_cfg(n), |ctx| {
        let x = vec![(ctx.rank() * 100) as f32];
        ctx.win_create("g", &x, true)?;
        // Register our value via win_update (no sources yet).
        ctx.win_update("g", &x, 1.0, &[])?;
        ctx.barrier()?;
        // Pull each in-neighbor's registered tensor, then average.
        let srcs: Vec<(usize, f64)> =
            ctx.in_neighbor_ranks().into_iter().map(|r| (r, 1.0)).collect();
        ctx.win_get("g", &srcs)?;
        // Barrier before the averaging win_update: it re-registers the
        // *averaged* value as the local tensor, which a late win_get on
        // another rank would otherwise observe.
        ctx.barrier()?;
        let third = 1.0 / 3.0;
        let srcs_avg: Vec<(usize, f64)> = srcs.iter().map(|&(r, _)| (r, third)).collect();
        let out = ctx.win_update("g", &x, third, &srcs_avg)?;
        ctx.barrier()?;
        ctx.win_free("g")?;
        Ok(out[0])
    })
    .unwrap();
    for (rank, got) in results.iter().enumerate() {
        let prev = (rank + n - 1) % n;
        let next = (rank + 1) % n;
        let want = ((rank + prev + next) * 100) as f32 / 3.0;
        assert!((got - want).abs() < 1e-4, "rank {rank}: {got} != {want}");
    }
}

#[test]
fn win_accumulate_conserves_mass() {
    let n = 6;
    let results = run_spmd(ring_cfg(n), |ctx| {
        let mut x = vec![1.0f32];
        ctx.win_create("m", &x, true)?;
        let out = ctx.out_neighbor_ranks();
        let share = 1.0 / (out.len() + 1) as f64;
        let dsts: Vec<(usize, f64)> = out.iter().map(|&r| (r, share)).collect();
        for _ in 0..25 {
            ctx.win_accumulate("m", &mut x, share, &dsts)?;
            ctx.win_update_then_collect("m", &mut x)?;
        }
        ctx.barrier()?;
        ctx.win_update_then_collect("m", &mut x)?;
        ctx.win_free("m")?;
        Ok(x[0] as f64)
    })
    .unwrap();
    let total: f64 = results.iter().sum();
    assert!((total - n as f64).abs() < 1e-4, "mass leaked: {total} != {n}");
}

#[test]
fn win_create_rejects_duplicates_and_free_unknown() {
    let results = run_spmd(ring_cfg(2), |ctx| {
        ctx.win_create("dup", &[1.0], false)?;
        let dup_err = ctx.win_create("dup", &[1.0], false).is_err();
        // Size mismatch caught:
        let size_err = ctx.win_update("dup", &[1.0, 2.0], 1.0, &[]).is_err();
        let missing_err = ctx.win_free("nope").is_err();
        ctx.barrier()?;
        ctx.win_free("dup")?;
        Ok((dup_err, size_err, missing_err))
    })
    .unwrap();
    for (dup, size, missing) in results {
        assert!(dup && size && missing);
    }
}

#[test]
fn win_put_to_non_neighbor_is_rejected() {
    // Window topology is fixed at creation: pushing to a rank that is not
    // an in-neighbor under the window's topology must error.
    let n = 4;
    let results = run_spmd(ring_cfg(n), |ctx| {
        let x = vec![0.0f32];
        ctx.win_create("t", &x, true)?;
        // Rank 0's non-neighbor on a 4-ring is rank 2.
        let res = if ctx.rank() == 0 {
            ctx.win_put("t", &x, &[(2, 1.0)]).is_err()
        } else {
            true
        };
        ctx.barrier()?;
        ctx.win_free("t")?;
        Ok(res)
    })
    .unwrap();
    assert!(results.iter().all(|&r| r));
}

#[test]
fn window_vtime_advances_on_update() {
    let results = run_spmd(ring_cfg(3), |ctx| {
        let mut x = vec![1.0f32; 1024];
        ctx.win_create("vt", &x, true)?;
        let share = 1.0 / 3.0;
        let dsts: Vec<(usize, f64)> =
            ctx.out_neighbor_ranks().into_iter().map(|r| (r, share)).collect();
        ctx.win_accumulate("vt", &mut x, share, &dsts)?;
        ctx.barrier()?;
        let before = ctx.vtime();
        ctx.win_update_then_collect("vt", &mut x)?;
        let after = ctx.vtime();
        ctx.barrier()?;
        ctx.win_free("vt")?;
        Ok(after >= before)
    })
    .unwrap();
    assert!(results.iter().all(|&ok| ok));
}
