//! Integration + property tests for the fault-injection layer (ISSUE 7):
//! self-healing Metropolis–Hastings renormalization, typed dead-peer
//! errors instead of infinite hangs (on both exec backends), bitwise
//! cross-backend fault-schedule reproducibility, and push-sum mass
//! behavior under randomized crash schedules.

use std::collections::BTreeSet;

use bluefog::launcher::{run_spmd, AsyncSpec, ExecMode, SpmdConfig};
use bluefog::optim::{AsyncDecentralizedOptimizer, AsyncPushSumSgd};
use bluefog::prop_assert;
use bluefog::proptest::{check, Gen};
use bluefog::simnet::faults::{FaultPlan, LinkFate};
use bluefog::simnet::hetero::ComputeHeterogeneity;
use bluefog::topology::health::survivor_mh_row;
use bluefog::topology::{builders, WeightMatrix};

fn ring_cfg(n: usize, mode: ExecMode, plan: FaultPlan) -> SpmdConfig {
    let g = builders::ring(n);
    let w = WeightMatrix::metropolis_hastings(&g);
    SpmdConfig::new(n)
        .with_topo_check(false)
        .with_exec(mode)
        .with_topology(g, w)
        .with_faults(plan)
}

// ---------------------------------------------------------------------------
// Self-healing weight renormalization (pure).
// ---------------------------------------------------------------------------

/// After ANY sequence of evictions on a random connected graph, every
/// survivor's re-derived Metropolis–Hastings row must stay row-stochastic
/// (entries >= 0, summing to 1), reference no dead peer, and agree
/// pairwise with the reverse entry — the three conditions that keep the
/// healed matrix doubly stochastic over the survivor set.
#[test]
fn prop_survivor_rows_stochastic_after_any_eviction_sequence() {
    check("survivor-mh-eviction", 16, |g: &mut Gen| {
        let n = g.usize_in(4, 10);
        let graph = g.connected_graph(n, 0.3);
        let kills = g.usize_in(1, n - 2);
        let mut dead: BTreeSet<usize> = BTreeSet::new();
        for _ in 0..kills {
            // Pick a not-yet-dead rank; keep at least two survivors.
            let victim = loop {
                let v = g.usize_in(0, n);
                if !dead.contains(&v) {
                    break v;
                }
            };
            dead.insert(victim);
            for i in 0..n {
                if dead.contains(&i) {
                    continue;
                }
                let (self_w, row) = survivor_mh_row(&graph, &dead, i);
                let sum: f64 = self_w + row.iter().map(|(_, w)| w).sum::<f64>();
                prop_assert!((sum - 1.0).abs() < 1e-12, "row {i} sums to {sum} (dead {dead:?})");
                prop_assert!(self_w >= 0.0, "row {i} negative self weight {self_w}");
                for &(j, w) in &row {
                    prop_assert!(w > 0.0, "row {i} nonpositive weight on {j}");
                    prop_assert!(!dead.contains(&j), "row {i} kept dead peer {j}");
                    let (_, back) = survivor_mh_row(&graph, &dead, j);
                    let w_ji = back.iter().find(|(k, _)| *k == i).map(|(_, w)| *w);
                    prop_assert!(
                        w_ji.is_some_and(|w_ji| (w - w_ji).abs() < 1e-12),
                        "w[{i},{j}]={w} vs w[{j},{i}]={w_ji:?} (dead {dead:?})"
                    );
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Fate purity (pure).
// ---------------------------------------------------------------------------

/// Without partitions, a message's fate is a pure function of
/// `(seed, src, dst, seq)` — bitwise independent of the virtual send
/// time. This is the property that makes fault schedules reproducible
/// across exec backends, whose clocks agree but whose wall-time
/// interleavings differ wildly.
#[test]
fn link_fates_are_independent_of_send_time_without_partitions() {
    let plan = FaultPlan::seeded(0x1234, 0.05)
        .with_drop(0.2, 2, 1e-4)
        .with_delay(0.3, 5e-5)
        .with_dup(0.2);
    for src in 0..4 {
        for dst in 0..4 {
            for seq in 0..64u64 {
                let a = plan.link_fate(src, dst, seq, 0.0);
                let b = plan.link_fate(src, dst, seq, 17.25);
                assert_eq!(a, b, "fate of ({src}->{dst}, seq {seq}) depends on send time");
            }
        }
    }
    // A partitioned link, by contrast, must kill every attempt that falls
    // inside the window when retries cannot reach past it.
    let cut = FaultPlan::seeded(0x1234, 0.05).with_partition(vec![0], vec![1], 1.0, 2.0);
    let clean = LinkFate::Delivered { extra_delay: 0.0, duplicate: false };
    assert_eq!(cut.link_fate(0, 1, 0, 1.5), LinkFate::Lost);
    assert_eq!(cut.link_fate(1, 0, 0, 1.5), LinkFate::Lost);
    assert_eq!(cut.link_fate(0, 1, 0, 2.5), clean);
    assert_eq!(cut.link_fate(2, 3, 0, 1.5), clean);
}

// ---------------------------------------------------------------------------
// Dead peer => typed error + eviction, no hang (both backends).
// ---------------------------------------------------------------------------

/// A rank crashes mid-run; its ring neighbors must convert the would-be
/// infinite receive into a typed `PeerDown`, evict the corpse, and keep
/// contracting over the survivor path graph. The crashed rank itself gets
/// a typed `SelfCrash` unwind. The test *completing* is the regression
/// gate for `Mailbox::recv_match` blocking forever on a dead sender under
/// `ExecMode::Threads`.
fn crash_evicts_and_completes(mode: ExecMode) {
    const N: usize = 6;
    const CRASH: usize = 2;
    const ROUNDS: usize = 30;
    const ROUND_COMPUTE: f64 = 200e-6;
    const CRASH_AT: f64 = 2.5e-3;
    let plan = FaultPlan::seeded(0xFA17, 1e-3).with_crash(CRASH, CRASH_AT);
    let results = run_spmd(ring_cfg(N, mode, plan), move |ctx| {
        let mut x = vec![ctx.rank() as f32; 2];
        if ctx.rank() == CRASH {
            // No pre-check: drive straight into the typed SelfCrash error.
            let mut unwound = String::new();
            for _ in 0..ROUNDS {
                ctx.simulate_compute(ROUND_COMPUTE);
                match ctx.neighbor_allreduce(&x) {
                    Ok(y) => x = y,
                    Err(e) => {
                        unwound = format!("{e:#}");
                        break;
                    }
                }
            }
            anyhow::ensure!(
                unwound.contains("crashed at its scheduled vtime"),
                "crashed rank unwound with the wrong error: {unwound:?}"
            );
        } else {
            for _ in 0..ROUNDS {
                ctx.simulate_compute(ROUND_COMPUTE);
                x = ctx.neighbor_allreduce(&x)?;
            }
        }
        Ok((x, ctx.health.is_evicted(CRASH), ctx.vtime()))
    })
    .expect("run must complete despite the crash");

    // The crashed rank stopped near its schedule, far before the full run.
    let (_, _, crash_end) = &results[CRASH];
    assert!(*crash_end < 4e-3, "rank {CRASH} ran to vtime {crash_end} — crash never fired");
    // Its ring neighbors observed PeerDown and evicted it; non-neighbors
    // never exchange with it and keep their original row.
    assert!(results[CRASH - 1].1, "rank {} never evicted the corpse", CRASH - 1);
    assert!(results[CRASH + 1].1, "rank {} never evicted the corpse", CRASH + 1);
    // Survivors keep contracting on the healed path graph.
    let survivors: Vec<usize> = (0..N).filter(|&r| r != CRASH).collect();
    let lo = survivors.iter().map(|&r| results[r].0[0]).fold(f32::INFINITY, f32::min);
    let hi = survivors.iter().map(|&r| results[r].0[0]).fold(f32::NEG_INFINITY, f32::max);
    let initial_spread = (N - 1) as f32; // ranks 0..N-1 minus the corpse
    assert!(
        hi - lo < 0.5 * initial_spread,
        "survivor consensus failed to contract: spread {} (initial {initial_spread})",
        hi - lo
    );
}

#[test]
fn crash_evicts_and_completes_threads() {
    crash_evicts_and_completes(ExecMode::Threads);
}

#[test]
fn crash_evicts_and_completes_event_loop() {
    crash_evicts_and_completes(ExecMode::EventLoop);
}

// ---------------------------------------------------------------------------
// Cross-backend fault-schedule reproducibility.
// ---------------------------------------------------------------------------

/// Identical drop/delay/duplication plans must produce bitwise-identical
/// iterates, virtual clocks, and fault-event counts under Threads and
/// EventLoop: without partitions every fate is vtime-independent (pinned
/// above), and deadline expiries land both backends on the same instant.
/// Partition fates are deliberately excluded — they depend on attempt
/// times, which legitimately shift once an expiry re-times later sends.
#[test]
fn fault_schedule_reproducible_across_exec_modes() {
    const N: usize = 6;
    const ROUNDS: usize = 12;
    let make_plan = |seed: u64| {
        FaultPlan::seeded(seed, 0.05)
            .with_drop(0.1, 3, 5e-5)
            .with_delay(0.2, 4e-5)
            .with_dup(0.1)
    };
    let run = |mode: ExecMode, seed: u64| {
        let plan = make_plan(seed);
        let stats = plan.stats.clone();
        let results = run_spmd(ring_cfg(N, mode, plan), move |ctx| {
            let mut x = vec![ctx.rank() as f32 - 2.0, (ctx.rank() * ctx.rank()) as f32];
            for _ in 0..ROUNDS {
                ctx.simulate_compute(100e-6);
                x = ctx.neighbor_allreduce(&x)?;
            }
            let bits: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
            Ok((bits, ctx.vtime().to_bits()))
        })
        .expect("faulty consensus run failed");
        (results, stats.snapshot())
    };
    let mut fired = 0u64;
    for seed in [1u64, 2, 3, 0xDEAD, 0xBEEF, 42] {
        let (res_t, stats_t) = run(ExecMode::Threads, seed);
        let (res_e, stats_e) = run(ExecMode::EventLoop, seed);
        assert_eq!(stats_t, stats_e, "seed {seed:#x}: fault-event counts diverged across modes");
        assert_eq!(res_t, res_e, "seed {seed:#x}: iterates/vtimes diverged across modes");
        let (lost, retried, delayed, duplicated, _) = stats_t;
        fired += lost + retried + delayed + duplicated;
    }
    assert!(fired > 0, "fault plans were active but no drop/delay/dup ever fired");
}

// ---------------------------------------------------------------------------
// Push-sum under randomized crash schedules.
// ---------------------------------------------------------------------------

/// Async push-sum with zero gradients under a randomized crash schedule:
/// because every wire message carries `[u; v]` jointly and the healing
/// redirect re-splits column-stochastically over survivors, each
/// survivor's debiased iterate `u/v` stays a convex combination of the
/// initial values (the hull never grows) and survivors still reach
/// approximate consensus. Note `Σ v_i = n` does NOT survive a crash —
/// the corpse takes its pending mass down with it; unbiasedness of the
/// ratio is the invariant that remains, and is what we pin here (8
/// randomized schedules).
#[test]
fn push_sum_ratio_stays_in_hull_under_randomized_crashes() {
    const N: usize = 6;
    const D: usize = 2;
    let base = 1e-3;
    let t_end = 0.08;
    for s in 0..8u64 {
        let crash_rank = (s as usize * 5 + 1) % N;
        let crash_at = (0.35 + 0.04 * s as f64) * t_end;
        let plan = FaultPlan::seeded(0x5EED ^ s, 4e-3).with_crash(crash_rank, crash_at);
        let hetero = ComputeHeterogeneity::uniform(N).with_jitter(0.1);
        let cfg = ring_cfg(N, ExecMode::Threads, plan)
            .with_async(AsyncSpec::new(hetero).with_horizon(16.0 * base));
        let results = run_spmd(cfg, move |ctx| {
            let mut x = vec![ctx.rank() as f32; D];
            let zeros = vec![0.0f32; D];
            let mut opt = AsyncPushSumSgd::new(0.0, "chaos");
            for _ in 0..10_000 {
                if ctx.vtime() >= t_end || ctx.crashed_now() {
                    break;
                }
                ctx.async_throttle();
                ctx.simulate_compute_hetero(base);
                let stepped = opt.refresh(ctx, &mut x).and_then(|_| opt.step(ctx, &mut x, &zeros));
                if let Err(e) = stepped {
                    if ctx.crashed_now() {
                        break; // own crash surfaced inside a window op
                    }
                    return Err(e);
                }
            }
            if !ctx.crashed_now() {
                opt.finalize(ctx, &mut x)?;
            }
            Ok((x, opt.push_weight(), ctx.crashed_now()))
        })
        .unwrap_or_else(|e| panic!("schedule {s} (crash rank {crash_rank}) failed: {e:#}"));

        assert!(results[crash_rank].2, "schedule {s}: rank {crash_rank} never saw its crash");
        let survivors: Vec<usize> = (0..N).filter(|&r| r != crash_rank).collect();
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &r in &survivors {
            let (x, v, crashed) = &results[r];
            assert!(!*crashed, "schedule {s}: survivor {r} thinks it crashed");
            assert!(*v > 1e-6, "schedule {s}: survivor {r} push-sum weight collapsed to {v}");
            for &c in x {
                assert!(
                    (-1e-2..=(N as f32 - 1.0) + 1e-2).contains(&c),
                    "schedule {s}: survivor {r} left the initial hull: {c}"
                );
                lo = lo.min(c);
                hi = hi.max(c);
            }
        }
        assert!(
            hi - lo < 0.5,
            "schedule {s}: survivors failed to re-converge (spread {})",
            hi - lo
        );
    }
}
