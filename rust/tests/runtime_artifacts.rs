//! Integration tests over the PJRT runtime: AOT artifacts (L1 Pallas
//! kernels + L2 JAX computations) loaded and executed from Rust, with
//! numerics cross-checked against native implementations.
//!
//! These tests skip when `artifacts/` has not been built (`make artifacts`).

use bluefog::runtime::{DeviceService, InputBuf, Manifest};
use bluefog::rng::Rng;
use bluefog::tensor::{max_abs_diff, weighted_combine};

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/.stamp").exists()
}

fn art(name: &str) -> (String, String) {
    (format!("artifacts/{name}.hlo.txt"), format!("artifacts/{name}.manifest"))
}

#[test]
fn combine_kernel_matches_native_combine() {
    if !artifacts_ready() {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let svc = DeviceService::new();
    let dev = svc.handle();
    let mut rng = Rng::new(42);
    for k in [1usize, 2, 3, 4] {
        let d = 16384;
        let name = format!("combine_k{k}_d{d}");
        let (hlo, _) = art(&name);
        dev.load(&name, &hlo).unwrap();
        let x = rng.normal_vec(d);
        let neighbors: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d)).collect();
        let mut weights = rng.uniform_vec(k + 1, 0.0, 1.0);
        let s: f32 = weights.iter().sum();
        for w in weights.iter_mut() {
            *w /= s;
        }
        let mut nb_flat = Vec::with_capacity(k * d);
        for nb in &neighbors {
            nb_flat.extend_from_slice(nb);
        }
        let outs = dev
            .execute(
                &name,
                vec![
                    InputBuf::F32(x.clone(), vec![d]),
                    InputBuf::F32(nb_flat, vec![k, d]),
                    InputBuf::F32(weights.clone(), vec![k + 1]),
                ],
            )
            .unwrap();
        // Native combine: w[0]*x + sum w[j+1]*nb[j].
        let mut parts: Vec<&[f32]> = vec![&x];
        for nb in &neighbors {
            parts.push(nb);
        }
        let want = weighted_combine(&parts, &weights);
        assert!(
            max_abs_diff(&outs[0], &want) < 1e-4,
            "combine k={k} diverges from native"
        );
    }
}

#[test]
fn fused_sgd_kernel_matches_native() {
    if !artifacts_ready() {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let svc = DeviceService::new();
    let dev = svc.handle();
    let d = 16384;
    let name = format!("fused_sgd_d{d}");
    let (hlo, _) = art(&name);
    dev.load(&name, &hlo).unwrap();
    let mut rng = Rng::new(7);
    let x = rng.normal_vec(d);
    let g = rng.normal_vec(d);
    let m = rng.normal_vec(d);
    let (lr, beta) = (0.1f32, 0.9f32);
    let outs = dev
        .execute(
            &name,
            vec![
                InputBuf::F32(x.clone(), vec![d]),
                InputBuf::F32(g.clone(), vec![d]),
                InputBuf::F32(m.clone(), vec![d]),
                InputBuf::F32(vec![lr, beta], vec![2]),
            ],
        )
        .unwrap();
    let m_new: Vec<f32> = m.iter().zip(&g).map(|(mi, gi)| beta * mi + gi).collect();
    let x_new: Vec<f32> = x.iter().zip(&m_new).map(|(xi, mi)| xi - lr * mi).collect();
    assert!(max_abs_diff(&outs[0], &x_new) < 1e-4, "x update diverges");
    assert!(max_abs_diff(&outs[1], &m_new) < 1e-4, "momentum update diverges");
}

#[test]
fn matmul_kernel_matches_native() {
    if !artifacts_ready() {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let svc = DeviceService::new();
    let dev = svc.handle();
    let (m, k, n) = (256, 256, 256);
    let name = format!("matmul_{m}x{k}x{n}");
    let (hlo, _) = art(&name);
    dev.load(&name, &hlo).unwrap();
    let mut rng = Rng::new(3);
    let a = rng.normal_vec(m * k);
    let b = rng.normal_vec(k * n);
    let outs = dev
        .execute(
            &name,
            vec![InputBuf::F32(a.clone(), vec![m, k]), InputBuf::F32(b.clone(), vec![k, n])],
        )
        .unwrap();
    // Spot-check 50 random entries against a native dot product.
    for _ in 0..50 {
        let i = rng.usize_below(m);
        let j = rng.usize_below(n);
        let want: f32 = (0..k).map(|t| a[i * k + t] * b[t * n + j]).sum();
        let got = outs[0][i * n + j];
        assert!(
            (got - want).abs() < 1e-2 * want.abs().max(1.0),
            "matmul[{i},{j}] = {got}, want {want}"
        );
    }
}

#[test]
fn linreg_grad_matches_closed_form() {
    if !artifacts_ready() {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let svc = DeviceService::new();
    let dev = svc.handle();
    let (hlo, man) = art("linreg_grad");
    let manifest = Manifest::load(&man).unwrap();
    let m = manifest.inputs[0].dims[0];
    let d = manifest.inputs[0].dims[1];
    dev.load("linreg_grad", &hlo).unwrap();
    let mut rng = Rng::new(11);
    let a = rng.normal_vec(m * d);
    let x = rng.normal_vec(d);
    let b = rng.normal_vec(m);
    let outs = dev
        .execute(
            "linreg_grad",
            vec![
                InputBuf::F32(a.clone(), vec![m, d]),
                InputBuf::F32(x.clone(), vec![d]),
                InputBuf::F32(b.clone(), vec![m]),
            ],
        )
        .unwrap();
    // grad = A^T (A x - b) / m
    let mut r = vec![0.0f32; m];
    for row in 0..m {
        let mut dot = 0.0;
        for c in 0..d {
            dot += a[row * d + c] * x[c];
        }
        r[row] = dot - b[row];
    }
    let mut want = vec![0.0f32; d];
    for row in 0..m {
        for c in 0..d {
            want[c] += a[row * d + c] * r[row] / m as f32;
        }
    }
    assert!(max_abs_diff(&outs[0], &want) < 1e-4);
    assert!(outs[1][0] >= 0.0, "loss must be non-negative");
}

#[test]
fn train_step_loss_finite_and_grads_shaped() {
    if !artifacts_ready() {
        eprintln!("skipped: artifacts not built");
        return;
    }
    let svc = DeviceService::new();
    let dev = svc.handle();
    let (hlo, man) = art("train_step_nano");
    let manifest = Manifest::load(&man).unwrap();
    dev.load("train_step_nano", &hlo).unwrap();
    let layout = bluefog::training::ParamLayout::from_manifest(&manifest);
    let params = layout.init(1);
    let batch = manifest.meta_usize("batch").unwrap();
    let seq = manifest.meta_usize("seq").unwrap();
    let vocab = manifest.meta_usize("vocab").unwrap();
    let mut rng = Rng::new(5);
    let tokens: Vec<i32> = (0..batch * seq).map(|_| rng.usize_below(vocab) as i32).collect();
    let targets: Vec<i32> = (0..batch * seq).map(|_| rng.usize_below(vocab) as i32).collect();
    let mut inputs = layout.to_inputs(&params);
    inputs.push(InputBuf::I32(tokens, vec![batch, seq]));
    inputs.push(InputBuf::I32(targets, vec![batch, seq]));
    let outs = dev.execute("train_step_nano", inputs).unwrap();
    assert_eq!(outs.len(), 1 + layout.specs().len());
    let loss = outs[0][0];
    assert!(loss.is_finite() && loss > 0.0, "bad loss {loss}");
    // Random targets: loss should be near log(vocab).
    assert!((loss - (vocab as f32).ln()).abs() < 1.5, "loss {loss} vs ln(V)");
    let grads = layout.flatten_grads(&outs[1..]).unwrap();
    assert_eq!(grads.len(), layout.total());
    assert!(grads.iter().all(|g| g.is_finite()));
}

#[test]
fn pallas_train_step_matches_jnp_train_step() {
    if !artifacts_ready() {
        eprintln!("skipped: artifacts not built");
        return;
    }
    // The same training computation lowered twice — pure-jnp vs with the
    // L1 Pallas matmul kernels inside — must agree through the Rust
    // runtime. This closes the three-layer correctness loop.
    let svc = DeviceService::new();
    let dev = svc.handle();
    let (hlo_a, man) = art("train_step_nano");
    let (hlo_b, _) = art("train_step_nano_pallas");
    let manifest = Manifest::load(&man).unwrap();
    dev.load("a", &hlo_a).unwrap();
    dev.load("b", &hlo_b).unwrap();
    let layout = bluefog::training::ParamLayout::from_manifest(&manifest);
    let params = layout.init(2);
    let batch = manifest.meta_usize("batch").unwrap();
    let seq = manifest.meta_usize("seq").unwrap();
    let mut rng = Rng::new(9);
    let tokens: Vec<i32> = (0..batch * seq).map(|_| rng.usize_below(96) as i32).collect();
    let targets: Vec<i32> = (0..batch * seq).map(|_| rng.usize_below(96) as i32).collect();
    let mut inputs = layout.to_inputs(&params);
    inputs.push(InputBuf::I32(tokens, vec![batch, seq]));
    inputs.push(InputBuf::I32(targets, vec![batch, seq]));
    let outs_a = dev.execute("a", inputs.clone()).unwrap();
    let outs_b = dev.execute("b", inputs).unwrap();
    assert!(
        (outs_a[0][0] - outs_b[0][0]).abs() < 1e-3,
        "loss: jnp {} vs pallas {}",
        outs_a[0][0],
        outs_b[0][0]
    );
    let ga = layout.flatten_grads(&outs_a[1..]).unwrap();
    let gb = layout.flatten_grads(&outs_b[1..]).unwrap();
    assert!(max_abs_diff(&ga, &gb) < 5e-3, "gradients diverge between jnp and pallas paths");
}

#[test]
fn runtime_errors_are_reported_not_panicked() {
    let svc = DeviceService::new();
    let dev = svc.handle();
    assert!(dev.load("missing", "artifacts/does_not_exist.hlo.txt").is_err());
    assert!(dev.execute("never_loaded", vec![]).is_err());
}
