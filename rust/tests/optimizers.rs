//! Integration tests: decentralized optimizers converge to the right fixed
//! points on analytically solvable problems.

use std::sync::Arc;

use bluefog::collective::AllreduceAlgo;
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::reference::{
    RefDgd, RefDmSgd, RefExactDiffusion, RefGradientTracking, RefPeriodicGlobalAveraging,
    RefPushSumGradientTracking,
};
use bluefog::optim::{
    make_optimizer, CommSpec, DecentralizedAdmm, DecentralizedOptimizer, Dgd, DmSgd,
    ExactDiffusion, GradientTracking, LocalUpdateSgd, MomentumKind, ParallelMomentumSgd,
    PeriodicGlobalAveraging, ProxKind, PushSumGradientTracking, StepOrder,
};
use bluefog::topology::builders;
use bluefog::topology::dynamic::{OnePeerExpo, OnePeerFromGraph};

const N: usize = 8;

/// Quadratic f_i(x) = 0.5 ||x - c_i||^2; optimum = mean(c_i). Runs the
/// optimizer and returns the worst-node distance to the optimum.
fn solve(
    make_opt: impl Fn(usize) -> Box<dyn DecentralizedOptimizer> + Send + Sync + 'static,
    topo_name: &str,
    iters: usize,
) -> f64 {
    let (graph, weights) = builders::by_name(topo_name, N).unwrap();
    let results = run_spmd(
        SpmdConfig::new(N).with_topology(graph, weights),
        move |ctx| {
            let d = 4;
            let c: Vec<f32> = (0..d).map(|j| (ctx.rank() * d + j) as f32).collect();
            let mut x = vec![0.0f32; d];
            let mut opt = make_opt(ctx.size());
            for _ in 0..iters {
                let grad: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| xi - ci).collect();
                opt.step(ctx, &mut x, &grad)?;
            }
            Ok(x)
        },
    )
    .unwrap();
    let d = 4;
    let want: Vec<f64> =
        (0..d).map(|j| (0..N).map(|r| (r * d + j) as f64).sum::<f64>() / N as f64).collect();
    results
        .iter()
        .map(|x| {
            x.iter()
                .zip(&want)
                .map(|(xi, wi)| (*xi as f64 - wi).powi(2))
                .sum::<f64>()
                .sqrt()
        })
        .fold(0.0, f64::max)
}

#[test]
fn gradient_tracking_is_exact() {
    let err = solve(|_| Box::new(GradientTracking::new(0.1, CommSpec::Static)), "ring", 400);
    assert!(err < 1e-3, "GT should be exact under heterogeneity: {err}");
}

#[test]
fn exact_diffusion_is_exact() {
    let err = solve(|_| Box::new(ExactDiffusion::new(0.1, CommSpec::Static)), "ring", 400);
    // f32 accumulation leaves a small floor; the point is the absence of
    // DGD's O(gamma) bias (~1e-1 at this step size).
    assert!(err < 1e-2, "ED should remove the DGD bias: {err}");
}

#[test]
fn dgd_has_bias_that_shrinks_with_stepsize() {
    let big = solve(|_| Box::new(Dgd::new(0.2, StepOrder::Atc, CommSpec::Static)), "ring", 600);
    let small = solve(|_| Box::new(Dgd::new(0.02, StepOrder::Atc, CommSpec::Static)), "ring", 4000);
    assert!(big > 1e-2, "DGD at large step should show its bias: {big}");
    assert!(small < big * 0.5, "bias must shrink with the step size: {big} -> {small}");
}

#[test]
fn corrected_methods_beat_dgd() {
    let dgd = solve(|_| Box::new(Dgd::new(0.1, StepOrder::Atc, CommSpec::Static)), "ring", 400);
    let ed = solve(|_| Box::new(ExactDiffusion::new(0.1, CommSpec::Static)), "ring", 400);
    let gt = solve(|_| Box::new(GradientTracking::new(0.1, CommSpec::Static)), "ring", 400);
    assert!(ed < dgd && gt < dgd, "ED {ed} / GT {gt} should beat DGD {dgd}");
}

#[test]
fn dgd_over_dynamic_topology_converges() {
    let err = solve(
        |n| {
            Box::new(Dgd::new(
                0.01,
                StepOrder::Atc,
                CommSpec::Dynamic(Arc::new(OnePeerExpo::new(n))),
            ))
        },
        "expo2",
        4000,
    );
    // One-peer rounds mix slower than the full graph, so DGD's bias floor
    // is larger; at gamma = 0.01 it sits well below 1.
    assert!(err < 0.6, "dynamic one-peer DGD should converge near optimum: {err}");
}

#[test]
fn push_sum_gradient_tracking_over_time_varying_digraph() {
    let err = solve(
        |n| {
            let base = builders::mesh_grid_2d(n);
            Box::new(PushSumGradientTracking::new(0.05, Arc::new(OnePeerFromGraph::new(&base))))
        },
        "mesh",
        800,
    );
    assert!(err < 1e-2, "push-sum GT should be exact over dynamic topology: {err}");
}

#[test]
fn momentum_variants_converge() {
    for kind in [MomentumKind::Vanilla, MomentumKind::Synced, MomentumKind::QuasiGlobal] {
        // Momentum amplifies DGD's bias by ~1/(1-beta); keep the effective
        // step small for a tight fixed point.
        let err = solve(
            move |_| Box::new(DmSgd::new(0.01, 0.5, kind, StepOrder::Atc, CommSpec::Static)),
            "expo2",
            2000,
        );
        assert!(err < 0.5, "{kind:?} failed to converge: {err}");
    }
}

#[test]
fn periodic_global_averaging_tightens_consensus() {
    let plain = solve(
        |_| Box::new(Dgd::new(0.1, StepOrder::Atc, CommSpec::Static)),
        "ring",
        300,
    );
    let periodic = solve(
        |_| {
            Box::new(PeriodicGlobalAveraging::new(
                Dgd::new(0.1, StepOrder::Atc, CommSpec::Static),
                10,
                AllreduceAlgo::Ring,
            ))
        },
        "ring",
        300,
    );
    assert!(
        periodic < plain,
        "periodic global averaging should reduce the bias: {plain} -> {periodic}"
    );
}

#[test]
fn parallel_sgd_baseline_is_exact() {
    let err = solve(|_| Box::new(ParallelMomentumSgd::new(0.1, 0.5, AllreduceAlgo::Ring)), "full", 300);
    assert!(err < 1e-3, "parallel SGD is centralized and must be exact: {err}");
}

#[test]
fn factory_rejects_unknown_and_builds_known() {
    assert!(make_optimizer("nope", 0.1, 0.9, CommSpec::Static).is_err());
    for algo in ["atc", "awc", "dmsgd", "dmsgd-vanilla", "qg-dmsgd", "ed", "gt", "psgd"] {
        let opt = make_optimizer(algo, 0.1, 0.9, CommSpec::Static).unwrap();
        assert!(!opt.name().is_empty());
    }
}

/// Runs the heterogeneous quadratic of [`solve`] and records the full
/// bit pattern of every rank's iterate after every step — the equality
/// oracle for the pipeline-vs-frozen-reference parity tests.
fn trace(
    make_opt: impl Fn(usize) -> Box<dyn DecentralizedOptimizer> + Send + Sync + 'static,
    topo_name: &str,
    iters: usize,
) -> Vec<Vec<u32>> {
    let (graph, weights) = builders::by_name(topo_name, N).unwrap();
    run_spmd(SpmdConfig::new(N).with_topology(graph, weights), move |ctx| {
        let d = 4;
        let c: Vec<f32> = (0..d).map(|j| (ctx.rank() * d + j) as f32).collect();
        let mut x = vec![0.0f32; d];
        let mut opt = make_opt(ctx.size());
        let mut bits = Vec::with_capacity(iters * d);
        for _ in 0..iters {
            let grad: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| xi - ci).collect();
            opt.step(ctx, &mut x, &grad)?;
            bits.extend(x.iter().map(|v| v.to_bits()));
        }
        Ok(bits)
    })
    .unwrap()
}

#[test]
fn pipeline_matches_frozen_references_bitwise() {
    const ITERS: usize = 60;
    type Maker = Box<dyn Fn(usize) -> Box<dyn DecentralizedOptimizer> + Send + Sync>;
    let mut cases: Vec<(&str, &str, Maker, Maker)> = vec![
        (
            "dgd-atc",
            "ring",
            Box::new(|_| Box::new(Dgd::new(0.1, StepOrder::Atc, CommSpec::Static))),
            Box::new(|_| Box::new(RefDgd::new(0.1, StepOrder::Atc, CommSpec::Static))),
        ),
        (
            "dgd-awc",
            "ring",
            Box::new(|_| Box::new(Dgd::new(0.1, StepOrder::Awc, CommSpec::Static))),
            Box::new(|_| Box::new(RefDgd::new(0.1, StepOrder::Awc, CommSpec::Static))),
        ),
        (
            "dgd-dynamic",
            "expo2",
            Box::new(|n| {
                Box::new(Dgd::new(
                    0.05,
                    StepOrder::Atc,
                    CommSpec::Dynamic(Arc::new(OnePeerExpo::new(n))),
                ))
            }),
            Box::new(|n| {
                Box::new(RefDgd::new(
                    0.05,
                    StepOrder::Atc,
                    CommSpec::Dynamic(Arc::new(OnePeerExpo::new(n))),
                ))
            }),
        ),
        (
            "exact-diffusion",
            "ring",
            Box::new(|_| Box::new(ExactDiffusion::new(0.1, CommSpec::Static))),
            Box::new(|_| Box::new(RefExactDiffusion::new(0.1, CommSpec::Static))),
        ),
        (
            "gradient-tracking",
            "ring",
            Box::new(|_| Box::new(GradientTracking::new(0.1, CommSpec::Static))),
            Box::new(|_| Box::new(RefGradientTracking::new(0.1, CommSpec::Static))),
        ),
        (
            "push-sum-gt",
            "mesh",
            Box::new(|n| {
                let base = builders::mesh_grid_2d(n);
                Box::new(PushSumGradientTracking::new(0.05, Arc::new(OnePeerFromGraph::new(&base))))
            }),
            Box::new(|n| {
                let base = builders::mesh_grid_2d(n);
                Box::new(RefPushSumGradientTracking::new(
                    0.05,
                    Arc::new(OnePeerFromGraph::new(&base)),
                ))
            }),
        ),
        (
            "periodic-global",
            "ring",
            Box::new(|_| {
                Box::new(PeriodicGlobalAveraging::new(
                    Dgd::new(0.1, StepOrder::Atc, CommSpec::Static),
                    10,
                    AllreduceAlgo::Ring,
                ))
            }),
            Box::new(|_| {
                Box::new(RefPeriodicGlobalAveraging::new(
                    RefDgd::new(0.1, StepOrder::Atc, CommSpec::Static),
                    10,
                    AllreduceAlgo::Ring,
                ))
            }),
        ),
    ];
    for (label, kind, ord) in [
        ("dmsgd-vanilla-atc", MomentumKind::Vanilla, StepOrder::Atc),
        ("dmsgd-vanilla-awc", MomentumKind::Vanilla, StepOrder::Awc),
        ("dmsgd-synced", MomentumKind::Synced, StepOrder::Atc),
        ("qg-dmsgd", MomentumKind::QuasiGlobal, StepOrder::Atc),
    ] {
        cases.push((
            label,
            "expo2",
            Box::new(move |_| Box::new(DmSgd::new(0.05, 0.9, kind, ord, CommSpec::Static))),
            Box::new(move |_| Box::new(RefDmSgd::new(0.05, 0.9, kind, ord, CommSpec::Static))),
        ));
    }
    for (label, topo, new, old) in cases {
        let got = trace(new, topo, ITERS);
        let want = trace(old, topo, ITERS);
        assert_eq!(got, want, "{label}: pipeline diverged bitwise from the frozen reference");
    }
}

#[test]
fn local_update_h1_is_plain_dsgd_bitwise() {
    let h1 = trace(|_| Box::new(LocalUpdateSgd::new(0.1, 1, CommSpec::Static)), "ring", 80);
    let dgd = trace(|_| Box::new(Dgd::new(0.1, StepOrder::Atc, CommSpec::Static)), "ring", 80);
    assert_eq!(h1, dgd, "LocalUpdateSgd(H=1) must be bitwise plain ATC D-SGD");
}

/// ADMM on the ring: returns (distance of the network-mean iterate from
/// the true optimum c_bar, max-node spread around the network mean).
fn admm_ring(alpha: f32, iters: usize) -> (f64, f64) {
    let results = trace(
        move |_| Box::new(DecentralizedAdmm::new(alpha, ProxKind::Quadratic)),
        "ring",
        iters,
    );
    let d = 4;
    // Final iterate of each rank = the last d bit patterns of its trace.
    let finals: Vec<Vec<f64>> = results
        .iter()
        .map(|bits| {
            bits[bits.len() - d..].iter().map(|&b| f32::from_bits(b) as f64).collect()
        })
        .collect();
    let mean: Vec<f64> =
        (0..d).map(|j| finals.iter().map(|x| x[j]).sum::<f64>() / N as f64).collect();
    let want: Vec<f64> =
        (0..d).map(|j| (0..N).map(|r| (r * d + j) as f64).sum::<f64>() / N as f64).collect();
    let mean_err =
        mean.iter().zip(&want).map(|(m, w)| (m - w).powi(2)).sum::<f64>().sqrt();
    let spread = finals
        .iter()
        .map(|x| x.iter().zip(&mean).map(|(xi, m)| (xi - m).powi(2)).sum::<f64>().sqrt())
        .fold(0.0, f64::max);
    (mean_err, spread)
}

#[test]
fn admm_consensus_on_ring() {
    // Fixed point: the network mean lands on the global optimum, and a
    // larger penalty alpha tightens the consensus spread.
    let (mean_err, _) = admm_ring(2.0, 300);
    assert!(mean_err < 1e-2, "ADMM mean iterate off the optimum: {mean_err}");
    let (_, tight) = admm_ring(4.0, 300);
    let (_, loose) = admm_ring(1.0, 300);
    assert!(
        tight < loose,
        "larger alpha must tighten ADMM consensus: alpha=4 {tight} vs alpha=1 {loose}"
    );
}

#[test]
fn awc_and_atc_agree_in_homogeneous_case() {
    // With identical data everywhere there is no bias: both orders converge
    // to the same point.
    let run = |order: StepOrder| {
        let (graph, weights) = builders::by_name("expo2", N).unwrap();
        run_spmd(SpmdConfig::new(N).with_topology(graph, weights), move |ctx| {
            let mut x = vec![10.0f32];
            let mut opt = Dgd::new(0.1, order, CommSpec::Static);
            for _ in 0..200 {
                let grad = vec![x[0] - 3.0];
                opt.step(ctx, &mut x, &grad)?;
            }
            Ok(x[0])
        })
        .unwrap()
    };
    for v in run(StepOrder::Atc).iter().chain(run(StepOrder::Awc).iter()) {
        assert!((v - 3.0).abs() < 1e-3, "homogeneous case must be exact: {v}");
    }
}
