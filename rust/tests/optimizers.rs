//! Integration tests: decentralized optimizers converge to the right fixed
//! points on analytically solvable problems.

use std::sync::Arc;

use bluefog::collective::AllreduceAlgo;
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{
    make_optimizer, CommSpec, DecentralizedOptimizer, Dgd, DmSgd, ExactDiffusion,
    GradientTracking, MomentumKind, ParallelMomentumSgd, PeriodicGlobalAveraging,
    PushSumGradientTracking, StepOrder,
};
use bluefog::topology::builders;
use bluefog::topology::dynamic::{OnePeerExpo, OnePeerFromGraph};

const N: usize = 8;

/// Quadratic f_i(x) = 0.5 ||x - c_i||^2; optimum = mean(c_i). Runs the
/// optimizer and returns the worst-node distance to the optimum.
fn solve(
    make_opt: impl Fn(usize) -> Box<dyn DecentralizedOptimizer> + Send + Sync + 'static,
    topo_name: &str,
    iters: usize,
) -> f64 {
    let (graph, weights) = builders::by_name(topo_name, N).unwrap();
    let results = run_spmd(
        SpmdConfig::new(N).with_topology(graph, weights),
        move |ctx| {
            let d = 4;
            let c: Vec<f32> = (0..d).map(|j| (ctx.rank() * d + j) as f32).collect();
            let mut x = vec![0.0f32; d];
            let mut opt = make_opt(ctx.size());
            for _ in 0..iters {
                let grad: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| xi - ci).collect();
                opt.step(ctx, &mut x, &grad)?;
            }
            Ok(x)
        },
    )
    .unwrap();
    let d = 4;
    let want: Vec<f64> =
        (0..d).map(|j| (0..N).map(|r| (r * d + j) as f64).sum::<f64>() / N as f64).collect();
    results
        .iter()
        .map(|x| {
            x.iter()
                .zip(&want)
                .map(|(xi, wi)| (*xi as f64 - wi).powi(2))
                .sum::<f64>()
                .sqrt()
        })
        .fold(0.0, f64::max)
}

#[test]
fn gradient_tracking_is_exact() {
    let err = solve(|_| Box::new(GradientTracking::new(0.1, CommSpec::Static)), "ring", 400);
    assert!(err < 1e-3, "GT should be exact under heterogeneity: {err}");
}

#[test]
fn exact_diffusion_is_exact() {
    let err = solve(|_| Box::new(ExactDiffusion::new(0.1, CommSpec::Static)), "ring", 400);
    // f32 accumulation leaves a small floor; the point is the absence of
    // DGD's O(gamma) bias (~1e-1 at this step size).
    assert!(err < 1e-2, "ED should remove the DGD bias: {err}");
}

#[test]
fn dgd_has_bias_that_shrinks_with_stepsize() {
    let big = solve(|_| Box::new(Dgd::new(0.2, StepOrder::Atc, CommSpec::Static)), "ring", 600);
    let small = solve(|_| Box::new(Dgd::new(0.02, StepOrder::Atc, CommSpec::Static)), "ring", 4000);
    assert!(big > 1e-2, "DGD at large step should show its bias: {big}");
    assert!(small < big * 0.5, "bias must shrink with the step size: {big} -> {small}");
}

#[test]
fn corrected_methods_beat_dgd() {
    let dgd = solve(|_| Box::new(Dgd::new(0.1, StepOrder::Atc, CommSpec::Static)), "ring", 400);
    let ed = solve(|_| Box::new(ExactDiffusion::new(0.1, CommSpec::Static)), "ring", 400);
    let gt = solve(|_| Box::new(GradientTracking::new(0.1, CommSpec::Static)), "ring", 400);
    assert!(ed < dgd && gt < dgd, "ED {ed} / GT {gt} should beat DGD {dgd}");
}

#[test]
fn dgd_over_dynamic_topology_converges() {
    let err = solve(
        |n| {
            Box::new(Dgd::new(
                0.01,
                StepOrder::Atc,
                CommSpec::Dynamic(Arc::new(OnePeerExpo::new(n))),
            ))
        },
        "expo2",
        4000,
    );
    // One-peer rounds mix slower than the full graph, so DGD's bias floor
    // is larger; at gamma = 0.01 it sits well below 1.
    assert!(err < 0.6, "dynamic one-peer DGD should converge near optimum: {err}");
}

#[test]
fn push_sum_gradient_tracking_over_time_varying_digraph() {
    let err = solve(
        |n| {
            let base = builders::mesh_grid_2d(n);
            Box::new(PushSumGradientTracking::new(0.05, Arc::new(OnePeerFromGraph::new(&base))))
        },
        "mesh",
        800,
    );
    assert!(err < 1e-2, "push-sum GT should be exact over dynamic topology: {err}");
}

#[test]
fn momentum_variants_converge() {
    for kind in [MomentumKind::Vanilla, MomentumKind::Synced, MomentumKind::QuasiGlobal] {
        // Momentum amplifies DGD's bias by ~1/(1-beta); keep the effective
        // step small for a tight fixed point.
        let err = solve(
            move |_| Box::new(DmSgd::new(0.01, 0.5, kind, StepOrder::Atc, CommSpec::Static)),
            "expo2",
            2000,
        );
        assert!(err < 0.5, "{kind:?} failed to converge: {err}");
    }
}

#[test]
fn periodic_global_averaging_tightens_consensus() {
    let plain = solve(
        |_| Box::new(Dgd::new(0.1, StepOrder::Atc, CommSpec::Static)),
        "ring",
        300,
    );
    let periodic = solve(
        |_| {
            Box::new(PeriodicGlobalAveraging::new(
                Dgd::new(0.1, StepOrder::Atc, CommSpec::Static),
                10,
                AllreduceAlgo::Ring,
            ))
        },
        "ring",
        300,
    );
    assert!(
        periodic < plain,
        "periodic global averaging should reduce the bias: {plain} -> {periodic}"
    );
}

#[test]
fn parallel_sgd_baseline_is_exact() {
    let err = solve(|_| Box::new(ParallelMomentumSgd::new(0.1, 0.5, AllreduceAlgo::Ring)), "full", 300);
    assert!(err < 1e-3, "parallel SGD is centralized and must be exact: {err}");
}

#[test]
fn factory_rejects_unknown_and_builds_known() {
    assert!(make_optimizer("nope", 0.1, 0.9, CommSpec::Static).is_err());
    for algo in ["atc", "awc", "dmsgd", "dmsgd-vanilla", "qg-dmsgd", "ed", "gt", "psgd"] {
        let opt = make_optimizer(algo, 0.1, 0.9, CommSpec::Static).unwrap();
        assert!(!opt.name().is_empty());
    }
}

#[test]
fn awc_and_atc_agree_in_homogeneous_case() {
    // With identical data everywhere there is no bias: both orders converge
    // to the same point.
    let run = |order: StepOrder| {
        let (graph, weights) = builders::by_name("expo2", N).unwrap();
        run_spmd(SpmdConfig::new(N).with_topology(graph, weights), move |ctx| {
            let mut x = vec![10.0f32];
            let mut opt = Dgd::new(0.1, order, CommSpec::Static);
            for _ in 0..200 {
                let grad = vec![x[0] - 3.0];
                opt.step(ctx, &mut x, &grad)?;
            }
            Ok(x[0])
        })
        .unwrap()
    };
    for v in run(StepOrder::Atc).iter().chain(run(StepOrder::Awc).iter()) {
        assert!((v - 3.0).abs() < 1e-3, "homogeneous case must be exact: {v}");
    }
}
