//! Compression subsystem integration tests: codec round-trip bounds under
//! randomized inputs, the error-feedback convergence property, and the
//! guarantee that `CompressionSpec::None` leaves the collective stack
//! bit-for-bit identical to the uncompressed (PR 2) path.

use bluefog::compress::{
    decode_into, CompressionSpec, Compressor, EncodeScratch, LowRank, QuantizeU8, RandomK, TopK,
};
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{CommSpec, DecentralizedOptimizer, Dgd, StepOrder};
use bluefog::proptest::Gen;
use bluefog::rng::Rng;
use bluefog::tensor::{max_abs_diff, norm2};

fn roundtrip(comp: &dyn Compressor, data: &[f32], rng: &mut Rng) -> (Vec<f32>, usize) {
    let mut wire = Vec::new();
    comp.encode(data, rng, &mut EncodeScratch::new(), &mut wire);
    let mut out = Vec::new();
    decode_into(&wire, &mut out).expect("decode of fresh encoding");
    assert_eq!(out.len(), data.len(), "{} changed the length", comp.name());
    (out, wire.len())
}

#[test]
fn prop_topk_roundtrip_within_stated_bound() {
    // Top-k's error is the energy of the dropped (smallest) coordinates:
    // ||x - C(x)||^2 <= (1 - k/d) ||x||^2 for every input.
    let mut g = Gen::new(0xbeef_01);
    let mut rng = Rng::new(1);
    for _ in 0..50 {
        let d = g.usize_in(16, 600);
        let k = g.usize_in(1, d + 1);
        let data = g.vec_f32(d, -8.0, 8.0);
        let (out, _) = roundtrip(&TopK { k }, &data, &mut rng);
        let err2: f64 = data.iter().zip(&out).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        let e2: f64 = data.iter().map(|x| (*x as f64).powi(2)).sum();
        let bound = e2 * (d - k.min(d)) as f64 / d as f64;
        assert!(err2 <= bound + 1e-6, "topk err {err2} above bound {bound} (d={d}, k={k})");
    }
}

#[test]
fn prop_randk_roundtrip_within_stated_bound() {
    // Random-k never invents mass: every coordinate is either exact or
    // zeroed, so ||x - C(x)||^2 <= ||x||^2 and exactly k survive (when the
    // sparse layout is smaller than dense).
    let mut g = Gen::new(0xbeef_02);
    let mut rng = Rng::new(2);
    for _ in 0..50 {
        let d = g.usize_in(64, 600);
        let k = g.usize_in(1, d / 4);
        let data = g.vec_f32(d, 1.0, 9.0); // strictly positive => no accidental zeros
        let (out, words) = roundtrip(&RandomK { k }, &data, &mut rng);
        let err2: f64 = data.iter().zip(&out).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        let e2: f64 = data.iter().map(|x| (*x as f64).powi(2)).sum();
        assert!(err2 <= e2, "randk err grew past input energy");
        assert_eq!(words, 3 + 2 * k);
        let kept = out.iter().filter(|y| **y != 0.0).count();
        assert_eq!(kept, k, "random-k must keep exactly k coordinates");
        for (x, y) in data.iter().zip(&out) {
            assert!(*y == 0.0 || y == x, "kept values must be exact");
        }
    }
}

#[test]
fn prop_quantize_roundtrip_within_stated_bound() {
    // Per-coordinate error is at most half a quantization step of its
    // block: (block max - block min) / 510.
    let mut g = Gen::new(0xbeef_03);
    let mut rng = Rng::new(3);
    for _ in 0..40 {
        let d = g.usize_in(64, 800);
        let block = [16usize, 64, 256][g.usize_in(0, 3)];
        let lo = g.f64_in(-100.0, 0.0) as f32;
        let hi = lo + g.f64_in(0.5, 50.0) as f32;
        let data = g.vec_f32(d, lo, hi);
        let (out, _) = roundtrip(&QuantizeU8 { block }, &data, &mut rng);
        let step = ((hi - lo) as f64) / 255.0; // >= any block's step
        assert!(
            max_abs_diff(&data, &out) <= step / 2.0 + 1e-6,
            "quant err {} above half-step {}",
            max_abs_diff(&data, &out),
            step / 2.0
        );
    }
}

#[test]
fn prop_lowrank_projection_contracts() {
    // P Q^T is an orthogonal projection of the matrix view, so the
    // reconstruction error never exceeds the input energy and the output
    // energy never exceeds the input's (up to f32 slack).
    let mut g = Gen::new(0xbeef_04);
    let mut rng = Rng::new(4);
    for _ in 0..30 {
        let d = g.usize_in(100, 900);
        let rank = g.usize_in(1, 4);
        let data = g.vec_f32(d, -3.0, 3.0);
        let (out, _) = roundtrip(&LowRank { rank }, &data, &mut rng);
        let e_in: f64 = data.iter().map(|x| (*x as f64).powi(2)).sum();
        let e_out: f64 = out.iter().map(|x| (*x as f64).powi(2)).sum();
        let err2: f64 = data.iter().zip(&out).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        assert!(e_out <= e_in * 1.01 + 1e-6, "projection expanded energy");
        assert!(err2 <= e_in * 1.01 + 1e-6, "projection error above input energy");
    }
}

#[test]
fn error_feedback_drives_cumulative_residual_to_zero() {
    // On a fixed vector, difference tracking transmits the top-k of the
    // *remaining* residual each round, exactly: the residual ‖v − x̂‖ is
    // non-increasing and reaches exactly zero once every coordinate has
    // been sent (⌈d/k⌉ rounds), after which the stream carries only
    // zero-differences.
    use bluefog::compress::CompressionState;
    let v: Vec<f32> = (0..96).map(|i| ((i * 17) % 31) as f32 / 7.0 - 2.0).collect();
    let mut st = CompressionState::new(CompressionSpec::top_k(6), 0xfeed);
    let mut wire = Vec::new();
    let mut prev = f64::INFINITY;
    for round in 1..=20usize {
        st.encode(7, &v, &mut wire);
        let resid = st.ef().residual_norm_for(7, &v);
        assert!(
            resid <= prev + 1e-9,
            "residual grew at round {round}: {resid} > {prev}"
        );
        prev = resid;
    }
    // 96 / 6 = 16 rounds cover every coordinate; by round 20 the residual
    // must be identically zero (top-k transmits exact coordinate values).
    assert_eq!(prev, 0.0, "cumulative residual did not reach zero");
    // And the decoded cumulative stream equals v exactly: one more encode
    // sends pure zeros.
    let mut out = Vec::new();
    decode_into(&wire, &mut out).unwrap();
    assert!(out.iter().all(|&y| y == 0.0), "steady-state messages must be zero-differences");
}

#[test]
fn spec_none_is_bitwise_identical_to_uncompressed_path() {
    // Same seed, same topology, same data: a run with an explicit
    // CompressionSpec::None must produce byte-identical outputs to the
    // default config (the PR 2 hot path), including over several rounds.
    let run = |spec: Option<CompressionSpec>| -> Vec<Vec<f32>> {
        let mut cfg = SpmdConfig::new(4).with_seed(77);
        if let Some(s) = spec {
            cfg = cfg.with_compression(s);
        }
        run_spmd(cfg, |ctx| {
            let mut x: Vec<f32> =
                (0..257).map(|i| ((i * (ctx.rank() + 3)) % 89) as f32 * 0.25 - 11.0).collect();
            for _ in 0..5 {
                x = ctx.neighbor_allreduce(&x)?;
            }
            Ok(x)
        })
        .unwrap()
    };
    let default = run(None);
    let explicit_none = run(Some(CompressionSpec::none()));
    assert_eq!(default, explicit_none, "explicit None diverged from the default path");
}

#[test]
fn lossless_topk_matches_dense_through_the_collective() {
    // k = d makes the sparse codec exact, so with the consensus step at
    // γ = 1 the corrected compressed combine computes the same average as
    // the dense path — up to float reassociation (the corrected form
    // evaluates x + Σ wx̂ − (1−w)x̂ instead of wx + Σ wx̂), so compare with
    // a tight tolerance rather than bitwise.
    let d = 200;
    let run = |spec: Option<CompressionSpec>| -> Vec<Vec<f32>> {
        let mut cfg = SpmdConfig::new(4).with_seed(31);
        if let Some(s) = spec {
            cfg = cfg.with_compression(s);
        }
        run_spmd(cfg, move |ctx| {
            let mut x: Vec<f32> =
                (0..d).map(|i| ((i * 7 + ctx.rank() * 13) % 97) as f32 - 48.0).collect();
            for _ in 0..3 {
                x = ctx.neighbor_allreduce(&x)?;
            }
            Ok(x)
        })
        .unwrap()
    };
    let dense = run(None);
    let lossless = run(Some(CompressionSpec::top_k(d).with_gossip_gamma(1.0)));
    for (xd, xl) in dense.iter().zip(&lossless) {
        assert!(
            max_abs_diff(xd, xl) < 1e-3,
            "lossless top-k diverged from the dense combine by {}",
            max_abs_diff(xd, xl)
        );
    }
}

#[test]
fn compressed_neighbor_allreduce_preserves_the_global_mean() {
    // Doubly-stochastic weights keep the network mean invariant; with EF
    // the compressed iteration preserves it on average and drifts only by
    // the (bounded) residual scale. Run enough rounds to see consensus.
    let n = 8;
    let d = 128;
    let results = run_spmd(
        SpmdConfig::new(n).with_compression(CompressionSpec::top_k(d / 8)),
        move |ctx| {
            let mut x = vec![ctx.rank() as f32; d];
            for _ in 0..60 {
                x = ctx.neighbor_allreduce(&x)?;
            }
            Ok(x)
        },
    )
    .unwrap();
    let target = (n - 1) as f32 / 2.0; // mean of 0..n
    for (rank, x) in results.iter().enumerate() {
        for v in x {
            assert!(
                (v - target).abs() < 0.35,
                "rank {rank} failed to reach approximate consensus: {v} vs {target}"
            );
        }
    }
    // The *network mean* itself must stay much tighter than per-rank error.
    let mean: f64 = results.iter().flat_map(|x| x.iter()).map(|&v| v as f64).sum::<f64>()
        / (n * d) as f64;
    assert!((mean - target as f64).abs() < 0.1, "network mean drifted: {mean}");
}

#[test]
fn compressed_dgd_tracks_dense_dgd() {
    // A short decentralized least-squares run: compressed (with EF) DGD
    // must land near the dense DGD trajectory's endpoint.
    let n = 4;
    let d = 64;
    let run = |spec: CompressionSpec| -> Vec<Vec<f32>> {
        run_spmd(SpmdConfig::new(n).with_compression(spec), move |ctx| {
            // Shared ground truth + per-node noise: the signal-dominated
            // regime where trajectory tracking is well-conditioned
            // (numerically validated margin ~4x at this tolerance).
            let mut x_star_rng = Rng::new(0x5eed);
            let x_star: Vec<f32> = x_star_rng.normal_vec(d);
            let mut rng = Rng::new(0xda7a + ctx.rank() as u64);
            let rows = 32;
            let a: Vec<f32> = rng.normal_vec(rows * d);
            let b: Vec<f32> = (0..rows)
                .map(|r| {
                    let dot: f32 =
                        a[r * d..(r + 1) * d].iter().zip(&x_star).map(|(ac, xc)| ac * xc).sum();
                    dot + 0.5 * rng.normal() as f32
                })
                .collect();
            let mut x = vec![0.0f32; d];
            let mut opt = Dgd::new(0.05, StepOrder::Atc, CommSpec::Static);
            let mut grad = vec![0.0f32; d];
            for _ in 0..300 {
                for g in grad.iter_mut() {
                    *g = 0.0;
                }
                for r in 0..rows {
                    let row = &a[r * d..(r + 1) * d];
                    let mut dot = 0.0f32;
                    for (ac, xc) in row.iter().zip(&x) {
                        dot += ac * xc;
                    }
                    let scale = (dot - b[r]) / rows as f32;
                    for (g, ac) in grad.iter_mut().zip(row) {
                        *g += scale * ac;
                    }
                }
                opt.step(ctx, &mut x, &grad)?;
            }
            Ok(x)
        })
        .unwrap()
    };
    let dense = run(CompressionSpec::none());
    let compressed = run(CompressionSpec::top_k(d / 4));
    for (xd, xc) in dense.iter().zip(&compressed) {
        let diff = norm2(&xd.iter().zip(xc).map(|(a, b)| a - b).collect::<Vec<f32>>());
        let scale = norm2(xd).max(1e-9);
        assert!(
            diff / scale < 0.15,
            "compressed DGD drifted {:.1}% from dense",
            100.0 * diff / scale
        );
    }
}

#[test]
fn compressed_nonblocking_fused_rounds_converge() {
    // The comm-thread path: several small non-blocking neighbor allreduces
    // per round get fused into one pack, which is encoded as a single wire
    // stream. Average-of-constants must still reach consensus.
    let n = 4;
    let results = run_spmd(
        SpmdConfig::new(n)
            .with_compression(CompressionSpec::quantize_u8(64))
            .with_fusion_threshold(1 << 20),
        move |ctx| {
            let mut parts: Vec<Vec<f32>> = (0..3)
                .map(|j| vec![(ctx.rank() * 3 + j) as f32; 100 + j * 40])
                .collect();
            for _ in 0..40 {
                let handles: Vec<_> = parts
                    .iter()
                    .map(|p| ctx.neighbor_allreduce_nonblocking(p, None))
                    .collect::<anyhow::Result<Vec<_>>>()?;
                for (slot, h) in handles.into_iter().enumerate() {
                    parts[slot] = h.wait(ctx)?;
                }
            }
            Ok(parts)
        },
    )
    .unwrap();
    for j in 0..3usize {
        // Mean over ranks of (rank*3 + j) for rank in 0..4 = 4.5 + j.
        let target = 4.5 + j as f32;
        for (rank, parts) in results.iter().enumerate() {
            for v in &parts[j] {
                assert!(
                    (v - target).abs() < 0.6,
                    "rank {rank} slot {j}: {v} not near {target}"
                );
            }
        }
    }
}

#[test]
fn wire_bytes_shrink_through_the_full_stack() {
    // End-to-end byte accounting: the same program under TopK(k=d/16) must
    // put at least 4x fewer bytes on the wire than dense.
    let d = 1024;
    let bytes = |spec: CompressionSpec| -> u64 {
        run_spmd(SpmdConfig::new(4).with_compression(spec), move |ctx| {
            let x = vec![1.0f32; d];
            ctx.reset_bytes_sent();
            let mut y = ctx.neighbor_allreduce(&x)?;
            for _ in 0..9 {
                y = ctx.neighbor_allreduce(&y)?;
            }
            let _ = y;
            Ok(ctx.bytes_sent())
        })
        .unwrap()
        .iter()
        .sum()
    };
    let dense = bytes(CompressionSpec::none());
    let topk = bytes(CompressionSpec::top_k(d / 16));
    assert!(
        dense as f64 / topk as f64 >= 4.0,
        "wire reduction {:.2}x below 4x (dense {dense} vs topk {topk})",
        dense as f64 / topk as f64
    );
}
