//! Integration tests: collective communication correctness over the full
//! launcher + transport + negotiation stack.

use bluefog::collective::neighbor::NeighborWeights;
use bluefog::collective::{AllreduceAlgo, ReduceOp};
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::tensor::max_abs_diff;
use bluefog::topology::dynamic::{DynamicTopology, OnePeerExpo};
use bluefog::topology::{builders, WeightMatrix};

/// All three allreduce algorithms must produce the exact same average.
#[test]
fn allreduce_algorithms_agree() {
    for algo in [AllreduceAlgo::Ring, AllreduceAlgo::ParameterServer, AllreduceAlgo::BytePs] {
        let n = 6;
        let results = run_spmd(SpmdConfig::new(n), move |ctx| {
            let data: Vec<f32> = (0..40).map(|i| (ctx.rank() * 40 + i) as f32).collect();
            ctx.allreduce(&data, ReduceOp::Average, algo)
        })
        .unwrap();
        // want[i] = mean_r (r*40 + i)
        let want: Vec<f32> =
            (0..40).map(|i| (0..n).map(|r| (r * 40 + i) as f32).sum::<f32>() / n as f32).collect();
        for (rank, got) in results.iter().enumerate() {
            assert!(
                max_abs_diff(got, &want) < 1e-4,
                "{algo:?} rank {rank}: {got:?} != {want:?}"
            );
        }
    }
}

/// Sum mode scales by n relative to average mode.
#[test]
fn allreduce_sum_vs_average() {
    let results = run_spmd(SpmdConfig::new(4), |ctx| {
        let data = vec![ctx.rank() as f32 + 1.0];
        let sum = ctx.allreduce(&data, ReduceOp::Sum, AllreduceAlgo::Ring)?;
        let avg = ctx.allreduce(&data, ReduceOp::Average, AllreduceAlgo::Ring)?;
        Ok((sum[0], avg[0]))
    })
    .unwrap();
    for (s, a) in results {
        assert!((s - 10.0).abs() < 1e-5);
        assert!((a - 2.5).abs() < 1e-5);
    }
}

/// Static neighbor_allreduce must equal the dense `W x` product.
#[test]
fn neighbor_allreduce_matches_weight_matrix() {
    for topo_name in ["ring", "star", "mesh", "expo2", "full"] {
        let n = 9;
        let (graph, weights) = builders::by_name(topo_name, n).unwrap();
        let w2 = weights.clone();
        let results = run_spmd(
            SpmdConfig::new(n).with_topology(graph, weights),
            |ctx| {
                let x = vec![(ctx.rank() as f32 + 1.0).powi(2); 3];
                ctx.neighbor_allreduce(&x)
            },
        )
        .unwrap();
        let x: Vec<f64> = (0..n).map(|r| ((r as f64) + 1.0).powi(2)).collect();
        let want = w2.apply(&x);
        for (rank, got) in results.iter().enumerate() {
            assert!(
                (got[0] as f64 - want[rank]).abs() < 1e-4,
                "{topo_name} rank {rank}: {} != {}",
                got[0],
                want[rank]
            );
        }
    }
}

/// Dynamic neighbor_allreduce over the one-peer graph: each round realizes
/// the round's doubly-stochastic matrix, so the global mean is invariant.
#[test]
fn dynamic_one_peer_preserves_mean() {
    let n = 8;
    let results = run_spmd(SpmdConfig::new(n), move |ctx| {
        let topo = OnePeerExpo::new(ctx.size());
        let mut x = vec![ctx.rank() as f32 * 10.0];
        for k in 0..12 {
            let view = topo.view(k, ctx.rank());
            let w = NeighborWeights::push_pull(
                view.self_weight,
                view.src_weights.clone(),
                view.dst_weights.iter().map(|&(d, _)| (d, 1.0)).collect(),
            );
            x = ctx.neighbor_allreduce_dynamic(&x, &w)?;
        }
        Ok(x[0])
    })
    .unwrap();
    let mean: f32 = (0..n).map(|r| r as f32 * 10.0).sum::<f32>() / n as f32;
    let total: f32 = results.iter().sum();
    assert!((total / n as f32 - mean).abs() < 1e-3, "mean drifted: {results:?}");
    // And after period * several rounds, values should be well mixed.
    for v in &results {
        assert!((v - mean).abs() < 2.0, "poor mixing: {results:?}");
    }
}

/// Pure push-style declaration: receivers resolved by the negotiation
/// service (footnote-2 configuration 2).
#[test]
fn push_style_resolution_roundtrip() {
    let n = 4;
    let results = run_spmd(SpmdConfig::new(n), move |ctx| {
        // Everyone pushes half its value to rank (r+1) % n.
        let dst = (ctx.rank() + 1) % ctx.size();
        let w = NeighborWeights::push(0.5, vec![(dst, 0.5)]);
        let x = vec![(ctx.rank() + 1) as f32 * 4.0];
        ctx.neighbor_allreduce_dynamic(&x, &w)
    })
    .unwrap();
    // out[i] = 0.5 * x[i] + 0.5 * x[i-1]
    for (i, got) in results.iter().enumerate() {
        let prev = (i + n - 1) % n;
        let want = 0.5 * (i + 1) as f32 * 4.0 + 0.5 * (prev + 1) as f32 * 4.0;
        assert!((got[0] - want).abs() < 1e-5, "rank {i}: {} != {want}", got[0]);
    }
}

/// The paper's hang scenario: rank 0 declares a push that rank 1's
/// declaration contradicts — must error, not hang.
#[test]
fn topology_mismatch_errors_instead_of_hanging() {
    let result = run_spmd(SpmdConfig::new(2), |ctx| {
        if ctx.rank() == 0 {
            // Declares: I push to 1, receive from 1.
            let w = NeighborWeights::push_pull(0.5, vec![(1, 0.5)], vec![(1, 0.5)]);
            ctx.neighbor_allreduce_dynamic(&[1.0], &w)
        } else {
            // Declares: I receive from nobody (contradiction).
            let w = NeighborWeights::push_pull(0.5, vec![], vec![(0, 0.5)]);
            ctx.neighbor_allreduce_dynamic(&[1.0], &w)
        }
    });
    let err = result.expect_err("mismatch must be detected");
    assert!(format!("{err:#}").contains("topology mismatch"), "{err:#}");
}

/// Broadcast from every root delivers identical data everywhere.
#[test]
fn broadcast_from_all_roots() {
    for root in 0..5 {
        let results = run_spmd(SpmdConfig::new(5), move |ctx| {
            let mut data = if ctx.rank() == root {
                vec![root as f32, 42.0, -1.0]
            } else {
                vec![0.0; 3]
            };
            ctx.broadcast(&mut data, root)?;
            Ok(data)
        })
        .unwrap();
        for got in &results {
            assert_eq!(got, &vec![root as f32, 42.0, -1.0]);
        }
    }
}

/// neighbor_allgather returns each in-neighbor's tensor unscaled.
#[test]
fn neighbor_allgather_collects_neighbors() {
    let n = 6;
    let (graph, weights) = builders::by_name("ring", n).unwrap();
    let g2 = graph.clone();
    let results = run_spmd(
        SpmdConfig::new(n).with_topology(graph, weights),
        |ctx| {
            let x = vec![ctx.rank() as f32; 2];
            ctx.neighbor_allgather(&x)
        },
    )
    .unwrap();
    for (rank, got) in results.iter().enumerate() {
        let expected = g2.in_neighbors(rank);
        let got_srcs: Vec<usize> = got.iter().map(|(s, _)| *s).collect();
        assert_eq!(got_srcs, expected, "rank {rank}");
        for (src, data) in got {
            assert_eq!(data, &vec![*src as f32; 2]);
        }
    }
}

/// Hierarchical neighbor allreduce preserves the global mean (each stage is
/// an average) and brings machines toward consensus.
#[test]
fn hierarchical_preserves_mean() {
    let n = 8; // 2 machines x 4 ranks
    let results = run_spmd(
        SpmdConfig::new(n).with_net(bluefog::simnet::NetworkModel::aws_p3(4)),
        |ctx| {
            let mut x = vec![ctx.rank() as f32];
            for _ in 0..6 {
                x = ctx.hierarchical_neighbor_allreduce(&x)?;
            }
            Ok(x[0])
        },
    )
    .unwrap();
    let mean = 3.5f32;
    let total: f32 = results.iter().sum();
    assert!((total / n as f32 - mean).abs() < 1e-4, "mean drifted: {results:?}");
    for v in &results {
        assert!((v - mean).abs() < 0.01, "no consensus: {results:?}");
    }
    // All ranks within the same machine must agree exactly.
    assert!((results[0] - results[3]).abs() < 1e-6);
    assert!((results[4] - results[7]).abs() < 1e-6);
}

/// Non-blocking path returns the same numbers as the blocking one.
#[test]
fn nonblocking_matches_blocking() {
    let n = 8;
    let results = run_spmd(SpmdConfig::new(n), |ctx| {
        let x = vec![ctx.rank() as f32 + 0.25; 16];
        let blocking = ctx.neighbor_allreduce(&x)?;
        let handle = ctx.neighbor_allreduce_nonblocking(&x, None)?;
        let nonblocking = handle.wait(ctx)?;
        Ok((blocking, nonblocking))
    })
    .unwrap();
    for (b, nb) in results {
        assert!(max_abs_diff(&b, &nb) < 1e-6, "blocking {b:?} vs nonblocking {nb:?}");
    }
}

/// Fused non-blocking requests return per-request results identical to
/// issuing them unfused.
#[test]
fn fusion_is_transparent() {
    let n = 4;
    let run = |threshold: usize| {
        run_spmd(
            SpmdConfig::new(n).with_fusion_threshold(threshold),
            |ctx| {
                let mut handles = vec![];
                for i in 0..10 {
                    let x = vec![ctx.rank() as f32 + i as f32; 32];
                    handles.push(ctx.neighbor_allreduce_nonblocking(&x, None)?);
                }
                let mut out = vec![];
                for h in handles {
                    out.push(h.wait(ctx)?);
                }
                Ok(out)
            },
        )
        .unwrap()
    };
    let unfused = run(0);
    let fused = run(1 << 20);
    for (rank, (u, f)) in unfused.iter().zip(&fused).enumerate() {
        for (i, (a, b)) in u.iter().zip(f).enumerate() {
            assert!(max_abs_diff(a, b) < 1e-6, "rank {rank} tensor {i} differs");
        }
    }
}

/// Regression: repeated nonblocking+wait rounds under a nonzero fusion
/// threshold (a wait must close the open group so the next round's
/// requests start a fresh one — previously deadlocked).
#[test]
fn nonblocking_rounds_with_fusion_enabled() {
    let results = run_spmd(
        SpmdConfig::new(4).with_fusion_threshold(2 << 20),
        |ctx| {
            let mut x = vec![ctx.rank() as f32; 8];
            for _ in 0..20 {
                let h = ctx.neighbor_allreduce_nonblocking(&x, None)?;
                x = h.wait(ctx)?;
            }
            Ok(x[0])
        },
    )
    .unwrap();
    let mean = 1.5f32;
    for v in &results {
        assert!((v - mean).abs() < 1e-3, "{results:?}");
    }
}

/// Barrier: no node proceeds before all arrive (checked via virtual time:
/// a deliberately slow rank drags everyone's post-barrier clock up).
#[test]
fn barrier_synchronizes_virtual_time() {
    let results = run_spmd(SpmdConfig::new(4), |ctx| {
        if ctx.rank() == 2 {
            ctx.simulate_compute(1.0); // slow rank
        }
        ctx.barrier()?;
        Ok(ctx.vtime())
    })
    .unwrap();
    for (rank, t) in results.iter().enumerate() {
        assert!(*t >= 1.0, "rank {rank} passed the barrier early (vtime {t})");
    }
}

/// Mixed workload with interleaved collectives stays consistent.
#[test]
fn interleaved_collectives_consistent() {
    let n = 4;
    let results = run_spmd(SpmdConfig::new(n), |ctx| {
        let mut x = vec![ctx.rank() as f32; 4];
        for _ in 0..3 {
            x = ctx.neighbor_allreduce(&x)?;
            x = ctx.allreduce(&x, ReduceOp::Average, AllreduceAlgo::Ring)?;
            ctx.barrier()?;
        }
        Ok(x[0])
    })
    .unwrap();
    let mean = 1.5f32;
    for v in &results {
        assert!((v - mean).abs() < 1e-4, "{results:?}");
    }
}

/// Weight matrices with negative entries (allowed by the paper's eq. (8))
/// still combine correctly.
#[test]
fn negative_weights_are_supported() {
    let n = 3;
    let g = builders::fully_connected(n);
    // W = 1.5 I - 0.25 (ones) — rows sum to 1, some entries negative.
    let mut w = WeightMatrix::zeros(n);
    for i in 0..n {
        for j in 0..n {
            w.set(i, j, if i == j { 1.5 } else { -0.25 }); // rows: 1.5 - 2*0.25 = 1
        }
    }
    let w2 = w.clone();
    assert!(w.is_pull(1e-12));
    let results = run_spmd(SpmdConfig::new(n).with_topology(g, w), |ctx| {
        let x = vec![(ctx.rank() as f32 + 1.0) * 2.0];
        ctx.neighbor_allreduce(&x)
    })
    .unwrap();
    let x: Vec<f64> = (0..n).map(|r| (r as f64 + 1.0) * 2.0).collect();
    let want = w2.apply(&x);
    for (rank, got) in results.iter().enumerate() {
        assert!((got[0] as f64 - want[rank]).abs() < 1e-5);
    }
}
