//! Sim/TCP parity and failure-path integration tests (ISSUE 8).
//!
//! The TCP meshes here are formed IN-PROCESS: one thread per rank over
//! real loopback sockets (rank 0 runs the rendezvous, ranks 1..n dial
//! in). The multi-process launch path (`run_tcp_job`) re-executes the
//! current binary, which under the libtest harness never reaches
//! `maybe_run_tcp_worker`; that path is exercised end-to-end by
//! `examples/wallclock_probe.rs` (blocking WALLCLOCK_SMOKE CI step) and
//! by `bfrun --backend tcp`.

use std::time::Duration;

use bluefog::config::PortableWorkload;
use bluefog::launcher::{run_spmd, SpmdConfig};
use bluefog::optim::{CommSpec, DecentralizedOptimizer, Dgd, StepOrder};
use bluefog::simnet::faults::CommError;
use bluefog::topology::builders;
use bluefog::transport::backend::Backend;
use bluefog::transport::portable::{
    local_grad, regression_data, run_sim_fleet, run_workload, KillSpec, RunOutput, RunSpec,
};
use bluefog::transport::tcp::{Rendezvous, TcpBackend};

const N: usize = 4;

fn spec() -> RunSpec {
    RunSpec {
        iters: 30,
        dim: 48,
        rows: 16,
        gamma: 0.05,
        topology: "ring".into(),
        deadline: Some(Duration::from_secs(20)),
        kill: None,
    }
}

/// One rank's life: run the workload, say Goodbye on success (an error
/// exit drops the backend unannounced — the crash model, on purpose).
fn run_rank<B: Backend>(
    mut b: B,
    workload: PortableWorkload,
    spec: &RunSpec,
) -> Result<RunOutput, CommError> {
    let out = run_workload(&mut b, workload, spec);
    if out.is_ok() {
        b.shutdown();
    }
    out
}

/// Real loopback TCP mesh, one thread per rank inside this process.
fn tcp_fleet(workload: PortableWorkload, spec: &RunSpec) -> Vec<Result<RunOutput, CommError>> {
    let rdz = Rendezvous::bind().expect("bind rendezvous listener");
    let port = rdz.port().expect("rendezvous port");
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(N);
        handles.push(
            s.spawn(move || run_rank(rdz.establish(N).expect("rank 0 mesh"), workload, spec)),
        );
        for rank in 1..N {
            handles.push(s.spawn(move || {
                let b = TcpBackend::connect(rank, N, port).expect("worker dial-in");
                run_rank(b, workload, spec)
            }));
        }
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
    })
}

/// Identical parameters (<= 1e-6) and bit-identical payload byte
/// counters across the two backends — the ISSUE 8 acceptance gate.
fn assert_parity(workload: PortableWorkload) {
    let spec = spec();
    let sim: Vec<RunOutput> =
        run_sim_fleet(N, workload, &spec).into_iter().map(|r| r.expect("sim rank")).collect();
    let tcp: Vec<RunOutput> =
        tcp_fleet(workload, &spec).into_iter().map(|r| r.expect("tcp rank")).collect();
    let mut max_delta = 0.0f64;
    for (s, t) in sim.iter().zip(&tcp) {
        assert_eq!(s.x.len(), t.x.len());
        assert_eq!(s.bytes_sent, t.bytes_sent, "payload accounting must not depend on backend");
        for (a, b) in s.x.iter().zip(&t.x) {
            max_delta = max_delta.max((*a as f64 - *b as f64).abs());
        }
    }
    assert!(max_delta <= 1e-6, "sim/tcp parameters diverged by {max_delta:.3e}");
}

#[test]
fn tcp_consensus_matches_sim() {
    assert_parity(PortableWorkload::Consensus);
}

#[test]
fn tcp_dsgd_matches_sim() {
    assert_parity(PortableWorkload::Dsgd);
}

/// The portable DSGD loop and `optim::Dgd` (ATC order) under `run_spmd`
/// are the same algorithm — paper eq. (23) computed two independent
/// ways must land on the same parameters.
#[test]
fn portable_dsgd_matches_run_spmd_dgd() {
    let spec = spec();
    let sim: Vec<Vec<f32>> = run_sim_fleet(N, PortableWorkload::Dsgd, &spec)
        .into_iter()
        .map(|r| r.expect("sim rank").x)
        .collect();

    let (graph, weights) = builders::by_name("ring", N).expect("ring topology");
    let cfg = SpmdConfig::new(N).with_topology(graph, weights).with_topo_check(false);
    let (iters, dim, rows, gamma) = (spec.iters, spec.dim, spec.rows, spec.gamma);
    let spmd: Vec<Vec<f32>> = run_spmd(cfg, move |ctx| {
        let (a, b) = regression_data(ctx.rank(), dim, rows);
        let mut x = vec![0.0f32; dim];
        let mut grad = vec![0.0f32; dim];
        let mut opt = Dgd::new(gamma, StepOrder::Atc, CommSpec::Static);
        for _ in 0..iters {
            local_grad(&a, &b, &x, &mut grad);
            opt.step(ctx, &mut x, &grad)?;
        }
        Ok(x)
    })
    .expect("run_spmd");

    for (rank, (p, q)) in sim.iter().zip(&spmd).enumerate() {
        for (a, b) in p.iter().zip(q) {
            let delta = (*a as f64 - *b as f64).abs();
            assert!(delta <= 1e-6, "rank {rank}: portable vs Dgd delta {delta:.3e}");
        }
    }
}

/// A rank killed mid-run (sockets slammed, no Goodbye) surfaces as a
/// typed error on every rank: `SelfCrash` on the victim, `PeerDown` on
/// all survivors. The test completing at all is the no-hang gate.
#[test]
fn killed_tcp_peer_surfaces_peer_down_without_hang() {
    let mut spec = spec();
    spec.iters = 12;
    spec.deadline = Some(Duration::from_secs(10));
    spec.kill = Some(KillSpec { rank: 2, at_iter: 2 });
    let outs = tcp_fleet(PortableWorkload::Consensus, &spec);
    match &outs[2] {
        Err(CommError::SelfCrash { rank: 2, .. }) => {}
        other => panic!("victim must report SelfCrash, got {other:?}"),
    }
    for (rank, out) in outs.iter().enumerate() {
        if rank == 2 {
            continue;
        }
        match out {
            Err(CommError::PeerDown { .. }) => {}
            other => panic!("rank {rank} must observe PeerDown, got {other:?}"),
        }
    }
}
