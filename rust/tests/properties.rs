//! Property-based tests on coordinator invariants, using the in-repo
//! mini-framework (`bluefog::proptest` — proptest itself is unavailable
//! offline; see DESIGN.md).

use bluefog::collective::neighbor::NeighborWeights;
use bluefog::collective::{AllreduceAlgo, ReduceOp};
use bluefog::fusion::{fusion_groups, FusionBuffer};
use bluefog::launcher::{run_spmd, ExecMode, SpmdConfig};
use bluefog::pool::BufferPool;
use bluefog::prop_assert;
use bluefog::proptest::{check, Gen};
use bluefog::simnet::analytic;
use bluefog::simnet::event::{Event, EventQueue, Grant, WakeKind};
use bluefog::tensor::{
    max_abs_diff, weighted_combine, weighted_combine_blocked, weighted_combine_blocked_into,
    weighted_combine_into, COMBINE_BLOCK,
};
use bluefog::topology::dynamic::{views_consistent, DynamicTopology, OnePeerExpo, OnePeerFromGraph};
use bluefog::topology::WeightMatrix;

/// For any random doubly-stochastic W on a connected graph, repeated
/// partial averaging contracts to the global mean and never changes it
/// (the consensus invariant behind every algorithm in the paper).
#[test]
fn prop_consensus_contracts_under_any_doubly_stochastic_matrix() {
    check("consensus-contraction", 12, |g: &mut Gen| {
        let n = g.usize_in(2, 9);
        let graph = g.connected_graph(n, 0.3);
        let w = WeightMatrix::metropolis_hastings(&graph);
        prop_assert!(w.is_doubly_stochastic(1e-9), "not doubly stochastic");
        let init: Vec<f32> = g.vec_f32(n, -10.0, 10.0);
        let mean: f64 = init.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let init2 = init.clone();
        let iters = 60;
        let results = run_spmd(
            SpmdConfig::new(n).with_topology(graph, w).with_seed(g.usize_in(0, 1 << 30) as u64),
            move |ctx| {
                let mut x = vec![init2[ctx.rank()]];
                for _ in 0..iters {
                    x = ctx.neighbor_allreduce(&x)?;
                }
                Ok(x[0] as f64)
            },
        )
        .map_err(|e| format!("run failed: {e:#}"))?;
        let post_mean: f64 = results.iter().sum::<f64>() / n as f64;
        prop_assert!(
            (post_mean - mean).abs() < 1e-3,
            "mean not preserved: {mean} -> {post_mean}"
        );
        let spread_before: f64 = init
            .iter()
            .map(|&x| (x as f64 - mean).abs())
            .fold(0.0, f64::max);
        let spread_after: f64 =
            results.iter().map(|&x| (x - mean).abs()).fold(0.0, f64::max);
        prop_assert!(
            spread_after <= spread_before * 0.5 + 1e-6,
            "no contraction: {spread_before} -> {spread_after}"
        );
        Ok(())
    });
}

/// Fusion pack/unpack is a lossless round-trip for any tensor collection.
#[test]
fn prop_fusion_roundtrip() {
    check("fusion-roundtrip", 100, |g: &mut Gen| {
        let count = g.usize_in(1, 12);
        let tensors: Vec<Vec<f32>> = (0..count)
            .map(|_| {
                let len = g.usize_in(0, 50);
                g.vec_f32(len, -1e6, 1e6)
            })
            .collect();
        let refs: Vec<&[f32]> = tensors.iter().map(|t| t.as_slice()).collect();
        let buf = FusionBuffer::pack(&refs);
        let out = buf.unpack(buf.data());
        prop_assert!(out == tensors, "round-trip mismatch");
        Ok(())
    });
}

/// The single-pass blocked combine kernels agree with the naive k-pass
/// kernels to 1e-5 for any arity k, any dimension d (straddling the block
/// boundary), any weights — pooling/blocking must be numerically
/// transparent.
#[test]
fn prop_blocked_combine_matches_naive() {
    check("blocked-combine", 60, |g: &mut Gen| {
        let k = g.usize_in(1, 9);
        let d = g.usize_in(1, 2 * COMBINE_BLOCK + 7);
        let parts: Vec<Vec<f32>> = (0..k).map(|_| g.vec_f32(d, -100.0, 100.0)).collect();
        let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
        let ws: Vec<f32> = (0..k).map(|_| g.f64_in(-1.0, 1.0) as f32).collect();
        let naive = weighted_combine(&refs, &ws);
        let blocked = weighted_combine_blocked(&refs, &ws);
        prop_assert!(
            max_abs_diff(&naive, &blocked) < 1e-5,
            "blocked combine diverged (k={k}, d={d})"
        );
        let base = g.vec_f32(d, -100.0, 100.0);
        let w_self = g.f64_in(-1.0, 1.0) as f32;
        let mut a = base.clone();
        let mut b = base;
        weighted_combine_into(&mut a, w_self, &refs, &ws);
        weighted_combine_blocked_into(&mut b, w_self, &refs, &ws);
        prop_assert!(
            max_abs_diff(&a, &b) < 1e-5,
            "blocked combine_into diverged (k={k}, d={d})"
        );
        Ok(())
    });
}

/// Pool checkout/recycle round-trips preserve contents: whatever initial
/// state a checked-out buffer carries, `checkout_copy`/`checkout_scaled`/
/// `checkout` always return exactly the requested values.
#[test]
fn prop_pool_roundtrip_preserves_contents() {
    check("pool-roundtrip", 80, |g: &mut Gen| {
        let pool = BufferPool::new();
        for _ in 0..g.usize_in(1, 6) {
            let len = g.usize_in(0, 600);
            match g.usize_in(0, 3) {
                0 => {
                    let src = g.vec_f32(len, -1e5, 1e5);
                    let buf = pool.checkout_copy(&src);
                    prop_assert!(&*buf == src.as_slice(), "copy corrupted (len={len})");
                    // Detach and hand back explicitly, like optimizers do.
                    pool.recycle_vec(buf.into_vec());
                }
                1 => {
                    let src = g.vec_f32(len, -1e5, 1e5);
                    let s = g.f64_in(-2.0, 2.0) as f32;
                    let buf = pool.checkout_scaled(&src, s);
                    let want: Vec<f32> = src.iter().map(|&x| s * x).collect();
                    prop_assert!(&*buf == want.as_slice(), "scale corrupted (len={len})");
                    // Implicit recycle on drop.
                }
                _ => {
                    let buf = pool.checkout(len);
                    prop_assert!(buf.iter().all(|&x| x == 0.0), "stale data (len={len})");
                }
            }
        }
        Ok(())
    });
}

/// Scatter-free `unpack_into` produces exactly what allocating `unpack`
/// does, for any slot layout and any pre-existing output contents.
#[test]
fn prop_unpack_into_matches_unpack() {
    check("unpack-into", 80, |g: &mut Gen| {
        let count = g.usize_in(1, 10);
        let tensors: Vec<Vec<f32>> = (0..count)
            .map(|_| {
                let len = g.usize_in(0, 40);
                g.vec_f32(len, -1e6, 1e6)
            })
            .collect();
        let refs: Vec<&[f32]> = tensors.iter().map(|t| t.as_slice()).collect();
        let buf = FusionBuffer::pack(&refs);
        let result: Vec<f32> = buf.data().iter().map(|x| x * 1.5 - 2.0).collect();
        let want = buf.unpack(&result);
        // Outputs start with arbitrary stale contents of arbitrary lengths.
        let mut outs: Vec<Vec<f32>> = (0..count)
            .map(|_| {
                let stale_len = g.usize_in(0, 50);
                g.vec_f32(stale_len, -9.0, 9.0)
            })
            .collect();
        buf.unpack_into(&result, &mut outs);
        prop_assert!(outs == want, "unpack_into mismatch");
        Ok(())
    });
}

/// Fusion groups partition the request sequence in order, never exceeding
/// the threshold except for single oversized tensors.
#[test]
fn prop_fusion_groups_partition() {
    check("fusion-groups", 200, |g: &mut Gen| {
        let count = g.usize_in(1, 30);
        let sizes: Vec<usize> = (0..count).map(|_| g.usize_in(1, 4096)).collect();
        let threshold = g.usize_in(0, 8192);
        let groups = fusion_groups(&sizes, threshold);
        // Coverage without gaps or overlaps.
        let mut expected_start = 0;
        for &(lo, hi) in &groups {
            prop_assert!(lo == expected_start, "gap at {lo}");
            prop_assert!(hi > lo, "empty group");
            expected_start = hi;
            if threshold > 0 && hi - lo > 1 {
                let total: usize = sizes[lo..hi].iter().sum();
                prop_assert!(total <= threshold, "group exceeds threshold");
            }
        }
        prop_assert!(expected_start == sizes.len(), "tail not covered");
        Ok(())
    });
}

/// One-peer dynamic views are mutually consistent and mean-preserving at
/// every iteration, for any n.
#[test]
fn prop_one_peer_views_consistent_and_stochastic() {
    check("one-peer-views", 60, |g: &mut Gen| {
        let n = g.usize_in(1, 33);
        let topo = OnePeerExpo::new(n);
        let k = g.usize_in(0, 3 * topo.period().max(1));
        let views: Vec<_> = (0..n).map(|r| topo.view(k, r)).collect();
        prop_assert!(views_consistent(&views), "inconsistent views at iter {k} (n={n})");
        for v in &views {
            let total: f64 = v.self_weight + v.src_weights.iter().map(|(_, w)| w).sum::<f64>();
            prop_assert!((total - 1.0).abs() < 1e-12, "receive weights not stochastic");
        }
        Ok(())
    });
}

/// Same for the round-robin one-peer schedule derived from any random
/// connected undirected base graph.
#[test]
fn prop_one_peer_from_graph_consistent() {
    check("one-peer-from-graph", 40, |g: &mut Gen| {
        let n = g.usize_in(2, 10);
        let base = g.connected_graph(n, 0.3);
        let topo = OnePeerFromGraph::new(&base);
        for k in 0..topo.period() {
            let views: Vec<_> = (0..n).map(|r| topo.view(k, r)).collect();
            prop_assert!(views_consistent(&views), "iter {k} inconsistent");
        }
        Ok(())
    });
}

/// Push-sum over random strongly-connected digraphs with uniform push
/// weights: mass conservation + unbiased consensus.
#[test]
fn prop_push_sum_mass_conservation() {
    check("push-sum-mass", 8, |g: &mut Gen| {
        let n = g.usize_in(2, 8);
        let graph = g.strongly_connected_digraph(n, 0.2);
        let graph2 = graph.clone();
        let w = WeightMatrix::uniform_pull(&graph);
        let init: Vec<f32> = g.vec_f32(n, -5.0, 5.0);
        let true_mean: f64 = init.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let init2 = init.clone();
        let results = run_spmd(
            SpmdConfig::new(n).with_topology(graph, w),
            move |ctx| {
                // Synchronous push-sum via dynamic neighbor_allreduce with
                // column-stochastic sender-side weights on the static graph.
                let outs = graph2.out_neighbors(ctx.rank());
                let share = 1.0 / (outs.len() + 1) as f64;
                let dsts: Vec<(usize, f64)> = outs.iter().map(|&d| (d, share)).collect();
                let srcs: Vec<(usize, f64)> =
                    graph2.in_neighbors(ctx.rank()).into_iter().map(|s| (s, 1.0)).collect();
                let weights = NeighborWeights::push_pull(share, srcs, dsts);
                let mut xp = vec![init2[ctx.rank()], 1.0];
                for _ in 0..120 {
                    xp = ctx.neighbor_allreduce_dynamic(&xp, &weights)?;
                }
                Ok((xp[0] as f64, xp[1] as f64))
            },
        )
        .map_err(|e| format!("run failed: {e:#}"))?;
        let mass: f64 = results.iter().map(|(_, p)| p).sum();
        prop_assert!((mass - n as f64).abs() < 1e-3, "push-sum weight mass leaked: {mass}");
        for (rank, (x, p)) in results.iter().enumerate() {
            prop_assert!(*p > 0.0, "weight collapsed at rank {rank}");
            let est = x / p;
            prop_assert!(
                (est - true_mean).abs() < 1e-2,
                "biased consensus at rank {rank}: {est} vs {true_mean}"
            );
        }
        Ok(())
    });
}

/// Allreduce average equals the arithmetic mean for any algorithm, any
/// payload, any node count.
#[test]
fn prop_allreduce_is_exact_mean() {
    check("allreduce-mean", 10, |g: &mut Gen| {
        let n = g.usize_in(2, 9);
        let d = g.usize_in(1, 64);
        let algo = match g.usize_in(0, 3) {
            0 => AllreduceAlgo::Ring,
            1 => AllreduceAlgo::ParameterServer,
            _ => AllreduceAlgo::BytePs,
        };
        let data: Vec<Vec<f32>> = (0..n).map(|_| g.vec_f32(d, -100.0, 100.0)).collect();
        let data2 = data.clone();
        let results = run_spmd(SpmdConfig::new(n), move |ctx| {
            ctx.allreduce(&data2[ctx.rank()], ReduceOp::Average, algo)
        })
        .map_err(|e| format!("run failed: {e:#}"))?;
        for i in 0..d {
            let want: f64 = data.iter().map(|v| v[i] as f64).sum::<f64>() / n as f64;
            for got in &results {
                prop_assert!(
                    (got[i] as f64 - want).abs() < 1e-3,
                    "element {i}: {} != {want} ({algo:?}, n={n})",
                    got[i]
                );
            }
        }
        Ok(())
    });
}

/// Analytic Table-I formulas: partial averaging is n-free; all costs are
/// monotone in M; ordering holds whenever n >= 4 and M/B dominates L.
#[test]
fn prop_cost_model_structure() {
    check("cost-model", 200, |g: &mut Gen| {
        let n = g.usize_in(4, 256);
        let m = g.f64_in(1e5, 1e9);
        let b = g.f64_in(1e8, 1e11);
        let l = g.f64_in(1e-6, 1e-3);
        prop_assert!(
            analytic::partial_averaging(1, m, b, l) == analytic::partial_averaging(1, m, b, l),
            "determinism"
        );
        // n-independence of partial averaging is structural (no n arg).
        let ps = analytic::parameter_server(n, m, b, l);
        let ring = analytic::ring_allreduce(n, m, b, l);
        let byteps = analytic::byteps(n, m, b, l);
        let partial = analytic::partial_averaging(1, m, b, l);
        // PS > ring only holds when bandwidth dominates; in latency-bound
        // regimes ring's 2nL rounds make it the worse choice — a real
        // crossover, not a bug (ring is "bandwidth optimal", Table I note).
        if m / b > 2.0 * n as f64 * l {
            prop_assert!(ps > ring, "PS {ps} <= ring {ring} (n={n})");
        }
        prop_assert!(byteps < ps, "BytePS {byteps} >= PS {ps}");
        prop_assert!(partial < byteps, "partial {partial} >= BytePS {byteps}");
        // Partial averaging always beats every global primitive.
        prop_assert!(partial < ring && partial < ps, "partial not cheapest");
        // Monotone in message size.
        let bigger = analytic::ring_allreduce(n, m * 2.0, b, l);
        prop_assert!(bigger > ring, "not monotone in M");
        Ok(())
    });
}

/// The virtual clock is monotone through arbitrary collective sequences.
#[test]
fn prop_virtual_time_monotone() {
    check("vtime-monotone", 6, |g: &mut Gen| {
        let n = g.usize_in(2, 6);
        let ops: Vec<usize> = (0..g.usize_in(1, 6)).map(|_| g.usize_in(0, 3)).collect();
        let results = run_spmd(SpmdConfig::new(n), move |ctx| {
            let mut last = ctx.vtime();
            let mut monotone = true;
            for &op in &ops {
                let x = vec![1.0f32; 32];
                match op {
                    0 => {
                        ctx.neighbor_allreduce(&x)?;
                    }
                    1 => {
                        ctx.allreduce(&x, ReduceOp::Average, AllreduceAlgo::Ring)?;
                    }
                    _ => {
                        ctx.barrier()?;
                    }
                }
                let now = ctx.vtime();
                monotone &= now >= last;
                last = now;
            }
            Ok(monotone)
        })
        .map_err(|e| format!("run failed: {e:#}"))?;
        prop_assert!(results.iter().all(|&m| m), "virtual clock went backwards");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Event-driven scheduler core (ISSUE 6).
// ---------------------------------------------------------------------------

/// The scheduler's priority queue against a brute-force model, under
/// randomized push/pop interleavings: every pop returns exactly the
/// model's minimum under the documented order (vtime, then rank, then
/// kind, then sequence number), and the popped multiset equals the pushed
/// multiset — no event lost, none duplicated, ties deterministic.
#[test]
fn prop_event_queue_matches_model_under_interleavings() {
    let kinds =
        [WakeKind::Start, WakeKind::Message, WakeKind::Resume, WakeKind::Clearance];
    check("event-queue-model", 40, |g: &mut Gen| {
        let n_events = g.usize_in(1, 80);
        let mut q = EventQueue::new();
        let mut model: Vec<Event> = Vec::new();
        let mut pushed = 0usize;
        let mut popped = Vec::new();
        let mut seq = 0u64;
        while pushed < n_events || !model.is_empty() {
            let do_push = pushed < n_events && (model.is_empty() || g.bool());
            if do_push {
                // A coarse vtime grid forces plenty of same-instant ties.
                let ev = Event {
                    vtime: g.usize_in(0, 5) as f64 * 0.25,
                    actor: g.usize_in(0, 5),
                    kind: kinds[g.usize_in(0, 4)],
                    seq,
                };
                seq += 1;
                q.push(ev);
                model.push(ev);
                pushed += 1;
            } else {
                let got = q.pop();
                prop_assert!(got.is_some(), "queue empty but model has {}", model.len());
                let got = got.unwrap();
                let (mi, _) = model
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| a.cmp(b))
                    .expect("model non-empty");
                let want = model.swap_remove(mi);
                prop_assert!(
                    got == want && got.seq == want.seq,
                    "pop {got:?} != model min {want:?}"
                );
                popped.push(got);
            }
        }
        prop_assert!(q.pop().is_none(), "queue retained events past the model");
        prop_assert!(popped.len() == pushed, "lost/duplicated events");
        Ok(())
    });
}

/// Same-instant ties break by rank: a burst of events at one virtual time
/// drains in ascending rank order regardless of insertion order.
#[test]
fn prop_event_queue_same_vtime_ties_break_by_rank() {
    check("event-queue-ties", 20, |g: &mut Gen| {
        let n = g.usize_in(2, 32);
        let mut q = EventQueue::new();
        // Random insertion order over a permutation of ranks 0..n.
        let mut ranks: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            ranks.swap(i, g.usize_in(0, i + 1));
        }
        for (seq, &actor) in ranks.iter().enumerate() {
            q.push(Event { vtime: 1.5, actor, kind: WakeKind::Resume, seq: seq as u64 });
        }
        let mut last = None;
        while let Some(ev) = q.pop() {
            if let Some(prev) = last {
                prop_assert!(ev.actor > prev, "rank order violated: {prev} before {}", ev.actor);
            }
            last = Some(ev.actor);
        }
        prop_assert!(last == Some(n - 1), "events lost in tie drain");
        Ok(())
    });
}

/// Event-loop determinism sweep: for >= 8 distinct seeds, a blocking
/// consensus workload replays with an *identical* scheduler grant trace
/// (same grants, same order) and bitwise-identical results — and every
/// trace's grant vtimes are nondecreasing (blocking workloads never
/// schedule into the past; non-blocking ops relax this by design, since
/// enqueue-time stamps can trail the flushing rank's clock).
#[test]
fn prop_event_loop_grant_traces_reproduce_across_seeds() {
    for seed in 0..8u64 {
        let n = 4 + (seed as usize % 4);
        let iters = 8;
        let run_once = || {
            let trace = std::sync::Arc::new(std::sync::Mutex::new(Vec::<Grant>::new()));
            let cfg = SpmdConfig::new(n)
                .with_exec(ExecMode::EventLoop)
                .with_seed(0xd15c0 + seed)
                .with_sched_trace(trace.clone());
            let results = run_spmd(cfg, move |ctx| {
                let mut x = vec![ctx.rank() as f32 + seed as f32; 2];
                for _ in 0..iters {
                    x = ctx.neighbor_allreduce(&x)?;
                }
                Ok(x)
            })
            .unwrap();
            let grants = trace.lock().unwrap().clone();
            (results, grants)
        };
        let (res_a, grants_a) = run_once();
        let (res_b, grants_b) = run_once();
        assert!(!grants_a.is_empty(), "seed {seed}: no grants recorded");
        assert_eq!(grants_a, grants_b, "seed {seed}: grant trace not reproducible");
        for (x, y) in res_a.iter().zip(&res_b) {
            let xb: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb, "seed {seed}: results not bitwise reproducible");
        }
        for w in grants_a.windows(2) {
            assert!(
                w[0].vtime.total_cmp(&w[1].vtime) != std::cmp::Ordering::Greater,
                "seed {seed}: grant vtimes decreased: {:?} -> {:?}",
                w[0],
                w[1]
            );
        }
    }
}
