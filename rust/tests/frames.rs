//! Property tests for the wire-format codec (ISSUE 8 satellite 4).
//!
//! Organized by the spec sections of DESIGN.md §Transport backends: each
//! test names the §WF rule it enforces, so a spec change without a
//! matching codec change (or vice versa) fails loudly here.

use bluefog::rng::Rng;
use bluefog::transport::frame::{
    decode, encode, encoded_len, read_frame_into, Frame, FrameError, FrameKind, ReadFrame,
    HEADER_LEN, MAGIC, MAX_PAYLOAD_ELEMS, VERSION,
};

/// Deterministic "arbitrary" frames: seeded payload lengths (including 0
/// and non-multiple-of-chunk sizes), values, and header fields.
fn arbitrary_frames() -> Vec<Frame> {
    let mut rng = Rng::new(0xF7A3_E5);
    let lens = [0usize, 1, 2, 3, 15, 16, 17, 63, 64, 255, 1000, 4096];
    lens.iter()
        .enumerate()
        .map(|(i, &len)| Frame {
            kind: FrameKind::Data,
            src: i as u64 * 0x0123_4567_89AB_CDEF,
            tag: rng.normal().to_bits() ^ i as u64,
            vtime: rng.normal() * 1e3,
            payload: rng.normal_vec(len),
        })
        .collect()
}

/// §WF-2/§WF-3: encode/decode is the identity on every frame, the byte
/// count matches the layout formula, and special f32 values survive.
#[test]
fn roundtrip_arbitrary_payloads() {
    for f in arbitrary_frames() {
        let bytes = encode(&f);
        assert_eq!(bytes.len(), encoded_len(f.payload.len()), "§WF-2 length formula");
        let (g, used) = decode(&bytes).expect("well-formed frame decodes");
        assert_eq!(used, bytes.len());
        assert_eq!(f, g);
    }
    // Non-finite and signed-zero payloads are bit-preserved (§WF-3: the
    // payload is raw IEEE-754 bits, not a numeric format).
    let f = Frame::data(0, 1, 0.0, vec![f32::NAN, f32::INFINITY, -0.0, f32::MIN_POSITIVE]);
    let (g, _) = decode(&encode(&f)).unwrap();
    for (a, b) in f.payload.iter().zip(&g.payload) {
        assert_eq!(a.to_bits(), b.to_bits(), "§WF-3 bit preservation");
    }
}

/// §WF-4: every kind round-trips through its wire byte; unknown kind
/// bytes are rejected rather than guessed at.
#[test]
fn kind_bytes_round_trip_and_reject() {
    let kinds = [
        FrameKind::Data,
        FrameKind::Hello,
        FrameKind::AddrMap,
        FrameKind::Goodbye,
        FrameKind::Error,
    ];
    for k in kinds {
        assert_eq!(FrameKind::from_u8(k.as_u8()), Some(k));
        let f = Frame::control(k, 3, 9);
        let (g, _) = decode(&encode(&f)).unwrap();
        assert_eq!(g.kind, k);
    }
    for b in 5..=u8::MAX {
        assert_eq!(FrameKind::from_u8(b), None, "§WF-4: kind byte {b} must not parse");
    }
    let mut bytes = encode(&Frame::control(FrameKind::Data, 0, 0));
    bytes[5] = 200;
    assert!(matches!(decode(&bytes), Err(FrameError::BadKind(200))));
}

/// §WF-5: EVERY strict prefix of a valid encoding is Truncated — the
/// decoder never consumes a partial frame, whatever the cut point.
#[test]
fn all_truncated_prefixes_rejected() {
    let f = Frame::data(2, 77, -1.25, (0..19).map(|i| i as f32 * 0.5).collect());
    let bytes = encode(&f);
    for cut in 0..bytes.len() {
        match decode(&bytes[..cut]) {
            Err(FrameError::Truncated { needed, have }) => {
                assert_eq!(have, cut);
                assert!(needed > cut, "needed {needed} must exceed available {cut}");
                // §WF-5: the full-frame need is reported once the header
                // is readable; before that only the header size is known.
                if cut >= HEADER_LEN {
                    assert_eq!(needed, bytes.len());
                } else {
                    assert_eq!(needed, HEADER_LEN);
                }
            }
            other => panic!("prefix of {cut} bytes must be Truncated, got {other:?}"),
        }
    }
}

/// §WF-2: corrupting any magic byte is BadMagic; §WF-6: any other
/// version byte is BadVersion, never a silent best-effort parse.
#[test]
fn bad_magic_and_version_rejected() {
    let good = encode(&Frame::control(FrameKind::Hello, 1, 2));
    assert_eq!(&good[0..4], &MAGIC, "encoder writes the spec magic");
    for i in 0..4 {
        let mut bytes = good.clone();
        bytes[i] ^= 0xFF;
        assert!(
            matches!(decode(&bytes), Err(FrameError::BadMagic(_))),
            "§WF-2: corrupt magic byte {i} must be rejected"
        );
    }
    for v in (0..=u8::MAX).filter(|&v| v != VERSION) {
        let mut bytes = good.clone();
        bytes[4] = v;
        assert!(matches!(decode(&bytes), Err(FrameError::BadVersion(b)) if b == v));
    }
}

/// §WF-5: a length field beyond the cap is rejected before any payload
/// allocation, even when the buffer claims to hold the bytes.
#[test]
fn oversize_rejected_before_allocation() {
    let mut bytes = encode(&Frame::control(FrameKind::Data, 0, 0));
    bytes[32..40].copy_from_slice(&(MAX_PAYLOAD_ELEMS + 1).to_le_bytes());
    assert!(matches!(decode(&bytes), Err(FrameError::Oversize(n)) if n == MAX_PAYLOAD_ELEMS + 1));
    // The cap itself is within spec: with only the header present the
    // decoder must report Truncated (more bytes wanted), never Oversize.
    bytes[32..40].copy_from_slice(&MAX_PAYLOAD_ELEMS.to_le_bytes());
    assert!(matches!(decode(&bytes), Err(FrameError::Truncated { .. })));
}

/// §WF-2: reserved bytes are zero on send and ignored on receive — a
/// nonzero reserved field from a future sender still decodes today.
#[test]
fn reserved_bytes_ignored_on_receive() {
    let f = Frame::data(5, 6, 7.0, vec![1.0, 2.0]);
    let mut bytes = encode(&f);
    assert_eq!(&bytes[6..8], &[0, 0], "encoder zeroes reserved bytes");
    bytes[6] = 0xAA;
    bytes[7] = 0x55;
    let (g, _) = decode(&bytes).expect("§WF-2: reserved bytes are ignored");
    assert_eq!(f, g);
}

/// §WF-1: the stream reader yields back-to-back frames, reports a clean
/// EOF only at a frame boundary, and treats a mid-frame cut as malformed.
#[test]
fn stream_reader_boundaries() {
    let frames = arbitrary_frames();
    let mut wire = Vec::new();
    for f in &frames {
        wire.extend_from_slice(&encode(f));
    }
    let mut cursor = &wire[..];
    let mut payload = Vec::new();
    for f in &frames {
        match read_frame_into(&mut cursor, &mut payload) {
            ReadFrame::Ok(g) => assert_eq!(*f, g),
            other => panic!("expected frame, got {other:?}"),
        }
    }
    assert!(matches!(read_frame_into(&mut cursor, &mut payload), ReadFrame::Eof));

    // Mid-frame cut: truncated stream is Malformed, not Eof (§WF-5).
    let one = encode(frames.last().expect("non-empty"));
    let mut cut = &one[..one.len() - 3];
    match read_frame_into(&mut cut, &mut payload) {
        ReadFrame::Malformed(FrameError::Truncated { .. }) => {}
        other => panic!("mid-frame EOF must be Malformed, got {other:?}"),
    }
}
