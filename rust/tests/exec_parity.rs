//! Differential tests: the event-driven execution backend (ISSUE 6) must
//! reproduce the thread-per-rank backend exactly. Threads is the oracle —
//! it has been validated by every tier-1 test since the seed — so for
//! synchronous workloads (blocking collectives, DSGD, gradient tracking,
//! non-blocking overlap with deterministic wait points) the event loop
//! must produce *bitwise-identical* final parameters, identical per-rank
//! `bytes_sent()`, and identical virtual-time traces. Asynchronous
//! workloads are OS-race-dependent under Threads, so there the contract
//! is run-to-run determinism of the event loop itself (identical grants,
//! identical parameters) plus the regime's algebraic invariants.

use std::sync::{Arc, Mutex};

use bluefog::launcher::{run_spmd, AsyncSpec, ExecMode, SpmdConfig};
use bluefog::optim::{
    AsyncDecentralizedOptimizer, AsyncPushSumSgd, CommSpec, DecentralizedOptimizer, Dgd,
    GradientTracking, StepOrder,
};
use bluefog::simnet::event::Grant;
use bluefog::simnet::hetero::ComputeHeterogeneity;
use bluefog::tensor::axpy;
use bluefog::timeline::Timeline;

const N: usize = 8;

/// Per-rank timeline spans reduced to their deterministic parts. Wall
/// times differ between backends by construction; operation names and
/// virtual-time endpoints must not.
fn vtime_trace(tl: &Timeline) -> Vec<Vec<(String, u64, u64)>> {
    let mut per_rank: Vec<Vec<(String, u64, u64)>> = vec![vec![]; N];
    for e in tl.events() {
        per_rank[e.rank].push((e.name, e.vtime_start.to_bits(), e.vtime_end.to_bits()));
    }
    per_rank
}

/// Run `f` under the given backend with a timeline attached; returns
/// (per-rank results, per-rank vtime traces).
fn run_traced<T, F>(exec: ExecMode, f: F) -> (Vec<T>, Vec<Vec<(String, u64, u64)>>)
where
    T: Send + 'static,
    F: Fn(&mut bluefog::context::NodeContext) -> anyhow::Result<T> + Send + Sync + 'static,
{
    let tl = Arc::new(Timeline::new(true));
    let cfg = SpmdConfig::new(N).with_exec(exec).with_timeline(tl.clone());
    let results = run_spmd(cfg, f).unwrap();
    let trace = vtime_trace(&tl);
    (results, trace)
}

fn assert_bitwise_eq(threads: &[f32], event: &[f32], what: &str) {
    assert_eq!(threads.len(), event.len(), "{what}: length mismatch");
    for (i, (a, b)) in threads.iter().zip(event).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{what}[{i}]: threads {a} != event-loop {b} (bitwise)"
        );
    }
}

// ---------------------------------------------------------------------------
// Synchronous workloads: bitwise parity against the Threads oracle.
// ---------------------------------------------------------------------------

/// Quickstart-scale average consensus: 30 rounds of blocking
/// `neighbor_allreduce` on the default expo-2 topology.
#[test]
fn consensus_parity_bitwise() {
    let body = |ctx: &mut bluefog::context::NodeContext| -> anyhow::Result<(Vec<f32>, u64, f64)> {
        let d = 4;
        let mut x: Vec<f32> = (0..d).map(|j| (ctx.rank() * d + j) as f32).collect();
        for _ in 0..30 {
            x = ctx.neighbor_allreduce(&x)?;
        }
        Ok((x, ctx.bytes_sent(), ctx.vtime()))
    };
    let (t_res, t_trace) = run_traced(ExecMode::Threads, body);
    let (e_res, e_trace) = run_traced(ExecMode::EventLoop, body);
    for rank in 0..N {
        let (tx, tb, tv) = &t_res[rank];
        let (ex, eb, ev) = &e_res[rank];
        assert_bitwise_eq(tx, ex, &format!("consensus rank {rank}"));
        assert_eq!(tb, eb, "rank {rank}: bytes_sent diverged");
        assert_eq!(tv.to_bits(), ev.to_bits(), "rank {rank}: final vtime diverged");
        assert_eq!(t_trace[rank], e_trace[rank], "rank {rank}: vtime trace diverged");
    }
}

/// Quickstart-scale DSGD (ATC order) on the node-local quadratic.
#[test]
fn dsgd_parity_bitwise() {
    let body = |ctx: &mut bluefog::context::NodeContext| -> anyhow::Result<(Vec<f32>, u64)> {
        let c = ctx.rank() as f32;
        let mut x = vec![0.0f32];
        let mut opt = Dgd::new(0.05, StepOrder::Atc, CommSpec::Static);
        for _ in 0..200 {
            let grad = vec![x[0] - c];
            opt.step(ctx, &mut x, &grad)?;
        }
        Ok((x, ctx.bytes_sent()))
    };
    let (t_res, t_trace) = run_traced(ExecMode::Threads, body);
    let (e_res, e_trace) = run_traced(ExecMode::EventLoop, body);
    for rank in 0..N {
        assert_bitwise_eq(&t_res[rank].0, &e_res[rank].0, &format!("dsgd rank {rank}"));
        assert_eq!(t_res[rank].1, e_res[rank].1, "rank {rank}: bytes_sent diverged");
        assert_eq!(t_trace[rank], e_trace[rank], "rank {rank}: vtime trace diverged");
    }
}

/// Gradient tracking: two collectives per step (iterate + tracker), so it
/// exercises interleaved negotiation rounds under the scheduler.
#[test]
fn gradient_tracking_parity_bitwise() {
    let body = |ctx: &mut bluefog::context::NodeContext| -> anyhow::Result<(Vec<f32>, u64)> {
        let d = 3;
        let c: Vec<f32> = (0..d).map(|j| (ctx.rank() * d + j) as f32).collect();
        let mut x = vec![0.0f32; d];
        let mut opt = GradientTracking::new(0.05, CommSpec::Static);
        for _ in 0..150 {
            let grad: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| xi - ci).collect();
            opt.step(ctx, &mut x, &grad)?;
        }
        Ok((x, ctx.bytes_sent()))
    };
    let (t_res, _) = run_traced(ExecMode::Threads, body);
    let (e_res, _) = run_traced(ExecMode::EventLoop, body);
    for rank in 0..N {
        assert_bitwise_eq(&t_res[rank].0, &e_res[rank].0, &format!("gt rank {rank}"));
        assert_eq!(t_res[rank].1, e_res[rank].1, "rank {rank}: bytes_sent diverged");
    }
}

/// Non-blocking overlap (quickstart's AWC loop): under Threads the fused
/// group is flushed by a communication thread; under the event loop the
/// same `CommEngine` runs inline at the wait point. Same state machine,
/// same wait vtimes — results must agree bitwise.
#[test]
fn nonblocking_awc_parity_bitwise() {
    let body = |ctx: &mut bluefog::context::NodeContext| -> anyhow::Result<(Vec<f32>, u64, f64)> {
        let c = ctx.rank() as f32;
        let mut x = vec![0.0f32];
        for _ in 0..100 {
            let handle = ctx.neighbor_allreduce_nonblocking(&x, None)?;
            let grad = vec![x[0] - c];
            x = handle.wait(ctx)?;
            axpy(-0.05, &grad, &mut x);
        }
        Ok((x, ctx.bytes_sent(), ctx.vtime()))
    };
    let (t_res, _) = run_traced(ExecMode::Threads, body);
    let (e_res, _) = run_traced(ExecMode::EventLoop, body);
    for rank in 0..N {
        let (tx, tb, tv) = &t_res[rank];
        let (ex, eb, ev) = &e_res[rank];
        assert_bitwise_eq(tx, ex, &format!("awc rank {rank}"));
        assert_eq!(tb, eb, "rank {rank}: bytes_sent diverged");
        assert_eq!(tv.to_bits(), ev.to_bits(), "rank {rank}: final vtime diverged");
    }
}

// ---------------------------------------------------------------------------
// Asynchronous workload: event-loop determinism + regime invariants.
// ---------------------------------------------------------------------------

/// `AsyncPushSumSgd` under a 4x straggler. The Threads backend is
/// OS-race-dependent here (async is the one regime where races are by
/// design), so the oracle property is the event loop against *itself*:
/// two runs with the same seed must produce bitwise-identical parameters,
/// identical push weights, and identical scheduler grant traces — and the
/// run must still satisfy push-sum mass conservation and consensus.
#[test]
fn async_push_sum_event_loop_deterministic() {
    let n = 6;
    let d = 3;
    let base = 1e-3;
    let t_end = 0.1;
    let run_once = || {
        let hetero = ComputeHeterogeneity::straggler(n, 0, 4.0).with_jitter(0.1);
        let trace = Arc::new(Mutex::new(Vec::<Grant>::new()));
        let cfg = SpmdConfig::new(n)
            .with_exec(ExecMode::EventLoop)
            .with_topo_check(false)
            .with_async(AsyncSpec::new(hetero).with_horizon(16.0 * base))
            .with_sched_trace(trace.clone());
        let results = run_spmd(cfg, move |ctx| {
            let mut x = vec![ctx.rank() as f32; d];
            let zeros = vec![0.0f32; d];
            let mut opt = AsyncPushSumSgd::new(0.0, "cons");
            for _ in 0..10_000 {
                if ctx.vtime() >= t_end {
                    break;
                }
                ctx.async_throttle();
                ctx.simulate_compute_hetero(base);
                opt.refresh(ctx, &mut x)?;
                opt.step(ctx, &mut x, &zeros)?;
            }
            opt.finalize(ctx, &mut x)?;
            Ok((x, opt.push_weight()))
        })
        .unwrap();
        let grants = trace.lock().unwrap().clone();
        (results, grants)
    };

    let (res_a, grants_a) = run_once();
    let (res_b, grants_b) = run_once();

    // Run-to-run determinism: parameters, push weights, grant trace.
    for rank in 0..n {
        assert_bitwise_eq(&res_a[rank].0, &res_b[rank].0, &format!("async rank {rank}"));
        assert_eq!(
            res_a[rank].1.to_bits(),
            res_b[rank].1.to_bits(),
            "rank {rank}: push weight diverged between identical runs"
        );
    }
    assert!(!grants_a.is_empty(), "sched_trace recorded no grants");
    assert_eq!(grants_a, grants_b, "scheduler grant traces diverged between identical runs");

    // Regime invariants: mass conservation and consensus.
    let v_total: f64 = res_a.iter().map(|(_, v)| f64::from(*v)).sum();
    assert!((v_total - n as f64).abs() < 1e-3, "push-sum mass leaked: {v_total}");
    let mean = (0..n).sum::<usize>() as f32 / n as f32;
    for (rank, (x, _)) in res_a.iter().enumerate() {
        for v in x {
            assert!((v - mean).abs() < 5e-3, "rank {rank} off consensus: {v} vs {mean}");
        }
    }
}

// ---------------------------------------------------------------------------
// Throttle regression: a blocked rank consumes no virtual time.
// ---------------------------------------------------------------------------

/// `async_throttle` used to spin on `thread::sleep(20us)`; it now parks on
/// the scheduler (event loop) or a generation-counted condvar (threads).
/// Either way, the *virtual* clock of a waiting rank must not move: the
/// fast rank's final vtime is exactly its own compute time, even though it
/// spent most of the run throttled behind the 4x straggler.
#[test]
fn throttled_rank_consumes_no_virtual_time() {
    let base = 1e-3;
    let steps = 40;
    for exec in [ExecMode::Threads, ExecMode::EventLoop] {
        let hetero = ComputeHeterogeneity::straggler(2, 0, 4.0);
        let cfg = SpmdConfig::new(2)
            .with_exec(exec)
            .with_topo_check(false)
            .with_async(AsyncSpec::new(hetero).with_horizon(2.0 * base));
        let results = run_spmd(cfg, move |ctx| {
            for _ in 0..steps {
                ctx.async_throttle();
                ctx.simulate_compute_hetero(base);
            }
            Ok(ctx.vtime())
        })
        .unwrap();
        // Rank 1 runs at nominal speed: its clock must read exactly
        // `steps` compute intervals — waiting added nothing.
        let mut expected = 0.0f64;
        for _ in 0..steps {
            expected += base;
        }
        assert!(
            (results[1] - expected).abs() < 1e-12,
            "{exec:?}: fast rank vtime {} != compute-only {} — waiting leaked virtual time",
            results[1],
            expected
        );
        // And the straggler reads exactly 4x that.
        assert!(
            (results[0] - 4.0 * expected).abs() < 1e-9,
            "{exec:?}: straggler vtime {} != {}",
            results[0],
            4.0 * expected
        );
    }
}

// ---------------------------------------------------------------------------
// Watchdog: drained queue with unfinished ranks fails fast.
// ---------------------------------------------------------------------------

/// Rank 0 blocks on a collective its peer never joins. Under threads this
/// would hang forever; the event-loop watchdog must poison the run and
/// name the stuck rank's pending wait instead.
#[test]
fn watchdog_reports_stuck_ranks() {
    let err = run_spmd(
        SpmdConfig::new(2).with_exec(ExecMode::EventLoop).with_topo_check(false),
        |ctx| {
            if ctx.rank() == 0 {
                let x = vec![1.0f32];
                ctx.neighbor_allreduce(&x)?;
            }
            Ok(())
        },
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("simnet deadlock"), "unexpected error: {msg}");
    assert!(msg.contains("unfinished rank"), "diagnostic lost rank info: {msg}");
}

/// The PR-4 `win_create` duplicate-name scenario under the event loop:
/// the erroring rank must still reach the create barrier (no deadlock —
/// the watchdog would fire) and the error must propagate.
#[test]
fn win_create_error_reaches_barrier_under_event_loop() {
    let results = run_spmd(
        SpmdConfig::new(3).with_exec(ExecMode::EventLoop).with_topo_check(false),
        |ctx| {
            ctx.win_create("dupwin", &[1.0], false)?;
            let dup_err = if ctx.rank() == 0 {
                ctx.win_create("dupwin", &[1.0], false).is_err()
            } else {
                ctx.win_create("other", &[1.0], false)?;
                true
            };
            ctx.barrier()?;
            ctx.win_free("dupwin")?;
            if ctx.rank() != 0 {
                ctx.win_free("other")?;
            }
            Ok(dup_err)
        },
    )
    .unwrap();
    assert!(results.iter().all(|&e| e), "duplicate create must error after the barrier");
}
