//! Minimal dense tensor used on the coordinator hot path.
//!
//! Model state and messages are flat `f32` buffers; shapes only matter at
//! the PJRT boundary, where the [`crate::runtime`] manifest supplies them.
//! The helpers here are the BLAS-1 style kernels the decentralized
//! optimizers are written in.

/// Flat f32 tensor with an optional shape annotation.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Flat row-major element buffer.
    pub data: Vec<f32>,
    /// Logical shape; product equals `data.len()`.
    pub shape: Vec<usize>,
}

impl Tensor {
    /// A tensor of zeros with the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { data: vec![0.0; n], shape: shape.to_vec() }
    }

    /// Wrap a flat vector as a rank-1 tensor.
    pub fn from_vec(data: Vec<f32>) -> Self {
        let n = data.len();
        Tensor { data, shape: vec![n] }
    }

    /// Wrap a flat vector with an explicit shape (must match length).
    pub fn with_shape(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/len mismatch");
        Tensor { data, shape: shape.to_vec() }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size in bytes on the wire.
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// `y += a * x` (classic axpy). Panics if lengths differ.
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// `x *= a` in place.
pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// Dot product.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot length mismatch");
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// `out = sum_k weights[k] * parts[k]` — the partial-averaging combine,
/// the mathematical core of `neighbor_allreduce` (paper eq. (5)).
///
/// This is the native (pure Rust) implementation; the same computation is
/// also available as an AOT-compiled Pallas kernel through the runtime, and
/// the two are cross-validated in integration tests.
pub fn weighted_combine(parts: &[&[f32]], weights: &[f32]) -> Vec<f32> {
    assert_eq!(parts.len(), weights.len(), "combine arity mismatch");
    assert!(!parts.is_empty(), "combine of zero parts");
    let d = parts[0].len();
    for p in parts {
        assert_eq!(p.len(), d, "combine length mismatch");
    }
    let mut out = vec![0.0f32; d];
    for (p, &w) in parts.iter().zip(weights) {
        axpy(w, p, &mut out);
    }
    out
}

/// In-place variant: `acc = w_self * acc + sum_k weights[k] * parts[k]`.
///
/// The self-scale is fused into the first accumulation pass so the buffer
/// is traversed `k` times instead of `k + 1` (hot-path optimization,
/// EXPERIMENTS.md §Perf).
pub fn weighted_combine_into(acc: &mut [f32], w_self: f32, parts: &[&[f32]], weights: &[f32]) {
    assert_eq!(parts.len(), weights.len());
    match parts.split_first() {
        None => scale(w_self, acc),
        Some((first, rest)) => {
            assert_eq!(first.len(), acc.len(), "combine length mismatch");
            let w0 = weights[0];
            for (a, x) in acc.iter_mut().zip(first.iter()) {
                *a = w_self * *a + w0 * x;
            }
            for (p, &w) in rest.iter().zip(&weights[1..]) {
                axpy(w, p, acc);
            }
        }
    }
}

/// Allocating variant that avoids the caller's init copy:
/// `out = w_self * base + sum_k weights[k] * parts[k]`, building `out` in a
/// single fused pass over `base` and the first part.
pub fn weighted_combine_from(
    base: &[f32],
    w_self: f32,
    parts: &[&[f32]],
    weights: &[f32],
) -> Vec<f32> {
    assert_eq!(parts.len(), weights.len());
    match parts.split_first() {
        None => base.iter().map(|x| w_self * x).collect(),
        Some((first, rest)) => {
            assert_eq!(first.len(), base.len(), "combine length mismatch");
            let w0 = weights[0];
            let mut out: Vec<f32> =
                base.iter().zip(first.iter()).map(|(a, x)| w_self * a + w0 * x).collect();
            for (p, &w) in rest.iter().zip(&weights[1..]) {
                axpy(w, p, &mut out);
            }
            out
        }
    }
}

/// Block size (elements) of the blocked combine kernels: 16 KB of `f32`,
/// small enough that the output block stays L1-resident while all `k`
/// neighbor parts stream through it.
pub const COMBINE_BLOCK: usize = 4096;

/// Blocked variant of [`weighted_combine`]: identical result, but the
/// output is traversed one cache-sized block at a time with **all** `k`
/// parts accumulated per block, instead of `k` full-buffer `axpy` sweeps
/// that evict the output between passes (hot-path optimization,
/// EXPERIMENTS.md §Perf "Buffer pool & blocked combine").
pub fn weighted_combine_blocked(parts: &[&[f32]], weights: &[f32]) -> Vec<f32> {
    assert_eq!(parts.len(), weights.len(), "combine arity mismatch");
    assert!(!parts.is_empty(), "combine of zero parts");
    let d = parts[0].len();
    for p in parts {
        assert_eq!(p.len(), d, "combine length mismatch");
    }
    let mut out = vec![0.0f32; d];
    let (first, rest) = parts.split_first().unwrap();
    let w0 = weights[0];
    let mut lo = 0;
    while lo < d {
        let hi = (lo + COMBINE_BLOCK).min(d);
        for (o, x) in out[lo..hi].iter_mut().zip(&first[lo..hi]) {
            *o = w0 * x;
        }
        for (p, &w) in rest.iter().zip(&weights[1..]) {
            axpy(w, &p[lo..hi], &mut out[lo..hi]);
        }
        lo = hi;
    }
    out
}

/// Blocked variant of [`weighted_combine_into`]:
/// `acc = w_self * acc + sum_k weights[k] * parts[k]`, with the self-scale
/// fused into the first accumulation and each cache-sized block of `acc`
/// fully combined before moving on (single traversal of the output per
/// block for all `k` parts).
pub fn weighted_combine_blocked_into(
    acc: &mut [f32],
    w_self: f32,
    parts: &[&[f32]],
    weights: &[f32],
) {
    assert_eq!(parts.len(), weights.len(), "combine arity mismatch");
    let Some((first, rest)) = parts.split_first() else {
        scale(w_self, acc);
        return;
    };
    assert_eq!(first.len(), acc.len(), "combine length mismatch");
    for p in rest {
        assert_eq!(p.len(), acc.len(), "combine length mismatch");
    }
    let d = acc.len();
    let w0 = weights[0];
    let mut lo = 0;
    while lo < d {
        let hi = (lo + COMBINE_BLOCK).min(d);
        for (a, x) in acc[lo..hi].iter_mut().zip(&first[lo..hi]) {
            *a = w_self * *a + w0 * x;
        }
        for (p, &w) in rest.iter().zip(&weights[1..]) {
            axpy(w, &p[lo..hi], &mut acc[lo..hi]);
        }
        lo = hi;
    }
}

/// Mean absolute difference between two buffers (test helper).
pub fn mean_abs_diff(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.is_empty() {
        return 0.0;
    }
    x.iter().zip(y).map(|(a, b)| (*a as f64 - *b as f64).abs()).sum::<f64>() / x.len() as f64
}

/// Max absolute difference between two buffers (test helper).
pub fn max_abs_diff(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (*a as f64 - *b as f64).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_has_right_len() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.nbytes(), 96);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "shape/len mismatch")]
    fn with_shape_validates() {
        Tensor::with_shape(vec![1.0, 2.0], &[3]);
    }

    #[test]
    fn axpy_matches_manual() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn weighted_combine_is_convex_mean() {
        let a = vec![1.0f32; 8];
        let b = vec![3.0f32; 8];
        let out = weighted_combine(&[&a, &b], &[0.5, 0.5]);
        assert!(out.iter().all(|&x| (x - 2.0).abs() < 1e-6));
    }

    #[test]
    fn weighted_combine_into_matches_functional() {
        let mut acc = vec![2.0f32, 4.0];
        let p1 = vec![1.0f32, 1.0];
        let p2 = vec![0.0f32, 2.0];
        weighted_combine_into(&mut acc, 0.5, &[&p1, &p2], &[0.25, 0.25]);
        // 0.5*[2,4] + 0.25*[1,1] + 0.25*[0,2] = [1.25, 2.75]
        assert_eq!(acc, vec![1.25, 2.75]);
    }

    #[test]
    fn blocked_combine_matches_naive_across_block_boundary() {
        // d > COMBINE_BLOCK so the block loop takes more than one trip.
        let d = COMBINE_BLOCK + 37;
        let parts: Vec<Vec<f32>> =
            (0..3).map(|k| (0..d).map(|i| ((i * 7 + k * 13) % 29) as f32 - 14.0).collect()).collect();
        let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
        let ws = [0.2f32, 0.5, 0.3];
        let naive = weighted_combine(&refs, &ws);
        let blocked = weighted_combine_blocked(&refs, &ws);
        assert_eq!(naive, blocked, "blocked kernel diverged");
    }

    #[test]
    fn blocked_combine_into_matches_into() {
        let d = 2 * COMBINE_BLOCK + 5;
        let base: Vec<f32> = (0..d).map(|i| (i % 17) as f32).collect();
        let p1: Vec<f32> = (0..d).map(|i| ((i + 3) % 11) as f32).collect();
        let p2: Vec<f32> = (0..d).map(|i| ((i * 5) % 13) as f32 - 6.0).collect();
        let mut a = base.clone();
        let mut b = base.clone();
        weighted_combine_into(&mut a, 0.4, &[&p1, &p2], &[0.3, 0.3]);
        weighted_combine_blocked_into(&mut b, 0.4, &[&p1, &p2], &[0.3, 0.3]);
        assert!(max_abs_diff(&a, &b) < 1e-5, "blocked into diverged");
    }

    #[test]
    fn blocked_combine_into_empty_parts_scales() {
        let mut a = vec![2.0f32, -4.0];
        weighted_combine_blocked_into(&mut a, 0.5, &[], &[]);
        assert_eq!(a, vec![1.0, -2.0]);
    }

    #[test]
    fn norms_and_dots() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
