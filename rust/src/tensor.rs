//! Minimal dense tensor used on the coordinator hot path.
//!
//! Model state and messages are flat `f32` buffers; shapes only matter at
//! the PJRT boundary, where the [`crate::runtime`] manifest supplies them.
//! The helpers here are the BLAS-1 style kernels the decentralized
//! optimizers are written in.
//!
//! # SIMD kernels (DESIGN.md §Kernels)
//!
//! The mutating kernels (`axpy`, `scale`, the fused combine passes) are
//! written as fixed-width lane loops: the buffer is split into
//! [`LANES`]-element chunks, each chunk is reborrowed as a `[f32; LANES]`
//! array so LLVM sees a constant trip count it can turn into vector
//! instructions on stable Rust (no `std::simd`), and a scalar loop handles
//! the tail. Vectorization runs *across output elements* — every output
//! element still sees exactly the seed's per-element operation order — so
//! results are bitwise identical to the frozen references in [`scalar`],
//! and every downstream parity gate (exec/tcp parity, compression smokes)
//! is unaffected. The property tests in `tests/kernels.rs` pin this down
//! at lengths straddling the lane/block/tail boundaries.

use crate::parallel::{shard_bounds, WorkerPool};

/// Lane width (elements) of the chunked kernels: 8 `f32`s = one AVX2
/// register / two NEON registers, the widest unit that still
/// autovectorizes cleanly on every tier-1 target.
pub const LANES: usize = 8;

/// Minimum buffer length before [`weighted_combine_blocked_into_par`]
/// shards across the worker pool; below this the dispatch overhead
/// outweighs the combine itself and the serial kernel runs inline.
pub const PAR_MIN_ELEMS: usize = 1 << 15;

/// Flat f32 tensor with an optional shape annotation.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Flat row-major element buffer.
    pub data: Vec<f32>,
    /// Logical shape; product equals `data.len()`.
    pub shape: Vec<usize>,
}

impl Tensor {
    /// A tensor of zeros with the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { data: vec![0.0; n], shape: shape.to_vec() }
    }

    /// Wrap a flat vector as a rank-1 tensor.
    pub fn from_vec(data: Vec<f32>) -> Self {
        let n = data.len();
        Tensor { data, shape: vec![n] }
    }

    /// Wrap a flat vector with an explicit shape (must match length).
    pub fn with_shape(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/len mismatch");
        Tensor { data, shape: shape.to_vec() }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size in bytes on the wire.
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// `y += a * x` (classic axpy), lane-chunked. Panics if lengths differ.
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yl, xl) in (&mut yc).zip(&mut xc) {
        let yl: &mut [f32; LANES] = yl.try_into().expect("lane chunk");
        let xl: &[f32; LANES] = xl.try_into().expect("lane chunk");
        for l in 0..LANES {
            yl[l] += a * xl[l];
        }
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yi += a * xi;
    }
}

/// `x *= a` in place, lane-chunked.
pub fn scale(a: f32, x: &mut [f32]) {
    let mut xc = x.chunks_exact_mut(LANES);
    for xl in &mut xc {
        let xl: &mut [f32; LANES] = xl.try_into().expect("lane chunk");
        for l in 0..LANES {
            xl[l] *= a;
        }
    }
    for xi in xc.into_remainder() {
        *xi *= a;
    }
}

/// Fused first combine pass: `acc = w_self * acc + w0 * x`, lane-chunked.
/// One multiply-add per element per pass, exactly the seed's per-element
/// expression, so the result is bitwise identical to the scalar loop.
#[inline]
fn fused_scale_axpy(w_self: f32, w0: f32, x: &[f32], acc: &mut [f32]) {
    debug_assert_eq!(x.len(), acc.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (al, xl) in (&mut ac).zip(&mut xc) {
        let al: &mut [f32; LANES] = al.try_into().expect("lane chunk");
        let xl: &[f32; LANES] = xl.try_into().expect("lane chunk");
        for l in 0..LANES {
            al[l] = w_self * al[l] + w0 * xl[l];
        }
    }
    for (ai, xi) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *ai = w_self * *ai + w0 * xi;
    }
}

/// Dot product.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot length mismatch");
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// `out = sum_k weights[k] * parts[k]` — the partial-averaging combine,
/// the mathematical core of `neighbor_allreduce` (paper eq. (5)).
///
/// This is the native (pure Rust) implementation; the same computation is
/// also available as an AOT-compiled Pallas kernel through the runtime, and
/// the two are cross-validated in integration tests.
pub fn weighted_combine(parts: &[&[f32]], weights: &[f32]) -> Vec<f32> {
    assert_eq!(parts.len(), weights.len(), "combine arity mismatch");
    assert!(!parts.is_empty(), "combine of zero parts");
    let d = parts[0].len();
    for p in parts {
        assert_eq!(p.len(), d, "combine length mismatch");
    }
    let mut out = vec![0.0f32; d];
    for (p, &w) in parts.iter().zip(weights) {
        axpy(w, p, &mut out);
    }
    out
}

/// In-place variant: `acc = w_self * acc + sum_k weights[k] * parts[k]`.
///
/// The self-scale is fused into the first accumulation pass so the buffer
/// is traversed `k` times instead of `k + 1` (hot-path optimization,
/// EXPERIMENTS.md §Perf).
pub fn weighted_combine_into(acc: &mut [f32], w_self: f32, parts: &[&[f32]], weights: &[f32]) {
    assert_eq!(parts.len(), weights.len());
    match parts.split_first() {
        None => scale(w_self, acc),
        Some((first, rest)) => {
            assert_eq!(first.len(), acc.len(), "combine length mismatch");
            fused_scale_axpy(w_self, weights[0], first, acc);
            for (p, &w) in rest.iter().zip(&weights[1..]) {
                axpy(w, p, acc);
            }
        }
    }
}

/// Allocating variant that avoids the caller's init copy:
/// `out = w_self * base + sum_k weights[k] * parts[k]`, building `out` in a
/// single fused pass over `base` and the first part.
pub fn weighted_combine_from(
    base: &[f32],
    w_self: f32,
    parts: &[&[f32]],
    weights: &[f32],
) -> Vec<f32> {
    assert_eq!(parts.len(), weights.len());
    match parts.split_first() {
        None => base.iter().map(|x| w_self * x).collect(),
        Some((first, rest)) => {
            assert_eq!(first.len(), base.len(), "combine length mismatch");
            let w0 = weights[0];
            let mut out: Vec<f32> =
                base.iter().zip(first.iter()).map(|(a, x)| w_self * a + w0 * x).collect();
            for (p, &w) in rest.iter().zip(&weights[1..]) {
                axpy(w, p, &mut out);
            }
            out
        }
    }
}

/// Block size (elements) of the blocked combine kernels: 16 KB of `f32`,
/// small enough that the output block stays L1-resident while all `k`
/// neighbor parts stream through it.
pub const COMBINE_BLOCK: usize = 4096;

/// Blocked variant of [`weighted_combine`]: identical result, but the
/// output is traversed one cache-sized block at a time with **all** `k`
/// parts accumulated per block, instead of `k` full-buffer `axpy` sweeps
/// that evict the output between passes (hot-path optimization,
/// EXPERIMENTS.md §Perf "Buffer pool & blocked combine").
///
/// Each block is *appended* from the first part (`w0 * x`), so the output
/// vector is written exactly once per block — there is no up-front
/// zero-fill pass over a buffer whose every element the first part
/// overwrites anyway.
pub fn weighted_combine_blocked(parts: &[&[f32]], weights: &[f32]) -> Vec<f32> {
    assert_eq!(parts.len(), weights.len(), "combine arity mismatch");
    assert!(!parts.is_empty(), "combine of zero parts");
    let d = parts[0].len();
    for p in parts {
        assert_eq!(p.len(), d, "combine length mismatch");
    }
    let (first, rest) = parts.split_first().unwrap();
    let w0 = weights[0];
    let mut out: Vec<f32> = Vec::with_capacity(d);
    let mut lo = 0;
    while lo < d {
        let hi = (lo + COMBINE_BLOCK).min(d);
        out.extend(first[lo..hi].iter().map(|x| w0 * x));
        for (p, &w) in rest.iter().zip(&weights[1..]) {
            axpy(w, &p[lo..hi], &mut out[lo..hi]);
        }
        lo = hi;
    }
    out
}

/// Blocked variant of [`weighted_combine_into`]:
/// `acc = w_self * acc + sum_k weights[k] * parts[k]`, with the self-scale
/// fused into the first accumulation and each cache-sized block of `acc`
/// fully combined before moving on (single traversal of the output per
/// block for all `k` parts).
pub fn weighted_combine_blocked_into(
    acc: &mut [f32],
    w_self: f32,
    parts: &[&[f32]],
    weights: &[f32],
) {
    assert_eq!(parts.len(), weights.len(), "combine arity mismatch");
    let Some((first, rest)) = parts.split_first() else {
        scale(w_self, acc);
        return;
    };
    assert_eq!(first.len(), acc.len(), "combine length mismatch");
    for p in rest {
        assert_eq!(p.len(), acc.len(), "combine length mismatch");
    }
    let d = acc.len();
    let mut lo = 0;
    while lo < d {
        let hi = (lo + COMBINE_BLOCK).min(d);
        fused_scale_axpy(w_self, weights[0], &first[lo..hi], &mut acc[lo..hi]);
        for (p, &w) in rest.iter().zip(&weights[1..]) {
            axpy(w, &p[lo..hi], &mut acc[lo..hi]);
        }
        lo = hi;
    }
}

/// Sharded variant of [`weighted_combine_blocked_into`]: the output is cut
/// into contiguous, [`COMBINE_BLOCK`]-aligned shards and each shard is
/// combined by exactly one worker of `pool`. Shard boundaries depend only
/// on `acc.len()` and `pool.threads()` — never on timing — and every
/// output element is computed by the same serial kernel over the same
/// operands in the same order, so the result is **byte-identical for any
/// thread count** (pinned by `tests/kernels.rs`).
///
/// Falls back to the serial kernel when the pool has a single thread or
/// the buffer is below [`PAR_MIN_ELEMS`].
pub fn weighted_combine_blocked_into_par(
    pool: &WorkerPool,
    acc: &mut [f32],
    w_self: f32,
    parts: &[&[f32]],
    weights: &[f32],
) {
    if pool.threads() <= 1 || acc.len() < PAR_MIN_ELEMS {
        return weighted_combine_blocked_into(acc, w_self, parts, weights);
    }
    assert_eq!(parts.len(), weights.len(), "combine arity mismatch");
    for p in parts {
        assert_eq!(p.len(), acc.len(), "combine length mismatch");
    }
    let bounds = shard_bounds(acc.len(), pool.threads(), COMBINE_BLOCK);
    pool.run_sharded_mut(acc, &bounds, |i, sub| {
        let (lo, hi) = bounds[i];
        let sub_parts: Vec<&[f32]> = parts.iter().map(|p| &p[lo..hi]).collect();
        weighted_combine_blocked_into(sub, w_self, &sub_parts, weights);
    });
}

/// Frozen scalar reference kernels — the seed implementations, kept
/// verbatim as (a) the baseline of the `perf_probe` scalar-vs-SIMD A/B
/// and (b) the bitwise oracle for the SIMD property tests. Do not
/// optimize these.
pub mod scalar {
    use super::COMBINE_BLOCK;

    /// Seed `y += a * x`: plain element loop, no lane chunking.
    pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len(), "axpy length mismatch");
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    /// Seed `x *= a`: plain element loop.
    pub fn scale(a: f32, x: &mut [f32]) {
        for xi in x.iter_mut() {
            *xi *= a;
        }
    }

    /// Seed combine: zero-fill then `k` full-buffer axpy sweeps — the
    /// multi-pass memory-traffic pattern the blocked kernels replace.
    pub fn weighted_combine(parts: &[&[f32]], weights: &[f32]) -> Vec<f32> {
        assert_eq!(parts.len(), weights.len(), "combine arity mismatch");
        assert!(!parts.is_empty(), "combine of zero parts");
        let d = parts[0].len();
        for p in parts {
            assert_eq!(p.len(), d, "combine length mismatch");
        }
        let mut out = vec![0.0f32; d];
        for (p, &w) in parts.iter().zip(weights) {
            axpy(w, p, &mut out);
        }
        out
    }

    /// Seed blocked in-place combine (scalar inner loops).
    pub fn weighted_combine_blocked_into(
        acc: &mut [f32],
        w_self: f32,
        parts: &[&[f32]],
        weights: &[f32],
    ) {
        assert_eq!(parts.len(), weights.len(), "combine arity mismatch");
        let Some((first, rest)) = parts.split_first() else {
            scale(w_self, acc);
            return;
        };
        assert_eq!(first.len(), acc.len(), "combine length mismatch");
        for p in rest {
            assert_eq!(p.len(), acc.len(), "combine length mismatch");
        }
        let d = acc.len();
        let w0 = weights[0];
        let mut lo = 0;
        while lo < d {
            let hi = (lo + COMBINE_BLOCK).min(d);
            for (a, x) in acc[lo..hi].iter_mut().zip(&first[lo..hi]) {
                *a = w_self * *a + w0 * x;
            }
            for (p, &w) in rest.iter().zip(&weights[1..]) {
                axpy(w, &p[lo..hi], &mut acc[lo..hi]);
            }
            lo = hi;
        }
    }
}

/// Mean absolute difference between two buffers (test helper).
pub fn mean_abs_diff(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.is_empty() {
        return 0.0;
    }
    x.iter().zip(y).map(|(a, b)| (*a as f64 - *b as f64).abs()).sum::<f64>() / x.len() as f64
}

/// Max absolute difference between two buffers (test helper).
pub fn max_abs_diff(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (*a as f64 - *b as f64).abs()).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_has_right_len() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.nbytes(), 96);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "shape/len mismatch")]
    fn with_shape_validates() {
        Tensor::with_shape(vec![1.0, 2.0], &[3]);
    }

    #[test]
    fn axpy_matches_manual() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn weighted_combine_is_convex_mean() {
        let a = vec![1.0f32; 8];
        let b = vec![3.0f32; 8];
        let out = weighted_combine(&[&a, &b], &[0.5, 0.5]);
        assert!(out.iter().all(|&x| (x - 2.0).abs() < 1e-6));
    }

    #[test]
    fn weighted_combine_into_matches_functional() {
        let mut acc = vec![2.0f32, 4.0];
        let p1 = vec![1.0f32, 1.0];
        let p2 = vec![0.0f32, 2.0];
        weighted_combine_into(&mut acc, 0.5, &[&p1, &p2], &[0.25, 0.25]);
        // 0.5*[2,4] + 0.25*[1,1] + 0.25*[0,2] = [1.25, 2.75]
        assert_eq!(acc, vec![1.25, 2.75]);
    }

    #[test]
    fn blocked_combine_matches_naive_across_block_boundary() {
        // d > COMBINE_BLOCK so the block loop takes more than one trip.
        let d = COMBINE_BLOCK + 37;
        let parts: Vec<Vec<f32>> =
            (0..3).map(|k| (0..d).map(|i| ((i * 7 + k * 13) % 29) as f32 - 14.0).collect()).collect();
        let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
        let ws = [0.2f32, 0.5, 0.3];
        let naive = weighted_combine(&refs, &ws);
        let blocked = weighted_combine_blocked(&refs, &ws);
        assert_eq!(naive, blocked, "blocked kernel diverged");
    }

    #[test]
    fn blocked_combine_into_matches_into() {
        let d = 2 * COMBINE_BLOCK + 5;
        let base: Vec<f32> = (0..d).map(|i| (i % 17) as f32).collect();
        let p1: Vec<f32> = (0..d).map(|i| ((i + 3) % 11) as f32).collect();
        let p2: Vec<f32> = (0..d).map(|i| ((i * 5) % 13) as f32 - 6.0).collect();
        let mut a = base.clone();
        let mut b = base.clone();
        weighted_combine_into(&mut a, 0.4, &[&p1, &p2], &[0.3, 0.3]);
        weighted_combine_blocked_into(&mut b, 0.4, &[&p1, &p2], &[0.3, 0.3]);
        assert!(max_abs_diff(&a, &b) < 1e-5, "blocked into diverged");
    }

    #[test]
    fn blocked_combine_into_empty_parts_scales() {
        let mut a = vec![2.0f32, -4.0];
        weighted_combine_blocked_into(&mut a, 0.5, &[], &[]);
        assert_eq!(a, vec![1.0, -2.0]);
    }

    #[test]
    fn par_combine_matches_serial_above_threshold() {
        let d = PAR_MIN_ELEMS + 123;
        let base: Vec<f32> = (0..d).map(|i| ((i * 3) % 23) as f32 - 11.0).collect();
        let parts: Vec<Vec<f32>> =
            (0..4).map(|k| (0..d).map(|i| ((i * 7 + k * 13) % 29) as f32 - 14.0).collect()).collect();
        let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
        let ws = [0.2f32, 0.3, 0.25, 0.25];
        let mut serial = base.clone();
        weighted_combine_blocked_into(&mut serial, 0.4, &refs, &ws);
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let mut par = base.clone();
            weighted_combine_blocked_into_par(&pool, &mut par, 0.4, &refs, &ws);
            let same = serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "par combine diverged at {threads} threads");
        }
    }

    #[test]
    fn norms_and_dots() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
