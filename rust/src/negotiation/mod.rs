//! Negotiation service (paper §VI-C).
//!
//! In BlueFog, rank 0 runs a negotiation daemon: every collective request is
//! announced to it; the daemon waits until *all* ranks announced the same
//! operation (readiness — ranks may issue ops in different orders), sanity
//! checks that the operations match (same kind, same element count), and —
//! for dynamic topologies — that the user-provided `src_weights` /
//! `dst_weights` are globally consistent, so a mismatched declaration
//! surfaces as an **error** instead of a hang. Only then does it release the
//! ranks to run the heavy tensor communication.
//!
//! The service additionally performs *resolution* for one-sided
//! declarations: in pure push-style partial averaging only the senders know
//! the edges (`dst_weights`), so the service tells every receiver which
//! ranks will push to it — the "synchronizes the ranks of sending and
//! receiving among the entire network" step of §VI-C. Symmetrically for
//! pure pull-style.
//!
//! Here the daemon is a dedicated thread owned by the launcher. The
//! virtual-clock cost of a negotiation round is that of a scalar
//! gather-to-0 + broadcast, which the service computes from the announced
//! per-rank times — matching the paper's claim that the check "only adds a
//! small overhead … since it is just a scalar".

use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::simnet::NetworkModel;

/// Operation kinds the service can match.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Global allreduce.
    Allreduce,
    /// Partial (neighborhood) averaging.
    NeighborAllreduce,
    /// Two-tier machine-level partial averaging.
    HierarchicalNeighborAllreduce,
    /// Neighborhood gather of raw tensors.
    NeighborAllgather,
    /// One-to-all broadcast.
    Broadcast,
    /// Synchronization barrier.
    Barrier,
    /// One-sided window operation.
    WinOp,
}

impl OpKind {
    fn name(&self) -> &'static str {
        match self {
            OpKind::Allreduce => "allreduce",
            OpKind::NeighborAllreduce => "neighbor_allreduce",
            OpKind::HierarchicalNeighborAllreduce => "hierarchical_neighbor_allreduce",
            OpKind::NeighborAllgather => "neighbor_allgather",
            OpKind::Broadcast => "broadcast",
            OpKind::Barrier => "barrier",
            OpKind::WinOp => "win_op",
        }
    }
}

/// A rank's announcement of a pending collective.
///
/// `dsts`/`srcs` use `Option`: `None` means *not declared* — the service
/// resolves the side from the other ranks' declarations; `Some(ranks)` is a
/// binding declaration that must be globally consistent.
#[derive(Debug, Clone)]
pub struct OpRequest {
    /// Announcing rank.
    pub rank: usize,
    /// Operation name (unique per call site + round).
    pub name: String,
    /// Which collective is pending.
    pub kind: OpKind,
    /// Elements in the tensor (0 for barrier).
    pub numel: usize,
    /// Ranks this node will send to.
    pub dsts: Option<Vec<usize>>,
    /// Ranks this node expects to receive from.
    pub srcs: Option<Vec<usize>>,
    /// Announcer's virtual time at submission.
    pub vtime: f64,
}

/// Outcome returned to every participating rank.
#[derive(Debug, Clone)]
pub struct OpClearance {
    /// Virtual time at which the rank may start the tensor communication
    /// (after the scalar negotiation round completed).
    pub start_vtime: f64,
    /// Error message when validation failed.
    pub error: Option<String>,
    /// Ranks that will send to this rank (resolved union of declarations).
    pub resolved_srcs: Vec<usize>,
    /// Ranks this rank must send to (resolved union of declarations).
    pub resolved_dsts: Vec<usize>,
}

enum ServiceMsg {
    Submit(OpRequest, Sender<OpClearance>),
    Shutdown,
}

/// Cloneable client handle used by [`crate::context::NodeContext`].
#[derive(Clone)]
pub struct NegotiationClient {
    tx: Sender<ServiceMsg>,
}

impl NegotiationClient {
    /// Announce an operation and block until all ranks are ready and the
    /// sanity checks pass. Returns the clearance (with the negotiated start
    /// virtual time and resolved edges) or the validation error.
    pub fn submit(&self, req: OpRequest) -> anyhow::Result<OpClearance> {
        let (tx, rx) = channel();
        self.tx
            .send(ServiceMsg::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("negotiation service down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("negotiation service dropped request"))
    }
}

/// The rank-0 negotiation daemon.
pub struct NegotiationService {
    tx: Sender<ServiceMsg>,
    handle: Option<JoinHandle<()>>,
}

impl NegotiationService {
    /// Spawn the service for `size` ranks over the given network model.
    pub fn spawn(size: usize, net: NetworkModel) -> Self {
        let alive = Arc::new((0..size).map(|_| AtomicBool::new(true)).collect());
        Self::spawn_with_liveness(size, net, alive)
    }

    /// Spawn with a shared per-rank liveness array (cleared by the
    /// launcher's exit guards). A batch whose missing announcers are all
    /// dead is resolved among the present ranks — with dead peers
    /// filtered from the resolved edge sets — instead of waiting
    /// forever, so a crash mid-round surfaces as a short survivor round
    /// rather than a hang.
    pub fn spawn_with_liveness(
        size: usize,
        net: NetworkModel,
        alive: Arc<Vec<AtomicBool>>,
    ) -> Self {
        let (tx, rx) = channel();
        let handle = std::thread::Builder::new()
            .name("bf-negotiation".into())
            .spawn(move || service_loop(size, net, rx, alive))
            .expect("spawn negotiation service");
        NegotiationService { tx, handle: Some(handle) }
    }

    /// A cloneable client handle for node threads.
    pub fn client(&self) -> NegotiationClient {
        NegotiationClient { tx: self.tx.clone() }
    }
}

impl Drop for NegotiationService {
    fn drop(&mut self) {
        let _ = self.tx.send(ServiceMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn service_loop(
    size: usize,
    net: NetworkModel,
    rx: Receiver<ServiceMsg>,
    alive: Arc<Vec<AtomicBool>>,
) {
    // Pending announcements per op name (readiness across ranks).
    let mut pending: HashMap<String, Vec<(OpRequest, Sender<OpClearance>)>> = HashMap::new();
    loop {
        // The timeout is the daemon's failure-detection heartbeat: quiet
        // periods trigger a sweep of batches whose missing announcers
        // have all exited. Wall-clock only — it decides *when* the
        // survivor round is discovered, never its virtual-time pricing.
        let msg = match rx.recv_timeout(std::time::Duration::from_millis(10)) {
            Ok(m) => m,
            Err(RecvTimeoutError::Timeout) => {
                sweep_dead(&mut pending, &net, size, &alive);
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break,
        };
        match msg {
            ServiceMsg::Shutdown => break,
            ServiceMsg::Submit(req, reply) => {
                let name = req.name.clone();
                let entry = pending.entry(name.clone()).or_default();
                entry.push((req, reply));
                if entry.len() == size {
                    let batch = pending.remove(&name).unwrap();
                    respond(&batch, &net, size, &[]);
                }
            }
        }
    }
}

/// Release every pending batch whose missing announcers are all dead,
/// resolving among the present ranks.
fn sweep_dead(
    pending: &mut HashMap<String, Vec<(OpRequest, Sender<OpClearance>)>>,
    net: &NetworkModel,
    size: usize,
    alive: &[AtomicBool],
) {
    let dead: Vec<usize> = (0..size).filter(|&r| !alive[r].load(Ordering::Acquire)).collect();
    if dead.is_empty() {
        return;
    }
    let ready: Vec<String> = pending
        .iter()
        .filter(|(_, batch)| {
            let present: BTreeSet<usize> = batch.iter().map(|(r, _)| r.rank).collect();
            (0..size).all(|r| present.contains(&r) || dead.contains(&r))
        })
        .map(|(name, _)| name.clone())
        .collect();
    for name in ready {
        let batch = pending.remove(&name).unwrap();
        respond(&batch, net, size, &dead);
    }
}

/// Validate a complete batch, resolve one-sided declarations, release
/// ranks. `dead` ranks are filtered from the resolved edge sets so
/// survivors never wait on a crashed peer the service already knows
/// about.
fn respond(
    batch: &[(OpRequest, Sender<OpClearance>)],
    net: &NetworkModel,
    size: usize,
    dead: &[usize],
) {
    let reqs: Vec<OpRequest> = batch.iter().map(|(r, _)| r.clone()).collect();
    let mut clearances = resolve_batch(&reqs, net, size);
    for c in &mut clearances {
        c.resolved_srcs.retain(|r| !dead.contains(r));
        c.resolved_dsts.retain(|r| !dead.contains(r));
    }
    for ((_, reply), clearance) in batch.iter().zip(clearances) {
        let _ = reply.send(clearance);
    }
}

/// Pure batch resolution: validate, resolve one-sided edge declarations,
/// and price the scalar gather/broadcast round. Returns one clearance per
/// request, **in batch order**. Shared by the threaded service loop and
/// the inline [`Rendezvous`] so both execution modes negotiate
/// identically.
pub fn resolve_batch(batch: &[OpRequest], net: &NetworkModel, size: usize) -> Vec<OpClearance> {
    let error = validate(batch, size);
    // Resolve edge sets: a send edge i->j exists when i declared j as dst
    // or j declared i as src.
    let mut send_edges: Vec<Vec<usize>> = vec![vec![]; size]; // by sender
    let mut recv_edges: Vec<Vec<usize>> = vec![vec![]; size]; // by receiver
    if error.is_none() {
        for r in batch {
            if let Some(dsts) = &r.dsts {
                for &d in dsts {
                    push_unique(&mut send_edges[r.rank], d);
                    push_unique(&mut recv_edges[d], r.rank);
                }
            }
            if let Some(srcs) = &r.srcs {
                for &s in srcs {
                    push_unique(&mut send_edges[s], r.rank);
                    push_unique(&mut recv_edges[r.rank], s);
                }
            }
        }
        for v in send_edges.iter_mut().chain(recv_edges.iter_mut()) {
            v.sort_unstable();
        }
    }
    // Scalar negotiation round: gather to rank 0, broadcast back.
    let gather_done = batch
        .iter()
        .map(|r| r.vtime + net.latency(r.rank, 0))
        .fold(0.0f64, f64::max);
    batch
        .iter()
        .map(|req| OpClearance {
            start_vtime: gather_done + net.latency(0, req.rank),
            error: error.clone(),
            resolved_srcs: recv_edges.get(req.rank).cloned().unwrap_or_default(),
            resolved_dsts: send_edges.get(req.rank).cloned().unwrap_or_default(),
        })
        .collect()
}

fn push_unique(v: &mut Vec<usize>, x: usize) {
    if !v.contains(&x) {
        v.push(x);
    }
}

fn validate(batch: &[OpRequest], size: usize) -> Option<String> {
    let kind = batch[0].kind;
    if let Some(r) = batch.iter().find(|r| r.kind != kind) {
        return Some(format!(
            "operation mismatch for '{}': rank {} issued {} while others issued {}",
            r.name,
            r.rank,
            r.kind.name(),
            kind.name()
        ));
    }
    let numel = batch[0].numel;
    if kind != OpKind::NeighborAllgather {
        if let Some(r) = batch.iter().find(|r| r.numel != numel) {
            return Some(format!(
                "tensor size mismatch for '{}': rank {} announced {} elements, rank {} announced {}",
                r.name, batch[0].rank, numel, r.rank, r.numel
            ));
        }
    }
    // Index declarations by rank for the topology cross-check.
    let mut by_rank: Vec<Option<&OpRequest>> = vec![None; size];
    for r in batch {
        if r.rank >= size {
            return Some(format!("invalid rank {} (size {})", r.rank, size));
        }
        by_rank[r.rank] = Some(r);
    }
    // Topology check (paper §VI-C): a declared send i->j conflicts when j
    // *also declared* its sources and did not list i; symmetrically for
    // declared receives. One-sided declarations are resolved, not errors.
    for r in batch {
        if let Some(dsts) = &r.dsts {
            for &dst in dsts {
                if dst >= size {
                    return Some(format!(
                        "invalid destination {} from rank {} (size {})",
                        dst, r.rank, size
                    ));
                }
                if let Some(Some(peer)) = by_rank.get(dst) {
                    if let Some(peer_srcs) = &peer.srcs {
                        if !peer_srcs.contains(&r.rank) {
                            return Some(format!(
                                "topology mismatch for '{}': rank {} pushes to rank {dst} but rank {dst} does not list it in src_weights",
                                r.name, r.rank
                            ));
                        }
                    }
                }
            }
        }
        if let Some(srcs) = &r.srcs {
            for &src in srcs {
                if src >= size {
                    return Some(format!(
                        "invalid source {} at rank {} (size {})",
                        src, r.rank, size
                    ));
                }
                if let Some(Some(peer)) = by_rank.get(src) {
                    if let Some(peer_dsts) = &peer.dsts {
                        if !peer_dsts.contains(&r.rank) {
                            return Some(format!(
                                "topology mismatch for '{}': rank {} pulls from rank {src} but rank {src} does not list it in dst_weights",
                                r.name, r.rank
                            ));
                        }
                    }
                }
            }
        }
    }
    None
}

/// Inline negotiation rendezvous for `ExecMode::EventLoop`.
///
/// The threaded backend parks ranks inside a channel `recv` to the
/// negotiation daemon — invisible to the virtual-time scheduler. Here the
/// first `n-1` submitters park on the scheduler (`Negotiate`), and the
/// **last** submitter resolves the batch inline via [`resolve_batch`]
/// (identical validation/resolution/pricing), stores the peers'
/// clearances, and pushes one `Clearance` event per peer at its
/// `start_vtime` — which is `>=` every submit-time clock, so grant vtimes
/// stay monotone.
pub struct Rendezvous {
    size: usize,
    net: NetworkModel,
    state: std::sync::Mutex<RendezvousState>,
}

struct RendezvousState {
    pending: HashMap<String, Vec<OpRequest>>,
    ready: HashMap<(String, usize), OpClearance>,
    exited: BTreeSet<usize>,
}

/// A batch is releasable when every rank either announced or exited.
fn batch_complete(entry: &[OpRequest], exited: &BTreeSet<usize>, size: usize) -> bool {
    if entry.len() == size {
        return true;
    }
    let present: BTreeSet<usize> = entry.iter().map(|r| r.rank).collect();
    (0..size).all(|r| present.contains(&r) || exited.contains(&r))
}

impl Rendezvous {
    /// New rendezvous for `size` ranks over the given network model.
    pub fn new(size: usize, net: NetworkModel) -> Self {
        Rendezvous {
            size,
            net,
            state: std::sync::Mutex::new(RendezvousState {
                pending: HashMap::new(),
                ready: HashMap::new(),
                exited: BTreeSet::new(),
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RendezvousState> {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Resolve a releasable batch: price it, filter exited ranks from
    /// the resolved edge sets, stash peers' clearances and wake them.
    /// Returns the clearance for `own_rank` if it is in the batch.
    fn release(
        st: &mut RendezvousState,
        net: &NetworkModel,
        size: usize,
        name: &str,
        own_rank: Option<usize>,
        sched: &crate::simnet::event::Scheduler,
    ) -> Option<OpClearance> {
        let batch = st.pending.remove(name).expect("releasable batch exists");
        let mut clearances = resolve_batch(&batch, net, size);
        for c in &mut clearances {
            c.resolved_srcs.retain(|r| !st.exited.contains(r));
            c.resolved_dsts.retain(|r| !st.exited.contains(r));
        }
        let mut own = None;
        for (peer, clearance) in batch.iter().zip(clearances) {
            if Some(peer.rank) == own_rank {
                own = Some(clearance);
            } else {
                let at = clearance.start_vtime;
                st.ready.insert((name.to_string(), peer.rank), clearance);
                // Clearance events to exited ranks are discarded by the
                // scheduler (the actor is parked Finished).
                sched.notify_clearance(peer.rank, at);
            }
        }
        own
    }

    /// Announce an operation; parks on `sched` until the batch completes.
    /// Semantically identical to [`NegotiationClient::submit`].
    pub fn submit(
        &self,
        req: OpRequest,
        sched: &crate::simnet::event::Scheduler,
    ) -> anyhow::Result<OpClearance> {
        let rank = req.rank;
        let name = req.name.clone();
        {
            let mut st = self.lock();
            st.pending.entry(name.clone()).or_default().push(req);
            let entry = st.pending.get(&name).expect("just inserted");
            if batch_complete(entry, &st.exited, self.size) {
                let own = Self::release(&mut st, &self.net, self.size, &name, Some(rank), sched);
                return Ok(own.expect("own request is in the batch"));
            }
        }
        sched.block_negotiate(rank);
        self.lock()
            .ready
            .remove(&(name, rank))
            .ok_or_else(|| anyhow::anyhow!("rendezvous clearance missing after wakeup"))
    }

    /// Notify the rendezvous that `rank` left its node body (crash or
    /// normal exit). Any pending batch now missing only exited ranks is
    /// resolved among the present announcers so survivors parked in
    /// `block_negotiate` wake with a clearance instead of deadlocking
    /// into the watchdog.
    pub fn rank_exited(&self, rank: usize, sched: &crate::simnet::event::Scheduler) {
        let mut st = self.lock();
        if !st.exited.insert(rank) {
            return;
        }
        let releasable: Vec<String> = st
            .pending
            .iter()
            .filter(|(_, batch)| batch_complete(batch, &st.exited, self.size))
            .map(|(name, _)| name.clone())
            .collect();
        for name in releasable {
            Self::release(&mut st, &self.net, self.size, &name, None, sched);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::NetworkModel;

    fn req(
        rank: usize,
        name: &str,
        dsts: Option<Vec<usize>>,
        srcs: Option<Vec<usize>>,
    ) -> OpRequest {
        OpRequest {
            rank,
            name: name.into(),
            kind: OpKind::NeighborAllreduce,
            numel: 16,
            dsts,
            srcs,
            vtime: rank as f64 * 1e-6,
        }
    }

    fn submit_all(reqs: Vec<OpRequest>) -> Vec<OpClearance> {
        let n = reqs.len();
        let svc = NegotiationService::spawn(n, NetworkModel::flat(1e9, 1e-5));
        let handles: Vec<_> = reqs
            .into_iter()
            .map(|r| {
                let c = svc.client();
                let rank = r.rank;
                (rank, std::thread::spawn(move || c.submit(r).unwrap()))
            })
            .collect();
        let mut out = vec![None; n];
        for (rank, h) in handles {
            out[rank] = Some(h.join().unwrap());
        }
        out.into_iter().map(Option::unwrap).collect()
    }

    #[test]
    fn consistent_ring_clears() {
        let n = 4;
        let reqs: Vec<_> = (0..n)
            .map(|i| req(i, "nar.0", Some(vec![(i + 1) % n]), Some(vec![(i + n - 1) % n])))
            .collect();
        let outs = submit_all(reqs);
        assert!(outs.iter().all(|o| o.error.is_none()));
        // Clearance time includes the scalar round-trip latency.
        assert!(outs.iter().all(|o| o.start_vtime > 0.0));
    }

    #[test]
    fn missing_src_declaration_is_detected_not_hung() {
        // Rank 0 pushes to 1, but rank 1 declared its sources without 0 (the
        // paper's example of a program that would hang without the check).
        let reqs = vec![
            req(0, "nar.0", Some(vec![1]), Some(vec![1])),
            req(1, "nar.0", Some(vec![0]), Some(vec![])),
        ];
        let outs = submit_all(reqs);
        assert!(outs.iter().all(|o| o.error.is_some()));
        let msg = outs[0].error.clone().unwrap();
        assert!(msg.contains("topology mismatch"), "{msg}");
    }

    #[test]
    fn pure_push_style_resolves_receivers() {
        // Only senders declare: the service must tell rank 2 who pushes.
        let reqs = vec![
            req(0, "push.0", Some(vec![2]), None),
            req(1, "push.0", Some(vec![2]), None),
            req(2, "push.0", Some(vec![0]), None),
        ];
        let outs = submit_all(reqs);
        assert!(outs.iter().all(|o| o.error.is_none()));
        assert_eq!(outs[2].resolved_srcs, vec![0, 1]);
        assert_eq!(outs[0].resolved_srcs, vec![2]);
        assert_eq!(outs[0].resolved_dsts, vec![2]);
    }

    #[test]
    fn pure_pull_style_resolves_senders() {
        let reqs = vec![
            req(0, "pull.0", None, Some(vec![1, 2])),
            req(1, "pull.0", None, Some(vec![])),
            req(2, "pull.0", None, Some(vec![0])),
        ];
        let outs = submit_all(reqs);
        assert!(outs.iter().all(|o| o.error.is_none()));
        assert_eq!(outs[1].resolved_dsts, vec![0]);
        assert_eq!(outs[2].resolved_dsts, vec![0]);
        assert_eq!(outs[0].resolved_dsts, vec![2]);
        assert_eq!(outs[0].resolved_srcs, vec![1, 2]);
    }

    #[test]
    fn size_mismatch_detected() {
        let mut a = req(0, "nar.1", Some(vec![1]), Some(vec![1]));
        let mut b = req(1, "nar.1", Some(vec![0]), Some(vec![0]));
        a.numel = 16;
        b.numel = 32;
        let outs = submit_all(vec![a, b]);
        assert!(outs[0].error.as_ref().unwrap().contains("size mismatch"));
    }

    #[test]
    fn kind_mismatch_detected() {
        let a = req(0, "op.2", None, None);
        let mut b = req(1, "op.2", None, None);
        b.kind = OpKind::Allreduce;
        let outs = submit_all(vec![a, b]);
        assert!(outs[0].error.as_ref().unwrap().contains("operation mismatch"));
    }

    #[test]
    fn out_of_range_destination_detected() {
        let a = req(0, "op.3", Some(vec![9]), None);
        let b = req(1, "op.3", None, None);
        let outs = submit_all(vec![a, b]);
        assert!(outs[0].error.as_ref().unwrap().contains("invalid destination"));
    }

    #[test]
    fn interleaved_ops_are_matched_by_name() {
        // The announcements for ops A and B arrive at the service in an
        // arbitrary interleaving (in BlueFog, requests are *enqueued* by the
        // background thread, so rank 1's B announcement can reach rank 0's
        // before its A): the readiness logic must pair them by name.
        let svc = NegotiationService::spawn(2, NetworkModel::flat(1e9, 1e-5));
        let submissions = vec![
            req(1, "B", Some(vec![0]), Some(vec![0])),
            req(0, "A", Some(vec![1]), Some(vec![1])),
            req(1, "A", Some(vec![0]), Some(vec![0])),
            req(0, "B", Some(vec![1]), Some(vec![1])),
        ];
        let handles: Vec<_> = submissions
            .into_iter()
            .map(|r| {
                let c = svc.client();
                std::thread::spawn(move || c.submit(r).unwrap())
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap().error.is_none());
        }
    }

    #[test]
    fn clearance_time_reflects_slowest_rank() {
        let mut reqs = vec![
            req(0, "t.0", Some(vec![1]), Some(vec![1])),
            req(1, "t.0", Some(vec![0]), Some(vec![0])),
        ];
        reqs[1].vtime = 1.0; // rank 1 arrives late
        let outs = submit_all(reqs);
        assert!(outs[0].start_vtime >= 1.0, "negotiation waits for the slowest rank");
    }
}
