//! Lightweight measurement utilities for the benchmark harness.
//!
//! Criterion is unavailable offline; this module provides the statistics
//! the paper's figures report: mean, percentiles and the 90% confidence
//! interval of Fig. 11 ("solid points … shaded areas represent 90%
//! confidence interval").

/// Summary statistics over a sample of measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (Bessel-corrected).
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Half-width of the 90% confidence interval of the mean
    /// (normal approximation, z = 1.645).
    pub ci90: f64,
}

impl Stats {
    /// Compute statistics from raw samples. Panics on empty input.
    pub fn from(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty(), "Stats::from on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let std = var.sqrt();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats {
            n,
            mean,
            std,
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            ci90: 1.645 * std / (n as f64).sqrt(),
        }
    }
}

/// Percentile by linear interpolation on a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Measure a closure `reps` times after `warmup` discarded runs, returning
/// per-run wall-clock seconds.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..reps)
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Format a rate (items/s) with an adaptive unit.
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} k/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.2} /s")
    }
}

/// Render a fixed-width table row (used by the bench binaries so the output
/// matches the paper's tables).
pub fn table_row(cells: &[String], widths: &[usize]) -> String {
    let mut out = String::from("|");
    for (c, w) in cells.iter().zip(widths) {
        out.push_str(&format!(" {c:<w$} |", w = w));
    }
    out
}

/// SIMD-relevant ISA feature flags worth recording next to a benchmark
/// number; anything else in `/proc/cpuinfo`'s flag soup is noise here.
const SIMD_FLAGS: [&str; 7] = ["sse2", "avx", "avx2", "fma", "avx512f", "neon", "asimd"];

fn parse_cpuinfo_model(cpuinfo: &str) -> Option<String> {
    cpuinfo
        .lines()
        // x86 calls it "model name", ARM "Processor" or a bare "Hardware".
        .find(|l| l.starts_with("model name") || l.starts_with("Processor"))
        .and_then(|l| l.split_once(':'))
        .map(|(_, v)| v.trim().to_string())
        .filter(|v| !v.is_empty())
}

fn parse_cpuinfo_features(cpuinfo: &str) -> Vec<String> {
    let flags = cpuinfo
        .lines()
        .find(|l| l.starts_with("flags") || l.starts_with("Features"))
        .and_then(|l| l.split_once(':'))
        .map(|(_, v)| v.to_string())
        .unwrap_or_default();
    let present: Vec<&str> = flags.split_whitespace().collect();
    SIMD_FLAGS.iter().filter(|f| present.contains(f)).map(|f| f.to_string()).collect()
}

/// Best-effort CPU model string from `/proc/cpuinfo` ("unknown" when the
/// file or field is unavailable, e.g. non-Linux). Benchmark JSON records
/// it so numbers from different machines are never compared blindly.
pub fn cpu_model() -> String {
    std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| parse_cpuinfo_model(&s))
        .unwrap_or_else(|| "unknown".to_string())
}

/// SIMD-relevant ISA flags the host advertises (subset of
/// sse2/avx/avx2/fma/avx512f/neon/asimd), empty when undetectable.
pub fn cpu_features() -> Vec<String> {
    std::fs::read_to_string("/proc/cpuinfo")
        .map(|s| parse_cpuinfo_features(&s))
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_sample() {
        let s = Stats::from(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.ci90, 0.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn stats_of_known_sample() {
        let s = Stats::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile(&sorted, 0.25), 2.5);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
        assert_eq!(percentile(&[7.0], 0.9), 7.0);
    }

    #[test]
    fn measure_runs_expected_times() {
        let mut count = 0;
        let samples = measure(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(samples.len(), 5);
        assert!(samples.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn formatting_units() {
        assert_eq!(fmt_time(2.5), "2.500 s");
        assert_eq!(fmt_time(0.0025), "2.500 ms");
        assert_eq!(fmt_time(2.5e-6), "2.500 µs");
        assert!(fmt_rate(3.2e6).contains("M/s"));
    }

    #[test]
    #[should_panic]
    fn stats_empty_panics() {
        Stats::from(&[]);
    }

    #[test]
    fn cpuinfo_model_and_flags_parse() {
        let x86 = "processor\t: 0\nmodel name\t: Example CPU @ 3.0GHz\n\
                   flags\t\t: fpu sse2 avx avx2 fma obscure_flag\n";
        assert_eq!(parse_cpuinfo_model(x86).unwrap(), "Example CPU @ 3.0GHz");
        assert_eq!(parse_cpuinfo_features(x86), vec!["sse2", "avx", "avx2", "fma"]);
        let arm = "Processor\t: ARMv8 Core\nFeatures\t: fp asimd evtstrm\n";
        assert_eq!(parse_cpuinfo_model(arm).unwrap(), "ARMv8 Core");
        assert_eq!(parse_cpuinfo_features(arm), vec!["asimd"]);
        assert!(parse_cpuinfo_model("bogus: file\n").is_none());
        assert!(parse_cpuinfo_features("").is_empty());
    }

    #[test]
    fn cpu_probes_never_panic() {
        let _ = cpu_model();
        let _ = cpu_features();
    }
}
