//! Non-blocking communication (paper §V-A, §VI-A).
//!
//! BlueFog's key system optimization: a dedicated per-node **communication
//! thread** consumes a shared request queue, so tensor communication
//! overlaps with local computation. The non-blocking API returns a
//! [`Handle`] immediately; [`Handle::wait`] joins the result
//! (`x = bf.wait(handle)` in Listing 5).
//!
//! Implementation notes:
//! - Each node owns a *second* transport endpoint dedicated to its comm
//!   thread, so in-flight asynchronous exchanges never collide with
//!   blocking ops on the main endpoint. Peers of a non-blocking op must
//!   also issue it non-blocking (as all the provided optimizers do).
//! - The virtual clock models overlap faithfully: a queued op starts at the
//!   *enqueue* virtual time and completes at the communication finish time;
//!   the compute thread's clock only advances to that finish time when it
//!   actually `wait()`s — time spent computing in between is overlapped.
//! - The comm thread applies **tensor fusion** (paper §VI-C). Fusion groups
//!   are assigned *deterministically at enqueue time* from the request
//!   sizes (which follow the SPMD program order, identical on every rank):
//!   a group closes when adding the next tensor would exceed the threshold.
//!   In BlueFog the rank-0 negotiation service plays this coordinating
//!   role; the deterministic size-stream rule achieves the same global
//!   agreement without a round trip. A group is transmitted when the first
//!   request of the *next* group arrives, or when the caller `wait()`s on
//!   one of its handles (which enqueues a flush marker).
//! - **Faults**: the engine's dedicated endpoint is *not* instrumented by
//!   [`crate::simnet::faults::FaultPlan`] — the chaos sweeps exercise the
//!   blocking path, where drops/partitions/deadlines live. What the fault
//!   layer does enforce here is the crash schedule: a rank whose crash
//!   vtime has passed cannot enqueue new non-blocking work (the enqueue
//!   APIs return [`crate::simnet::faults::CommError::SelfCrash`]), so a
//!   crashed rank never parks peers on an exchange it will not complete.

use std::collections::HashMap;
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::compress::{CompressionSpec, CompressionState};
use crate::context::{ef_key, NodeContext, EF_PEER, EF_SHARED};
use crate::fusion::FusionBuffer;
use crate::parallel::WorkerPool;
use crate::pool::{BufferPool, HotPath};
use crate::simnet::NetworkModel;
use crate::transport::{make_tag, op_id, Mailbox, Message, Postman, VClock};

/// How a [`Handle`]'s request reaches the communication engine.
enum Route {
    /// Threads mode: flushed through the comm thread's request channel.
    Thread(Sender<CommRequest>),
    /// EventLoop mode: the engine lives inside the caller's [`NodeContext`]
    /// (`inline_comm`) and is driven synchronously at wait time.
    Inline,
}

/// A non-blocking operation's completion token.
pub struct Handle {
    rx: Receiver<CommResult>,
    /// Fusion group of the request (flushed on wait).
    group: u64,
    route: Route,
    /// The node's group counter/accumulator: waiting on a handle closes the
    /// open group so later requests start a fresh one (every rank waits in
    /// the same program order, so grouping stays globally deterministic).
    group_counter: Arc<std::sync::atomic::AtomicU64>,
    acc_bytes: Arc<std::sync::atomic::AtomicUsize>,
}

impl Handle {
    /// Close the node's open fusion group so later requests start fresh.
    fn close_group(&self) {
        use std::sync::atomic::Ordering;
        if self.group_counter.load(Ordering::Relaxed) == self.group {
            self.group_counter.store(self.group + 1, Ordering::Relaxed);
            self.acc_bytes.store(0, Ordering::Relaxed);
        }
    }
}

#[derive(Debug)]
pub(crate) struct CommResult {
    data: Vec<f32>,
    done_vtime: f64,
}

impl Handle {
    /// Block until the communication finishes; returns the reduced tensor
    /// and advances the caller's virtual clock to the completion time
    /// (`bf.wait(handle)`).
    ///
    /// Under [`crate::launcher::ExecMode::EventLoop`] the flush drives the
    /// rank's inline engine directly (cooperatively yielding to peers while
    /// receives are outstanding); under `Threads` it joins the comm thread's
    /// reply channel. Virtual-time accounting is identical in both modes:
    /// the op starts at its enqueue vtime and `wait` advances the caller to
    /// the completion time, so compute in between is overlapped.
    pub fn wait(self, ctx: &mut NodeContext) -> anyhow::Result<Vec<f32>> {
        self.close_group();
        let res = match &self.route {
            Route::Thread(tx) => {
                let _ = tx.send(CommRequest::Flush(self.group));
                self.rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("communication thread dropped the request"))?
            }
            Route::Inline => {
                let mut engine = ctx
                    .inline_comm
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("inline communication engine missing"))?;
                engine.handle(CommRequest::Flush(self.group));
                ctx.inline_comm = Some(engine);
                self.rx.try_recv().map_err(|_| {
                    anyhow::anyhow!("inline communication engine did not complete the request")
                })?
            }
        };
        ctx.clock().advance_to(res.done_vtime);
        Ok(res.data)
    }

    /// Non-advancing wait, for callers that manage virtual time themselves.
    ///
    /// Only available in `Threads` mode: the inline engine needs the owning
    /// [`NodeContext`] to run, so EventLoop callers must use
    /// [`Handle::wait`].
    pub fn wait_raw(self) -> anyhow::Result<(Vec<f32>, f64)> {
        self.close_group();
        match &self.route {
            Route::Thread(tx) => {
                let _ = tx.send(CommRequest::Flush(self.group));
                let res = self
                    .rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("communication thread dropped the request"))?;
                Ok((res.data, res.done_vtime))
            }
            Route::Inline => anyhow::bail!(
                "wait_raw is unsupported under ExecMode::EventLoop; use Handle::wait"
            ),
        }
    }
}

/// The exchange structure of a queued request (determines fusability).
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct ExchangePlan {
    pub self_weight: f64,
    /// `(src, r_ij)` receive scales.
    pub srcs: Vec<(usize, f64)>,
    /// `(dst, s_ij)` send scales.
    pub dsts: Vec<(usize, f64)>,
    /// Derived from the static topology (destination set stable round over
    /// round) — allows the compressed path to share one difference stream
    /// across the fan-out and apply the mean-conserving self-correction.
    pub static_plan: bool,
}

pub(crate) enum CommRequest {
    NeighborAllreduce {
        group: u64,
        data: Vec<f32>,
        plan: ExchangePlan,
        enqueue_vtime: f64,
        reply: Sender<CommResult>,
    },
    RingAllreduceAvg {
        group: u64,
        data: Vec<f32>,
        enqueue_vtime: f64,
        reply: Sender<CommResult>,
    },
    /// Transmit group `g` even if no later request has arrived.
    Flush(u64),
    Shutdown,
}

/// Cloneable enqueue side of a node's communication thread.
#[derive(Clone)]
pub struct CommQueue {
    tx: Sender<CommRequest>,
}

/// The per-node communication thread.
pub struct CommThread {
    tx: Sender<CommRequest>,
    handle: Option<JoinHandle<()>>,
}

impl CommThread {
    /// Spawn the communication thread for `rank`, owning the node's second
    /// transport endpoint.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        rank: usize,
        size: usize,
        mailbox: Mailbox,
        postman: Postman,
        clocks: Arc<Vec<VClock>>,
        net: Arc<NetworkModel>,
        _fusion_threshold: usize,
        hot_path: HotPath,
        compression: CompressionSpec,
        intra_threads: usize,
        seed: u64,
        tx_bytes: Arc<AtomicU64>,
    ) -> Self {
        let (tx, rx) = channel();
        let handle = std::thread::Builder::new()
            .name(format!("bf-comm-{rank}"))
            .spawn(move || {
                let mut engine = CommEngine::new(
                    rank,
                    size,
                    mailbox,
                    postman,
                    clocks,
                    net,
                    hot_path,
                    compression,
                    intra_threads,
                    seed,
                    tx_bytes,
                    None,
                );
                while let Ok(req) = rx.recv() {
                    let stop = matches!(req, CommRequest::Shutdown);
                    engine.handle(req);
                    if stop {
                        break;
                    }
                }
            })
            .expect("spawn comm thread");
        CommThread { tx, handle: Some(handle) }
    }

    /// A cloneable enqueue handle for this thread's request queue.
    pub fn queue(&self) -> CommQueue {
        CommQueue { tx: self.tx.clone() }
    }
}

impl Drop for CommThread {
    fn drop(&mut self) {
        let _ = self.tx.send(CommRequest::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One group of fusable neighbor requests.
struct PendingGroup {
    group: u64,
    plan: ExchangePlan,
    items: Vec<(Vec<f32>, f64, Sender<CommResult>)>,
}

/// The communication engine: the resumable state machine behind both comm
/// backends. In `Threads` mode a dedicated thread drives it off a request
/// channel; in `EventLoop` mode each rank owns one inline
/// (`NodeContext::inline_comm`) and drives it at enqueue/wait points, with
/// receives routed through the virtual-time scheduler instead of parking an
/// OS thread. Identical request handling in both modes is what the
/// differential parity suite (`tests/exec_parity.rs`) leans on.
pub struct CommEngine {
    rank: usize,
    size: usize,
    mailbox: Mailbox,
    postman: Postman,
    clocks: Arc<Vec<VClock>>,
    net: Arc<NetworkModel>,
    hot_path: HotPath,
    tx_bytes: Arc<AtomicU64>,
    rounds: HashMap<u32, u32>,
    /// Groups are issued in nondecreasing order; at most one is open.
    pending: Option<PendingGroup>,
    /// Groups below this are already done.
    flushed_below: u64,
    /// This engine's buffer pool plus a dedicated fusion-pack allocation,
    /// both reused across rounds (zero-allocation steady state).
    pool: BufferPool,
    fusion_storage: Vec<f32>,
    /// This engine's compression endpoint: fused packs are encoded while
    /// being packed (one pass over the group's bytes, one wire stream) and
    /// decoded before unpacking, with residuals independent of the
    /// blocking path's.
    comp: CompressionState,
    /// Intra-rank worker pool sharding multi-MB combines and codec encodes
    /// issued by this engine (serial when `intra_threads` is 1).
    par: WorkerPool,
    /// Set in EventLoop mode: receives park the rank on the scheduler.
    sched: Option<Arc<crate::simnet::event::Scheduler>>,
}

impl CommEngine {
    /// Build an engine over the node's second transport endpoint. `sched`
    /// is `None` for the comm-thread backend (receives block the thread)
    /// and `Some` for the inline EventLoop backend (receives cooperatively
    /// yield).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        rank: usize,
        size: usize,
        mailbox: Mailbox,
        postman: Postman,
        clocks: Arc<Vec<VClock>>,
        net: Arc<NetworkModel>,
        hot_path: HotPath,
        compression: CompressionSpec,
        intra_threads: usize,
        seed: u64,
        tx_bytes: Arc<AtomicU64>,
        sched: Option<Arc<crate::simnet::event::Scheduler>>,
    ) -> Self {
        let par = WorkerPool::new(intra_threads);
        let comp = CompressionState::new(
            compression,
            seed ^ 0x5eed ^ (rank as u64).wrapping_mul(0xA24BAED4963EE407),
        )
        .with_par(par.clone());
        CommEngine {
            rank,
            size,
            mailbox,
            postman,
            clocks,
            net,
            hot_path,
            tx_bytes,
            rounds: HashMap::new(),
            pending: None,
            flushed_below: 0,
            pool: BufferPool::new(),
            fusion_storage: Vec::new(),
            comp,
            par,
            sched,
        }
    }

    /// Pack and exchange one fusion group, replying to every member.
    ///
    /// With compression on a static fan-out plan, the error-feedback
    /// staging pass is fused into the pack traversal (ISSUE 9 tentpole
    /// layer 3, [`CompressionState::encode_packed`]): each slot's bytes
    /// are staged while still cache-hot from the pack copy, and the
    /// resulting wire stream is handed to the exchange prewired, so the
    /// seed's cold second pass over the multi-MB packed buffer disappears.
    /// Byte-identical to pack-then-encode (same staging values, same RNG
    /// order) — the parity suites cannot tell the difference.
    fn transmit(&mut self, pg: PendingGroup) {
        let tensors: Vec<&[f32]> = pg.items.iter().map(|(d, _, _)| d.as_slice()).collect();
        let fuse_encode = self.comp.enabled() && pg.plan.static_plan && !pg.plan.dsts.is_empty();
        let (buf, prewired) = if fuse_encode {
            let total: usize = tensors.iter().map(|t| t.len()).sum();
            let mut wire = match self.hot_path {
                HotPath::Naive => Vec::with_capacity(self.comp.encoded_cap(total)),
                HotPath::Pooled => {
                    self.pool.checkout_empty(self.comp.encoded_cap(total)).into_vec()
                }
            };
            let key = ef_key(EF_SHARED, 0, 0, total);
            let storage = std::mem::take(&mut self.fusion_storage);
            let buf = self.comp.encode_packed(key, &tensors, storage, &mut wire);
            (buf, Some(Arc::new(wire)))
        } else {
            let storage = std::mem::take(&mut self.fusion_storage);
            (FusionBuffer::pack_into_vec(&tensors, storage), None)
        };
        drop(tensors);
        let start_vtime = pg.items.iter().map(|(_, t, _)| *t).fold(f64::NEG_INFINITY, f64::max);
        let tag = next_tag(&mut self.rounds, "nb.neighbor");
        let mut ep = Endpoint::new(
            self.rank,
            self.size,
            &mut self.mailbox,
            &self.postman,
            &self.clocks,
            &self.net,
            &self.pool,
            &self.par,
            self.hot_path,
            start_vtime,
            &self.tx_bytes,
            self.sched.as_deref(),
        );
        let out = ep.neighbor_exchange(buf.data(), &pg.plan, tag, &mut self.comp, prewired);
        let done_vtime = ep.completion;
        // Scatter-free unpack: each request's own input buffer is
        // overwritten in place and becomes its reply — no per-slot `Vec`.
        for (i, (mut data, _, reply)) in pg.items.into_iter().enumerate() {
            buf.unpack_slot_into(&out, i, &mut data);
            let _ = reply.send(CommResult { data, done_vtime });
        }
        self.fusion_storage = buf.into_data();
        if self.hot_path == HotPath::Pooled {
            self.pool.recycle_vec(out);
        }
    }

    /// Advance the state machine by one request. Fusable neighbor requests
    /// accumulate in the open group; a flush, a newer group, or an unfusable
    /// op transmits it.
    pub(crate) fn handle(&mut self, req: CommRequest) {
        match req {
            CommRequest::Shutdown => {
                if let Some(pg) = self.pending.take() {
                    self.transmit(pg);
                }
            }
            CommRequest::Flush(g) => {
                if g >= self.flushed_below {
                    if let Some(pg) = self.pending.take() {
                        if pg.group <= g {
                            self.flushed_below = pg.group + 1;
                            self.transmit(pg);
                        } else {
                            self.pending = Some(pg);
                        }
                    }
                }
            }
            CommRequest::RingAllreduceAvg { group, data, enqueue_vtime, reply } => {
                // Ring ops are never fused; close any open group first.
                if let Some(pg) = self.pending.take() {
                    self.flushed_below = pg.group + 1;
                    self.transmit(pg);
                }
                self.flushed_below = self.flushed_below.max(group + 1);
                let tag = next_tag(&mut self.rounds, "nb.ring");
                let mut ep = Endpoint::new(
                    self.rank,
                    self.size,
                    &mut self.mailbox,
                    &self.postman,
                    &self.clocks,
                    &self.net,
                    &self.pool,
                    &self.par,
                    self.hot_path,
                    enqueue_vtime,
                    &self.tx_bytes,
                    self.sched.as_deref(),
                );
                // The request's own buffer is reduced in place — no copy.
                let mut out = ep.ring_allreduce(data, tag);
                let done_vtime = ep.completion;
                let inv = 1.0 / self.size as f32;
                for x in out.iter_mut() {
                    *x *= inv;
                }
                let _ = reply.send(CommResult { data: out, done_vtime });
            }
            CommRequest::NeighborAllreduce { group, data, plan, enqueue_vtime, reply } => {
                // A request for a newer group closes the previous one.
                if let Some(pg) = self.pending.take() {
                    if pg.group < group || pg.plan != plan {
                        self.flushed_below = pg.group + 1;
                        self.transmit(pg);
                    } else {
                        self.pending = Some(pg);
                    }
                }
                match self.pending.as_mut() {
                    Some(pg) => pg.items.push((data, enqueue_vtime, reply)),
                    None => {
                        self.pending = Some(PendingGroup {
                            group,
                            plan,
                            items: vec![(data, enqueue_vtime, reply)],
                        });
                    }
                }
            }
        }
    }
}

fn next_tag(rounds: &mut HashMap<u32, u32>, name: &str) -> u64 {
    let id = op_id(name);
    let round = rounds.entry(id).or_insert(0);
    let tag = make_tag(id, round.wrapping_mul(4096));
    *round = round.wrapping_add(1);
    tag
}

/// A transport endpoint with virtual-time tracking decoupled from the
/// node's compute clock: ops start at the enqueue time, reserve the shared
/// NIC ports, and record their own completion time.
struct Endpoint<'a> {
    rank: usize,
    size: usize,
    mailbox: &'a mut Mailbox,
    postman: &'a Postman,
    clocks: &'a Arc<Vec<VClock>>,
    net: &'a Arc<NetworkModel>,
    /// The communication thread's buffer pool (payloads + combine scratch).
    pool: &'a BufferPool,
    /// Intra-rank worker pool for sharded combines (serial = seed path).
    par: &'a WorkerPool,
    /// Pooled/blocked vs naive implementation switch.
    hot_path: HotPath,
    /// Virtual time the operation became eligible to run.
    base_vtime: f64,
    /// Running completion time (max over receives).
    completion: f64,
    /// The node's wire-byte counter (shared with the blocking context).
    tx_bytes: &'a AtomicU64,
    /// EventLoop mode: receives park the owning rank on the scheduler and
    /// sends post wakeup events, instead of blocking an OS thread.
    sched: Option<&'a crate::simnet::event::Scheduler>,
}

impl<'a> Endpoint<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        rank: usize,
        size: usize,
        mailbox: &'a mut Mailbox,
        postman: &'a Postman,
        clocks: &'a Arc<Vec<VClock>>,
        net: &'a Arc<NetworkModel>,
        pool: &'a BufferPool,
        par: &'a WorkerPool,
        hot_path: HotPath,
        base_vtime: f64,
        tx_bytes: &'a AtomicU64,
        sched: Option<&'a crate::simnet::event::Scheduler>,
    ) -> Self {
        Endpoint {
            rank,
            size,
            mailbox,
            postman,
            clocks,
            net,
            pool,
            par,
            hot_path,
            base_vtime,
            completion: base_vtime,
            tx_bytes,
            sched,
        }
    }

    /// Pooled (or naive) copy of `src` as an `Arc` payload (mode-gated,
    /// shared policy in [`BufferPool::payload_from`]).
    fn payload_from(&self, src: &[f32]) -> Arc<Vec<f32>> {
        self.pool.payload_from(self.hot_path, src)
    }

    /// Pooled (or naive) `s * src` payload in one fused pass.
    fn scaled_payload(&self, src: &[f32], s: f32) -> Arc<Vec<f32>> {
        self.pool.scaled_payload(self.hot_path, src, s)
    }

    /// Hand a finished receive payload back to the pool.
    fn reclaim(&self, payload: Arc<Vec<f32>>) {
        self.pool.reclaim_if(self.hot_path, payload);
    }

    /// Encode/decode scratch: pooled under [`HotPath::Pooled`], fresh
    /// allocation under [`HotPath::Naive`] (keeps the A/B honest even when
    /// compression is on).
    fn codec_scratch(&self, cap: usize) -> Vec<f32> {
        match self.hot_path {
            HotPath::Naive => Vec::with_capacity(cap),
            HotPath::Pooled => self.pool.checkout_empty(cap).into_vec(),
        }
    }

    fn send(&mut self, dst: usize, tag: u64, payload: Arc<Vec<f32>>) {
        let bytes = payload.len() * 4;
        self.tx_bytes.fetch_add(bytes as u64, std::sync::atomic::Ordering::Relaxed);
        let ser = self.net.port_time(self.rank, dst, bytes);
        let send_done = self.clocks[self.rank].reserve_send(self.base_vtime, ser);
        let recv_done = self.clocks[dst].reserve_recv(send_done - ser, ser);
        let arrival = send_done.max(recv_done) + self.net.latency(self.rank, dst);
        let _ = self.postman.send(
            dst,
            Message { src: self.rank, tag, payload, arrival_vtime: arrival },
        );
        if let Some(sched) = self.sched {
            sched.notify_message(dst, arrival);
        }
    }

    fn recv(&mut self, src: usize, tag: u64) -> Arc<Vec<f32>> {
        let msg = match self.sched {
            // EventLoop: drain what's already queued, then park the rank on
            // the scheduler until a message event wakes it.
            Some(sched) => loop {
                if let Some(m) = self.mailbox.try_recv_match(src, tag) {
                    break m;
                }
                sched.block_recv(self.rank, "comm engine recv");
            },
            None => self.mailbox.recv_match(src, tag).expect("comm endpoint closed"),
        };
        self.completion = self.completion.max(msg.arrival_vtime);
        msg.payload
    }

    /// Partial-averaging exchange with explicit plan (srcs/dsts resolved by
    /// the caller). With compression enabled, the (possibly fused) payload
    /// is encoded once per distinct wire stream — one stream covers the
    /// whole fusion group — and every receive is decoded into pooled
    /// scratch before the combine. `prewired` carries a shared-stream wire
    /// already produced by the fused pack+encode; the compressed path uses
    /// it instead of re-encoding.
    fn neighbor_exchange(
        &mut self,
        data: &[f32],
        plan: &ExchangePlan,
        tag: u64,
        comp: &mut CompressionState,
        prewired: Option<Arc<Vec<f32>>>,
    ) -> Vec<f32> {
        let n = self.size;
        let me = self.rank;
        let mut dsts = plan.dsts.clone();
        dsts.sort_by_key(|&(d, _)| (d + n - me) % n);
        if comp.enabled() {
            return self.compressed_exchange(data, plan, &dsts, tag, comp, prewired);
        }
        debug_assert!(prewired.is_none(), "prewired stream without compression");
        let mut shared: Option<Arc<Vec<f32>>> = None;
        for &(dst, s) in &dsts {
            if s != 1.0 {
                let payload = self.scaled_payload(data, s as f32);
                self.send(dst, tag, payload);
            } else {
                let p = shared.get_or_insert_with(|| self.payload_from(data)).clone();
                self.send(dst, tag, p);
            }
        }
        drop(shared);
        let mut incoming: Vec<(f32, Arc<Vec<f32>>)> = Vec::with_capacity(plan.srcs.len());
        for &(src, r) in &plan.srcs {
            let y = self.recv(src, tag);
            incoming.push((r as f32, y));
        }
        let parts: Vec<&[f32]> = incoming.iter().map(|(_, y)| y.as_slice()).collect();
        let ws: Vec<f32> = incoming.iter().map(|(r, _)| *r).collect();
        let w0 = plan.self_weight as f32;
        let out = self.pool.combine_from_par(self.hot_path, data, w0, &parts, &ws, self.par);
        drop(parts);
        for (_, y) in incoming {
            self.reclaim(y);
        }
        out
    }

    /// Compressed variant of [`Endpoint::neighbor_exchange`]; mirrors the
    /// blocking path's policy: static plans share one difference stream
    /// across the fan-out and apply the mean-conserving self-correction,
    /// explicit-weight plans (whose destination sets may vary) keep one
    /// stream per destination and combine plainly. Fused packs ride a
    /// single stream id (0): the pack layout is part of the stream. When
    /// the fused pack+encode already produced the shared-stream wire, it
    /// arrives in `prewired` and the lazy encode below is skipped.
    fn compressed_exchange(
        &mut self,
        data: &[f32],
        plan: &ExchangePlan,
        dsts_sorted: &[(usize, f64)],
        tag: u64,
        comp: &mut CompressionState,
        mut prewired: Option<Arc<Vec<f32>>>,
    ) -> Vec<f32> {
        let d = data.len();
        let cap = comp.encoded_cap(d);
        let shared_key = ef_key(EF_SHARED, 0, 0, d);
        let mut shared: Option<Arc<Vec<f32>>> = None;
        for &(dst, s) in dsts_sorted {
            if !plan.static_plan {
                let mut wire = self.codec_scratch(cap);
                if s != 1.0 {
                    let mut scaled = self.codec_scratch(d);
                    scaled.extend(data.iter().map(|&x| s as f32 * x));
                    comp.encode(ef_key(EF_PEER, 0, dst, d), &scaled, &mut wire);
                    if self.hot_path == HotPath::Pooled {
                        self.pool.recycle_vec(scaled);
                    }
                } else {
                    comp.encode(ef_key(EF_PEER, 0, dst, d), data, &mut wire);
                }
                self.send(dst, tag, Arc::new(wire));
            } else {
                let p = match &shared {
                    Some(p) => p.clone(),
                    None => {
                        let p = match prewired.take() {
                            Some(wire) => wire,
                            None => {
                                let mut wire = self.codec_scratch(cap);
                                comp.encode(shared_key, data, &mut wire);
                                Arc::new(wire)
                            }
                        };
                        shared = Some(p.clone());
                        p
                    }
                };
                self.send(dst, tag, p);
            }
        }
        drop(shared);
        let had_shared = plan.static_plan && !dsts_sorted.is_empty();
        let mut incoming: Vec<(f32, Vec<f32>)> = Vec::with_capacity(plan.srcs.len());
        for &(src, r) in &plan.srcs {
            let y = self.recv(src, tag);
            let mut dec = self.codec_scratch(d);
            comp.decode(ef_key(EF_PEER, 0, src, d), &y, &mut dec)
                .expect("malformed compressed stream from peer");
            assert_eq!(dec.len(), d, "compressed stream length mismatch from rank {src}");
            self.reclaim(y);
            incoming.push((r as f32, dec));
        }
        let mut parts: Vec<&[f32]> = incoming.iter().map(|(_, y)| y.as_slice()).collect();
        let mut ws: Vec<f32> = incoming.iter().map(|(r, _)| *r).collect();
        let correct = had_shared && comp.spec().error_feedback;
        let w0 = plan.self_weight as f32;
        let out = match comp.estimate(shared_key) {
            Some(est) if correct => {
                // CHOCO-style relaxed, mean-conserving combine (see the
                // blocking twin in collective::neighbor).
                let gamma = comp.spec().gossip_gamma;
                for w in ws.iter_mut() {
                    *w *= gamma;
                }
                parts.push(est);
                ws.push(-gamma * (1.0 - w0));
                self.pool.combine_from_par(self.hot_path, data, 1.0, &parts, &ws, self.par)
            }
            _ => self.pool.combine_from_par(self.hot_path, data, w0, &parts, &ws, self.par),
        };
        drop(parts);
        for (_, y) in incoming {
            if self.hot_path == HotPath::Pooled {
                self.pool.recycle_vec(y);
            }
        }
        out
    }

    /// Chunked ring allreduce (sum) over all ranks, reducing `buf` in place.
    fn ring_allreduce(&mut self, mut buf: Vec<f32>, tag: u64) -> Vec<f32> {
        let n = self.size;
        let me = self.rank;
        if n == 1 {
            return buf;
        }
        let len = buf.len();
        let bounds: Vec<(usize, usize)> =
            (0..n).map(|c| (c * len / n, (c + 1) * len / n)).collect();
        let next = (me + 1) % n;
        let prev = (me + n - 1) % n;
        for r in 0..(n - 1) {
            let send_c = (me + n - r) % n;
            let recv_c = (me + n - r - 1) % n;
            let (slo, shi) = bounds[send_c];
            let payload = self.payload_from(&buf[slo..shi]);
            self.send(next, tag + r as u64, payload);
            let incoming = self.recv(prev, tag + r as u64);
            let (rlo, rhi) = bounds[recv_c];
            for (x, y) in buf[rlo..rhi].iter_mut().zip(incoming.iter()) {
                *x += y;
            }
            self.reclaim(incoming);
        }
        for r in 0..(n - 1) {
            let send_c = (me + 1 + n - r) % n;
            let recv_c = (me + n - r) % n;
            let (slo, shi) = bounds[send_c];
            let payload = self.payload_from(&buf[slo..shi]);
            self.send(next, tag + n as u64 + r as u64, payload);
            let incoming = self.recv(prev, tag + n as u64 + r as u64);
            let (rlo, rhi) = bounds[recv_c];
            buf[rlo..rhi].copy_from_slice(&incoming);
            self.reclaim(incoming);
        }
        buf
    }
}

impl NodeContext {
    /// Deterministic fusion-group assignment: a group closes when adding
    /// this request would exceed the fusion threshold (threshold 0: every
    /// request is its own group). Driven purely by the program-order size
    /// stream, so all ranks agree.
    fn assign_fusion_group(&mut self, bytes: usize) -> u64 {
        use std::sync::atomic::Ordering;
        if self.fusion_threshold == 0 {
            return self.fusion_group.fetch_add(1, Ordering::Relaxed) + 1;
        }
        let acc = self.fusion_acc_bytes.load(Ordering::Relaxed);
        if acc > 0 && acc + bytes > self.fusion_threshold {
            self.fusion_group.fetch_add(1, Ordering::Relaxed);
            self.fusion_acc_bytes.store(0, Ordering::Relaxed);
        }
        self.fusion_acc_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.fusion_group.load(Ordering::Relaxed)
    }

    /// `bf.neighbor_allreduce_nonblocking(...)` — enqueue a partial
    /// averaging on the communication thread and return immediately.
    ///
    /// The plan must be fully specified: pass explicit weights, or omit
    /// them to use the static topology's local view.
    pub fn neighbor_allreduce_nonblocking(
        &mut self,
        data: &[f32],
        weights: Option<&crate::collective::neighbor::NeighborWeights>,
    ) -> anyhow::Result<Handle> {
        self.fault_guard()?;
        let plan = match weights {
            Some(w) => {
                let srcs = w.src_weights.clone().ok_or_else(|| {
                    anyhow::anyhow!("non-blocking dynamic neighbor_allreduce requires src_weights")
                })?;
                let dsts = w.dst_weights.clone().ok_or_else(|| {
                    anyhow::anyhow!("non-blocking dynamic neighbor_allreduce requires dst_weights")
                })?;
                ExchangePlan { self_weight: w.self_weight, srcs, dsts, static_plan: false }
            }
            None => {
                let me = self.rank();
                let topo = self.topology.read().unwrap();
                let (self_weight, srcs) = topo.views.pull_view(me);
                let srcs = srcs.to_vec();
                let dsts: Vec<(usize, f64)> =
                    topo.views.out_neighbors(me).iter().map(|&r| (r, 1.0)).collect();
                ExchangePlan { self_weight, srcs, dsts, static_plan: true }
            }
        };
        let group = self.assign_fusion_group(data.len() * 4);
        let (tx, rx) = channel();
        let data = self.vec_from(data);
        let req = CommRequest::NeighborAllreduce {
            group,
            data,
            plan,
            enqueue_vtime: self.vtime(),
            reply: tx,
        };
        let route = self.dispatch_comm(req)?;
        Ok(Handle {
            rx,
            group,
            route,
            group_counter: self.fusion_group.clone(),
            acc_bytes: self.fusion_acc_bytes.clone(),
        })
    }

    /// Route a request to the rank's communication backend: the inline
    /// engine when one is installed (EventLoop), otherwise the comm thread's
    /// queue (Threads).
    fn dispatch_comm(&mut self, req: CommRequest) -> anyhow::Result<Route> {
        if self.inline_comm.is_some() {
            let mut engine =
                self.inline_comm.take().expect("inline engine presence just checked");
            engine.handle(req);
            self.inline_comm = Some(engine);
            Ok(Route::Inline)
        } else {
            let q = self.comm_queue()?;
            let flush_tx = q.tx.clone();
            q.tx.send(req).map_err(|_| anyhow::anyhow!("communication thread down"))?;
            Ok(Route::Thread(flush_tx))
        }
    }

    /// Non-blocking global average via ring allreduce (the overlapped
    /// Horovod baseline).
    pub fn allreduce_nonblocking(&mut self, data: &[f32]) -> anyhow::Result<Handle> {
        use std::sync::atomic::Ordering;
        self.fault_guard()?;
        // Ring ops close the open fusion group.
        let group = self.fusion_group.fetch_add(1, Ordering::Relaxed) + 1;
        self.fusion_acc_bytes.store(0, Ordering::Relaxed);
        let (tx, rx) = channel();
        let data = self.vec_from(data);
        let req = CommRequest::RingAllreduceAvg {
            group,
            data,
            enqueue_vtime: self.vtime(),
            reply: tx,
        };
        let route = self.dispatch_comm(req)?;
        Ok(Handle {
            rx,
            group,
            route,
            group_counter: self.fusion_group.clone(),
            acc_bytes: self.fusion_acc_bytes.clone(),
        })
    }
}
