//! Minimal CLI argument parsing (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, bare `--flag`, and positional
//! arguments; typed accessors with defaults and error messages listing
//! valid options.

use std::collections::HashMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional (non-flag) arguments in order.
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                anyhow::ensure!(!key.is_empty(), "bare '--' is not a valid flag");
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// From the process environment.
    pub fn from_env() -> anyhow::Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// True when `--key` was passed (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String value of `--key` or `default`.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// `usize` value of `--key` or `default`; errors on unparsable input.
    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    /// Optional `usize` value of `--key`: `None` when absent, error on
    /// unparsable input (flags like `--kill-rank` that have no meaningful
    /// default).
    pub fn usize_opt(&self, key: &str) -> anyhow::Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    /// `f64` value of `--key` or `default`; errors on unparsable input.
    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    /// Boolean value of `--key` or `default`; accepts true/false/1/0/yes/no.
    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => anyhow::bail!("--{key} {v}: expected true/false"),
        }
    }

    /// Value constrained to a fixed choice set.
    pub fn choice_or<'a>(
        &'a self,
        key: &str,
        default: &'a str,
        choices: &[&str],
    ) -> anyhow::Result<&'a str> {
        let v = self.str_or(key, default);
        if choices.contains(&v) {
            Ok(v)
        } else {
            anyhow::bail!("--{key} {v}: expected one of {}", choices.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse("train --nodes 8 --algo=atc --verbose --lr 0.1");
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("nodes", 1).unwrap(), 8);
        assert_eq!(a.str_or("algo", "x"), "atc");
        assert!(a.bool_or("verbose", false).unwrap());
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.1);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("");
        assert_eq!(a.usize_or("nodes", 4).unwrap(), 4);
        assert!(!a.has("anything"));
    }

    #[test]
    fn optional_usize() {
        let a = parse("--kill-rank 2");
        assert_eq!(a.usize_opt("kill-rank").unwrap(), Some(2));
        assert_eq!(a.usize_opt("kill-at").unwrap(), None);
        let a = parse("--kill-rank two");
        assert!(a.usize_opt("kill-rank").is_err());
    }

    #[test]
    fn bad_values_error() {
        let a = parse("--nodes eight");
        assert!(a.usize_or("nodes", 1).is_err());
        let a = parse("--flag maybe");
        assert!(a.bool_or("flag", false).is_err());
    }

    #[test]
    fn choices_validated() {
        let a = parse("--topo ring");
        assert_eq!(a.choice_or("topo", "expo2", &["ring", "expo2"]).unwrap(), "ring");
        let a = parse("--topo blob");
        assert!(a.choice_or("topo", "expo2", &["ring", "expo2"]).is_err());
    }

    #[test]
    fn negative_numbers_are_values_not_flags() {
        let a = parse("--offset -3");
        // "-3" doesn't start with "--", so it's consumed as the value.
        assert_eq!(a.get("offset"), Some("-3"));
    }
}
