//! Virtual-time event scheduler — the `ExecMode::EventLoop` core.
//!
//! Under [`crate::launcher::ExecMode::EventLoop`] every rank still owns an
//! OS thread (so four PRs' worth of blocking collective code runs
//! unchanged), but the threads are *cooperative*: exactly one is runnable
//! at any instant, and the baton is passed through a single virtual-time
//! priority queue. A rank runs until it reaches a yield point — a mailbox
//! receive with nothing to read, a negotiation waiting for peers, an
//! async-throttle horizon, or an explicit cooperative yield after compute
//! — parks on its own condvar, and the scheduler grants the globally
//! smallest pending [`Event`] `(vtime, rank, kind)`. The result is a
//! deterministic discrete-event simulation: grant order is a pure function
//! of the virtual-time cost model, independent of OS scheduling, which is
//! what lets `tests/exec_parity.rs` pin the event backend bit-for-bit
//! against the free-running thread backend.
//!
//! Invariants (property-tested in `tests/properties.rs`):
//! - pops from the [`EventQueue`] are nondecreasing in vtime;
//! - same-vtime ties break deterministically by rank, then kind, then
//!   insertion sequence;
//! - no event is lost or duplicated: the popped multiset equals the pushed
//!   multiset;
//! - a rank parked on a receive consumes **no virtual time** while parked
//!   (its clock moves only when the matched message's arrival stamp does).
//!
//! Deadlock watchdog: if the queue drains while unfinished ranks remain
//! parked, the scheduler poisons itself with a per-rank diagnostic (park
//! kind, what it was waiting on, its clock) and wakes everyone; parked
//! ranks panic with that diagnostic, which the launcher converts into a
//! run error — a mismatched collective fails in milliseconds instead of
//! hanging the test suite.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, Ordering as AtomicOrdering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

use crate::transport::VClock;

/// What a queued event delivers to its target rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum WakeKind {
    /// Initial grant releasing an attached rank into its node body.
    Start,
    /// A point-to-point message became (virtually) available.
    Message,
    /// A self-scheduled resume: cooperative yield or throttle release.
    Resume,
    /// A negotiation batch this rank submitted to has been resolved.
    Clearance,
    // New kinds are appended (never inserted) so the derived `Ord` —
    // and with it the same-vtime tie-break order the parity suite
    // pins — is preserved for all pre-existing kinds.
    /// A receive deadline expired (fault layer): grants a recv-parked
    /// rank whose recorded deadline is `<=` the event vtime so the wait
    /// converts into a typed `CommError` instead of a hang.
    Timeout,
    /// Informational: `actor` reaches its scheduled crash vtime. Never
    /// granted — dispatch consumes it to mark the rank crashed so the
    /// watchdog can distinguish "deadlocked" from "peer crashed".
    Crash,
    /// Informational: a link partition heals at this vtime. Never
    /// granted; kept in the queue so chaos traces show heal instants.
    Heal,
}

/// A scheduler event: rank `actor` becomes eligible to run at `vtime`.
///
/// Total order: vtime (IEEE `total_cmp`), then rank, then kind, then the
/// insertion sequence number — so same-instant ties are deterministic and
/// independent of heap internals.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Virtual time at which the wakeup fires.
    pub vtime: f64,
    /// Target rank.
    pub actor: usize,
    /// What the wakeup delivers.
    pub kind: WakeKind,
    /// Insertion sequence number (final tie-breaker).
    pub seq: u64,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.vtime
            .total_cmp(&other.vtime)
            .then(self.actor.cmp(&other.actor))
            .then(self.kind.cmp(&other.kind))
            .then(self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

/// Deterministic min-priority queue over [`Event`]s.
///
/// Exposed on its own (rather than buried in the scheduler) so property
/// tests can drive it directly with randomized interleavings.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<std::cmp::Reverse<Event>>,
}

impl EventQueue {
    /// An empty queue.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new() }
    }

    /// Insert an event.
    pub fn push(&mut self, ev: Event) {
        self.heap.push(std::cmp::Reverse(ev));
    }

    /// Remove and return the smallest event (earliest vtime, lowest rank).
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|r| r.0)
    }

    /// The smallest event without removing it.
    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek().map(|r| &r.0)
    }

    /// Number of queued events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events are queued.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// What a rank is currently parked on (its resumable-state-machine state;
/// the rest of the per-rank record — parameters, pool, pending window
/// slots, staleness counters — lives on `NodeContext` and is simply not
/// touched while the rank is parked).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Park {
    /// Attached, waiting for the initial grant.
    Start,
    /// Currently running (holds the baton).
    Running,
    /// Cooperative yield; resumes on its own `Resume` event.
    Yield,
    /// Blocked on a mailbox receive; resumes on a `Message` event.
    Recv,
    /// Blocked on a negotiation batch; resumes on a `Clearance` event.
    Negotiate,
    /// Blocked on the bounded-staleness throttle; resumes on a `Resume`
    /// event pushed by the release sweep.
    Throttle,
    /// Node body returned; never granted again.
    Finished,
}

/// One granted wakeup, recorded when tracing is enabled — the
/// deterministic "virtual-time trace" the parity tests compare.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Grant {
    /// Virtual time of the granting event.
    pub vtime: f64,
    /// Rank that received the baton.
    pub actor: usize,
    /// Kind of the granting event.
    pub kind: WakeKind,
}

struct ActorState {
    park: Park,
    granted: bool,
    /// Kind of the event that granted the current wakeup (read by the
    /// waiter to distinguish a message wake from a deadline expiry).
    granted_kind: WakeKind,
    /// Human-readable description of what the rank is blocked on
    /// (deadlock diagnostics).
    info: &'static str,
    /// Clock reading when the rank parked (deadlock diagnostics).
    parked_at: f64,
    /// Peer rank a `Recv` park is matched against (`None` = any source).
    recv_peer: Option<usize>,
    /// Tag a `Recv` park is matched against.
    recv_tag: Option<u64>,
    /// Absolute vtime deadline of the current `Recv` park (`INFINITY`
    /// when the wait has no deadline — the seed behavior).
    recv_deadline: f64,
    /// Set when a `Crash` event for this rank has been consumed.
    crashed: bool,
}

impl ActorState {
    fn fresh() -> Self {
        ActorState {
            park: Park::Start,
            granted: false,
            granted_kind: WakeKind::Start,
            info: "attach",
            parked_at: 0.0,
            recv_peer: None,
            recv_tag: None,
            recv_deadline: f64::INFINITY,
            crashed: false,
        }
    }
}

struct Inner {
    queue: EventQueue,
    seq: u64,
    actors: Vec<ActorState>,
    /// Ranks that have called `attach`; dispatch is gated on all `n` so
    /// OS-racy thread spawn order cannot perturb the first grant.
    attached: usize,
    unfinished: usize,
    /// `(rank, threshold)`: release when `min_active_vtime() >= threshold`.
    throttle: Vec<(usize, f64)>,
    poison: Option<Arc<String>>,
    trace: Option<Vec<Grant>>,
}

impl Inner {
    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }
}

/// The baton-passing cooperative scheduler (one per `EventLoop` run).
pub struct Scheduler {
    n: usize,
    inner: Mutex<Inner>,
    /// One condvar per rank (all paired with the single `inner` mutex) so
    /// a grant wakes exactly its target — no thundering herd at 10k ranks.
    cvs: Vec<Condvar>,
    clocks: Vec<VClock>,
    async_done: Arc<Vec<AtomicBool>>,
}

impl Scheduler {
    /// New scheduler over `n` ranks sharing `clocks`/`async_done` with the
    /// launcher. `trace` enables grant recording (parity/property tests).
    pub fn new(
        n: usize,
        clocks: Vec<VClock>,
        async_done: Arc<Vec<AtomicBool>>,
        trace: bool,
    ) -> Arc<Self> {
        let actors = (0..n).map(|_| ActorState::fresh()).collect();
        Arc::new(Scheduler {
            n,
            inner: Mutex::new(Inner {
                queue: EventQueue::new(),
                seq: 0,
                actors,
                attached: 0,
                unfinished: n,
                throttle: Vec::new(),
                poison: None,
                trace: if trace { Some(Vec::new()) } else { None },
            }),
            cvs: (0..n).map(|_| Condvar::new()).collect(),
            clocks,
            async_done,
        })
    }

    /// Number of ranks.
    pub fn size(&self) -> usize {
        self.n
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Register the calling rank and park until the scheduler grants its
    /// `Start` event. Dispatch begins only once all `n` ranks attached, so
    /// the first baton always goes to rank 0 regardless of spawn order.
    pub fn attach(&self, rank: usize) {
        let mut g = self.lock();
        let seq = g.next_seq();
        g.queue.push(Event { vtime: 0.0, actor: rank, kind: WakeKind::Start, seq });
        g.attached += 1;
        let crashed = g.actors[rank].crashed;
        g.actors[rank] = ActorState::fresh();
        g.actors[rank].crashed = crashed;
        self.dispatch(&mut g);
        self.wait_granted(g, rank);
    }

    /// Cooperative yield: hand the baton back and resume once `vtime` is
    /// the smallest pending instant. Called after compute advances the
    /// local clock so cheaper ranks run first.
    pub fn yield_now(&self, rank: usize, vtime: f64) {
        let mut g = self.lock();
        let seq = g.next_seq();
        g.queue.push(Event { vtime, actor: rank, kind: WakeKind::Resume, seq });
        self.park(g, rank, Park::Yield, "cooperative yield", vtime);
    }

    /// Park until a `Message` event targets this rank. The caller must
    /// have drained its mailbox first (`try_recv_*`) — arrivals pushed
    /// before this park are already queued as events and will be granted.
    pub fn block_recv(&self, rank: usize, info: &'static str) {
        self.block_recv_with(rank, None, None, f64::INFINITY, info);
    }

    /// Deadline-aware receive park: like [`Scheduler::block_recv`] but
    /// records the awaited peer/tag (watchdog diagnostics) and the
    /// absolute vtime `deadline` at which a previously scheduled
    /// [`WakeKind::Timeout`] event may grant the park. Returns the kind
    /// of the granting event so the waiter can tell a message wake from
    /// a deadline expiry (either way it re-drains its mailbox — a
    /// `Timeout` grant racing an already-stashed match must lose).
    pub fn block_recv_with(
        &self,
        rank: usize,
        peer: Option<usize>,
        tag: Option<u64>,
        deadline: f64,
        info: &'static str,
    ) -> WakeKind {
        let mut g = self.lock();
        let at = self.clocks[rank].now();
        {
            let a = &mut g.actors[rank];
            a.recv_peer = peer;
            a.recv_tag = tag;
            a.recv_deadline = deadline;
        }
        self.park(g, rank, Park::Recv, info, at);
        let mut g = self.lock();
        let a = &mut g.actors[rank];
        a.recv_peer = None;
        a.recv_tag = None;
        a.recv_deadline = f64::INFINITY;
        a.granted_kind
    }

    /// Schedule a [`WakeKind::Timeout`] event for `rank` at `vtime`. The
    /// caller pushes this once per logical deadline-bounded receive (not
    /// per re-park) so drained queues still wake the waiter exactly at
    /// its deadline. Stale timeout events (from receives that completed
    /// early) are discarded by the dispatch deadline check.
    pub fn schedule_timeout(&self, rank: usize, vtime: f64) {
        let mut g = self.lock();
        let seq = g.next_seq();
        g.queue.push(Event { vtime, actor: rank, kind: WakeKind::Timeout, seq });
    }

    /// Schedule an informational [`WakeKind::Crash`] marker for `rank` at
    /// its planned crash vtime (consumed by dispatch, never granted).
    pub fn schedule_crash(&self, rank: usize, vtime: f64) {
        let mut g = self.lock();
        let seq = g.next_seq();
        g.queue.push(Event { vtime, actor: rank, kind: WakeKind::Crash, seq });
    }

    /// Schedule an informational [`WakeKind::Heal`] marker at a partition
    /// heal instant (consumed by dispatch, never granted).
    pub fn schedule_heal(&self, vtime: f64) {
        let mut g = self.lock();
        let seq = g.next_seq();
        g.queue.push(Event { vtime, actor: 0, kind: WakeKind::Heal, seq });
    }

    /// True when `rank` has finished (returned or crashed out of) its
    /// node body. Used by the inline rendezvous to resolve negotiation
    /// batches whose missing submitters will never arrive.
    pub fn is_finished(&self, rank: usize) -> bool {
        self.lock().actors[rank].park == Park::Finished
    }

    /// Park until the negotiation batch this rank submitted to resolves
    /// (a `Clearance` event pushed by the batch's last submitter).
    pub fn block_negotiate(&self, rank: usize) {
        let g = self.lock();
        let at = self.clocks[rank].now();
        self.park(g, rank, Park::Negotiate, "negotiation rendezvous", at);
    }

    /// Park until `min_active_vtime() >= threshold` (the bounded-staleness
    /// throttle). The release sweep runs at every dispatch.
    pub fn throttle_wait(&self, rank: usize, threshold: f64) {
        let mut g = self.lock();
        g.throttle.push((rank, threshold));
        let at = self.clocks[rank].now();
        self.park(g, rank, Park::Throttle, "async throttle horizon", at);
    }

    /// Announce a message delivered to `dst`'s mailbox with the given
    /// virtual arrival time. Called by the (running) sender; does not
    /// dispatch — the sender keeps the baton.
    pub fn notify_message(&self, dst: usize, vtime: f64) {
        let mut g = self.lock();
        let seq = g.next_seq();
        g.queue.push(Event { vtime, actor: dst, kind: WakeKind::Message, seq });
    }

    /// Announce a resolved negotiation clearance for `dst` effective at
    /// `vtime`. Called by the batch's last submitter (still running).
    pub fn notify_clearance(&self, dst: usize, vtime: f64) {
        let mut g = self.lock();
        let seq = g.next_seq();
        g.queue.push(Event { vtime, actor: dst, kind: WakeKind::Clearance, seq });
    }

    /// Mark the calling rank finished and pass the baton on. Must never
    /// panic — it runs from a drop guard during unwinding.
    pub fn finish(&self, rank: usize) {
        let mut g = self.lock();
        if g.actors[rank].park == Park::Finished {
            return;
        }
        g.actors[rank].park = Park::Finished;
        g.unfinished = g.unfinished.saturating_sub(1);
        if g.attached == self.n && g.poison.is_none() {
            self.dispatch(&mut g);
        }
    }

    /// The recorded grant sequence (empty unless tracing was enabled).
    pub fn grants(&self) -> Vec<Grant> {
        self.lock().trace.clone().unwrap_or_default()
    }

    /// The watchdog diagnostic, if the run deadlocked.
    pub fn poison_message(&self) -> Option<String> {
        self.lock().poison.as_ref().map(|p| p.as_str().to_string())
    }

    fn park(
        &self,
        mut g: MutexGuard<'_, Inner>,
        rank: usize,
        park: Park,
        info: &'static str,
        at: f64,
    ) {
        {
            let a = &mut g.actors[rank];
            a.park = park;
            a.info = info;
            a.parked_at = at;
        }
        self.dispatch(&mut g);
        self.wait_granted(g, rank);
    }

    fn wait_granted(&self, mut g: MutexGuard<'_, Inner>, rank: usize) {
        loop {
            if let Some(p) = &g.poison {
                let msg = Arc::clone(p);
                drop(g);
                panic!("{msg}");
            }
            if g.actors[rank].granted {
                g.actors[rank].granted = false;
                g.actors[rank].park = Park::Running;
                return;
            }
            g = self.cvs[rank].wait(g).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Smallest clock among ranks still participating in the async regime
    /// (`async_done` ranks are skipped, matching
    /// `NodeContext::min_active_vtime`).
    fn min_active_vtime(&self) -> f64 {
        let mut min = f64::INFINITY;
        for (i, c) in self.clocks.iter().enumerate() {
            if !self.async_done[i].load(AtomicOrdering::SeqCst) {
                min = min.min(c.now());
            }
        }
        min
    }

    /// Grant the smallest matching pending event, or poison on drain.
    /// Called with the lock held, only when no rank holds the baton.
    fn dispatch(&self, g: &mut Inner) {
        if g.attached < self.n || g.poison.is_some() {
            return;
        }
        // Throttle release sweep: waiters whose horizon condition now
        // holds re-enter the queue at their own clock, competing in
        // vtime order with everything else.
        if !g.throttle.is_empty() {
            let min_active = self.min_active_vtime();
            let released: Vec<usize> = g
                .throttle
                .iter()
                .filter(|&&(_, th)| min_active >= th)
                .map(|&(r, _)| r)
                .collect();
            g.throttle.retain(|&(_, th)| min_active < th);
            for r in released {
                let seq = g.next_seq();
                let vt = self.clocks[r].now();
                g.queue.push(Event { vtime: vt, actor: r, kind: WakeKind::Resume, seq });
            }
        }
        loop {
            let Some(ev) = g.queue.pop() else {
                if g.unfinished > 0 {
                    self.poison_deadlock(g);
                }
                return;
            };
            // Informational fault markers: consumed, never granted.
            if ev.kind == WakeKind::Crash {
                g.actors[ev.actor].crashed = true;
                continue;
            }
            if ev.kind == WakeKind::Heal {
                continue;
            }
            let matches = matches!(
                (g.actors[ev.actor].park, ev.kind),
                (Park::Start, WakeKind::Start)
                    | (Park::Yield, WakeKind::Resume)
                    | (Park::Throttle, WakeKind::Resume)
                    | (Park::Recv, WakeKind::Message)
                    | (Park::Negotiate, WakeKind::Clearance)
            )
                // A Timeout event grants a recv park only once the park's
                // recorded deadline is due; earlier (stale) timeouts from
                // receives that completed are discarded here.
                || (g.actors[ev.actor].park == Park::Recv
                    && ev.kind == WakeKind::Timeout
                    && g.actors[ev.actor].recv_deadline <= ev.vtime);
            if matches {
                g.actors[ev.actor].granted = true;
                g.actors[ev.actor].granted_kind = ev.kind;
                if let Some(tr) = &mut g.trace {
                    tr.push(Grant { vtime: ev.vtime, actor: ev.actor, kind: ev.kind });
                }
                self.cvs[ev.actor].notify_one();
                return;
            }
            // Mismatched (park, kind) pairs are discarded: a Message for a
            // rank that is not recv-parked is already sitting in its
            // mailbox (every recv path drains before parking), and waking
            // a Yield-parked rank early would run it out of vtime order.
        }
    }

    /// Status word for the peer a stuck receive is waiting on, so the
    /// watchdog can say *why* the message never came: a crashed peer is
    /// not a deadlock, it is a missing deadline.
    fn peer_status(g: &Inner, peer: usize) -> &'static str {
        let a = &g.actors[peer];
        if a.crashed {
            "crashed"
        } else {
            match a.park {
                Park::Finished => "finished",
                Park::Throttle => "throttled",
                Park::Running => "running",
                Park::Recv => "itself recv-parked",
                Park::Negotiate => "negotiating",
                Park::Yield => "yield-parked",
                Park::Start => "not yet started",
            }
        }
    }

    fn poison_deadlock(&self, g: &mut Inner) {
        let mut msg = format!(
            "simnet deadlock: event queue drained with {} unfinished rank(s); pending waits:\n",
            g.unfinished
        );
        for r in 0..g.actors.len() {
            let a = &g.actors[r];
            if a.park != Park::Finished {
                msg.push_str(&format!(
                    "  rank {r}: parked on {:?} ({}) at vtime {:.9}",
                    a.park, a.info, a.parked_at
                ));
                if a.park == Park::Recv {
                    match a.recv_peer {
                        Some(p) => {
                            msg.push_str(&format!(
                                " awaiting src={p} tag={:#x}; peer {p} is {}",
                                a.recv_tag.unwrap_or(0),
                                Self::peer_status(g, p)
                            ));
                        }
                        None => {
                            if let Some(t) = a.recv_tag {
                                msg.push_str(&format!(" awaiting any-source tag={t:#x}"));
                            }
                        }
                    }
                    if a.recv_deadline.is_finite() {
                        msg.push_str(&format!(" (deadline {:.9})", a.recv_deadline));
                    }
                }
                if a.crashed {
                    msg.push_str(" [rank itself crashed]");
                }
                msg.push('\n');
            }
        }
        for &(r, th) in &g.throttle {
            msg.push_str(&format!("  rank {r}: throttle threshold {th:.9}\n"));
        }
        g.poison = Some(Arc::new(msg));
        for cv in &self.cvs {
            cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(vtime: f64, actor: usize, kind: WakeKind, seq: u64) -> Event {
        Event { vtime, actor, kind, seq }
    }

    #[test]
    fn queue_pops_in_vtime_then_rank_order() {
        let mut q = EventQueue::new();
        q.push(ev(2.0, 0, WakeKind::Resume, 1));
        q.push(ev(1.0, 5, WakeKind::Message, 2));
        q.push(ev(1.0, 3, WakeKind::Message, 3));
        assert_eq!(q.pop().unwrap().actor, 3);
        assert_eq!(q.pop().unwrap().actor, 5);
        assert_eq!(q.pop().unwrap().vtime, 2.0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn same_rank_same_vtime_breaks_by_kind_then_seq() {
        let mut q = EventQueue::new();
        q.push(ev(1.0, 2, WakeKind::Resume, 7));
        q.push(ev(1.0, 2, WakeKind::Message, 9));
        q.push(ev(1.0, 2, WakeKind::Message, 8));
        assert_eq!(q.pop().unwrap(), ev(1.0, 2, WakeKind::Message, 8));
        assert_eq!(q.pop().unwrap(), ev(1.0, 2, WakeKind::Message, 9));
        assert_eq!(q.pop().unwrap().kind, WakeKind::Resume);
    }
}
