//! Per-rank compute heterogeneity — stragglers in virtual time.
//!
//! The paper's throughput model (and the synchronous training driver built
//! on it) assumes every rank computes a step in the same time. Real
//! clusters do not: multi-tenant interference, thermal throttling and
//! hardware generations make some ranks persistently slower, and every
//! rank jitters around its own mean. Synchronous decentralized methods pay
//! the slowest rank's price each iteration; asynchronous methods (paper
//! §IV-C; Lian et al. 2017) are exactly the regime where that stops being
//! true — which is unreachable in a simulator that models all ranks as
//! equally fast.
//!
//! [`ComputeHeterogeneity`] closes that gap: a deterministic per-rank
//! *slowdown factor* (1.0 = nominal speed, 4.0 = a 4x straggler) plus a
//! seeded multiplicative jitter drawn from the node's own
//! [`crate::rng::Rng`], so runs stay reproducible from a single seed. It is
//! threaded through [`crate::launcher::SpmdConfig`]'s `AsyncSpec` into
//! [`crate::context::NodeContext::simulate_compute_hetero`] and from there
//! into the training drivers, so stragglers exist in virtual time for both
//! the synchronous baseline and the asynchronous loop.

use crate::rng::Rng;

/// Deterministic per-rank compute slowdown factors plus seeded jitter.
#[derive(Debug, Clone)]
pub struct ComputeHeterogeneity {
    /// Per-rank slowdown factor (>= 0; 1.0 = nominal). Ranks beyond the
    /// vector's length run at factor 1.0.
    pub slowdowns: Vec<f64>,
    /// Relative jitter amplitude in `[0, 1)`: each sampled step time is
    /// multiplied by `1 + jitter * u` with `u` uniform in `[-1, 1)`.
    pub jitter: f64,
}

impl ComputeHeterogeneity {
    /// All `n` ranks at nominal speed (the homogeneous baseline).
    pub fn uniform(n: usize) -> Self {
        ComputeHeterogeneity { slowdowns: vec![1.0; n], jitter: 0.0 }
    }

    /// `n` ranks at nominal speed except `rank`, which is `factor` times
    /// slower — the single-straggler scenario of the async probes.
    pub fn straggler(n: usize, rank: usize, factor: f64) -> Self {
        assert!(rank < n, "straggler rank {rank} out of range for {n} ranks");
        assert!(factor > 0.0, "slowdown factor must be positive");
        let mut slowdowns = vec![1.0; n];
        slowdowns[rank] = factor;
        ComputeHeterogeneity { slowdowns, jitter: 0.0 }
    }

    /// Explicit per-rank factors (e.g. a hardware-generation gradient).
    pub fn from_slowdowns(slowdowns: Vec<f64>) -> Self {
        assert!(slowdowns.iter().all(|&f| f > 0.0), "slowdown factors must be positive");
        ComputeHeterogeneity { slowdowns, jitter: 0.0 }
    }

    /// Add relative jitter (builder style). Clamped to `[0, 0.99]` so a
    /// sampled step time can never be negative.
    pub fn with_jitter(mut self, jitter: f64) -> Self {
        self.jitter = jitter.clamp(0.0, 0.99);
        self
    }

    /// Deterministic slowdown factor of `rank` (1.0 beyond the table).
    pub fn factor(&self, rank: usize) -> f64 {
        self.slowdowns.get(rank).copied().unwrap_or(1.0)
    }

    /// Largest slowdown factor — handy for sizing staleness horizons.
    pub fn max_factor(&self) -> f64 {
        self.slowdowns.iter().copied().fold(1.0, f64::max)
    }

    /// Sample one step's compute time for `rank` given the nominal `base`
    /// seconds: `base * factor(rank) * (1 + jitter * u)`, `u ∈ [-1, 1)`
    /// drawn from `rng` (the caller's per-node deterministic stream).
    pub fn sample(&self, rank: usize, base: f64, rng: &mut Rng) -> f64 {
        let f = self.factor(rank);
        if self.jitter <= 0.0 {
            return base * f;
        }
        let u = 2.0 * rng.f64() - 1.0;
        base * f * (1.0 + self.jitter * u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_slows_only_one_rank() {
        let h = ComputeHeterogeneity::straggler(8, 3, 4.0);
        for r in 0..8 {
            let want = if r == 3 { 4.0 } else { 1.0 };
            assert_eq!(h.factor(r), want, "rank {r}");
        }
        assert_eq!(h.max_factor(), 4.0);
        assert_eq!(h.factor(100), 1.0, "out-of-table ranks run nominal");
    }

    #[test]
    fn sample_without_jitter_is_exact() {
        let h = ComputeHeterogeneity::straggler(4, 0, 2.5);
        let mut rng = Rng::new(1);
        assert_eq!(h.sample(0, 0.01, &mut rng), 0.025);
        assert_eq!(h.sample(1, 0.01, &mut rng), 0.01);
    }

    #[test]
    fn jitter_stays_within_bounds_and_is_deterministic() {
        let h = ComputeHeterogeneity::uniform(4).with_jitter(0.2);
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..1000 {
            let dt = h.sample(2, 1.0, &mut a);
            assert!((0.8..1.2).contains(&dt), "jittered sample out of band: {dt}");
            assert_eq!(dt, h.sample(2, 1.0, &mut b), "same seed must give same samples");
        }
    }

    #[test]
    fn jitter_is_clamped() {
        let h = ComputeHeterogeneity::uniform(2).with_jitter(5.0);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            assert!(h.sample(0, 1.0, &mut rng) > 0.0);
        }
    }
}
