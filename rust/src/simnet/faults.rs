//! Deterministic fault injection at the transport boundary (ISSUE 7).
//!
//! The paper's fault-tolerance claim — decentralized algorithms have no
//! single point of failure — is only testable if failures can actually
//! happen. This module defines a seeded, deterministic [`FaultPlan`]
//! carried by `SpmdConfig`: rank crash-at-vtime, per-link message
//! drop/delay/duplication probabilities, and link partitions over vtime
//! windows.
//!
//! **Determinism across exec modes.** Every fault decision is a *pure
//! function* of `(plan seed, src, dst, per-link message sequence number,
//! virtual send time)`. Per-link sequence numbers follow the sender's
//! program order, which the exec-parity suite already pins to be
//! identical under `ExecMode::Threads` and `ExecMode::EventLoop`; virtual
//! send times are likewise bitwise-identical across modes. Both backends
//! therefore observe the *same* fault schedule — verified by the
//! differential test in `tests/faults.rs`.
//!
//! **Drop + retry model.** The simulated transport is "reliable protocol
//! over a lossy link": a dropped packet is retransmitted up to
//! [`FaultPlan::max_retries`] times with exponential backoff
//! ([`FaultPlan::backoff_base`]` * 2^k` before attempt `k+1`). Attempt
//! `k` occurs at virtual time `send + backoff_base * (2^k - 1)`; it
//! succeeds if the link is not partitioned at that instant and the
//! per-attempt drop roll passes. A surviving attempt delivers with the
//! accumulated backoff as extra delay; if every attempt fails the message
//! is truly lost and the receiver's [`CommDeadline`] converts the loss
//! into a typed [`CommError`] instead of an infinite hang. Retries
//! happening *after* a partition heals model the self-healing transport.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Typed communication failures surfaced by deadline-based receives.
///
/// These replace the two infinite hangs the seed had: blocking on a peer
/// that crashed, and blocking on a message that the (faulty) link lost.
#[derive(Debug, Clone, PartialEq)]
pub enum CommError {
    /// No matching message arrived (virtually) before the deadline and
    /// the fault oracle does not mark the peer as crashed — the message
    /// was lost or the peer is partitioned/slow.
    Timeout {
        /// Peer the receive was matched against (`usize::MAX` = any).
        src: usize,
        /// Virtual time at which the deadline expired.
        deadline: f64,
    },
    /// The awaited peer is crashed at the deadline instant, per the
    /// plan's crash oracle (the simulator's stand-in for a transport
    /// connection error).
    PeerDown {
        /// The crashed peer.
        peer: usize,
        /// Virtual time at which the failure was observed.
        at: f64,
    },
    /// This rank itself has reached its scheduled crash point; the
    /// caller must unwind (the launcher's exit guards mark the rank dead
    /// for everyone else).
    SelfCrash {
        /// The crashing rank.
        rank: usize,
        /// The scheduled crash virtual time.
        at: f64,
    },
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::Timeout { src, deadline } => {
                if *src == usize::MAX {
                    write!(f, "recv-any timed out at vtime {deadline:.6}s")
                } else {
                    write!(f, "recv from rank {src} timed out at vtime {deadline:.6}s")
                }
            }
            CommError::PeerDown { peer, at } => {
                write!(f, "peer rank {peer} is down (observed at vtime {at:.6}s)")
            }
            CommError::SelfCrash { rank, at } => {
                write!(f, "rank {rank} crashed at its scheduled vtime {at:.6}s")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// Virtual-time budget for a blocking receive or drain.
///
/// `budget` is relative to the instant the wait starts; the absolute
/// deadline is `wait-start vtime + budget`. On expiry the waiter's clock
/// advances to exactly the deadline in *both* exec modes (Threads: direct
/// `advance_to`; EventLoop: a `WakeKind::Timeout` event at that vtime),
/// so fault-path vtimes stay bitwise mode-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommDeadline {
    /// Virtual seconds to wait before giving up (`f64::INFINITY` = wait
    /// forever, the seed's behavior).
    pub budget: f64,
}

impl CommDeadline {
    /// Wait forever — bitwise-identical to the pre-fault-layer behavior.
    pub fn none() -> Self {
        CommDeadline { budget: f64::INFINITY }
    }

    /// Give up after `budget` virtual seconds.
    pub fn after(budget: f64) -> Self {
        CommDeadline { budget }
    }

    /// True when this deadline can actually expire.
    pub fn is_finite(&self) -> bool {
        self.budget.is_finite()
    }
}

/// A link partition: messages between group `a` and group `b` (either
/// direction) are lost while `from <= vtime < until`.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    /// One side of the cut.
    pub a: Vec<usize>,
    /// The other side of the cut.
    pub b: Vec<usize>,
    /// Partition start (virtual seconds).
    pub from: f64,
    /// Partition end — the heal instant (virtual seconds).
    pub until: f64,
}

impl Partition {
    /// True when the `src -> dst` link is cut at `vtime`.
    pub fn cuts(&self, src: usize, dst: usize, vtime: f64) -> bool {
        if vtime < self.from || vtime >= self.until {
            return false;
        }
        (self.a.contains(&src) && self.b.contains(&dst))
            || (self.b.contains(&src) && self.a.contains(&dst))
    }
}

/// Shared fault-event counters, one instance per `run_spmd` launch. The
/// differential test compares these across exec modes: identical plans
/// must produce identical counts.
#[derive(Debug, Default)]
pub struct FaultStats {
    /// Messages lost after exhausting every retry.
    pub lost: AtomicU64,
    /// Messages delivered only after at least one retransmission.
    pub retried: AtomicU64,
    /// Messages hit by the random-delay fault.
    pub delayed: AtomicU64,
    /// Messages duplicated by the link (the dedup layer absorbs the
    /// copy; it is observable only as a spurious wakeup and this count).
    pub duplicated: AtomicU64,
    /// Sends suppressed because the sender had already crashed.
    pub crashed_sends: AtomicU64,
}

impl FaultStats {
    /// Snapshot `(lost, retried, delayed, duplicated, crashed_sends)`.
    pub fn snapshot(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.lost.load(Ordering::Relaxed),
            self.retried.load(Ordering::Relaxed),
            self.delayed.load(Ordering::Relaxed),
            self.duplicated.load(Ordering::Relaxed),
            self.crashed_sends.load(Ordering::Relaxed),
        )
    }
}

/// Outcome of injecting faults into one point-to-point message.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LinkFate {
    /// The message arrives, `extra_delay` virtual seconds later than the
    /// fault-free schedule (retransmission backoff + random link delay);
    /// `duplicate` marks a transport-level duplicated packet riding
    /// along (absorbed by the receiver's dedup layer).
    Delivered {
        /// Additional virtual delay beyond the fault-free arrival.
        extra_delay: f64,
        /// A duplicated copy is delivered alongside the original.
        duplicate: bool,
    },
    /// Every transmission attempt was dropped or partitioned away — the
    /// message never arrives.
    Lost,
}

/// Seeded, deterministic fault schedule for one SPMD launch.
///
/// [`FaultPlan::none`] is the default and is guaranteed to be a bitwise
/// no-op: no crash events are scheduled, no fate rolls alter arrival
/// times, and every deadline is infinite, so all pre-existing parity and
/// BENCH gates see exactly the seed behavior.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Seed mixed into every fate roll (independent of the data RNG).
    pub seed: u64,
    /// `(rank, vtime)` pairs: the rank observes its crash the first time
    /// its virtual clock reaches `vtime` inside a fault-guarded call.
    pub crashes: Vec<(usize, f64)>,
    /// Per-attempt probability a message transmission is dropped.
    pub drop_prob: f64,
    /// Probability a delivered message is hit by extra link delay.
    pub delay_prob: f64,
    /// Maximum extra delay (virtual seconds, uniform in `(0, max]`).
    pub delay_max: f64,
    /// Probability a delivered message is duplicated by the link.
    pub dup_prob: f64,
    /// Link partitions over vtime windows.
    pub partitions: Vec<Partition>,
    /// Retransmission attempts after the first (reliable-over-lossy).
    pub max_retries: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub backoff_base: f64,
    /// Default receive budget (virtual seconds) applied to every
    /// blocking comm path when the caller does not pass an explicit
    /// [`CommDeadline`]. Infinite in [`FaultPlan::none`].
    pub deadline: f64,
    /// Consecutive deadline misses after which a peer is evicted from
    /// the local [`crate::topology::health::HealthView`] (crash-oracle
    /// `PeerDown` evicts immediately regardless).
    pub miss_threshold: u32,
    /// Shared event counters (cloned handles observe the same totals).
    pub stats: Arc<FaultStats>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The empty plan: no faults, infinite deadlines, bitwise no-op.
    pub fn none() -> Self {
        FaultPlan {
            seed: 0,
            crashes: Vec::new(),
            drop_prob: 0.0,
            delay_prob: 0.0,
            delay_max: 0.0,
            dup_prob: 0.0,
            partitions: Vec::new(),
            max_retries: 0,
            backoff_base: 0.0,
            deadline: f64::INFINITY,
            miss_threshold: 8,
            stats: Arc::new(FaultStats::default()),
        }
    }

    /// A plan with a seed and a finite default receive deadline — the
    /// usual starting point for chaos runs.
    pub fn seeded(seed: u64, deadline: f64) -> Self {
        FaultPlan { seed, deadline, ..FaultPlan::none() }
    }

    /// Schedule `rank` to crash at `vtime` (builder style).
    pub fn with_crash(mut self, rank: usize, vtime: f64) -> Self {
        self.crashes.push((rank, vtime));
        self
    }

    /// Set per-attempt drop probability with `retries` retransmissions
    /// backed off from `backoff_base` (builder style).
    pub fn with_drop(mut self, prob: f64, retries: u32, backoff_base: f64) -> Self {
        self.drop_prob = prob;
        self.max_retries = retries;
        self.backoff_base = backoff_base;
        self
    }

    /// Set the random extra-delay fault (builder style).
    pub fn with_delay(mut self, prob: f64, max: f64) -> Self {
        self.delay_prob = prob;
        self.delay_max = max;
        self
    }

    /// Set the duplication fault (builder style).
    pub fn with_dup(mut self, prob: f64) -> Self {
        self.dup_prob = prob;
        self
    }

    /// Cut links between `a` and `b` over `[from, until)` (builder
    /// style).
    pub fn with_partition(mut self, a: Vec<usize>, b: Vec<usize>, from: f64, until: f64) -> Self {
        self.partitions.push(Partition { a, b, from, until });
        self
    }

    /// Set the eviction miss threshold (builder style).
    pub fn with_miss_threshold(mut self, misses: u32) -> Self {
        self.miss_threshold = misses;
        self
    }

    /// True when the plan can affect message delivery at all. The hot
    /// paths branch on this once and skip every fate computation when
    /// false, which is what makes [`FaultPlan::none`] a provable no-op.
    pub fn active(&self) -> bool {
        !self.crashes.is_empty()
            || self.drop_prob > 0.0
            || self.delay_prob > 0.0
            || self.dup_prob > 0.0
            || !self.partitions.is_empty()
    }

    /// The scheduled crash vtime of `rank`, if any (earliest wins).
    pub fn crash_vtime(&self, rank: usize) -> Option<f64> {
        self.crashes
            .iter()
            .filter(|(r, _)| *r == rank)
            .map(|&(_, t)| t)
            .min_by(|a, b| a.total_cmp(b))
    }

    /// Crash oracle: is `rank` crashed at (or before) `vtime`? This is
    /// the simulator's stand-in for the transport-layer connection error
    /// a real shm/TCP backend would surface; it is a pure function of
    /// the plan, so every rank — in either exec mode — classifies the
    /// same failure identically.
    pub fn crashed_by(&self, rank: usize, vtime: f64) -> bool {
        self.crash_vtime(rank).is_some_and(|t| t <= vtime)
    }

    /// Ranks not crashed by `vtime`, out of `n`.
    pub fn survivors_at(&self, n: usize, vtime: f64) -> Vec<usize> {
        (0..n).filter(|&r| !self.crashed_by(r, vtime)).collect()
    }

    /// True when the `src -> dst` link is cut at `vtime`.
    pub fn partitioned(&self, src: usize, dst: usize, vtime: f64) -> bool {
        self.partitions.iter().any(|p| p.cuts(src, dst, vtime))
    }

    /// splitmix64-style stateless mix of the fate coordinates.
    fn fate_hash(&self, src: usize, dst: usize, seq: u64, salt: u64) -> u64 {
        let mut z = self
            .seed
            .wrapping_add((src as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add((dst as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .wrapping_add(seq.wrapping_mul(0x94D0_49BB_1331_11EB))
            .wrapping_add(salt.wrapping_mul(0xD6E8_FEB8_6659_FD93));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform roll in `[0, 1)` for one fate coordinate.
    fn roll(&self, src: usize, dst: usize, seq: u64, salt: u64) -> f64 {
        (self.fate_hash(src, dst, seq, salt) >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Fate of the `seq`-th message the sending entity puts on the
    /// `src -> dst` link at virtual time `send_vtime`. Pure in
    /// `(seed, src, dst, seq, send_vtime)`; both exec modes present
    /// identical coordinates, so the schedule is mode-invariant.
    /// Updates the shared [`FaultStats`].
    pub fn link_fate(&self, src: usize, dst: usize, seq: u64, send_vtime: f64) -> LinkFate {
        if !self.active() {
            return LinkFate::Delivered { extra_delay: 0.0, duplicate: false };
        }
        for attempt in 0..=self.max_retries {
            // Attempt k happens after the cumulative exponential backoff
            // base*(2^k - 1) (k = 0 -> immediately).
            let backoff = if attempt == 0 {
                0.0
            } else {
                self.backoff_base * ((1u64 << attempt) - 1) as f64
            };
            let at = send_vtime + backoff;
            if self.partitioned(src, dst, at) {
                continue; // attempt swallowed by the partition
            }
            if self.drop_prob > 0.0 && self.roll(src, dst, seq, attempt as u64) < self.drop_prob {
                continue; // attempt dropped by the lossy link
            }
            let mut extra = backoff;
            if attempt > 0 {
                self.stats.retried.fetch_add(1, Ordering::Relaxed);
            }
            if self.delay_prob > 0.0 && self.roll(src, dst, seq, 0xDE1A) < self.delay_prob {
                extra += self.roll(src, dst, seq, 0xDE1B) * self.delay_max;
                self.stats.delayed.fetch_add(1, Ordering::Relaxed);
            }
            let duplicate = self.dup_prob > 0.0 && self.roll(src, dst, seq, 0xD0B1) < self.dup_prob;
            if duplicate {
                self.stats.duplicated.fetch_add(1, Ordering::Relaxed);
            }
            return LinkFate::Delivered { extra_delay: extra, duplicate };
        }
        self.stats.lost.fetch_add(1, Ordering::Relaxed);
        LinkFate::Lost
    }

    /// Classify a deadline expiry on a receive from `src`: `PeerDown`
    /// when the crash oracle marks the peer crashed at the deadline,
    /// `Timeout` otherwise. Pure in vtime, hence mode-invariant.
    pub fn classify_expiry(&self, src: usize, deadline: f64) -> CommError {
        if src != usize::MAX && self.crashed_by(src, deadline) {
            CommError::PeerDown { peer: src, at: deadline }
        } else {
            CommError::Timeout { src, deadline }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_is_inert() {
        let p = FaultPlan::none();
        assert!(!p.active());
        let clean = LinkFate::Delivered { extra_delay: 0.0, duplicate: false };
        assert_eq!(p.link_fate(0, 1, 7, 0.5), clean);
        assert!(!p.crashed_by(3, 1e9));
        assert!(p.deadline.is_infinite());
    }

    #[test]
    fn fates_are_deterministic() {
        let a = FaultPlan::seeded(42, 1.0).with_drop(0.3, 2, 1e-4).with_delay(0.2, 1e-3);
        let b = FaultPlan::seeded(42, 1.0).with_drop(0.3, 2, 1e-4).with_delay(0.2, 1e-3);
        for seq in 0..200 {
            assert_eq!(a.link_fate(1, 2, seq, 0.01), b.link_fate(1, 2, seq, 0.01));
        }
    }

    #[test]
    fn drop_rate_is_approximately_honored() {
        let p = FaultPlan::seeded(7, 1.0).with_drop(0.5, 0, 0.0);
        let lost = (0..2000).filter(|&s| p.link_fate(0, 1, s, 0.0) == LinkFate::Lost).count();
        assert!((800..1200).contains(&lost), "lost {lost} of 2000 at p=0.5");
    }

    #[test]
    fn retries_recover_most_drops_with_backoff_delay() {
        let p = FaultPlan::seeded(7, 1.0).with_drop(0.3, 3, 1e-4);
        let mut lost = 0;
        for seq in 0..1000 {
            match p.link_fate(0, 1, seq, 0.0) {
                LinkFate::Lost => lost += 1,
                LinkFate::Delivered { extra_delay, .. } => assert!(extra_delay >= 0.0),
            }
        }
        // p_loss = 0.3^4 = 0.81% -> ~8 of 1000.
        assert!(lost < 40, "lost {lost} of 1000 with 3 retries at p=0.3");
    }

    #[test]
    fn partition_cuts_both_directions_and_heals() {
        let p = FaultPlan::seeded(1, 1.0).with_partition(vec![0, 1], vec![2, 3], 1.0, 2.0);
        assert!(p.partitioned(0, 2, 1.5));
        assert!(p.partitioned(3, 1, 1.5));
        assert!(!p.partitioned(0, 1, 1.5)); // same side
        assert!(!p.partitioned(0, 2, 2.0)); // healed
        assert_eq!(p.link_fate(0, 2, 9, 1.5), LinkFate::Lost);
        assert!(matches!(p.link_fate(0, 2, 9, 0.5), LinkFate::Delivered { .. }));
    }

    #[test]
    fn retry_backoff_rides_past_a_short_partition() {
        // Partition [1.0, 1.001); backoff base 1 ms reaches past it.
        let p = FaultPlan::seeded(1, 1.0)
            .with_partition(vec![0], vec![1], 1.0, 1.001)
            .with_drop(0.0, 2, 1e-3);
        match p.link_fate(0, 1, 0, 1.0) {
            LinkFate::Delivered { extra_delay, .. } => assert!(extra_delay >= 1e-3),
            LinkFate::Lost => panic!("retry should outlive the partition"),
        }
    }

    #[test]
    fn crash_oracle_is_a_step_function() {
        let p = FaultPlan::none().with_crash(2, 0.5);
        assert!(!p.crashed_by(2, 0.49));
        assert!(p.crashed_by(2, 0.5));
        assert!(!p.crashed_by(1, 9.0));
        assert_eq!(p.survivors_at(4, 1.0), vec![0, 1, 3]);
        assert_eq!(
            p.classify_expiry(2, 1.0),
            CommError::PeerDown { peer: 2, at: 1.0 }
        );
        assert_eq!(p.classify_expiry(1, 1.0), CommError::Timeout { src: 1, deadline: 1.0 });
    }
}
