//! Virtual network model — the AWS-testbed substitute (paper §VII, Table I).
//!
//! The paper's evaluation runs on p3.16xlarge machines: 8 V100s joined by
//! NVLink inside a machine, 25 Gbps Ethernet between machines. We model a
//! two-tier network: each *machine* (super node) hosts `ranks_per_machine`
//! ranks; links are characterized by bandwidth `B` (bytes/s) and latency
//! `L` (s). Intra-machine links are fast ("NVLink"), inter-machine links
//! slow ("NIC").
//!
//! [`NetworkModel::transfer_time`] prices a point-to-point message; the
//! collectives in [`crate::collective`] call it per hop so the virtual
//! clock reproduces the *structural* costs of Table I:
//!
//! | primitive            | cost             |
//! |----------------------|------------------|
//! | Parameter Server     | `nM/B + nL`      |
//! | Ring-Allreduce       | `2M/B + 2nL`     |
//! | BytePS               | `M/B + nL`       |
//! | partial averaging    | `M/B + L`        |
//!
//! The same formulas are also exposed in closed form
//! ([`analytic`]) so the Table I bench can print model-vs-simulated rows.

/// Link tiers of the two-level hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkTier {
    /// Same machine (NVLink / shared memory).
    Intra,
    /// Cross machine (NIC).
    Inter,
    /// Loopback (same rank) — free.
    Loopback,
}

pub mod event;
pub mod faults;
pub mod hetero;
pub mod schedule;

/// Two-tier bandwidth/latency network model.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// Ranks per machine (8 on p3.16xlarge). 1 = every rank its own machine.
    pub ranks_per_machine: usize,
    /// Intra-machine bandwidth, bytes/s (NVLink ~ 300 GB/s on V100).
    pub intra_bw: f64,
    /// Intra-machine latency, seconds (~3 µs).
    pub intra_lat: f64,
    /// Inter-machine bandwidth, bytes/s (25 Gbps ≈ 3.125 GB/s).
    pub inter_bw: f64,
    /// Inter-machine latency, seconds (~50 µs TCP without RDMA).
    pub inter_lat: f64,
    /// Per-message sender/receiver CPU overhead, intra tier (LogP's `o`).
    /// Unlike latency, overhead *occupies the port*, so it serializes
    /// across messages — this is what tensor fusion amortizes.
    pub intra_overhead: f64,
    /// Per-message overhead, inter tier (TCP stack, ~20-30 µs w/o RDMA).
    pub inter_overhead: f64,
}

impl NetworkModel {
    /// The paper's GPU testbed: 8 ranks/machine, NVLink intra, 25 Gbps inter.
    pub fn aws_p3(ranks_per_machine: usize) -> Self {
        NetworkModel {
            ranks_per_machine,
            intra_bw: 300e9,
            intra_lat: 3e-6,
            inter_bw: 25e9 / 8.0,
            inter_lat: 50e-6,
            intra_overhead: 1e-6,
            inter_overhead: 20e-6,
        }
    }

    /// The paper's CPU testbed (m4.4xlarge, flat 10 Gbps-ish network):
    /// single tier.
    pub fn aws_m4() -> Self {
        NetworkModel {
            ranks_per_machine: 1,
            intra_bw: 10e9 / 8.0,
            intra_lat: 25e-6,
            inter_bw: 10e9 / 8.0,
            inter_lat: 25e-6,
            intra_overhead: 15e-6,
            inter_overhead: 15e-6,
        }
    }

    /// A flat homogeneous network with explicit parameters.
    pub fn flat(bandwidth: f64, latency: f64) -> Self {
        NetworkModel {
            ranks_per_machine: 1,
            intra_bw: bandwidth,
            intra_lat: latency,
            inter_bw: bandwidth,
            inter_lat: latency,
            intra_overhead: 0.0,
            inter_overhead: 0.0,
        }
    }

    /// Set both tiers' per-message overhead (builder style).
    pub fn with_overhead(mut self, overhead: f64) -> Self {
        self.intra_overhead = overhead;
        self.inter_overhead = overhead;
        self
    }

    /// Machine (super-node) index of a rank.
    pub fn machine_of(&self, rank: usize) -> usize {
        rank / self.ranks_per_machine.max(1)
    }

    /// Local rank within its machine.
    pub fn local_rank(&self, rank: usize) -> usize {
        rank % self.ranks_per_machine.max(1)
    }

    /// Which tier the `src -> dst` link belongs to.
    pub fn tier(&self, src: usize, dst: usize) -> LinkTier {
        if src == dst {
            LinkTier::Loopback
        } else if self.machine_of(src) == self.machine_of(dst) {
            LinkTier::Intra
        } else {
            LinkTier::Inter
        }
    }

    /// Bandwidth of the `src -> dst` link, bytes/s.
    pub fn bandwidth(&self, src: usize, dst: usize) -> f64 {
        match self.tier(src, dst) {
            LinkTier::Loopback => f64::INFINITY,
            LinkTier::Intra => self.intra_bw,
            LinkTier::Inter => self.inter_bw,
        }
    }

    /// Latency of the `src -> dst` link, seconds.
    pub fn latency(&self, src: usize, dst: usize) -> f64 {
        match self.tier(src, dst) {
            LinkTier::Loopback => 0.0,
            LinkTier::Intra => self.intra_lat,
            LinkTier::Inter => self.inter_lat,
        }
    }

    /// Per-message CPU overhead of the `src -> dst` link (serializes on the
    /// ports, amortized by tensor fusion).
    pub fn msg_overhead(&self, src: usize, dst: usize) -> f64 {
        match self.tier(src, dst) {
            LinkTier::Loopback => 0.0,
            LinkTier::Intra => self.intra_overhead,
            LinkTier::Inter => self.inter_overhead,
        }
    }

    /// Serialization (bandwidth-bound) time of `bytes` on the link.
    pub fn serialization_time(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        let bw = self.bandwidth(src, dst);
        if bw.is_infinite() {
            0.0
        } else {
            bytes as f64 / bw
        }
    }

    /// Port-occupancy time of one message: serialization + overhead.
    pub fn port_time(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        self.serialization_time(src, dst, bytes) + self.msg_overhead(src, dst)
    }

    /// Total unloaded transfer time `M/B + L` for one message.
    pub fn transfer_time(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        self.serialization_time(src, dst, bytes) + self.latency(src, dst)
    }
}

/// Closed-form communication costs of Table I (n nodes, message M bytes,
/// flat network with bandwidth B and latency L).
pub mod analytic {
    /// Parameter server: every worker's full message crosses the server's
    /// NIC: `nM/B + nL`.
    pub fn parameter_server(n: usize, m: f64, b: f64, l: f64) -> f64 {
        n as f64 * m / b + n as f64 * l
    }

    /// Ring-Allreduce: `2M/B + 2nL` (reduce-scatter + allgather, n-1 rounds
    /// each of M/n bytes).
    pub fn ring_allreduce(n: usize, m: f64, b: f64, l: f64) -> f64 {
        2.0 * (n as f64 - 1.0) / n as f64 * m / b + 2.0 * (n as f64 - 1.0) * l
    }

    /// BytePS: `M/B + nL` using n extra CPU servers.
    pub fn byteps(n: usize, m: f64, b: f64, l: f64) -> f64 {
        m / b + n as f64 * l
    }

    /// Partial averaging on a sparse graph of max degree `deg`:
    /// `deg * M/B + L` — independent of n. With `deg = 1` (one-peer
    /// exponential graph) this is the paper's `M/B + L` row.
    pub fn partial_averaging(deg: usize, m: f64, b: f64, l: f64) -> f64 {
        deg as f64 * m / b + l
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiers_follow_machine_boundaries() {
        let net = NetworkModel::aws_p3(8);
        assert_eq!(net.tier(0, 7), LinkTier::Intra);
        assert_eq!(net.tier(0, 8), LinkTier::Inter);
        assert_eq!(net.tier(3, 3), LinkTier::Loopback);
        assert_eq!(net.machine_of(15), 1);
        assert_eq!(net.local_rank(13), 5);
    }

    #[test]
    fn intra_is_faster_than_inter() {
        let net = NetworkModel::aws_p3(8);
        let m = 10 << 20;
        assert!(net.transfer_time(0, 1, m) < net.transfer_time(0, 9, m) / 10.0);
    }

    #[test]
    fn flat_network_single_tier() {
        let net = NetworkModel::flat(1e9, 1e-5);
        assert_eq!(net.bandwidth(0, 5), 1e9);
        assert_eq!(net.latency(0, 5), 1e-5);
        assert_eq!(net.transfer_time(2, 2, 123456), 0.0);
    }

    #[test]
    fn table1_ordering_holds_at_scale() {
        // For large n and sizeable M: PS > ring > byteps > partial.
        let (m, b, l) = (100e6, 3.125e9, 50e-6);
        let n = 64;
        let ps = analytic::parameter_server(n, m, b, l);
        let ring = analytic::ring_allreduce(n, m, b, l);
        let byteps = analytic::byteps(n, m, b, l);
        let partial = analytic::partial_averaging(1, m, b, l);
        assert!(ps > ring, "ps={ps} ring={ring}");
        assert!(ring > byteps, "ring={ring} byteps={byteps}");
        assert!(byteps > partial, "byteps={byteps} partial={partial}");
    }

    #[test]
    fn partial_averaging_is_n_independent() {
        let (m, b, l) = (1e6, 1e9, 1e-5);
        let c = analytic::partial_averaging(2, m, b, l);
        // No n anywhere in the formula — the whole point of the paper.
        assert!((c - (2.0 * m / b + l)).abs() < 1e-12);
    }

    #[test]
    fn ring_latency_term_grows_linearly() {
        let (m, b, l) = (1e6, 1e12, 1e-4); // bandwidth negligible
        let c8 = analytic::ring_allreduce(8, m, b, l);
        let c64 = analytic::ring_allreduce(64, m, b, l);
        assert!(c64 / c8 > 7.0, "latency term should scale ~n: {c64}/{c8}");
    }
}
