//! Deterministic step-schedule model for DNN training throughput
//! (paper Fig. 8, Fig. 12, Table II).
//!
//! The in-process transport cannot physically move BERT-large's 1.4 GB/rank
//! per step at n = 128, so the throughput benches use this analytic
//! scheduler instead: it reproduces the paper's Fig. 8 timeline semantics —
//! layer-wise backward compute produces gradient *buckets* back-to-front;
//! each bucket's communication is enqueued on the NIC as soon as its
//! prerequisite is ready (ATC: when the bucket's gradient is computed; AWC:
//! at step start, since AWC communicates last iteration's parameters); the
//! NIC serializes transfers; the step ends when both compute and the last
//! transfer finish.
//!
//! Per-bucket communication costs follow Table I:
//! - ring allreduce: `2b(n-1)/(n B) + 2(n-1)L`
//! - one-peer partial averaging: `b/B + L`
//! - hierarchical: intra-machine ring over `g` ranks on the fast tier,
//!   one-peer machine-level exchange on the slow tier, intra broadcast.

use crate::config::WorkloadModel;
use crate::simnet::NetworkModel;

/// Communication pattern per bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommScheme {
    /// Chunked ring allreduce over all n ranks (Horovod baseline).
    RingAllreduce,
    /// One-peer dynamic exponential partial averaging.
    NeighborOnePeer,
    /// Hierarchical: intra-machine ring + machine-level one-peer + bcast.
    HierarchicalOnePeer,
    /// No communication (upper bound).
    None,
}

/// When a bucket's communication may start (paper §V-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TriggerStyle {
    /// Adapt-Then-Communicate: bucket leaves after its gradient is ready.
    Atc,
    /// Adapt-While-Communicate: parameters from the previous iteration are
    /// sent from step start, fully overlapping the whole forward+backward.
    Awc,
    /// No overlap: all communication after the full backward pass
    /// (unoptimized baseline for the ablation).
    Sequential,
}

/// Time to move `bytes` once under `scheme` on network `net` with `n` ranks.
pub fn bucket_comm_time(scheme: CommScheme, bytes: f64, n: usize, net: &NetworkModel) -> f64 {
    let g = net.ranks_per_machine.max(1).min(n);
    let machines = n / g.max(1);
    // Effective per-rank link for flat schemes: the slowest tier in use.
    let (bw, lat) = if machines > 1 {
        (net.inter_bw, net.inter_lat)
    } else {
        (net.intra_bw, net.intra_lat)
    };
    match scheme {
        CommScheme::None => 0.0,
        CommScheme::RingAllreduce => {
            if n == 1 {
                0.0
            } else {
                // 2(n-1) rounds of bytes/n; each round crosses the slowest
                // link on the ring.
                2.0 * (n as f64 - 1.0) / n as f64 * bytes / bw + 2.0 * (n as f64 - 1.0) * lat
            }
        }
        CommScheme::NeighborOnePeer => {
            if n == 1 {
                0.0
            } else {
                bytes / bw + lat
            }
        }
        CommScheme::HierarchicalOnePeer => {
            // Step 1: intra ring-allreduce over g ranks (fast tier);
            // Step 2: machine-level one-peer exchange (slow tier);
            // Step 3: intra broadcast (fast tier).
            let intra_ring = if g > 1 {
                2.0 * (g as f64 - 1.0) / g as f64 * bytes / net.intra_bw
                    + 2.0 * (g as f64 - 1.0) * net.intra_lat
            } else {
                0.0
            };
            let inter = if machines > 1 { bytes / net.inter_bw + net.inter_lat } else { 0.0 };
            let bcast = if g > 1 { bytes / net.intra_bw + net.intra_lat } else { 0.0 };
            intra_ring + inter + bcast
        }
    }
}

/// Fuse per-layer buckets into transfer buckets of at least
/// `threshold_bytes` (Horovod's tensor fusion; paper §VI-C notes a smaller
/// optimal buffer for neighbor communication). `0` disables fusion.
pub fn fuse_buckets(layer_params: &[usize], threshold_bytes: usize) -> Vec<usize> {
    if threshold_bytes == 0 {
        return layer_params.to_vec();
    }
    let mut out = vec![];
    let mut acc = 0usize;
    for &p in layer_params {
        acc += p;
        if acc * 4 >= threshold_bytes {
            out.push(acc);
            acc = 0;
        }
    }
    if acc > 0 {
        out.push(acc);
    }
    out
}

/// Simulate one training step; returns `(step_time_s, comm_exposed_s)` where
/// `comm_exposed` is the communication time *not* hidden by compute.
pub fn step_time(
    workload: &WorkloadModel,
    n: usize,
    net: &NetworkModel,
    scheme: CommScheme,
    trigger: TriggerStyle,
    device_flops: f64,
    efficiency: f64,
) -> (f64, f64) {
    // Default fusion: Horovod's 64 MB buffer for ring allreduce (amortizes
    // the O(n) latency term); 8 MB for neighbor communication, whose O(1)
    // latency prefers smaller buffers (paper §VI-C).
    let fusion = match scheme {
        CommScheme::RingAllreduce => 64 << 20,
        _ => 8 << 20,
    };
    step_time_fused(workload, n, net, scheme, trigger, device_flops, efficiency, fusion)
}

/// [`step_time`] with an explicit fusion threshold (bytes; 0 = off).
#[allow(clippy::too_many_arguments)]
pub fn step_time_fused(
    workload: &WorkloadModel,
    n: usize,
    net: &NetworkModel,
    scheme: CommScheme,
    trigger: TriggerStyle,
    device_flops: f64,
    efficiency: f64,
    fusion_bytes: usize,
) -> (f64, f64) {
    let total_compute = workload.step_compute_time(device_flops, efficiency);
    // Forward ~1/3, backward ~2/3 of step compute (standard fwd:bwd 1:2).
    let fwd = total_compute / 3.0;
    let bwd = total_compute - fwd;
    let buckets = fuse_buckets(&workload.layer_params, fusion_bytes);
    let total_params: usize = buckets.iter().sum();

    // Gradient buckets become ready back-to-front during backward,
    // proportionally to their parameter mass.
    let mut ready_times = Vec::with_capacity(buckets.len());
    let mut acc = 0.0;
    for &p in &buckets {
        acc += p as f64 / total_params as f64 * bwd;
        ready_times.push(match trigger {
            TriggerStyle::Atc => fwd + acc,
            TriggerStyle::Awc => 0.0,
            TriggerStyle::Sequential => total_compute,
        });
    }

    // NIC serializes bucket transfers in ready order.
    let mut nic_free: f64 = 0.0;
    let mut last_arrival: f64 = 0.0;
    let mut exposed = 0.0f64;
    let mut order: Vec<usize> = (0..ready_times.len()).collect();
    order.sort_by(|&a, &b| ready_times[a].partial_cmp(&ready_times[b]).unwrap());
    for i in order {
        let bytes = buckets[i] as f64 * 4.0;
        let t = bucket_comm_time(scheme, bytes, n, net);
        let start = ready_times[i].max(nic_free);
        nic_free = start + t;
        last_arrival = last_arrival.max(nic_free);
        exposed = (nic_free - total_compute).max(exposed);
    }
    let step = total_compute.max(last_arrival);
    (step, exposed.max(0.0))
}

/// Throughput in samples/s for `n` ranks.
pub fn throughput(
    workload: &WorkloadModel,
    n: usize,
    net: &NetworkModel,
    scheme: CommScheme,
    trigger: TriggerStyle,
    device_flops: f64,
    efficiency: f64,
) -> f64 {
    let (step, _) = step_time(workload, n, net, scheme, trigger, device_flops, efficiency);
    n as f64 * workload.batch as f64 / step
}

/// Scaling efficiency vs ideal linear scaling from 1 rank.
pub fn scaling_efficiency(
    workload: &WorkloadModel,
    n: usize,
    net: &NetworkModel,
    scheme: CommScheme,
    trigger: TriggerStyle,
    device_flops: f64,
    efficiency: f64,
) -> f64 {
    let t1 = workload.batch as f64 / workload.step_compute_time(device_flops, efficiency);
    let tn = throughput(workload, n, net, scheme, trigger, device_flops, efficiency);
    tn / (n as f64 * t1)
}

#[cfg(test)]
mod tests {
    use super::*;

    const V100: f64 = 125e12;
    const EFF: f64 = 0.35;

    #[test]
    fn neighbor_beats_ring_at_scale() {
        let w = WorkloadModel::vgg16();
        let net = NetworkModel::aws_p3(8);
        let (ring, _) = step_time(&w, 64, &net, CommScheme::RingAllreduce, TriggerStyle::Atc, V100, EFF);
        let (nbr, _) = step_time(&w, 64, &net, CommScheme::NeighborOnePeer, TriggerStyle::Atc, V100, EFF);
        assert!(nbr < ring, "neighbor {nbr} vs ring {ring}");
    }

    #[test]
    fn single_rank_has_no_comm() {
        let w = WorkloadModel::resnet50();
        let net = NetworkModel::aws_p3(8);
        let (t, exposed) =
            step_time(&w, 1, &net, CommScheme::RingAllreduce, TriggerStyle::Atc, V100, EFF);
        assert!((t - w.step_compute_time(V100, EFF)).abs() < 1e-12);
        assert_eq!(exposed, 0.0);
    }

    #[test]
    fn awc_overlaps_at_least_as_much_as_atc() {
        let w = WorkloadModel::bert_large();
        let net = NetworkModel::aws_p3(8);
        for n in [8, 32, 128] {
            let (atc, _) =
                step_time(&w, n, &net, CommScheme::NeighborOnePeer, TriggerStyle::Atc, V100, EFF);
            let (awc, _) =
                step_time(&w, n, &net, CommScheme::NeighborOnePeer, TriggerStyle::Awc, V100, EFF);
            assert!(awc <= atc + 1e-12, "n={n}: awc {awc} vs atc {atc}");
        }
    }

    #[test]
    fn overlap_beats_sequential() {
        let w = WorkloadModel::vgg16();
        let net = NetworkModel::aws_p3(8);
        let (atc, _) =
            step_time(&w, 16, &net, CommScheme::RingAllreduce, TriggerStyle::Atc, V100, EFF);
        let (seq, _) =
            step_time(&w, 16, &net, CommScheme::RingAllreduce, TriggerStyle::Sequential, V100, EFF);
        assert!(atc < seq, "atc {atc} vs sequential {seq}");
    }

    #[test]
    fn efficiency_drops_crossing_machine_boundary() {
        // The paper's Fig. 12 observation: scaling efficiency drops sharply
        // from 8 GPUs (one machine) to 16 (two machines).
        let w = WorkloadModel::bert_large();
        let net = NetworkModel::aws_p3(8);
        let e8 = scaling_efficiency(&w, 8, &net, CommScheme::NeighborOnePeer, TriggerStyle::Atc, V100, EFF);
        let e16 = scaling_efficiency(&w, 16, &net, CommScheme::NeighborOnePeer, TriggerStyle::Atc, V100, EFF);
        assert!(e8 > 0.9, "intra-machine should be near-linear: {e8}");
        assert!(e16 < e8 - 0.05, "machine boundary should cost efficiency: {e8} -> {e16}");
    }

    #[test]
    fn hierarchical_beats_flat_neighbor_for_many_machines() {
        // With 8 fast local ranks, paying NVLink prices for the intra part
        // and sending only once over the NIC per machine beats every rank
        // individually crossing the NIC.
        let w = WorkloadModel::vgg16();
        let net = NetworkModel::aws_p3(8);
        let flat = bucket_comm_time(CommScheme::NeighborOnePeer, 552e6, 64, &net);
        let hier = bucket_comm_time(CommScheme::HierarchicalOnePeer, 552e6, 64, &net);
        // Flat: every rank pushes 552 MB over its NIC share; hierarchical
        // sends the same volume once per machine after a cheap NVLink
        // reduction. Same NIC bytes per machine-pair link here, so the two
        // are close; hierarchical must not be dramatically worse.
        assert!(hier < flat * 1.5, "hier {hier} vs flat {flat}");
        let _ = w;
    }

    #[test]
    fn throughput_monotone_in_n_for_neighbor() {
        let w = WorkloadModel::resnet50();
        let net = NetworkModel::aws_p3(8);
        let t8 = throughput(&w, 8, &net, CommScheme::NeighborOnePeer, TriggerStyle::Atc, V100, EFF);
        let t64 = throughput(&w, 64, &net, CommScheme::NeighborOnePeer, TriggerStyle::Atc, V100, EFF);
        // 8 -> 64 ranks crosses the machine boundary (NVLink -> 25 Gbps),
        // so scaling is sub-linear but still substantial.
        assert!(t64 > 3.5 * t8, "partial averaging scales: {t8} -> {t64}");
    }
}
