//! Minimal property-based testing framework.
//!
//! `proptest`/`quickcheck` are unavailable in this offline environment, so
//! this module provides the same workflow in ~150 lines: a seeded
//! generator, value strategies (including random graphs and stochastic
//! matrices), a runner that reports the failing seed, and bounded
//! shrinking for numeric inputs. Coordinator invariants (consensus
//! contraction, fusion round-trips, push-sum mass conservation, …) are
//! tested with it in `rust/tests/`.

use crate::rng::Rng;
use crate::topology::{builders, Graph, WeightMatrix};

/// Value generator handed to properties.
pub struct Gen {
    /// The underlying seeded generator (exposed for custom strategies).
    pub rng: Rng,
}

impl Gen {
    /// Generator for one property case, derived from the runner's seed.
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed) }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.usize_in(lo, hi)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_in(lo, hi)
    }

    /// Vector of `len` uniform samples in `[lo, hi)`.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        self.rng.uniform_vec(len, lo, hi)
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// A random *connected undirected* graph over `n` nodes: a random
    /// spanning ring plus each extra edge with probability `p_extra`.
    pub fn connected_graph(&mut self, n: usize, p_extra: f64) -> Graph {
        let mut perm: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut perm);
        let mut g = Graph::empty(n);
        for i in 0..n {
            if n > 1 {
                g.add_undirected_edge(perm[i], perm[(i + 1) % n]);
            }
        }
        for a in 0..n {
            for b in (a + 1)..n {
                if self.rng.chance(p_extra) {
                    g.add_undirected_edge(a, b);
                }
            }
        }
        g
    }

    /// A random strongly-connected *directed* graph: a directed ring over a
    /// random permutation plus random extra arcs.
    pub fn strongly_connected_digraph(&mut self, n: usize, p_extra: f64) -> Graph {
        let mut perm: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut perm);
        let mut g = Graph::empty(n);
        for i in 0..n {
            if n > 1 {
                g.add_edge(perm[i], perm[(i + 1) % n]);
            }
        }
        for a in 0..n {
            for b in 0..n {
                if a != b && self.rng.chance(p_extra) {
                    g.add_edge(a, b);
                }
            }
        }
        g
    }

    /// A random doubly-stochastic matrix (Metropolis–Hastings on a random
    /// connected graph).
    pub fn doubly_stochastic(&mut self, n: usize) -> WeightMatrix {
        let g = self.connected_graph(n, 0.3);
        WeightMatrix::metropolis_hastings(&g)
    }

    /// One of the built-in topologies, by random choice.
    pub fn builtin_graph(&mut self, n: usize) -> Graph {
        match self.usize_in(0, 5) {
            0 => builders::ring(n),
            1 => builders::star(n),
            2 => builders::fully_connected(n),
            3 => builders::mesh_grid_2d(n),
            _ => builders::exponential_two(n),
        }
    }
}

/// Outcome of a property check.
pub type PropResult = Result<(), String>;

/// Run `prop` for `cases` seeds derived from `base_seed`. Panics (failing
/// the enclosing test) with the offending seed and message on first
/// failure, after attempting to find a smaller failing case by re-running
/// nearby seeds.
pub fn check<F: Fn(&mut Gen) -> PropResult>(name: &str, cases: usize, prop: F) {
    check_seeded(name, 0x5eed_b1fe, cases, prop)
}

/// Like [`check`] with an explicit base seed (to reproduce failures).
pub fn check_seeded<F: Fn(&mut Gen) -> PropResult>(
    name: &str,
    base_seed: u64,
    cases: usize,
    prop: F,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut gen = Gen::new(seed);
        if let Err(msg) = prop(&mut gen) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with check_seeded(\"{name}\", {seed:#x}, 1, ...)"
            );
        }
    }
}

/// Assert-like helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_for_tautology() {
        check("tautology", 50, |g| {
            let n = g.usize_in(1, 10);
            prop_assert!(n >= 1, "n = {n}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'falsum' failed")]
    fn check_reports_failures_with_seed() {
        check("falsum", 10, |g| {
            let n = g.usize_in(0, 100);
            prop_assert!(n < 5, "found n = {n}");
            Ok(())
        });
    }

    #[test]
    fn generated_graphs_are_connected() {
        check("connected", 30, |g| {
            let n = g.usize_in(2, 12);
            let graph = g.connected_graph(n, 0.2);
            prop_assert!(graph.is_strongly_connected(), "disconnected graph size {n}");
            prop_assert!(graph.is_undirected(), "not undirected");
            Ok(())
        });
    }

    #[test]
    fn generated_digraphs_strongly_connected() {
        check("sc-digraph", 30, |g| {
            let n = g.usize_in(2, 12);
            let graph = g.strongly_connected_digraph(n, 0.1);
            prop_assert!(graph.is_strongly_connected(), "not strongly connected, n={n}");
            Ok(())
        });
    }

    #[test]
    fn generated_matrices_doubly_stochastic() {
        check("ds-matrix", 20, |g| {
            let n = g.usize_in(2, 10);
            let w = g.doubly_stochastic(n);
            prop_assert!(w.is_doubly_stochastic(1e-9), "not doubly stochastic n={n}");
            Ok(())
        });
    }
}
