//! Deterministic pseudo-random number generation.
//!
//! The whole repository (data synthesis, weight init, property tests,
//! simulated delays) uses this splitmix64/xoshiro-style generator so that
//! every experiment is reproducible from a single seed. No external `rand`
//! crate is available offline; this is a faithful minimal replacement.

/// A small, fast, deterministic PRNG (xorshift64* seeded via splitmix64).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        // splitmix64 scramble so that small seeds decorrelate.
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        Rng { state: z | 1 }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn usize_below(&mut self, n: usize) -> usize {
        assert!(n > 0, "usize_below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.usize_below(hi - lo)
    }

    /// Standard normal sample (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard-normal f32 samples.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Vector of uniform f32 samples in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| lo + (hi - lo) * self.f32()).collect()
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-node seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn usize_below_covers_range() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.usize_below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
