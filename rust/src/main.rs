//! `bfrun` — the BlueFog-rs launcher CLI (paper §VI-A).
//!
//! Subcommands:
//! - `train`     decentralized DNN training on simulated nodes (E2E driver)
//! - `consensus` average-consensus demo (`--backend sim|tcp`)
//! - `dsgd`      decentralized SGD on synthetic data (`--backend sim|tcp`)
//! - `info`      artifact + preset inventory
//!
//! With `--backend tcp`, the binary re-executes itself as one OS process
//! per rank over loopback sockets (DESIGN.md §Transport backends) and
//! cross-checks the result against the in-process simulator.
//!
//! Examples:
//! ```text
//! bfrun train --preset tiny --nodes 8 --steps 200 --algo atc --topology expo2
//! bfrun consensus --nodes 16 --topology ring --iters 200
//! bfrun consensus --backend tcp --nodes 4 --topology ring --iters 50
//! bfrun dsgd --backend tcp --nodes 4 --topology ring --iters 50 --dim 64
//! bfrun info
//! ```

use std::sync::Arc;

use bluefog::cli::Args;
use bluefog::config::{AlgoConfig, ModelPreset, PortableWorkload, TcpJobSpec};
use bluefog::launcher::{maybe_run_tcp_worker, run_spmd, run_tcp_job, BackendKind, SpmdConfig};
use bluefog::optim::{make_optimizer_cfg, CommSpec};
use bluefog::runtime::DeviceService;
use bluefog::simnet::NetworkModel;
use bluefog::tensor::norm2;
use bluefog::topology::dynamic::OnePeerExpo;
use bluefog::topology::builders;
use bluefog::training::{train_node, ShardSpec, TrainRun};
use bluefog::transport::portable::{run_sim_fleet, RunSpec};

fn main() {
    // Worker mode: when the parent launcher set BF_TCP_WORKER, this
    // process is one rank of a TCP job and never reaches the CLI.
    maybe_run_tcp_worker();
    if let Err(e) = run() {
        eprintln!("bfrun: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("consensus") => cmd_consensus(&args),
        Some("dsgd") => cmd_portable(&args, PortableWorkload::Dsgd),
        Some("info") => cmd_info(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand '{o}'");
            }
            eprintln!(
                "usage: bfrun <train|consensus|dsgd|info> [--backend sim|tcp] [--nodes N] ..."
            );
            std::process::exit(2);
        }
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let nodes = args.usize_or("nodes", 8)?;
    let steps = args.usize_or("steps", 100)?;
    let preset_name = args.choice_or("preset", "nano", &["nano", "tiny", "small"])?;
    let topo_name = args.str_or("topology", "expo2").to_string();
    let dynamic = args.bool_or("dynamic", false)?;
    let pallas = args.bool_or("pallas", false)?;
    let artifacts_dir = args.str_or("artifacts", "artifacts").to_string();
    let ranks_per_machine = args.usize_or("local-size", nodes.min(8))?;
    // The whole algorithm surface (--algo/--lr/--beta/--order/
    // --local-steps/--global-period/--weighting/--admm-*) parses into one
    // registry config; `train` keeps its historical lr default of 0.3.
    let mut acfg = AlgoConfig::from_args(args)?;
    if !args.has("lr") {
        acfg.gamma = 0.3;
    }
    let noniid = args.bool_or("noniid", false)?;

    let preset = ModelPreset::by_name(preset_name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {preset_name}"))?;
    let device = DeviceService::new();
    let (graph, weights) = builders::by_name(&topo_name, nodes)?;

    let net = NetworkModel::aws_p3(ranks_per_machine.max(1));
    let cfg = SpmdConfig::new(nodes)
        .with_net(net)
        .with_topology(graph, weights)
        .with_device(device.handle());

    let mut run = TrainRun::new(preset.clone(), steps);
    run.artifacts_dir = artifacts_dir;
    run.use_pallas = pallas;
    if noniid {
        run.noniid = Some(ShardSpec::default());
    }

    println!(
        "# train preset={} nodes={nodes} steps={steps} algo={} topology={topo_name}{} lr={} \
         local_steps={} weighting={}{}",
        preset.name,
        acfg.algo,
        if dynamic { " (dynamic)" } else { "" },
        acfg.gamma,
        acfg.local_steps,
        acfg.weighting,
        if noniid { " (non-iid shards)" } else { "" },
    );
    println!("# params={} flops/step={:.3e}", preset.param_count(), preset.flops_per_step());

    let acfg2 = acfg.clone();
    let results = run_spmd(cfg, move |ctx| {
        let comm = if dynamic {
            CommSpec::Dynamic(Arc::new(OnePeerExpo::new(ctx.size())))
        } else {
            CommSpec::Static
        };
        let mut opt = make_optimizer_cfg(&acfg2, comm)?;
        let (logs, params) = train_node(ctx, &run, &mut opt)?;
        Ok((logs, params, ctx.vtime()))
    })?;

    // Report from rank 0 (the paper's convention: "we take the solution at
    // the rank-0 node").
    let (logs, _, vtime) = &results[0];
    println!("# step, loss, vtime_s, wall_s, comm_rounds");
    for l in logs {
        println!(
            "{:6} {:8.4} {:10.4} {:8.2} {:6}",
            l.step, l.loss, l.vtime, l.wall, l.comm_rounds
        );
    }
    let first = logs.first().map(|l| l.loss).unwrap_or(f32::NAN);
    let last = logs.last().map(|l| l.loss).unwrap_or(f32::NAN);
    println!("# loss {first:.4} -> {last:.4}; simulated time {vtime:.3}s");
    Ok(())
}

fn cmd_consensus(args: &Args) -> anyhow::Result<()> {
    if BackendKind::parse(args.str_or("backend", "sim"))? == BackendKind::Tcp {
        return cmd_portable(args, PortableWorkload::Consensus);
    }
    let nodes = args.usize_or("nodes", 16)?;
    let iters = args.usize_or("iters", 100)?;
    let topo_name = args.str_or("topology", "expo2").to_string();
    let (graph, weights) = builders::by_name(&topo_name, nodes)?;
    println!("# consensus nodes={nodes} topology={topo_name} iters={iters}");
    println!("# spectral gap: {:.4}", weights.spectral_gap());
    let cfg = SpmdConfig::new(nodes).with_topology(graph, weights);
    let results = run_spmd(cfg, move |ctx| {
        let mut x = vec![ctx.rank() as f32; 4];
        for _ in 0..iters {
            x = ctx.neighbor_allreduce(&x)?;
        }
        Ok(x[0])
    })?;
    let mean = (nodes as f32 - 1.0) / 2.0;
    let err: f64 = results.iter().map(|&x| (x - mean) as f64).map(|e| e * e).sum::<f64>().sqrt();
    println!("values: {results:?}");
    println!("consensus error vs true mean {mean}: {err:.3e}");
    Ok(())
}

/// `consensus --backend tcp` and the `dsgd` subcommand: run a portable
/// workload over the chosen backend; under TCP, cross-verify against the
/// in-process simulator (`--verify false` to skip).
fn cmd_portable(args: &Args, workload: PortableWorkload) -> anyhow::Result<()> {
    let backend = BackendKind::parse(args.str_or("backend", "sim"))?;
    let kill = match (args.usize_opt("kill-rank")?, args.usize_opt("kill-at")?) {
        (Some(r), Some(a)) => Some((r, a)),
        (None, None) => None,
        _ => anyhow::bail!("--kill-rank and --kill-at must be given together"),
    };
    let spec = TcpJobSpec {
        workload,
        nodes: args.usize_or("nodes", 4)?,
        iters: args.usize_or("iters", 50)?,
        dim: args.usize_or("dim", 32)?,
        rows: args.usize_or("rows", 16)?,
        gamma: args.f64_or("gamma", 0.05)? as f32,
        topology: args.str_or("topology", "ring").to_string(),
        deadline_secs: args.f64_or("deadline", 30.0)?,
        kill,
    };
    println!(
        "# {} backend={:?} nodes={} iters={} dim={} topology={}",
        workload.as_str(),
        backend,
        spec.nodes,
        spec.iters,
        spec.dim,
        spec.topology
    );
    let run = RunSpec::from_job(&spec);

    if backend == BackendKind::Sim {
        let outs = run_sim_fleet(spec.nodes, workload, &run);
        for (rank, out) in outs.into_iter().enumerate() {
            match out {
                Ok(o) => println!("rank {rank}: bytes={} x[0]={:.6}", o.bytes_sent, o.x[0]),
                Err(e) => println!("rank {rank}: error {e}"),
            }
        }
        return Ok(());
    }

    let report = run_tcp_job(&spec)?;
    for r in &report.ranks {
        match (&r.output, &r.error) {
            (Some(o), _) => {
                println!("rank {}: bytes={} x[0]={:.6}", r.rank, o.bytes_sent, o.x[0])
            }
            (None, Some(e)) => println!(
                "rank {}: {}{} (exit code {:?})",
                r.rank,
                e.kind,
                e.peer.map(|p| format!(" peer={p}")).unwrap_or_default(),
                r.exit_code
            ),
            (None, None) => println!("rank {}: no result (exit code {:?})", r.rank, r.exit_code),
        }
    }
    if spec.kill.is_some() {
        // A killed job has no complete result set to verify; the per-rank
        // error lines above are the point of the run.
        return Ok(());
    }
    if args.bool_or("verify", true)? {
        let tcp_outs = report.outputs()?;
        let sim_outs = run_sim_fleet(spec.nodes, workload, &run);
        let mut max_delta = 0.0f64;
        let mut bytes_match = true;
        for (t, s) in tcp_outs.iter().zip(&sim_outs) {
            let s = s.as_ref().expect("sim reference rank failed");
            for (a, b) in t.x.iter().zip(&s.x) {
                max_delta = max_delta.max((*a as f64 - *b as f64).abs());
            }
            bytes_match &= t.bytes_sent == s.bytes_sent;
        }
        println!("# sim/tcp parity: max |delta| = {max_delta:.3e}, bytes match = {bytes_match}");
        anyhow::ensure!(max_delta <= 1e-6, "sim/tcp divergence {max_delta:.3e} exceeds 1e-6");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    println!("model presets:");
    for p in bluefog::config::PRESETS {
        println!(
            "  {:8} d_model={:4} layers={} seq={:4} batch={:2} params={}",
            p.name,
            p.d_model,
            p.n_layers,
            p.seq,
            p.batch,
            p.param_count()
        );
    }
    println!("workload cost models (Fig. 12 / Table II):");
    for w in bluefog::config::WorkloadModel::all() {
        println!("  {:12} params={:>11} batch={}", w.name, w.params, w.batch);
    }
    println!("artifacts in {dir}:");
    match std::fs::read_dir(dir) {
        Ok(entries) => {
            let mut names: Vec<String> = entries
                .filter_map(|e| e.ok())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| n.ends_with(".hlo.txt"))
                .collect();
            names.sort();
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("  (unavailable: {e})"),
    }
    // Sanity demo of the tensor module so `info` exercises the library.
    let _ = norm2(&[3.0, 4.0]);
    Ok(())
}
