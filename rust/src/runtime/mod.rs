//! PJRT runtime — executes AOT-compiled JAX/Pallas artifacts (L2/L1) from
//! the Rust coordinator (L3).
//!
//! Artifacts are HLO **text** files produced by `python/compile/aot.py`
//! (text, not serialized proto: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids). Each
//! artifact ships with a `.manifest` describing its inputs/outputs so the
//! coordinator can marshal flat `f32`/`i32` buffers.
//!
//! The `xla` crate's PJRT client is `Rc`-based (not `Send`), so a single
//! **device-service thread** owns the client and all compiled executables;
//! node threads submit [`ExecuteRequest`]s over a channel. This mirrors
//! BlueFog's own split between the Python compute thread and the C++
//! background thread — and on the 1-core simulation host, serializing XLA
//! execution costs nothing.

pub mod manifest;

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

pub use manifest::{DType, Manifest, TensorSpec};

/// A flat input buffer with shape/dtype, marshalled to an `xla::Literal`.
#[derive(Debug, Clone)]
pub enum InputBuf {
    /// `f32` data with its logical dimensions (empty dims = scalar).
    F32(Vec<f32>, Vec<usize>),
    /// `i32` data with its logical dimensions (empty dims = scalar).
    I32(Vec<i32>, Vec<usize>),
}

impl InputBuf {
    fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let lit = match self {
            InputBuf::F32(data, dims) => {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(&dims_i64)?
                }
            }
            InputBuf::I32(data, dims) => {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                if dims.is_empty() {
                    xla::Literal::scalar(data[0])
                } else {
                    xla::Literal::vec1(data).reshape(&dims_i64)?
                }
            }
        };
        Ok(lit)
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            InputBuf::F32(d, _) => d.len(),
            InputBuf::I32(d, _) => d.len(),
        }
    }

    /// True when the buffer holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

enum ServiceMsg {
    Load { name: String, hlo_path: String, reply: Sender<anyhow::Result<()>> },
    Execute { name: String, inputs: Vec<InputBuf>, reply: Sender<anyhow::Result<Vec<Vec<f32>>>> },
    Shutdown,
}

/// Cloneable handle to the device service, held by node contexts.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: Sender<ServiceMsg>,
}

impl DeviceHandle {
    /// Compile an HLO-text artifact under `name`. Idempotent per name.
    pub fn load(&self, name: &str, hlo_path: &str) -> anyhow::Result<()> {
        let (tx, rx) = channel();
        self.tx
            .send(ServiceMsg::Load { name: name.into(), hlo_path: hlo_path.into(), reply: tx })
            .map_err(|_| anyhow::anyhow!("device service down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("device service dropped request"))?
    }

    /// Execute a loaded artifact; returns each output flattened to `f32`.
    pub fn execute(&self, name: &str, inputs: Vec<InputBuf>) -> anyhow::Result<Vec<Vec<f32>>> {
        let (tx, rx) = channel();
        self.tx
            .send(ServiceMsg::Execute { name: name.into(), inputs, reply: tx })
            .map_err(|_| anyhow::anyhow!("device service down"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("device service dropped request"))?
    }
}

/// The device-service thread owning the PJRT client.
pub struct DeviceService {
    tx: Sender<ServiceMsg>,
    handle: Option<JoinHandle<()>>,
}

impl Default for DeviceService {
    fn default() -> Self {
        Self::new()
    }
}

impl DeviceService {
    /// Spawn the service with a CPU PJRT client.
    pub fn new() -> Self {
        let (tx, rx) = channel();
        let handle = std::thread::Builder::new()
            .name("bf-device".into())
            .spawn(move || service_loop(rx))
            .expect("spawn device service");
        DeviceService { tx, handle: Some(handle) }
    }

    /// A cloneable client handle for node threads.
    pub fn handle(&self) -> DeviceHandle {
        DeviceHandle { tx: self.tx.clone() }
    }
}

impl Drop for DeviceService {
    fn drop(&mut self) {
        let _ = self.tx.send(ServiceMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn service_loop(rx: Receiver<ServiceMsg>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Drain requests with the construction error.
            while let Ok(msg) = rx.recv() {
                match msg {
                    ServiceMsg::Load { reply, .. } => {
                        let _ = reply.send(Err(anyhow::anyhow!("PJRT client failed: {e}")));
                    }
                    ServiceMsg::Execute { reply, .. } => {
                        let _ = reply.send(Err(anyhow::anyhow!("PJRT client failed: {e}")));
                    }
                    ServiceMsg::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut executables: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            ServiceMsg::Shutdown => break,
            ServiceMsg::Load { name, hlo_path, reply } => {
                let result = (|| -> anyhow::Result<()> {
                    if executables.contains_key(&name) {
                        return Ok(());
                    }
                    let proto = xla::HloModuleProto::from_text_file(&hlo_path)
                        .map_err(|e| anyhow::anyhow!("parse {hlo_path}: {e}"))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client
                        .compile(&comp)
                        .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
                    executables.insert(name, exe);
                    Ok(())
                })();
                let _ = reply.send(result);
            }
            ServiceMsg::Execute { name, inputs, reply } => {
                let result = execute_one(&executables, &name, &inputs);
                let _ = reply.send(result);
            }
        }
    }
}

fn execute_one(
    executables: &HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
    inputs: &[InputBuf],
) -> anyhow::Result<Vec<Vec<f32>>> {
    let exe = executables
        .get(name)
        .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not loaded"))?;
    let literals: Vec<xla::Literal> =
        inputs.iter().map(|b| b.to_literal()).collect::<anyhow::Result<_>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetch result of {name}: {e}"))?;
    // aot.py lowers with return_tuple=True: unpack the tuple of outputs.
    let parts = lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple {name}: {e}"))?;
    let mut outs = Vec::with_capacity(parts.len());
    for p in parts {
        // Convert any output dtype to f32 on the way out.
        let p32 = p
            .convert(xla::PrimitiveType::F32)
            .map_err(|e| anyhow::anyhow!("convert output of {name}: {e}"))?;
        outs.push(p32.to_vec::<f32>().map_err(|e| anyhow::anyhow!("read output: {e}"))?);
    }
    Ok(outs)
}
