//! Artifact manifests — the shape/dtype contract between `aot.py` and the
//! Rust runtime.
//!
//! A manifest is a plain text file next to each `.hlo.txt` artifact:
//!
//! ```text
//! # comment
//! meta key value
//! input  <name> <f32|i32> <d0>x<d1>x...   (scalar: "-")
//! output <name> <f32|i32> <dims>
//! ```
//!
//! Lines appear in the artifact's positional input/output order. `meta`
//! lines carry free-form key/value pairs (e.g. parameter counts, flops).

use std::collections::HashMap;

/// Element types crossing the boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => anyhow::bail!("unsupported dtype '{other}'"),
        }
    }
}

/// One input or output tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Tensor name in the artifact manifest.
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Shape (row-major).
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Input tensor specs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs, in result order.
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata key/value pairs.
    pub meta: HashMap<String, String>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            match fields[0] {
                "meta" => {
                    anyhow::ensure!(fields.len() >= 3, "line {}: malformed meta", lineno + 1);
                    m.meta.insert(fields[1].to_string(), fields[2..].join(" "));
                }
                kind @ ("input" | "output") => {
                    anyhow::ensure!(
                        fields.len() == 4,
                        "line {}: expected '{kind} <name> <dtype> <dims>'",
                        lineno + 1
                    );
                    let spec = TensorSpec {
                        name: fields[1].to_string(),
                        dtype: DType::parse(fields[2])?,
                        dims: parse_dims(fields[3])
                            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?,
                    };
                    if kind == "input" {
                        m.inputs.push(spec);
                    } else {
                        m.outputs.push(spec);
                    }
                }
                other => anyhow::bail!("line {}: unknown directive '{other}'", lineno + 1),
            }
        }
        Ok(m)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read manifest {path}: {e}"))?;
        Manifest::parse(&text)
    }

    /// Positional index of the input named `name`.
    pub fn input_index(&self, name: &str) -> anyhow::Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow::anyhow!("input '{name}' not in manifest"))
    }

    /// Positional index of the output named `name`.
    pub fn output_index(&self, name: &str) -> anyhow::Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow::anyhow!("output '{name}' not in manifest"))
    }

    /// Inputs whose names start with `prefix` (e.g. the parameter tensors
    /// of a train step), in positional order.
    pub fn inputs_with_prefix(&self, prefix: &str) -> Vec<(usize, &TensorSpec)> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.name.starts_with(prefix))
            .collect()
    }

    /// Integer metadata accessor.
    pub fn meta_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.meta
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("meta '{key}' missing"))?
            .parse()
            .map_err(|e| anyhow::anyhow!("meta '{key}' not an integer: {e}"))
    }
}

fn parse_dims(s: &str) -> anyhow::Result<Vec<usize>> {
    if s == "-" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<usize>().map_err(|e| anyhow::anyhow!("bad dim '{d}': {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# train step artifact
meta param_count 123456
meta flops_per_step 7.5e9
input  tokens i32 8x128
input  targets i32 8x128
input  p.embed f32 256x64
input  lr f32 -
output loss f32 -
output g.embed f32 256x64
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.inputs.len(), 4);
        assert_eq!(m.outputs.len(), 2);
        assert_eq!(m.inputs[0].dtype, DType::I32);
        assert_eq!(m.inputs[0].dims, vec![8, 128]);
        assert_eq!(m.inputs[3].dims, Vec::<usize>::new());
        assert_eq!(m.meta["param_count"], "123456");
        assert_eq!(m.meta_usize("param_count").unwrap(), 123456);
    }

    #[test]
    fn indices_and_prefix_queries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.input_index("lr").unwrap(), 3);
        assert_eq!(m.output_index("loss").unwrap(), 0);
        let params = m.inputs_with_prefix("p.");
        assert_eq!(params.len(), 1);
        assert_eq!(params[0].0, 2);
        assert_eq!(params[0].1.numel(), 256 * 64);
    }

    #[test]
    fn scalar_dims() {
        assert_eq!(parse_dims("-").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_dims("3").unwrap(), vec![3]);
        assert_eq!(parse_dims("2x3x4").unwrap(), vec![2, 3, 4]);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Manifest::parse("input x f32").is_err());
        assert!(Manifest::parse("frobnicate y").is_err());
        assert!(Manifest::parse("input x f64 3").is_err());
        assert!(Manifest::parse("input x f32 3xq").is_err());
    }

    #[test]
    fn missing_lookups_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.input_index("nope").is_err());
        assert!(m.meta_usize("nope").is_err());
        assert!(m.meta_usize("flops_per_step").is_err(), "float meta is not usize");
    }
}
