//! Asynchronous decentralized optimizers (paper §IV-C, Listing 3; Lian et
//! al. 2017; Assran et al. 2019).
//!
//! These optimizers communicate exclusively through one-sided window
//! operations ([`crate::window`]) — no barriers, no matched send/recv — so
//! each rank steps at its own virtual-time rate and a straggler slows
//! nobody but itself:
//!
//! - [`AsyncPushSumSgd`] carries the extended vector `[u; v]` (parameter
//!   *mass* plus the push-sum scalar) and splits it column-stochastically
//!   over the out-neighbors with `win_accumulate`, draining arrived mass
//!   with the causal `win_update_then_collect_causal`. The iterate exposed
//!   to the caller is the de-biased `x = u / v`: because the weights
//!   conserve mass exactly (`Σ_i (u_i + pending)` is invariant), the
//!   network average of `x` is unbiased no matter how asymmetric the
//!   communication pattern gets — the property naive asynchronous gossip
//!   loses.
//! - [`AsyncGossipSgd`] is AD-PSGD-flavored pairwise gossip: a convex
//!   *causal* `win_update` average of the local tensor with the (possibly
//!   stale) neighbor slots — puts still virtually in flight keep their
//!   weight on the local tensor — one local SGD step, then a `win_put` of
//!   the parameters to a uniformly random out-neighbor. Cheaper per step
//!   and convex-hull contractive, but only approximately mean-preserving.
//!
//! The per-iteration contract is **receive-then-adapt** (paper Listing 3's
//! order), split across two calls so the gradient is evaluated on the
//! freshest available information: [`AsyncDecentralizedOptimizer::refresh`]
//! folds arrived neighbor mass into the iterate *before* the caller
//! computes its gradient, and [`AsyncDecentralizedOptimizer::step`]
//! applies the gradient and sends. Draining after the gradient instead
//! (adapt-then-receive) makes every gradient one compute-window staler —
//! numerically that costs ~1.5x more iterations to a target loss on the
//! linear-regression probe, eating most of the asynchrony win.
//!
//! Both optimizers are meant to run under a bounded-staleness regime
//! ([`crate::launcher::AsyncSpec`] horizon +
//! [`crate::context::NodeContext::async_throttle`]) and a **virtual-time
//! budget** (loop `while ctx.vtime() < t_end`, not a fixed step count):
//! with a fixed per-rank step count the fast ranks finish early and a
//! straggler keeps splitting its mass into windows nobody drains, driving
//! its push-sum weight to floating-point zero — the same unbounded-
//! asynchrony failure mode `examples/async_push_sum.rs` documents.

use crate::context::NodeContext;

/// The asynchronous optimization contract: refresh (receive) → caller
/// computes the gradient → step (adapt + send), plus an explicit teardown.
/// Unlike [`crate::optim::DecentralizedOptimizer`], implementations own a
/// window and therefore a teardown protocol: `finalize` marks the rank
/// done (so peers' throttles stop waiting on it), synchronizes, performs
/// the final blocking drain that collects all still-pending mass, and
/// frees the window.
pub trait AsyncDecentralizedOptimizer: Send {
    /// Fold whatever neighbor information has (virtually) arrived into the
    /// iterate, in place. Call once per iteration, after charging the
    /// step's compute time and *before* computing the gradient. Lazily
    /// performs the collective window creation on the first call — the
    /// regime's only startup synchronization.
    fn refresh(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>) -> anyhow::Result<()>;

    /// Apply one gradient to the (refreshed) iterate and send this rank's
    /// share to its neighbors. Never blocks on a peer.
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32])
        -> anyhow::Result<()>;

    /// Leave the asynchronous regime: mark done, barrier, drain pending
    /// mass into `x`, free the window. Collective — every rank must call it
    /// exactly once after its last `step`.
    fn finalize(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>) -> anyhow::Result<()>;

    /// Display name.
    fn name(&self) -> String;

    /// Window staleness observed at the most recent `refresh` (virtual
    /// seconds between now and the oldest last-write among the slots).
    fn staleness(&self) -> f64 {
        0.0
    }
}

/// Asynchronous push-sum SGD: mass-conserving one-sided gossip with the
/// push-sum scalar correcting the bias (paper §IV-C / Listing 3, with an
/// SGD term — stochastic gradient push).
///
/// Per iteration, with `d = dim(x)`, out-degree `m` and
/// `share = 1/(m+1)`:
///
/// 1. `refresh`: `win_update_then_collect_causal` folds arrived mass into
///    `[u; v]` and exposes `x = u / v`;
/// 2. the caller computes `g(x)` at the refreshed iterate;
/// 3. `step`: `u ← u − γ · v · g` (the gradient scales by `v` so `x`
///    moves by exactly `−γ g`), then `win_accumulate([u; v], share, dsts)`
///    keeps `share` and pushes `share` to each out-neighbor
///    (column-stochastic, so mass is conserved; the split leaves `u/v`
///    unchanged).
pub struct AsyncPushSumSgd {
    /// Step size `γ`.
    pub gamma: f32,
    window: String,
    u: Vec<f32>,
    v: f32,
    /// Persistent `[u; v]` scratch — the per-step wire image, reused so the
    /// regime's hot loop allocates nothing (the repo's pooled hot-path
    /// discipline).
    ext: Vec<f32>,
    /// Column-stochastic destination weights, cached at window creation
    /// (the window topology is fixed then anyway).
    dsts: Vec<(usize, f64)>,
    share: f64,
    created: bool,
    last_staleness: f64,
    /// Completed gradient steps (diagnostics).
    pub steps: u64,
}

impl AsyncPushSumSgd {
    /// New asynchronous push-sum SGD communicating through the window
    /// `window` (every rank must use the same name).
    pub fn new(gamma: f32, window: &str) -> Self {
        AsyncPushSumSgd {
            gamma,
            window: window.to_string(),
            u: Vec::new(),
            v: 1.0,
            ext: Vec::new(),
            dsts: Vec::new(),
            share: 1.0,
            created: false,
            last_staleness: 0.0,
            steps: 0,
        }
    }

    /// Current push-sum weight `v` (tests assert `Σ_i v_i = n` at rest).
    pub fn push_weight(&self) -> f32 {
        self.v
    }

    fn fill_ext(&mut self) {
        self.ext.clear();
        self.ext.extend_from_slice(&self.u);
        self.ext.push(self.v);
    }

    fn take_ext(&mut self, d: usize) {
        self.u.copy_from_slice(&self.ext[..d]);
        self.v = self.ext[d];
    }

    fn debias_into(&self, x: &mut [f32]) {
        for (xi, ui) in x.iter_mut().zip(&self.u) {
            *xi = ui / self.v;
        }
    }

    /// Self-healing redirect: drop destinations the health view (crash
    /// oracle or miss counter) has evicted and recompute the
    /// column-stochastic split over the survivors, so the crashed peer's
    /// outbound share is redirected and `Σ (v_i + pending)` over the
    /// survivors stays conserved from this step on. Mass already in the
    /// dead rank's window is gone — but because every wire message
    /// carries `[u; v]` *jointly*, the debiased iterate `x = u/v` of
    /// every survivor stays unbiased (u and v lose the same fraction).
    fn heal_dsts(&mut self, ctx: &mut NodeContext) {
        if !ctx.faults().active() {
            return;
        }
        for i in 0..self.dsts.len() {
            let r = self.dsts[i].0;
            if !ctx.health.is_evicted(r) && ctx.peer_down(r) {
                ctx.health.evict(r);
            }
        }
        if self.dsts.iter().any(|&(r, _)| ctx.health.is_evicted(r)) {
            self.dsts.retain(|&(r, _)| !ctx.health.is_evicted(r));
            self.share = 1.0 / (self.dsts.len() + 1) as f64;
            for d in &mut self.dsts {
                d.1 = self.share;
            }
        }
    }
}

impl AsyncDecentralizedOptimizer for AsyncPushSumSgd {
    fn refresh(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>) -> anyhow::Result<()> {
        let d = x.len();
        if !self.created {
            // First call: seed the mass from the caller's iterate and cache
            // the column-stochastic split (the window topology is fixed at
            // creation). The win_create barrier is the regime's only
            // startup synchronization (all ranks are still at iteration 0).
            self.u = x.clone();
            self.v = 1.0;
            let out = ctx.out_neighbor_ranks();
            self.share = 1.0 / (out.len() + 1) as f64;
            self.dsts = out.iter().map(|&r| (r, self.share)).collect();
            self.fill_ext();
            // Re-arm the regime membership: a second async phase in the
            // same program must be throttled like the first.
            ctx.mark_async_active();
            ctx.win_create(&self.window, &self.ext, /*zero_init=*/ true)?;
            self.created = true;
        }
        anyhow::ensure!(self.u.len() == d, "parameter size changed mid-run");
        self.heal_dsts(ctx);
        self.last_staleness = ctx.win_staleness(&self.window)?;
        self.fill_ext();
        ctx.win_update_then_collect_causal(&self.window, &mut self.ext)?;
        self.take_ext(d);
        anyhow::ensure!(
            self.v > 1e-12,
            "push-sum weight collapsed at step {} (unbounded asynchrony? configure an \
             AsyncSpec horizon and loop on a virtual-time budget)",
            self.steps
        );
        self.debias_into(x);
        Ok(())
    }

    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        let d = x.len();
        anyhow::ensure!(grad.len() == d, "gradient/parameter size mismatch");
        anyhow::ensure!(self.created && self.u.len() == d, "step before refresh");

        for (ui, g) in self.u.iter_mut().zip(grad) {
            *ui -= self.gamma * self.v * g;
        }

        self.fill_ext();
        ctx.win_accumulate(&self.window, &mut self.ext, self.share, &self.dsts)?;
        self.take_ext(d);
        // The split scales u and v alike, so u/v only moved by -γ g.
        self.debias_into(x);
        self.steps += 1;
        Ok(())
    }

    fn finalize(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>) -> anyhow::Result<()> {
        if !self.created {
            return Ok(());
        }
        ctx.mark_async_done();
        // After the barrier no rank issues further accumulates, so the
        // blocking drain below observes every write ever made. Under an
        // active fault plan the barrier is best-effort: a crashed peer
        // makes it expire at the receive deadline, which still bounds how
        // early any survivor can pass — loose synchronization is enough
        // for the teardown drain.
        if ctx.faults().active() {
            if let Err(e) = ctx.barrier() {
                if ctx.crashed_now() {
                    return Err(e);
                }
            }
        } else {
            ctx.barrier()?;
        }
        let d = x.len();
        self.fill_ext();
        ctx.win_update_then_collect(&self.window, &mut self.ext)?;
        self.take_ext(d);
        anyhow::ensure!(self.v > 1e-12, "push-sum weight collapsed during teardown");
        self.debias_into(x);
        ctx.win_free(&self.window)?;
        self.created = false;
        Ok(())
    }

    fn name(&self) -> String {
        "AsyncPushSumSGD(window)".into()
    }

    fn staleness(&self) -> f64 {
        self.last_staleness
    }
}

/// AD-PSGD-style asynchronous gossip SGD: `refresh` is a convex *causal*
/// `win_update` average of the local tensor with the (stale) neighbor
/// slots (in-flight puts keep their weight on the local tensor); `step`
/// is a local SGD step followed by a `win_put` of the parameters to one
/// uniformly random out-neighbor. Every combine is a convex combination,
/// so iterates stay inside the convex hull of the initial points plus the
/// gradient displacements; unlike push-sum the network mean is only
/// approximately preserved, which is the standard AD-PSGD trade-off
/// (cheaper steps, small asymptotic bias).
pub struct AsyncGossipSgd {
    /// Step size `γ`.
    pub gamma: f32,
    window: String,
    /// Out-neighbor ranks, cached at window creation (the topology is
    /// fixed then) so the hot loop allocates nothing.
    outs: Vec<usize>,
    /// Uniform source weights over in-neighbors, cached likewise.
    srcs: Vec<(usize, f64)>,
    self_w: f64,
    created: bool,
    last_staleness: f64,
    /// Completed gradient steps (diagnostics).
    pub steps: u64,
}

impl AsyncGossipSgd {
    /// New asynchronous pairwise-gossip SGD on the window `window`.
    pub fn new(gamma: f32, window: &str) -> Self {
        AsyncGossipSgd {
            gamma,
            window: window.to_string(),
            outs: Vec::new(),
            srcs: Vec::new(),
            self_w: 1.0,
            created: false,
            last_staleness: 0.0,
            steps: 0,
        }
    }
}

impl AsyncDecentralizedOptimizer for AsyncGossipSgd {
    fn refresh(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>) -> anyhow::Result<()> {
        if !self.created {
            // zero_init = false: slots start at the owner's initial tensor,
            // so the very first averages are exact under a common init.
            // Neighbor lists and weights are cached here — the window
            // topology is fixed at creation. Re-arm the regime membership
            // so a second async phase is throttled like the first.
            ctx.mark_async_active();
            ctx.win_create(&self.window, x, /*zero_init=*/ false)?;
            self.outs = ctx.out_neighbor_ranks();
            let ins = ctx.in_neighbor_ranks();
            self.self_w = 1.0 / (ins.len() + 1) as f64;
            self.srcs = ins.iter().map(|&r| (r, self.self_w)).collect();
            self.created = true;
        }
        self.last_staleness = ctx.win_staleness(&self.window)?;
        // Causal variant: a slot whose latest put is still virtually in
        // flight keeps its weight on the local tensor (the combination
        // stays convex) and never drags this rank's clock forward.
        let averaged = ctx.win_update_causal(&self.window, x, self.self_w, &self.srcs)?;
        ctx.recycle(std::mem::replace(x, averaged));
        Ok(())
    }

    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(grad.len() == x.len(), "gradient/parameter size mismatch");
        anyhow::ensure!(self.created, "step before refresh");

        for (xi, g) in x.iter_mut().zip(grad) {
            *xi -= self.gamma * g;
        }

        if !self.outs.is_empty() {
            let peer = self.outs[ctx.rng.usize_below(self.outs.len())];
            ctx.win_put(&self.window, x, &[(peer, 1.0)])?;
        }
        self.steps += 1;
        Ok(())
    }

    fn finalize(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>) -> anyhow::Result<()> {
        if !self.created {
            return Ok(());
        }
        ctx.mark_async_done();
        ctx.barrier()?;
        // One last synchronized (blocking) average so stragglers fold in
        // their peers' final parameters before the window disappears.
        let averaged = ctx.win_update(&self.window, x, self.self_w, &self.srcs)?;
        ctx.recycle(std::mem::replace(x, averaged));
        ctx.barrier()?;
        ctx.win_free(&self.window)?;
        self.created = false;
        Ok(())
    }

    fn name(&self) -> String {
        "AsyncGossipSGD(window)".into()
    }

    fn staleness(&self) -> f64 {
        self.last_staleness
    }
}
