//! Frozen pre-refactor optimizer implementations — parity oracles only.
//!
//! These are *verbatim* copies of the algorithm bodies as they existed
//! before the pipeline refactor (`AlgoStep` / [`super::ScheduledOptimizer`]).
//! `tests/optimizers.rs` runs each re-expressed optimizer side by side with
//! its `Ref*` twin and asserts the parameter traces match **bitwise**
//! (`f32::to_bits`), which is the acceptance gate for the refactor.
//!
//! Do not "fix" or modernize anything here: the whole point is that this
//! module does not evolve with the pipeline. It is not part of the public
//! algorithm surface and should never be used outside parity tests.

use std::sync::Arc;

use crate::collective::neighbor::NeighborWeights;
use crate::collective::{AllreduceAlgo, ReduceOp};
use crate::context::NodeContext;
use crate::tensor::axpy;
use crate::topology::dynamic::DynamicTopology;

use super::{CommSpec, DecentralizedOptimizer, MomentumKind, StepOrder};

/// Frozen pre-refactor [`super::Dgd`].
pub struct RefDgd {
    /// Step size `γ`.
    pub gamma: f32,
    /// Communication/adaptation order (ATC vs AWC).
    pub order: StepOrder,
    /// Communication pattern used by the combine step.
    pub comm: CommSpec,
    iter: usize,
}

impl RefDgd {
    /// New frozen DGD oracle with step size `gamma`.
    pub fn new(gamma: f32, order: StepOrder, comm: CommSpec) -> Self {
        RefDgd { gamma, order, comm, iter: 0 }
    }
}

impl DecentralizedOptimizer for RefDgd {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        match self.order {
            StepOrder::Atc => {
                // Pooled scratch for the half-step; the replaced parameter
                // buffer goes back to the pool for the next round.
                let mut half = ctx.scratch_copy(x);
                axpy(-self.gamma, grad, &mut half);
                let combined = self.comm.combine(ctx, self.iter, &half)?;
                ctx.recycle(std::mem::replace(x, combined));
            }
            StepOrder::Awc => {
                let combined = self.comm.combine(ctx, self.iter, x)?;
                ctx.recycle(std::mem::replace(x, combined));
                axpy(-self.gamma, grad, x);
            }
        }
        self.iter += 1;
        Ok(())
    }

    fn name(&self) -> String {
        format!("RefDGD-{:?}({})", self.order, self.comm.label())
    }
}

/// Frozen pre-refactor [`super::ExactDiffusion`].
pub struct RefExactDiffusion {
    /// Step size `γ`.
    pub gamma: f32,
    /// Communication pattern used by the combine step.
    pub comm: CommSpec,
    prev_psi: Option<Vec<f32>>,
    iter: usize,
}

impl RefExactDiffusion {
    /// New frozen Exact-Diffusion oracle with step size `gamma`.
    pub fn new(gamma: f32, comm: CommSpec) -> Self {
        RefExactDiffusion { gamma, comm, prev_psi: None, iter: 0 }
    }
}

impl DecentralizedOptimizer for RefExactDiffusion {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        let mut psi = ctx.vec_from(x);
        axpy(-self.gamma, grad, &mut psi);
        let mut phi = ctx.scratch_copy(&psi);
        match &self.prev_psi {
            None => {}
            Some(prev) => {
                for ((f, (p, xi)), pp) in
                    phi.iter_mut().zip(psi.iter().zip(x.iter())).zip(prev.iter())
                {
                    *f = p + xi - pp;
                }
            }
        }
        let combined = self.comm.combine(ctx, self.iter, &phi)?;
        ctx.recycle(std::mem::replace(x, combined));
        if let Some(old) = self.prev_psi.replace(psi) {
            ctx.recycle(old);
        }
        self.iter += 1;
        Ok(())
    }

    fn name(&self) -> String {
        format!("RefExactDiffusion({})", self.comm.label())
    }
}

/// Frozen pre-refactor [`super::GradientTracking`].
pub struct RefGradientTracking {
    /// Step size `γ`.
    pub gamma: f32,
    /// Communication pattern used by the combine step.
    pub comm: CommSpec,
    y: Option<Vec<f32>>,
    prev_grad: Option<Vec<f32>>,
    iter: usize,
}

impl RefGradientTracking {
    /// New frozen gradient-tracking oracle with step size `gamma`.
    pub fn new(gamma: f32, comm: CommSpec) -> Self {
        RefGradientTracking { gamma, comm, y: None, prev_grad: None, iter: 0 }
    }

    /// The tracked global-gradient estimate.
    pub fn tracker(&self) -> Option<&Vec<f32>> {
        self.y.as_ref()
    }
}

impl DecentralizedOptimizer for RefGradientTracking {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        let y = match (&mut self.y, &self.prev_grad) {
            (None, _) => grad.to_vec(),
            (Some(y), Some(pg)) => {
                let mut q = ctx.scratch_copy(y);
                for ((qi, g), p) in q.iter_mut().zip(grad).zip(pg.iter()) {
                    *qi += g - p;
                }
                // Stream 1: the tracker exchange must not share compression
                // state with the same-length parameter exchange below.
                self.comm.combine_stream(ctx, self.iter, &q, 1)?
            }
            (Some(_), None) => unreachable!("prev_grad set with y"),
        };
        let mut half = ctx.scratch_copy(x);
        axpy(-self.gamma, &y, &mut half);
        let combined = self.comm.combine(ctx, self.iter, &half)?;
        ctx.recycle(std::mem::replace(x, combined));
        if let Some(old) = self.y.replace(y) {
            ctx.recycle(old);
        }
        let grad_copy = ctx.vec_from(grad);
        if let Some(old) = self.prev_grad.replace(grad_copy) {
            ctx.recycle(old);
        }
        self.iter += 1;
        Ok(())
    }

    fn name(&self) -> String {
        format!("RefGradientTracking({})", self.comm.label())
    }
}

/// Frozen pre-refactor [`super::PushSumGradientTracking`].
pub struct RefPushSumGradientTracking {
    /// Step size `γ`.
    pub gamma: f32,
    /// Per-iteration directed topology schedule.
    pub topo: Arc<dyn DynamicTopology>,
    u: Option<Vec<f32>>,
    v: f32,
    y: Option<Vec<f32>>,
    prev_grad: Option<Vec<f32>>,
    iter: usize,
}

impl RefPushSumGradientTracking {
    /// New frozen push-sum gradient-tracking oracle over `topo`.
    pub fn new(gamma: f32, topo: Arc<dyn DynamicTopology>) -> Self {
        RefPushSumGradientTracking {
            gamma,
            topo,
            u: None,
            v: 1.0,
            y: None,
            prev_grad: None,
            iter: 0,
        }
    }

    /// Push-style combine: senders scale by the column-stochastic weights.
    fn push_combine(
        &self,
        ctx: &mut NodeContext,
        iter: usize,
        data: &[f32],
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        let view = self.topo.view(iter, ctx.rank());
        // Column-stochastic: self keeps self_weight, sends s_ij to dsts;
        // receivers apply r = 1.
        let w = NeighborWeights::push_pull(
            view.self_weight,
            view.src_weights.iter().map(|&(s, _)| (s, 1.0)).collect(),
            view.dst_weights.clone(),
        );
        ctx.neighbor_allreduce_dynamic_stream(data, &w, stream)
    }
}

impl DecentralizedOptimizer for RefPushSumGradientTracking {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        // Initialize u from the current x, y from the first gradient.
        if self.u.is_none() {
            self.u = Some(x.clone());
            self.y = Some(grad.to_vec());
            self.prev_grad = Some(grad.to_vec());
        } else {
            // y_{k+1} = W^k (y_k + g_{k+1} - g_k); built in pooled scratch
            // so `self.y` stays intact if the combine errors.
            let mut q = ctx.scratch_copy(self.y.as_ref().unwrap());
            let pg = self.prev_grad.as_ref().unwrap();
            for ((qi, g), p) in q.iter_mut().zip(grad).zip(pg.iter()) {
                *qi += g - p;
            }
            let new_y = self.push_combine(ctx, self.iter, &q, 1)?;
            if let Some(old) = self.y.replace(new_y) {
                ctx.recycle(old);
            }
            let grad_copy = ctx.vec_from(grad);
            if let Some(old) = self.prev_grad.replace(grad_copy) {
                ctx.recycle(old);
            }
        }
        // u_{k+1} = W^k (u_k - γ y_k)
        let mut w = ctx.scratch_copy(self.u.as_ref().unwrap());
        axpy(-self.gamma, self.y.as_ref().unwrap(), &mut w);
        let u_new = self.push_combine(ctx, self.iter, &w, 0)?;
        // v_{k+1} = W^k v_k  (scalar push-sum weight)
        let v_new = self.push_combine(ctx, self.iter, &[self.v], 2)?[0];
        // x_{k+1} = u_{k+1} / v_{k+1}
        if let Some(old) = self.u.replace(u_new) {
            ctx.recycle(old);
        }
        self.v = v_new;
        let u = self.u.as_ref().unwrap();
        x.clear();
        x.extend(u.iter().map(|ui| ui / self.v));
        self.iter += 1;
        Ok(())
    }

    fn name(&self) -> String {
        "RefPushSumGradientTracking(dynamic)".into()
    }
}

/// Frozen pre-refactor [`super::DmSgd`].
pub struct RefDmSgd {
    /// Step size `γ`.
    pub gamma: f32,
    /// Momentum coefficient `β`.
    pub beta: f32,
    /// Which momentum variant to run (Table III rows).
    pub kind: MomentumKind,
    /// Communication/adaptation order (ATC vs AWC).
    pub order: StepOrder,
    /// Communication pattern used by the combine step.
    pub comm: CommSpec,
    m: Option<Vec<f32>>,
    iter: usize,
}

impl RefDmSgd {
    /// New frozen decentralized momentum-SGD oracle.
    pub fn new(gamma: f32, beta: f32, kind: MomentumKind, order: StepOrder, comm: CommSpec) -> Self {
        RefDmSgd { gamma, beta, kind, order, comm, m: None, iter: 0 }
    }
}

impl DecentralizedOptimizer for RefDmSgd {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        let d = x.len();
        if self.m.is_none() {
            self.m = Some(vec![0.0; d]);
        }
        match self.kind {
            MomentumKind::Vanilla | MomentumKind::Synced => {
                {
                    let m = self.m.as_mut().unwrap();
                    for (mi, g) in m.iter_mut().zip(grad) {
                        *mi = self.beta * *mi + g;
                    }
                }
                match self.order {
                    StepOrder::Atc => {
                        let mut half = ctx.scratch_copy(x);
                        axpy(-self.gamma, self.m.as_ref().unwrap(), &mut half);
                        let combined = self.comm.combine(ctx, self.iter, &half)?;
                        ctx.recycle(std::mem::replace(x, combined));
                    }
                    StepOrder::Awc => {
                        let combined = self.comm.combine(ctx, self.iter, x)?;
                        ctx.recycle(std::mem::replace(x, combined));
                        axpy(-self.gamma, self.m.as_ref().unwrap(), x);
                    }
                }
                if self.kind == MomentumKind::Synced {
                    // Stream 1: keep the momentum exchange's compression
                    // state apart from the parameter exchange's.
                    let synced =
                        self.comm.combine_stream(ctx, self.iter, self.m.as_ref().unwrap(), 1)?;
                    if let Some(old) = self.m.replace(synced) {
                        ctx.recycle(old);
                    }
                }
            }
            MomentumKind::QuasiGlobal => {
                // [67]: d_k = g_k + beta * m_k ; x half-step, combine, then
                // m_{k+1} = beta * m_k + (1 - beta) * (x_k - x_{k+1}) / gamma.
                let mut half = ctx.scratch_copy(x);
                {
                    let m = self.m.as_ref().unwrap();
                    for ((h, g), mi) in half.iter_mut().zip(grad).zip(m.iter()) {
                        *h -= self.gamma * (g + self.beta * mi);
                    }
                }
                let combined = self.comm.combine(ctx, self.iter, &half)?;
                let x_prev = std::mem::replace(x, combined);
                let m = self.m.as_mut().unwrap();
                for ((mi, xp), xn) in m.iter_mut().zip(&x_prev).zip(x.iter()) {
                    *mi = self.beta * *mi + (1.0 - self.beta) * (xp - xn) / self.gamma;
                }
                ctx.recycle(x_prev);
            }
        }
        self.iter += 1;
        Ok(())
    }

    fn name(&self) -> String {
        let kind = match self.kind {
            MomentumKind::Vanilla => "RefDmSGD-vanilla",
            MomentumKind::Synced => "RefDmSGD",
            MomentumKind::QuasiGlobal => "RefQG-DmSGD",
        };
        format!("{kind}({})", self.comm.label())
    }
}

/// Frozen pre-refactor [`super::PeriodicGlobalAveraging`] (the standalone
/// wrapper logic, before it was folded into the schedule layer).
pub struct RefPeriodicGlobalAveraging<O: DecentralizedOptimizer> {
    /// The wrapped decentralized optimizer.
    pub inner: O,
    /// A global allreduce replaces partial averaging every `period` steps.
    pub period: usize,
    /// Allreduce algorithm used for the periodic global average.
    pub algo: AllreduceAlgo,
    iter: usize,
}

impl<O: DecentralizedOptimizer> RefPeriodicGlobalAveraging<O> {
    /// Wrap `inner`, averaging globally every `period` steps.
    pub fn new(inner: O, period: usize, algo: AllreduceAlgo) -> Self {
        assert!(period > 0);
        RefPeriodicGlobalAveraging { inner, period, algo, iter: 0 }
    }
}

impl<O: DecentralizedOptimizer> DecentralizedOptimizer for RefPeriodicGlobalAveraging<O> {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        self.inner.step(ctx, x, grad)?;
        self.iter += 1;
        if self.iter % self.period == 0 {
            *x = ctx.allreduce(x, ReduceOp::Average, self.algo)?;
        }
        Ok(())
    }

    fn name(&self) -> String {
        format!("{}+global/{}", self.inner.name(), self.period)
    }
}
