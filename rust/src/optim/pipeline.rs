//! The composable algorithm pipeline (paper §II's unified abstraction).
//!
//! Every synchronous decentralized algorithm decomposes into three phases
//! ([`AlgoStep`]): a *local* adaptation (no communication), a
//! *communicate* phase issuing neighbor exchanges through a
//! [`CommPipe`], and a post-communication *correction*.
//! [`ScheduledOptimizer`] drives the phases under a
//! [`CommSchedule`] (every step / every `H` steps / periodic global
//! sync) and a [`NeighborWeighting`] policy (static MH rows, survivor
//! rows, AL-DSGD dynamic rows).
//!
//! The six pre-refactor optimizers are re-expressed as [`AlgoStep`]s
//! whose phase bodies replay the frozen implementations' float-operation
//! sequences *exactly* — `tests/optimizers.rs` pins them bitwise against
//! the verbatim copies in [`super::reference`]. On top of the skeleton,
//! [`LocalUpdateSgd`] (DIGEST-style `H` local steps + one gossip) falls
//! out of `DgdStep` + `CommSchedule::local_updates(H)` for free, and
//! composes multiplicatively with communication compression.

use std::sync::Arc;

use crate::collective::neighbor::NeighborWeights;
use crate::collective::AllreduceAlgo;
use crate::context::NodeContext;
use crate::tensor::axpy;
use crate::topology::dynamic::DynamicTopology;

use super::schedule::CommSchedule;
use super::weighting::{CommPipe, NeighborWeighting, WeightingState};
use super::{CommSpec, DecentralizedOptimizer, MomentumKind, StepOrder};

/// One algorithm expressed as local step · neighbor communicate ·
/// correction, driven by a [`ScheduledOptimizer`].
pub trait AlgoStep: Send {
    /// Display name, given the communication spec's label.
    fn label(&self, comm: &CommSpec) -> String;

    /// Whether skipping the communicate/correct phases (an `H > 1`
    /// schedule) leaves a sound algorithm. Only plain gradient-step
    /// algorithms qualify; tracking/correction methods interleave state
    /// exchanges into every step and must gossip each iteration.
    fn supports_local_schedule(&self) -> bool {
        false
    }

    /// Local adaptation — must not communicate.
    fn local(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32])
        -> anyhow::Result<()>;

    /// Communication phase: neighbor exchanges through `pipe`.
    fn communicate(
        &mut self,
        ctx: &mut NodeContext,
        pipe: &mut CommPipe<'_>,
        x: &mut Vec<f32>,
        grad: &[f32],
    ) -> anyhow::Result<()>;

    /// Post-communication correction (momentum rebuilds, bookkeeping).
    fn correct(
        &mut self,
        ctx: &mut NodeContext,
        x: &mut Vec<f32>,
        grad: &[f32],
    ) -> anyhow::Result<()> {
        let _ = (ctx, x, grad);
        Ok(())
    }
}

/// Drives an [`AlgoStep`] under a [`CommSchedule`] and a
/// [`NeighborWeighting`] policy.
pub struct ScheduledOptimizer<A: AlgoStep> {
    algo: A,
    comm: CommSpec,
    schedule: CommSchedule,
    weighting: WeightingState,
    weighting_spec: NeighborWeighting,
    iter: usize,
    rounds: usize,
    local_done: usize,
    last_loss: f32,
}

impl<A: AlgoStep> ScheduledOptimizer<A> {
    /// Drive `algo` over `comm` under `schedule`, with static weighting.
    pub fn new(algo: A, comm: CommSpec, schedule: CommSchedule) -> Self {
        assert!(
            schedule.local_steps() == 1 || algo.supports_local_schedule(),
            "H > 1 local-update schedules require a local-update-capable algorithm"
        );
        ScheduledOptimizer {
            algo,
            comm,
            schedule,
            weighting: WeightingState::new(&NeighborWeighting::Static),
            weighting_spec: NeighborWeighting::Static,
            iter: 0,
            rounds: 0,
            local_done: 0,
            last_loss: 0.0,
        }
    }

    /// Swap the neighbor weighting policy.
    pub fn with_weighting(mut self, w: NeighborWeighting) -> Self {
        self.weighting = WeightingState::new(&w);
        self.weighting_spec = w;
        self
    }

    /// The underlying algorithm state (tracker access etc.).
    pub fn algo(&self) -> &A {
        &self.algo
    }

    /// The communication spec this optimizer gossips over.
    pub fn comm(&self) -> &CommSpec {
        &self.comm
    }

    /// The configured weighting policy.
    pub fn weighting(&self) -> &NeighborWeighting {
        &self.weighting_spec
    }

    /// [`DecentralizedOptimizer::step`] with an explicit activity flag:
    /// `active = false` skips the local adaptation (a straggler that
    /// missed its compute window) while still joining every due gossip
    /// and global-sync round — matched collectives stay matched, and the
    /// AL-DSGD staleness report sees the missed steps.
    pub fn step_with_activity(
        &mut self,
        ctx: &mut NodeContext,
        x: &mut Vec<f32>,
        grad: &[f32],
        active: bool,
    ) -> anyhow::Result<()> {
        if active {
            self.algo.local(ctx, x, grad)?;
            self.local_done += 1;
        }
        if self.schedule.gossip_due(self.iter) {
            let progress =
                (self.local_done as f32 / self.schedule.local_steps() as f32).min(1.0);
            let mut pipe = CommPipe {
                comm: &self.comm,
                weighting: &mut self.weighting,
                iter: self.iter,
                rounds: &mut self.rounds,
                loss: self.last_loss,
                progress,
            };
            self.algo.communicate(ctx, &mut pipe, x, grad)?;
            self.algo.correct(ctx, x, grad)?;
            self.local_done = 0;
        }
        self.iter += 1;
        if let Some(g) = self.schedule.global_mut() {
            if g.after_step(ctx, x)? {
                self.rounds += 1;
            }
        }
        Ok(())
    }
}

impl<A: AlgoStep> DecentralizedOptimizer for ScheduledOptimizer<A> {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        self.step_with_activity(ctx, x, grad, true)
    }

    fn name(&self) -> String {
        self.algo.label(&self.comm)
    }

    fn observe_loss(&mut self, loss: f32) {
        self.last_loss = loss;
    }

    fn comm_rounds(&self) -> usize {
        self.rounds
    }
}

/// D-SGD phase kernel (paper eq. (22)/(23)): ATC adapts locally then
/// combines; AWC combines then adapts in the correction phase.
pub struct DgdStep {
    gamma: f32,
    order: StepOrder,
}

impl DgdStep {
    /// New D-SGD kernel with step size `gamma`.
    pub fn new(gamma: f32, order: StepOrder) -> Self {
        DgdStep { gamma, order }
    }
}

impl AlgoStep for DgdStep {
    fn label(&self, comm: &CommSpec) -> String {
        format!("DGD-{:?}({})", self.order, comm.label())
    }

    fn supports_local_schedule(&self) -> bool {
        // AWC's adaptation runs *after* the combine; skipping the combine
        // would skip the gradient too.
        matches!(self.order, StepOrder::Atc)
    }

    fn local(&mut self, _ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        if let StepOrder::Atc = self.order {
            axpy(-self.gamma, grad, x);
        }
        Ok(())
    }

    fn communicate(
        &mut self,
        ctx: &mut NodeContext,
        pipe: &mut CommPipe<'_>,
        x: &mut Vec<f32>,
        _grad: &[f32],
    ) -> anyhow::Result<()> {
        let combined = pipe.combine(ctx, x)?;
        ctx.recycle(std::mem::replace(x, combined));
        Ok(())
    }

    fn correct(&mut self, _ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        if let StepOrder::Awc = self.order {
            axpy(-self.gamma, grad, x);
        }
        Ok(())
    }
}

/// Exact-Diffusion phase kernel (Appendix A). The psi/phi construction
/// consumes the *pre-communication* `x`, so the whole update lives in the
/// communicate phase; the algorithm cannot skip gossip rounds.
pub struct ExactDiffusionStep {
    gamma: f32,
    prev_psi: Option<Vec<f32>>,
}

impl ExactDiffusionStep {
    /// New Exact-Diffusion kernel with step size `gamma`.
    pub fn new(gamma: f32) -> Self {
        ExactDiffusionStep { gamma, prev_psi: None }
    }
}

impl AlgoStep for ExactDiffusionStep {
    fn label(&self, comm: &CommSpec) -> String {
        format!("ExactDiffusion({})", comm.label())
    }

    fn local(&mut self, _ctx: &mut NodeContext, _x: &mut Vec<f32>, _grad: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }

    fn communicate(
        &mut self,
        ctx: &mut NodeContext,
        pipe: &mut CommPipe<'_>,
        x: &mut Vec<f32>,
        grad: &[f32],
    ) -> anyhow::Result<()> {
        let mut psi = ctx.vec_from(x);
        axpy(-self.gamma, grad, &mut psi);
        let mut phi = ctx.scratch_copy(&psi);
        match &self.prev_psi {
            None => {}
            Some(prev) => {
                for ((f, (p, xi)), pp) in
                    phi.iter_mut().zip(psi.iter().zip(x.iter())).zip(prev.iter())
                {
                    *f = p + xi - pp;
                }
            }
        }
        let combined = pipe.combine(ctx, &phi)?;
        ctx.recycle(std::mem::replace(x, combined));
        if let Some(old) = self.prev_psi.replace(psi) {
            ctx.recycle(old);
        }
        Ok(())
    }
}

/// Gradient-tracking phase kernel (DIGing). The tracker update is itself
/// a combine, so both exchanges live in the communicate phase.
pub struct GradientTrackingStep {
    gamma: f32,
    y: Option<Vec<f32>>,
    prev_grad: Option<Vec<f32>>,
}

impl GradientTrackingStep {
    /// New gradient-tracking kernel with step size `gamma`.
    pub fn new(gamma: f32) -> Self {
        GradientTrackingStep { gamma, y: None, prev_grad: None }
    }

    /// The tracked global-gradient estimate.
    pub fn tracker(&self) -> Option<&Vec<f32>> {
        self.y.as_ref()
    }
}

impl AlgoStep for GradientTrackingStep {
    fn label(&self, comm: &CommSpec) -> String {
        format!("GradientTracking({})", comm.label())
    }

    fn local(&mut self, _ctx: &mut NodeContext, _x: &mut Vec<f32>, _grad: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }

    fn communicate(
        &mut self,
        ctx: &mut NodeContext,
        pipe: &mut CommPipe<'_>,
        x: &mut Vec<f32>,
        grad: &[f32],
    ) -> anyhow::Result<()> {
        let y = match (&mut self.y, &self.prev_grad) {
            (None, _) => grad.to_vec(),
            (Some(y), Some(pg)) => {
                let mut q = ctx.scratch_copy(y);
                for ((qi, g), p) in q.iter_mut().zip(grad).zip(pg.iter()) {
                    *qi += g - p;
                }
                // Stream 1: the tracker exchange must not share compression
                // state with the same-length parameter exchange below.
                pipe.combine_stream(ctx, &q, 1)?
            }
            (Some(_), None) => unreachable!("prev_grad set with y"),
        };
        let mut half = ctx.scratch_copy(x);
        axpy(-self.gamma, &y, &mut half);
        let combined = pipe.combine(ctx, &half)?;
        ctx.recycle(std::mem::replace(x, combined));
        if let Some(old) = self.y.replace(y) {
            ctx.recycle(old);
        }
        let grad_copy = ctx.vec_from(grad);
        if let Some(old) = self.prev_grad.replace(grad_copy) {
            ctx.recycle(old);
        }
        Ok(())
    }
}

/// Push-sum gradient-tracking phase kernel (Appendix B): push-style
/// combines over a directed time-varying topology with the scalar
/// push-sum weight correcting the bias. Bypasses the weighting policy —
/// its column-stochastic realizations are part of the algorithm.
pub struct PushSumStep {
    gamma: f32,
    topo: Arc<dyn DynamicTopology>,
    u: Option<Vec<f32>>,
    v: f32,
    y: Option<Vec<f32>>,
    prev_grad: Option<Vec<f32>>,
}

impl PushSumStep {
    /// New push-sum tracking kernel over `topo`.
    pub fn new(gamma: f32, topo: Arc<dyn DynamicTopology>) -> Self {
        PushSumStep { gamma, topo, u: None, v: 1.0, y: None, prev_grad: None }
    }

    /// Push-style combine: senders scale by the column-stochastic weights.
    fn push_combine(
        &self,
        ctx: &mut NodeContext,
        pipe: &mut CommPipe<'_>,
        data: &[f32],
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        let view = self.topo.view(pipe.iter(), ctx.rank());
        // Column-stochastic: self keeps self_weight, sends s_ij to dsts;
        // receivers apply r = 1.
        let w = NeighborWeights::push_pull(
            view.self_weight,
            view.src_weights.iter().map(|&(s, _)| (s, 1.0)).collect(),
            view.dst_weights.clone(),
        );
        pipe.combine_with(ctx, data, &w, stream)
    }
}

impl AlgoStep for PushSumStep {
    fn label(&self, _comm: &CommSpec) -> String {
        "PushSumGradientTracking(dynamic)".into()
    }

    fn local(&mut self, _ctx: &mut NodeContext, _x: &mut Vec<f32>, _grad: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }

    fn communicate(
        &mut self,
        ctx: &mut NodeContext,
        pipe: &mut CommPipe<'_>,
        x: &mut Vec<f32>,
        grad: &[f32],
    ) -> anyhow::Result<()> {
        // Initialize u from the current x, y from the first gradient.
        if self.u.is_none() {
            self.u = Some(x.clone());
            self.y = Some(grad.to_vec());
            self.prev_grad = Some(grad.to_vec());
        } else {
            // y_{k+1} = W^k (y_k + g_{k+1} - g_k); built in pooled scratch
            // so `self.y` stays intact if the combine errors.
            let mut q = ctx.scratch_copy(self.y.as_ref().unwrap());
            let pg = self.prev_grad.as_ref().unwrap();
            for ((qi, g), p) in q.iter_mut().zip(grad).zip(pg.iter()) {
                *qi += g - p;
            }
            let new_y = self.push_combine(ctx, pipe, &q, 1)?;
            if let Some(old) = self.y.replace(new_y) {
                ctx.recycle(old);
            }
            let grad_copy = ctx.vec_from(grad);
            if let Some(old) = self.prev_grad.replace(grad_copy) {
                ctx.recycle(old);
            }
        }
        // u_{k+1} = W^k (u_k - γ y_k)
        let mut w = ctx.scratch_copy(self.u.as_ref().unwrap());
        axpy(-self.gamma, self.y.as_ref().unwrap(), &mut w);
        let u_new = self.push_combine(ctx, pipe, &w, 0)?;
        // v_{k+1} = W^k v_k  (scalar push-sum weight)
        let v_new = self.push_combine(ctx, pipe, &[self.v], 2)?[0];
        // x_{k+1} = u_{k+1} / v_{k+1}
        if let Some(old) = self.u.replace(u_new) {
            ctx.recycle(old);
        }
        self.v = v_new;
        let u = self.u.as_ref().unwrap();
        x.clear();
        x.extend(u.iter().map(|ui| ui / self.v));
        Ok(())
    }
}

/// Decentralized momentum-SGD phase kernel (Table III's family): the
/// momentum update is the local phase; combines and the QG rebuild live
/// in communicate.
pub struct DmSgdStep {
    gamma: f32,
    beta: f32,
    kind: MomentumKind,
    order: StepOrder,
    m: Option<Vec<f32>>,
}

impl DmSgdStep {
    /// New momentum kernel.
    pub fn new(gamma: f32, beta: f32, kind: MomentumKind, order: StepOrder) -> Self {
        DmSgdStep { gamma, beta, kind, order, m: None }
    }
}

impl AlgoStep for DmSgdStep {
    fn label(&self, comm: &CommSpec) -> String {
        let kind = match self.kind {
            MomentumKind::Vanilla => "DmSGD-vanilla",
            MomentumKind::Synced => "DmSGD",
            MomentumKind::QuasiGlobal => "QG-DmSGD",
        };
        format!("{kind}({})", comm.label())
    }

    fn local(&mut self, _ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        if self.m.is_none() {
            self.m = Some(vec![0.0; x.len()]);
        }
        if let MomentumKind::Vanilla | MomentumKind::Synced = self.kind {
            let m = self.m.as_mut().unwrap();
            for (mi, g) in m.iter_mut().zip(grad) {
                *mi = self.beta * *mi + g;
            }
            if let StepOrder::Atc = self.order {
                axpy(-self.gamma, m, x);
            }
        }
        Ok(())
    }

    fn communicate(
        &mut self,
        ctx: &mut NodeContext,
        pipe: &mut CommPipe<'_>,
        x: &mut Vec<f32>,
        grad: &[f32],
    ) -> anyhow::Result<()> {
        match self.kind {
            MomentumKind::Vanilla | MomentumKind::Synced => {
                let combined = pipe.combine(ctx, x)?;
                ctx.recycle(std::mem::replace(x, combined));
                if self.kind == MomentumKind::Synced {
                    // Stream 1: keep the momentum exchange's compression
                    // state apart from the parameter exchange's.
                    let synced = pipe.combine_stream(ctx, self.m.as_ref().unwrap(), 1)?;
                    if let Some(old) = self.m.replace(synced) {
                        ctx.recycle(old);
                    }
                }
            }
            MomentumKind::QuasiGlobal => {
                // [67]: d_k = g_k + beta * m_k ; x half-step, combine, then
                // m_{k+1} = beta * m_k + (1 - beta) * (x_k - x_{k+1}) / gamma.
                let mut half = ctx.scratch_copy(x);
                {
                    let m = self.m.as_ref().unwrap();
                    for ((h, g), mi) in half.iter_mut().zip(grad).zip(m.iter()) {
                        *h -= self.gamma * (g + self.beta * mi);
                    }
                }
                let combined = pipe.combine(ctx, &half)?;
                let x_prev = std::mem::replace(x, combined);
                let m = self.m.as_mut().unwrap();
                for ((mi, xp), xn) in m.iter_mut().zip(&x_prev).zip(x.iter()) {
                    *mi = self.beta * *mi + (1.0 - self.beta) * (xp - xn) / self.gamma;
                }
                ctx.recycle(x_prev);
            }
        }
        Ok(())
    }

    fn correct(&mut self, _ctx: &mut NodeContext, x: &mut Vec<f32>, _grad: &[f32]) -> anyhow::Result<()> {
        if let (MomentumKind::Vanilla | MomentumKind::Synced, StepOrder::Awc) =
            (self.kind, self.order)
        {
            axpy(-self.gamma, self.m.as_ref().unwrap(), x);
        }
        Ok(())
    }
}

/// DIGEST-style local-update SGD (arXiv:2307.07652): `H` local gradient
/// steps, then one gossip exchange of the parameters — `H`x fewer
/// communication rounds, and the savings multiply with TopK compression
/// (k = d/16 × H = 8 ≈ two orders of magnitude fewer bytes on the wire;
/// EXPERIMENTS.md E17). At `H = 1` this is bitwise identical to
/// ATC D-SGD.
pub struct LocalUpdateSgd {
    inner: ScheduledOptimizer<DgdStep>,
    local_steps: usize,
}

impl LocalUpdateSgd {
    /// `H = local_steps` local steps per gossip over `comm`.
    pub fn new(gamma: f32, local_steps: usize, comm: CommSpec) -> Self {
        LocalUpdateSgd {
            inner: ScheduledOptimizer::new(
                DgdStep::new(gamma, StepOrder::Atc),
                comm,
                CommSchedule::local_updates(local_steps),
            ),
            local_steps,
        }
    }

    /// `H` local steps with an additional global allreduce every `period`
    /// completed steps.
    pub fn with_global_sync(
        gamma: f32,
        local_steps: usize,
        comm: CommSpec,
        period: usize,
        algo: AllreduceAlgo,
    ) -> Self {
        LocalUpdateSgd {
            inner: ScheduledOptimizer::new(
                DgdStep::new(gamma, StepOrder::Atc),
                comm,
                CommSchedule::local_updates(local_steps).with_global_sync(period, algo),
            ),
            local_steps,
        }
    }

    /// Swap the neighbor weighting policy (AL-DSGD dynamic rows).
    pub fn with_weighting(mut self, w: NeighborWeighting) -> Self {
        self.inner = self.inner.with_weighting(w);
        self
    }

    /// Step with an explicit activity flag — see
    /// [`ScheduledOptimizer::step_with_activity`].
    pub fn step_with_activity(
        &mut self,
        ctx: &mut NodeContext,
        x: &mut Vec<f32>,
        grad: &[f32],
        active: bool,
    ) -> anyhow::Result<()> {
        self.inner.step_with_activity(ctx, x, grad, active)
    }
}

impl DecentralizedOptimizer for LocalUpdateSgd {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        self.inner.step(ctx, x, grad)
    }

    fn name(&self) -> String {
        format!(
            "LocalUpdateSGD(H={}, {}, {})",
            self.local_steps,
            self.inner.comm().label(),
            match self.inner.weighting() {
                NeighborWeighting::Static => "static-w",
                NeighborWeighting::AlDsgd(_) => "al-dsgd",
            }
        )
    }

    fn observe_loss(&mut self, loss: f32) {
        self.inner.observe_loss(loss);
    }

    fn comm_rounds(&self) -> usize {
        self.inner.comm_rounds()
    }
}
