//! Decentralized optimizers (paper §II, §IV, §V-C, Appendices A/B).
//!
//! Every optimizer operates on a flat `f32` parameter vector plus a caller-
//! supplied stochastic gradient, and communicates through a [`CommSpec`]:
//! static topology, per-iteration dynamic topology, hierarchical, or global
//! averaging (the parallel-SGD baseline). This mirrors BlueFog's
//! `Distributed*Optimizer` wrappers, where the communication type and
//! topology weights are swappable per step (paper Listing 4).
//!
//! **The composable pipeline.** Synchronous algorithms are expressed as
//! [`AlgoStep`]s (local gradient step · neighbor communicate · correction,
//! see [`pipeline`]) driven by a [`ScheduledOptimizer`] that composes three
//! orthogonal policies:
//!
//! - *when* to communicate — a [`CommSchedule`] ([`schedule`]): every step,
//!   every `H` steps (DIGEST-style local updates), plus an optional
//!   periodic global sync that subsumes the old standalone
//!   [`PeriodicGlobalAveraging`] wrapper;
//! - *with which weights* — a [`NeighborWeighting`] ([`weighting`]): the
//!   static MH / survivor rows bit-for-bit, or AL-DSGD loss/staleness-
//!   boosted dynamic rows;
//! - *compressed how* — a [`crate::compress::CompressionSpec`] set on
//!   [`crate::launcher::SpmdConfig`] rides the
//!   [`crate::context::NodeContext`] into every combine, orthogonal to
//!   both (error-feedback residuals live per stream in the context).
//!
//! The classic optimizer structs below ([`Dgd`], [`ExactDiffusion`],
//! [`GradientTracking`], [`PushSumGradientTracking`], [`DmSgd`]) are thin
//! wrappers over the pipeline with their pre-refactor constructors and
//! names; `tests/optimizers.rs` pins each one bitwise against the frozen
//! copies in [`reference`]. New families land as pipeline compositions or
//! new [`AlgoStep`]s:
//!
//! - [`LocalUpdateSgd`] — `H` local steps + one gossip (DIGEST,
//!   arXiv:2307.07652), multiplying its `H`x byte savings with TopK
//!   compression;
//! - [`DecentralizedAdmm`] — proximal step + neighbor consensus + dual
//!   ascent ([`admm`]), the first non-SGD family;
//! - [`ParallelMomentumSgd`] — the centralized baseline (global gradient
//!   averaging every step).
//!
//! The *asynchronous* family — [`AsyncPushSumSgd`] and [`AsyncGossipSgd`],
//! which communicate through one-sided window operations instead of
//! matched collectives — lives in [`asynchronous`] behind its own
//! [`AsyncDecentralizedOptimizer`] trait (the step/teardown contract
//! differs: async optimizers own a window and a drain protocol).

pub mod admm;
pub mod asynchronous;
pub mod pipeline;
pub mod reference;
pub mod schedule;
pub mod weighting;

pub use admm::{DecentralizedAdmm, ProxKind};
pub use asynchronous::{AsyncDecentralizedOptimizer, AsyncGossipSgd, AsyncPushSumSgd};
pub use pipeline::{
    AlgoStep, DgdStep, DmSgdStep, ExactDiffusionStep, GradientTrackingStep, LocalUpdateSgd,
    PushSumStep, ScheduledOptimizer,
};
pub use schedule::{CommSchedule, GlobalSync, LocalUpdateSpec};
pub use weighting::{AlDsgdSpec, CommPipe, NeighborWeighting};

use std::sync::Arc;

use crate::collective::neighbor::NeighborWeights;
use crate::collective::{AllreduceAlgo, ReduceOp};
use crate::config::AlgoConfig;
use crate::context::NodeContext;
use crate::tensor::axpy;
use crate::topology::dynamic::DynamicTopology;

/// How an optimizer communicates each iteration.
#[derive(Clone)]
pub enum CommSpec {
    /// Partial averaging over the static global topology.
    Static,
    /// Partial averaging over a per-iteration dynamic topology.
    Dynamic(Arc<dyn DynamicTopology>),
    /// Hierarchical neighbor allreduce (machine-level topology).
    Hierarchical,
    /// Global averaging — the centralized baseline.
    Global(AllreduceAlgo),
    /// No communication (local SGD step).
    None,
}

impl CommSpec {
    /// Perform the combine step `x <- W x` for iteration `iter`.
    pub fn combine(
        &self,
        ctx: &mut NodeContext,
        iter: usize,
        data: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        self.combine_stream(ctx, iter, data, 0)
    }

    /// [`CommSpec::combine`] on an explicit compression stream id.
    ///
    /// Optimizers that issue *several* same-length combines per iteration
    /// (gradient tracking's `x` and `y`, DmSGD's synced momentum) pass
    /// distinct ids so the difference-tracking estimates of
    /// [`crate::compress`] never cross between logical tensors; with
    /// compression disabled the id is inert.
    pub fn combine_stream(
        &self,
        ctx: &mut NodeContext,
        iter: usize,
        data: &[f32],
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        match self {
            CommSpec::Static => ctx.neighbor_allreduce_stream(data, stream),
            CommSpec::Dynamic(topo) => {
                let view = topo.view(iter, ctx.rank());
                // Pull-style realization of the view: receivers scale.
                let w = NeighborWeights::push_pull(
                    view.self_weight,
                    view.src_weights.clone(),
                    view.dst_weights.iter().map(|&(d, _)| (d, 1.0)).collect(),
                );
                ctx.neighbor_allreduce_dynamic_stream(data, &w, stream)
            }
            CommSpec::Hierarchical => ctx.hierarchical_neighbor_allreduce_stream(data, stream),
            CommSpec::Global(algo) => ctx.allreduce(data, ReduceOp::Average, *algo),
            // Pooled copy: the caller treats the result as a fresh tensor
            // and recycles it like any combine output.
            CommSpec::None => Ok(ctx.vec_from(data)),
        }
    }

    /// Short label for logs and tables.
    pub fn label(&self) -> &'static str {
        match self {
            CommSpec::Static => "static",
            CommSpec::Dynamic(_) => "dynamic",
            CommSpec::Hierarchical => "hierarchical",
            CommSpec::Global(_) => "global",
            CommSpec::None => "none",
        }
    }
}

/// Common interface: one optimization step given the local gradient.
pub trait DecentralizedOptimizer: Send {
    /// Apply one step in place.
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32])
        -> anyhow::Result<()>;
    /// Display name.
    fn name(&self) -> String;
    /// Feed the most recent training/validation loss *before* the step —
    /// the AL-DSGD weighting's deviation signal. Default: ignored.
    fn observe_loss(&mut self, _loss: f32) {}
    /// Communication rounds issued so far (gossip exchanges + global
    /// syncs). Default 0 for optimizers that do not count.
    fn comm_rounds(&self) -> usize {
        0
    }
}

impl DecentralizedOptimizer for Box<dyn DecentralizedOptimizer> {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32])
        -> anyhow::Result<()> {
        (**self).step(ctx, x, grad)
    }

    fn name(&self) -> String {
        (**self).name()
    }

    fn observe_loss(&mut self, loss: f32) {
        (**self).observe_loss(loss)
    }

    fn comm_rounds(&self) -> usize {
        (**self).comm_rounds()
    }
}

/// Execution order of communication vs adaptation (paper §V-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOrder {
    /// Adapt-Then-Communicate: `x <- W (x - γ g)` (eq. 23).
    Atc,
    /// Adapt-While-Communicate: `x <- W x - γ g` (eq. 22) — the combine can
    /// overlap the gradient computation.
    Awc,
}

/// Momentum flavor of [`DmSgd`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MomentumKind {
    /// Local momentum buffer (vanilla DmSGD, [3]).
    Vanilla,
    /// Momentum buffer is partially averaged together with the parameters
    /// ([61] — "DmSGD" row of Table III).
    Synced,
    /// Quasi-global momentum ([67]): the buffer tracks the *global*
    /// parameter displacement instead of the noisy local gradient.
    QuasiGlobal,
}

/// Decentralized (stochastic) gradient descent — paper eq. (16)/(17).
///
/// Thin wrapper over [`DgdStep`] on the every-step schedule; bitwise
/// identical to the pre-refactor implementation.
pub struct Dgd {
    inner: ScheduledOptimizer<DgdStep>,
}

impl Dgd {
    /// New DGD optimizer with step size `gamma`.
    pub fn new(gamma: f32, order: StepOrder, comm: CommSpec) -> Self {
        Dgd {
            inner: ScheduledOptimizer::new(
                DgdStep::new(gamma, order),
                comm,
                CommSchedule::every_step(),
            ),
        }
    }

    /// Swap the neighbor weighting policy (AL-DSGD dynamic rows).
    pub fn with_weighting(mut self, w: NeighborWeighting) -> Self {
        self.inner = self.inner.with_weighting(w);
        self
    }
}

impl DecentralizedOptimizer for Dgd {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        self.inner.step(ctx, x, grad)
    }

    fn name(&self) -> String {
        self.inner.name()
    }

    fn observe_loss(&mut self, loss: f32) {
        self.inner.observe_loss(loss);
    }

    fn comm_rounds(&self) -> usize {
        self.inner.comm_rounds()
    }
}

/// Exact-Diffusion (Appendix A): corrects DGD's steady-state bias.
///
/// `psi_k = x_k - γ g_k`; `phi_k = psi_k + x_k - psi_{k-1}`;
/// `x_{k+1} = W phi_k`. Thin wrapper over [`ExactDiffusionStep`].
pub struct ExactDiffusion {
    inner: ScheduledOptimizer<ExactDiffusionStep>,
}

impl ExactDiffusion {
    /// New Exact-Diffusion optimizer with step size `gamma`.
    pub fn new(gamma: f32, comm: CommSpec) -> Self {
        ExactDiffusion {
            inner: ScheduledOptimizer::new(
                ExactDiffusionStep::new(gamma),
                comm,
                CommSchedule::every_step(),
            ),
        }
    }

    /// Swap the neighbor weighting policy (AL-DSGD dynamic rows).
    pub fn with_weighting(mut self, w: NeighborWeighting) -> Self {
        self.inner = self.inner.with_weighting(w);
        self
    }
}

impl DecentralizedOptimizer for ExactDiffusion {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        self.inner.step(ctx, x, grad)
    }

    fn name(&self) -> String {
        self.inner.name()
    }

    fn observe_loss(&mut self, loss: f32) {
        self.inner.observe_loss(loss);
    }

    fn comm_rounds(&self) -> usize {
        self.inner.comm_rounds()
    }
}

/// Gradient tracking (DIGing): `y` tracks the network-average gradient so
/// the fixed point is exact even under heterogeneous data.
///
/// `y_{k+1} = W(y_k + g_{k+1} - g_k)` (y_0 = g_0);
/// `x_{k+1} = W(x_k - γ y_{k+1})`. Thin wrapper over
/// [`GradientTrackingStep`].
pub struct GradientTracking {
    inner: ScheduledOptimizer<GradientTrackingStep>,
}

impl GradientTracking {
    /// New gradient-tracking optimizer with step size `gamma`.
    pub fn new(gamma: f32, comm: CommSpec) -> Self {
        GradientTracking {
            inner: ScheduledOptimizer::new(
                GradientTrackingStep::new(gamma),
                comm,
                CommSchedule::every_step(),
            ),
        }
    }

    /// Swap the neighbor weighting policy (AL-DSGD dynamic rows).
    pub fn with_weighting(mut self, w: NeighborWeighting) -> Self {
        self.inner = self.inner.with_weighting(w);
        self
    }

    /// The tracked global-gradient estimate (tests verify the tracking
    /// invariant `mean_i y_i = mean_i g_i`).
    pub fn tracker(&self) -> Option<&Vec<f32>> {
        self.inner.algo().tracker()
    }
}

impl DecentralizedOptimizer for GradientTracking {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        self.inner.step(ctx, x, grad)
    }

    fn name(&self) -> String {
        self.inner.name()
    }

    fn observe_loss(&mut self, loss: f32) {
        self.inner.observe_loss(loss);
    }

    fn comm_rounds(&self) -> usize {
        self.inner.comm_rounds()
    }
}

/// Push-sum gradient tracking (Appendix B, eq. (27)–(31)) — runs over
/// *directed, time-varying* graphs using column-stochastic (push) weights,
/// with the push-sum weight `v` correcting the bias. Thin wrapper over
/// [`PushSumStep`] (the weighting policy is bypassed: column-stochastic
/// realizations are part of the algorithm).
pub struct PushSumGradientTracking {
    inner: ScheduledOptimizer<PushSumStep>,
}

impl PushSumGradientTracking {
    /// New push-sum gradient-tracking optimizer over `topo`.
    pub fn new(gamma: f32, topo: Arc<dyn DynamicTopology>) -> Self {
        PushSumGradientTracking {
            inner: ScheduledOptimizer::new(
                PushSumStep::new(gamma, topo),
                CommSpec::None,
                CommSchedule::every_step(),
            ),
        }
    }
}

impl DecentralizedOptimizer for PushSumGradientTracking {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        self.inner.step(ctx, x, grad)
    }

    fn name(&self) -> String {
        self.inner.name()
    }

    fn observe_loss(&mut self, loss: f32) {
        self.inner.observe_loss(loss);
    }

    fn comm_rounds(&self) -> usize {
        self.inner.comm_rounds()
    }
}

/// Decentralized momentum SGD (Table III's algorithm family). Thin
/// wrapper over [`DmSgdStep`].
pub struct DmSgd {
    inner: ScheduledOptimizer<DmSgdStep>,
}

impl DmSgd {
    /// New decentralized momentum-SGD optimizer.
    pub fn new(gamma: f32, beta: f32, kind: MomentumKind, order: StepOrder, comm: CommSpec) -> Self {
        DmSgd {
            inner: ScheduledOptimizer::new(
                DmSgdStep::new(gamma, beta, kind, order),
                comm,
                CommSchedule::every_step(),
            ),
        }
    }

    /// Swap the neighbor weighting policy (AL-DSGD dynamic rows).
    pub fn with_weighting(mut self, w: NeighborWeighting) -> Self {
        self.inner = self.inner.with_weighting(w);
        self
    }
}

impl DecentralizedOptimizer for DmSgd {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        self.inner.step(ctx, x, grad)
    }

    fn name(&self) -> String {
        self.inner.name()
    }

    fn observe_loss(&mut self, loss: f32) {
        self.inner.observe_loss(loss);
    }

    fn comm_rounds(&self) -> usize {
        self.inner.comm_rounds()
    }
}

/// Wrapper that periodically replaces partial averaging with a global
/// allreduce (paper Listing 4: `allreduce if batch_idx % 20 == 0`).
///
/// Thin shim over [`GlobalSync`] — the schedule layer owns the logic now
/// ([`CommSchedule::with_global_sync`] is the composable form); this
/// wrapper survives so existing call sites and tests don't churn.
pub struct PeriodicGlobalAveraging<O: DecentralizedOptimizer> {
    /// The wrapped decentralized optimizer.
    pub inner: O,
    sync: GlobalSync,
    syncs_done: usize,
}

impl<O: DecentralizedOptimizer> PeriodicGlobalAveraging<O> {
    /// Wrap `inner`, averaging globally every `period` steps.
    pub fn new(inner: O, period: usize, algo: AllreduceAlgo) -> Self {
        PeriodicGlobalAveraging { inner, sync: GlobalSync::new(period, algo), syncs_done: 0 }
    }
}

impl<O: DecentralizedOptimizer> DecentralizedOptimizer for PeriodicGlobalAveraging<O> {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        self.inner.step(ctx, x, grad)?;
        if self.sync.after_step(ctx, x)? {
            self.syncs_done += 1;
        }
        Ok(())
    }

    fn name(&self) -> String {
        format!("{}+global/{}", self.inner.name(), self.sync.period())
    }

    fn observe_loss(&mut self, loss: f32) {
        self.inner.observe_loss(loss);
    }

    fn comm_rounds(&self) -> usize {
        self.inner.comm_rounds() + self.syncs_done
    }
}

/// Optimizer factory by name — thin shim over [`make_optimizer_cfg`] with
/// the pre-registry surface (kept so existing call sites don't churn).
///
/// Names: `atc`, `awc` (D-SGD orders), `dmsgd-vanilla`, `dmsgd`,
/// `qg-dmsgd` (momentum family, ATC order), `ed` (Exact-Diffusion),
/// `gt` (Gradient-Tracking), `psgd` (parallel SGD baseline).
pub fn make_optimizer(
    algo: &str,
    gamma: f32,
    beta: f32,
    comm: CommSpec,
) -> anyhow::Result<Box<dyn DecentralizedOptimizer>> {
    let cfg = AlgoConfig { algo: algo.to_string(), gamma, beta, ..AlgoConfig::default() };
    make_optimizer_cfg(&cfg, comm)
}

/// The name→algorithm registry: build any optimizer family from an
/// [`AlgoConfig`] (the CLI's `--algo`/`--local-steps`/`--weighting`/...
/// surface) plus a communication spec.
///
/// Families: `atc`/`awc`/`dsgd` (plain D-SGD; `local_steps > 1` turns the
/// ATC order into [`LocalUpdateSgd`]), `local-sgd`/`digest` (explicit
/// local-update form), `dmsgd-vanilla`/`dmsgd`/`qg-dmsgd` (momentum,
/// order from `cfg.order`), `ed`/`exact-diffusion`, `gt`/
/// `gradient-tracking`, `psgt`/`push-sum-gt` (requires a dynamic
/// topology), `admm` ([`DecentralizedAdmm`]), `psgd`/`parallel` (the
/// centralized baseline). `cfg.global_period > 0` wraps the result in
/// [`PeriodicGlobalAveraging`]; `cfg.weighting` selects the
/// [`NeighborWeighting`] policy for the gossip families.
pub fn make_optimizer_cfg(
    cfg: &AlgoConfig,
    comm: CommSpec,
) -> anyhow::Result<Box<dyn DecentralizedOptimizer>> {
    let weighting = match cfg.weighting.as_str() {
        "static" => NeighborWeighting::Static,
        "al-dsgd" | "aldsgd" => NeighborWeighting::AlDsgd(AlDsgdSpec::default()),
        other => anyhow::bail!("unknown weighting '{other}' (expected static, al-dsgd)"),
    };
    if weighting != NeighborWeighting::Static {
        anyhow::ensure!(
            matches!(comm, CommSpec::Static),
            "al-dsgd weighting modulates the static topology row; got comm '{}'",
            comm.label()
        );
    }
    let order = match cfg.order.as_str() {
        "atc" => StepOrder::Atc,
        "awc" => StepOrder::Awc,
        other => anyhow::bail!("unknown step order '{other}' (expected atc, awc)"),
    };
    let h = cfg.local_steps.max(1);
    let gossip_only = |family: &str| -> anyhow::Result<()> {
        anyhow::ensure!(
            h == 1,
            "--local-steps > 1 is only sound for the plain D-SGD family, not '{family}'"
        );
        Ok(())
    };
    let (gamma, beta) = (cfg.gamma, cfg.beta);
    let opt: Box<dyn DecentralizedOptimizer> = match cfg.algo.as_str() {
        "atc" | "awc" | "dsgd" | "local-sgd" | "digest" => {
            let ord = match cfg.algo.as_str() {
                "atc" | "dsgd" | "local-sgd" | "digest" => StepOrder::Atc,
                "awc" => StepOrder::Awc,
                _ => order,
            };
            if h > 1 || matches!(cfg.algo.as_str(), "local-sgd" | "digest") {
                anyhow::ensure!(
                    ord == StepOrder::Atc,
                    "local-update schedules require the ATC order"
                );
                Box::new(LocalUpdateSgd::new(gamma, h, comm).with_weighting(weighting))
            } else {
                Box::new(Dgd::new(gamma, ord, comm).with_weighting(weighting))
            }
        }
        "dmsgd-vanilla" => {
            gossip_only("dmsgd-vanilla")?;
            Box::new(
                DmSgd::new(gamma, beta, MomentumKind::Vanilla, order, comm)
                    .with_weighting(weighting),
            )
        }
        "dmsgd" => {
            gossip_only("dmsgd")?;
            Box::new(
                DmSgd::new(gamma, beta, MomentumKind::Synced, order, comm)
                    .with_weighting(weighting),
            )
        }
        "qg-dmsgd" => {
            gossip_only("qg-dmsgd")?;
            Box::new(
                DmSgd::new(gamma, beta, MomentumKind::QuasiGlobal, order, comm)
                    .with_weighting(weighting),
            )
        }
        "ed" | "exact-diffusion" => {
            gossip_only("ed")?;
            Box::new(ExactDiffusion::new(gamma, comm).with_weighting(weighting))
        }
        "gt" | "gradient-tracking" => {
            gossip_only("gt")?;
            Box::new(GradientTracking::new(gamma, comm).with_weighting(weighting))
        }
        "psgt" | "push-sum-gt" => {
            gossip_only("psgt")?;
            anyhow::ensure!(
                weighting == NeighborWeighting::Static,
                "push-sum gradient tracking owns its column-stochastic weights"
            );
            match &comm {
                CommSpec::Dynamic(topo) => {
                    Box::new(PushSumGradientTracking::new(gamma, topo.clone()))
                }
                other => anyhow::bail!(
                    "psgt requires a dynamic directed topology (got '{}')",
                    other.label()
                ),
            }
        }
        "admm" => {
            gossip_only("admm")?;
            anyhow::ensure!(
                weighting == NeighborWeighting::Static,
                "admm owns its consensus weights"
            );
            Box::new(DecentralizedAdmm::new(
                cfg.admm_alpha,
                ProxKind::Linearized { eta: cfg.admm_eta },
            ))
        }
        "psgd" | "parallel" => {
            gossip_only("psgd")?;
            Box::new(ParallelMomentumSgd::new(gamma, beta, AllreduceAlgo::Ring))
        }
        other => anyhow::bail!(
            "unknown algorithm '{other}' (expected atc, awc, dsgd, local-sgd, digest, \
             dmsgd-vanilla, dmsgd, qg-dmsgd, ed, gt, psgt, admm, psgd)"
        ),
    };
    Ok(if cfg.global_period > 0 {
        Box::new(PeriodicGlobalAveraging::new(opt, cfg.global_period, AllreduceAlgo::Ring))
    } else {
        opt
    })
}

/// Parallel SGD with momentum — the centralized baseline of Table III
/// (global averaging of gradients every step).
pub struct ParallelMomentumSgd {
    /// Step size `γ`.
    pub gamma: f32,
    /// Momentum coefficient `β`.
    pub beta: f32,
    /// Allreduce algorithm used for the per-step global gradient average.
    pub algo: AllreduceAlgo,
    m: Option<Vec<f32>>,
    rounds: usize,
}

impl ParallelMomentumSgd {
    /// New centralized momentum-SGD baseline.
    pub fn new(gamma: f32, beta: f32, algo: AllreduceAlgo) -> Self {
        ParallelMomentumSgd { gamma, beta, algo, m: None, rounds: 0 }
    }
}

impl DecentralizedOptimizer for ParallelMomentumSgd {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        let g_avg = ctx.allreduce(grad, ReduceOp::Average, self.algo)?;
        let m = self.m.get_or_insert_with(|| vec![0.0; x.len()]);
        for (mi, g) in m.iter_mut().zip(&g_avg) {
            *mi = self.beta * *mi + g;
        }
        axpy(-self.gamma, &m[..], x);
        ctx.recycle(g_avg);
        self.rounds += 1;
        Ok(())
    }

    fn name(&self) -> String {
        "ParallelSGD".into()
    }

    fn comm_rounds(&self) -> usize {
        self.rounds
    }
}
