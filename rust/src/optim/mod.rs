//! Decentralized optimizers (paper §II, §IV, §V-C, Appendices A/B).
//!
//! Every optimizer operates on a flat `f32` parameter vector plus a caller-
//! supplied stochastic gradient, and communicates through a [`CommSpec`]:
//! static topology, per-iteration dynamic topology, hierarchical, or global
//! averaging (the parallel-SGD baseline). This mirrors BlueFog's
//! `Distributed*Optimizer` wrappers, where the communication type and
//! topology weights are swappable per step (paper Listing 4).
//!
//! **Communication compression** is orthogonal to the optimizer: a
//! [`crate::compress::CompressionSpec`] set on
//! [`crate::launcher::SpmdConfig`] rides the [`crate::context::NodeContext`]
//! into every neighbor combine a [`CommSpec`] issues, so each optimizer
//! below runs compressed with zero API change at its call site (the
//! error-feedback residuals that keep this convergent live per stream in
//! the context, not in the optimizer). Global averaging
//! ([`CommSpec::Global`]) stays dense — it is the exact baseline the
//! compression probes compare against.
//!
//! Implemented algorithms:
//! - [`Dgd`] — decentralized (stochastic) gradient descent, ATC and AWC
//!   orders (paper eq. (22)/(23));
//! - [`ExactDiffusion`] — bias-corrected diffusion (Appendix A);
//! - [`GradientTracking`] — DIGing-style tracking of the global gradient;
//! - [`PushSumGradientTracking`] — push-style tracking over directed
//!   time-varying graphs (Appendix B);
//! - [`DmSgd`] — decentralized momentum SGD in three flavors: vanilla
//!   (local momentum, [3]), synchronized momentum ([61]: the momentum
//!   buffer is partially averaged too) and quasi-global momentum
//!   (QG-DmSGD, [67]);
//! - [`PeriodicGlobalAveraging`] — wrapper that swaps partial averaging for
//!   a global allreduce every `period` steps (paper Listing 4 / [4]).
//!
//! The *asynchronous* family — [`AsyncPushSumSgd`] and [`AsyncGossipSgd`],
//! which communicate through one-sided window operations instead of
//! matched collectives — lives in [`asynchronous`] behind its own
//! [`AsyncDecentralizedOptimizer`] trait (the step/teardown contract
//! differs: async optimizers own a window and a drain protocol).

pub mod asynchronous;

pub use asynchronous::{AsyncDecentralizedOptimizer, AsyncGossipSgd, AsyncPushSumSgd};

use std::sync::Arc;

use crate::collective::neighbor::NeighborWeights;
use crate::collective::{AllreduceAlgo, ReduceOp};
use crate::context::NodeContext;
use crate::tensor::axpy;
use crate::topology::dynamic::DynamicTopology;

/// How an optimizer communicates each iteration.
#[derive(Clone)]
pub enum CommSpec {
    /// Partial averaging over the static global topology.
    Static,
    /// Partial averaging over a per-iteration dynamic topology.
    Dynamic(Arc<dyn DynamicTopology>),
    /// Hierarchical neighbor allreduce (machine-level topology).
    Hierarchical,
    /// Global averaging — the centralized baseline.
    Global(AllreduceAlgo),
    /// No communication (local SGD step).
    None,
}

impl CommSpec {
    /// Perform the combine step `x <- W x` for iteration `iter`.
    pub fn combine(
        &self,
        ctx: &mut NodeContext,
        iter: usize,
        data: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        self.combine_stream(ctx, iter, data, 0)
    }

    /// [`CommSpec::combine`] on an explicit compression stream id.
    ///
    /// Optimizers that issue *several* same-length combines per iteration
    /// (gradient tracking's `x` and `y`, DmSGD's synced momentum) pass
    /// distinct ids so the difference-tracking estimates of
    /// [`crate::compress`] never cross between logical tensors; with
    /// compression disabled the id is inert.
    pub fn combine_stream(
        &self,
        ctx: &mut NodeContext,
        iter: usize,
        data: &[f32],
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        match self {
            CommSpec::Static => ctx.neighbor_allreduce_stream(data, stream),
            CommSpec::Dynamic(topo) => {
                let view = topo.view(iter, ctx.rank());
                // Pull-style realization of the view: receivers scale.
                let w = NeighborWeights::push_pull(
                    view.self_weight,
                    view.src_weights.clone(),
                    view.dst_weights.iter().map(|&(d, _)| (d, 1.0)).collect(),
                );
                ctx.neighbor_allreduce_dynamic_stream(data, &w, stream)
            }
            CommSpec::Hierarchical => ctx.hierarchical_neighbor_allreduce_stream(data, stream),
            CommSpec::Global(algo) => ctx.allreduce(data, ReduceOp::Average, *algo),
            // Pooled copy: the caller treats the result as a fresh tensor
            // and recycles it like any combine output.
            CommSpec::None => Ok(ctx.vec_from(data)),
        }
    }

    /// Short label for logs and tables.
    pub fn label(&self) -> &'static str {
        match self {
            CommSpec::Static => "static",
            CommSpec::Dynamic(_) => "dynamic",
            CommSpec::Hierarchical => "hierarchical",
            CommSpec::Global(_) => "global",
            CommSpec::None => "none",
        }
    }
}

/// Common interface: one optimization step given the local gradient.
pub trait DecentralizedOptimizer: Send {
    /// Apply one step in place.
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32])
        -> anyhow::Result<()>;
    /// Display name.
    fn name(&self) -> String;
}

impl DecentralizedOptimizer for Box<dyn DecentralizedOptimizer> {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32])
        -> anyhow::Result<()> {
        (**self).step(ctx, x, grad)
    }

    fn name(&self) -> String {
        (**self).name()
    }
}

/// Execution order of communication vs adaptation (paper §V-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOrder {
    /// Adapt-Then-Communicate: `x <- W (x - γ g)` (eq. 23).
    Atc,
    /// Adapt-While-Communicate: `x <- W x - γ g` (eq. 22) — the combine can
    /// overlap the gradient computation.
    Awc,
}

/// Decentralized (stochastic) gradient descent — paper eq. (16)/(17).
pub struct Dgd {
    /// Step size `γ`.
    pub gamma: f32,
    /// Communication/adaptation order (ATC vs AWC).
    pub order: StepOrder,
    /// Communication pattern used by the combine step.
    pub comm: CommSpec,
    iter: usize,
}

impl Dgd {
    /// New DGD optimizer with step size `gamma`.
    pub fn new(gamma: f32, order: StepOrder, comm: CommSpec) -> Self {
        Dgd { gamma, order, comm, iter: 0 }
    }
}

impl DecentralizedOptimizer for Dgd {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        match self.order {
            StepOrder::Atc => {
                // Pooled scratch for the half-step; the replaced parameter
                // buffer goes back to the pool for the next round.
                let mut half = ctx.scratch_copy(x);
                axpy(-self.gamma, grad, &mut half);
                let combined = self.comm.combine(ctx, self.iter, &half)?;
                ctx.recycle(std::mem::replace(x, combined));
            }
            StepOrder::Awc => {
                let combined = self.comm.combine(ctx, self.iter, x)?;
                ctx.recycle(std::mem::replace(x, combined));
                axpy(-self.gamma, grad, x);
            }
        }
        self.iter += 1;
        Ok(())
    }

    fn name(&self) -> String {
        format!("DGD-{:?}({})", self.order, self.comm.label())
    }
}

/// Exact-Diffusion (Appendix A): corrects DGD's steady-state bias.
///
/// `psi_k = x_k - γ g_k`; `phi_k = psi_k + x_k - psi_{k-1}`;
/// `x_{k+1} = W phi_k`.
pub struct ExactDiffusion {
    /// Step size `γ`.
    pub gamma: f32,
    /// Communication pattern used by the combine step.
    pub comm: CommSpec,
    prev_psi: Option<Vec<f32>>,
    iter: usize,
}

impl ExactDiffusion {
    /// New Exact-Diffusion optimizer with step size `gamma`.
    pub fn new(gamma: f32, comm: CommSpec) -> Self {
        ExactDiffusion { gamma, comm, prev_psi: None, iter: 0 }
    }
}

impl DecentralizedOptimizer for ExactDiffusion {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        let mut psi = ctx.vec_from(x);
        axpy(-self.gamma, grad, &mut psi);
        let mut phi = ctx.scratch_copy(&psi);
        match &self.prev_psi {
            None => {}
            Some(prev) => {
                for ((f, (p, xi)), pp) in
                    phi.iter_mut().zip(psi.iter().zip(x.iter())).zip(prev.iter())
                {
                    *f = p + xi - pp;
                }
            }
        }
        let combined = self.comm.combine(ctx, self.iter, &phi)?;
        ctx.recycle(std::mem::replace(x, combined));
        if let Some(old) = self.prev_psi.replace(psi) {
            ctx.recycle(old);
        }
        self.iter += 1;
        Ok(())
    }

    fn name(&self) -> String {
        format!("ExactDiffusion({})", self.comm.label())
    }
}

/// Gradient tracking (DIGing): `y` tracks the network-average gradient so
/// the fixed point is exact even under heterogeneous data.
///
/// `y_{k+1} = W(y_k + g_{k+1} - g_k)` (y_0 = g_0);
/// `x_{k+1} = W(x_k - γ y_{k+1})`.
pub struct GradientTracking {
    /// Step size `γ`.
    pub gamma: f32,
    /// Communication pattern used by the combine step.
    pub comm: CommSpec,
    y: Option<Vec<f32>>,
    prev_grad: Option<Vec<f32>>,
    iter: usize,
}

impl GradientTracking {
    /// New gradient-tracking optimizer with step size `gamma`.
    pub fn new(gamma: f32, comm: CommSpec) -> Self {
        GradientTracking { gamma, comm, y: None, prev_grad: None, iter: 0 }
    }

    /// The tracked global-gradient estimate (tests verify the tracking
    /// invariant `mean_i y_i = mean_i g_i`).
    pub fn tracker(&self) -> Option<&Vec<f32>> {
        self.y.as_ref()
    }
}

impl DecentralizedOptimizer for GradientTracking {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        let y = match (&mut self.y, &self.prev_grad) {
            (None, _) => grad.to_vec(),
            (Some(y), Some(pg)) => {
                let mut q = ctx.scratch_copy(y);
                for ((qi, g), p) in q.iter_mut().zip(grad).zip(pg.iter()) {
                    *qi += g - p;
                }
                // Stream 1: the tracker exchange must not share compression
                // state with the same-length parameter exchange below.
                self.comm.combine_stream(ctx, self.iter, &q, 1)?
            }
            (Some(_), None) => unreachable!("prev_grad set with y"),
        };
        let mut half = ctx.scratch_copy(x);
        axpy(-self.gamma, &y, &mut half);
        let combined = self.comm.combine(ctx, self.iter, &half)?;
        ctx.recycle(std::mem::replace(x, combined));
        if let Some(old) = self.y.replace(y) {
            ctx.recycle(old);
        }
        let grad_copy = ctx.vec_from(grad);
        if let Some(old) = self.prev_grad.replace(grad_copy) {
            ctx.recycle(old);
        }
        self.iter += 1;
        Ok(())
    }

    fn name(&self) -> String {
        format!("GradientTracking({})", self.comm.label())
    }
}

/// Push-sum gradient tracking (Appendix B, eq. (27)–(31)) — runs over
/// *directed, time-varying* graphs using column-stochastic (push) weights,
/// with the push-sum weight `v` correcting the bias.
pub struct PushSumGradientTracking {
    /// Step size `γ`.
    pub gamma: f32,
    /// Per-iteration directed topology schedule.
    pub topo: Arc<dyn DynamicTopology>,
    u: Option<Vec<f32>>,
    v: f32,
    y: Option<Vec<f32>>,
    prev_grad: Option<Vec<f32>>,
    iter: usize,
}

impl PushSumGradientTracking {
    /// New push-sum gradient-tracking optimizer over `topo`.
    pub fn new(gamma: f32, topo: Arc<dyn DynamicTopology>) -> Self {
        PushSumGradientTracking { gamma, topo, u: None, v: 1.0, y: None, prev_grad: None, iter: 0 }
    }

    /// Push-style combine: senders scale by the column-stochastic weights.
    fn push_combine(
        &self,
        ctx: &mut NodeContext,
        iter: usize,
        data: &[f32],
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        let view = self.topo.view(iter, ctx.rank());
        // Column-stochastic: self keeps self_weight, sends s_ij to dsts;
        // receivers apply r = 1.
        let w = NeighborWeights::push_pull(
            view.self_weight,
            view.src_weights.iter().map(|&(s, _)| (s, 1.0)).collect(),
            view.dst_weights.clone(),
        );
        ctx.neighbor_allreduce_dynamic_stream(data, &w, stream)
    }
}

impl DecentralizedOptimizer for PushSumGradientTracking {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        // Initialize u from the current x, y from the first gradient.
        if self.u.is_none() {
            self.u = Some(x.clone());
            self.y = Some(grad.to_vec());
            self.prev_grad = Some(grad.to_vec());
        } else {
            // y_{k+1} = W^k (y_k + g_{k+1} - g_k); built in pooled scratch
            // so `self.y` stays intact if the combine errors.
            let mut q = ctx.scratch_copy(self.y.as_ref().unwrap());
            let pg = self.prev_grad.as_ref().unwrap();
            for ((qi, g), p) in q.iter_mut().zip(grad).zip(pg.iter()) {
                *qi += g - p;
            }
            let new_y = self.push_combine(ctx, self.iter, &q, 1)?;
            if let Some(old) = self.y.replace(new_y) {
                ctx.recycle(old);
            }
            let grad_copy = ctx.vec_from(grad);
            if let Some(old) = self.prev_grad.replace(grad_copy) {
                ctx.recycle(old);
            }
        }
        // u_{k+1} = W^k (u_k - γ y_k)
        let mut w = ctx.scratch_copy(self.u.as_ref().unwrap());
        axpy(-self.gamma, self.y.as_ref().unwrap(), &mut w);
        let u_new = self.push_combine(ctx, self.iter, &w, 0)?;
        // v_{k+1} = W^k v_k  (scalar push-sum weight)
        let v_new = self.push_combine(ctx, self.iter, &[self.v], 2)?[0];
        // x_{k+1} = u_{k+1} / v_{k+1}
        if let Some(old) = self.u.replace(u_new) {
            ctx.recycle(old);
        }
        self.v = v_new;
        let u = self.u.as_ref().unwrap();
        x.clear();
        x.extend(u.iter().map(|ui| ui / self.v));
        self.iter += 1;
        Ok(())
    }

    fn name(&self) -> String {
        "PushSumGradientTracking(dynamic)".into()
    }
}

/// Momentum flavor of [`DmSgd`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MomentumKind {
    /// Local momentum buffer (vanilla DmSGD, [3]).
    Vanilla,
    /// Momentum buffer is partially averaged together with the parameters
    /// ([61] — "DmSGD" row of Table III).
    Synced,
    /// Quasi-global momentum ([67]): the buffer tracks the *global*
    /// parameter displacement instead of the noisy local gradient.
    QuasiGlobal,
}

/// Decentralized momentum SGD (Table III's algorithm family).
pub struct DmSgd {
    /// Step size `γ`.
    pub gamma: f32,
    /// Momentum coefficient `β`.
    pub beta: f32,
    /// Which momentum variant to run (Table III rows).
    pub kind: MomentumKind,
    /// Communication/adaptation order (ATC vs AWC).
    pub order: StepOrder,
    /// Communication pattern used by the combine step.
    pub comm: CommSpec,
    m: Option<Vec<f32>>,
    iter: usize,
}

impl DmSgd {
    /// New decentralized momentum-SGD optimizer.
    pub fn new(gamma: f32, beta: f32, kind: MomentumKind, order: StepOrder, comm: CommSpec) -> Self {
        DmSgd { gamma, beta, kind, order, comm, m: None, iter: 0 }
    }
}

impl DecentralizedOptimizer for DmSgd {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        let d = x.len();
        if self.m.is_none() {
            self.m = Some(vec![0.0; d]);
        }
        match self.kind {
            MomentumKind::Vanilla | MomentumKind::Synced => {
                {
                    let m = self.m.as_mut().unwrap();
                    for (mi, g) in m.iter_mut().zip(grad) {
                        *mi = self.beta * *mi + g;
                    }
                }
                match self.order {
                    StepOrder::Atc => {
                        let mut half = ctx.scratch_copy(x);
                        axpy(-self.gamma, self.m.as_ref().unwrap(), &mut half);
                        let combined = self.comm.combine(ctx, self.iter, &half)?;
                        ctx.recycle(std::mem::replace(x, combined));
                    }
                    StepOrder::Awc => {
                        let combined = self.comm.combine(ctx, self.iter, x)?;
                        ctx.recycle(std::mem::replace(x, combined));
                        axpy(-self.gamma, self.m.as_ref().unwrap(), x);
                    }
                }
                if self.kind == MomentumKind::Synced {
                    // Stream 1: keep the momentum exchange's compression
                    // state apart from the parameter exchange's.
                    let synced =
                        self.comm.combine_stream(ctx, self.iter, self.m.as_ref().unwrap(), 1)?;
                    if let Some(old) = self.m.replace(synced) {
                        ctx.recycle(old);
                    }
                }
            }
            MomentumKind::QuasiGlobal => {
                // [67]: d_k = g_k + beta * m_k ; x half-step, combine, then
                // m_{k+1} = beta * m_k + (1 - beta) * (x_k - x_{k+1}) / gamma.
                let mut half = ctx.scratch_copy(x);
                {
                    let m = self.m.as_ref().unwrap();
                    for ((h, g), mi) in half.iter_mut().zip(grad).zip(m.iter()) {
                        *h -= self.gamma * (g + self.beta * mi);
                    }
                }
                let combined = self.comm.combine(ctx, self.iter, &half)?;
                let x_prev = std::mem::replace(x, combined);
                let m = self.m.as_mut().unwrap();
                for ((mi, xp), xn) in m.iter_mut().zip(&x_prev).zip(x.iter()) {
                    *mi = self.beta * *mi + (1.0 - self.beta) * (xp - xn) / self.gamma;
                }
                ctx.recycle(x_prev);
            }
        }
        self.iter += 1;
        Ok(())
    }

    fn name(&self) -> String {
        let kind = match self.kind {
            MomentumKind::Vanilla => "DmSGD-vanilla",
            MomentumKind::Synced => "DmSGD",
            MomentumKind::QuasiGlobal => "QG-DmSGD",
        };
        format!("{kind}({})", self.comm.label())
    }
}

/// Wrapper that periodically replaces partial averaging with a global
/// allreduce (paper Listing 4: `allreduce if batch_idx % 20 == 0`).
pub struct PeriodicGlobalAveraging<O: DecentralizedOptimizer> {
    /// The wrapped decentralized optimizer.
    pub inner: O,
    /// A global allreduce replaces partial averaging every `period` steps.
    pub period: usize,
    /// Allreduce algorithm used for the periodic global average.
    pub algo: AllreduceAlgo,
    iter: usize,
}

impl<O: DecentralizedOptimizer> PeriodicGlobalAveraging<O> {
    /// Wrap `inner`, averaging globally every `period` steps.
    pub fn new(inner: O, period: usize, algo: AllreduceAlgo) -> Self {
        assert!(period > 0);
        PeriodicGlobalAveraging { inner, period, algo, iter: 0 }
    }
}

impl<O: DecentralizedOptimizer> DecentralizedOptimizer for PeriodicGlobalAveraging<O> {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        self.inner.step(ctx, x, grad)?;
        self.iter += 1;
        if self.iter % self.period == 0 {
            *x = ctx.allreduce(x, ReduceOp::Average, self.algo)?;
        }
        Ok(())
    }

    fn name(&self) -> String {
        format!("{}+global/{}", self.inner.name(), self.period)
    }
}

/// Optimizer factory by name (CLI / bench convenience).
///
/// Names: `atc`, `awc` (D-SGD orders), `dmsgd-vanilla`, `dmsgd`,
/// `qg-dmsgd` (momentum family, ATC order), `ed` (Exact-Diffusion),
/// `gt` (Gradient-Tracking), `psgd` (parallel SGD baseline).
pub fn make_optimizer(
    algo: &str,
    gamma: f32,
    beta: f32,
    comm: CommSpec,
) -> anyhow::Result<Box<dyn DecentralizedOptimizer>> {
    Ok(match algo {
        "atc" => Box::new(Dgd::new(gamma, StepOrder::Atc, comm)),
        "awc" => Box::new(Dgd::new(gamma, StepOrder::Awc, comm)),
        "dmsgd-vanilla" => {
            Box::new(DmSgd::new(gamma, beta, MomentumKind::Vanilla, StepOrder::Atc, comm))
        }
        "dmsgd" => Box::new(DmSgd::new(gamma, beta, MomentumKind::Synced, StepOrder::Atc, comm)),
        "qg-dmsgd" => {
            Box::new(DmSgd::new(gamma, beta, MomentumKind::QuasiGlobal, StepOrder::Atc, comm))
        }
        "ed" | "exact-diffusion" => Box::new(ExactDiffusion::new(gamma, comm)),
        "gt" | "gradient-tracking" => Box::new(GradientTracking::new(gamma, comm)),
        "psgd" | "parallel" => {
            Box::new(ParallelMomentumSgd::new(gamma, beta, AllreduceAlgo::Ring))
        }
        other => anyhow::bail!(
            "unknown algorithm '{other}' (expected atc, awc, dmsgd-vanilla, dmsgd, \
             qg-dmsgd, ed, gt, psgd)"
        ),
    })
}

/// Parallel SGD with momentum — the centralized baseline of Table III
/// (global averaging of gradients every step).
pub struct ParallelMomentumSgd {
    /// Step size `γ`.
    pub gamma: f32,
    /// Momentum coefficient `β`.
    pub beta: f32,
    /// Allreduce algorithm used for the per-step global gradient average.
    pub algo: AllreduceAlgo,
    m: Option<Vec<f32>>,
}

impl ParallelMomentumSgd {
    /// New centralized momentum-SGD baseline.
    pub fn new(gamma: f32, beta: f32, algo: AllreduceAlgo) -> Self {
        ParallelMomentumSgd { gamma, beta, algo, m: None }
    }
}

impl DecentralizedOptimizer for ParallelMomentumSgd {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        let g_avg = ctx.allreduce(grad, ReduceOp::Average, self.algo)?;
        let m = self.m.get_or_insert_with(|| vec![0.0; x.len()]);
        for (mi, g) in m.iter_mut().zip(&g_avg) {
            *mi = self.beta * *mi + g;
        }
        axpy(-self.gamma, &m[..], x);
        ctx.recycle(g_avg);
        Ok(())
    }

    fn name(&self) -> String {
        "ParallelSGD".into()
    }
}
