//! Neighbor weighting policies: *with which weights* an optimizer gossips.
//!
//! The pipeline's communication phase goes through a [`CommPipe`], which
//! applies the configured [`NeighborWeighting`] to every combine:
//!
//! - [`NeighborWeighting::Static`] — the [`CommSpec`]'s own weights,
//!   bit-for-bit (static Metropolis–Hastings rows; under active fault
//!   injection the static path already re-derives survivor MH rows, so
//!   survivor weighting is subsumed here);
//! - [`NeighborWeighting::AlDsgd`] — AL-DSGD-style dynamic rows
//!   (arXiv:2405.11389, adapted): each gossip round, edge `(i, j)` of the
//!   static MH row is boosted by how *deviant* (high validation loss,
//!   normalized to the fleet's range) and how *stale* (fraction of the
//!   scheduled local steps actually completed) its worse endpoint is. The
//!   boost is symmetric in `(i, j)` and capped so every self-weight keeps
//!   an `eps` floor — the modulated matrix therefore stays doubly
//!   stochastic, which is what turns the boost into a consensus-spread
//!   win instead of a mean-drag (row-stochastic softmax reweighting, the
//!   paper's literal form, moves the average around and loses the spread
//!   it gains; see EXPERIMENTS.md E17).
//!
//! The per-round fleet report (loss, staleness, MH self-weight) is shared
//! through a one-hot sum-allreduce of `3n` floats — each slot has exactly
//! one nonzero contributor, so the exchange is order-independent and
//! bitwise deterministic on every backend.

use crate::collective::neighbor::NeighborWeights;
use crate::collective::{AllreduceAlgo, ReduceOp};
use crate::context::NodeContext;

use super::CommSpec;

/// Tuning constants of the AL-DSGD dynamic weighting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlDsgdSpec {
    /// Boost per unit of loss deviation (normalized to the fleet range).
    pub kappa: f32,
    /// Boost per unit of staleness (missed fraction of scheduled steps).
    pub lambda: f32,
    /// Self-weight floor: boosts are capped so `w_ii >= eps`.
    pub eps: f32,
}

impl Default for AlDsgdSpec {
    fn default() -> Self {
        AlDsgdSpec { kappa: 2.0, lambda: 1.0, eps: 0.02 }
    }
}

/// Per-gossip-round neighbor weighting policy.
#[derive(Debug, Clone, PartialEq)]
pub enum NeighborWeighting {
    /// The communication spec's own weights (static MH / survivor rows).
    Static,
    /// Loss/staleness-boosted dynamic rows over the static topology.
    AlDsgd(AlDsgdSpec),
}

/// Runtime state of a weighting policy (row cache per gossip round).
pub(crate) enum WeightingState {
    Static,
    AlDsgd { spec: AlDsgdSpec, cached: Option<(usize, NeighborWeights)> },
}

impl WeightingState {
    pub(crate) fn new(w: &NeighborWeighting) -> Self {
        match w {
            NeighborWeighting::Static => WeightingState::Static,
            NeighborWeighting::AlDsgd(spec) => WeightingState::AlDsgd { spec: *spec, cached: None },
        }
    }
}

/// Compute this rank's boosted pull row for the current gossip round.
///
/// `loss` is the rank's last observed training/validation loss and
/// `progress` the fraction of scheduled local steps it completed this
/// window (1.0 = on pace). Symmetry of the boost plus the shared caps
/// keep the implied global matrix doubly stochastic.
fn al_dsgd_row(
    ctx: &mut NodeContext,
    spec: &AlDsgdSpec,
    loss: f32,
    progress: f32,
) -> anyhow::Result<NeighborWeights> {
    let n = ctx.size();
    let me = ctx.rank();
    let (self_w, srcs, dsts) = ctx.static_pull_row();
    // Fleet report: [loss, staleness, mh_self_weight] per rank, exchanged
    // as a one-hot sum so every slot is exact.
    let mut report = vec![0.0f32; 3 * n];
    report[3 * me] = loss;
    report[3 * me + 1] = (1.0 - progress).clamp(0.0, 1.0);
    report[3 * me + 2] = self_w as f32;
    let table = ctx.allreduce(&report, ReduceOp::Sum, AllreduceAlgo::Ring)?;
    let (mut lmin, mut lmax) = (f32::INFINITY, f32::NEG_INFINITY);
    for r in 0..n {
        lmin = lmin.min(table[3 * r]);
        lmax = lmax.max(table[3 * r]);
    }
    let range = (lmax - lmin).max(1e-12);
    let dev = |r: usize| (table[3 * r] - lmin) / range;
    let stale = |r: usize| table[3 * r + 1];
    let cap = |r: usize| {
        let sw = table[3 * r + 2];
        if sw >= 1.0 - 1e-6 {
            1.0
        } else {
            (1.0 - spec.eps) / (1.0 - sw)
        }
    };
    let mut kept = 1.0f64;
    let boosted: Vec<(usize, f64)> = srcs
        .iter()
        .map(|&(j, w)| {
            let b = (1.0 + spec.kappa * dev(me).max(dev(j)) + spec.lambda * stale(me).max(stale(j)))
                .min(cap(me))
                .min(cap(j));
            let wj = w * b as f64;
            kept -= wj;
            (j, wj)
        })
        .collect();
    ctx.recycle(table);
    Ok(NeighborWeights::push_pull(kept, boosted, dsts.into_iter().map(|d| (d, 1.0)).collect()))
}

/// The pipeline's communication handle: every combine an [`super::AlgoStep`]
/// issues goes through here, so the weighting policy applies uniformly and
/// communication rounds are counted in one place.
pub struct CommPipe<'a> {
    pub(crate) comm: &'a CommSpec,
    pub(crate) weighting: &'a mut WeightingState,
    pub(crate) iter: usize,
    pub(crate) rounds: &'a mut usize,
    pub(crate) loss: f32,
    pub(crate) progress: f32,
}

impl CommPipe<'_> {
    /// The driver iteration this gossip round belongs to (indexes dynamic
    /// topologies exactly as the pre-refactor optimizers did).
    pub fn iter(&self) -> usize {
        self.iter
    }

    /// Combine on stream 0.
    pub fn combine(&mut self, ctx: &mut NodeContext, data: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.combine_stream(ctx, data, 0)
    }

    /// Combine `data` under the configured weighting policy on an explicit
    /// compression stream id. With [`NeighborWeighting::Static`] this is
    /// exactly [`CommSpec::combine_stream`] — bitwise identical to the
    /// pre-refactor paths.
    pub fn combine_stream(
        &mut self,
        ctx: &mut NodeContext,
        data: &[f32],
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        *self.rounds += 1;
        match self.weighting {
            WeightingState::Static => self.comm.combine_stream(ctx, self.iter, data, stream),
            WeightingState::AlDsgd { spec, cached } => {
                anyhow::ensure!(
                    matches!(self.comm, CommSpec::Static),
                    "al-dsgd weighting modulates the static topology row; got comm '{}'",
                    self.comm.label()
                );
                let w = match cached {
                    Some((it, w)) if *it == self.iter => w.clone(),
                    _ => {
                        let w = al_dsgd_row(ctx, spec, self.loss, self.progress)?;
                        *cached = Some((self.iter, w.clone()));
                        w
                    }
                };
                ctx.neighbor_allreduce_dynamic_stream(data, &w, stream)
            }
        }
    }

    /// Combine with caller-supplied weights (push-sum's column-stochastic
    /// realizations bypass the weighting policy but still count as rounds).
    pub fn combine_with(
        &mut self,
        ctx: &mut NodeContext,
        data: &[f32],
        weights: &NeighborWeights,
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        *self.rounds += 1;
        ctx.neighbor_allreduce_dynamic_stream(data, weights, stream)
    }
}
