//! Communication schedules: *when* an optimizer communicates.
//!
//! The composable pipeline (DESIGN.md §Algorithms) factors every
//! decentralized algorithm into local adaptation, neighbor communication
//! and correction; a [`CommSchedule`] decides at which iterations the
//! communication phases actually run:
//!
//! - every step — the classical synchronous regime;
//! - every `H` steps ([`LocalUpdateSpec`]) — DIGEST-style local updates
//!   (arXiv:2307.07652): `H` local gradient steps between gossip
//!   exchanges cut communication by `H`x while preserving the rate;
//! - periodic global sync ([`GlobalSync`]) — a global allreduce every
//!   `period` completed steps, subsuming the old standalone
//!   `PeriodicGlobalAveraging` wrapper (paper Listing 4), whose
//!   constructor survives as a thin shim over this state.

use crate::collective::{AllreduceAlgo, ReduceOp};
use crate::context::NodeContext;

/// DIGEST-style local-update specification: how many local gradient steps
/// run between consecutive gossip exchanges.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LocalUpdateSpec {
    /// Local steps per gossip exchange (`H >= 1`; 1 = gossip every step).
    pub local_steps: usize,
}

impl LocalUpdateSpec {
    /// `H` local steps per gossip exchange.
    pub fn new(local_steps: usize) -> Self {
        assert!(local_steps >= 1, "local_steps must be >= 1");
        LocalUpdateSpec { local_steps }
    }

    /// Gossip on every step (the classical synchronous schedule).
    pub fn every_step() -> Self {
        LocalUpdateSpec { local_steps: 1 }
    }
}

/// Periodic global allreduce, folded into the schedule layer. This *is*
/// the old `PeriodicGlobalAveraging` logic — the wrapper now delegates
/// here, so the replace-`x`-by-the-global-average rule exists once.
#[derive(Debug, Clone, Copy)]
pub struct GlobalSync {
    period: usize,
    algo: AllreduceAlgo,
    iter: usize,
}

impl GlobalSync {
    /// Globally average every `period` completed steps (`period > 0`).
    pub fn new(period: usize, algo: AllreduceAlgo) -> Self {
        assert!(period > 0);
        GlobalSync { period, algo, iter: 0 }
    }

    /// The configured period.
    pub fn period(&self) -> usize {
        self.period
    }

    /// Advance one completed optimizer step; when the period elapses,
    /// replace `x` by the global average. Returns whether a sync ran.
    pub fn after_step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>) -> anyhow::Result<bool> {
        self.iter += 1;
        if self.iter % self.period == 0 {
            *x = ctx.allreduce(x, ReduceOp::Average, self.algo)?;
            return Ok(true);
        }
        Ok(false)
    }
}

/// When the communication phases of a pipelined optimizer run.
#[derive(Debug, Clone, Copy)]
pub struct CommSchedule {
    local: LocalUpdateSpec,
    global: Option<GlobalSync>,
}

impl CommSchedule {
    /// Gossip every step, never sync globally.
    pub fn every_step() -> Self {
        CommSchedule { local: LocalUpdateSpec::every_step(), global: None }
    }

    /// Gossip every `local_steps` steps (DIGEST-style local updates).
    pub fn local_updates(local_steps: usize) -> Self {
        CommSchedule { local: LocalUpdateSpec::new(local_steps), global: None }
    }

    /// Add a periodic global allreduce every `period` completed steps.
    pub fn with_global_sync(mut self, period: usize, algo: AllreduceAlgo) -> Self {
        self.global = Some(GlobalSync::new(period, algo));
        self
    }

    /// Whether iteration `iter` (0-based) ends with a gossip exchange.
    pub fn gossip_due(&self, iter: usize) -> bool {
        (iter + 1) % self.local.local_steps == 0
    }

    /// Local steps per gossip exchange (`H`).
    pub fn local_steps(&self) -> usize {
        self.local.local_steps
    }

    /// Mutable access to the global-sync state, if configured.
    pub(crate) fn global_mut(&mut self) -> Option<&mut GlobalSync> {
        self.global.as_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_step_gossips_always() {
        let s = CommSchedule::every_step();
        assert!((0..10).all(|i| s.gossip_due(i)));
        assert_eq!(s.local_steps(), 1);
    }

    #[test]
    fn local_updates_gossip_every_h() {
        let s = CommSchedule::local_updates(4);
        let due: Vec<usize> = (0..12).filter(|&i| s.gossip_due(i)).collect();
        assert_eq!(due, vec![3, 7, 11]);
    }

    #[test]
    #[should_panic(expected = "local_steps")]
    fn zero_local_steps_rejected() {
        LocalUpdateSpec::new(0);
    }
}
