//! Decentralized ADMM (consensus form) — the first non-SGD family on the
//! pipeline's communication substrate.
//!
//! Each node holds a primal iterate `x`, a consensus estimate `a` and a
//! scaled dual `v`, and repeats (SNIPPETS.md §2/§3 idiom, `ρ = α·|N_i|`):
//!
//! 1. **Proximal step** — minimize the local loss plus
//!    `ρ/2 ‖x − v‖²`-style coupling. With [`ProxKind::Linearized`] the
//!    loss is linearized at the previous prox output (gradient `g`), so
//!    the solve is closed-form: `x ⟵ (x/η − g + ρ v) / (1/η + ρ)`.
//!    [`ProxKind::Quadratic`] assumes the caller's gradient is that of a
//!    unit-curvature quadratic (`g = x − c`), giving the exact prox
//!    `x ⟵ (c + ρ v) / (1 + ρ)` — useful for property tests where the
//!    fixed point is known analytically.
//! 2. **Consensus combine** — `a⁺ = ½ x + ½·(mean of in-neighbor x)`, a
//!    neighbor allreduce with explicit weights (self ½, each of the `n`
//!    in-neighbors ½/n).
//! 3. **Dual ascent** — `v ⟵ v + a⁺ − a`.
//!
//! The iterate exposed to the driver stays the *prox output* `x`; the
//! consensus trace `a` is internal state. On connected graphs the mean
//! iterate converges to the consensus optimum (probed end to end on the
//! linear-regression workload by `examples/algos_probe.rs`; the ring
//! fixed-point property test lives in `tests/optimizers.rs`).

use crate::collective::neighbor::NeighborWeights;
use crate::context::NodeContext;

use super::DecentralizedOptimizer;

/// Pull row `a⁺ = ½ x_self + ½·mean(in-neighbor x)` over the current
/// static topology (fault-healed neighbor sets included).
fn weights_half_mean(ctx: &NodeContext) -> NeighborWeights {
    let ins = ctx.in_neighbor_ranks();
    let outs = ctx.out_neighbor_ranks();
    let n = ins.len().max(1);
    NeighborWeights::push_pull(
        0.5,
        ins.into_iter().map(|j| (j, 0.5 / n as f64)).collect(),
        outs.into_iter().map(|d| (d, 1.0)).collect(),
    )
}

/// Which proximal subproblem the ADMM step solves in closed form.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ProxKind {
    /// Linearize the local loss at the previous iterate; `eta` is the
    /// proximal step size of the resulting gradient-style solve.
    Linearized {
        /// Proximal step size `η`.
        eta: f32,
    },
    /// Exact prox of a unit-curvature quadratic (`g = x − c`).
    Quadratic,
}

/// Decentralized consensus ADMM over the static neighbor topology.
pub struct DecentralizedAdmm {
    /// Dual coupling strength per neighbor: `ρ = α·|N_i|`.
    pub alpha: f32,
    /// Proximal subproblem variant.
    pub prox: ProxKind,
    a: Option<Vec<f32>>,
    v: Option<Vec<f32>>,
    rounds: usize,
}

impl DecentralizedAdmm {
    /// New decentralized ADMM optimizer.
    pub fn new(alpha: f32, prox: ProxKind) -> Self {
        DecentralizedAdmm { alpha, prox, a: None, v: None, rounds: 0 }
    }

    /// The current consensus estimate `a` (None before the first step).
    pub fn consensus(&self) -> Option<&Vec<f32>> {
        self.a.as_ref()
    }
}

impl DecentralizedOptimizer for DecentralizedAdmm {
    fn step(&mut self, ctx: &mut NodeContext, x: &mut Vec<f32>, grad: &[f32]) -> anyhow::Result<()> {
        let d = x.len();
        let n_in = ctx.in_neighbor_ranks().len().max(1);
        let rho = self.alpha * n_in as f32;
        if self.a.is_none() {
            self.a = Some(vec![0.0; d]);
            self.v = Some(vec![0.0; d]);
        }
        // 1. Proximal step (in place on the exposed iterate).
        {
            let v = self.v.as_ref().unwrap();
            match self.prox {
                ProxKind::Linearized { eta } => {
                    let inv = 1.0 / eta;
                    for ((xi, g), vi) in x.iter_mut().zip(grad).zip(v.iter()) {
                        *xi = (*xi * inv - g + rho * vi) / (inv + rho);
                    }
                }
                ProxKind::Quadratic => {
                    for ((xi, g), vi) in x.iter_mut().zip(grad).zip(v.iter()) {
                        let c = *xi - g;
                        *xi = (c + rho * vi) / (1.0 + rho);
                    }
                }
            }
        }
        // 2. Consensus combine: a⁺ = ½ x + ½·mean(in-neighbor x).
        let w = weights_half_mean(ctx);
        let a_new = ctx.neighbor_allreduce_dynamic_stream(x, &w, 0)?;
        self.rounds += 1;
        // 3. Dual ascent: v += a⁺ − a.
        {
            let v = self.v.as_mut().unwrap();
            let a = self.a.as_ref().unwrap();
            for ((vi, an), ao) in v.iter_mut().zip(&a_new).zip(a.iter()) {
                *vi += an - ao;
            }
        }
        if let Some(old) = self.a.replace(a_new) {
            ctx.recycle(old);
        }
        Ok(())
    }

    fn name(&self) -> String {
        match self.prox {
            ProxKind::Linearized { eta } => format!("DecentralizedADMM(linearized, eta={eta})"),
            ProxKind::Quadratic => "DecentralizedADMM(quadratic)".into(),
        }
    }

    fn comm_rounds(&self) -> usize {
        self.rounds
    }
}
