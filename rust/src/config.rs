//! Model presets and workload descriptions.
//!
//! Two families:
//! - [`ModelPreset`] — real transformer-LM configurations that `aot.py`
//!   lowers to executable artifacts (the E2E training path).
//! - [`WorkloadModel`] — *cost-model* descriptions of the paper's benchmark
//!   DNNs (ResNet-50, VGG-16, BERT-large): parameter count, per-layer
//!   bucket sizes and per-sample FLOPs. The throughput benches (Fig. 12,
//!   Table II) time the communication schedule of these workloads on the
//!   virtual network without executing the actual DNN — the substitution
//!   documented in DESIGN.md.

/// A transformer-LM configuration matching `python/compile/model.py`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelPreset {
    /// Preset name (`nano`, `tiny`, `small`).
    pub name: &'static str,
    /// Vocabulary size.
    pub vocab: usize,
    /// Model (embedding) dimension.
    pub d_model: usize,
    /// Transformer block count.
    pub n_layers: usize,
    /// Attention head count.
    pub n_heads: usize,
    /// Sequence length.
    pub seq: usize,
    /// Per-node batch size.
    pub batch: usize,
}

impl ModelPreset {
    /// Look up a preset by its `name` field.
    pub fn by_name(name: &str) -> Option<ModelPreset> {
        PRESETS.iter().find(|p| p.name == name).cloned()
    }

    /// Parameter count (must agree with `model.py::param_specs`).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let ff = 4 * d;
        let per_layer = 2 * d            // ln1 scale+bias
            + 4 * d * d                  // wq wk wv wo
            + 2 * d                      // ln2
            + d * ff + ff                // w1 b1
            + ff * d + d;                // w2 b2
        self.vocab * d                   // embed
            + self.seq * d               // pos
            + self.n_layers * per_layer
            + 2 * d                      // final ln
            + d * self.vocab             // head
    }

    /// Approximate forward+backward FLOPs per step (6 * params * tokens,
    /// the standard transformer estimate).
    pub fn flops_per_step(&self) -> f64 {
        6.0 * self.param_count() as f64 * (self.batch * self.seq) as f64
    }

    /// Artifact base name (`train_step_<name>`).
    pub fn artifact(&self) -> String {
        format!("train_step_{}", self.name)
    }
}

/// The presets `aot.py` knows how to lower. Keep in sync with model.py.
pub const PRESETS: &[ModelPreset] = &[
    ModelPreset { name: "nano", vocab: 96, d_model: 32, n_layers: 1, n_heads: 2, seq: 32, batch: 4 },
    ModelPreset { name: "tiny", vocab: 96, d_model: 64, n_layers: 2, n_heads: 2, seq: 64, batch: 8 },
    ModelPreset { name: "small", vocab: 96, d_model: 128, n_layers: 4, n_heads: 4, seq: 128, batch: 8 },
];

/// Cost-model description of a benchmark DNN (paper §VII-B).
#[derive(Debug, Clone)]
pub struct WorkloadModel {
    /// Benchmark DNN name (paper Fig. 12 / Table II rows).
    pub name: &'static str,
    /// Total parameters.
    pub params: usize,
    /// Per-layer parameter buckets, output-side first (the order gradients
    /// become available during backprop).
    pub layer_params: Vec<usize>,
    /// Forward+backward FLOPs per sample.
    pub flops_per_sample: f64,
    /// Per-GPU batch size used in the paper's Fig. 12.
    pub batch: usize,
}

impl WorkloadModel {
    /// ResNet-50: ~23 M params (paper quotes "around 23 million"), batch 64.
    pub fn resnet50() -> Self {
        // 16 residual stages + stem + fc, parameter mass concentrated late.
        let layer_params = geometric_buckets(23_000_000, 18, 1.35);
        WorkloadModel {
            name: "ResNet-50",
            params: 23_000_000,
            layer_params,
            flops_per_sample: 3.8e9 * 3.0, // fwd 3.8 GFLOPs, bwd ~2x
            batch: 64,
        }
    }

    /// VGG-16: 138 M params, batch 32.
    pub fn vgg16() -> Self {
        let layer_params = geometric_buckets(138_000_000, 16, 1.8);
        WorkloadModel {
            name: "VGG-16",
            params: 138_000_000,
            layer_params,
            flops_per_sample: 15.5e9 * 3.0,
            batch: 32,
        }
    }

    /// BERT-large: 345 M params, per-GPU tokens 4096 (batch 8 x seq 512).
    pub fn bert_large() -> Self {
        // 24 uniform encoder layers + embeddings.
        let mut layer_params = vec![345_000_000 / 26; 24];
        layer_params.push(345_000_000 / 13); // embeddings
        layer_params.push(345_000_000 - layer_params.iter().sum::<usize>());
        WorkloadModel {
            name: "BERT-large",
            params: 345_000_000,
            layer_params,
            flops_per_sample: 6.0 * 345e6 * 512.0, // 6*N*T per sample (seq 512)
            batch: 8,
        }
    }

    /// The paper's three benchmark workloads.
    pub fn all() -> Vec<WorkloadModel> {
        vec![Self::resnet50(), Self::vgg16(), Self::bert_large()]
    }

    /// Message size in bytes for a full-gradient exchange (f32).
    pub fn message_bytes(&self) -> usize {
        self.params * 4
    }

    /// Per-step compute time on a device with `device_flops` peak and
    /// `efficiency` utilization.
    pub fn step_compute_time(&self, device_flops: f64, efficiency: f64) -> f64 {
        self.flops_per_sample * self.batch as f64 / (device_flops * efficiency)
    }
}

/// Algorithm selection and hyper-parameters for the optimizer registry
/// ([`crate::optim::make_optimizer_cfg`]) — the surface behind
/// `bfrun train --algo/--lr/--beta/--order/--local-steps/
/// --global-period/--weighting/--admm-alpha/--admm-eta`.
#[derive(Debug, Clone, PartialEq)]
pub struct AlgoConfig {
    /// Registry name (`atc`, `awc`, `dsgd`, `local-sgd`, `digest`,
    /// `dmsgd-vanilla`, `dmsgd`, `qg-dmsgd`, `ed`, `gt`, `psgt`, `admm`,
    /// `psgd`).
    pub algo: String,
    /// Step size `γ`.
    pub gamma: f32,
    /// Momentum coefficient `β` (momentum families).
    pub beta: f32,
    /// Communication/adaptation order (`atc` / `awc`) for the momentum
    /// family; the plain D-SGD names `atc`/`awc` imply their own order.
    pub order: String,
    /// Local steps per gossip exchange (DIGEST `H`; 1 = every step).
    pub local_steps: usize,
    /// Global allreduce every `global_period` steps (0 = never).
    pub global_period: usize,
    /// Neighbor weighting policy (`static`, `al-dsgd`).
    pub weighting: String,
    /// ADMM dual coupling strength `α` (`ρ = α·|N_i|`).
    pub admm_alpha: f32,
    /// ADMM linearized-prox step size `η`.
    pub admm_eta: f32,
}

impl Default for AlgoConfig {
    fn default() -> Self {
        AlgoConfig {
            algo: "atc".into(),
            gamma: 0.05,
            beta: 0.9,
            order: "atc".into(),
            local_steps: 1,
            global_period: 0,
            weighting: "static".into(),
            admm_alpha: 2.0,
            admm_eta: 0.05,
        }
    }
}

impl AlgoConfig {
    /// Read the registry surface from parsed CLI flags; absent flags keep
    /// the [`Default`] values.
    pub fn from_args(args: &crate::cli::Args) -> anyhow::Result<AlgoConfig> {
        let d = AlgoConfig::default();
        Ok(AlgoConfig {
            algo: args.str_or("algo", &d.algo).to_string(),
            gamma: args.f64_or("lr", f64::from(d.gamma))? as f32,
            beta: args.f64_or("beta", f64::from(d.beta))? as f32,
            order: args.choice_or("order", &d.order, &["atc", "awc"])?.to_string(),
            local_steps: args.usize_or("local-steps", d.local_steps)?,
            global_period: args.usize_or("global-period", d.global_period)?,
            weighting: args
                .choice_or("weighting", &d.weighting, &["static", "al-dsgd", "aldsgd"])?
                .to_string(),
            admm_alpha: args.f64_or("admm-alpha", f64::from(d.admm_alpha))? as f32,
            admm_eta: args.f64_or("admm-eta", f64::from(d.admm_eta))? as f32,
        })
    }
}

/// Which backend-portable workload a TCP worker process runs
/// (`transport::portable::{run_consensus, run_dsgd}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PortableWorkload {
    /// Iterated consensus `x <- W x`.
    Consensus,
    /// DSGD with ATC ordering on the shared synthetic regression problem.
    Dsgd,
}

impl PortableWorkload {
    /// Stable name used on the CLI and in the env handshake.
    pub fn as_str(&self) -> &'static str {
        match self {
            PortableWorkload::Consensus => "consensus",
            PortableWorkload::Dsgd => "dsgd",
        }
    }

    /// Inverse of [`PortableWorkload::as_str`].
    pub fn parse(s: &str) -> anyhow::Result<PortableWorkload> {
        match s {
            "consensus" => Ok(PortableWorkload::Consensus),
            "dsgd" => Ok(PortableWorkload::Dsgd),
            other => anyhow::bail!("unknown workload '{other}' (expected consensus|dsgd)"),
        }
    }
}

/// Description of a multi-process TCP job, shipped from the `bfrun`
/// parent to each worker through environment variables (DESIGN.md
/// §Transport backends: the launch handshake).
///
/// The parent serializes the spec with [`TcpJobSpec::to_env`]; a child
/// detects worker mode via [`TcpJobSpec::ENV_WORKER`] and reconstructs
/// everything with [`TcpJobSpec::from_lookup`]. Round-tripping is tested
/// here so the two directions cannot drift apart.
#[derive(Debug, Clone, PartialEq)]
pub struct TcpJobSpec {
    /// Which portable workload to run.
    pub workload: PortableWorkload,
    /// Process count (one OS process per rank).
    pub nodes: usize,
    /// Iteration count.
    pub iters: usize,
    /// Tensor dimension.
    pub dim: usize,
    /// Rows per rank (DSGD only).
    pub rows: usize,
    /// DSGD step size.
    pub gamma: f32,
    /// Topology name (`topology::builders::by_name`).
    pub topology: String,
    /// Per-receive wall deadline in seconds (0 = no deadline).
    pub deadline_secs: f64,
    /// Optional crash injection: `(rank, at_iter)`.
    pub kill: Option<(usize, usize)>,
}

/// What a worker process reads back from its environment: its rank, the
/// rendezvous port (absent for rank 0, which *owns* the rendezvous), and
/// the job spec.
#[derive(Debug, Clone, PartialEq)]
pub struct TcpWorkerSetup {
    /// This process's rank.
    pub rank: usize,
    /// Rank 0's rendezvous port (`None` when `rank == 0`).
    pub port: Option<u16>,
    /// The job description, identical across all ranks.
    pub spec: TcpJobSpec,
}

impl TcpJobSpec {
    /// Sentinel: set (to any value) in a child's environment to route
    /// `main` into the worker entry point instead of the CLI.
    pub const ENV_WORKER: &'static str = "BF_TCP_WORKER";
    /// Rendezvous port env var (set for ranks >= 1 only).
    pub const ENV_PORT: &'static str = "BF_PORT";

    /// Serialize for one child process. `port` is `None` for rank 0
    /// (which binds the rendezvous itself and prints the port on stdout)
    /// and `Some` for every other rank.
    pub fn to_env(&self, rank: usize, port: Option<u16>) -> Vec<(String, String)> {
        let mut vars = vec![
            (Self::ENV_WORKER.into(), "1".into()),
            ("BF_RANK".into(), rank.to_string()),
            ("BF_SIZE".into(), self.nodes.to_string()),
            ("BF_JOB".into(), self.workload.as_str().into()),
            ("BF_ITERS".into(), self.iters.to_string()),
            ("BF_DIM".into(), self.dim.to_string()),
            ("BF_ROWS".into(), self.rows.to_string()),
            ("BF_GAMMA".into(), self.gamma.to_string()),
            ("BF_TOPOLOGY".into(), self.topology.clone()),
            ("BF_DEADLINE_SECS".into(), self.deadline_secs.to_string()),
        ];
        if let Some(p) = port {
            vars.push((Self::ENV_PORT.into(), p.to_string()));
        }
        if let Some((kr, ka)) = self.kill {
            vars.push(("BF_KILL_RANK".into(), kr.to_string()));
            vars.push(("BF_KILL_AT".into(), ka.to_string()));
        }
        vars
    }

    /// Reconstruct a worker's setup from a key -> value lookup (the
    /// process environment in production, a map in tests).
    pub fn from_lookup(get: impl Fn(&str) -> Option<String>) -> anyhow::Result<TcpWorkerSetup> {
        fn req<T: std::str::FromStr>(
            get: &impl Fn(&str) -> Option<String>,
            key: &str,
        ) -> anyhow::Result<T>
        where
            T::Err: std::fmt::Display,
        {
            let raw = get(key).ok_or_else(|| anyhow::anyhow!("missing env var {key}"))?;
            raw.parse().map_err(|e| anyhow::anyhow!("{key}={raw}: {e}"))
        }
        let rank: usize = req(&get, "BF_RANK")?;
        let port = match get(Self::ENV_PORT) {
            None => None,
            Some(raw) => {
                Some(raw.parse::<u16>().map_err(|e| anyhow::anyhow!("BF_PORT={raw}: {e}"))?)
            }
        };
        anyhow::ensure!(
            (rank == 0) == port.is_none(),
            "BF_PORT must be set exactly when BF_RANK >= 1 (rank={rank}, port={port:?})",
        );
        let kill = match (get("BF_KILL_RANK"), get("BF_KILL_AT")) {
            (None, None) => None,
            (Some(_), None) | (None, Some(_)) => {
                anyhow::bail!("BF_KILL_RANK and BF_KILL_AT must be set together")
            }
            (Some(_), Some(_)) => {
                Some((req(&get, "BF_KILL_RANK")?, req(&get, "BF_KILL_AT")?))
            }
        };
        let spec = TcpJobSpec {
            workload: PortableWorkload::parse(
                &get("BF_JOB").ok_or_else(|| anyhow::anyhow!("missing env var BF_JOB"))?,
            )?,
            nodes: req(&get, "BF_SIZE")?,
            iters: req(&get, "BF_ITERS")?,
            dim: req(&get, "BF_DIM")?,
            rows: req(&get, "BF_ROWS")?,
            gamma: req(&get, "BF_GAMMA")?,
            topology: get("BF_TOPOLOGY")
                .ok_or_else(|| anyhow::anyhow!("missing env var BF_TOPOLOGY"))?,
            deadline_secs: req(&get, "BF_DEADLINE_SECS")?,
            kill,
        };
        anyhow::ensure!(rank < spec.nodes, "BF_RANK {rank} out of range for BF_SIZE");
        Ok(TcpWorkerSetup { rank, port, spec })
    }
}

/// Split `total` into `k` buckets with geometric ratio `r` (later buckets
/// larger), summing exactly to `total`.
fn geometric_buckets(total: usize, k: usize, r: f64) -> Vec<usize> {
    let mut weights: Vec<f64> = (0..k).map(|i| r.powi(i as i32)).collect();
    let s: f64 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= s;
    }
    let mut out: Vec<usize> = weights.iter().map(|w| (w * total as f64) as usize).collect();
    let assigned: usize = out.iter().sum();
    *out.last_mut().unwrap() += total - assigned;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolvable_by_name() {
        assert!(ModelPreset::by_name("tiny").is_some());
        assert!(ModelPreset::by_name("nope").is_none());
    }

    #[test]
    fn param_count_formula_sane() {
        let p = ModelPreset::by_name("tiny").unwrap();
        let count = p.param_count();
        // embed + head dominate at this size: 96*64*2 = 12288 plus layers.
        assert!(count > 50_000 && count < 500_000, "count={count}");
        assert!(p.flops_per_step() > 1e6);
    }

    #[test]
    fn workload_buckets_sum_to_total() {
        for w in WorkloadModel::all() {
            let sum: usize = w.layer_params.iter().sum();
            assert_eq!(sum, w.params, "{}", w.name);
            assert!(w.layer_params.iter().all(|&b| b > 0), "{}", w.name);
        }
    }

    #[test]
    fn workload_params_match_paper() {
        assert_eq!(WorkloadModel::resnet50().params, 23_000_000);
        assert_eq!(WorkloadModel::vgg16().params, 138_000_000);
        assert_eq!(WorkloadModel::bert_large().params, 345_000_000);
    }

    fn job() -> TcpJobSpec {
        TcpJobSpec {
            workload: PortableWorkload::Dsgd,
            nodes: 4,
            iters: 25,
            dim: 64,
            rows: 16,
            gamma: 0.05,
            topology: "ring".into(),
            deadline_secs: 10.0,
            kill: Some((2, 3)),
        }
    }

    fn lookup(vars: &[(String, String)]) -> impl Fn(&str) -> Option<String> + '_ {
        move |k| vars.iter().find(|(key, _)| key == k).map(|(_, v)| v.clone())
    }

    #[test]
    fn tcp_job_env_round_trips() {
        let spec = job();
        // Rank 0: no BF_PORT.
        let vars = spec.to_env(0, None);
        let setup = TcpJobSpec::from_lookup(lookup(&vars)).unwrap();
        assert_eq!(setup, TcpWorkerSetup { rank: 0, port: None, spec: spec.clone() });
        // Rank 2: BF_PORT present.
        let vars = spec.to_env(2, Some(40123));
        let setup = TcpJobSpec::from_lookup(lookup(&vars)).unwrap();
        assert_eq!(setup, TcpWorkerSetup { rank: 2, port: Some(40123), spec });
    }

    #[test]
    fn tcp_job_env_rejects_inconsistency() {
        let spec = job();
        // Rank 1 without a port is a launch bug, not a default.
        assert!(TcpJobSpec::from_lookup(lookup(&spec.to_env(1, None))).is_err());
        // Rank 0 with a port likewise.
        assert!(TcpJobSpec::from_lookup(lookup(&spec.to_env(0, Some(9)))).is_err());
        // Half a kill spec is rejected.
        let mut vars = spec.to_env(0, None);
        vars.retain(|(k, _)| k != "BF_KILL_AT");
        assert!(TcpJobSpec::from_lookup(lookup(&vars)).is_err());
        // Out-of-range rank is rejected.
        let mut vars = spec.to_env(3, Some(9));
        for (k, v) in vars.iter_mut() {
            if k == "BF_RANK" {
                *v = "7".into();
            }
        }
        assert!(TcpJobSpec::from_lookup(lookup(&vars)).is_err());
    }

    #[test]
    fn workload_names_round_trip() {
        for w in [PortableWorkload::Consensus, PortableWorkload::Dsgd] {
            assert_eq!(PortableWorkload::parse(w.as_str()).unwrap(), w);
        }
        assert!(PortableWorkload::parse("blob").is_err());
    }

    #[test]
    fn algo_config_from_args() {
        let args = crate::cli::Args::parse(
            "--algo digest --local-steps 8 --weighting al-dsgd --lr 0.08 --global-period 50"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let cfg = AlgoConfig::from_args(&args).unwrap();
        assert_eq!(cfg.algo, "digest");
        assert_eq!(cfg.local_steps, 8);
        assert_eq!(cfg.weighting, "al-dsgd");
        assert_eq!(cfg.global_period, 50);
        assert!((cfg.gamma - 0.08).abs() < 1e-6);
        // Untouched fields keep defaults.
        assert_eq!(cfg.order, "atc");
        assert_eq!(AlgoConfig::from_args(&crate::cli::Args::default()).unwrap(),
                   AlgoConfig::default());
        // Bad weighting is rejected at parse time.
        let bad = crate::cli::Args::parse(
            ["--weighting".to_string(), "softmax".to_string()],
        )
        .unwrap();
        assert!(AlgoConfig::from_args(&bad).is_err());
    }

    #[test]
    fn compute_time_scales_with_flops() {
        let r = WorkloadModel::resnet50();
        let b = WorkloadModel::bert_large();
        let dev = 125e12; // V100 bf16 peak
        assert!(b.step_compute_time(dev, 0.4) > r.step_compute_time(dev, 0.4));
    }
}
