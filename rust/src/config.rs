//! Model presets and workload descriptions.
//!
//! Two families:
//! - [`ModelPreset`] — real transformer-LM configurations that `aot.py`
//!   lowers to executable artifacts (the E2E training path).
//! - [`WorkloadModel`] — *cost-model* descriptions of the paper's benchmark
//!   DNNs (ResNet-50, VGG-16, BERT-large): parameter count, per-layer
//!   bucket sizes and per-sample FLOPs. The throughput benches (Fig. 12,
//!   Table II) time the communication schedule of these workloads on the
//!   virtual network without executing the actual DNN — the substitution
//!   documented in DESIGN.md.

/// A transformer-LM configuration matching `python/compile/model.py`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelPreset {
    /// Preset name (`nano`, `tiny`, `small`).
    pub name: &'static str,
    /// Vocabulary size.
    pub vocab: usize,
    /// Model (embedding) dimension.
    pub d_model: usize,
    /// Transformer block count.
    pub n_layers: usize,
    /// Attention head count.
    pub n_heads: usize,
    /// Sequence length.
    pub seq: usize,
    /// Per-node batch size.
    pub batch: usize,
}

impl ModelPreset {
    /// Look up a preset by its `name` field.
    pub fn by_name(name: &str) -> Option<ModelPreset> {
        PRESETS.iter().find(|p| p.name == name).cloned()
    }

    /// Parameter count (must agree with `model.py::param_specs`).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let ff = 4 * d;
        let per_layer = 2 * d            // ln1 scale+bias
            + 4 * d * d                  // wq wk wv wo
            + 2 * d                      // ln2
            + d * ff + ff                // w1 b1
            + ff * d + d;                // w2 b2
        self.vocab * d                   // embed
            + self.seq * d               // pos
            + self.n_layers * per_layer
            + 2 * d                      // final ln
            + d * self.vocab             // head
    }

    /// Approximate forward+backward FLOPs per step (6 * params * tokens,
    /// the standard transformer estimate).
    pub fn flops_per_step(&self) -> f64 {
        6.0 * self.param_count() as f64 * (self.batch * self.seq) as f64
    }

    /// Artifact base name (`train_step_<name>`).
    pub fn artifact(&self) -> String {
        format!("train_step_{}", self.name)
    }
}

/// The presets `aot.py` knows how to lower. Keep in sync with model.py.
pub const PRESETS: &[ModelPreset] = &[
    ModelPreset { name: "nano", vocab: 96, d_model: 32, n_layers: 1, n_heads: 2, seq: 32, batch: 4 },
    ModelPreset { name: "tiny", vocab: 96, d_model: 64, n_layers: 2, n_heads: 2, seq: 64, batch: 8 },
    ModelPreset { name: "small", vocab: 96, d_model: 128, n_layers: 4, n_heads: 4, seq: 128, batch: 8 },
];

/// Cost-model description of a benchmark DNN (paper §VII-B).
#[derive(Debug, Clone)]
pub struct WorkloadModel {
    /// Benchmark DNN name (paper Fig. 12 / Table II rows).
    pub name: &'static str,
    /// Total parameters.
    pub params: usize,
    /// Per-layer parameter buckets, output-side first (the order gradients
    /// become available during backprop).
    pub layer_params: Vec<usize>,
    /// Forward+backward FLOPs per sample.
    pub flops_per_sample: f64,
    /// Per-GPU batch size used in the paper's Fig. 12.
    pub batch: usize,
}

impl WorkloadModel {
    /// ResNet-50: ~23 M params (paper quotes "around 23 million"), batch 64.
    pub fn resnet50() -> Self {
        // 16 residual stages + stem + fc, parameter mass concentrated late.
        let layer_params = geometric_buckets(23_000_000, 18, 1.35);
        WorkloadModel {
            name: "ResNet-50",
            params: 23_000_000,
            layer_params,
            flops_per_sample: 3.8e9 * 3.0, // fwd 3.8 GFLOPs, bwd ~2x
            batch: 64,
        }
    }

    /// VGG-16: 138 M params, batch 32.
    pub fn vgg16() -> Self {
        let layer_params = geometric_buckets(138_000_000, 16, 1.8);
        WorkloadModel {
            name: "VGG-16",
            params: 138_000_000,
            layer_params,
            flops_per_sample: 15.5e9 * 3.0,
            batch: 32,
        }
    }

    /// BERT-large: 345 M params, per-GPU tokens 4096 (batch 8 x seq 512).
    pub fn bert_large() -> Self {
        // 24 uniform encoder layers + embeddings.
        let mut layer_params = vec![345_000_000 / 26; 24];
        layer_params.push(345_000_000 / 13); // embeddings
        layer_params.push(345_000_000 - layer_params.iter().sum::<usize>());
        WorkloadModel {
            name: "BERT-large",
            params: 345_000_000,
            layer_params,
            flops_per_sample: 6.0 * 345e6 * 512.0, // 6*N*T per sample (seq 512)
            batch: 8,
        }
    }

    /// The paper's three benchmark workloads.
    pub fn all() -> Vec<WorkloadModel> {
        vec![Self::resnet50(), Self::vgg16(), Self::bert_large()]
    }

    /// Message size in bytes for a full-gradient exchange (f32).
    pub fn message_bytes(&self) -> usize {
        self.params * 4
    }

    /// Per-step compute time on a device with `device_flops` peak and
    /// `efficiency` utilization.
    pub fn step_compute_time(&self, device_flops: f64, efficiency: f64) -> f64 {
        self.flops_per_sample * self.batch as f64 / (device_flops * efficiency)
    }
}

/// Split `total` into `k` buckets with geometric ratio `r` (later buckets
/// larger), summing exactly to `total`.
fn geometric_buckets(total: usize, k: usize, r: f64) -> Vec<usize> {
    let mut weights: Vec<f64> = (0..k).map(|i| r.powi(i as i32)).collect();
    let s: f64 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= s;
    }
    let mut out: Vec<usize> = weights.iter().map(|w| (w * total as f64) as usize).collect();
    let assigned: usize = out.iter().sum();
    *out.last_mut().unwrap() += total - assigned;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolvable_by_name() {
        assert!(ModelPreset::by_name("tiny").is_some());
        assert!(ModelPreset::by_name("nope").is_none());
    }

    #[test]
    fn param_count_formula_sane() {
        let p = ModelPreset::by_name("tiny").unwrap();
        let count = p.param_count();
        // embed + head dominate at this size: 96*64*2 = 12288 plus layers.
        assert!(count > 50_000 && count < 500_000, "count={count}");
        assert!(p.flops_per_step() > 1e6);
    }

    #[test]
    fn workload_buckets_sum_to_total() {
        for w in WorkloadModel::all() {
            let sum: usize = w.layer_params.iter().sum();
            assert_eq!(sum, w.params, "{}", w.name);
            assert!(w.layer_params.iter().all(|&b| b > 0), "{}", w.name);
        }
    }

    #[test]
    fn workload_params_match_paper() {
        assert_eq!(WorkloadModel::resnet50().params, 23_000_000);
        assert_eq!(WorkloadModel::vgg16().params, 138_000_000);
        assert_eq!(WorkloadModel::bert_large().params, 345_000_000);
    }

    #[test]
    fn compute_time_scales_with_flops() {
        let r = WorkloadModel::resnet50();
        let b = WorkloadModel::bert_large();
        let dev = 125e12; // V100 bf16 peak
        assert!(b.step_compute_time(dev, 0.4) > r.step_compute_time(dev, 0.4));
    }
}
