//! Backend-portable workloads: the collectives and training loops that
//! run identically over any [`Backend`] — the in-memory [`SimBackend`]
//! or a real [`TcpBackend`] mesh of OS processes.
//!
//! The point of this module is the sim/tcp **parity contract**
//! (`rust/tests/tcp_parity.rs`, `examples/wallclock_probe.rs`): the
//! static `neighbor_allreduce` here reproduces the simulator's dense
//! path *arithmetic* exactly — same `pull_view` source order, same
//! ring-distance destination sort, same
//! [`crate::tensor::weighted_combine_blocked_into`] kernel, same f32
//! cast points — so a TCP job and a `run_spmd` job produce bitwise-equal
//! parameters, and the 1e-6 acceptance bound of ISSUE 8 holds with zero
//! slack lost to reimplementation drift. Data generators are shared for
//! the same reason: both sides call [`regression_data`] /
//! [`consensus_x0`], so cross-process comparisons never depend on a
//! duplicated constant.
//!
//! [`SimBackend`]: crate::transport::backend::SimBackend
//! [`TcpBackend`]: crate::transport::tcp::TcpBackend

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{PortableWorkload, TcpJobSpec};
use crate::rng::Rng;
use crate::simnet::faults::CommError;
use crate::tensor::{axpy, weighted_combine_blocked_into};
use crate::topology::builders;
use crate::topology::views::SparseViews;
use crate::transport::backend::{sim_backends, Backend};
use crate::transport::{make_tag, op_id};

/// Seed base for per-rank design matrices ([`regression_data`]).
const SEED_A: u64 = 0x5EED_0A11;
/// Seed for the shared ground-truth parameter vector.
const SEED_XSTAR: u64 = 0x5EED_57A8;
/// Seed base for per-rank label noise.
const SEED_NOISE: u64 = 0x5EED_B0B0;
/// Seed base for per-rank consensus initial vectors.
const SEED_X0: u64 = 0x5EED_C0A5;

/// One rank's static-topology communication pattern, precomputed once so
/// the per-round hot path allocates nothing topology-related.
#[derive(Debug, Clone)]
pub struct LocalTopology {
    /// `w_ii` from the weight matrix's pull view.
    pub self_weight: f64,
    /// In-neighbor `(rank, weight)` pairs, ascending by rank — the
    /// receive/combine order of the simulator's dense path.
    pub srcs: Vec<(usize, f64)>,
    /// Out-neighbor ranks sorted by ring distance `(d + n - rank) % n`,
    /// the paper §VI-B send order the simulator uses.
    pub dsts: Vec<usize>,
}

/// Build rank `rank`'s [`LocalTopology`] for a named topology
/// ([`builders::by_name`]) over `n` ranks.
pub fn local_topology(name: &str, n: usize, rank: usize) -> anyhow::Result<LocalTopology> {
    let (graph, weights) = builders::by_name(name, n)?;
    let views = SparseViews::from_matrix(&weights, &graph);
    let (self_weight, srcs) = views.pull_view(rank);
    let mut dsts = views.out_neighbors(rank).to_vec();
    dsts.sort_by_key(|&d| (d + n - rank) % n);
    Ok(LocalTopology { self_weight, srcs: srcs.to_vec(), dsts })
}

/// Static partial averaging over any [`Backend`] — the portable form of
/// the simulator's dense `neighbor_allreduce` (paper eq. (5)):
/// `x <- w_ii x + Σ_j w_ij x_j`. Fails fast with the backend's typed
/// [`CommError`] (no weight folding — failure handling is the caller's
/// policy at this layer).
pub fn neighbor_allreduce_portable<B: Backend>(
    backend: &mut B,
    topo: &LocalTopology,
    round: u32,
    data: &[f32],
    deadline: Option<Duration>,
) -> Result<Vec<f32>, CommError> {
    let tag = make_tag(op_id("portable.neighbor_allreduce"), round);
    let shared = Arc::new(data.to_vec());
    for &dst in &topo.dsts {
        backend.send(dst, tag, Arc::clone(&shared), 0.0)?;
    }
    let mut incoming: Vec<(f32, Arc<Vec<f32>>)> = Vec::with_capacity(topo.srcs.len());
    for &(src, w) in &topo.srcs {
        let m = backend.recv_match(src, tag, deadline)?;
        incoming.push((w as f32, m.payload));
    }
    let parts: Vec<&[f32]> = incoming.iter().map(|(_, y)| y.as_slice()).collect();
    let ws: Vec<f32> = incoming.iter().map(|(w, _)| *w).collect();
    let mut out = data.to_vec();
    weighted_combine_blocked_into(&mut out, topo.self_weight as f32, &parts, &ws);
    drop(parts);
    for (_, y) in incoming {
        backend.reclaim(y);
    }
    Ok(out)
}

/// Deterministic consensus start vector for `rank` (shared by every
/// runner that wants cross-backend comparability).
pub fn consensus_x0(rank: usize, dim: usize) -> Vec<f32> {
    Rng::new(SEED_X0 + rank as u64).normal_vec(dim)
}

/// Per-rank synthetic linear-regression data: design matrix `a`
/// (`rows x dim`, row-major) and labels `b = A x* + 0.1 ε`, with `x*`
/// shared across ranks and `A`, `ε` rank-specific — the heterogeneous
/// local objectives every DSGD experiment in this repo trains on.
pub fn regression_data(rank: usize, dim: usize, rows: usize) -> (Vec<f32>, Vec<f32>) {
    let a = Rng::new(SEED_A + rank as u64).normal_vec(rows * dim);
    let x_star = Rng::new(SEED_XSTAR).normal_vec(dim);
    let mut noise_rng = Rng::new(SEED_NOISE + rank as u64);
    let b: Vec<f32> = (0..rows)
        .map(|r| {
            let row = &a[r * dim..(r + 1) * dim];
            let clean: f32 = row.iter().zip(&x_star).map(|(ai, xi)| ai * xi).sum();
            clean + 0.1 * noise_rng.normal() as f32
        })
        .collect();
    (a, b)
}

/// Gradient of the local least-squares objective
/// `f(x) = (1/rows) ||A x - b||^2` into `grad` (no allocation).
pub fn local_grad(a: &[f32], b: &[f32], x: &[f32], grad: &mut [f32]) {
    let dim = x.len();
    let rows = b.len();
    grad.fill(0.0);
    for r in 0..rows {
        let row = &a[r * dim..(r + 1) * dim];
        let resid: f32 = row.iter().zip(x).map(|(ai, xi)| ai * xi).sum::<f32>() - b[r];
        let scale = 2.0 * resid / rows as f32;
        for (g, ai) in grad.iter_mut().zip(row) {
            *g += scale * ai;
        }
    }
}

/// Local least-squares loss `(1/rows) ||A x - b||^2`.
pub fn local_loss(a: &[f32], b: &[f32], x: &[f32]) -> f64 {
    let dim = x.len();
    let rows = b.len();
    let mut acc = 0.0f64;
    for r in 0..rows {
        let row = &a[r * dim..(r + 1) * dim];
        let resid = row.iter().zip(x).map(|(ai, xi)| (*ai as f64) * (*xi as f64)).sum::<f64>()
            - b[r] as f64;
        acc += resid * resid;
    }
    acc / rows as f64
}

/// Crash injection for the failure-path acceptance test: rank
/// [`KillSpec::rank`] abandons its sockets (no Goodbye — a model of
/// `kill -9`) just before iteration [`KillSpec::at_iter`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KillSpec {
    /// Rank that dies.
    pub rank: usize,
    /// Iteration before which it dies.
    pub at_iter: usize,
}

/// Parameters of a portable run (one struct so sim/tcp callers cannot
/// diverge on defaults).
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Iteration count.
    pub iters: usize,
    /// Tensor dimension.
    pub dim: usize,
    /// Rows per rank (DSGD only).
    pub rows: usize,
    /// DSGD step size.
    pub gamma: f32,
    /// Topology name for [`builders::by_name`].
    pub topology: String,
    /// Per-receive wall deadline.
    pub deadline: Option<Duration>,
    /// Optional crash injection.
    pub kill: Option<KillSpec>,
}

impl RunSpec {
    /// Build from the launch-protocol job description ([`TcpJobSpec`]) —
    /// the single conversion point shared by the TCP worker, the CLI's
    /// sim reference, and the parity tests, so a unit mix-up (seconds vs
    /// millis, say) cannot affect only one side of a comparison.
    pub fn from_job(job: &TcpJobSpec) -> RunSpec {
        let secs = job.deadline_secs;
        let deadline = (secs > 0.0).then(|| Duration::from_secs_f64(secs));
        RunSpec {
            iters: job.iters,
            dim: job.dim,
            rows: job.rows,
            gamma: job.gamma,
            topology: job.topology.clone(),
            deadline,
            kill: job.kill.map(|(rank, at_iter)| KillSpec { rank, at_iter }),
        }
    }
}

/// What a portable run produced on this rank.
#[derive(Debug, Clone)]
pub struct RunOutput {
    /// Final parameter vector.
    pub x: Vec<f32>,
    /// Payload bytes this rank sent ([`Backend::bytes_sent`]).
    pub bytes_sent: u64,
    /// Wall milliseconds per iteration.
    pub iter_ms: Vec<f64>,
}

/// If this rank is scheduled to die before `iter`, abandon the backend
/// and surface the typed self-crash error.
fn maybe_kill<B: Backend>(
    backend: &mut B,
    kill: Option<KillSpec>,
    iter: usize,
) -> Result<(), CommError> {
    if let Some(k) = kill {
        if k.rank == backend.rank() && iter == k.at_iter {
            backend.abandon();
            return Err(CommError::SelfCrash { rank: k.rank, at: iter as f64 });
        }
    }
    Ok(())
}

/// Iterated consensus (`x <- W x`) over any backend. Returns this rank's
/// final vector; all ranks converge toward the network mean.
pub fn run_consensus<B: Backend>(backend: &mut B, spec: &RunSpec) -> Result<RunOutput, CommError> {
    let topo = local_topology(&spec.topology, backend.size(), backend.rank())
        .expect("portable run over a known topology");
    let mut x = consensus_x0(backend.rank(), spec.dim);
    let mut iter_ms = Vec::with_capacity(spec.iters);
    for iter in 0..spec.iters {
        maybe_kill(backend, spec.kill, iter)?;
        let t0 = Instant::now();
        x = neighbor_allreduce_portable(backend, &topo, iter as u32, &x, spec.deadline)?;
        iter_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(RunOutput { x, bytes_sent: backend.bytes_sent(), iter_ms })
}

/// DSGD with ATC order (`x <- W (x - γ g)`, paper eq. (23)) on the
/// shared synthetic regression problem, starting from `x = 0`. The
/// half-step/combine sequence matches `optim::Dgd` exactly, so a
/// `run_spmd` job with `Dgd::new(γ, Atc, Static)` lands on bitwise the
/// same parameters.
pub fn run_dsgd<B: Backend>(backend: &mut B, spec: &RunSpec) -> Result<RunOutput, CommError> {
    let topo = local_topology(&spec.topology, backend.size(), backend.rank())
        .expect("portable run over a known topology");
    let (a, b) = regression_data(backend.rank(), spec.dim, spec.rows);
    let mut x = vec![0.0f32; spec.dim];
    let mut grad = vec![0.0f32; spec.dim];
    let mut iter_ms = Vec::with_capacity(spec.iters);
    for iter in 0..spec.iters {
        maybe_kill(backend, spec.kill, iter)?;
        let t0 = Instant::now();
        local_grad(&a, &b, &x, &mut grad);
        let mut half = x.clone();
        axpy(-spec.gamma, &grad, &mut half);
        x = neighbor_allreduce_portable(backend, &topo, iter as u32, &half, spec.deadline)?;
        iter_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(RunOutput { x, bytes_sent: backend.bytes_sent(), iter_ms })
}

/// Dispatch to the workload named by a [`PortableWorkload`].
pub fn run_workload<B: Backend>(
    backend: &mut B,
    workload: PortableWorkload,
    spec: &RunSpec,
) -> Result<RunOutput, CommError> {
    match workload {
        PortableWorkload::Consensus => run_consensus(backend, spec),
        PortableWorkload::Dsgd => run_dsgd(backend, spec),
    }
}

/// Run a portable workload on `n` in-process [`SimBackend`]s, one OS
/// thread per rank — the reference side of every sim/tcp parity check
/// (`rust/tests/tcp_parity.rs`, `examples/wallclock_probe.rs`, and the
/// `--backend tcp` CLI's `--verify` pass).
///
/// [`SimBackend`]: crate::transport::backend::SimBackend
pub fn run_sim_fleet(
    n: usize,
    workload: PortableWorkload,
    spec: &RunSpec,
) -> Vec<Result<RunOutput, CommError>> {
    let fleet = sim_backends(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = fleet
            .into_iter()
            .map(|mut b| s.spawn(move || run_workload(&mut b, workload, spec)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(iters: usize, dim: usize) -> RunSpec {
        RunSpec {
            iters,
            dim,
            rows: 8,
            gamma: 0.05,
            topology: "ring".into(),
            deadline: Some(Duration::from_secs(10)),
            kill: None,
        }
    }

    /// Drive all ranks of a portable run over SimBackends on threads.
    fn run_fleet<F>(n: usize, f: F) -> Vec<Result<RunOutput, CommError>>
    where
        F: Fn(&mut crate::transport::backend::SimBackend) -> Result<RunOutput, CommError>
            + Send
            + Sync,
    {
        let fleet = sim_backends(n);
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = fleet
                .into_iter()
                .map(|mut b| s.spawn(move || f(&mut b)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
        })
    }

    #[test]
    fn consensus_contracts_toward_the_mean() {
        let n = 4;
        let dim = 16;
        let x0s: Vec<Vec<f32>> = (0..n).map(|r| consensus_x0(r, dim)).collect();
        let mean: Vec<f64> = (0..dim)
            .map(|j| x0s.iter().map(|x| x[j] as f64).sum::<f64>() / n as f64)
            .collect();
        let outs = run_fleet(n, |b| run_consensus(b, &spec(30, dim)));
        for out in outs {
            let out = out.expect("consensus run failed");
            for (xi, mi) in out.x.iter().zip(&mean) {
                assert!((*xi as f64 - mi).abs() < 1e-4, "not contracted: {xi} vs {mi}");
            }
        }
    }

    #[test]
    fn dsgd_reduces_mean_loss() {
        let n = 4;
        let s = spec(40, 8);
        let outs = run_fleet(n, |b| run_dsgd(b, &s));
        let mut loss0 = 0.0;
        let mut loss1 = 0.0;
        let zeros = vec![0.0f32; s.dim];
        for (rank, out) in outs.into_iter().enumerate() {
            let out = out.expect("dsgd run failed");
            let (a, b) = regression_data(rank, s.dim, s.rows);
            loss0 += local_loss(&a, &b, &zeros);
            loss1 += local_loss(&a, &b, &out.x);
        }
        assert!(loss1 < loss0 * 0.5, "loss did not drop: {loss0} -> {loss1}");
    }

    #[test]
    fn killed_rank_surfaces_as_typed_errors() {
        let n = 4;
        let mut s = spec(20, 8);
        s.kill = Some(KillSpec { rank: 2, at_iter: 3 });
        let outs = run_fleet(n, |b| run_consensus(b, &s));
        let mut self_crashes = 0;
        let mut peer_downs = 0;
        for out in outs {
            match out {
                Err(CommError::SelfCrash { rank: 2, .. }) => self_crashes += 1,
                Err(CommError::PeerDown { .. }) => peer_downs += 1,
                other => panic!("expected typed failure, got {other:?}"),
            }
        }
        assert_eq!(self_crashes, 1);
        assert_eq!(peer_downs, n - 1, "every survivor observes PeerDown");
    }
}
