//! The transport backend abstraction (ISSUE 8 tentpole).
//!
//! [`Backend`] is the narrow waist between collectives and the machinery
//! that actually moves bytes: MPI-style tagged point-to-point sends and
//! receives, wall-clock deadline hooks, and payload-byte accounting. Two
//! implementations exist in-tree:
//!
//! - [`SimBackend`] (this module) wraps the in-memory [`Mailbox`] /
//!   [`Postman`] fabric. It *composes* the existing fabric rather than
//!   reimplementing it, so matching, stash order and delivery semantics
//!   are bitwise-identical to what `run_spmd` drives directly under both
//!   `ExecMode`s — the fabric is the same code either way.
//! - [`crate::transport::tcp::TcpBackend`] speaks the framed wire format
//!   of [`crate::transport::frame`] over per-peer persistent loopback/LAN
//!   sockets (DESIGN.md §Transport backends).
//!
//! The contract both must honor (and `rust/tests/tcp_parity.rs` checks):
//! a departed peer surfaces as [`CommError::PeerDown`], an expired wall
//! deadline as [`CommError::Timeout`], and `bytes_sent` counts *payload*
//! bytes only (`4 * elements`), excluding headers and control traffic, so
//! the number is comparable across backends and with
//! `NodeContext::bytes_sent`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::simnet::faults::CommError;
use crate::transport::{fabric, Mailbox, Message, Postman, Tag};

/// Payload bytes on the wire for a tensor of `nelems` f32 elements — the
/// one formula every byte counter in the crate shares (`NodeContext`,
/// [`SimBackend`], `TcpBackend`).
pub fn payload_nbytes(nelems: usize) -> u64 {
    (nelems * std::mem::size_of::<f32>()) as u64
}

/// Granularity of the wait/recheck loop inside blocking receives: how
/// often a parked receiver rechecks peer liveness and its deadline.
const WAIT_SLICE: Duration = Duration::from_millis(25);

/// A point-to-point transport endpoint owned by one rank.
///
/// Deadlines here are **wall-clock** (`Option<Duration>`, `None` = wait
/// forever modulo peer death) — this is the boundary where virtual time
/// ends. The virtual-time deadline machinery of `NodeContext` stays in
/// the simulator; real backends map socket timeouts onto the same typed
/// [`CommError`]s so callers handle failure identically on both.
pub trait Backend: Send {
    /// This endpoint's rank.
    fn rank(&self) -> usize;

    /// Number of ranks in the job.
    fn size(&self) -> usize;

    /// Send `payload` to `dst` under `tag`. `vtime` is the sender's
    /// virtual time, carried for trace comparability (real backends do
    /// not schedule by it). Fails with [`CommError::PeerDown`] when the
    /// destination has departed.
    fn send(
        &mut self,
        dst: usize,
        tag: Tag,
        payload: Arc<Vec<f32>>,
        vtime: f64,
    ) -> Result<(), CommError>;

    /// Blocking receive of the next message matching `(src, tag)`.
    ///
    /// Returns [`CommError::PeerDown`] as soon as `src` is known to have
    /// departed with no matching message buffered, and
    /// [`CommError::Timeout`] when `deadline` elapses first.
    fn recv_match(
        &mut self,
        src: usize,
        tag: Tag,
        deadline: Option<Duration>,
    ) -> Result<Message, CommError>;

    /// Blocking receive of the next message with `tag` from any source
    /// (lowest buffered source rank wins, for determinism). Returns
    /// [`CommError::PeerDown`] when every peer has departed and nothing
    /// matching is buffered; [`CommError::Timeout`] (with
    /// `src == usize::MAX`) on deadline expiry.
    fn recv_any(&mut self, tag: Tag, deadline: Option<Duration>) -> Result<Message, CommError>;

    /// Non-blocking [`Backend::recv_match`]; `None` when nothing matches.
    fn try_recv_match(&mut self, src: usize, tag: Tag) -> Option<Message>;

    /// Non-blocking [`Backend::recv_any`] (lowest source rank wins).
    fn try_recv_any(&mut self, tag: Tag) -> Option<Message>;

    /// Total *payload* bytes sent by this endpoint (`4 * elements`,
    /// headers and control frames excluded — see module docs).
    fn bytes_sent(&self) -> u64;

    /// Hand a received payload's storage back to the backend's buffer
    /// pool once the caller is done combining it. Default: plain drop.
    fn reclaim(&self, payload: Arc<Vec<f32>>) {
        drop(payload);
    }

    /// Orderly departure: tell every peer this rank is done (they observe
    /// [`CommError::PeerDown`] on further receives, never a hang).
    fn shutdown(&mut self);

    /// Depart *without* notice — the test hook that models a killed
    /// process. Peers must still observe [`CommError::PeerDown`].
    fn abandon(&mut self);
}

/// Shared liveness board for a [`SimBackend`] fleet: `flags[r]` is true
/// while rank r's endpoint is still participating.
#[derive(Clone)]
struct Liveness {
    flags: Arc<Vec<AtomicBool>>,
}

impl Liveness {
    fn new(n: usize) -> Self {
        Liveness { flags: Arc::new((0..n).map(|_| AtomicBool::new(true)).collect()) }
    }

    fn is_alive(&self, rank: usize) -> bool {
        self.flags[rank].load(Ordering::Acquire)
    }

    fn depart(&self, rank: usize) {
        self.flags[rank].store(false, Ordering::Release);
    }
}

/// The in-memory fabric behind the [`Backend`] trait.
///
/// Composition, not reimplementation: all matching/stash behavior is the
/// [`Mailbox`] the simulator has always used, so `SimBackend` cannot
/// drift from `run_spmd` semantics. What this wrapper adds is exactly the
/// trait contract: payload-byte accounting, wall-clock deadlines, and
/// peer-death detection via a shared liveness board (the in-memory
/// analogue of a TCP reader thread observing EOF — the raw MPSC channel
/// cannot signal a *single* dead sender because the sender table is
/// shared).
pub struct SimBackend {
    mailbox: Mailbox,
    postman: Postman,
    liveness: Liveness,
    tx_payload_bytes: u64,
    start: Instant,
    departed: bool,
}

/// Build a connected fleet of `n` [`SimBackend`] endpoints (index = rank).
pub fn sim_backends(n: usize) -> Vec<SimBackend> {
    let (mailboxes, postman) = fabric(n);
    let liveness = Liveness::new(n);
    let start = Instant::now();
    mailboxes
        .into_iter()
        .map(|mailbox| SimBackend {
            mailbox,
            postman: postman.clone(),
            liveness: liveness.clone(),
            tx_payload_bytes: 0,
            start,
            departed: false,
        })
        .collect()
}

impl SimBackend {
    /// Wall seconds since the fleet was built — the `at` stamp carried by
    /// this backend's [`CommError`]s (real backends have no virtual
    /// clock, so the trait reports failure times on the wall clock).
    fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Lowest-ranked departed peer, if any peer has departed.
    fn first_departed_peer(&self) -> Option<usize> {
        (0..self.size()).find(|&r| r != self.rank() && !self.liveness.is_alive(r))
    }
}

impl Backend for SimBackend {
    fn rank(&self) -> usize {
        self.mailbox.rank()
    }

    fn size(&self) -> usize {
        self.postman.size()
    }

    fn send(
        &mut self,
        dst: usize,
        tag: Tag,
        payload: Arc<Vec<f32>>,
        vtime: f64,
    ) -> Result<(), CommError> {
        if !self.liveness.is_alive(dst) {
            return Err(CommError::PeerDown { peer: dst, at: self.elapsed() });
        }
        let nbytes = payload_nbytes(payload.len());
        let msg = Message { src: self.rank(), tag, payload, arrival_vtime: vtime };
        self.postman
            .send(dst, msg)
            .map_err(|_| CommError::PeerDown { peer: dst, at: self.elapsed() })?;
        self.tx_payload_bytes += nbytes;
        Ok(())
    }

    fn recv_match(
        &mut self,
        src: usize,
        tag: Tag,
        deadline: Option<Duration>,
    ) -> Result<Message, CommError> {
        let wait_start = Instant::now();
        loop {
            if let Some(m) = self.mailbox.try_recv_match(src, tag) {
                return Ok(m);
            }
            // Buffered messages win over death: only report PeerDown once
            // nothing matching remains (same order as the TCP inbox).
            if !self.liveness.is_alive(src) {
                return Err(CommError::PeerDown { peer: src, at: self.elapsed() });
            }
            let slice = match deadline {
                None => WAIT_SLICE,
                Some(d) => {
                    let remaining = d.saturating_sub(wait_start.elapsed());
                    if remaining.is_zero() {
                        return Err(CommError::Timeout { src, deadline: self.elapsed() });
                    }
                    remaining.min(WAIT_SLICE)
                }
            };
            self.mailbox.wait_for_message(slice);
        }
    }

    fn recv_any(&mut self, tag: Tag, deadline: Option<Duration>) -> Result<Message, CommError> {
        let wait_start = Instant::now();
        loop {
            if let Some(m) = self.mailbox.try_recv_any(tag) {
                return Ok(m);
            }
            let all_peers_departed =
                (0..self.size()).all(|r| r == self.rank() || !self.liveness.is_alive(r));
            if all_peers_departed {
                let peer = self.first_departed_peer().unwrap_or(self.rank());
                return Err(CommError::PeerDown { peer, at: self.elapsed() });
            }
            let slice = match deadline {
                None => WAIT_SLICE,
                Some(d) => {
                    let remaining = d.saturating_sub(wait_start.elapsed());
                    if remaining.is_zero() {
                        return Err(CommError::Timeout {
                            src: usize::MAX,
                            deadline: self.elapsed(),
                        });
                    }
                    remaining.min(WAIT_SLICE)
                }
            };
            self.mailbox.wait_for_message(slice);
        }
    }

    fn try_recv_match(&mut self, src: usize, tag: Tag) -> Option<Message> {
        self.mailbox.try_recv_match(src, tag)
    }

    fn try_recv_any(&mut self, tag: Tag) -> Option<Message> {
        self.mailbox.try_recv_any(tag)
    }

    fn bytes_sent(&self) -> u64 {
        self.tx_payload_bytes
    }

    fn shutdown(&mut self) {
        self.departed = true;
        self.liveness.depart(self.rank());
    }

    fn abandon(&mut self) {
        // In-memory there is no Goodbye frame to withhold; departing is
        // departing. The distinction matters only on real sockets.
        self.shutdown();
    }
}

impl Drop for SimBackend {
    fn drop(&mut self) {
        if !self.departed {
            self.liveness.depart(self.mailbox.rank());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_backend_send_recv_and_byte_accounting() {
        let mut fleet = sim_backends(2);
        let mut b1 = fleet.pop().unwrap();
        let mut b0 = fleet.pop().unwrap();
        let tag = crate::transport::make_tag(crate::transport::op_id("x"), 0);
        b0.send(1, tag, Arc::new(vec![1.0, 2.0, 3.0]), 0.5).unwrap();
        let m = b1.recv_match(0, tag, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(*m.payload, vec![1.0, 2.0, 3.0]);
        assert_eq!(m.arrival_vtime, 0.5);
        assert_eq!(b0.bytes_sent(), 12, "payload bytes only: 3 f32 = 12");
        assert_eq!(b1.bytes_sent(), 0);
    }

    #[test]
    fn departed_peer_is_typed_peer_down() {
        let mut fleet = sim_backends(2);
        let mut b1 = fleet.pop().unwrap();
        let mut b0 = fleet.pop().unwrap();
        b0.shutdown();
        let tag = crate::transport::make_tag(crate::transport::op_id("x"), 0);
        match b1.recv_match(0, tag, Some(Duration::from_secs(5))) {
            Err(CommError::PeerDown { peer: 0, .. }) => {}
            other => panic!("expected PeerDown from rank 0, got {other:?}"),
        }
        match b1.send(0, tag, Arc::new(vec![1.0]), 0.0) {
            Err(CommError::PeerDown { peer: 0, .. }) => {}
            other => panic!("expected send-side PeerDown, got {other:?}"),
        }
    }

    #[test]
    fn buffered_messages_win_over_peer_death() {
        let mut fleet = sim_backends(2);
        let mut b1 = fleet.pop().unwrap();
        let mut b0 = fleet.pop().unwrap();
        let tag = crate::transport::make_tag(crate::transport::op_id("x"), 7);
        b0.send(1, tag, Arc::new(vec![4.0]), 0.0).unwrap();
        b0.shutdown();
        let m = b1.recv_match(0, tag, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(*m.payload, vec![4.0]);
        assert!(b1.recv_match(0, tag, Some(Duration::from_millis(50))).is_err());
    }

    #[test]
    fn deadline_expiry_is_typed_timeout() {
        let mut fleet = sim_backends(2);
        let mut b1 = fleet.pop().unwrap();
        let tag = crate::transport::make_tag(crate::transport::op_id("x"), 0);
        match b1.recv_match(0, tag, Some(Duration::from_millis(30))) {
            Err(CommError::Timeout { src: 0, .. }) => {}
            other => panic!("expected Timeout, got {other:?}"),
        }
        match b1.recv_any(tag, Some(Duration::from_millis(30))) {
            Err(CommError::Timeout { src: usize::MAX, .. }) => {}
            other => panic!("expected recv-any Timeout, got {other:?}"),
        }
    }
}
