//! Real multi-process transport: framed messages over loopback/LAN TCP.
//!
//! This is the first out-of-process [`Backend`]: one persistent
//! full-duplex socket per peer pair, every message a
//! [`crate::transport::frame`] frame (DESIGN.md §Transport backends).
//! Connection establishment follows the rendezvous protocol of §RDZ-1…4:
//!
//! 1. Every rank binds a *data listener* on an ephemeral port.
//! 2. Ranks 1..n dial rank 0's rendezvous listener and send a `Hello`
//!    frame whose `tag` carries their data port (§RDZ-2).
//! 3. Rank 0 replies to each with an `AddrMap` frame: `payload[r]` =
//!    rank r's data port (§RDZ-3; ports ≤ 65535 are exact in f32).
//! 4. The mesh forms deadlock-free: rank i dials every j < i (sending a
//!    `Hello` to identify itself) and accepts from every j > i (§RDZ-4).
//!
//! After setup, one reader thread per peer socket decodes frames into a
//! shared inbox (same `(src, tag)` stash semantics as [`Mailbox`]); a
//! condvar wakes blocked receivers. EOF or a socket error *without* a
//! preceding `Goodbye` frame marks the peer dead exactly like a crashed
//! process — receivers observe [`CommError::PeerDown`], never a hang.
//! Decode buffers come from the PR-2 [`BufferPool`] and callers hand
//! payload storage back via [`Backend::reclaim`], so the zero-copy
//! discipline survives the backend swap.
//!
//! [`Mailbox`]: crate::transport::Mailbox

use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::pool::BufferPool;
use crate::simnet::faults::CommError;
use crate::transport::backend::{payload_nbytes, Backend};
use crate::transport::frame::{read_frame_into, write_frame, Frame, FrameKind, ReadFrame};
use crate::transport::{Message, Tag};

/// How long connection establishment (rendezvous + mesh) may take before
/// a missing peer turns into a setup error instead of a hang.
pub const SETUP_TIMEOUT: Duration = Duration::from_secs(20);

/// Polling granularity for blocked receivers and setup accept loops.
const WAIT_SLICE: Duration = Duration::from_millis(25);

/// Shared inbox fed by the per-peer reader threads.
struct InboxState {
    /// Buffered arrivals, keyed `(src, tag)` — the [`Mailbox`] stash
    /// discipline, shared across reader threads.
    stash: HashMap<(usize, Tag), VecDeque<Message>>,
    /// `dead[r]`: rank r's socket has closed (Goodbye, EOF, or error).
    dead: Vec<bool>,
    /// `clean[r]`: the closure was announced by a `Goodbye` frame.
    clean: Vec<bool>,
}

struct Inbox {
    state: Mutex<InboxState>,
    cond: Condvar,
}

impl Inbox {
    fn new(n: usize) -> Arc<Inbox> {
        Arc::new(Inbox {
            state: Mutex::new(InboxState {
                stash: HashMap::new(),
                dead: vec![false; n],
                clean: vec![false; n],
            }),
            cond: Condvar::new(),
        })
    }

    fn push(&self, msg: Message) {
        let mut st = self.state.lock().unwrap();
        st.stash.entry((msg.src, msg.tag)).or_default().push_back(msg);
        self.cond.notify_all();
    }

    fn mark_dead(&self, peer: usize, clean: bool) {
        let mut st = self.state.lock().unwrap();
        st.dead[peer] = true;
        st.clean[peer] = clean;
        self.cond.notify_all();
    }
}

/// Pop the oldest `(src, tag)` match from the stash.
fn pop_match(st: &mut InboxState, src: usize, tag: Tag) -> Option<Message> {
    let q = st.stash.get_mut(&(src, tag))?;
    let m = q.pop_front().expect("stash entries are non-empty");
    if q.is_empty() {
        st.stash.remove(&(src, tag));
    }
    Some(m)
}

/// Pop the `tag` match from the lowest buffered source rank.
fn pop_any(st: &mut InboxState, tag: Tag) -> Option<Message> {
    let src = st.stash.keys().filter(|&&(_, t)| t == tag).map(|&(s, _)| s).min()?;
    pop_match(st, src, tag)
}

/// The write half of one peer connection.
struct WriterConn {
    stream: TcpStream,
    scratch: Vec<u8>,
}

impl WriterConn {
    fn write(&mut self, frame: &Frame) -> std::io::Result<usize> {
        write_frame(&mut self.stream, frame, &mut self.scratch)?;
        self.stream.flush()?;
        Ok(self.scratch.len())
    }
}

/// TCP implementation of [`Backend`] — see module docs for the protocol.
pub struct TcpBackend {
    rank: usize,
    size: usize,
    /// `writers[r]`: write half of the socket to rank r (`None` for self
    /// and for peers whose connection has failed).
    writers: Vec<Option<WriterConn>>,
    inbox: Arc<Inbox>,
    pool: BufferPool,
    tx_payload_bytes: u64,
    tx_wire_bytes: u64,
    start: Instant,
    shut_down: bool,
}

/// Spawn the reader thread for one peer socket.
fn spawn_reader(peer: usize, stream: TcpStream, inbox: Arc<Inbox>, pool: BufferPool) {
    std::thread::Builder::new()
        .name(format!("bf-tcp-rx-{peer}"))
        .spawn(move || {
            let mut stream = stream;
            // Bucket hint so pooled decode buffers land in (and return
            // from) the bucket matching the workload's tensor size.
            let mut hint: usize = 64;
            loop {
                let mut scratch = pool.checkout_empty(hint).into_vec();
                match read_frame_into(&mut stream, &mut scratch) {
                    ReadFrame::Ok(frame) => match frame.kind {
                        FrameKind::Data => {
                            hint = hint.max(frame.payload.len());
                            inbox.push(Message {
                                src: frame.src as usize,
                                tag: frame.tag,
                                payload: Arc::new(frame.payload),
                                arrival_vtime: frame.vtime,
                            });
                        }
                        FrameKind::Goodbye => {
                            inbox.mark_dead(peer, true);
                            return;
                        }
                        FrameKind::Error => {
                            inbox.mark_dead(peer, false);
                            return;
                        }
                        // Setup-phase kinds are a protocol violation
                        // after the mesh is up; treat as peer failure.
                        FrameKind::Hello | FrameKind::AddrMap => {
                            inbox.mark_dead(peer, false);
                            return;
                        }
                    },
                    // EOF without Goodbye = the peer process died.
                    ReadFrame::Eof | ReadFrame::Malformed(_) | ReadFrame::Io(_) => {
                        inbox.mark_dead(peer, false);
                        return;
                    }
                }
            }
        })
        .expect("spawn tcp reader thread");
}

/// Dial `port` on loopback, retrying until `SETUP_TIMEOUT` (the listener
/// may not be up yet when a fast child starts dialing).
fn dial_retry(port: u16) -> std::io::Result<TcpStream> {
    let deadline = Instant::now() + SETUP_TIMEOUT;
    loop {
        match TcpStream::connect(("127.0.0.1", port)) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e);
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Accept one connection within `SETUP_TIMEOUT`.
fn accept_timeout(listener: &TcpListener) -> std::io::Result<TcpStream> {
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + SETUP_TIMEOUT;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false)?;
                return Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "timed out waiting for a peer connection",
                    ));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Read one frame of the expected `kind` during setup.
fn read_setup_frame(stream: &mut TcpStream, kind: FrameKind) -> std::io::Result<Frame> {
    let mut payload = Vec::new();
    match read_frame_into(stream, &mut payload) {
        ReadFrame::Ok(f) if f.kind == kind => Ok(f),
        ReadFrame::Ok(f) => Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("expected {kind:?} during setup, got {:?}", f.kind),
        )),
        ReadFrame::Eof => Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "peer closed during setup",
        )),
        ReadFrame::Malformed(e) => {
            Err(std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
        }
        ReadFrame::Io(e) => Err(e),
    }
}

fn send_setup_frame(stream: &mut TcpStream, frame: &Frame) -> std::io::Result<()> {
    let mut scratch = Vec::new();
    write_frame(stream, frame, &mut scratch)?;
    stream.flush()
}

/// Rank 0's rendezvous service, bound before any child dials in so the
/// port can be published to them (the launcher prints it on stdout — the
/// port-allocation guard that lets parallel CI jobs coexist).
pub struct Rendezvous {
    listener: TcpListener,
}

impl Rendezvous {
    /// Bind the rendezvous listener on an ephemeral loopback port.
    pub fn bind() -> std::io::Result<Rendezvous> {
        Ok(Rendezvous { listener: TcpListener::bind(("127.0.0.1", 0))? })
    }

    /// The port peers must dial (publish out-of-band; see §RDZ-1).
    pub fn port(&self) -> std::io::Result<u16> {
        Ok(self.listener.local_addr()?.port())
    }

    /// Run rank 0's side to completion: collect `Hello`s from ranks
    /// 1..n, reply with the address map, then form rank 0's mesh edges.
    pub fn establish(self, size: usize) -> std::io::Result<TcpBackend> {
        let data_listener = TcpListener::bind(("127.0.0.1", 0))?;
        let mut ports = vec![0u16; size];
        ports[0] = data_listener.local_addr()?.port();

        // §RDZ-2/3: one Hello per joining rank, one AddrMap reply each.
        let mut rdz_conns: Vec<TcpStream> = Vec::with_capacity(size - 1);
        for _ in 1..size {
            let mut conn = accept_timeout(&self.listener)?;
            let hello = read_setup_frame(&mut conn, FrameKind::Hello)?;
            let peer = hello.src as usize;
            if peer == 0 || peer >= size {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("rendezvous Hello from out-of-range rank {peer}"),
                ));
            }
            ports[peer] = hello.tag as u16;
            rdz_conns.push(conn);
        }
        let map = Frame {
            kind: FrameKind::AddrMap,
            src: 0,
            tag: 0,
            vtime: 0.0,
            payload: ports.iter().map(|&p| p as f32).collect(),
        };
        for conn in &mut rdz_conns {
            send_setup_frame(conn, &map)?;
        }
        drop(rdz_conns);
        TcpBackend::finish_mesh(0, size, data_listener)
    }
}

impl TcpBackend {
    /// Join a job as rank `rank >= 1` by dialing rank 0's rendezvous
    /// port (§RDZ-2…4).
    pub fn connect(rank: usize, size: usize, rendezvous_port: u16) -> std::io::Result<TcpBackend> {
        assert!(rank >= 1 && rank < size, "rank 0 uses Rendezvous::establish");
        let data_listener = TcpListener::bind(("127.0.0.1", 0))?;
        let data_port = data_listener.local_addr()?.port();

        let mut rdz = dial_retry(rendezvous_port)?;
        let hello = Frame::control(FrameKind::Hello, rank as u64, data_port as u64);
        send_setup_frame(&mut rdz, &hello)?;
        let map = read_setup_frame(&mut rdz, FrameKind::AddrMap)?;
        drop(rdz);
        if map.payload.len() != size {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("address map has {} entries, expected {size}", map.payload.len()),
            ));
        }
        let ports: Vec<u16> = map.payload.iter().map(|&p| p as u16).collect();

        let mut backend = TcpBackend::empty(rank, size);
        // §RDZ-4: dial every lower rank, identifying ourselves with a
        // Hello on the fresh data connection.
        for peer in 0..rank {
            let mut stream = dial_retry(ports[peer])?;
            stream.set_nodelay(true)?;
            send_setup_frame(&mut stream, &Frame::control(FrameKind::Hello, rank as u64, 0))?;
            backend.adopt(peer, stream)?;
        }
        backend.accept_uppers(&data_listener)?;
        Ok(backend)
    }

    fn empty(rank: usize, size: usize) -> TcpBackend {
        TcpBackend {
            rank,
            size,
            writers: (0..size).map(|_| None).collect(),
            inbox: Inbox::new(size),
            pool: BufferPool::new(),
            tx_payload_bytes: 0,
            tx_wire_bytes: 0,
            start: Instant::now(),
            shut_down: false,
        }
    }

    /// Register an established peer socket: keep the write half, spawn
    /// the reader thread on a clone.
    fn adopt(&mut self, peer: usize, stream: TcpStream) -> std::io::Result<()> {
        let read_half = stream.try_clone()?;
        spawn_reader(peer, read_half, Arc::clone(&self.inbox), self.pool.clone());
        self.writers[peer] = Some(WriterConn { stream, scratch: Vec::new() });
        Ok(())
    }

    /// Accept the mesh edges dialed by every higher rank (§RDZ-4).
    fn accept_uppers(&mut self, data_listener: &TcpListener) -> std::io::Result<()> {
        let expected = self.size - 1 - self.rank;
        for _ in 0..expected {
            let mut stream = accept_timeout(data_listener)?;
            stream.set_nodelay(true)?;
            let hello = read_setup_frame(&mut stream, FrameKind::Hello)?;
            let peer = hello.src as usize;
            if peer <= self.rank || peer >= self.size {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("mesh Hello from unexpected rank {peer}"),
                ));
            }
            self.adopt(peer, stream)?;
        }
        Ok(())
    }

    /// Shared tail of mesh formation for rank 0 (dials nobody).
    fn finish_mesh(
        rank: usize,
        size: usize,
        data_listener: TcpListener,
    ) -> std::io::Result<TcpBackend> {
        let mut backend = TcpBackend::empty(rank, size);
        backend.accept_uppers(&data_listener)?;
        Ok(backend)
    }

    /// Total bytes written to sockets, headers and control frames
    /// included (contrast [`Backend::bytes_sent`], which is payload-only
    /// for cross-backend comparability).
    pub fn wire_bytes_sent(&self) -> u64 {
        self.tx_wire_bytes
    }

    fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Write a frame to `dst`, mapping any socket error to `PeerDown`
    /// and dropping the broken write half.
    fn write_to(&mut self, dst: usize, frame: &Frame) -> Result<u64, CommError> {
        let at = self.elapsed();
        let conn = self.writers[dst].as_mut().ok_or(CommError::PeerDown { peer: dst, at })?;
        match conn.write(frame) {
            Ok(n) => Ok(n as u64),
            Err(_) => {
                self.writers[dst] = None;
                self.inbox.mark_dead(dst, false);
                Err(CommError::PeerDown { peer: dst, at })
            }
        }
    }
}

impl Backend for TcpBackend {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(
        &mut self,
        dst: usize,
        tag: Tag,
        payload: Arc<Vec<f32>>,
        vtime: f64,
    ) -> Result<(), CommError> {
        let nbytes = payload_nbytes(payload.len());
        if dst == self.rank {
            // Loopback-in-the-small: self-sends skip the socket but are
            // accounted identically on both backends.
            self.inbox.push(Message { src: dst, tag, payload, arrival_vtime: vtime });
            self.tx_payload_bytes += nbytes;
            return Ok(());
        }
        let frame = Frame::data(self.rank as u64, tag, vtime, payload.as_ref().clone());
        let wire = self.write_to(dst, &frame)?;
        self.pool.recycle_vec(frame.payload);
        self.tx_payload_bytes += nbytes;
        self.tx_wire_bytes += wire;
        Ok(())
    }

    fn recv_match(
        &mut self,
        src: usize,
        tag: Tag,
        deadline: Option<Duration>,
    ) -> Result<Message, CommError> {
        let wait_start = Instant::now();
        let inbox = Arc::clone(&self.inbox);
        let mut st = inbox.state.lock().unwrap();
        loop {
            if let Some(m) = pop_match(&mut st, src, tag) {
                return Ok(m);
            }
            if st.dead[src] {
                return Err(CommError::PeerDown { peer: src, at: self.elapsed() });
            }
            let slice = match deadline {
                None => WAIT_SLICE,
                Some(d) => {
                    let remaining = d.saturating_sub(wait_start.elapsed());
                    if remaining.is_zero() {
                        return Err(CommError::Timeout { src, deadline: self.elapsed() });
                    }
                    remaining.min(WAIT_SLICE)
                }
            };
            st = inbox.cond.wait_timeout(st, slice).unwrap().0;
        }
    }

    fn recv_any(&mut self, tag: Tag, deadline: Option<Duration>) -> Result<Message, CommError> {
        let wait_start = Instant::now();
        let inbox = Arc::clone(&self.inbox);
        let mut st = inbox.state.lock().unwrap();
        loop {
            if let Some(m) = pop_any(&mut st, tag) {
                return Ok(m);
            }
            let all_dead = (0..self.size).all(|r| r == self.rank || st.dead[r]);
            if all_dead {
                let peer = (0..self.size).find(|&r| r != self.rank).unwrap_or(self.rank);
                return Err(CommError::PeerDown { peer, at: self.elapsed() });
            }
            let slice = match deadline {
                None => WAIT_SLICE,
                Some(d) => {
                    let remaining = d.saturating_sub(wait_start.elapsed());
                    if remaining.is_zero() {
                        return Err(CommError::Timeout {
                            src: usize::MAX,
                            deadline: self.elapsed(),
                        });
                    }
                    remaining.min(WAIT_SLICE)
                }
            };
            st = inbox.cond.wait_timeout(st, slice).unwrap().0;
        }
    }

    fn try_recv_match(&mut self, src: usize, tag: Tag) -> Option<Message> {
        pop_match(&mut self.inbox.state.lock().unwrap(), src, tag)
    }

    fn try_recv_any(&mut self, tag: Tag) -> Option<Message> {
        pop_any(&mut self.inbox.state.lock().unwrap(), tag)
    }

    fn bytes_sent(&self) -> u64 {
        self.tx_payload_bytes
    }

    fn reclaim(&self, payload: Arc<Vec<f32>>) {
        self.pool.reclaim(payload);
    }

    fn shutdown(&mut self) {
        if self.shut_down {
            return;
        }
        self.shut_down = true;
        let goodbye = Frame::control(FrameKind::Goodbye, self.rank as u64, 0);
        for dst in 0..self.size {
            if dst != self.rank {
                let _ = self.write_to(dst, &goodbye);
            }
        }
        // Keep the write halves open until drop: peers may still be
        // mid-receive and closing early would race their final reads.
    }

    fn abandon(&mut self) {
        // Model a killed process: slam every socket shut with no
        // Goodbye. Peers observe EOF → PeerDown.
        self.shut_down = true;
        for conn in self.writers.iter_mut() {
            if let Some(c) = conn.take() {
                let _ = c.stream.shutdown(Shutdown::Both);
            }
        }
    }
}

impl Drop for TcpBackend {
    fn drop(&mut self) {
        if !self.shut_down {
            // An unannounced drop is indistinguishable from a crash on
            // the wire — which is exactly the semantics we want.
            self.abandon();
        }
    }
}
