//! Wire-format codec for out-of-process transport backends.
//!
//! This module is the executable form of the frame specification in
//! `DESIGN.md` §Transport backends — every constant and layout decision
//! below cites its spec section (§WF-1 … §WF-6), and the property tests in
//! `rust/tests/frames.rs` are organized by those same sections. Keep the
//! two in lock-step: a change here without a spec bump (§WF-6) is a
//! protocol break.
//!
//! One codec serves every out-of-process message: tensor payloads (`Data`),
//! the rendezvous handshake (`Hello` / `AddrMap`), orderly shutdown
//! (`Goodbye`) and mid-stream aborts (`Error`) all ride the same
//! fixed-header + f32-payload frame, so a backend implementation needs
//! exactly one parser.

use std::fmt;
use std::io::{Read, Write};

/// §WF-2: frame magic, the ASCII bytes `"BFOG"`. A connection whose first
/// four bytes differ is not speaking this protocol and must be dropped.
pub const MAGIC: [u8; 4] = *b"BFOG";

/// §WF-6: wire-format version byte. Bump on any layout change; a decoder
/// rejects frames from a different version instead of guessing.
pub const VERSION: u8 = 1;

/// §WF-2: fixed header length in bytes (magic through payload length).
pub const HEADER_LEN: usize = 40;

/// §WF-5: maximum payload length in f32 elements (2^28 elements = 1 GiB).
/// A length field above this is treated as a corrupt frame *before* any
/// allocation happens — a malformed peer cannot OOM the receiver.
pub const MAX_PAYLOAD_ELEMS: u64 = 1 << 28;

/// §WF-4: frame kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// A tensor message: `src`/`tag`/`vtime` meaningful, payload is data.
    Data,
    /// Rendezvous registration: `src` = sender rank, `tag` = the data-plane
    /// port the sender listens on (§RDZ-2); empty payload.
    Hello,
    /// Rendezvous reply from rank 0: payload is the full address map,
    /// `payload[r]` = rank r's data port as an exactly-representable f32
    /// (§RDZ-3); empty tag.
    AddrMap,
    /// Orderly shutdown: the sender will write nothing further. Receivers
    /// treat subsequent receives from this peer as `PeerDown` (§WF-4).
    Goodbye,
    /// Mid-stream abort: `tag` carries a reason code; the sender closes
    /// right after. Receivers treat the peer as down, exactly as for an
    /// unexpected EOF — the frame only makes failure propagation faster.
    Error,
}

impl FrameKind {
    /// §WF-4 wire encoding of the kind byte.
    pub fn as_u8(self) -> u8 {
        match self {
            FrameKind::Data => 0,
            FrameKind::Hello => 1,
            FrameKind::AddrMap => 2,
            FrameKind::Goodbye => 3,
            FrameKind::Error => 4,
        }
    }

    /// Inverse of [`FrameKind::as_u8`]; unknown bytes are a decode error
    /// (§WF-4: receivers must not guess at future kinds).
    pub fn from_u8(b: u8) -> Option<FrameKind> {
        match b {
            0 => Some(FrameKind::Data),
            1 => Some(FrameKind::Hello),
            2 => Some(FrameKind::AddrMap),
            3 => Some(FrameKind::Goodbye),
            4 => Some(FrameKind::Error),
            _ => None,
        }
    }
}

/// A decoded frame (§WF-2). The payload is `f32` because every tensor in
/// this codebase is; compressed streams ride the existing self-describing
/// f32 format from `crate::compress` unchanged, so they need no special
/// framing.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    /// Frame kind (§WF-4).
    pub kind: FrameKind,
    /// Sending rank.
    pub src: u64,
    /// Operation/round tag ([`crate::transport::make_tag`] layout), or the
    /// kind-specific overload documented on [`FrameKind`].
    pub tag: u64,
    /// Sender's virtual time at send (informational on real backends:
    /// wall clock is authoritative there, but carrying it keeps sim/tcp
    /// traces comparable).
    pub vtime: f64,
    /// f32 payload, little-endian on the wire (§WF-3).
    pub payload: Vec<f32>,
}

impl Frame {
    /// A payload-free frame of the given kind.
    pub fn control(kind: FrameKind, src: u64, tag: u64) -> Frame {
        Frame { kind, src, tag, vtime: 0.0, payload: Vec::new() }
    }

    /// A data frame.
    pub fn data(src: u64, tag: u64, vtime: f64, payload: Vec<f32>) -> Frame {
        Frame { kind: FrameKind::Data, src, tag, vtime, payload }
    }
}

/// Why a byte sequence failed to decode as a frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// First four bytes were not [`MAGIC`] (§WF-2).
    BadMagic([u8; 4]),
    /// Version byte differed from [`VERSION`] (§WF-6).
    BadVersion(u8),
    /// Unknown kind byte (§WF-4).
    BadKind(u8),
    /// Payload length field exceeded [`MAX_PAYLOAD_ELEMS`] (§WF-5).
    Oversize(u64),
    /// The buffer ended mid-header or mid-payload (§WF-5: a decoder never
    /// consumes a partial frame).
    Truncated {
        /// Bytes the complete frame needs.
        needed: usize,
        /// Bytes actually available.
        have: usize,
    },
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::BadVersion(v) => {
                write!(f, "unsupported wire-format version {v} (this build speaks {VERSION})")
            }
            FrameError::BadKind(k) => write!(f, "unknown frame kind byte {k}"),
            FrameError::Oversize(n) => {
                write!(f, "payload length {n} exceeds the {MAX_PAYLOAD_ELEMS}-element cap")
            }
            FrameError::Truncated { needed, have } => {
                write!(f, "truncated frame: need {needed} bytes, have {have}")
            }
        }
    }
}

impl std::error::Error for FrameError {}

/// Total encoded size in bytes of a frame carrying `nelems` f32 elements.
pub fn encoded_len(nelems: usize) -> usize {
    HEADER_LEN + nelems * std::mem::size_of::<f32>()
}

/// Encode `frame` to the §WF-2 layout, appending to `out` (callers reuse
/// one scratch buffer across sends — the byte-level analogue of the PR-2
/// pool discipline).
pub fn encode_into(frame: &Frame, out: &mut Vec<u8>) {
    out.reserve(encoded_len(frame.payload.len()));
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(frame.kind.as_u8());
    out.extend_from_slice(&[0u8, 0u8]); // §WF-2: reserved, zero on send
    out.extend_from_slice(&frame.src.to_le_bytes());
    out.extend_from_slice(&frame.tag.to_le_bytes());
    out.extend_from_slice(&frame.vtime.to_le_bytes());
    out.extend_from_slice(&(frame.payload.len() as u64).to_le_bytes());
    for v in &frame.payload {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode `frame` into a fresh buffer.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::with_capacity(encoded_len(frame.payload.len()));
    encode_into(frame, &mut out);
    out
}

fn le_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b.try_into().expect("8-byte slice"))
}

/// Validate a §WF-2 header and return `(kind, src, tag, vtime, nelems)`.
fn decode_header(h: &[u8; HEADER_LEN]) -> Result<(FrameKind, u64, u64, f64, usize), FrameError> {
    if h[0..4] != MAGIC {
        return Err(FrameError::BadMagic([h[0], h[1], h[2], h[3]]));
    }
    if h[4] != VERSION {
        return Err(FrameError::BadVersion(h[4]));
    }
    let kind = FrameKind::from_u8(h[5]).ok_or(FrameError::BadKind(h[5]))?;
    // h[6..8] reserved: ignored on receive (§WF-2).
    let src = le_u64(&h[8..16]);
    let tag = le_u64(&h[16..24]);
    let vtime = f64::from_le_bytes(h[24..32].try_into().expect("8-byte slice"));
    let nelems = le_u64(&h[32..40]);
    if nelems > MAX_PAYLOAD_ELEMS {
        return Err(FrameError::Oversize(nelems));
    }
    Ok((kind, src, tag, vtime, nelems as usize))
}

/// Decode one frame from the front of `buf`, returning it and the number
/// of bytes consumed. Fails without consuming anything on a malformed or
/// incomplete prefix (§WF-5).
pub fn decode(buf: &[u8]) -> Result<(Frame, usize), FrameError> {
    if buf.len() < HEADER_LEN {
        return Err(FrameError::Truncated { needed: HEADER_LEN, have: buf.len() });
    }
    let header: &[u8; HEADER_LEN] = buf[..HEADER_LEN].try_into().expect("checked length");
    let (kind, src, tag, vtime, nelems) = decode_header(header)?;
    let total = encoded_len(nelems);
    if buf.len() < total {
        return Err(FrameError::Truncated { needed: total, have: buf.len() });
    }
    let mut payload = Vec::with_capacity(nelems);
    for chunk in buf[HEADER_LEN..total].chunks_exact(4) {
        payload.push(f32::from_le_bytes(chunk.try_into().expect("4-byte chunk")));
    }
    Ok((Frame { kind, src, tag, vtime, payload }, total))
}

/// Outcome of reading one frame from a byte stream.
#[derive(Debug)]
pub enum ReadFrame {
    /// A complete, well-formed frame.
    Ok(Frame),
    /// Clean end of stream at a frame boundary (zero bytes read).
    Eof,
    /// The stream violated the spec (bad magic/version/kind/length, or it
    /// ended mid-frame). The connection must be dropped — framing cannot
    /// be re-synchronized (§WF-1).
    Malformed(FrameError),
    /// Underlying I/O error (connection reset, timeout, …).
    Io(std::io::Error),
}

/// Read exactly one frame from `r`, decoding the payload into `payload`
/// (cleared first — pass a pooled buffer to recycle tensor storage across
/// receives). Distinguishes a clean EOF at a frame boundary from a
/// mid-frame truncation, which is malformed (§WF-5).
pub fn read_frame_into<R: Read>(r: &mut R, payload: &mut Vec<f32>) -> ReadFrame {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                return if got == 0 {
                    ReadFrame::Eof
                } else {
                    ReadFrame::Malformed(FrameError::Truncated { needed: HEADER_LEN, have: got })
                };
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return ReadFrame::Io(e),
        }
    }
    let (kind, src, tag, vtime, nelems) = match decode_header(&header) {
        Ok(h) => h,
        Err(e) => return ReadFrame::Malformed(e),
    };
    payload.clear();
    payload.reserve(nelems);
    let mut chunk = [0u8; 4096];
    let mut remaining = nelems * std::mem::size_of::<f32>();
    let mut carry: Vec<u8> = Vec::new();
    while remaining > 0 {
        let want = remaining.min(chunk.len());
        match r.read(&mut chunk[..want]) {
            Ok(0) => {
                return ReadFrame::Malformed(FrameError::Truncated {
                    needed: encoded_len(nelems),
                    have: encoded_len(nelems) - remaining,
                });
            }
            Ok(n) => {
                remaining -= n;
                // Reads may split an f32 across calls; carry the tail.
                carry.extend_from_slice(&chunk[..n]);
                let whole = carry.len() / 4 * 4;
                for c in carry[..whole].chunks_exact(4) {
                    payload.push(f32::from_le_bytes(c.try_into().expect("4-byte chunk")));
                }
                carry.drain(..whole);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return ReadFrame::Io(e),
        }
    }
    debug_assert!(carry.is_empty(), "payload bytes are a multiple of 4");
    ReadFrame::Ok(Frame { kind, src, tag, vtime, payload: std::mem::take(payload) })
}

/// Write one frame to `w` (buffered writers should flush afterwards),
/// reusing `scratch` as the encode buffer.
pub fn write_frame<W: Write>(
    w: &mut W,
    frame: &Frame,
    scratch: &mut Vec<u8>,
) -> std::io::Result<()> {
    scratch.clear();
    encode_into(frame, scratch);
    w.write_all(scratch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let f = Frame::data(3, 0xABCD_EF01_2345_6789, 1.5, vec![1.0, -2.5, 0.0]);
        let bytes = encode(&f);
        assert_eq!(bytes.len(), encoded_len(3));
        let (g, used) = decode(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(f, g);
    }

    #[test]
    fn empty_payload_roundtrips() {
        let f = Frame::control(FrameKind::Goodbye, 7, 0);
        let (g, used) = decode(&encode(&f)).unwrap();
        assert_eq!(used, HEADER_LEN);
        assert_eq!(f, g);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode(&Frame::control(FrameKind::Hello, 0, 0));
        bytes[0] = b'X';
        assert!(matches!(decode(&bytes), Err(FrameError::BadMagic(_))));
    }

    #[test]
    fn oversize_len_rejected_before_alloc() {
        let mut bytes = encode(&Frame::control(FrameKind::Data, 0, 0));
        bytes[32..40].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(matches!(decode(&bytes), Err(FrameError::Oversize(_))));
    }

    #[test]
    fn stream_reader_matches_buffer_decoder() {
        let f = Frame::data(1, 42, 0.25, (0..1025).map(|i| i as f32).collect());
        let bytes = encode(&f);
        let mut cursor = &bytes[..];
        let mut payload = Vec::new();
        match read_frame_into(&mut cursor, &mut payload) {
            ReadFrame::Ok(g) => assert_eq!(f, g),
            other => panic!("expected frame, got {other:?}"),
        }
        match read_frame_into(&mut cursor, &mut payload) {
            ReadFrame::Eof => {}
            other => panic!("expected EOF, got {other:?}"),
        }
    }
}
