//! In-process point-to-point transport — the MPI/NCCL substitute.
//!
//! Every simulated node owns a [`Mailbox`]; senders deliver [`Message`]s
//! through per-node MPSC channels. Matching is MPI-style: a receive names a
//! `(source, tag)` pair and out-of-order arrivals are buffered. All
//! collectives (global and neighbor) are built strictly on top of this
//! interface, exactly as BlueFog builds on MPI point-to-point — so swapping
//! in a real network backend only touches this module.
//!
//! Each message also carries a *virtual arrival time* computed by the
//! [`crate::simnet`] cost model at send time; receivers advance their
//! virtual clock to `max(own, arrival)`. This yields the discrete-event
//! timing the benchmarks report without a global event queue.
//!
//! Since ISSUE 8 the module is split along the [`backend::Backend`]
//! seam: this file keeps the in-memory fabric and virtual clock;
//! [`frame`] defines the versioned wire format (DESIGN.md §Transport
//! backends); [`tcp`] implements the first out-of-process backend;
//! [`portable`] hosts the backend-agnostic collectives and workloads
//! used by the sim/tcp parity suite.

pub mod backend;
pub mod frame;
pub mod portable;
pub mod tcp;

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// Message tag: distinguishes concurrent operations between the same pair.
/// Layout convention: high 32 bits = operation id (name hash + op kind),
/// low 32 bits = round/iteration within the operation.
pub type Tag = u64;

/// Build a tag from an op identifier and a round counter.
///
/// ```
/// use bluefog::transport::{make_tag, op_id};
/// let id = op_id("neighbor_allreduce");
/// let tag = make_tag(id, 7);
/// assert_eq!(tag >> 32, id as u64, "high half is the op id");
/// assert_eq!(tag & 0xFFFF_FFFF, 7, "low half is the round");
/// ```
pub fn make_tag(op_id: u32, round: u32) -> Tag {
    ((op_id as u64) << 32) | round as u64
}

/// FNV-1a hash of an operation name into a 32-bit op id space.
///
/// ```
/// use bluefog::transport::op_id;
/// // FNV-1a reference values: offset basis for "", one round for "a".
/// assert_eq!(op_id(""), 0x811c9dc5);
/// assert_eq!(op_id("a"), 0xe40c292c);
/// assert_ne!(op_id("hier.intra"), op_id("hier.inter"));
/// ```
///
/// # Collision analysis
///
/// Two distinct op names hashing to the same id would let unrelated
/// collectives match each other's messages — silent data corruption, not
/// a crash. With the [`KNOWN_OP_NAMES`] census of k = 17 in-tree names,
/// the birthday bound on any collision is k(k−1)/2 / 2³² ≈ 3.2 × 10⁻⁸;
/// negotiation's per-call names (`"{kind}.{seq}"`) are never hashed —
/// they travel as strings — so the hashed universe really is this static
/// list. Rather than trusting the estimate, [`fabric`] debug-asserts
/// pairwise distinctness over the census (and a unit test checks it in
/// every build), so adding a colliding name fails loudly at the first
/// test run instead of corrupting a training job.
pub fn op_id(name: &str) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for b in name.as_bytes() {
        h ^= *b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

/// Census of every op name the crate passes to [`op_id`] (tag-forming
/// call sites in `collective/`, `context.rs`, `nonblocking/` and
/// `transport/portable.rs`). Keep in sync when adding a collective: the
/// guard in [`fabric`] and the `known_op_ids_are_collision_free` test
/// check pairwise distinctness of exactly this list.
pub const KNOWN_OP_NAMES: [&str; 17] = [
    "barrier",
    "broadcast",
    "byteps_allreduce",
    "hier.bcast",
    "hier.inter",
    "hier.intra",
    "nb.neighbor",
    "nb.ring",
    "negotiation.allreduce",
    "negotiation.hier_neighbor_allreduce",
    "negotiation.neighbor_allgather",
    "negotiation.neighbor_allreduce",
    "neighbor_allgather",
    "neighbor_allreduce",
    "portable.neighbor_allreduce",
    "ps_allreduce",
    "ring_allreduce",
];

/// True when every pair of [`KNOWN_OP_NAMES`] hashes to a distinct id.
fn known_op_ids_distinct() -> bool {
    let ids: Vec<u32> = KNOWN_OP_NAMES.iter().map(|n| op_id(n)).collect();
    ids.iter().enumerate().all(|(i, a)| ids[..i].iter().all(|b| a != b))
}

/// A point-to-point message. The payload is `Arc`-shared so one tensor can
/// be sent to several destinations without copying (a hot-path optimization
/// measured in EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct Message {
    /// Sending rank.
    pub src: usize,
    /// Operation/round tag the receiver matches on.
    pub tag: Tag,
    /// Tensor payload (shared across multi-destination sends).
    pub payload: Arc<Vec<f32>>,
    /// Virtual time at which this message arrives at the destination.
    pub arrival_vtime: f64,
}

/// Receiving endpoint with MPI-style `(src, tag)` matching.
pub struct Mailbox {
    rank: usize,
    rx: Receiver<Message>,
    /// Out-of-order arrivals buffered by (src, tag). Deques so a matched
    /// receive pops the oldest arrival in O(1) — `recv_match`/`recv_any`
    /// hit this on every out-of-order round.
    stash: HashMap<(usize, Tag), VecDeque<Message>>,
}

/// Sending side: the cloneable sender handles for every rank. The handle
/// table is `Arc`-shared so a per-rank clone costs one pointer, not
/// `O(n)` senders — at 10k ranks a by-value table would dominate the
/// per-rank memory budget.
#[derive(Clone)]
pub struct Postman {
    senders: Arc<Vec<Sender<Message>>>,
}

/// Create the transport fabric for `n` nodes: one mailbox per rank plus a
/// shared postman.
pub fn fabric(n: usize) -> (Vec<Mailbox>, Postman) {
    debug_assert!(
        known_op_ids_distinct(),
        "op_id collision inside KNOWN_OP_NAMES — rename the new collective"
    );
    let mut senders = Vec::with_capacity(n);
    let mut mailboxes = Vec::with_capacity(n);
    for rank in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        mailboxes.push(Mailbox { rank, rx, stash: HashMap::new() });
    }
    (mailboxes, Postman { senders: Arc::new(senders) })
}

impl Postman {
    /// Number of reachable ranks.
    pub fn size(&self) -> usize {
        self.senders.len()
    }

    /// Deliver a message to `dst`. Panics if `dst` is out of range; returns
    /// an error if the destination mailbox was dropped (node exited).
    pub fn send(&self, dst: usize, msg: Message) -> anyhow::Result<()> {
        self.senders[dst]
            .send(msg)
            .map_err(|_| anyhow::anyhow!("rank {dst} mailbox closed"))
    }
}

impl Mailbox {
    /// The rank this mailbox belongs to.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Blocking receive of the next message matching `(src, tag)`,
    /// buffering any non-matching arrivals.
    pub fn recv_match(&mut self, src: usize, tag: Tag) -> anyhow::Result<Message> {
        if let Some(q) = self.stash.get_mut(&(src, tag)) {
            if let Some(m) = q.pop_front() {
                if q.is_empty() {
                    self.stash.remove(&(src, tag));
                }
                return Ok(m);
            }
        }
        loop {
            let m = self
                .rx
                .recv()
                .map_err(|_| anyhow::anyhow!("rank {} transport closed", self.rank))?;
            if m.src == src && m.tag == tag {
                return Ok(m);
            }
            self.stash.entry((m.src, m.tag)).or_default().push_back(m);
        }
    }

    /// Blocking receive of the next message with `tag` from *any* source.
    pub fn recv_any(&mut self, tag: Tag) -> anyhow::Result<Message> {
        let key = self.stash.keys().find(|&&(_, t)| t == tag).copied();
        if let Some(key) = key {
            let q = self.stash.get_mut(&key).unwrap();
            let m = q.pop_front().expect("stash entries are non-empty");
            if q.is_empty() {
                self.stash.remove(&key);
            }
            return Ok(m);
        }
        loop {
            let m = self
                .rx
                .recv()
                .map_err(|_| anyhow::anyhow!("rank {} transport closed", self.rank))?;
            if m.tag == tag {
                return Ok(m);
            }
            self.stash.entry((m.src, m.tag)).or_default().push_back(m);
        }
    }

    /// Drain everything currently sitting in the channel into the stash
    /// without blocking (the event-loop backend drains before parking so
    /// no already-delivered message can be missed).
    fn drain_channel(&mut self) {
        while let Ok(m) = self.rx.try_recv() {
            self.stash.entry((m.src, m.tag)).or_default().push_back(m);
        }
    }

    /// Non-blocking receive of the next message matching `(src, tag)`.
    /// Returns `None` when no such message has been delivered yet.
    pub fn try_recv_match(&mut self, src: usize, tag: Tag) -> Option<Message> {
        self.drain_channel();
        let q = self.stash.get_mut(&(src, tag))?;
        let m = q.pop_front().expect("stash entries are non-empty");
        if q.is_empty() {
            self.stash.remove(&(src, tag));
        }
        Some(m)
    }

    /// Non-blocking receive of the next message with `tag` from any
    /// source, picking the **lowest source rank** among candidates so the
    /// choice is deterministic (the blocking `recv_any` inherits hash-map
    /// iteration order, which varies run to run — unusable under the
    /// reproducible event-loop backend).
    pub fn try_recv_any(&mut self, tag: Tag) -> Option<Message> {
        self.drain_channel();
        let src = self
            .stash
            .keys()
            .filter(|&&(_, t)| t == tag)
            .map(|&(s, _)| s)
            .min()?;
        self.try_recv_match(src, tag)
    }

    /// Number of stashed (unmatched) messages — used by shutdown sanity
    /// checks and tests.
    pub fn stashed(&self) -> usize {
        self.stash.values().map(|v| v.len()).sum()
    }

    /// Block up to `timeout` (wall clock) for one more message to land,
    /// stashing it. Returns `true` when a message arrived, `false` on
    /// timeout or a closed channel. Deadline-based receives under
    /// `ExecMode::Threads` poll through this tick so a dead peer cannot
    /// hold the receiver forever the way the bare blocking `recv` does.
    pub fn wait_for_message(&mut self, timeout: std::time::Duration) -> bool {
        match self.rx.recv_timeout(timeout) {
            Ok(m) => {
                self.stash.entry((m.src, m.tag)).or_default().push_back(m);
                true
            }
            Err(_) => false,
        }
    }

    /// Virtual arrival time of the next `(src, tag)` match, without
    /// consuming it. Per-link delivery is FIFO (the fault layer keeps
    /// arrivals monotone per link), so the queue front is the earliest.
    pub fn earliest_match(&mut self, src: usize, tag: Tag) -> Option<f64> {
        self.drain_channel();
        self.stash.get(&(src, tag)).and_then(|q| q.front()).map(|m| m.arrival_vtime)
    }

    /// `(src, arrival_vtime)` of the earliest-arriving message with `tag`
    /// from any source (ties broken toward the lowest source rank, so the
    /// choice is deterministic across runs and exec modes).
    pub fn earliest_any(&mut self, tag: Tag) -> Option<(usize, f64)> {
        self.drain_channel();
        self.stash
            .iter()
            .filter(|(&(_, t), q)| t == tag && !q.is_empty())
            .map(|(&(s, _), q)| (s, q.front().map(|m| m.arrival_vtime).unwrap_or(f64::INFINITY)))
            .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
    }
}

/// Per-node virtual clock plus NIC occupancy, shared with the cost model.
///
/// `recv_busy_until` is shared (a sender reserves the receiver's ingress
/// port), matching the half-duplex NIC serialization that makes
/// many-to-one patterns (parameter server) slow in the paper's Table I.
#[derive(Clone)]
pub struct VClock {
    /// This node's local virtual time (seconds).
    now: Arc<Mutex<f64>>,
    /// When this node's egress port frees up.
    send_busy: Arc<Mutex<f64>>,
    /// When this node's ingress port frees up (contended by remote senders).
    recv_busy: Arc<Mutex<f64>>,
    /// Deadline of the finite-deadline receive this node is currently
    /// parked in (`INFINITY` = not parked). Published under
    /// `ExecMode::Threads` so peers waiting *on this node* can break
    /// mutual-wait cycles: two ranks parked on each other would otherwise
    /// freeze both virtual clocks and poll forever.
    wait_deadline: Arc<Mutex<f64>>,
}

impl Default for VClock {
    fn default() -> Self {
        Self::new()
    }
}

impl VClock {
    /// A clock at virtual time zero with idle ports.
    pub fn new() -> Self {
        VClock {
            now: Arc::new(Mutex::new(0.0)),
            send_busy: Arc::new(Mutex::new(0.0)),
            recv_busy: Arc::new(Mutex::new(0.0)),
            wait_deadline: Arc::new(Mutex::new(f64::INFINITY)),
        }
    }

    /// Publish the deadline of a finite-deadline receive park (Threads
    /// mode). Cleared with [`VClock::clear_wait_deadline`] on delivery or
    /// expiry.
    pub fn set_wait_deadline(&self, deadline: f64) {
        *self.wait_deadline.lock().unwrap() = deadline;
    }

    /// Clear the published receive-park deadline.
    pub fn clear_wait_deadline(&self) {
        *self.wait_deadline.lock().unwrap() = f64::INFINITY;
    }

    /// The published receive-park deadline (`INFINITY` when not parked in
    /// a finite-deadline wait).
    pub fn wait_deadline(&self) -> f64 {
        *self.wait_deadline.lock().unwrap()
    }

    /// Current local virtual time in seconds.
    pub fn now(&self) -> f64 {
        *self.now.lock().unwrap()
    }

    /// Advance local time to at least `t`.
    pub fn advance_to(&self, t: f64) {
        let mut now = self.now.lock().unwrap();
        if t > *now {
            *now = t;
        }
    }

    /// Add compute time `dt` to local time.
    pub fn elapse(&self, dt: f64) {
        *self.now.lock().unwrap() += dt;
    }

    /// Reserve this node's egress port starting no earlier than `start` for
    /// `duration`; returns the transmission finish time.
    pub fn reserve_send(&self, start: f64, duration: f64) -> f64 {
        let mut busy = self.send_busy.lock().unwrap();
        let begin = start.max(*busy);
        *busy = begin + duration;
        *busy
    }

    /// Reserve the node's ingress port (called by the *sender* on the
    /// receiver's clock): transmission occupies the receiver NIC too.
    pub fn reserve_recv(&self, start: f64, duration: f64) -> f64 {
        let mut busy = self.recv_busy.lock().unwrap();
        let begin = start.max(*busy);
        *busy = begin + duration;
        *busy
    }

    /// Reset all lanes to zero (between benchmark repetitions).
    pub fn reset(&self) {
        *self.now.lock().unwrap() = 0.0;
        *self.send_busy.lock().unwrap() = 0.0;
        *self.recv_busy.lock().unwrap() = 0.0;
        *self.wait_deadline.lock().unwrap() = f64::INFINITY;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_recv_roundtrip() {
        let (mut boxes, post) = fabric(2);
        let tag = make_tag(op_id("x"), 0);
        post.send(1, Message { src: 0, tag, payload: Arc::new(vec![1.0, 2.0]), arrival_vtime: 0.5 })
            .unwrap();
        let m = boxes[1].recv_match(0, tag).unwrap();
        assert_eq!(*m.payload, vec![1.0, 2.0]);
        assert_eq!(m.arrival_vtime, 0.5);
    }

    #[test]
    fn out_of_order_arrivals_are_stashed() {
        let (mut boxes, post) = fabric(3);
        let t_a = make_tag(op_id("a"), 0);
        let t_b = make_tag(op_id("b"), 0);
        post.send(2, Message { src: 0, tag: t_a, payload: Arc::new(vec![1.0]), arrival_vtime: 0.0 })
            .unwrap();
        post.send(2, Message { src: 1, tag: t_b, payload: Arc::new(vec![2.0]), arrival_vtime: 0.0 })
            .unwrap();
        // Ask for (1, b) first even though (0, a) arrived first.
        let m = boxes[2].recv_match(1, t_b).unwrap();
        assert_eq!(*m.payload, vec![2.0]);
        assert_eq!(boxes[2].stashed(), 1);
        let m = boxes[2].recv_match(0, t_a).unwrap();
        assert_eq!(*m.payload, vec![1.0]);
        assert_eq!(boxes[2].stashed(), 0);
    }

    #[test]
    fn same_pair_ordering_by_round() {
        let (mut boxes, post) = fabric(2);
        let op = op_id("iter");
        for round in 0..4u32 {
            post.send(
                1,
                Message {
                    src: 0,
                    tag: make_tag(op, round),
                    payload: Arc::new(vec![round as f32]),
                    arrival_vtime: 0.0,
                },
            )
            .unwrap();
        }
        // Receive rounds in reverse order: stash must hold the rest.
        for round in (0..4u32).rev() {
            let m = boxes[1].recv_match(0, make_tag(op, round)).unwrap();
            assert_eq!(*m.payload, vec![round as f32]);
        }
    }

    #[test]
    fn recv_any_matches_any_source() {
        let (mut boxes, post) = fabric(3);
        let tag = make_tag(op_id("g"), 1);
        post.send(0, Message { src: 2, tag, payload: Arc::new(vec![9.0]), arrival_vtime: 0.0 }).unwrap();
        let m = boxes[0].recv_any(tag).unwrap();
        assert_eq!(m.src, 2);
    }

    #[test]
    fn closed_mailbox_errors() {
        let (boxes, post) = fabric(2);
        drop(boxes);
        let tag = make_tag(0, 0);
        assert!(post.send(1, Message { src: 0, tag, payload: Arc::new(vec![]), arrival_vtime: 0.0 }).is_err());
    }

    #[test]
    fn vclock_ports_serialize() {
        let c = VClock::new();
        let f1 = c.reserve_send(0.0, 1.0);
        let f2 = c.reserve_send(0.0, 1.0);
        assert_eq!(f1, 1.0);
        assert_eq!(f2, 2.0, "second transfer waits for the port");
        c.advance_to(5.0);
        assert_eq!(c.now(), 5.0);
        c.elapse(0.5);
        assert_eq!(c.now(), 5.5);
        c.reset();
        assert_eq!(c.now(), 0.0);
    }

    #[test]
    fn op_ids_distinct_for_distinct_names() {
        assert_ne!(op_id("neighbor.allreduce.x"), op_id("neighbor.allreduce.y"));
        assert_eq!(op_id("same"), op_id("same"));
    }

    #[test]
    fn known_op_ids_are_collision_free() {
        assert!(known_op_ids_distinct(), "op_id collision among in-tree op names");
        // The census must at least stay deduplicated as a name list too.
        for (i, a) in KNOWN_OP_NAMES.iter().enumerate() {
            assert!(!KNOWN_OP_NAMES[..i].contains(a), "duplicate census entry {a}");
        }
    }
}
