//! Tensor fusion (paper §VI-C).
//!
//! Batches several small tensors into one contiguous buffer so one message
//! pays one latency: 1) copy tensors into the fusion buffer, 2) communicate
//! the buffer, 3) scatter the results back. BlueFog applies it to
//! `allreduce`, `neighbor_allreduce` and the hierarchical variant; the paper
//! notes the optimal buffer size is *smaller* for neighbor communication
//! because its latency term is O(1) rather than O(n).
//!
//! [`FusionBuffer`] implements the pack/unpack steps; the non-blocking
//! communication thread ([`crate::nonblocking`]) applies the policy, fusing
//! queued requests with identical communication structure up to the
//! threshold.
//!
//! Fusion composes with communication compression ([`crate::compress`]) in
//! a fixed order: pack first, then encode the *fused* buffer as one wire
//! stream (so a fusion group pays one compression header, and top-k
//! selection sees the whole group's coordinates); symmetrically, receives
//! are decoded back to the dense fused layout before slots are scattered.

/// Layout record of one fused tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusedSlot {
    /// Element offset of this tensor inside the fused buffer.
    pub offset: usize,
    /// Element count of this tensor.
    pub len: usize,
}

/// A contiguous pack of several tensors.
#[derive(Debug, Clone, Default)]
pub struct FusionBuffer {
    data: Vec<f32>,
    slots: Vec<FusedSlot>,
}

impl FusionBuffer {
    /// An empty buffer to [`FusionBuffer::push`] tensors into.
    pub fn new() -> Self {
        Self::default()
    }

    /// Pack a list of tensors; the i-th slot corresponds to the i-th input.
    pub fn pack(tensors: &[&[f32]]) -> Self {
        Self::pack_into_vec(tensors, Vec::new())
    }

    /// Like [`FusionBuffer::pack`], but reusing `storage` as the backing
    /// buffer (cleared first) so a communication thread can recycle one
    /// allocation across fusion rounds. Recover it with
    /// [`FusionBuffer::into_data`].
    pub fn pack_into_vec(tensors: &[&[f32]], mut storage: Vec<f32>) -> Self {
        let total: usize = tensors.iter().map(|t| t.len()).sum();
        storage.clear();
        storage.reserve(total);
        let mut slots = Vec::with_capacity(tensors.len());
        for t in tensors {
            slots.push(FusedSlot { offset: storage.len(), len: t.len() });
            storage.extend_from_slice(t);
        }
        FusionBuffer { data: storage, slots }
    }

    /// Rebuild a buffer from an already-packed payload and its slot table,
    /// for callers that interleave packing with another per-slot pass (the
    /// fused compress-into-pack path in [`crate::compress`]). The slots
    /// must tile `data` contiguously from offset 0, exactly as
    /// [`FusionBuffer::pack_into_vec`] lays them out.
    pub fn from_packed(data: Vec<f32>, slots: Vec<FusedSlot>) -> Self {
        debug_assert_eq!(
            slots.iter().map(|s| s.len).sum::<usize>(),
            data.len(),
            "packed slots must tile the payload"
        );
        debug_assert!(slots
            .iter()
            .scan(0usize, |off, s| {
                let ok = s.offset == *off;
                *off += s.len;
                Some(ok)
            })
            .all(|ok| ok));
        FusionBuffer { data, slots }
    }

    /// Consume the buffer, returning the backing allocation for reuse.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Append one more tensor, returning its slot index.
    pub fn push(&mut self, tensor: &[f32]) -> usize {
        self.slots.push(FusedSlot { offset: self.data.len(), len: tensor.len() });
        self.data.extend_from_slice(tensor);
        self.slots.len() - 1
    }

    /// The fused payload.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Number of fused tensors.
    pub fn count(&self) -> usize {
        self.slots.len()
    }

    /// Total elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when no elements are packed.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Total bytes of the fused payload.
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Split a *result* buffer (same layout) back into per-tensor vectors.
    pub fn unpack(&self, result: &[f32]) -> Vec<Vec<f32>> {
        assert_eq!(result.len(), self.data.len(), "fused result length mismatch");
        self.slots
            .iter()
            .map(|s| result[s.offset..s.offset + s.len].to_vec())
            .collect()
    }

    /// Scatter-free unpack: write each slot of `result` straight into the
    /// corresponding caller-owned output vector (cleared first), instead of
    /// allocating one fresh `Vec` per slot like [`FusionBuffer::unpack`].
    /// The communication thread reuses each request's own input buffer as
    /// its reply, so a fused round allocates nothing.
    pub fn unpack_into(&self, result: &[f32], outs: &mut [Vec<f32>]) {
        assert_eq!(result.len(), self.data.len(), "fused result length mismatch");
        assert_eq!(outs.len(), self.slots.len(), "fused output arity mismatch");
        for (i, out) in outs.iter_mut().enumerate() {
            self.unpack_slot_into(result, i, out);
        }
    }

    /// Scatter slot `i` of `result` into `out` (cleared first).
    pub fn unpack_slot_into(&self, result: &[f32], i: usize, out: &mut Vec<f32>) {
        let s = &self.slots[i];
        assert!(
            s.offset + s.len <= result.len(),
            "fused slot {i} out of bounds: offset {} + len {} > result len {}",
            s.offset,
            s.len,
            result.len()
        );
        out.clear();
        out.extend_from_slice(&result[s.offset..s.offset + s.len]);
    }

    /// View of slot `i` inside a result buffer.
    pub fn slot<'a>(&self, result: &'a [f32], i: usize) -> &'a [f32] {
        let s = &self.slots[i];
        assert!(
            s.offset + s.len <= result.len(),
            "fused slot {i} out of bounds: offset {} + len {} > result len {}",
            s.offset,
            s.len,
            result.len()
        );
        &result[s.offset..s.offset + s.len]
    }
}

/// Greedy fusion policy: group consecutive requests while the packed size
/// stays under `threshold_bytes`. Returns group boundaries `[start, end)`.
/// `threshold_bytes == 0` disables fusion (every request alone).
pub fn fusion_groups(sizes_bytes: &[usize], threshold_bytes: usize) -> Vec<(usize, usize)> {
    let mut groups = vec![];
    let mut start = 0;
    while start < sizes_bytes.len() {
        let mut end = start + 1;
        if threshold_bytes > 0 {
            let mut acc = sizes_bytes[start];
            while end < sizes_bytes.len() && acc + sizes_bytes[end] <= threshold_bytes {
                acc += sizes_bytes[end];
                end += 1;
            }
        }
        groups.push((start, end));
        start = end;
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32];
        let c = vec![4.0f32, 5.0, 6.0];
        let buf = FusionBuffer::pack(&[&a, &b, &c]);
        assert_eq!(buf.len(), 6);
        assert_eq!(buf.count(), 3);
        let out = buf.unpack(buf.data());
        assert_eq!(out, vec![a, b, c]);
    }

    #[test]
    fn unpack_of_transformed_result() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let buf = FusionBuffer::pack(&[&a, &b]);
        let doubled: Vec<f32> = buf.data().iter().map(|x| x * 2.0).collect();
        let out = buf.unpack(&doubled);
        assert_eq!(out[0], vec![2.0, 4.0]);
        assert_eq!(out[1], vec![6.0, 8.0]);
    }

    #[test]
    fn push_returns_slot_indices() {
        let mut buf = FusionBuffer::new();
        assert_eq!(buf.push(&[1.0]), 0);
        assert_eq!(buf.push(&[2.0, 3.0]), 1);
        assert_eq!(buf.slot(buf.data(), 1), &[2.0, 3.0]);
    }

    #[test]
    fn empty_tensor_slots_are_preserved() {
        let a: Vec<f32> = vec![];
        let b = vec![1.0f32];
        let buf = FusionBuffer::pack(&[&a, &b]);
        let out = buf.unpack(buf.data());
        assert!(out[0].is_empty());
        assert_eq!(out[1], vec![1.0]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn unpack_validates_length() {
        let buf = FusionBuffer::pack(&[&[1.0f32, 2.0][..]]);
        buf.unpack(&[1.0]);
    }

    #[test]
    fn unpack_into_matches_unpack_and_reuses_buffers() {
        let a = vec![1.0f32, 2.0];
        let b: Vec<f32> = vec![];
        let c = vec![3.0f32, 4.0, 5.0];
        let buf = FusionBuffer::pack(&[&a, &b, &c]);
        let halved: Vec<f32> = buf.data().iter().map(|x| x * 0.5).collect();
        let want = buf.unpack(&halved);
        // Pre-sized outputs with stale contents get overwritten in place.
        let mut outs = vec![vec![9.0f32; 7], vec![9.0], vec![]];
        let caps: Vec<usize> = outs.iter().map(|o| o.capacity()).collect();
        buf.unpack_into(&halved, &mut outs);
        assert_eq!(outs, want);
        assert!(outs[0].capacity() >= caps[0], "slot 0 should reuse its allocation");
    }

    #[test]
    fn pack_into_vec_reuses_storage_roundtrip() {
        let storage = Vec::with_capacity(64);
        let buf = FusionBuffer::pack_into_vec(&[&[1.0f32, 2.0][..], &[3.0f32][..]], storage);
        assert_eq!(buf.data(), &[1.0, 2.0, 3.0]);
        let recovered = buf.into_data();
        assert!(recovered.capacity() >= 64, "backing allocation not recovered");
    }

    #[test]
    fn from_packed_matches_pack() {
        let a = vec![1.0f32, 2.0];
        let b: Vec<f32> = vec![];
        let c = vec![3.0f32, 4.0, 5.0];
        let want = FusionBuffer::pack(&[&a, &b, &c]);
        let slots = vec![
            FusedSlot { offset: 0, len: 2 },
            FusedSlot { offset: 2, len: 0 },
            FusedSlot { offset: 2, len: 3 },
        ];
        let buf = FusionBuffer::from_packed(vec![1.0, 2.0, 3.0, 4.0, 5.0], slots);
        assert_eq!(buf.data(), want.data());
        assert_eq!(buf.unpack(buf.data()), want.unpack(want.data()));
    }

    #[test]
    fn nbytes_uses_f32_width() {
        let buf = FusionBuffer::pack(&[&[1.0f32, 2.0, 3.0][..]]);
        assert_eq!(buf.nbytes(), 3 * std::mem::size_of::<f32>());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slot_view_checks_bounds() {
        let buf = FusionBuffer::pack(&[&[1.0f32, 2.0][..]]);
        buf.slot(&[1.0], 0);
    }

    #[test]
    fn fusion_groups_respect_threshold() {
        // sizes in bytes: 4 tensors of 100B each, threshold 250B.
        let groups = fusion_groups(&[100, 100, 100, 100], 250);
        assert_eq!(groups, vec![(0, 2), (2, 4)]);
    }

    #[test]
    fn zero_threshold_disables_fusion() {
        let groups = fusion_groups(&[10, 10, 10], 0);
        assert_eq!(groups, vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn oversized_tensor_gets_own_group() {
        let groups = fusion_groups(&[1000, 10, 10], 100);
        assert_eq!(groups, vec![(0, 1), (1, 3)]);
    }
}
