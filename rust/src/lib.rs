//! # bluefog — decentralized optimization and deep-learning runtime
//!
//! A Rust + JAX + Pallas reproduction of *BlueFog: Make Decentralized
//! Algorithms Practical for Optimization and Deep Learning* (Ying, Yuan, Hu,
//! Chen, Yin; 2021).
//!
//! The crate provides:
//!
//! - [`topology`] — directed/undirected graph topologies and weight matrices
//!   (pull/push/doubly-stochastic), including the exponential graphs the
//!   paper champions.
//! - [`transport`] — in-process point-to-point message passing between
//!   simulated nodes (the MPI/NCCL substitute).
//! - [`simnet`] — a virtual-clock network cost model (bandwidth/latency per
//!   link, two-tier NVLink/NIC hierarchy) standing in for the paper's AWS
//!   testbed.
//! - [`collective`] — global collectives (ring allreduce, parameter server,
//!   BytePS) and partial averaging (`neighbor_allreduce`, dynamic and
//!   hierarchical variants).
//! - [`window`] — asynchronous one-sided window operations
//!   (`win_create`/`put`/`get`/`accumulate`/`update`) with distributed
//!   mutexes, used by asynchronous push-sum.
//! - [`negotiation`] — the rank-0 negotiation service: readiness, operation
//!   matching and dynamic-topology validity checks.
//! - [`fusion`] — tensor-fusion buffers batching small messages.
//! - [`compress`] — communication compression (top-k / random-k / u8
//!   quantization / PowerGossip-style low-rank) with per-stream error
//!   feedback, applied to the neighbor-averaging payloads.
//! - [`pool`] — rank-local tensor buffer pool feeding the zero-allocation
//!   communication hot path (pooled payloads, reclaimed receives).
//! - [`nonblocking`] — non-blocking communication handles backed by a
//!   dedicated per-node communication thread (compute/comm overlap).
//! - [`parallel`] — rank-local worker pool sharding multi-MB combines and
//!   codec encodes across `intra_threads` (deterministic fixed-boundary
//!   shards; 1 = serial).
//! - [`optim`] — decentralized optimizers as a composable pipeline:
//!   `AlgoStep` kernels (DGD, Exact-Diffusion, Gradient-Tracking,
//!   push-sum, DmSGD/QG-DmSGD) driven by a `CommSchedule` (every step,
//!   DIGEST-style `H` local steps, periodic global sync) and a
//!   `NeighborWeighting` policy (static MH rows or AL-DSGD dynamic rows),
//!   plus `DecentralizedAdmm` and the name→algorithm registry
//!   (`make_optimizer_cfg`).
//! - [`runtime`] — the PJRT runtime executing AOT-compiled JAX/Pallas
//!   artifacts from the Rust hot path.
//! - [`launcher`] — the SPMD launcher (`bfrun` analogue) spawning one thread
//!   per simulated node.
//! - [`training`] — the deep-learning training driver used by the paper's
//!   DNN experiments.
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for the
//! reproduced tables and figures.

#![warn(missing_docs)]

pub mod cli;
pub mod collective;
pub mod compress;
pub mod config;
pub mod context;
pub mod fusion;
pub mod launcher;
pub mod metrics;
pub mod negotiation;
pub mod nonblocking;
pub mod optim;
pub mod parallel;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod simnet;
pub mod tensor;
pub mod timeline;
pub mod topology;
pub mod training;
pub mod transport;
pub mod window;

pub use context::NodeContext;
pub use launcher::{run_spmd, SpmdConfig};
pub use topology::graph::Graph;
pub use topology::weights::WeightMatrix;
