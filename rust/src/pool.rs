//! Rank-local tensor buffer pool — the zero-allocation hot path.
//!
//! Every collective round used to allocate fresh `Vec<f32>`s: one per
//! outgoing payload, one per combine output, one per fusion pack. Under
//! D-PSGD-style iteration-heavy training that is thousands of large
//! allocations per second per rank, all of identical sizes — exactly the
//! pattern a free-list removes (paper §VI's zero-copy theme; DIGEST-style
//! buffer reuse across rounds).
//!
//! [`BufferPool`] is a cheap-clone handle over a size-bucketed free-list:
//!
//! - **Checkout** ([`BufferPool::checkout`], [`BufferPool::checkout_copy`],
//!   [`BufferPool::checkout_scaled`]) pops a buffer whose capacity covers
//!   the request (buckets are powers of two) or allocates on miss; hits and
//!   misses are counted so benchmarks can report the hit rate.
//! - The returned [`PoolBuf`] guard derefs to `[f32]` and **returns its
//!   buffer to the pool on drop**; [`PoolBuf::into_vec`] /
//!   [`PoolBuf::into_arc`] detach the storage for APIs that take ownership
//!   (detached buffers come back via [`BufferPool::recycle_vec`] or
//!   [`BufferPool::reclaim`]).
//! - **Reclaim** ([`BufferPool::reclaim`]) recovers the storage of a
//!   received [`crate::transport::Message`] payload once the last `Arc`
//!   clone drops — the receive side of a fan-out send feeds the pool, so
//!   symmetric traffic keeps every rank's free-list warm.
//!
//! The pool is rank-local (each [`crate::context::NodeContext`] and each
//! communication thread owns one); buffers migrate between ranks through
//! reclaim, which is fine — a free-list only needs *some* buffer of the
//! right size, not the same one. [`HotPath`] selects between this pooled
//! path and the original allocating path so `examples/perf_probe.rs` can
//! A/B them on identical workloads (`BENCH_hotpath.json`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Smallest bucket (elements): buffers below this are not worth pooling.
const MIN_BUCKET: usize = 64;

/// Free-list depth per bucket; excess recycles are dropped to bound memory.
const MAX_PER_BUCKET: usize = 16;

/// Which implementation the communication hot path uses.
///
/// `Naive` allocates a fresh `Vec` for every payload and combine output and
/// uses the original k-pass kernels; `Pooled` draws payloads and scratch
/// from the rank-local [`BufferPool`] and combines with the single-pass
/// blocked kernels. Semantics are identical (property-tested); only
/// allocation and traversal order differ. Mode-independent structural
/// improvements (in-place fused replies, in-place ring reduction, move-
/// instead-of-clone receives) apply in both modes, so `Naive` isolates the
/// pool/kernel effect rather than reproducing the seed revision bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HotPath {
    /// Fresh allocation per buffer, k-pass combine kernels.
    Naive,
    /// Reuse pooled buffers and blocked combine kernels.
    #[default]
    Pooled,
}

/// Counters describing pool behavior since the last reset.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    /// Checkouts served from the free-list.
    pub hits: u64,
    /// Checkouts that had to allocate.
    pub misses: u64,
    /// Buffers returned to the free-list.
    pub recycled: u64,
    /// Buffers dropped instead of shelved (bucket full or too small).
    pub dropped: u64,
    /// Buffers currently shelved across all buckets.
    pub shelved: usize,
}

impl PoolStats {
    /// Fraction of checkouts served from the free-list (1.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct PoolInner {
    /// Free buffers keyed by power-of-two bucket (buffer capacity >= key).
    shelves: Mutex<HashMap<usize, Vec<Vec<f32>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    recycled: AtomicU64,
    dropped: AtomicU64,
}

/// Cheap-clone handle to a rank-local free-list of `f32` buffers.
#[derive(Clone)]
pub struct BufferPool {
    inner: Arc<PoolInner>,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for BufferPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BufferPool").field("stats", &self.stats()).finish()
    }
}

/// Bucket a checkout of `len` elements lands in.
fn bucket_for(len: usize) -> usize {
    len.next_power_of_two().max(MIN_BUCKET)
}

/// Bucket a returning buffer of capacity `cap` is shelved under: the
/// largest power of two `<= cap`, so any checkout from that bucket is
/// guaranteed `capacity >= bucket >= requested len`.
fn shelf_for(cap: usize) -> Option<usize> {
    if cap < MIN_BUCKET {
        None
    } else {
        Some(1usize << (usize::BITS - 1 - cap.leading_zeros()))
    }
}

impl BufferPool {
    /// An empty pool.
    pub fn new() -> Self {
        BufferPool {
            inner: Arc::new(PoolInner {
                shelves: Mutex::new(HashMap::new()),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
                recycled: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
            }),
        }
    }

    /// Pop a cleared buffer with capacity >= `len`, or allocate one.
    fn checkout_raw(&self, len: usize) -> Vec<f32> {
        let bucket = bucket_for(len);
        let popped = self.inner.shelves.lock().unwrap().get_mut(&bucket).and_then(Vec::pop);
        match popped {
            Some(mut v) => {
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                v.clear();
                v
            }
            None => {
                self.inner.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(bucket)
            }
        }
    }

    /// Check out a zero-filled buffer of `len` elements.
    pub fn checkout(&self, len: usize) -> PoolBuf {
        let mut data = self.checkout_raw(len);
        data.resize(len, 0.0);
        PoolBuf { data, pool: Some(self.clone()) }
    }

    /// Check out a buffer initialized to a copy of `src` (single pass, no
    /// zero-fill).
    pub fn checkout_copy(&self, src: &[f32]) -> PoolBuf {
        let mut data = self.checkout_raw(src.len());
        data.extend_from_slice(src);
        PoolBuf { data, pool: Some(self.clone()) }
    }

    /// Check out a buffer initialized to `s * src` (single fused pass).
    pub fn checkout_scaled(&self, src: &[f32], s: f32) -> PoolBuf {
        let mut data = self.checkout_raw(src.len());
        data.extend(src.iter().map(|&x| s * x));
        PoolBuf { data, pool: Some(self.clone()) }
    }

    /// Check out an *empty* buffer with capacity covering `cap` elements —
    /// encode/decode scratch for the compression layer, which `extend`s the
    /// buffer itself ([`crate::compress`]).
    pub fn checkout_empty(&self, cap: usize) -> PoolBuf {
        PoolBuf { data: self.checkout_raw(cap), pool: Some(self.clone()) }
    }

    /// Return a detached buffer to the free-list (contents are discarded on
    /// the next checkout). Buffers that are too small or land in a full
    /// bucket are dropped.
    pub fn recycle_vec(&self, v: Vec<f32>) {
        let Some(bucket) = shelf_for(v.capacity()) else {
            if v.capacity() > 0 {
                self.inner.dropped.fetch_add(1, Ordering::Relaxed);
            }
            return;
        };
        let mut shelves = self.inner.shelves.lock().unwrap();
        let shelf = shelves.entry(bucket).or_default();
        if shelf.len() < MAX_PER_BUCKET {
            shelf.push(v);
            self.inner.recycled.fetch_add(1, Ordering::Relaxed);
        } else {
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Recover a message payload's storage if this is the last `Arc` clone
    /// (the common case once every receiver of a fan-out has combined it).
    pub fn reclaim(&self, payload: Arc<Vec<f32>>) {
        if let Ok(v) = Arc::try_unwrap(payload) {
            self.recycle_vec(v);
        }
    }

    // Mode-gated variants shared by the blocking (`NodeContext`) and
    // non-blocking (comm thread `Endpoint`) transports, so the
    // pooled-vs-naive allocation policy is written exactly once.

    /// An outgoing payload holding a copy of `src`: pooled checkout under
    /// [`HotPath::Pooled`], fresh allocation under [`HotPath::Naive`].
    pub fn payload_from(&self, mode: HotPath, src: &[f32]) -> Arc<Vec<f32>> {
        match mode {
            HotPath::Naive => Arc::new(src.to_vec()),
            HotPath::Pooled => self.checkout_copy(src).into_arc(),
        }
    }

    /// An outgoing payload holding `s * src`, built in one fused pass.
    pub fn scaled_payload(&self, mode: HotPath, src: &[f32], s: f32) -> Arc<Vec<f32>> {
        match mode {
            HotPath::Naive => Arc::new(src.iter().map(|&x| s * x).collect()),
            HotPath::Pooled => self.checkout_scaled(src, s).into_arc(),
        }
    }

    /// [`BufferPool::reclaim`] under [`HotPath::Pooled`], plain drop under
    /// [`HotPath::Naive`].
    pub fn reclaim_if(&self, mode: HotPath, payload: Arc<Vec<f32>>) {
        if mode == HotPath::Pooled {
            self.reclaim(payload);
        }
    }

    /// The receive-combine kernel of the hot path:
    /// `out = w_self * base + sum_k ws[k] * parts[k]`. Pooled mode combines
    /// into a pooled buffer with the single-pass blocked kernel; naive mode
    /// is the original `weighted_combine_from`. Serial (`par` = the shared
    /// serial pool); see [`BufferPool::combine_from_par`] for the sharded
    /// variant.
    pub fn combine_from(
        &self,
        mode: HotPath,
        base: &[f32],
        w_self: f32,
        parts: &[&[f32]],
        ws: &[f32],
    ) -> Vec<f32> {
        self.combine_from_par(mode, base, w_self, parts, ws, crate::parallel::WorkerPool::serial())
    }

    /// [`BufferPool::combine_from`] with the combine sharded across `par`
    /// (ISSUE 9 tentpole layer 2). Naive mode stays the seed serial kernel
    /// regardless of the pool — it is the A/B baseline; pooled mode shards
    /// multi-MB combines on fixed block boundaries, byte-identical to the
    /// serial result for any pool size.
    pub fn combine_from_par(
        &self,
        mode: HotPath,
        base: &[f32],
        w_self: f32,
        parts: &[&[f32]],
        ws: &[f32],
        par: &crate::parallel::WorkerPool,
    ) -> Vec<f32> {
        match mode {
            HotPath::Naive => crate::tensor::weighted_combine_from(base, w_self, parts, ws),
            HotPath::Pooled => {
                let mut out = self.checkout_copy(base);
                crate::tensor::weighted_combine_blocked_into_par(par, &mut out, w_self, parts, ws);
                out.into_vec()
            }
        }
    }

    /// Counter snapshot.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            recycled: self.inner.recycled.load(Ordering::Relaxed),
            dropped: self.inner.dropped.load(Ordering::Relaxed),
            shelved: self.inner.shelves.lock().unwrap().values().map(Vec::len).sum(),
        }
    }

    /// Zero the counters (buffers stay shelved) — called between benchmark
    /// warm-up and measurement.
    pub fn reset_stats(&self) {
        self.inner.hits.store(0, Ordering::Relaxed);
        self.inner.misses.store(0, Ordering::Relaxed);
        self.inner.recycled.store(0, Ordering::Relaxed);
        self.inner.dropped.store(0, Ordering::Relaxed);
    }
}

/// Checkout guard: a `Vec<f32>` that returns to its [`BufferPool`] on drop.
///
/// Derefs to `[f32]` so it slots into the BLAS-1 kernels directly; use
/// [`PoolBuf::into_vec`] / [`PoolBuf::into_arc`] to detach the storage for
/// APIs that take ownership.
pub struct PoolBuf {
    data: Vec<f32>,
    /// `None` for detached guards (naive-mode scratch): dropped, not pooled.
    pool: Option<BufferPool>,
}

impl PoolBuf {
    /// Wrap a plain allocation in the guard interface without attaching it
    /// to any pool — the naive-mode counterpart of a checkout, so A/B
    /// callers share one code path while `HotPath::Naive` stays truly
    /// allocation-per-use.
    pub fn detached(data: Vec<f32>) -> Self {
        PoolBuf { data, pool: None }
    }

    /// Detach the buffer from the pool (it will not be recycled on drop;
    /// hand it back later via [`BufferPool::recycle_vec`]).
    pub fn into_vec(mut self) -> Vec<f32> {
        std::mem::take(&mut self.data)
    }

    /// Detach into an `Arc` payload for [`crate::transport::Message`];
    /// receivers hand the storage back via [`BufferPool::reclaim`].
    pub fn into_arc(self) -> Arc<Vec<f32>> {
        Arc::new(self.into_vec())
    }
}

impl std::ops::Deref for PoolBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl std::ops::DerefMut for PoolBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

impl std::fmt::Debug for PoolBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolBuf").field("len", &self.data.len()).finish()
    }
}

impl Drop for PoolBuf {
    fn drop(&mut self) {
        if let Some(pool) = &self.pool {
            if self.data.capacity() > 0 {
                pool.recycle_vec(std::mem::take(&mut self.data));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_recycle_roundtrip_reuses_storage() {
        let pool = BufferPool::new();
        let v = pool.checkout_copy(&[1.0, 2.0, 3.0]);
        assert_eq!(&*v, &[1.0, 2.0, 3.0]);
        let cap = v.data.capacity();
        drop(v); // recycles
        let w = pool.checkout(3);
        assert_eq!(&*w, &[0.0; 3]);
        assert_eq!(w.data.capacity(), cap, "storage not reused");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn checkout_scaled_is_single_pass_copy() {
        let pool = BufferPool::new();
        let v = pool.checkout_scaled(&[1.0, -2.0, 4.0], 0.5);
        assert_eq!(&*v, &[0.5, -1.0, 2.0]);
    }

    #[test]
    fn into_vec_detaches_and_recycle_vec_returns() {
        let pool = BufferPool::new();
        let v = pool.checkout(128).into_vec();
        assert_eq!(pool.stats().shelved, 0, "detached buffer must not auto-recycle");
        pool.recycle_vec(v);
        assert_eq!(pool.stats().shelved, 1);
        assert_eq!(pool.stats().recycled, 1);
        let w = pool.checkout(100); // 100 <= 128 bucket
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(w.len(), 100);
    }

    #[test]
    fn reclaim_recovers_only_unique_arcs() {
        let pool = BufferPool::new();
        let a = pool.checkout_copy(&[7.0; 200]).into_arc();
        let b = a.clone();
        pool.reclaim(a); // refcount 2: dropped, not recycled
        assert_eq!(pool.stats().shelved, 0);
        pool.reclaim(b); // last clone: recovered
        assert_eq!(pool.stats().shelved, 1);
        assert_eq!(pool.checkout(200).len(), 200);
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn detached_guard_drops_without_pooling() {
        let pool = BufferPool::new();
        let buf = PoolBuf::detached(vec![1.0; 128]);
        assert_eq!(&*buf, &[1.0; 128][..]);
        drop(buf);
        assert_eq!(pool.stats().shelved, 0, "detached guards must not feed any pool");
        assert_eq!(pool.stats().recycled, 0);
    }

    #[test]
    fn checkout_empty_reuses_capacity_without_fill() {
        let pool = BufferPool::new();
        drop(pool.checkout(200)); // shelve a 256-capacity buffer
        let w = pool.checkout_empty(180);
        assert_eq!(w.len(), 0, "codec scratch starts empty");
        assert!(w.data.capacity() >= 180);
        assert_eq!(pool.stats().hits, 1, "empty checkout must hit the shelf");
    }

    #[test]
    fn tiny_buffers_are_not_pooled() {
        let pool = BufferPool::new();
        pool.recycle_vec(vec![1.0; 4]);
        assert_eq!(pool.stats().shelved, 0);
    }

    #[test]
    fn bucket_depth_is_bounded() {
        let pool = BufferPool::new();
        for _ in 0..(MAX_PER_BUCKET + 5) {
            pool.recycle_vec(vec![0.0; MIN_BUCKET]);
        }
        let s = pool.stats();
        assert_eq!(s.shelved, MAX_PER_BUCKET);
        assert_eq!(s.dropped, 5);
    }

    #[test]
    fn capacity_always_covers_request_across_buckets() {
        let pool = BufferPool::new();
        // A buffer with non-power-of-two capacity shelves under its floor
        // bucket, so a checkout from that bucket still fits.
        let mut v = Vec::with_capacity(100); // shelf 64
        v.resize(100, 1.0);
        pool.recycle_vec(v);
        let w = pool.checkout(60); // bucket 64 -> hit
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(&*w, &vec![0.0; 60][..]);
    }

    #[test]
    fn reset_stats_keeps_shelves() {
        let pool = BufferPool::new();
        drop(pool.checkout(256));
        pool.reset_stats();
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.recycled), (0, 0, 0));
        assert_eq!(s.shelved, 1);
        drop(pool.checkout(256));
        assert_eq!(pool.stats().hits, 1);
    }
}
