//! Global-averaging collectives (paper §II-B, Table I).
//!
//! These are the baselines the paper compares partial averaging against.
//! Each is implemented over the point-to-point [`crate::transport`] with the
//! real message schedule, so the virtual clock reproduces the structural
//! cost:
//!
//! - [`NodeContext::allreduce`] with [`AllreduceAlgo::Ring`]: chunked
//!   reduce-scatter + allgather, `2(n-1)` rounds of `M/n` bytes —
//!   `2M/B + 2nL` (the Horovod baseline).
//! - [`AllreduceAlgo::ParameterServer`]: all ranks push to rank 0 which sums
//!   and pushes back — the server NIC serializes `n` messages: `nM/B + nL`.
//! - [`AllreduceAlgo::BytePs`]: tensor sharded into `n` chunks, chunk `i`
//!   served by rank `i` — every NIC carries `M/n * n = M`: `M/B + nL`.

use crate::collective::{AllreduceAlgo, ReduceOp};
use crate::context::NodeContext;
use crate::negotiation::{OpKind, OpRequest};

impl NodeContext {
    /// Dissemination barrier (`bf.barrier()`): ceil(log2 n) rounds.
    pub fn barrier(&mut self) -> anyhow::Result<()> {
        let n = self.size();
        if n == 1 {
            return Ok(());
        }
        let tag = self.next_tag("barrier");
        let mut hop = 1;
        let mut round = 0u32;
        while hop < n {
            let dst = (self.rank() + hop) % n;
            let src = (self.rank() + n - hop) % n;
            let rtag = tag + u64::from(round);
            self.send_tensor(dst, rtag, vec![])?;
            let _ = self
                .recv_tensor(src, rtag)
                .map_err(|e| e.context(format!("barrier round {round}: waiting on rank {src}")))?;
            hop *= 2;
            round += 1;
        }
        Ok(())
    }

    /// Broadcast `data` from `root` to all ranks (binomial tree).
    pub fn broadcast(&mut self, data: &mut Vec<f32>, root: usize) -> anyhow::Result<()> {
        let n = self.size();
        if n == 1 {
            return Ok(());
        }
        let tag = self.next_tag("broadcast");
        // Virtual rank so that root = 0; binomial tree over virtual ranks
        // (MPICH scheme: parent clears the lowest set bit; after receiving,
        // a node fans out to vrank + mask for decreasing mask).
        let vrank = (self.rank() + n - root) % n;
        let mut mask = 1usize;
        while mask < n {
            if vrank & mask != 0 {
                let parent = ((vrank - mask) + root) % n;
                let y = self.recv_tensor(parent, tag)?;
                let old = std::mem::replace(data, self.take_payload(y));
                self.recycle(old);
                break;
            }
            mask <<= 1;
        }
        mask >>= 1;
        let mut shared: Option<std::sync::Arc<Vec<f32>>> = None;
        while mask > 0 {
            if vrank + mask < n {
                let child = ((vrank + mask) + root) % n;
                let p = shared.get_or_insert_with(|| self.payload_from(data)).clone();
                self.send_shared(child, tag, p)?;
            }
            mask >>= 1;
        }
        self.defer_reclaim(shared);
        Ok(())
    }

    /// Global allreduce (`bf.allreduce`) with the configured algorithm.
    /// Returns the elementwise sum or average across all ranks.
    pub fn allreduce(&mut self, data: &[f32], op: ReduceOp, algo: AllreduceAlgo) -> anyhow::Result<Vec<f32>> {
        let name = self.next_collective_name("allreduce");
        self.negotiate(&name, OpKind::Allreduce, data.len(), None, None)?;
        let wall = self.timeline.now_us();
        let v0 = self.vtime();
        let mut out = match algo {
            AllreduceAlgo::Ring => self.ring_allreduce(data)?,
            AllreduceAlgo::ParameterServer => self.ps_allreduce(data)?,
            AllreduceAlgo::BytePs => self.byteps_allreduce(data)?,
        };
        if op == ReduceOp::Average {
            let inv = 1.0 / self.size() as f32;
            for x in out.iter_mut() {
                *x *= inv;
            }
        }
        self.timeline.record(self.rank(), "allreduce", "comm", wall, v0, self.vtime());
        Ok(out)
    }

    /// Chunked ring allreduce: reduce-scatter then allgather.
    fn ring_allreduce(&mut self, data: &[f32]) -> anyhow::Result<Vec<f32>> {
        let n = self.size();
        let me = self.rank();
        if n == 1 {
            return Ok(data.to_vec());
        }
        let tag = self.next_tag("ring_allreduce");
        let len = data.len();
        // Chunk boundaries (n chunks, nearly equal).
        let bounds: Vec<(usize, usize)> = (0..n)
            .map(|c| {
                let lo = c * len / n;
                let hi = (c + 1) * len / n;
                (lo, hi)
            })
            .collect();
        let mut buf = self.vec_from(data);
        let next = (me + 1) % n;
        let prev = (me + n - 1) % n;
        // Reduce-scatter: in round r, send chunk (me - r) and accumulate
        // chunk (me - r - 1) arriving from prev.
        for r in 0..(n - 1) {
            let send_c = (me + n - r) % n;
            let recv_c = (me + n - r - 1) % n;
            let (slo, shi) = bounds[send_c];
            let rtag = tag + r as u64;
            let payload = self.payload_from(&buf[slo..shi]);
            self.send_shared(next, rtag, payload)?;
            let incoming = self.recv_tensor(prev, rtag)?;
            let (rlo, rhi) = bounds[recv_c];
            for (x, y) in buf[rlo..rhi].iter_mut().zip(incoming.iter()) {
                *x += y;
            }
            self.reclaim_payload(incoming);
        }
        // Allgather: circulate the reduced chunks.
        for r in 0..(n - 1) {
            let send_c = (me + 1 + n - r) % n;
            let recv_c = (me + n - r) % n;
            let (slo, shi) = bounds[send_c];
            let rtag = tag + n as u64 + r as u64;
            let payload = self.payload_from(&buf[slo..shi]);
            self.send_shared(next, rtag, payload)?;
            let incoming = self.recv_tensor(prev, rtag)?;
            let (rlo, rhi) = bounds[recv_c];
            buf[rlo..rhi].copy_from_slice(&incoming);
            self.reclaim_payload(incoming);
        }
        Ok(buf)
    }

    /// Parameter-server allreduce: push to rank 0, sum, pull back.
    fn ps_allreduce(&mut self, data: &[f32]) -> anyhow::Result<Vec<f32>> {
        let n = self.size();
        if n == 1 {
            return Ok(data.to_vec());
        }
        let tag = self.next_tag("ps_allreduce");
        let rtag = tag + 1;
        if self.rank() == 0 {
            let mut acc = self.vec_from(data);
            for src in 1..n {
                let part = self
                    .recv_tensor(src, tag)
                    .map_err(|e| e.context(format!("ps_allreduce: gathering from rank {src}")))?;
                for (a, p) in acc.iter_mut().zip(part.iter()) {
                    *a += p;
                }
                self.reclaim_payload(part);
            }
            let shared = self.payload_from(&acc);
            for dst in 1..n {
                self.send_shared(dst, rtag, shared.clone())?;
            }
            self.defer_reclaim(Some(shared));
            Ok(acc)
        } else {
            self.send_shared(0, tag, self.payload_from(data))?;
            let reply = self.recv_tensor(0, rtag)?;
            Ok(self.take_payload(reply))
        }
    }

    /// BytePS-style allreduce: chunk `c` is served by rank `c` — every rank
    /// pushes its chunk `c` to server `c` and pulls the sum back.
    fn byteps_allreduce(&mut self, data: &[f32]) -> anyhow::Result<Vec<f32>> {
        let n = self.size();
        let me = self.rank();
        if n == 1 {
            return Ok(data.to_vec());
        }
        let tag = self.next_tag("byteps_allreduce");
        let rtag = tag + 1;
        let len = data.len();
        let bounds: Vec<(usize, usize)> = (0..n)
            .map(|c| (c * len / n, (c + 1) * len / n))
            .collect();
        // Push phase: send chunk c to rank c (keep own chunk local).
        for c in 0..n {
            if c != me {
                let (lo, hi) = bounds[c];
                self.send_shared(c, tag, self.payload_from(&data[lo..hi]))?;
            }
        }
        // Serve own chunk: sum the n-1 incoming contributions.
        let (mlo, mhi) = bounds[me];
        let mut served = self.vec_from(&data[mlo..mhi]);
        for _ in 0..(n - 1) {
            let (_, part) = self
                .recv_tensor_any(tag)
                .map_err(|e| e.context("byteps_allreduce: gathering chunk contributions"))?;
            for (a, p) in served.iter_mut().zip(part.iter()) {
                *a += p;
            }
            self.reclaim_payload(part);
        }
        // Pull phase: broadcast the served chunk to everyone else, receive
        // the other chunks.
        let shared = self.payload_from(&served);
        for c in 0..n {
            if c != me {
                self.send_shared(c, rtag, shared.clone())?;
            }
        }
        self.defer_reclaim(Some(shared));
        let mut out = self.vec_from(data);
        out[mlo..mhi].copy_from_slice(&served);
        self.recycle(served);
        for _ in 0..(n - 1) {
            let (src, part) = self.recv_tensor_any(rtag)?;
            let (lo, hi) = bounds[src];
            out[lo..hi].copy_from_slice(&part);
            self.reclaim_payload(part);
        }
        Ok(out)
    }

    /// Announce an op to the negotiation service (when enabled) and advance
    /// the virtual clock by the scalar round. Errors on validation failure;
    /// returns the clearance with resolved src/dst edge sets, or `None` when
    /// the topology check is disabled.
    pub(crate) fn negotiate(
        &mut self,
        name: &str,
        kind: OpKind,
        numel: usize,
        dsts: Option<Vec<usize>>,
        srcs: Option<Vec<usize>>,
    ) -> anyhow::Result<Option<crate::negotiation::OpClearance>> {
        if !self.enable_topo_check {
            return Ok(None);
        }
        let req = OpRequest {
            rank: self.rank(),
            name: name.to_string(),
            kind,
            numel,
            dsts,
            srcs,
            vtime: self.vtime(),
        };
        // EventLoop parks on the inline rendezvous (same resolution code
        // path as the daemon — `resolve_batch`); Threads blocks on the
        // negotiation daemon's reply channel.
        let clearance = match (&self.rendezvous, &self.sched) {
            (Some(rdv), Some(sched)) => rdv.submit(req, sched)?,
            _ => self.negotiation.submit(req)?,
        };
        self.clock().advance_to(clearance.start_vtime);
        if let Some(err) = &clearance.error {
            anyhow::bail!("negotiation failed: {err}");
        }
        Ok(Some(clearance))
    }
}
