//! Partial averaging — `neighbor_allreduce` (paper §III-A/B, eq. (5), (10)–(12)).
//!
//! The static form uses the global topology's weight matrix: each node sends
//! its raw tensor to its out-neighbors and combines the in-coming copies
//! with its row of `W`:
//!
//! `x_i <- w_ii x_i + sum_{j in N(i)} w_ij x_j`.
//!
//! The dynamic form takes the *local view* — `self_weight`, `src_weights`
//! (receive-side scaling `r_ij`) and/or `dst_weights` (send-side scaling
//! `s_ij`) — per call, supporting the paper's four configurations
//! (footnote 2): static default, pure push, pure pull, and push-pull. When
//! one side is omitted, the negotiation service resolves the matching ranks
//! (it "synchronizes the ranks of sending and receiving among the entire
//! network").

use crate::context::NodeContext;
use crate::negotiation::OpKind;

/// Arguments of a dynamic `neighbor_allreduce` (BlueFog's optional
/// `self_weight` / `src_weights` / `dst_weights`).
#[derive(Debug, Clone, Default)]
pub struct NeighborWeights {
    pub self_weight: f64,
    /// `(src_rank, r_ij)` receive-side scales; `None` = not declared.
    pub src_weights: Option<Vec<(usize, f64)>>,
    /// `(dst_rank, s_ij)` send-side scales; `None` = not declared.
    pub dst_weights: Option<Vec<(usize, f64)>>,
}

impl NeighborWeights {
    /// Pure pull-style: receiver scales (`r_ij = w_ij`, senders send raw).
    pub fn pull(self_weight: f64, src_weights: Vec<(usize, f64)>) -> Self {
        NeighborWeights { self_weight, src_weights: Some(src_weights), dst_weights: None }
    }

    /// Pure push-style: sender scales (`s_ij = w_ij`, receivers sum raw).
    pub fn push(self_weight: f64, dst_weights: Vec<(usize, f64)>) -> Self {
        NeighborWeights { self_weight, src_weights: None, dst_weights: Some(dst_weights) }
    }

    /// Push-pull: both sides scale (`w_ij = r_ij * s_ij`).
    pub fn push_pull(
        self_weight: f64,
        src_weights: Vec<(usize, f64)>,
        dst_weights: Vec<(usize, f64)>,
    ) -> Self {
        NeighborWeights {
            self_weight,
            src_weights: Some(src_weights),
            dst_weights: Some(dst_weights),
        }
    }

    /// From a [`crate::topology::dynamic::LocalView`].
    pub fn from_view(v: &crate::topology::dynamic::LocalView) -> Self {
        NeighborWeights {
            self_weight: v.self_weight,
            src_weights: Some(v.src_weights.clone()),
            dst_weights: Some(v.dst_weights.clone()),
        }
    }
}

impl NodeContext {
    /// Static-topology partial averaging (`bf.neighbor_allreduce(tensor)`),
    /// paper eq. (5): combine with this rank's row of the global weight
    /// matrix.
    pub fn neighbor_allreduce(&mut self, data: &[f32]) -> anyhow::Result<Vec<f32>> {
        let (self_w, srcs, dsts) = {
            let topo = self.load_topology();
            let (self_w, srcs) = topo.weights.pull_view(self.rank());
            let dsts: Vec<(usize, f64)> =
                topo.graph.out_neighbors(self.rank()).into_iter().map(|r| (r, 1.0)).collect();
            (self_w, srcs, dsts)
        };
        self.neighbor_allreduce_impl(
            data,
            self_w,
            Some(srcs),
            Some(dsts),
            /*scale_on_send=*/ false,
        )
    }

    /// Dynamic partial averaging
    /// (`bf.neighbor_allreduce(tensor, self_weight, src_weights, dst_weights)`),
    /// paper eq. (10)–(12).
    pub fn neighbor_allreduce_dynamic(
        &mut self,
        data: &[f32],
        weights: &NeighborWeights,
    ) -> anyhow::Result<Vec<f32>> {
        self.neighbor_allreduce_impl(
            data,
            weights.self_weight,
            weights.src_weights.clone(),
            weights.dst_weights.clone(),
            /*scale_on_send=*/ true,
        )
    }

    /// Shared implementation. `scale_on_send` distinguishes the static form
    /// (receiver applies `w_ij`; senders send raw) from the dynamic form
    /// (senders apply `s_ij` from `dst_weights`, receivers apply `r_ij`
    /// from `src_weights`, missing side defaults to scale 1).
    fn neighbor_allreduce_impl(
        &mut self,
        data: &[f32],
        self_weight: f64,
        src_weights: Option<Vec<(usize, f64)>>,
        dst_weights: Option<Vec<(usize, f64)>>,
        scale_on_send: bool,
    ) -> anyhow::Result<Vec<f32>> {
        let wall = self.timeline.now_us();
        let v0 = self.vtime();
        let name = self.next_collective_name("neighbor_allreduce");
        let clearance = self.negotiate(
            &name,
            OpKind::NeighborAllreduce,
            data.len(),
            dst_weights.as_ref().map(|v| v.iter().map(|&(r, _)| r).collect()),
            src_weights.as_ref().map(|v| v.iter().map(|&(r, _)| r).collect()),
        )?;
        // Resolve missing sides from the negotiation service.
        let dsts: Vec<(usize, f64)> = match (&dst_weights, &clearance) {
            (Some(d), _) => d.clone(),
            (None, Some(c)) => c.resolved_dsts.iter().map(|&r| (r, 1.0)).collect(),
            (None, None) => anyhow::bail!(
                "neighbor_allreduce: dst_weights not declared and topology check disabled — \
                 senders cannot be resolved (enable the check or pass dst_weights)"
            ),
        };
        let srcs: Vec<(usize, f64)> = match (&src_weights, &clearance) {
            (Some(s), _) => s.clone(),
            (None, Some(c)) => c.resolved_srcs.iter().map(|&r| (r, 1.0)).collect(),
            (None, None) => anyhow::bail!(
                "neighbor_allreduce: src_weights not declared and topology check disabled — \
                 receivers cannot be resolved (enable the check or pass src_weights)"
            ),
        };
        let tag = self.next_tag("neighbor_allreduce");
        // Sort destinations by ring distance from own rank to de-conflict
        // convergent sends (paper §VI-B: "the destination order at each
        // process is sorted based on the difference between its own rank
        // and the destination rank").
        let n = self.size();
        let me = self.rank();
        let mut dsts_sorted = dsts.clone();
        dsts_sorted.sort_by_key(|&(d, _)| (d + n - me) % n);
        // Unscaled sends share one Arc'd buffer across all destinations
        // (zero-copy fan-out); the buffer itself comes from the rank-local
        // pool in pooled mode (EXPERIMENTS.md §Perf).
        let mut shared: Option<std::sync::Arc<Vec<f32>>> = None;
        for &(dst, s) in &dsts_sorted {
            if scale_on_send && s != 1.0 {
                self.send_shared(dst, tag, self.scaled_payload(data, s as f32))?;
            } else {
                let p = shared.get_or_insert_with(|| self.payload_from(data)).clone();
                self.send_shared(dst, tag, p)?;
            }
        }
        // Combine: out = self_weight * x + sum_j r_ij * y_ij.
        let mut incoming: Vec<(f32, std::sync::Arc<Vec<f32>>)> = Vec::with_capacity(srcs.len());
        for &(src, r) in &srcs {
            let y = self.recv_tensor(src, tag)?;
            anyhow::ensure!(
                y.len() == data.len(),
                "neighbor_allreduce: rank {src} sent {} elements, expected {}",
                y.len(),
                data.len()
            );
            incoming.push((r as f32, y));
        }
        let parts: Vec<&[f32]> = incoming.iter().map(|(_, y)| y.as_slice()).collect();
        let ws: Vec<f32> = incoming.iter().map(|(r, _)| *r).collect();
        let out = self.combine_hotpath(data, self_weight as f32, &parts, &ws);
        drop(parts);
        for (_, y) in incoming {
            self.reclaim_payload(y);
        }
        self.defer_reclaim(shared);
        self.timeline.record(me, "neighbor_allreduce", "comm", wall, v0, self.vtime());
        Ok(out)
    }

    /// `bf.neighbor_allgather(tensor)` — collect the raw tensors of all
    /// in-neighbors (MPI_Neighbor_allgatherv: sizes may vary per neighbor).
    /// Returns `(src_rank, tensor)` pairs sorted by source rank.
    pub fn neighbor_allgather(
        &mut self,
        data: &[f32],
    ) -> anyhow::Result<Vec<(usize, Vec<f32>)>> {
        let (srcs, dsts) = {
            let topo = self.load_topology();
            (topo.graph.in_neighbors(self.rank()), topo.graph.out_neighbors(self.rank()))
        };
        let name = self.next_collective_name("neighbor_allgather");
        self.negotiate(
            &name,
            OpKind::NeighborAllgather,
            data.len(),
            Some(dsts.clone()),
            Some(srcs.clone()),
        )?;
        let tag = self.next_tag("neighbor_allgather");
        let shared = self.payload_from(data);
        for &dst in &dsts {
            self.send_shared(dst, tag, shared.clone())?;
        }
        let mut out = Vec::with_capacity(srcs.len());
        for &src in &srcs {
            let y = self.recv_tensor(src, tag)?;
            out.push((src, self.take_payload(y)));
        }
        self.defer_reclaim(Some(shared));
        Ok(out)
    }
}
