//! Partial averaging — `neighbor_allreduce` (paper §III-A/B, eq. (5), (10)–(12)).
//!
//! The static form uses the global topology's weight matrix: each node sends
//! its raw tensor to its out-neighbors and combines the in-coming copies
//! with its row of `W`:
//!
//! `x_i <- w_ii x_i + sum_{j in N(i)} w_ij x_j`.
//!
//! The dynamic form takes the *local view* — `self_weight`, `src_weights`
//! (receive-side scaling `r_ij`) and/or `dst_weights` (send-side scaling
//! `s_ij`) — per call, supporting the paper's four configurations
//! (footnote 2): static default, pure push, pure pull, and push-pull. When
//! one side is omitted, the negotiation service resolves the matching ranks
//! (it "synchronizes the ranks of sending and receiving among the entire
//! network").
//!
//! When a [`crate::compress::CompressionSpec`] is configured
//! ([`crate::launcher::SpmdConfig::with_compression`]), both forms encode
//! every outgoing payload and decode every incoming one, with per-stream
//! error feedback on the send side; `neighbor_allgather` intentionally
//! stays dense (it gathers *exact* neighbor tensors, not averages).

use crate::context::{ef_key, NodeContext, EF_PEER, EF_SHARED};
use crate::negotiation::OpKind;
use crate::simnet::faults::CommError;
use crate::topology::health::survivor_mh_row;

/// Arguments of a dynamic `neighbor_allreduce` (BlueFog's optional
/// `self_weight` / `src_weights` / `dst_weights`).
#[derive(Debug, Clone, Default)]
pub struct NeighborWeights {
    /// Weight this rank keeps on its own tensor (`w_ii`).
    pub self_weight: f64,
    /// `(src_rank, r_ij)` receive-side scales; `None` = not declared.
    pub src_weights: Option<Vec<(usize, f64)>>,
    /// `(dst_rank, s_ij)` send-side scales; `None` = not declared.
    pub dst_weights: Option<Vec<(usize, f64)>>,
}

impl NeighborWeights {
    /// Pure pull-style: receiver scales (`r_ij = w_ij`, senders send raw).
    pub fn pull(self_weight: f64, src_weights: Vec<(usize, f64)>) -> Self {
        NeighborWeights { self_weight, src_weights: Some(src_weights), dst_weights: None }
    }

    /// Pure push-style: sender scales (`s_ij = w_ij`, receivers sum raw).
    pub fn push(self_weight: f64, dst_weights: Vec<(usize, f64)>) -> Self {
        NeighborWeights { self_weight, src_weights: None, dst_weights: Some(dst_weights) }
    }

    /// Push-pull: both sides scale (`w_ij = r_ij * s_ij`).
    pub fn push_pull(
        self_weight: f64,
        src_weights: Vec<(usize, f64)>,
        dst_weights: Vec<(usize, f64)>,
    ) -> Self {
        NeighborWeights {
            self_weight,
            src_weights: Some(src_weights),
            dst_weights: Some(dst_weights),
        }
    }

    /// From a [`crate::topology::dynamic::LocalView`].
    pub fn from_view(v: &crate::topology::dynamic::LocalView) -> Self {
        NeighborWeights {
            self_weight: v.self_weight,
            src_weights: Some(v.src_weights.clone()),
            dst_weights: Some(v.dst_weights.clone()),
        }
    }
}

impl NodeContext {
    /// Static-topology partial averaging (`bf.neighbor_allreduce(tensor)`),
    /// paper eq. (5): combine with this rank's row of the global weight
    /// matrix.
    pub fn neighbor_allreduce(&mut self, data: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.neighbor_allreduce_stream(data, 0)
    }

    /// Static partial averaging on an explicit error-feedback stream id
    /// (optimizers that interleave several same-length combines per
    /// iteration pass distinct streams so compression estimates do not
    /// cross; see [`crate::optim::CommSpec::combine_stream`]).
    pub(crate) fn neighbor_allreduce_stream(
        &mut self,
        data: &[f32],
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        // Read the per-rank CSR views under the lock: O(degree) copies, no
        // dense-matrix clone (the previous `load_topology()` snapshot per
        // call is O(n^2) — 800 MB per call at 10k ranks).
        let me = self.rank();
        let (self_w, srcs, dsts) = {
            let topo = self.topology.read().unwrap();
            if self.faults().active() && self.health.evicted_count() > 0 {
                // Self-healing static form: re-derive a Metropolis–
                // Hastings row over the survivor-induced subgraph. The MH
                // formula is symmetric in (i, j), so once neighbors share
                // an eviction view the healed matrix is again doubly
                // stochastic over the survivors and consensus keeps
                // contracting (DESIGN.md §Faults).
                let dead = self.health.evicted_set().clone();
                let (self_w, srcs) = survivor_mh_row(&topo.graph, &dead, me);
                let dsts: Vec<(usize, f64)> = topo
                    .views
                    .out_neighbors(me)
                    .iter()
                    .filter(|r| !dead.contains(r))
                    .map(|&r| (r, 1.0))
                    .collect();
                (self_w, srcs, dsts)
            } else {
                let (self_w, srcs) = topo.views.pull_view(me);
                let dsts: Vec<(usize, f64)> =
                    topo.views.out_neighbors(me).iter().map(|&r| (r, 1.0)).collect();
                (self_w, srcs.to_vec(), dsts)
            }
        };
        self.neighbor_allreduce_impl(
            data,
            self_w,
            Some(srcs),
            Some(dsts),
            /*scale_on_send=*/ false,
            stream,
        )
    }

    /// This rank's static pull row and out-neighbor list — exactly the
    /// weights [`NodeContext::neighbor_allreduce`] would combine with
    /// (survivor-healed MH rows under active fault injection). Returns
    /// `(self_weight, (src_rank, w_ij) pairs, out_neighbor ranks)`; dynamic
    /// weighting policies modulate this row per round
    /// (`optim::weighting`).
    pub fn static_pull_row(&self) -> (f64, Vec<(usize, f64)>, Vec<usize>) {
        let me = self.rank();
        let topo = self.topology.read().unwrap();
        if self.faults().active() && self.health.evicted_count() > 0 {
            let dead = self.health.evicted_set().clone();
            let (self_w, srcs) = survivor_mh_row(&topo.graph, &dead, me);
            let dsts: Vec<usize> =
                topo.views.out_neighbors(me).iter().filter(|r| !dead.contains(r)).copied().collect();
            (self_w, srcs, dsts)
        } else {
            let (self_w, srcs) = topo.views.pull_view(me);
            (self_w, srcs.to_vec(), topo.views.out_neighbors(me).to_vec())
        }
    }

    /// Dynamic partial averaging
    /// (`bf.neighbor_allreduce(tensor, self_weight, src_weights, dst_weights)`),
    /// paper eq. (10)–(12).
    pub fn neighbor_allreduce_dynamic(
        &mut self,
        data: &[f32],
        weights: &NeighborWeights,
    ) -> anyhow::Result<Vec<f32>> {
        self.neighbor_allreduce_dynamic_stream(data, weights, 0)
    }

    /// Dynamic partial averaging on an explicit error-feedback stream id.
    pub(crate) fn neighbor_allreduce_dynamic_stream(
        &mut self,
        data: &[f32],
        weights: &NeighborWeights,
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        self.neighbor_allreduce_impl(
            data,
            weights.self_weight,
            weights.src_weights.clone(),
            weights.dst_weights.clone(),
            /*scale_on_send=*/ true,
            stream,
        )
    }

    /// Shared implementation. `scale_on_send` distinguishes the static form
    /// (receiver applies `w_ij`; senders send raw) from the dynamic form
    /// (senders apply `s_ij` from `dst_weights`, receivers apply `r_ij`
    /// from `src_weights`, missing side defaults to scale 1).
    #[allow(clippy::too_many_arguments)]
    fn neighbor_allreduce_impl(
        &mut self,
        data: &[f32],
        self_weight: f64,
        src_weights: Option<Vec<(usize, f64)>>,
        dst_weights: Option<Vec<(usize, f64)>>,
        scale_on_send: bool,
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        let wall = self.timeline.now_us();
        let v0 = self.vtime();
        let name = self.next_collective_name("neighbor_allreduce");
        let clearance = self.negotiate(
            &name,
            OpKind::NeighborAllreduce,
            data.len(),
            dst_weights.as_ref().map(|v| v.iter().map(|&(r, _)| r).collect()),
            src_weights.as_ref().map(|v| v.iter().map(|&(r, _)| r).collect()),
        )?;
        // Resolve missing sides from the negotiation service.
        let dsts: Vec<(usize, f64)> = match (&dst_weights, &clearance) {
            (Some(d), _) => d.clone(),
            (None, Some(c)) => c.resolved_dsts.iter().map(|&r| (r, 1.0)).collect(),
            (None, None) => anyhow::bail!(
                "neighbor_allreduce: dst_weights not declared and topology check disabled — \
                 senders cannot be resolved (enable the check or pass dst_weights)"
            ),
        };
        let srcs: Vec<(usize, f64)> = match (&src_weights, &clearance) {
            (Some(s), _) => s.clone(),
            (None, Some(c)) => c.resolved_srcs.iter().map(|&r| (r, 1.0)).collect(),
            (None, None) => anyhow::bail!(
                "neighbor_allreduce: src_weights not declared and topology check disabled — \
                 receivers cannot be resolved (enable the check or pass src_weights)"
            ),
        };
        let tag = self.next_tag("neighbor_allreduce");
        // Sort destinations by ring distance from own rank to de-conflict
        // convergent sends (paper §VI-B: "the destination order at each
        // process is sorted based on the difference between its own rank
        // and the destination rank").
        let n = self.size();
        let me = self.rank();
        let mut dsts_sorted = dsts.clone();
        dsts_sorted.sort_by_key(|&(d, _)| (d + n - me) % n);
        let out = if self.comp.enabled() {
            self.compressed_exchange(
                data,
                self_weight,
                &srcs,
                &dsts_sorted,
                scale_on_send,
                stream,
                tag,
            )?
        } else {
            // Dense path (CompressionSpec::None) — byte-identical to PR 2.
            // Unscaled sends share one Arc'd buffer across all destinations
            // (zero-copy fan-out); the buffer itself comes from the
            // rank-local pool in pooled mode (EXPERIMENTS.md §Perf).
            let mut shared: Option<std::sync::Arc<Vec<f32>>> = None;
            for &(dst, s) in &dsts_sorted {
                if self.faults().active() && self.health.is_evicted(dst) {
                    continue;
                }
                if scale_on_send && s != 1.0 {
                    self.send_shared(dst, tag, self.scaled_payload(data, s as f32))?;
                } else {
                    let p = shared.get_or_insert_with(|| self.payload_from(data)).clone();
                    self.send_shared(dst, tag, p)?;
                }
            }
            // Combine: out = self_weight * x + sum_j r_ij * y_ij. A
            // neighbor that misses its deadline (or is known crashed)
            // contributes nothing this round; its weight folds into the
            // self weight so the row stays stochastic, and the health
            // view records the evidence (suspicion on Timeout, immediate
            // eviction on PeerDown) so later rounds re-derive survivor
            // rows instead of waiting again.
            let mut self_w_eff = self_weight;
            let dl = self.default_deadline();
            let mut incoming: Vec<(f32, std::sync::Arc<Vec<f32>>)> =
                Vec::with_capacity(srcs.len());
            for &(src, r) in &srcs {
                let y = match self.recv_tensor_within(src, tag, dl) {
                    Ok(y) => y,
                    Err(CommError::PeerDown { peer, at }) => {
                        self.health.evict(peer);
                        self.timeline.record(me, "peer_down", "fault", wall, at, at);
                        self_w_eff += r;
                        continue;
                    }
                    Err(CommError::Timeout { .. }) => {
                        self.health.record_miss(src);
                        self_w_eff += r;
                        continue;
                    }
                    Err(e @ CommError::SelfCrash { .. }) => return Err(e.into()),
                };
                if self.faults().active() {
                    let at = self.vtime();
                    self.health.record_heard(src, at);
                }
                anyhow::ensure!(
                    y.len() == data.len(),
                    "neighbor_allreduce: rank {src} sent {} elements, expected {}",
                    y.len(),
                    data.len()
                );
                incoming.push((r as f32, y));
            }
            let parts: Vec<&[f32]> = incoming.iter().map(|(_, y)| y.as_slice()).collect();
            let ws: Vec<f32> = incoming.iter().map(|(r, _)| *r).collect();
            let out = self.combine_hotpath(data, self_w_eff as f32, &parts, &ws);
            drop(parts);
            for (_, y) in incoming {
                self.reclaim_payload(y);
            }
            self.defer_reclaim(shared);
            out
        };
        self.timeline.record(me, "neighbor_allreduce", "comm", wall, v0, self.vtime());
        Ok(out)
    }

    /// Compressed partial-averaging exchange ([`crate::compress`]).
    ///
    /// Static form (`scale_on_send == false`): the destination set is the
    /// static out-neighborhood — stable round over round — so the node
    /// encodes **one shared difference stream** for the whole fan-out, and
    /// the combine applies the mean-conserving self-correction
    /// `x + Σ_j r_j x̂_j − (1 − w_self) x̂_self` (exact network-mean
    /// invariance under doubly-stochastic weights, estimate lag
    /// notwithstanding).
    ///
    /// Dynamic form (`scale_on_send == true`): destination sets and scales
    /// may change every round, so every destination gets its own stream
    /// (receivers would otherwise miss messages of a shared stream and
    /// desynchronize their estimates) and the plain weighted combine is
    /// used — approximate, with the tracking error bounded by the
    /// difference codec.
    #[allow(clippy::too_many_arguments)]
    fn compressed_exchange(
        &mut self,
        data: &[f32],
        self_weight: f64,
        srcs: &[(usize, f64)],
        dsts_sorted: &[(usize, f64)],
        scale_on_send: bool,
        stream: u32,
        tag: crate::transport::Tag,
    ) -> anyhow::Result<Vec<f32>> {
        let d = data.len();
        let cap = self.comp.encoded_cap(d);
        let shared_key = ef_key(EF_SHARED, stream, 0, d);
        let mut shared: Option<std::sync::Arc<Vec<f32>>> = None;
        for &(dst, s) in dsts_sorted {
            if scale_on_send {
                let mut wire = self.codec_scratch(cap);
                if s != 1.0 {
                    let scaled = self.scaled_vec(data, s as f32);
                    self.comp.encode(ef_key(EF_PEER, stream, dst, d), &scaled, &mut wire);
                    self.recycle(scaled);
                } else {
                    // CommSpec::Dynamic realizes pull-style views with unit
                    // send scales — skip the O(d) staging copy.
                    self.comp.encode(ef_key(EF_PEER, stream, dst, d), data, &mut wire);
                }
                self.send_tensor(dst, tag, wire)?;
            } else {
                let p = match &shared {
                    Some(p) => p.clone(),
                    None => {
                        let mut wire = self.codec_scratch(cap);
                        self.comp.encode(shared_key, data, &mut wire);
                        let p = std::sync::Arc::new(wire);
                        shared = Some(p.clone());
                        p
                    }
                };
                self.send_shared(dst, tag, p)?;
            }
        }
        let mut incoming: Vec<(f32, Vec<f32>)> = Vec::with_capacity(srcs.len());
        for &(src, r) in srcs {
            let y = self.recv_tensor(src, tag)?;
            let mut dec = self.codec_scratch(d);
            self.comp.decode(ef_key(EF_PEER, stream, src, d), &y, &mut dec)?;
            self.reclaim_payload(y);
            anyhow::ensure!(
                dec.len() == d,
                "neighbor_allreduce: rank {src} sent a {}-element stream, expected {d}",
                dec.len()
            );
            incoming.push((r as f32, dec));
        }
        let mut parts: Vec<&[f32]> = incoming.iter().map(|(_, y)| y.as_slice()).collect();
        let mut ws: Vec<f32> = incoming.iter().map(|(r, _)| *r).collect();
        let correct = !scale_on_send && shared.is_some() && self.comp.spec().error_feedback;
        let out = match self.comp.estimate(shared_key) {
            Some(est) if correct => {
                // CHOCO-style relaxed, mean-conserving combine:
                // x + γ(Σ_j r_j x̂_j − (1 − w_self) x̂_self).
                let gamma = self.comp.spec().gossip_gamma;
                for w in ws.iter_mut() {
                    *w *= gamma;
                }
                parts.push(est);
                ws.push(-gamma * (1.0 - self_weight as f32));
                self.combine_hotpath(data, 1.0, &parts, &ws)
            }
            _ => self.combine_hotpath(data, self_weight as f32, &parts, &ws),
        };
        drop(parts);
        for (_, y) in incoming {
            self.recycle(y);
        }
        self.defer_reclaim(shared);
        Ok(out)
    }

    /// `bf.neighbor_allgather(tensor)` — collect the raw tensors of all
    /// in-neighbors (MPI_Neighbor_allgatherv: sizes may vary per neighbor).
    /// Returns `(src_rank, tensor)` pairs sorted by source rank.
    pub fn neighbor_allgather(
        &mut self,
        data: &[f32],
    ) -> anyhow::Result<Vec<(usize, Vec<f32>)>> {
        let me = self.rank();
        let (srcs, dsts) = {
            let topo = self.topology.read().unwrap();
            (topo.views.in_neighbor_ranks(me), topo.views.out_neighbors(me).to_vec())
        };
        let name = self.next_collective_name("neighbor_allgather");
        self.negotiate(
            &name,
            OpKind::NeighborAllgather,
            data.len(),
            Some(dsts.clone()),
            Some(srcs.clone()),
        )?;
        let tag = self.next_tag("neighbor_allgather");
        let shared = self.payload_from(data);
        for &dst in &dsts {
            self.send_shared(dst, tag, shared.clone())?;
        }
        let mut out = Vec::with_capacity(srcs.len());
        for &src in &srcs {
            let y = self.recv_tensor(src, tag)?;
            out.push((src, self.take_payload(y)));
        }
        self.defer_reclaim(Some(shared));
        Ok(out)
    }
}
