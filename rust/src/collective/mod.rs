//! Collective communication (paper §II-B, §III, §V-B, §VI-B).
//!
//! - [`global`] — global-averaging primitives the paper compares against:
//!   ring allreduce (the Horovod baseline), parameter server, BytePS,
//!   broadcast, barrier.
//! - [`neighbor`] — partial averaging: `neighbor_allreduce` over the static
//!   global topology or a dynamic local view (`self/src/dst` weights), and
//!   `neighbor_allgather`.
//! - [`hierarchical`] — `hierarchical_neighbor_allreduce`, the two-tier
//!   variant exploiting fast intra-machine links (paper §V-B, Fig. 7/10).

pub mod global;
pub mod hierarchical;
pub mod neighbor;

/// How `allreduce` averages are computed by the global primitives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// Elementwise sum across ranks.
    Sum,
    /// Elementwise mean across ranks.
    Average,
}

/// Which global-averaging algorithm `allreduce` uses (paper Table I rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AllreduceAlgo {
    /// Chunked ring allreduce — the Horovod/NCCL algorithm: `2M/B + 2nL`.
    #[default]
    Ring,
    /// Central parameter server at rank 0: `nM/B + nL`.
    ParameterServer,
    /// BytePS-style sharded servers: `M/B + nL`.
    BytePs,
}

/// Communication style selector mirrored from the BlueFog optimizer API.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommunicationType {
    /// Global averaging every step.
    Allreduce,
    /// Partial (neighborhood) averaging.
    NeighborAllreduce,
    /// Two-tier machine-level partial averaging.
    HierarchicalNeighborAllreduce,
    /// No communication this step (local SGD step).
    Empty,
}
