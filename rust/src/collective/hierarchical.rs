//! Hierarchical neighbor allreduce (paper §V-B, Fig. 7; §VI-B, Fig. 10).
//!
//! For two-tier networks (fast NVLink inside a machine, slow NIC between
//! machines) the flat `neighbor_allreduce` wastes inter-machine bandwidth.
//! The hierarchical variant runs four steps:
//!
//! 1. **Intra-machine allreduce** (sum) over the machine's local ranks —
//!    cheap on NVLink;
//! 2. **Inter-machine neighbor communication**: local rank 0 of each
//!    machine performs partial averaging over the *machine-level* topology;
//! 3. **Intra-machine broadcast** of the received neighbor average;
//! 4. Every local rank adopts the machine-level result.
//!
//! Note (paper): this is **not** functionally equivalent to the flat
//! operation — the neighborhood is defined at machine level.

use crate::context::{ef_key, NodeContext, EF_HIER};
use crate::negotiation::OpKind;
use crate::topology::WeightMatrix;

impl NodeContext {
    /// `bf.hierarchical_neighbor_allreduce(tensor)` over the machine-level
    /// topology (set via [`NodeContext::set_machine_topology`], defaulting
    /// to the exponential-2 graph over machines).
    ///
    /// With a single machine this degrades to a plain intra-machine average
    /// (matching the paper's Fig. 12 note that 4/8-GPU points reuse the
    /// flat result).
    pub fn hierarchical_neighbor_allreduce(&mut self, data: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.hierarchical_neighbor_allreduce_stream(data, 0)
    }

    /// Hierarchical variant on an explicit error-feedback stream id (see
    /// [`crate::optim::CommSpec::combine_stream`]).
    pub(crate) fn hierarchical_neighbor_allreduce_stream(
        &mut self,
        data: &[f32],
        stream: u32,
    ) -> anyhow::Result<Vec<f32>> {
        let wall = self.timeline.now_us();
        let v0 = self.vtime();
        let g = self.local_size();
        let n_machines = (self.size() + g - 1) / g;
        anyhow::ensure!(
            self.size() % g == 0,
            "hierarchical_neighbor_allreduce is ill-defined when machines have \
             different numbers of processes (size {} not divisible by local size {g})",
            self.size()
        );
        let name = self.next_collective_name("hier_neighbor_allreduce");
        self.negotiate(&name, OpKind::HierarchicalNeighborAllreduce, data.len(), None, None)?;

        let machine = self.machine_rank();
        let members: Vec<usize> = (machine * g..(machine + 1) * g).collect();

        // Step 1: intra-machine allreduce (average) over NVLink.
        let mut local_avg = self.ring_allreduce_group(&members, data, "hier.intra")?;
        let inv = 1.0 / g as f32;
        for x in local_avg.iter_mut() {
            *x *= inv;
        }

        // Step 2: machine-level neighbor averaging, local rank 0 only.
        let machine_weights = {
            let topo = self.load_topology();
            match &topo.machine_weights {
                Some(w) => w.clone(),
                None => WeightMatrix::exponential_two(n_machines),
            }
        };
        // `result` takes over the intra-machine average and is combined in
        // place; the inter-machine payload snapshots it first.
        let mut result = local_avg;
        if self.local_rank() == 0 && n_machines > 1 {
            let (self_w, srcs) = machine_weights.pull_view(machine);
            let (_, dsts) = machine_weights.push_view(machine);
            let tag = self.next_tag("hier.inter");
            // The inter-machine leg rides the slow NIC tier — exactly where
            // a configured compression spec pays; the NVLink-tier phases
            // (intra allreduce, broadcast) stay dense.
            if self.comp.enabled() {
                let d = result.len();
                let send_key = ef_key(EF_HIER, stream, 0, d);
                let mut wire = self.codec_scratch(self.comp.encoded_cap(d));
                self.comp.encode(send_key, &result, &mut wire);
                let shared = std::sync::Arc::new(wire);
                for &(dst_machine, _) in &dsts {
                    self.send_shared(dst_machine * g, tag, shared.clone())?;
                }
                let mut incoming: Vec<(f32, Vec<f32>)> = Vec::with_capacity(srcs.len());
                for &(src_machine, w) in &srcs {
                    let y = self.recv_tensor(src_machine * g, tag).map_err(|e| {
                        e.context(format!(
                            "hierarchical: inter-machine recv from machine {src_machine}"
                        ))
                    })?;
                    let mut dec = self.codec_scratch(d);
                    self.comp.decode(ef_key(EF_HIER, stream, src_machine, d), &y, &mut dec)?;
                    self.reclaim_payload(y);
                    anyhow::ensure!(
                        dec.len() == d,
                        "hierarchical: machine {src_machine} sent a {}-element stream, \
                         expected {d}",
                        dec.len()
                    );
                    incoming.push((w as f32, dec));
                }
                let mut parts: Vec<&[f32]> =
                    incoming.iter().map(|(_, y)| y.as_slice()).collect();
                let mut ws: Vec<f32> = incoming.iter().map(|(w, _)| *w).collect();
                // Same relaxed mean-conserving combine as the flat static
                // form: the machine topology is fixed, so the fan-out
                // stream is shared and x̂_self is available.
                match self.comp.estimate(send_key) {
                    Some(est) if self.comp.spec().error_feedback => {
                        let gamma = self.comp.spec().gossip_gamma;
                        for w in ws.iter_mut() {
                            *w *= gamma;
                        }
                        parts.push(est);
                        ws.push(-gamma * (1.0 - self_w as f32));
                        self.combine_into_hotpath(&mut result, 1.0, &parts, &ws);
                    }
                    _ => self.combine_into_hotpath(&mut result, self_w as f32, &parts, &ws),
                }
                drop(parts);
                for (_, y) in incoming {
                    self.recycle(y);
                }
                self.defer_reclaim(Some(shared));
            } else {
                let shared = self.payload_from(&result);
                for &(dst_machine, _) in &dsts {
                    self.send_shared(dst_machine * g, tag, shared.clone())?;
                }
                let mut incoming = Vec::with_capacity(srcs.len());
                for &(src_machine, w) in &srcs {
                    let y = self.recv_tensor(src_machine * g, tag).map_err(|e| {
                        e.context(format!(
                            "hierarchical: inter-machine recv from machine {src_machine}"
                        ))
                    })?;
                    incoming.push((w as f32, y));
                }
                let parts: Vec<&[f32]> = incoming.iter().map(|(_, y)| y.as_slice()).collect();
                let ws: Vec<f32> = incoming.iter().map(|(w, _)| *w).collect();
                self.combine_into_hotpath(&mut result, self_w as f32, &parts, &ws);
                drop(parts);
                for (_, y) in incoming {
                    self.reclaim_payload(y);
                }
                self.defer_reclaim(Some(shared));
            }
        }

        // Steps 3-4: intra-machine broadcast of the machine-level result.
        if g > 1 {
            self.broadcast_group(&members, &mut result, members[0], "hier.bcast")?;
        }
        self.timeline
            .record(self.rank(), "hierarchical_neighbor_allreduce", "comm", wall, v0, self.vtime());
        Ok(result)
    }

    /// Ring allreduce (sum) restricted to `members` (which must contain this
    /// rank). Used for the intra-machine phase.
    pub(crate) fn ring_allreduce_group(
        &mut self,
        members: &[usize],
        data: &[f32],
        op_name: &str,
    ) -> anyhow::Result<Vec<f32>> {
        let k = members.len();
        let me_idx = members
            .iter()
            .position(|&r| r == self.rank())
            .ok_or_else(|| anyhow::anyhow!("rank {} not in group", self.rank()))?;
        if k == 1 {
            return Ok(data.to_vec());
        }
        let tag = self.next_tag(op_name);
        let len = data.len();
        let bounds: Vec<(usize, usize)> =
            (0..k).map(|c| (c * len / k, (c + 1) * len / k)).collect();
        let mut buf = self.vec_from(data);
        let next = members[(me_idx + 1) % k];
        let prev = members[(me_idx + k - 1) % k];
        for r in 0..(k - 1) {
            let send_c = (me_idx + k - r) % k;
            let recv_c = (me_idx + k - r - 1) % k;
            let (slo, shi) = bounds[send_c];
            let rtag = tag + r as u64;
            let payload = self.payload_from(&buf[slo..shi]);
            self.send_shared(next, rtag, payload)?;
            let incoming = self.recv_tensor(prev, rtag)?;
            let (rlo, rhi) = bounds[recv_c];
            for (x, y) in buf[rlo..rhi].iter_mut().zip(incoming.iter()) {
                *x += y;
            }
            self.reclaim_payload(incoming);
        }
        for r in 0..(k - 1) {
            let send_c = (me_idx + 1 + k - r) % k;
            let recv_c = (me_idx + k - r) % k;
            let (slo, shi) = bounds[send_c];
            let rtag = tag + k as u64 + r as u64;
            let payload = self.payload_from(&buf[slo..shi]);
            self.send_shared(next, rtag, payload)?;
            let incoming = self.recv_tensor(prev, rtag)?;
            let (rlo, rhi) = bounds[recv_c];
            buf[rlo..rhi].copy_from_slice(&incoming);
            self.reclaim_payload(incoming);
        }
        Ok(buf)
    }

    /// Broadcast within `members` from `root` (linear fan-out — fine for
    /// machine-sized groups over NVLink).
    pub(crate) fn broadcast_group(
        &mut self,
        members: &[usize],
        data: &mut Vec<f32>,
        root: usize,
        op_name: &str,
    ) -> anyhow::Result<()> {
        let tag = self.next_tag(op_name);
        if self.rank() == root {
            let shared = self.payload_from(data);
            for &m in members {
                if m != root {
                    self.send_shared(m, tag, shared.clone())?;
                }
            }
            self.defer_reclaim(Some(shared));
        } else {
            let y = self.recv_tensor(root, tag)?;
            let old = std::mem::replace(data, self.take_payload(y));
            self.recycle(old);
        }
        Ok(())
    }
}
