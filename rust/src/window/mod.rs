//! Asynchronous one-sided window operations (paper §III-C, §IV-C).
//!
//! Every node can expose a named *window*: a buffer per in-coming neighbor
//! plus a registered copy of its local tensor. Remote nodes manipulate the
//! window without the owner's participation:
//!
//! - [`NodeContext::win_put`] overwrites the caller's slot at each
//!   destination;
//! - [`NodeContext::win_accumulate`] adds into the slot (and, with a
//!   `self_weight`, scales the caller's own tensor so total mass is
//!   conserved — the push-sum requirement);
//! - [`NodeContext::win_get`] pulls neighbors' registered tensors into the
//!   caller's own slots;
//! - [`NodeContext::win_update`] makes remote writes visible and returns the
//!   weighted average of the local tensor and the slots;
//! - [`NodeContext::win_update_then_collect`] *sums and resets* the slots —
//!   the atomic drain that keeps `sum_i (x_i + pending)` invariant, which is
//!   exactly what unbiased asynchronous push-sum needs (paper Listing 3);
//! - [`NodeContext::win_update_then_collect_causal`] drains only writes
//!   whose virtual arrival has passed, leaving future writes pending — the
//!   drain the asynchronous optimizers use so a fast rank is never dragged
//!   onto a straggler's timeline.
//!
//! Each window entry carries one mutex — the "distributed mutex" of paper
//! §V-D — and per-slot virtual arrival times so the virtual clock reflects
//! asynchronous message delays.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::context::NodeContext;

/// State of one `(owner, name)` window.
#[derive(Debug, Default)]
pub struct WindowState {
    /// Element count of the windowed tensor.
    pub len: usize,
    /// Owner's registered local tensor (refreshed by `win_update*`).
    pub local: Vec<f32>,
    /// One buffer per in-coming neighbor rank.
    pub slots: HashMap<usize, Vec<f32>>,
    /// Virtual arrival time of the latest write per slot.
    pub slot_vtime: HashMap<usize, f64>,
    /// Monotone counter of remote writes (for tests/metrics).
    pub writes: u64,
}

/// Global registry of windows, shared by all in-process nodes.
#[derive(Default)]
pub struct WindowTable {
    entries: Mutex<HashMap<(usize, String), Arc<Mutex<WindowState>>>>,
}

impl WindowTable {
    /// An empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    fn create(
        &self,
        owner: usize,
        name: &str,
        tensor: &[f32],
        in_neighbors: &[usize],
        zero_init: bool,
    ) -> anyhow::Result<()> {
        let mut entries = self.entries.lock().unwrap();
        let key = (owner, name.to_string());
        if entries.contains_key(&key) {
            anyhow::bail!("window '{name}' already exists at rank {owner}");
        }
        let mut slots = HashMap::new();
        let mut slot_vtime = HashMap::new();
        for &nb in in_neighbors {
            let init = if zero_init { vec![0.0; tensor.len()] } else { tensor.to_vec() };
            slots.insert(nb, init);
            slot_vtime.insert(nb, 0.0);
        }
        entries.insert(
            key,
            Arc::new(Mutex::new(WindowState {
                len: tensor.len(),
                local: tensor.to_vec(),
                slots,
                slot_vtime,
                writes: 0,
            })),
        );
        Ok(())
    }

    fn get(&self, owner: usize, name: &str) -> anyhow::Result<Arc<Mutex<WindowState>>> {
        self.entries
            .lock()
            .unwrap()
            .get(&(owner, name.to_string()))
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("window '{name}' not found at rank {owner}"))
    }

    fn free(&self, owner: usize, name: &str) -> anyhow::Result<()> {
        self.entries
            .lock()
            .unwrap()
            .remove(&(owner, name.to_string()))
            .map(|_| ())
            .ok_or_else(|| anyhow::anyhow!("window '{name}' not found at rank {owner}"))
    }

    /// Number of live windows (tests).
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when no windows are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl NodeContext {
    /// `bf.win_create(tensor, name)` — allocate the window at this rank with
    /// one slot per in-coming neighbor under the current global topology.
    ///
    /// Collective (like `MPI_Win_create`): all ranks must call it, and no
    /// rank returns before every window exists. The barrier is reached on
    /// both the success and the error path — a rank whose local create
    /// fails (e.g. duplicate name) must still participate, otherwise its
    /// peers deadlock waiting for it; the local error is propagated after
    /// the ranks have synchronized.
    pub fn win_create(&mut self, name: &str, tensor: &[f32], zero_init: bool) -> anyhow::Result<()> {
        let in_nbrs = self.in_neighbor_ranks();
        let created = self.windows.create(self.rank(), name, tensor, &in_nbrs, zero_init);
        let synced = self.barrier();
        created.and(synced)
    }

    /// `bf.win_free(name)`.
    pub fn win_free(&self, name: &str) -> anyhow::Result<()> {
        self.windows.free(self.rank(), name)
    }

    /// `bf.win_put(tensor, name, dst_weights)` — overwrite this rank's slot
    /// at each destination with `w * tensor`. Destinations default to the
    /// out-neighbors with weight 1 when `dst_weights` is empty.
    pub fn win_put(&self, name: &str, tensor: &[f32], dst_weights: &[(usize, f64)]) -> anyhow::Result<()> {
        // One-sided ops never block, but under ExecMode::EventLoop they
        // yield cooperatively first so remote writes land in global
        // virtual-time order (a peer with an earlier clock drains its
        // window before this later write appears in it).
        self.coop_yield();
        // One-sided ops cannot hang on a dead peer (the table is local),
        // so the fault layer's only hook here is the caller's own crash
        // schedule.
        self.fault_guard()?;
        let dsts = self.default_dsts(dst_weights);
        for (dst, w) in dsts {
            let arrival = self.one_sided_arrival(dst, tensor.len() * 4);
            let entry = self.windows.get(dst, name)?;
            let mut st = entry.lock().unwrap();
            anyhow::ensure!(st.len == tensor.len(), "win_put size mismatch on '{name}'");
            anyhow::ensure!(
                st.slots.contains_key(&self.rank()),
                "rank {} is not an in-neighbor of rank {dst} for window '{name}' \
                 (window topology is fixed at creation)",
                self.rank()
            );
            let slot = st.slots.get_mut(&self.rank()).unwrap();
            for (s, x) in slot.iter_mut().zip(tensor) {
                *s = (w as f32) * x;
            }
            st.slot_vtime.insert(self.rank(), arrival);
            st.writes += 1;
        }
        Ok(())
    }

    /// `bf.win_accumulate(tensor, name, self_weight, dst_weights)` — add
    /// `w * tensor` into this rank's slot at each destination and scale the
    /// caller's tensor by `self_weight` (mass splitting: with a
    /// column-stochastic weight set, `sum_i x_i + pending` is conserved).
    /// Destinations default to the out-neighbors with weight 1 when
    /// `dst_weights` is empty, the same fallback as `win_put`/`win_get` —
    /// the caller's tensor is scaled by `self_weight` either way, so
    /// silently sending to nobody would destroy mass. Note the weight-1
    /// default is the `win_put` convention (each destination receives the
    /// full tensor), *not* a column-stochastic split: mass-conserving
    /// algorithms must pass explicit weights with
    /// `self_weight + Σ column = 1`, as the async push-sum optimizer does.
    pub fn win_accumulate(
        &self,
        name: &str,
        tensor: &mut [f32],
        self_weight: f64,
        dst_weights: &[(usize, f64)],
    ) -> anyhow::Result<()> {
        // Same vtime-ordering yield and crash guard as win_put (see there).
        self.coop_yield();
        self.fault_guard()?;
        let dsts = self.default_dsts(dst_weights);
        for &(dst, w) in &dsts {
            let arrival = self.one_sided_arrival(dst, tensor.len() * 4);
            let entry = self.windows.get(dst, name)?;
            let mut st = entry.lock().unwrap();
            anyhow::ensure!(st.len == tensor.len(), "win_accumulate size mismatch on '{name}'");
            anyhow::ensure!(
                st.slots.contains_key(&self.rank()),
                "rank {} is not an in-neighbor of rank {dst} for window '{name}'",
                self.rank()
            );
            let slot = st.slots.get_mut(&self.rank()).unwrap();
            for (s, x) in slot.iter_mut().zip(tensor.iter()) {
                *s += (w as f32) * x;
            }
            let prev = st.slot_vtime.get(&self.rank()).copied().unwrap_or(0.0);
            st.slot_vtime.insert(self.rank(), prev.max(arrival));
            st.writes += 1;
        }
        for x in tensor.iter_mut() {
            *x *= self_weight as f32;
        }
        Ok(())
    }

    /// `bf.win_get(tensor, name, src_weights)` — pull each source's
    /// *registered* tensor (as of its last `win_update*`) into this rank's
    /// own window slots, scaled by the source weight.
    pub fn win_get(&self, name: &str, src_weights: &[(usize, f64)]) -> anyhow::Result<()> {
        // Same vtime-ordering yield and crash guard as win_put (see there).
        self.coop_yield();
        self.fault_guard()?;
        let srcs = self.default_srcs(src_weights);
        let own = self.windows.get(self.rank(), name)?;
        for (src, w) in srcs {
            let remote = self.windows.get(src, name)?;
            let data: Vec<f32> = {
                let st = remote.lock().unwrap();
                self.scaled_vec(&st.local, w as f32)
            };
            let arrival = self.one_sided_arrival(src, data.len() * 4);
            let mut st = own.lock().unwrap();
            anyhow::ensure!(
                st.slots.contains_key(&src),
                "rank {src} is not an in-neighbor of rank {} for window '{name}'",
                self.rank()
            );
            // The displaced slot buffer feeds the pool for the next pull.
            if let Some(old) = st.slots.insert(src, data) {
                self.recycle(old);
            }
            st.slot_vtime.insert(src, arrival);
            st.writes += 1;
        }
        Ok(())
    }

    /// `bf.win_update(name, self_weight, src_weights)` — synchronize the
    /// window and return the weighted average of the local tensor and the
    /// neighbor slots. Also registers `tensor` as the new local value so
    /// subsequent `win_get`s observe it. Blocking flavor: every listed
    /// slot participates, including writes whose virtual arrival is still
    /// in this rank's future, and the clock advances to the latest such
    /// arrival (the rank "waits" for them).
    pub fn win_update(
        &self,
        name: &str,
        tensor: &[f32],
        self_weight: f64,
        src_weights: &[(usize, f64)],
    ) -> anyhow::Result<Vec<f32>> {
        self.combine_window(name, tensor, self_weight, src_weights, false)
    }

    /// Causal variant of [`NodeContext::win_update`]: average only with the
    /// slots whose latest write has virtually *arrived* (arrival vtime ≤
    /// this rank's current vtime). A listed source whose write is still in
    /// flight keeps its weight on the local tensor instead, so the
    /// combination stays convex whenever the caller's weights sum to one,
    /// and the caller's clock is never advanced — the `win_update` the
    /// asynchronous gossip optimizer uses so a straggler is never dragged
    /// onto a fast peer's timeline (or vice versa). Errors on a listed
    /// source with no slot, like `win_update`.
    pub fn win_update_causal(
        &self,
        name: &str,
        tensor: &[f32],
        self_weight: f64,
        src_weights: &[(usize, f64)],
    ) -> anyhow::Result<Vec<f32>> {
        self.combine_window(name, tensor, self_weight, src_weights, true)
    }

    /// Shared combine kernel behind [`NodeContext::win_update`] and
    /// [`NodeContext::win_update_causal`]: weighted average of the local
    /// tensor and the listed slots, registered as the window's new local
    /// value. `causal` reassigns the weight of any slot whose latest write
    /// has not virtually arrived onto the local tensor (keeping the
    /// combination convex) — in that mode `latest ≤ now`, so the final
    /// clock advance is a no-op.
    fn combine_window(
        &self,
        name: &str,
        tensor: &[f32],
        self_weight: f64,
        src_weights: &[(usize, f64)],
        causal: bool,
    ) -> anyhow::Result<Vec<f32>> {
        self.fault_guard()?;
        let srcs = self.default_srcs(src_weights);
        let entry = self.windows.get(self.rank(), name)?;
        let mut st = entry.lock().unwrap();
        anyhow::ensure!(st.len == tensor.len(), "win_update size mismatch on '{name}'");
        let now = self.vtime();
        let mut self_w = self_weight;
        let mut latest = now;
        let mut included: Vec<(usize, f64)> = Vec::with_capacity(srcs.len());
        for (src, w) in srcs {
            // A listed source without a slot must be an error, not a silent
            // skip: dropping its weight would bias the average low (the
            // same contract win_put/win_get enforce).
            anyhow::ensure!(
                st.slots.contains_key(&src),
                "rank {src} is not an in-neighbor of rank {} for window '{name}' \
                 (window topology is fixed at creation)",
                self.rank()
            );
            let arrival = st.slot_vtime.get(&src).copied().unwrap_or(0.0);
            if causal && arrival > now {
                self_w += w;
            } else {
                included.push((src, w));
                latest = latest.max(arrival);
            }
        }
        let mut out = self.scaled_vec(tensor, self_w as f32);
        for (src, w) in included {
            let slot = st.slots.get(&src).unwrap();
            for (o, s) in out.iter_mut().zip(slot) {
                *o += (w as f32) * s;
            }
        }
        let old = std::mem::replace(&mut st.local, self.vec_from(&out));
        self.recycle(old);
        self.clock().advance_to(latest);
        Ok(out)
    }

    /// `bf.win_update_then_collect(name)` — atomically add all pending slot
    /// contents into the local tensor and **reset the slots to zero**. With
    /// `win_accumulate`, this is the mass-conserving drain of asynchronous
    /// push-sum. Returns the collected tensor.
    ///
    /// This variant is a *blocking* drain: it collects every slot, including
    /// writes whose virtual arrival lies in this rank's future, and advances
    /// the local clock to the latest arrival (the rank "waits" for them).
    /// Asynchronous optimizers should prefer
    /// [`NodeContext::win_update_then_collect_causal`], which never pulls
    /// the caller's clock forward.
    pub fn win_update_then_collect(&self, name: &str, tensor: &mut [f32]) -> anyhow::Result<()> {
        self.drain_window(name, tensor, false).map(|_| ())
    }

    /// Causal variant of [`NodeContext::win_update_then_collect`]: collect
    /// only the slots whose latest write has virtually *arrived* (arrival
    /// vtime ≤ this rank's current vtime) and leave the rest pending —
    /// exactly what a real one-sided window would expose at this instant.
    /// The caller's clock is never advanced past `now`, so a fast rank is
    /// not dragged to a straggler's timeline by merely draining its window.
    /// Returns the number of slots whose content was *deferred* because its
    /// latest write is still in flight (useful as a staleness signal).
    pub fn win_update_then_collect_causal(
        &self,
        name: &str,
        tensor: &mut [f32],
    ) -> anyhow::Result<usize> {
        self.drain_window(name, tensor, true)
    }

    /// Shared drain kernel: collect slots into `tensor`, zero them, register
    /// the result as the new local value. `causal` gates collection on the
    /// slot's virtual arrival time; a slot whose latest write is in the
    /// future is skipped whole (per-source writes arrive in causal order, so
    /// an arrived latest write implies every merged write has arrived).
    fn drain_window(&self, name: &str, tensor: &mut [f32], causal: bool) -> anyhow::Result<usize> {
        self.fault_guard()?;
        let entry = self.windows.get(self.rank(), name)?;
        let mut guard = entry.lock().unwrap();
        let st = &mut *guard;
        anyhow::ensure!(st.len == tensor.len(), "win_update_then_collect size mismatch on '{name}'");
        let now = self.vtime();
        let mut latest = now;
        let mut deferred = 0usize;
        for (src, slot) in st.slots.iter_mut() {
            let arrival = st.slot_vtime.get(src).copied().unwrap_or(0.0);
            if causal && arrival > now {
                deferred += 1;
                continue;
            }
            for (x, s) in tensor.iter_mut().zip(slot.iter_mut()) {
                *x += *s;
                *s = 0.0;
            }
            // A collected slot is no longer pending: drop its arrival time
            // so win_staleness only reports mass still awaiting a drain.
            st.slot_vtime.remove(src);
            latest = latest.max(arrival);
        }
        let old = std::mem::replace(&mut st.local, self.vec_from(tensor));
        self.recycle(old);
        self.clock().advance_to(latest);
        Ok(deferred)
    }

    /// Elementwise sum of this rank's pending (written but not yet
    /// collected) slot contents — the "in flight" term of the push-sum
    /// conservation invariant `Σ_i (x_i + pending_i)`. Read-only; used by
    /// the mass-conservation property tests and staleness diagnostics.
    pub fn win_pending(&self, name: &str) -> anyhow::Result<Vec<f32>> {
        let entry = self.windows.get(self.rank(), name)?;
        let st = entry.lock().unwrap();
        let mut sum = vec![0.0f32; st.len];
        for slot in st.slots.values() {
            for (acc, s) in sum.iter_mut().zip(slot) {
                *acc += *s;
            }
        }
        Ok(sum)
    }

    /// Staleness of this rank's window: current vtime minus the *oldest*
    /// last-write arrival among slots that have ever been written. Returns
    /// 0 when no slot has been written yet or every write is newer than
    /// `now` (writes still in flight are not stale, merely pending).
    pub fn win_staleness(&self, name: &str) -> anyhow::Result<f64> {
        let entry = self.windows.get(self.rank(), name)?;
        let st = entry.lock().unwrap();
        let oldest = st
            .slot_vtime
            .values()
            .copied()
            .filter(|&t| t > 0.0)
            .fold(f64::INFINITY, f64::min);
        if oldest.is_finite() {
            Ok((self.vtime() - oldest).max(0.0))
        } else {
            Ok(0.0)
        }
    }

    /// Virtual arrival time of a one-sided transfer to/from `peer`.
    fn one_sided_arrival(&self, peer: usize, bytes: usize) -> f64 {
        let now = self.vtime();
        let ser = self.net.port_time(self.rank(), peer, bytes);
        let done = self.clock().reserve_send(now, ser);
        done + self.net.latency(self.rank(), peer)
    }

    fn default_dsts(&self, dst_weights: &[(usize, f64)]) -> Vec<(usize, f64)> {
        if dst_weights.is_empty() {
            self.out_neighbor_ranks().into_iter().map(|r| (r, 1.0)).collect()
        } else {
            dst_weights.to_vec()
        }
    }

    fn default_srcs(&self, src_weights: &[(usize, f64)]) -> Vec<(usize, f64)> {
        if src_weights.is_empty() {
            self.in_neighbor_ranks().into_iter().map(|r| (r, 1.0)).collect()
        } else {
            src_weights.to_vec()
        }
    }
}
