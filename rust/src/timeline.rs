//! Timeline tracing (paper §V-D "timeline function").
//!
//! Records `(rank, name, category, wall start/dur, virtual start/end)` for
//! every traced operation and can serialize to the Chrome trace-event JSON
//! format (`chrome://tracing`, Perfetto). Used by the ablation benches to
//! visualize communication/computation overlap.

use std::sync::Mutex;
use std::time::Instant;

/// One traced span.
#[derive(Debug, Clone)]
pub struct Event {
    /// Rank that recorded the span.
    pub rank: usize,
    /// Operation name (e.g. `neighbor_allreduce`).
    pub name: String,
    /// Trace category (`comm`, `compute`, ...).
    pub category: &'static str,
    /// Wall-clock microseconds since timeline creation.
    pub wall_start_us: f64,
    /// Wall-clock duration in microseconds.
    pub wall_dur_us: f64,
    /// Virtual times (seconds) at span start/end.
    pub vtime_start: f64,
    /// Virtual time (seconds) at span end.
    pub vtime_end: f64,
}

/// Thread-safe event recorder shared by all node threads.
pub struct Timeline {
    origin: Instant,
    events: Mutex<Vec<Event>>,
    enabled: bool,
}

impl Timeline {
    /// New recorder; a disabled one drops every span at zero cost.
    pub fn new(enabled: bool) -> Self {
        Timeline { origin: Instant::now(), events: Mutex::new(vec![]), enabled }
    }

    /// Microseconds since the timeline was created.
    pub fn now_us(&self) -> f64 {
        self.origin.elapsed().as_secs_f64() * 1e6
    }

    /// Record a completed span.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        rank: usize,
        name: &str,
        category: &'static str,
        wall_start_us: f64,
        vtime_start: f64,
        vtime_end: f64,
    ) {
        if !self.enabled {
            return;
        }
        let wall_dur_us = self.now_us() - wall_start_us;
        self.events.lock().unwrap().push(Event {
            rank,
            name: name.to_string(),
            category,
            wall_start_us,
            wall_dur_us,
            vtime_start,
            vtime_end,
        });
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    /// True when no events were recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of all events.
    pub fn events(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    /// Serialize to Chrome trace-event JSON ("X" complete events, wall
    /// clock). `pid` is the rank, so each node gets its own track.
    pub fn to_chrome_trace(&self) -> String {
        let events = self.events.lock().unwrap();
        let mut out = String::from("[\n");
        for (i, e) in events.iter().enumerate() {
            let comma = if i + 1 == events.len() { "" } else { "," };
            out.push_str(&format!(
                "  {{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \"pid\": {}, \"tid\": {}, \"ts\": {:.3}, \"dur\": {:.3}, \"args\": {{\"vtime_start\": {:.9}, \"vtime_end\": {:.9}}}}}{}\n",
                escape(&e.name), e.category, e.rank, e.rank, e.wall_start_us, e.wall_dur_us,
                e.vtime_start, e.vtime_end, comma
            ));
        }
        out.push(']');
        out
    }

    /// Write the Chrome trace to a file.
    pub fn dump(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_trace())
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_timeline_records_nothing() {
        let t = Timeline::new(false);
        t.record(0, "x", "comm", 0.0, 0.0, 1.0);
        assert!(t.is_empty());
    }

    #[test]
    fn records_and_serializes() {
        let t = Timeline::new(true);
        let start = t.now_us();
        t.record(1, "neighbor_allreduce", "comm", start, 0.0, 0.5);
        t.record(1, "grad \"q\"", "compute", start, 0.5, 0.7);
        assert_eq!(t.len(), 2);
        let json = t.to_chrome_trace();
        assert!(json.contains("neighbor_allreduce"));
        assert!(json.contains("\\\"q\\\""), "quotes escaped: {json}");
        assert!(json.starts_with('[') && json.ends_with(']'));
    }

    #[test]
    fn events_snapshot_is_ordered() {
        let t = Timeline::new(true);
        for i in 0..5 {
            t.record(0, &format!("e{i}"), "comm", t.now_us(), i as f64, i as f64 + 1.0);
        }
        let ev = t.events();
        assert_eq!(ev.len(), 5);
        assert_eq!(ev[3].name, "e3");
    }
}
