//! Rank-local worker pool for intra-rank kernel parallelism (ISSUE 9).
//!
//! Each endpoint (node context or communication engine) owns a
//! [`WorkerPool`] sized by `SpmdConfig::intra_threads` (default 1). The
//! pool shards multi-MB combines and codec encodes into contiguous,
//! fixed-boundary output ranges, each written by exactly one worker.
//!
//! # Determinism argument (DESIGN.md §Kernels)
//!
//! Sharding is deterministic by construction, not by synchronization:
//!
//! 1. shard boundaries are a pure function of `(len, threads, align)` —
//!    see [`shard_bounds`] — never of timing or work stealing;
//! 2. every shard of the output is written by exactly one task, using the
//!    same serial kernel over the same operands in the same order the
//!    single-threaded code would use for that range;
//! 3. tasks share no mutable state besides their disjoint output shards.
//!
//! Therefore the bytes produced are identical for any `intra_threads`
//! setting, including 1 (pinned by `tests/kernels.rs`). With one thread
//! the pool spawns nothing and every `run` call executes inline, so the
//! default configuration is exactly the seed's serial behavior.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Inner {
    threads: usize,
    /// `None` once the pool has shut down (drop). Workers exit when the
    /// channel disconnects.
    tx: Mutex<Option<Sender<Job>>>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Drop for Inner {
    fn drop(&mut self) {
        // Disconnect the channel so the worker loops fall out of recv(),
        // then join them; a worker that panicked is already accounted for
        // by the completion barrier, so join errors are ignorable here.
        drop(self.tx.lock().expect("pool tx lock").take());
        for h in self.handles.lock().expect("pool handle lock").drain(..) {
            let _ = h.join();
        }
    }
}

/// A fixed-size rank-local thread pool executing closures over disjoint
/// output shards. Cloning is cheap (shared `Arc`); the threads are joined
/// when the last clone drops.
#[derive(Clone)]
pub struct WorkerPool {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("threads", &self.inner.threads).finish()
    }
}

impl WorkerPool {
    /// Pool with `threads` total lanes of execution: the calling thread
    /// plus `threads - 1` spawned workers. `threads <= 1` spawns nothing
    /// and makes every [`WorkerPool::run`] call execute inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        if threads == 1 {
            return WorkerPool {
                inner: Arc::new(Inner {
                    threads: 1,
                    tx: Mutex::new(None),
                    handles: Mutex::new(Vec::new()),
                }),
            };
        }
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(threads - 1);
        for w in 0..threads - 1 {
            let rx = Arc::clone(&rx);
            let h = std::thread::Builder::new()
                .name(format!("bf-par-{w}"))
                .spawn(move || worker_loop(&rx))
                .expect("spawn pool worker");
            handles.push(h);
        }
        WorkerPool {
            inner: Arc::new(Inner {
                threads,
                tx: Mutex::new(Some(tx)),
                handles: Mutex::new(handles),
            }),
        }
    }

    /// Process-wide inert single-thread pool for callers that were not
    /// handed an intra-rank pool.
    pub fn serial() -> &'static WorkerPool {
        static SERIAL: OnceLock<WorkerPool> = OnceLock::new();
        SERIAL.get_or_init(|| WorkerPool::new(1))
    }

    /// Total execution lanes (caller + workers).
    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    /// Run `f(0), f(1), …, f(tasks - 1)`, each exactly once, spread over
    /// the pool plus the calling thread. Blocks until every task has
    /// finished; a panicking task is caught on the worker, and `run`
    /// re-panics on the caller after all tasks complete. Inline (plain
    /// loop) when the pool is serial or there is at most one task.
    pub fn run<F>(&self, tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        let threads = self.inner.threads;
        if threads <= 1 || tasks <= 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }

        // Fat pointer to `f`, copied into each dispatched job. SAFETY of
        // the lifetime erasure: `run` does not return (not even by
        // unwinding — see WaitOnDrop) until the completion barrier has
        // counted every dispatched job, so no worker can touch the
        // pointer after `f` is dropped.
        #[derive(Clone, Copy)]
        struct TaskFn(*const (dyn Fn(usize) + Sync));
        unsafe impl Send for TaskFn {}
        let task_fn = TaskFn(&f as &(dyn Fn(usize) + Sync) as *const _);

        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        let panicked = Arc::new(AtomicBool::new(false));
        let mut dispatched = 0usize;
        {
            let guard = self.inner.tx.lock().expect("pool tx lock");
            let tx = guard.as_ref().expect("worker pool already shut down");
            for i in 0..tasks {
                if i % threads == 0 {
                    continue; // the caller's share
                }
                let done = Arc::clone(&done);
                let panicked = Arc::clone(&panicked);
                let job: Job = Box::new(move || {
                    let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (*task_fn.0)(i) })).is_ok();
                    if !ok {
                        panicked.store(true, Ordering::SeqCst);
                    }
                    let (count, cv) = &*done;
                    *count.lock().expect("pool barrier lock") += 1;
                    cv.notify_one();
                });
                tx.send(job).expect("worker pool channel closed");
                dispatched += 1;
            }
        }

        {
            // Waits for all dispatched jobs even if the caller's own share
            // panics, keeping the `task_fn` borrow alive past every use.
            let _barrier = WaitOnDrop { done: &done, need: dispatched };
            let mut i = 0;
            while i < tasks {
                f(i);
                i += threads;
            }
        }
        assert!(!panicked.load(Ordering::SeqCst), "worker pool task panicked");
    }

    /// Run a sharded mutation of `data`: `bounds` must be ascending,
    /// pairwise-disjoint `(lo, hi)` ranges within `data`; task `i`
    /// receives `(i, &mut data[lo_i..hi_i])`. Panics on malformed bounds.
    pub fn run_sharded_mut<F>(&self, data: &mut [f32], bounds: &[(usize, usize)], f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let mut prev = 0usize;
        for &(lo, hi) in bounds {
            assert!(prev <= lo && lo <= hi && hi <= data.len(), "malformed shard bounds");
            prev = hi;
        }
        let base = data.as_mut_ptr() as usize;
        self.run(bounds.len(), |i| {
            let (lo, hi) = bounds[i];
            // SAFETY: bounds are validated ascending and disjoint above,
            // and `run` hands each index to exactly one task, so no two
            // live `&mut` shards alias; all stay within `data`, which
            // outlives `run` (it blocks until every task completes).
            let sub =
                unsafe { std::slice::from_raw_parts_mut((base as *mut f32).add(lo), hi - lo) };
            f(i, sub);
        });
    }
}

/// Completion barrier armed on the stack of [`WorkerPool::run`]; waiting
/// in `Drop` makes the barrier unwind-safe (see the SAFETY note there).
struct WaitOnDrop<'a> {
    done: &'a (Mutex<usize>, Condvar),
    need: usize,
}

impl Drop for WaitOnDrop<'_> {
    fn drop(&mut self) {
        let (count, cv) = self.done;
        let mut n = count.lock().expect("pool barrier lock");
        while *n < self.need {
            n = cv.wait(n).expect("pool barrier wait");
        }
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        let job = match rx.lock().expect("pool rx lock").recv() {
            Ok(job) => job,
            Err(_) => return, // pool dropped
        };
        job();
    }
}

/// Cut `[0, len)` into at most `shards` contiguous ranges whose interior
/// boundaries are multiples of `align` (so blocked kernels never split a
/// block across workers). Pure function of its arguments — the center of
/// the determinism argument in the module docs. Returns an empty vector
/// for `len == 0`.
pub fn shard_bounds(len: usize, shards: usize, align: usize) -> Vec<(usize, usize)> {
    let shards = shards.max(1);
    let align = align.max(1);
    if len == 0 {
        return Vec::new();
    }
    let per = len.div_ceil(shards).div_ceil(align) * align;
    let mut bounds = Vec::with_capacity(shards);
    let mut lo = 0;
    while lo < len {
        let hi = (lo + per).min(len);
        bounds.push((lo, hi));
        lo = hi;
    }
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn shard_bounds_cover_exactly_once_and_align() {
        for (len, shards, align) in
            [(0, 4, 8), (1, 4, 8), (17, 4, 8), (100, 3, 8), (4096, 4, 4096), (10000, 4, 4096)]
        {
            let b = shard_bounds(len, shards, align);
            let mut prev = 0;
            for (i, &(lo, hi)) in b.iter().enumerate() {
                assert_eq!(lo, prev, "gap at shard {i}");
                assert!(lo < hi, "empty shard {i}");
                if hi != len {
                    assert_eq!(hi % align, 0, "unaligned interior boundary");
                }
                prev = hi;
            }
            assert_eq!(prev, len, "shards do not cover len={len}");
            assert!(b.len() <= shards.max(1));
        }
    }

    #[test]
    fn run_executes_each_index_exactly_once() {
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            let hits: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
            pool.run(hits.len(), |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "index {i} at {threads} threads");
            }
        }
    }

    #[test]
    fn run_sharded_mut_writes_disjoint_ranges() {
        let mut data = vec![0.0f32; 1000];
        let bounds = shard_bounds(data.len(), 4, 64);
        let pool = WorkerPool::new(4);
        pool.run_sharded_mut(&mut data, &bounds, |i, sub| {
            for x in sub.iter_mut() {
                *x += (i + 1) as f32;
            }
        });
        for (i, &(lo, hi)) in bounds.iter().enumerate() {
            assert!(data[lo..hi].iter().all(|&x| x == (i + 1) as f32));
        }
    }

    #[test]
    #[should_panic(expected = "worker pool task panicked")]
    fn worker_panic_propagates_to_caller() {
        let pool = WorkerPool::new(2);
        pool.run(8, |i| {
            if i == 5 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_round() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i % 2 == 1 {
                    panic!("boom");
                }
            })
        }));
        assert!(r.is_err());
        // The workers caught the panic and keep serving jobs.
        let hits = AtomicUsize::new(0);
        pool.run(8, |_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }
}
