//! Decentralized DNN training driver (paper §VII-B).
//!
//! Each node: sample a batch from its shard, execute the AOT train-step
//! artifact on the PJRT device service (loss + per-parameter grads),
//! hand the flat gradient to the decentralized optimizer (which performs
//! the partial averaging), and log `(step, loss, vtime, wall)`.
//!
//! Virtual time charges the per-step compute as `flops / (device_flops *
//! efficiency)` — the communication side is charged by the transport —
//! so throughput numbers reflect the paper's testbed model rather than
//! this container's single CPU.

use crate::config::ModelPreset;
use crate::context::NodeContext;
use crate::optim::{AsyncDecentralizedOptimizer, DecentralizedOptimizer};
use crate::rng::Rng;
use crate::runtime::{DeviceHandle, InputBuf, Manifest, TensorSpec};
use crate::training::corpus::{Corpus, ShardSpec};

/// Flat parameter vector with the manifest-derived layout.
#[derive(Debug, Clone)]
pub struct ParamLayout {
    specs: Vec<TensorSpec>,
    offsets: Vec<usize>,
    total: usize,
}

impl ParamLayout {
    /// Extract the parameter inputs (prefix `p.`) from a train-step
    /// manifest.
    pub fn from_manifest(m: &Manifest) -> Self {
        let specs: Vec<TensorSpec> =
            m.inputs.iter().filter(|s| s.name.starts_with("p.")).cloned().collect();
        let mut offsets = Vec::with_capacity(specs.len());
        let mut total = 0;
        for s in &specs {
            offsets.push(total);
            total += s.numel();
        }
        ParamLayout { specs, offsets, total }
    }

    /// Total parameter count.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Per-parameter tensor specs, in layout order.
    pub fn specs(&self) -> &[TensorSpec] {
        &self.specs
    }

    /// Deterministic init: `*_s` tensors to ones, `*_b`/`b1`/`b2` to zeros,
    /// matrices to scaled normal (1/sqrt(fan_in)). All nodes call this with
    /// the same seed so they start from a common point (standard in the
    /// paper's experiments).
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut flat = vec![0.0f32; self.total];
        for (s, &off) in self.specs.iter().zip(&self.offsets) {
            let dst = &mut flat[off..off + s.numel()];
            if s.name.ends_with("_s") {
                dst.fill(1.0);
            } else if s.name.ends_with("_b") || s.name.ends_with("b1") || s.name.ends_with("b2") {
                dst.fill(0.0);
            } else {
                let fan_in = *s.dims.first().unwrap_or(&1) as f64;
                let scale = (1.0 / fan_in).sqrt() as f32;
                for v in dst.iter_mut() {
                    *v = scale * rng.normal() as f32;
                }
            }
        }
        flat
    }

    /// Marshal the flat vector into per-tensor [`InputBuf`]s.
    pub fn to_inputs(&self, flat: &[f32]) -> Vec<InputBuf> {
        assert_eq!(flat.len(), self.total);
        self.specs
            .iter()
            .zip(&self.offsets)
            .map(|(s, &off)| InputBuf::F32(flat[off..off + s.numel()].to_vec(), s.dims.clone()))
            .collect()
    }

    /// Flatten per-tensor gradients (outputs after `loss`) back into one
    /// vector.
    pub fn flatten_grads(&self, grads: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            grads.len() == self.specs.len(),
            "expected {} grad tensors, got {}",
            self.specs.len(),
            grads.len()
        );
        let mut flat = Vec::with_capacity(self.total);
        for (g, s) in grads.iter().zip(&self.specs) {
            anyhow::ensure!(
                g.len() == s.numel(),
                "grad '{}' has {} elements, expected {}",
                s.name,
                g.len(),
                s.numel()
            );
            flat.extend_from_slice(g);
        }
        Ok(flat)
    }
}

/// One logged step.
#[derive(Debug, Clone)]
pub struct StepLog {
    /// Step index.
    pub step: usize,
    /// Training loss at this step.
    pub loss: f32,
    /// Virtual time (seconds) at the end of the step.
    pub vtime: f64,
    /// Wall-clock seconds since training started.
    pub wall: f64,
    /// Cumulative communication rounds the optimizer has issued (gossip
    /// exchanges + global syncs; 0 for optimizers that do not count).
    /// Local-update schedules show up here as a sub-linear slope.
    pub comm_rounds: usize,
}

/// Training-run configuration.
#[derive(Clone)]
pub struct TrainRun {
    /// Model configuration to train.
    pub preset: ModelPreset,
    /// Number of optimizer steps.
    pub steps: usize,
    /// Log every `log_every` steps.
    pub log_every: usize,
    /// Device peak FLOPs for virtual-time accounting (V100 ~ 125e12 bf16).
    pub device_flops: f64,
    /// Achieved efficiency fraction for the compute estimate.
    pub efficiency: f64,
    /// Corpus tokens per node shard.
    pub shard_tokens: usize,
    /// Corpus seed.
    pub data_seed: u64,
    /// Parameter init seed.
    pub init_seed: u64,
    /// Artifact directory.
    pub artifacts_dir: String,
    /// Use the `_pallas` artifact variant (L1 kernels inside the step).
    pub use_pallas: bool,
    /// Label-skew non-IID sharding; `None` keeps the contiguous split.
    pub noniid: Option<ShardSpec>,
}

impl TrainRun {
    /// A run with the defaults used across the paper's experiments.
    pub fn new(preset: ModelPreset, steps: usize) -> Self {
        TrainRun {
            preset,
            steps,
            log_every: 10,
            device_flops: 125e12,
            efficiency: 0.35,
            shard_tokens: 40_000,
            data_seed: 7,
            init_seed: 13,
            artifacts_dir: "artifacts".into(),
            use_pallas: false,
            noniid: None,
        }
    }

    /// The per-rank shard this run assigns: label-skew non-IID when
    /// configured, the contiguous split otherwise.
    pub fn shard_for(&self, corpus: &Corpus, rank: usize, size: usize) -> Corpus {
        match &self.noniid {
            Some(spec) => corpus.shard_noniid(rank, size, spec),
            None => corpus.shard(rank, size),
        }
    }

    /// Artifact name for this run.
    pub fn artifact(&self) -> String {
        if self.use_pallas {
            format!("train_step_{}_pallas", self.preset.name)
        } else {
            format!("train_step_{}", self.preset.name)
        }
    }

    /// Manifest path.
    pub fn manifest_path(&self) -> String {
        format!("{}/{}.manifest", self.artifacts_dir, self.artifact())
    }

    /// HLO path.
    pub fn hlo_path(&self) -> String {
        format!("{}/{}.hlo.txt", self.artifacts_dir, self.artifact())
    }

    /// Per-step compute time under the virtual device model — the
    /// *nominal* (rank-independent) figure. Per-rank heterogeneity (an
    /// [`crate::launcher::AsyncSpec`] straggler profile) is applied where
    /// the drivers charge this through
    /// [`NodeContext::simulate_compute_hetero`].
    pub fn step_compute_time(&self) -> f64 {
        self.preset.flops_per_step() / (self.device_flops * self.efficiency)
    }
}

/// Load the artifact (idempotent) and run decentralized training on this
/// node. Returns the step logs and the final parameters.
pub fn train_node(
    ctx: &mut NodeContext,
    run: &TrainRun,
    opt: &mut dyn DecentralizedOptimizer,
) -> anyhow::Result<(Vec<StepLog>, Vec<f32>)> {
    train_node_resumable(ctx, run, opt, None, 0)
}

/// [`train_node`] variant that can resume from carried parameters (used by
/// drivers that interleave training with evaluation). `step_offset` only
/// affects the step numbers in the logs.
pub fn train_node_resumable(
    ctx: &mut NodeContext,
    run: &TrainRun,
    opt: &mut dyn DecentralizedOptimizer,
    initial: Option<Vec<f32>>,
    step_offset: usize,
) -> anyhow::Result<(Vec<StepLog>, Vec<f32>)> {
    let device: DeviceHandle = ctx
        .device
        .clone()
        .ok_or_else(|| anyhow::anyhow!("training requires a device service"))?;
    let manifest = Manifest::load(&run.manifest_path())?;
    let layout = ParamLayout::from_manifest(&manifest);
    device.load(&run.artifact(), &run.hlo_path())?;

    // Heterogeneous shards: one big corpus, split per rank (contiguous by
    // default, label-skew non-IID when the run configures it).
    let corpus = Corpus::synthetic(run.data_seed, run.shard_tokens * ctx.size());
    let shard = run.shard_for(&corpus, ctx.rank(), ctx.size());
    let mut data_rng = ctx.rng.fork(0xda7a ^ step_offset as u64);

    let mut params = match initial {
        Some(p) => {
            anyhow::ensure!(p.len() == layout.total(), "carried params have wrong size");
            p
        }
        None => layout.init(run.init_seed),
    };
    let (b, t) = (run.preset.batch, run.preset.seq);
    let step_compute = run.step_compute_time();
    let t0 = std::time::Instant::now();
    let mut logs = Vec::new();

    for step in 0..run.steps {
        let (tokens, targets) = shard.sample_batch(&mut data_rng, b, t);
        let mut inputs = layout.to_inputs(&params);
        inputs.push(InputBuf::I32(tokens, vec![b, t]));
        inputs.push(InputBuf::I32(targets, vec![b, t]));
        let wall_exec = ctx.timeline.now_us();
        let v_before = ctx.vtime();
        let outputs = device.execute(&run.artifact(), inputs)?;
        // Heterogeneity-aware charge: under an AsyncSpec the synchronous
        // loop feels stragglers too, so sync-vs-async comparisons share one
        // virtual hardware model.
        ctx.simulate_compute_hetero(step_compute);
        ctx.timeline.record(ctx.rank(), "train_step", "compute", wall_exec, v_before, ctx.vtime());
        let loss = outputs[0][0];
        let grads = layout.flatten_grads(&outputs[1..])?;
        // Feed the loss *before* stepping: dynamic weighting policies
        // (AL-DSGD) boost neighbors by the deviation this round observed.
        opt.observe_loss(loss);
        opt.step(ctx, &mut params, &grads)?;
        if step % run.log_every == 0 || step + 1 == run.steps {
            logs.push(StepLog {
                step: step + step_offset,
                loss,
                vtime: ctx.vtime(),
                wall: t0.elapsed().as_secs_f64(),
                comm_rounds: opt.comm_rounds(),
            });
        }
    }
    Ok((logs, params))
}

/// One logged asynchronous step. Extends [`StepLog`] with the two
/// staleness signals of the async regime: how old the window mass a rank
/// consumed was, and how far its clock ran ahead of the slowest active
/// peer.
#[derive(Debug, Clone)]
pub struct AsyncStepLog {
    /// Local step index (ranks advance at different rates — there is no
    /// global step counter in the asynchronous regime).
    pub step: usize,
    /// Training loss at this step.
    pub loss: f32,
    /// Virtual time (seconds) at the end of the step.
    pub vtime: f64,
    /// Wall-clock seconds since training started.
    pub wall: f64,
    /// Window staleness observed by the optimizer this step (virtual
    /// seconds between now and the oldest pending neighbor write).
    pub staleness: f64,
    /// This rank's virtual-clock lead over the slowest active rank.
    pub clock_lag: f64,
}

/// Asynchronous decentralized training loop (paper §IV-C): like
/// [`train_node`], but each rank steps at its own virtual-time rate with
/// **no barriers** — per-step compute is charged through
/// [`NodeContext::simulate_compute_hetero`] (so configured stragglers are
/// slow in virtual time), the bounded-staleness throttle keeps virtual
/// clocks within the configured horizon, and all communication happens
/// inside the [`AsyncDecentralizedOptimizer`]'s one-sided window ops with
/// the receive-then-adapt order: `refresh` folds arrived neighbor mass in
/// *before* the gradient executes, so no gradient is computed on
/// needlessly stale parameters (communication overlaps the compute window
/// it was charged against, AWC-style). The optimizer's collective
/// `finalize` (mark-done → barrier → blocking drain → free) is the loop's
/// only synchronization and runs after the last step. Per-step staleness
/// is logged alongside the loss.
///
/// `run.steps` is an upper bound; the loop additionally stops once
/// `vtime_budget` virtual seconds have elapsed *since the loop was
/// entered* (pass `f64::INFINITY` for pure step-count control; the budget
/// is relative, so clocks advanced by earlier phases don't shrink it and
/// the decision at iteration 0 is identical on every rank — all ranks
/// reach the optimizer's collective window creation together). Prefer the
/// budget in heterogeneous runs: with a fixed per-rank step count the
/// fast ranks finish early and a straggler keeps splitting mass into
/// windows nobody drains, collapsing its push-sum weight.
pub fn train_node_async(
    ctx: &mut NodeContext,
    run: &TrainRun,
    opt: &mut dyn AsyncDecentralizedOptimizer,
    vtime_budget: f64,
) -> anyhow::Result<(Vec<AsyncStepLog>, Vec<f32>)> {
    let device: DeviceHandle = ctx
        .device
        .clone()
        .ok_or_else(|| anyhow::anyhow!("training requires a device service"))?;
    let manifest = Manifest::load(&run.manifest_path())?;
    let layout = ParamLayout::from_manifest(&manifest);
    device.load(&run.artifact(), &run.hlo_path())?;

    let corpus = Corpus::synthetic(run.data_seed, run.shard_tokens * ctx.size());
    let shard = run.shard_for(&corpus, ctx.rank(), ctx.size());
    let mut data_rng = ctx.rng.fork(0xa57a);

    fn log_entry(
        ctx: &NodeContext,
        opt: &dyn AsyncDecentralizedOptimizer,
        t0: &std::time::Instant,
        step: usize,
        loss: f32,
    ) -> AsyncStepLog {
        AsyncStepLog {
            step,
            loss,
            vtime: ctx.vtime(),
            wall: t0.elapsed().as_secs_f64(),
            staleness: opt.staleness(),
            clock_lag: ctx.async_lag(),
        }
    }

    let mut params = layout.init(run.init_seed);
    let (b, t) = (run.preset.batch, run.preset.seq);
    let step_compute = run.step_compute_time();
    let t0 = std::time::Instant::now();
    let v_entry = ctx.vtime();
    let mut logs = Vec::new();
    let mut last_logged: Option<usize> = None;
    let mut last_step: Option<(usize, f32)> = None;

    for step in 0..run.steps {
        if ctx.vtime() - v_entry >= vtime_budget {
            break;
        }
        // A rank whose scheduled crash vtime has passed unwinds here
        // instead of erroring deep inside a comm call: the partial log is
        // preserved, and the caller distinguishes the crash from a real
        // failure via `ctx.crashed_now()`.
        if ctx.crashed_now() {
            break;
        }
        // Bounded staleness: hold this rank until the slowest active
        // rank's virtual clock is within the horizon. Under
        // ExecMode::Threads that is a condvar wait on the throttle gate;
        // under ExecMode::EventLoop the rank parks on the scheduler and
        // consumes no virtual time until released.
        ctx.async_throttle();
        let wall_exec = ctx.timeline.now_us();
        let v_before = ctx.vtime();
        ctx.simulate_compute_hetero(step_compute);
        // Receive-then-adapt: fold in mass that arrived during the compute
        // window just charged, then evaluate the gradient on it.
        opt.refresh(ctx, &mut params)?;
        let (tokens, targets) = shard.sample_batch(&mut data_rng, b, t);
        let mut inputs = layout.to_inputs(&params);
        inputs.push(InputBuf::I32(tokens, vec![b, t]));
        inputs.push(InputBuf::I32(targets, vec![b, t]));
        let outputs = device.execute(&run.artifact(), inputs)?;
        ctx.timeline.record(ctx.rank(), "train_step", "compute", wall_exec, v_before, ctx.vtime());
        let loss = outputs[0][0];
        let grads = layout.flatten_grads(&outputs[1..])?;
        opt.step(ctx, &mut params, &grads)?;
        last_step = Some((step, loss));
        if step % run.log_every == 0 || step + 1 == run.steps {
            logs.push(log_entry(ctx, &*opt, &t0, step, loss));
            last_logged = Some(step);
        }
    }
    // The vtime budget can end the loop between log points; always log the
    // final executed step so `logs.last()` reflects where the rank stopped.
    if let Some((step, loss)) = last_step {
        if last_logged != Some(step) {
            logs.push(log_entry(ctx, &*opt, &t0, step, loss));
        }
    }
    // A crashed rank must not enter the collective teardown (its peers
    // will time out on it and evict it); its partial results still come
    // back so the caller can report where it stopped.
    if !ctx.crashed_now() {
        opt.finalize(ctx, &mut params)?;
    }
    Ok((logs, params))
}

/// Evaluate loss/accuracy of `params` on freshly sampled held-out batches.
pub fn eval_node(
    ctx: &mut NodeContext,
    run: &TrainRun,
    params: &[f32],
    batches: usize,
) -> anyhow::Result<(f32, f32)> {
    let device = ctx
        .device
        .clone()
        .ok_or_else(|| anyhow::anyhow!("eval requires a device service"))?;
    let name = if run.use_pallas {
        format!("eval_{}_pallas", run.preset.name)
    } else {
        format!("eval_{}", run.preset.name)
    };
    let manifest = Manifest::load(&format!("{}/{}.manifest", run.artifacts_dir, name))?;
    let layout = ParamLayout::from_manifest(&manifest);
    device.load(&name, &format!("{}/{}.hlo.txt", run.artifacts_dir, name))?;
    // Held-out data: a different seed stream than training.
    let corpus = Corpus::synthetic(run.data_seed ^ 0xe7a1, run.shard_tokens);
    let mut rng = Rng::new(0xe0a1 ^ ctx.rank() as u64);
    let (b, t) = (run.preset.batch, run.preset.seq);
    let (mut loss_sum, mut acc_sum) = (0.0f32, 0.0f32);
    for _ in 0..batches {
        let (tokens, targets) = corpus.sample_batch(&mut rng, b, t);
        let mut inputs = layout.to_inputs(params);
        inputs.push(InputBuf::I32(tokens, vec![b, t]));
        inputs.push(InputBuf::I32(targets, vec![b, t]));
        let outputs = device.execute(&name, inputs)?;
        loss_sum += outputs[0][0];
        acc_sum += outputs[1][0];
    }
    Ok((loss_sum / batches as f32, acc_sum / batches as f32))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        Manifest::parse(
            "input p.w f32 2x3\ninput p.b f32 3\ninput tokens i32 1x4\n\
             input targets i32 1x4\noutput loss f32 -\noutput g.w f32 2x3\noutput g.b f32 3\n",
        )
        .unwrap()
    }

    #[test]
    fn layout_extracts_params_only() {
        let l = ParamLayout::from_manifest(&toy_manifest());
        assert_eq!(l.total(), 9);
        assert_eq!(l.specs().len(), 2);
    }

    #[test]
    fn init_respects_suffix_conventions() {
        let l = ParamLayout::from_manifest(&toy_manifest());
        let flat = l.init(1);
        // p.b (suffix 'b'? name is "p.b" which ends with ".b" — matrices vs
        // biases are split by the _b convention; "p.b" doesn't end with
        // "_b", so it gets normal init. Check p.w variance instead.)
        let w = &flat[0..6];
        assert!(w.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn inputs_roundtrip_layout() {
        let l = ParamLayout::from_manifest(&toy_manifest());
        let flat: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let inputs = l.to_inputs(&flat);
        assert_eq!(inputs.len(), 2);
        match &inputs[0] {
            InputBuf::F32(d, dims) => {
                assert_eq!(d, &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
                assert_eq!(dims, &[2, 3]);
            }
            _ => panic!("wrong input type"),
        }
    }

    #[test]
    fn flatten_grads_validates_shapes() {
        let l = ParamLayout::from_manifest(&toy_manifest());
        let ok = l.flatten_grads(&[vec![0.0; 6], vec![1.0; 3]]).unwrap();
        assert_eq!(ok.len(), 9);
        assert!(l.flatten_grads(&[vec![0.0; 5], vec![1.0; 3]]).is_err());
        assert!(l.flatten_grads(&[vec![0.0; 6]]).is_err());
    }
}
