//! Synthetic-but-learnable text corpus for the E2E training runs.
//!
//! The paper trains on ImageNet-1k; per DESIGN.md we substitute a character
//! LM on a corpus with real statistical structure: an embedded seed text
//! expanded by an order-2 Markov chain, so next-character prediction is
//! genuinely learnable (entropy well below log V) while the repository
//! stays self-contained. Shards are contiguous splits so nodes see
//! heterogeneous data — the regime decentralized algorithms must handle.

use crate::rng::Rng;

/// Character vocabulary: printable ASCII 32..=126 plus newline -> 95.
pub const VOCAB: usize = 96;

/// Map a byte to a token id.
pub fn encode_byte(b: u8) -> i32 {
    match b {
        32..=126 => (b - 32) as i32,
        _ => 95,
    }
}

/// Map a token id back to a byte.
pub fn decode_token(t: i32) -> u8 {
    match t {
        0..=94 => (t as u8) + 32,
        _ => b'\n',
    }
}

/// Seed text for the Markov expansion (public-domain style prose about the
/// domain itself, so learned samples are recognizably English-like).
pub const SEED_TEXT: &str = "\
decentralized algorithms achieve a global goal through local dynamics that \
rely on low cost communication between directly connected agents. on large \
scale optimization tasks involving distributed datasets, decentralized \
methods have shown strong and sometimes superior performance over methods \
with a central node. communication rather than computation tends to be the \
bottleneck: many to one communication, one to many communication, and many \
rounds of communication of even short messages all incur huge costs. the \
parameter server performs many to one and one to many communication, and \
the ring allreduce places the agents on a ring and uses two rounds of \
communication per chunk. partial averaging instead lets every node exchange \
information only with its direct neighbors over a sparse graph, so the cost \
per iteration is independent of the number of agents. the network topology \
and the weights significantly affect the convergence performance and the \
communication efficiency. a pull matrix has rows that add up to one, a push \
matrix has columns that add up to one, and a standard weight matrix is \
doubly stochastic. the exponential graph is both sparse and well connected, \
and the one peer variant picks a single neighbor each iteration so the \
transfer volume stays constant while the information still mixes quickly. \
gradient tracking corrects the bias of decentralized gradient descent under \
heterogeneous data, exact diffusion removes the steady state error, and \
push sum corrects the bias of asynchronous updates over directed graphs. \
with overlapping communication and computation, tensor fusion for small \
messages, and hierarchical communication inside each machine, decentralized \
training reaches a higher throughput than ring allreduce at scale. ";

/// Label-skew non-IID partition parameters for [`Corpus::shard_noniid`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardSpec {
    /// Seed of the skew interpolation (same seed ⇒ same partition).
    pub seed: u64,
    /// Heterogeneity in `[0, 1]`: 0 = IID random windows, 1 = each node a
    /// disjoint band of the label (mean-token-id) distribution.
    pub skew: f32,
    /// Window length in tokens (the unit of assignment).
    pub window: usize,
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec { seed: 0x5eed, skew: 0.8, window: 64 }
    }
}

/// A tokenized corpus with shard views and batch sampling.
#[derive(Debug, Clone)]
pub struct Corpus {
    tokens: Vec<i32>,
}

impl Corpus {
    /// Tokenize a string directly.
    pub fn from_text(text: &str) -> Self {
        Corpus { tokens: text.bytes().map(encode_byte).collect() }
    }

    /// Expand the seed text to `len` tokens with an order-2 Markov chain.
    pub fn synthetic(seed: u64, len: usize) -> Self {
        let base: Vec<u8> = SEED_TEXT.bytes().collect();
        assert!(base.len() > 3);
        // Transition table: (b0, b1) -> candidate next bytes.
        let mut table: std::collections::HashMap<(u8, u8), Vec<u8>> =
            std::collections::HashMap::new();
        for w in base.windows(3) {
            table.entry((w[0], w[1])).or_default().push(w[2]);
        }
        let mut rng = Rng::new(seed);
        let mut out: Vec<u8> = base[..2].to_vec();
        while out.len() < len {
            let key = (out[out.len() - 2], out[out.len() - 1]);
            match table.get(&key) {
                Some(cands) => out.push(cands[rng.usize_below(cands.len())]),
                None => {
                    // Dead end: restart from a random seed position.
                    let p = rng.usize_below(base.len() - 2);
                    out.push(base[p]);
                    out.push(base[p + 1]);
                }
            }
        }
        out.truncate(len);
        Corpus { tokens: out.into_iter().map(encode_byte).collect() }
    }

    /// Token count.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// True when the corpus holds no tokens.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// The encoded token stream.
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Contiguous shard `rank` of `size` (data-parallel heterogeneous
    /// shards). The shard keeps at least `min_len` tokens when possible.
    pub fn shard(&self, rank: usize, size: usize) -> Corpus {
        assert!(rank < size);
        let n = self.tokens.len();
        let lo = rank * n / size;
        let hi = (rank + 1) * n / size;
        Corpus { tokens: self.tokens[lo..hi].to_vec() }
    }

    /// Deterministic label-skew non-IID shard `rank` of `size`.
    ///
    /// The corpus is cut into fixed-length windows (the tail window may be
    /// short) and each window gets a *label* — its mean token id, a cheap
    /// stand-in for class identity. Every window's sort key interpolates
    /// between a seeded uniform draw and its label's rank order with
    /// weight [`ShardSpec::skew`]; nodes take contiguous blocks of the
    /// key-sorted order. `skew = 0` reproduces an IID random partition,
    /// `skew = 1` gives each node a disjoint band of the label
    /// distribution — the heterogeneous-data regime where consensus
    /// quality separates weighting policies (EXPERIMENTS.md E17). The
    /// partition is a pure function of `(corpus, size, spec)`: disjoint,
    /// exhaustive, and identical on every backend.
    pub fn shard_noniid(&self, rank: usize, size: usize, spec: &ShardSpec) -> Corpus {
        assert!(rank < size);
        assert!(spec.window >= 1, "window must be >= 1");
        assert!((0.0..=1.0).contains(&spec.skew), "skew must be in [0, 1]");
        let windows: Vec<&[i32]> = self.tokens.chunks(spec.window).collect();
        let nw = windows.len();
        let labels: Vec<f64> = windows
            .iter()
            .map(|w| w.iter().map(|&t| t as f64).sum::<f64>() / w.len().max(1) as f64)
            .collect();
        let mut by_label: Vec<usize> = (0..nw).collect();
        by_label.sort_by(|&a, &b| {
            labels[a].partial_cmp(&labels[b]).unwrap().then(a.cmp(&b))
        });
        let mut pos = vec![0usize; nw];
        for (p, &i) in by_label.iter().enumerate() {
            pos[i] = p;
        }
        let mut rng = Rng::new(spec.seed);
        let skew = spec.skew as f64;
        let mut scored: Vec<(f64, usize)> = (0..nw)
            .map(|i| {
                let key = (1.0 - skew) * rng.f64() + skew * (pos[i] as f64 / nw.max(1) as f64);
                (key, i)
            })
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let lo = rank * nw / size;
        let hi = (rank + 1) * nw / size;
        let mut tokens = Vec::new();
        for &(_, i) in &scored[lo..hi] {
            tokens.extend_from_slice(windows[i]);
        }
        Corpus { tokens }
    }

    /// Sample a `[batch, seq]` window batch; targets are inputs shifted by
    /// one. Returns `(tokens, targets)` flat row-major.
    pub fn sample_batch(&self, rng: &mut Rng, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        assert!(
            self.tokens.len() > seq + 1,
            "shard too small: {} tokens for seq {}",
            self.tokens.len(),
            seq
        );
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.usize_below(self.tokens.len() - seq - 1);
            tokens.extend_from_slice(&self.tokens[start..start + seq]);
            targets.extend_from_slice(&self.tokens[start + 1..start + seq + 1]);
        }
        (tokens, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        for b in 32u8..=126 {
            assert_eq!(decode_token(encode_byte(b)), b);
        }
        assert_eq!(encode_byte(b'\n'), 95);
        assert_eq!(decode_token(95), b'\n');
    }

    #[test]
    fn tokens_in_vocab_range() {
        let c = Corpus::synthetic(1, 10_000);
        assert_eq!(c.len(), 10_000);
        assert!(c.tokens().iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }

    #[test]
    fn synthetic_is_deterministic_and_seed_sensitive() {
        let a = Corpus::synthetic(5, 2000);
        let b = Corpus::synthetic(5, 2000);
        let c = Corpus::synthetic(6, 2000);
        assert_eq!(a.tokens(), b.tokens());
        assert_ne!(a.tokens(), c.tokens());
    }

    #[test]
    fn markov_text_has_low_bigram_entropy() {
        // The expansion must preserve structure: bigram entropy far below
        // the uniform 2*log2(96) ≈ 13.2 bits.
        let c = Corpus::synthetic(2, 50_000);
        let mut counts = std::collections::HashMap::new();
        for w in c.tokens().windows(2) {
            *counts.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let total: usize = counts.values().sum();
        let h: f64 = counts
            .values()
            .map(|&c| {
                let p = c as f64 / total as f64;
                -p * p.log2()
            })
            .sum();
        assert!(h < 9.0, "bigram entropy too high: {h}");
    }

    #[test]
    fn shards_partition_the_corpus() {
        let c = Corpus::synthetic(3, 1000);
        let total: usize = (0..4).map(|r| c.shard(r, 4).len()).sum();
        assert_eq!(total, 1000);
        assert_ne!(c.shard(0, 4).tokens(), c.shard(1, 4).tokens());
    }

    #[test]
    fn batches_have_shifted_targets() {
        let c = Corpus::synthetic(4, 5000);
        let mut rng = Rng::new(0);
        let (toks, tgts) = c.sample_batch(&mut rng, 3, 16);
        assert_eq!(toks.len(), 48);
        assert_eq!(tgts.len(), 48);
        // Within each row, target[t] should equal token[t+1].
        for row in 0..3 {
            for t in 0..15 {
                assert_eq!(tgts[row * 16 + t], toks[row * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn noniid_shards_disjoint_exhaustive_reproducible() {
        let c = Corpus::synthetic(7, 4096);
        let spec = ShardSpec { seed: 42, skew: 0.8, window: 32 };
        let shards: Vec<Corpus> = (0..8).map(|r| c.shard_noniid(r, 8, &spec)).collect();
        // Exhaustive: every token lands in exactly one shard (multiset
        // equality under sorting — windows are permuted, never duplicated).
        let mut all: Vec<i32> = shards.iter().flat_map(|s| s.tokens().to_vec()).collect();
        let mut orig = c.tokens().to_vec();
        all.sort_unstable();
        orig.sort_unstable();
        assert_eq!(all, orig);
        // Reproducible: same (corpus, size, spec) ⇒ identical shards.
        for (r, s) in shards.iter().enumerate() {
            assert_eq!(s.tokens(), c.shard_noniid(r, 8, &spec).tokens());
        }
        // Seed-sensitive: a different seed permutes the partition.
        let other = ShardSpec { seed: 43, ..spec };
        assert_ne!(shards[0].tokens(), c.shard_noniid(0, 8, &other).tokens());
    }

    #[test]
    fn noniid_skew_widens_label_spread() {
        let c = Corpus::synthetic(9, 8192);
        let mean = |s: &Corpus| {
            s.tokens().iter().map(|&t| t as f64).sum::<f64>() / s.len() as f64
        };
        let spread = |skew: f32| {
            let spec = ShardSpec { seed: 1, skew, window: 32 };
            let means: Vec<f64> = (0..8).map(|r| mean(&c.shard_noniid(r, 8, &spec))).collect();
            let (lo, hi) = means.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &m| {
                (l.min(m), h.max(m))
            });
            hi - lo
        };
        assert!(
            spread(1.0) > 2.0 * spread(0.0),
            "sorted partition should widen per-shard label spread: {} vs {}",
            spread(1.0),
            spread(0.0)
        );
    }

    #[test]
    #[should_panic(expected = "shard too small")]
    fn sampling_from_tiny_shard_panics() {
        let c = Corpus::from_text("ab");
        let mut rng = Rng::new(0);
        c.sample_batch(&mut rng, 1, 16);
    }
}
