//! Training subsystem: corpus synthesis ([`corpus`]) and the decentralized
//! DNN training driver ([`driver`]) used by the paper's deep-learning
//! experiments (§VII-B).

pub mod corpus;
pub mod driver;

pub use corpus::{Corpus, ShardSpec};
pub use driver::{
    eval_node, train_node, train_node_async, train_node_resumable, AsyncStepLog, ParamLayout,
    StepLog, TrainRun,
};
