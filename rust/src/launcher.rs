//! SPMD launcher — the `bfrun` analogue (paper §VI-A).
//!
//! `bfrun -np N python prog.py` starts N processes running the same
//! program; here [`run_spmd`] spawns N OS threads, each with its own
//! [`NodeContext`], over a shared in-process fabric: transport endpoints,
//! virtual clocks, the negotiation service, the window table, per-node
//! communication threads and (optionally) the PJRT device service.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::compress::CompressionSpec;
use crate::context::{NodeContext, ThrottleGate, TopologyState};
use crate::negotiation::{NegotiationService, Rendezvous};
use crate::nonblocking::{CommEngine, CommThread};
use crate::pool::HotPath;
use crate::runtime::DeviceHandle;
use crate::simnet::event::{Grant, Scheduler};
use crate::simnet::faults::FaultPlan;
use crate::simnet::hetero::ComputeHeterogeneity;
use crate::simnet::NetworkModel;
use crate::timeline::Timeline;
use crate::topology::{builders, Graph, WeightMatrix};
use crate::transport::{fabric, VClock};
use crate::window::WindowTable;

/// Which backend executes the simulated ranks (paper §VI-A scaled up).
///
/// Both backends run the *same* per-rank program over the same virtual-time
/// cost model; `tests/exec_parity.rs` is the differential harness pinning
/// them against each other.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// One free-running OS thread per rank (the original backend and the
    /// parity oracle). Blocking receives park real threads; fine up to a
    /// few hundred ranks.
    #[default]
    Threads,
    /// Cooperative rank state machines over a single virtual-time event
    /// loop ([`crate::simnet::event::Scheduler`]): exactly one rank is
    /// runnable at any instant, the baton passing through a priority queue
    /// of `(vtime, rank, wakeup-kind)` events. Deterministic grant order
    /// independent of OS scheduling, and cheap enough per rank for
    /// 10k-rank sweeps (`examples/scale_probe.rs`).
    EventLoop,
}

/// Configuration of the asynchronous execution regime (paper §IV-C).
///
/// Two knobs, both inert unless a driver opts in:
///
/// - **Compute heterogeneity** — per-rank slowdown factors plus seeded
///   jitter ([`ComputeHeterogeneity`]), applied wherever per-step compute is
///   charged through
///   [`crate::context::NodeContext::simulate_compute_hetero`]. This makes
///   stragglers exist in virtual time for synchronous *and* asynchronous
///   runs, so the two regimes are comparable.
/// - **Staleness horizon** — the bounded-asynchrony window (virtual
///   seconds) enforced by
///   [`crate::context::NodeContext::async_throttle`]: a rank whose virtual
///   clock runs more than `horizon` ahead of the slowest still-active rank
///   yields until the laggard catches up. This is the simulator's stand-in
///   for real wall time, where a fast worker physically cannot execute
///   unbounded iterations while a peer performs one; every known
///   convergence result for asynchronous decentralized SGD assumes such a
///   bound. `f64::INFINITY` (the default) disables the throttle.
#[derive(Clone)]
pub struct AsyncSpec {
    /// Per-rank compute slowdown factors + jitter.
    pub hetero: ComputeHeterogeneity,
    /// Bounded-staleness window in virtual seconds (∞ = unthrottled).
    pub horizon: f64,
}

impl AsyncSpec {
    /// A spec with the given heterogeneity and no staleness throttle.
    pub fn new(hetero: ComputeHeterogeneity) -> Self {
        AsyncSpec { hetero, horizon: f64::INFINITY }
    }

    /// Set the bounded-staleness horizon (builder style). A good default is
    /// a few straggler step times: `k * base_step * hetero.max_factor()`.
    pub fn with_horizon(mut self, horizon: f64) -> Self {
        self.horizon = horizon;
        self
    }
}

/// Configuration of an SPMD run.
#[derive(Clone)]
pub struct SpmdConfig {
    /// Number of simulated nodes.
    pub nodes: usize,
    /// Network model (bandwidth/latency tiers).
    pub net: NetworkModel,
    /// Initial global topology; default: static exponential-2 with its
    /// doubly-stochastic weights (the paper's recommended default).
    pub topology: Option<(Graph, WeightMatrix)>,
    /// Base seed for per-node RNGs.
    pub seed: u64,
    /// Shared timeline recorder (pass one to collect traces).
    pub timeline: Option<Arc<Timeline>>,
    /// Shared PJRT device service handle (None = no XLA execution).
    pub device: Option<DeviceHandle>,
    /// Spawn per-node communication threads (required for non-blocking ops).
    pub comm_threads: bool,
    /// Tensor-fusion threshold in bytes for the communication threads.
    pub fusion_threshold: usize,
    /// Run the negotiation-service topology check before collectives.
    pub enable_topo_check: bool,
    /// Communication hot-path implementation (pooled/blocked vs naive).
    pub hot_path: HotPath,
    /// Communication compression applied to neighbor-averaging payloads
    /// (blocking and fused non-blocking), default none.
    pub compression: CompressionSpec,
    /// Asynchronous-regime configuration: per-rank compute heterogeneity
    /// and the bounded-staleness throttle. `None` (default) leaves every
    /// rank at nominal speed and every async helper a no-op.
    pub async_spec: Option<AsyncSpec>,
    /// Execution backend (default: [`ExecMode::Threads`], the parity
    /// oracle; flip to [`ExecMode::EventLoop`] for large-scale sweeps).
    pub exec: ExecMode,
    /// Node-thread stack size in bytes (default 8 MiB). Event-loop ranks
    /// are parked almost all the time, so 10k-rank sweeps shrink this to
    /// keep reserved address space proportional to real usage.
    pub stack_size: usize,
    /// Sparse topology: build the per-rank CSR views directly from the
    /// graph with uniform pull weights, skipping the dense `n × n`
    /// [`WeightMatrix`] entirely (`O(E)` memory — mandatory at 10k ranks).
    /// Takes precedence over `topology` when set.
    pub sparse_topology: Option<Graph>,
    /// When set under [`ExecMode::EventLoop`], the scheduler records its
    /// grant sequence and the launcher deposits it here after the run
    /// (the virtual-time trace the parity/property tests compare).
    pub sched_trace: Option<Arc<Mutex<Vec<Grant>>>>,
    /// Seeded fault schedule injected at the transport boundary: rank
    /// crashes, link drops/delays/duplication, partitions, and the
    /// default receive deadline. [`FaultPlan::none`] (the default) is a
    /// bitwise no-op on every existing path.
    pub faults: FaultPlan,
}

impl SpmdConfig {
    /// A sensible default: flat fast network, expo2 topology, topo check on.
    ///
    /// ```
    /// use bluefog::launcher::{run_spmd, SpmdConfig};
    /// // Four simulated nodes each report their rank.
    /// let ranks = run_spmd(SpmdConfig::new(4), |ctx| Ok(ctx.rank())).unwrap();
    /// assert_eq!(ranks, vec![0, 1, 2, 3]);
    /// ```
    pub fn new(nodes: usize) -> Self {
        SpmdConfig {
            nodes,
            net: NetworkModel::flat(10e9, 10e-6),
            topology: None,
            seed: 0xb1fe_f06,
            timeline: None,
            device: None,
            comm_threads: true,
            fusion_threshold: 2 << 20,
            enable_topo_check: true,
            hot_path: HotPath::default(),
            compression: CompressionSpec::default(),
            async_spec: None,
            exec: ExecMode::default(),
            stack_size: 8 << 20,
            sparse_topology: None,
            sched_trace: None,
            faults: FaultPlan::none(),
        }
    }

    /// Inject a fault schedule (crashes, drops, partitions, deadlines).
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Select the execution backend (default: [`ExecMode::Threads`]).
    pub fn with_exec(mut self, exec: ExecMode) -> Self {
        self.exec = exec;
        self
    }

    /// Set the per-rank thread stack size in bytes.
    pub fn with_stack_size(mut self, bytes: usize) -> Self {
        self.stack_size = bytes;
        self
    }

    /// Use a sparse CSR topology with uniform pull weights (no dense
    /// weight matrix is ever materialized — required for 10k-rank runs).
    pub fn with_sparse_topology(mut self, graph: Graph) -> Self {
        self.sparse_topology = Some(graph);
        self
    }

    /// Record the EventLoop scheduler's grant trace into `sink` after the
    /// run completes (no-op under [`ExecMode::Threads`]).
    pub fn with_sched_trace(mut self, sink: Arc<Mutex<Vec<Grant>>>) -> Self {
        self.sched_trace = Some(sink);
        self
    }

    /// Replace the network cost model.
    pub fn with_net(mut self, net: NetworkModel) -> Self {
        self.net = net;
        self
    }

    /// Set the initial global topology and weights.
    pub fn with_topology(mut self, graph: Graph, weights: WeightMatrix) -> Self {
        self.topology = Some((graph, weights));
        self
    }

    /// Set the base seed for per-node RNGs.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Attach a PJRT device service for AOT-artifact execution.
    pub fn with_device(mut self, device: DeviceHandle) -> Self {
        self.device = Some(device);
        self
    }

    /// Attach a timeline recorder to collect traces.
    pub fn with_timeline(mut self, timeline: Arc<Timeline>) -> Self {
        self.timeline = Some(timeline);
        self
    }

    /// Toggle the negotiation-service topology check.
    pub fn with_topo_check(mut self, enabled: bool) -> Self {
        self.enable_topo_check = enabled;
        self
    }

    /// Set the tensor-fusion threshold in bytes (0 disables fusion).
    pub fn with_fusion_threshold(mut self, bytes: usize) -> Self {
        self.fusion_threshold = bytes;
        self
    }

    /// Select the communication hot-path implementation (default: pooled).
    pub fn with_hot_path(mut self, hot_path: HotPath) -> Self {
        self.hot_path = hot_path;
        self
    }

    /// Apply communication compression to neighbor-averaging payloads
    /// (default: [`CompressionSpec::none`], the exact dense path).
    pub fn with_compression(mut self, compression: CompressionSpec) -> Self {
        self.compression = compression;
        self
    }

    /// Enable the asynchronous execution regime: per-rank compute
    /// heterogeneity plus (optionally) a bounded-staleness throttle.
    pub fn with_async(mut self, spec: AsyncSpec) -> Self {
        self.async_spec = Some(spec);
        self
    }
}

/// Run `f` as a single program on `cfg.nodes` simulated nodes and return
/// the per-rank results (index = rank). Any node error aborts the run.
pub fn run_spmd<T, F>(cfg: SpmdConfig, f: F) -> anyhow::Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(&mut NodeContext) -> anyhow::Result<T> + Send + Sync + 'static,
{
    let n = cfg.nodes;
    assert!(n > 0, "run_spmd needs at least one node");
    let net = Arc::new(cfg.net.clone());
    let (mailboxes, postman) = fabric(n);
    let (comm_mailboxes, comm_postman) = fabric(n);
    let clocks: Arc<Vec<VClock>> = Arc::new((0..n).map(|_| VClock::new()).collect());
    // Per-rank liveness, cleared by the exit guard (and eagerly by a
    // rank's own crash guard). Peers' deadline waits and the negotiation
    // daemon's dead-batch sweep read it.
    let alive: Arc<Vec<AtomicBool>> = Arc::new((0..n).map(|_| AtomicBool::new(true)).collect());
    let faults = Arc::new(cfg.faults.clone());
    let negotiation = NegotiationService::spawn_with_liveness(n, cfg.net.clone(), alive.clone());
    let timeline = cfg.timeline.clone().unwrap_or_else(|| Arc::new(Timeline::new(false)));
    let windows = Arc::new(WindowTable::new());

    let topology = if let Some(graph) = cfg.sparse_topology.clone() {
        Arc::new(RwLock::new(TopologyState::sparse_uniform_pull(graph)))
    } else {
        let (graph, weights) = cfg.topology.clone().unwrap_or_else(|| {
            let g = builders::exponential_two(n);
            let w = WeightMatrix::uniform_pull(&g);
            (g, w)
        });
        Arc::new(RwLock::new(TopologyState::new(graph, weights)))
    };

    // Per-rank wire-byte counters, shared between a node's blocking context
    // and its communication thread.
    let tx_bytes: Vec<Arc<AtomicU64>> = (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect();

    // Asynchronous-regime state: the shared spec plus one "done" flag per
    // rank so the bounded-staleness throttle stops waiting on ranks that
    // have left their training loop (their clocks stall forever).
    let async_spec = cfg.async_spec.clone().map(Arc::new);
    let async_done: Arc<Vec<AtomicBool>> =
        Arc::new((0..n).map(|_| AtomicBool::new(false)).collect());

    // Backend-specific plumbing: EventLoop gets the virtual-time scheduler,
    // the inline negotiation rendezvous, and per-rank inline comm engines;
    // Threads keeps the comm/negotiation daemons and (when the async regime
    // is on) a condvar gate replacing the old sleep-poll throttle.
    let event_loop = cfg.exec == ExecMode::EventLoop;
    let sched = if event_loop {
        Some(Scheduler::new(
            n,
            clocks.as_ref().clone(),
            async_done.clone(),
            cfg.sched_trace.is_some(),
        ))
    } else {
        None
    };
    if let Some(s) = &sched {
        // Pre-seed the fault schedule as scheduler events: Crash marks
        // the actor for the watchdog's diagnostics, Heal wakes the loop
        // when a partition window closes (delivery retries were already
        // priced at send time; the event is for observability).
        for &(rank, at) in &faults.crashes {
            s.schedule_crash(rank, at);
        }
        for p in &faults.partitions {
            s.schedule_heal(p.until);
        }
    }
    let rendezvous =
        if event_loop { Some(Arc::new(Rendezvous::new(n, cfg.net.clone()))) } else { None };
    let throttle_gate = if !event_loop && async_spec.is_some() {
        Some(Arc::new(ThrottleGate::new()))
    } else {
        None
    };

    // The second endpoint fabric backs the non-blocking engines: dedicated
    // comm threads under `Threads`, rank-owned inline engines under
    // `EventLoop` (same state machine, driven at enqueue/wait points).
    let mut comm_threads = vec![];
    let mut comm_queues: Vec<Option<crate::nonblocking::CommQueue>> =
        (0..n).map(|_| None).collect();
    let mut inline_engines: Vec<Option<Box<CommEngine>>> = (0..n).map(|_| None).collect();
    if cfg.comm_threads {
        for (rank, mb) in comm_mailboxes.into_iter().enumerate() {
            if event_loop {
                inline_engines[rank] = Some(Box::new(CommEngine::new(
                    rank,
                    n,
                    mb,
                    comm_postman.clone(),
                    clocks.clone(),
                    net.clone(),
                    cfg.hot_path,
                    cfg.compression,
                    cfg.seed,
                    tx_bytes[rank].clone(),
                    sched.clone(),
                )));
            } else {
                let t = CommThread::spawn(
                    rank,
                    n,
                    mb,
                    comm_postman.clone(),
                    clocks.clone(),
                    net.clone(),
                    cfg.fusion_threshold,
                    cfg.hot_path,
                    cfg.compression,
                    cfg.seed,
                    tx_bytes[rank].clone(),
                );
                comm_queues[rank] = Some(t.queue());
                comm_threads.push(t);
            }
        }
    }

    let f = Arc::new(f);
    let mut handles = vec![];
    for (rank, ((mailbox, comm_queue), engine)) in mailboxes
        .into_iter()
        .zip(comm_queues.into_iter())
        .zip(inline_engines.into_iter())
        .enumerate()
    {
        let f = f.clone();
        let mut ctx = NodeContext::new(
            rank,
            n,
            mailbox,
            postman.clone(),
            clocks.clone(),
            net.clone(),
            topology.clone(),
            negotiation.client(),
            timeline.clone(),
            windows.clone(),
            cfg.device.clone(),
            cfg.seed,
            cfg.compression,
            tx_bytes[rank].clone(),
            async_spec.clone(),
            async_done.clone(),
            faults.clone(),
            alive.clone(),
        );
        ctx.enable_topo_check = cfg.enable_topo_check;
        ctx.fusion_threshold = cfg.fusion_threshold;
        ctx.hot_path = cfg.hot_path;
        ctx.comm = comm_queue;
        ctx.sched = sched.clone();
        ctx.rendezvous = rendezvous.clone();
        ctx.inline_comm = engine;
        ctx.throttle_gate = throttle_gate.clone();
        let done_on_exit = async_done.clone();
        let sched_exit = sched.clone();
        let alive_exit = alive.clone();
        let rendezvous_exit = rendezvous.clone();
        let handle = std::thread::Builder::new()
            .name(format!("bf-node-{rank}"))
            .stack_size(cfg.stack_size)
            .spawn(move || {
                // Any exit — success, error, or panic — marks this rank
                // async-done, so peers spinning in `async_throttle` on its
                // stalled clock wake up and the run can surface the error
                // instead of hanging.
                struct DoneOnExit(Arc<Vec<AtomicBool>>, usize);
                impl Drop for DoneOnExit {
                    fn drop(&mut self) {
                        self.0[self.1].store(true, Ordering::Release);
                    }
                }
                // EventLoop: hand the baton on no matter how the body
                // exits. Declared *before* DoneOnExit so it drops *after*
                // it — the final dispatch's throttle-release sweep must
                // already see this rank as inactive.
                struct FinishOnExit(Option<Arc<Scheduler>>, usize);
                impl Drop for FinishOnExit {
                    fn drop(&mut self) {
                        if let Some(s) = &self.0 {
                            s.finish(self.1);
                        }
                    }
                }
                // Liveness teardown, dropped first (declared last): clear
                // the alive flag so Threads-mode deadline waits stop
                // early, and resolve any negotiation batch this rank was
                // the last missing announcer of — both must land before
                // `finish` hands the baton on.
                struct AliveOnExit {
                    alive: Arc<Vec<AtomicBool>>,
                    rendezvous: Option<Arc<Rendezvous>>,
                    sched: Option<Arc<Scheduler>>,
                    rank: usize,
                }
                impl Drop for AliveOnExit {
                    fn drop(&mut self) {
                        self.alive[self.rank].store(false, Ordering::Release);
                        if let (Some(r), Some(s)) = (&self.rendezvous, &self.sched) {
                            r.rank_exited(self.rank, s);
                        }
                    }
                }
                let _finish = FinishOnExit(sched_exit.clone(), rank);
                let _guard = DoneOnExit(done_on_exit, rank);
                let _alive = AliveOnExit {
                    alive: alive_exit,
                    rendezvous: rendezvous_exit,
                    sched: sched_exit.clone(),
                    rank,
                };
                if let Some(s) = &sched_exit {
                    s.attach(rank);
                }
                f(&mut ctx)
            })
            .expect("spawn node thread");
        handles.push(handle);
    }

    let mut results = Vec::with_capacity(n);
    let mut first_err: Option<anyhow::Error> = None;
    for (rank, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok(Ok(v)) => results.push(v),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e.context(format!("node {rank} failed")));
                }
            }
            Err(panic) => {
                if first_err.is_none() {
                    let msg = panic
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "unknown panic".into());
                    first_err = Some(anyhow::anyhow!("node {rank} panicked: {msg}"));
                }
            }
        }
    }
    // Keep comm threads alive until all nodes joined, then drop (shutdown).
    drop(comm_threads);
    // Deposit the recorded grant sequence for trace-comparing tests.
    if let (Some(s), Some(sink)) = (&sched, &cfg.sched_trace) {
        *sink.lock().unwrap_or_else(std::sync::PoisonError::into_inner) = s.grants();
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(results),
    }
}

/// Convenience: run with default flat network and expo2 topology.
pub fn run_simple<T, F>(nodes: usize, f: F) -> anyhow::Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(&mut NodeContext) -> anyhow::Result<T> + Send + Sync + 'static,
{
    run_spmd(SpmdConfig::new(nodes), f)
}
