//! SPMD launcher — the `bfrun` analogue (paper §VI-A).
//!
//! `bfrun -np N python prog.py` starts N processes running the same
//! program; here [`run_spmd`] spawns N OS threads, each with its own
//! [`NodeContext`], over a shared in-process fabric: transport endpoints,
//! virtual clocks, the negotiation service, the window table, per-node
//! communication threads and (optionally) the PJRT device service.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::compress::CompressionSpec;
use crate::context::{NodeContext, TopologyState};
use crate::negotiation::NegotiationService;
use crate::nonblocking::CommThread;
use crate::pool::HotPath;
use crate::runtime::DeviceHandle;
use crate::simnet::hetero::ComputeHeterogeneity;
use crate::simnet::NetworkModel;
use crate::timeline::Timeline;
use crate::topology::{builders, Graph, WeightMatrix};
use crate::transport::{fabric, VClock};
use crate::window::WindowTable;

/// Configuration of the asynchronous execution regime (paper §IV-C).
///
/// Two knobs, both inert unless a driver opts in:
///
/// - **Compute heterogeneity** — per-rank slowdown factors plus seeded
///   jitter ([`ComputeHeterogeneity`]), applied wherever per-step compute is
///   charged through
///   [`crate::context::NodeContext::simulate_compute_hetero`]. This makes
///   stragglers exist in virtual time for synchronous *and* asynchronous
///   runs, so the two regimes are comparable.
/// - **Staleness horizon** — the bounded-asynchrony window (virtual
///   seconds) enforced by
///   [`crate::context::NodeContext::async_throttle`]: a rank whose virtual
///   clock runs more than `horizon` ahead of the slowest still-active rank
///   yields until the laggard catches up. This is the simulator's stand-in
///   for real wall time, where a fast worker physically cannot execute
///   unbounded iterations while a peer performs one; every known
///   convergence result for asynchronous decentralized SGD assumes such a
///   bound. `f64::INFINITY` (the default) disables the throttle.
#[derive(Clone)]
pub struct AsyncSpec {
    /// Per-rank compute slowdown factors + jitter.
    pub hetero: ComputeHeterogeneity,
    /// Bounded-staleness window in virtual seconds (∞ = unthrottled).
    pub horizon: f64,
}

impl AsyncSpec {
    /// A spec with the given heterogeneity and no staleness throttle.
    pub fn new(hetero: ComputeHeterogeneity) -> Self {
        AsyncSpec { hetero, horizon: f64::INFINITY }
    }

    /// Set the bounded-staleness horizon (builder style). A good default is
    /// a few straggler step times: `k * base_step * hetero.max_factor()`.
    pub fn with_horizon(mut self, horizon: f64) -> Self {
        self.horizon = horizon;
        self
    }
}

/// Configuration of an SPMD run.
#[derive(Clone)]
pub struct SpmdConfig {
    /// Number of simulated nodes.
    pub nodes: usize,
    /// Network model (bandwidth/latency tiers).
    pub net: NetworkModel,
    /// Initial global topology; default: static exponential-2 with its
    /// doubly-stochastic weights (the paper's recommended default).
    pub topology: Option<(Graph, WeightMatrix)>,
    /// Base seed for per-node RNGs.
    pub seed: u64,
    /// Shared timeline recorder (pass one to collect traces).
    pub timeline: Option<Arc<Timeline>>,
    /// Shared PJRT device service handle (None = no XLA execution).
    pub device: Option<DeviceHandle>,
    /// Spawn per-node communication threads (required for non-blocking ops).
    pub comm_threads: bool,
    /// Tensor-fusion threshold in bytes for the communication threads.
    pub fusion_threshold: usize,
    /// Run the negotiation-service topology check before collectives.
    pub enable_topo_check: bool,
    /// Communication hot-path implementation (pooled/blocked vs naive).
    pub hot_path: HotPath,
    /// Communication compression applied to neighbor-averaging payloads
    /// (blocking and fused non-blocking), default none.
    pub compression: CompressionSpec,
    /// Asynchronous-regime configuration: per-rank compute heterogeneity
    /// and the bounded-staleness throttle. `None` (default) leaves every
    /// rank at nominal speed and every async helper a no-op.
    pub async_spec: Option<AsyncSpec>,
}

impl SpmdConfig {
    /// A sensible default: flat fast network, expo2 topology, topo check on.
    ///
    /// ```
    /// use bluefog::launcher::{run_spmd, SpmdConfig};
    /// // Four simulated nodes each report their rank.
    /// let ranks = run_spmd(SpmdConfig::new(4), |ctx| Ok(ctx.rank())).unwrap();
    /// assert_eq!(ranks, vec![0, 1, 2, 3]);
    /// ```
    pub fn new(nodes: usize) -> Self {
        SpmdConfig {
            nodes,
            net: NetworkModel::flat(10e9, 10e-6),
            topology: None,
            seed: 0xb1fe_f06,
            timeline: None,
            device: None,
            comm_threads: true,
            fusion_threshold: 2 << 20,
            enable_topo_check: true,
            hot_path: HotPath::default(),
            compression: CompressionSpec::default(),
            async_spec: None,
        }
    }

    /// Replace the network cost model.
    pub fn with_net(mut self, net: NetworkModel) -> Self {
        self.net = net;
        self
    }

    /// Set the initial global topology and weights.
    pub fn with_topology(mut self, graph: Graph, weights: WeightMatrix) -> Self {
        self.topology = Some((graph, weights));
        self
    }

    /// Set the base seed for per-node RNGs.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Attach a PJRT device service for AOT-artifact execution.
    pub fn with_device(mut self, device: DeviceHandle) -> Self {
        self.device = Some(device);
        self
    }

    /// Attach a timeline recorder to collect traces.
    pub fn with_timeline(mut self, timeline: Arc<Timeline>) -> Self {
        self.timeline = Some(timeline);
        self
    }

    /// Toggle the negotiation-service topology check.
    pub fn with_topo_check(mut self, enabled: bool) -> Self {
        self.enable_topo_check = enabled;
        self
    }

    /// Set the tensor-fusion threshold in bytes (0 disables fusion).
    pub fn with_fusion_threshold(mut self, bytes: usize) -> Self {
        self.fusion_threshold = bytes;
        self
    }

    /// Select the communication hot-path implementation (default: pooled).
    pub fn with_hot_path(mut self, hot_path: HotPath) -> Self {
        self.hot_path = hot_path;
        self
    }

    /// Apply communication compression to neighbor-averaging payloads
    /// (default: [`CompressionSpec::none`], the exact dense path).
    pub fn with_compression(mut self, compression: CompressionSpec) -> Self {
        self.compression = compression;
        self
    }

    /// Enable the asynchronous execution regime: per-rank compute
    /// heterogeneity plus (optionally) a bounded-staleness throttle.
    pub fn with_async(mut self, spec: AsyncSpec) -> Self {
        self.async_spec = Some(spec);
        self
    }
}

/// Run `f` as a single program on `cfg.nodes` simulated nodes and return
/// the per-rank results (index = rank). Any node error aborts the run.
pub fn run_spmd<T, F>(cfg: SpmdConfig, f: F) -> anyhow::Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(&mut NodeContext) -> anyhow::Result<T> + Send + Sync + 'static,
{
    let n = cfg.nodes;
    assert!(n > 0, "run_spmd needs at least one node");
    let net = Arc::new(cfg.net.clone());
    let (mailboxes, postman) = fabric(n);
    let (comm_mailboxes, comm_postman) = fabric(n);
    let clocks: Arc<Vec<VClock>> = Arc::new((0..n).map(|_| VClock::new()).collect());
    let negotiation = NegotiationService::spawn(n, cfg.net.clone());
    let timeline = cfg.timeline.clone().unwrap_or_else(|| Arc::new(Timeline::new(false)));
    let windows = Arc::new(WindowTable::new());

    let (graph, weights) = cfg.topology.clone().unwrap_or_else(|| {
        let g = builders::exponential_two(n);
        let w = WeightMatrix::uniform_pull(&g);
        (g, w)
    });
    let topology = Arc::new(RwLock::new(TopologyState::new(graph, weights)));

    // Per-rank wire-byte counters, shared between a node's blocking context
    // and its communication thread.
    let tx_bytes: Vec<Arc<AtomicU64>> = (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect();

    // Asynchronous-regime state: the shared spec plus one "done" flag per
    // rank so the bounded-staleness throttle stops waiting on ranks that
    // have left their training loop (their clocks stall forever).
    let async_spec = cfg.async_spec.clone().map(Arc::new);
    let async_done: Arc<Vec<AtomicBool>> =
        Arc::new((0..n).map(|_| AtomicBool::new(false)).collect());

    // Communication threads own the second endpoint fabric.
    let mut comm_threads = vec![];
    let mut comm_queues = vec![];
    if cfg.comm_threads {
        for (rank, mb) in comm_mailboxes.into_iter().enumerate() {
            let t = CommThread::spawn(
                rank,
                n,
                mb,
                comm_postman.clone(),
                clocks.clone(),
                net.clone(),
                cfg.fusion_threshold,
                cfg.hot_path,
                cfg.compression,
                cfg.seed,
                tx_bytes[rank].clone(),
            );
            comm_queues.push(Some(t.queue()));
            comm_threads.push(t);
        }
    } else {
        comm_queues = (0..n).map(|_| None).collect();
    }

    let f = Arc::new(f);
    let mut handles = vec![];
    for (rank, (mailbox, comm_queue)) in
        mailboxes.into_iter().zip(comm_queues.into_iter()).enumerate()
    {
        let f = f.clone();
        let mut ctx = NodeContext::new(
            rank,
            n,
            mailbox,
            postman.clone(),
            clocks.clone(),
            net.clone(),
            topology.clone(),
            negotiation.client(),
            timeline.clone(),
            windows.clone(),
            cfg.device.clone(),
            cfg.seed,
            cfg.compression,
            tx_bytes[rank].clone(),
            async_spec.clone(),
            async_done.clone(),
        );
        ctx.enable_topo_check = cfg.enable_topo_check;
        ctx.fusion_threshold = cfg.fusion_threshold;
        ctx.hot_path = cfg.hot_path;
        ctx.comm = comm_queue;
        let done_on_exit = async_done.clone();
        let handle = std::thread::Builder::new()
            .name(format!("bf-node-{rank}"))
            .stack_size(8 << 20)
            .spawn(move || {
                // Any exit — success, error, or panic — marks this rank
                // async-done, so peers spinning in `async_throttle` on its
                // stalled clock wake up and the run can surface the error
                // instead of hanging.
                struct DoneOnExit(Arc<Vec<AtomicBool>>, usize);
                impl Drop for DoneOnExit {
                    fn drop(&mut self) {
                        self.0[self.1].store(true, Ordering::Release);
                    }
                }
                let _guard = DoneOnExit(done_on_exit, rank);
                f(&mut ctx)
            })
            .expect("spawn node thread");
        handles.push(handle);
    }

    let mut results = Vec::with_capacity(n);
    let mut first_err: Option<anyhow::Error> = None;
    for (rank, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok(Ok(v)) => results.push(v),
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(e.context(format!("node {rank} failed")));
                }
            }
            Err(panic) => {
                if first_err.is_none() {
                    let msg = panic
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "unknown panic".into());
                    first_err = Some(anyhow::anyhow!("node {rank} panicked: {msg}"));
                }
            }
        }
    }
    // Keep comm threads alive until all nodes joined, then drop (shutdown).
    drop(comm_threads);
    match first_err {
        Some(e) => Err(e),
        None => Ok(results),
    }
}

/// Convenience: run with default flat network and expo2 topology.
pub fn run_simple<T, F>(nodes: usize, f: F) -> anyhow::Result<Vec<T>>
where
    T: Send + 'static,
    F: Fn(&mut NodeContext) -> anyhow::Result<T> + Send + Sync + 'static,
{
    run_spmd(SpmdConfig::new(nodes), f)
}
